//! Integration tests for the `ExplorationService` job layer and the
//! declarative experiment suite: worker-count invariance (the property
//! behind byte-identical `--jobs N` CSVs), run-cache keying, and the
//! end-to-end suite path.

use helex::cgra::Grid;
use helex::coordinator::{experiments, suite, ExperimentConfig};
use helex::dfg::benchmarks;
use helex::search::{SearchConfig, SearchEvent};
use helex::service::{ExplorationService, JobSpec, Objective, ServiceEvent};
use helex::util::prop;

fn tiny_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        l_test_base: 30,
        gsg_passes: 1,
        use_xla_scorer: false,
        ..Default::default()
    };
    cfg.mapper.seed = seed;
    cfg
}

/// The suite's emitted `(csv_basename, csv_body)` pairs for one worker
/// count (fresh service per call, so nothing is shared between runs).
fn suite_csvs(cfg: &ExperimentConfig, name: &str, jobs: usize) -> Vec<(String, String)> {
    let defs = experiments::find(name).unwrap();
    let service = ExplorationService::with_jobs(jobs);
    suite::run_suite(cfg, &defs, true, &service, None)
        .into_iter()
        .map(|(csv, table)| (csv, table.csv()))
        .collect()
}

#[test]
fn two_and_eight_worker_suites_emit_identical_tables() {
    // the deterministic-seeding property: per-job seeds derive from job
    // content, so worker count and scheduling order cannot change any
    // table cell (fig9 has no wall-clock cells, making the comparison
    // exact). Replayed over varying base seeds by the property harness.
    prop::forall("worker-count invariance", 2, 0xC6A1, |g| {
        let cfg = tiny_cfg(g.rng.next_u64());
        let two = suite_csvs(&cfg, "fig9", 2);
        let eight = suite_csvs(&cfg, "fig9", 8);
        if two != eight {
            return Err(format!(
                "fig9 tables differ between 2 and 8 workers (seed {:#x})",
                cfg.mapper.seed
            ));
        }
        if two.len() != 1 || two[0].0 != "fig9_size_sweep" {
            return Err("fig9 must emit exactly its one CSV".to_string());
        }
        Ok(())
    });
}

#[test]
fn base_seed_still_selects_independent_replications() {
    // derived seeds must not collapse distinct base seeds onto one run
    let a = suite_csvs(&tiny_cfg(1), "fig9", 2);
    let b = suite_csvs(&tiny_cfg(1), "fig9", 4);
    assert_eq!(a, b, "same base seed must reproduce exactly");
    let spec_a = JobSpec {
        seed: 1,
        ..JobSpec::new("s", benchmarks::dfg_set("S4"), Grid::new(9, 9))
    };
    let spec_b = JobSpec { seed: 2, ..spec_a.clone() };
    assert_ne!(spec_a.derived_seed(), spec_b.derived_seed());
}

#[test]
fn run_cache_keying_matches_spec_content() {
    // identical specs hit; any result-relevant field change misses
    let service = ExplorationService::with_jobs(2);
    let base = JobSpec {
        search: SearchConfig { l_test: 30, gsg_passes: 1, ..Default::default() },
        ..JobSpec::new("base", vec![benchmarks::benchmark("SOB")], Grid::new(6, 6))
    };
    let first = service.run_job(&base);
    assert!(!first.from_cache);
    assert!(service.run_job(&base).from_cache, "identical spec must hit");

    let mut relabeled = base.clone();
    relabeled.label = "other-label".into();
    assert!(service.run_job(&relabeled).from_cache, "label is not part of the key");

    let mut grid = base.clone();
    grid.grid = Grid::new(6, 7);
    assert!(!service.run_job(&grid).from_cache, "grid change must miss");

    let mut l_test = base.clone();
    l_test.search.l_test = 31;
    assert!(!service.run_job(&l_test).from_cache, "l_test change must miss");

    let mut seed = base.clone();
    seed.seed = 99;
    assert!(!service.run_job(&seed).from_cache, "seed change must miss");

    let mut objective = base.clone();
    objective.objective = Objective::Power;
    assert!(!service.run_job(&objective).from_cache, "objective change must miss");

    assert_eq!(service.cache_len(), 5);
}

#[test]
fn suite_batch_streams_progress_and_replays_event_traces() {
    let cfg = tiny_cfg(7);
    let defs = experiments::find("fig9").unwrap();
    let service = ExplorationService::with_jobs(2);
    let mut started = 0usize;
    let mut finished = 0usize;
    let mut last_done = 0usize;
    let mut cb = |ev: &ServiceEvent| match ev {
        ServiceEvent::Started { .. } => started += 1,
        ServiceEvent::Finished { done, total, .. } => {
            finished += 1;
            assert!(*done > last_done && *done <= *total);
            last_done = *done;
        }
        ServiceEvent::Improved { .. } => {}
    };
    let tables = suite::run_suite(&cfg, &defs, true, &service, Some(&mut cb));
    assert_eq!(tables.len(), 1);
    assert_eq!(started, 5, "fig9 sweeps five sizes");
    assert_eq!(finished, 5);
    // every feasible job's result carries a usable event trace
    let spec = JobSpec {
        search: cfg.search_config(Grid::new(9, 9)),
        ..JobSpec::new("probe", benchmarks::dfg_set("S4"), Grid::new(9, 9))
    };
    let r = service.run_job(&spec);
    if r.outcome.is_completed() {
        assert!(r
            .events
            .iter()
            .any(|e| matches!(e, SearchEvent::PhaseFinished { .. })));
    }
}

#[test]
fn job_id_display_fromstr_roundtrip_property() {
    // the stable-id contract: URLs and filenames render ids as
    // zero-padded hex, and parsing that form recovers exactly the
    // in-memory id — for *every* u64, not just small ones
    use helex::service::JobId;
    prop::forall("JobId roundtrip", 500, 0x1D5, |g| {
        let n = g.rng.next_u64();
        let id = JobId(n);
        let text = id.to_string();
        if !text.starts_with("job-") || text.len() != "job-".len() + 16 {
            return Err(format!("non-canonical rendering {text:?}"));
        }
        match text.parse::<JobId>() {
            Ok(back) if back == id => {}
            other => return Err(format!("{text:?} parsed to {other:?}, expected {id:?}")),
        }
        // the bare-hex convenience form parses to the same id
        match text.trim_start_matches("job-").parse::<JobId>() {
            Ok(back) if back == id => Ok(()),
            other => Err(format!("bare hex parsed to {other:?}, expected {id:?}")),
        }
    });
    // zero-padding keeps lexicographic order == numeric order
    let mut rendered: Vec<String> = [0u64, 1, 15, 16, 255, 4096, u64::MAX >> 1, u64::MAX]
        .iter()
        .map(|&n| JobId(n).to_string())
        .collect();
    let numeric = rendered.clone();
    rendered.sort();
    assert_eq!(rendered, numeric, "zero-padded hex must sort like the numbers");
    // malformed forms are rejected
    for bad in ["", "job-", "job-xyz", "job-11112222333344445", "job--1", "0x12", "12 "] {
        assert!(bad.parse::<JobId>().is_err(), "{bad:?} must not parse");
    }
}
