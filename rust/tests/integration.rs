//! Cross-module integration tests: mapper over all 20 benchmark DFGs,
//! end-to-end searches, baselines, experiments plumbing.

use helex::cgra::{Grid, Layout};
use helex::coordinator::{experiments, Coordinator, ExperimentConfig};
use helex::cost::{reduction_pct, CostModel};
use helex::dfg::{benchmarks, heta, min_group_instances};
use helex::ops::OpGroup;
use helex::search::{self, SearchConfig};
use helex::{Mapper, MappingEngine};

fn tiny_cfg() -> ExperimentConfig {
    ExperimentConfig {
        l_test_base: 60,
        gsg_passes: 1,
        use_xla_scorer: false,
        results_dir: std::env::temp_dir().join("helex_it_results"),
        ..Default::default()
    }
}

#[test]
fn all_20_benchmarks_map_on_their_paper_grids() {
    let engine = MappingEngine::default();
    // Table II set on 10x10 (the smallest size the paper says all map on)
    let dfgs = benchmarks::all();
    let full = Layout::full(Grid::new(10, 10), helex::dfg::groups_used(&dfgs));
    for d in &dfgs {
        let m = engine.map(d, &full);
        assert!(m.is_mapped(), "{} must map on 10x10: {:?}", d.name, m.failure());
        let m = m.into_mapping().unwrap();
        assert!(m.validate(d, &full).is_empty(), "{}", d.name);
    }
    // HETA set on 20x20
    let hd = heta::all();
    let big = Layout::full(Grid::new(20, 20), helex::dfg::groups_used(&hd));
    for d in &hd {
        assert!(engine.map(d, &big).is_mapped(), "{} must map on 20x20", d.name);
    }
}

#[test]
fn table_vii_sets_map_on_their_configs() {
    let engine = MappingEngine::default();
    for (id, _names, cfgs) in benchmarks::TABLE_VII {
        let dfgs = benchmarks::dfg_set(id);
        for (r, c) in cfgs {
            let full = Layout::full(Grid::new(r, c), helex::dfg::groups_used(&dfgs));
            match engine.map_all(&dfgs, &full) {
                Ok(_) => {}
                Err(fail) => panic!("{id}: {fail} on {r}x{c}"),
            }
        }
    }
}

#[test]
fn search_monotonically_dominates_baselines_on_small_case() {
    // HeLEx >= REVAMP-like hotspot in compute-instance reduction (same
    // mapper, HeLEx starts from the same overlay and only improves it).
    let dfgs = benchmarks::dfg_set("S3");
    let grid = Grid::new(10, 10);
    let mut co = Coordinator::new(tiny_cfg());
    let full = Layout::full(grid, helex::dfg::groups_used(&dfgs));
    let hotspot = helex::baselines::revamp::run(&dfgs, &full, &co.engine).unwrap();
    let r = co.run_helex(&dfgs, grid).unwrap();
    let helex_red = helex::metrics::total_reduction_pct(&r.full_layout, &r.best_layout);
    let revamp_red = helex::metrics::total_reduction_pct(&full, &hotspot.layout);
    assert!(
        helex_red >= revamp_red - 1e-9,
        "HeLEx {helex_red}% must be >= REVAMP-like {revamp_red}%"
    );
}

#[test]
fn headline_shape_small_scale() {
    // At bench scale on an 11x11 with the 12 DFGs, the headline shape
    // must hold: >=40% instance reduction, area reduction > power
    // reduction, Div/Other nearly eliminated. (10x10 starts from the
    // full layout — paper Table IV marks it * — and needs the paper's
    // L_test=2000 budget to converge; 11x11 starts from the heatmap.)
    let dfgs = benchmarks::all();
    let mut co = Coordinator::new(ExperimentConfig { l_test_base: 150, ..tiny_cfg() });
    let r = co.run_helex(&dfgs, Grid::new(11, 11)).expect("11x11 must be feasible");
    let inst_red = helex::metrics::total_reduction_pct(&r.full_layout, &r.best_layout);
    assert!(inst_red > 40.0, "instance reduction only {inst_red}%");
    let a_red = reduction_pct(
        co.area.layout_cost(&r.full_layout),
        co.area.layout_cost(&r.best_layout),
    );
    let p_red = reduction_pct(
        co.power.layout_cost(&r.full_layout),
        co.power.layout_cost(&r.best_layout),
    );
    assert!(a_red > p_red, "area {a_red}% must exceed power {p_red}%");
    // Div is needed at most 3 times across DFGs but provisioned 64 times
    let n = r.best_layout.compute_group_instances();
    let mins = min_group_instances(&dfgs);
    assert!(
        n[OpGroup::Div.index()] <= mins[OpGroup::Div.index()] + 6,
        "Div instances {} vs min {}",
        n[OpGroup::Div.index()],
        mins[OpGroup::Div.index()]
    );
}

#[test]
fn selective_testing_is_sound() {
    // OPSG's selective testing must never admit a layout that breaks an
    // unaffected DFG: verify final layouts against the FULL set.
    let dfgs = benchmarks::dfg_set("S2");
    let mut co = Coordinator::new(tiny_cfg());
    let r = co.run_helex(&dfgs, Grid::new(9, 9)).unwrap();
    for (di, d) in dfgs.iter().enumerate() {
        let errs = r.final_mappings[di].validate(d, &r.best_layout);
        assert!(errs.is_empty(), "{}: {errs:?}", d.name);
    }
}

#[test]
fn nogsg_never_beats_full_search() {
    let dfgs = benchmarks::dfg_set("S3");
    let grid = Grid::new(10, 10);
    let mapper = Mapper::default();
    let cost = CostModel::area();
    let full_cfg = SearchConfig { l_test: 200, gsg_passes: 1, ..Default::default() };
    let nogsg_cfg = SearchConfig { run_gsg: false, ..full_cfg.clone() };
    let a = search::run(&dfgs, grid, &mapper, &cost, &full_cfg, None).unwrap();
    let b = search::run(&dfgs, grid, &mapper, &cost, &nogsg_cfg, None).unwrap();
    assert!(
        a.best_cost <= b.best_cost + 1e-9,
        "full {} must be <= noGSG {}",
        a.best_cost,
        b.best_cost
    );
}

#[test]
fn experiments_smoke_and_csv_emission() {
    let mut co = Coordinator::new(ExperimentConfig { l_test_base: 30, ..tiny_cfg() });
    // fig9 exercises the multi-size sweep path end to end
    experiments::run_experiment(&mut co, "fig9", true).unwrap();
    let csv = co.cfg.results_dir.join("fig9_size_sweep.csv");
    assert!(csv.exists(), "CSV not written: {}", csv.display());
    let body = std::fs::read_to_string(csv).unwrap();
    assert!(body.lines().count() >= 3, "CSV too short:\n{body}");
}

#[test]
fn latency_ratios_bounded() {
    // Fig 10 shape: hetero/full latency ratios stay modest (< 2x).
    let dfgs = benchmarks::dfg_set("S4");
    let mut co = Coordinator::new(tiny_cfg());
    let r = co.run_helex(&dfgs, Grid::new(9, 9)).unwrap();
    for (di, d) in dfgs.iter().enumerate() {
        let ratio = helex::metrics::latency_ratio_with_witness(
            &co.engine,
            d,
            &r.full_layout,
            &r.final_mappings[di],
        )
        .expect("full layout maps");
        assert!(ratio < 2.0, "{}: latency ratio {ratio}", d.name);
        assert!(ratio > 0.5, "{}: latency ratio {ratio}", d.name);
    }
}

#[test]
fn cli_binary_basic_invocations() {
    // run the built binary for usage + show-dfg; this keeps the CLI wired
    let exe = env!("CARGO_BIN_EXE_helex");
    let out = std::process::Command::new(exe).output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));

    let out = std::process::Command::new(exe)
        .args(["show-dfg", "BIL"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("V=26"), "{s}");
    assert!(s.contains("Div"), "{s}");

    let out = std::process::Command::new(exe)
        .args(["map", "--dfg", "SOB", "--size", "6x6", "--no-xla"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("mapped"));
}
