//! Cross-module integration tests: mapper over all 20 benchmark DFGs,
//! end-to-end searches, baselines, experiments plumbing.

use helex::cgra::{Grid, Layout};
use helex::coordinator::{experiments, Coordinator, ExperimentConfig};
use helex::cost::{reduction_pct, CostModel};
use helex::dfg::{benchmarks, heta, min_group_instances};
use helex::ops::OpGroup;
use helex::search::{self, SearchConfig};
use helex::{Mapper, MappingEngine};

fn tiny_cfg() -> ExperimentConfig {
    ExperimentConfig {
        l_test_base: 60,
        gsg_passes: 1,
        use_xla_scorer: false,
        results_dir: std::env::temp_dir().join("helex_it_results"),
        ..Default::default()
    }
}

#[test]
fn all_20_benchmarks_map_on_their_paper_grids() {
    let engine = MappingEngine::default();
    // Table II set on 10x10 (the smallest size the paper says all map on)
    let dfgs = benchmarks::all();
    let full = Layout::full(Grid::new(10, 10), helex::dfg::groups_used(&dfgs));
    for d in &dfgs {
        let m = engine.map(d, &full);
        assert!(m.is_mapped(), "{} must map on 10x10: {:?}", d.name, m.failure());
        let m = m.into_mapping().unwrap();
        assert!(m.validate(d, &full).is_empty(), "{}", d.name);
    }
    // HETA set on 20x20
    let hd = heta::all();
    let big = Layout::full(Grid::new(20, 20), helex::dfg::groups_used(&hd));
    for d in &hd {
        assert!(engine.map(d, &big).is_mapped(), "{} must map on 20x20", d.name);
    }
}

#[test]
fn table_vii_sets_map_on_their_configs() {
    let engine = MappingEngine::default();
    for (id, _names, cfgs) in benchmarks::TABLE_VII {
        let dfgs = benchmarks::dfg_set(id);
        for (r, c) in cfgs {
            let full = Layout::full(Grid::new(r, c), helex::dfg::groups_used(&dfgs));
            match engine.map_all(&dfgs, &full) {
                Ok(_) => {}
                Err(fail) => panic!("{id}: {fail} on {r}x{c}"),
            }
        }
    }
}

#[test]
fn search_monotonically_dominates_baselines_on_small_case() {
    // HeLEx >= REVAMP-like hotspot in compute-instance reduction (same
    // mapper, HeLEx starts from the same overlay and only improves it).
    let dfgs = benchmarks::dfg_set("S3");
    let grid = Grid::new(10, 10);
    let mut co = Coordinator::new(tiny_cfg());
    let full = Layout::full(grid, helex::dfg::groups_used(&dfgs));
    let hotspot = helex::baselines::revamp::run(&dfgs, &full, &co.engine).unwrap();
    let r = co.run_helex(&dfgs, grid).unwrap();
    let helex_red = helex::metrics::total_reduction_pct(&r.full_layout, &r.best_layout);
    let revamp_red = helex::metrics::total_reduction_pct(&full, &hotspot.layout);
    assert!(
        helex_red >= revamp_red - 1e-9,
        "HeLEx {helex_red}% must be >= REVAMP-like {revamp_red}%"
    );
}

#[test]
fn headline_shape_small_scale() {
    // At bench scale on an 11x11 with the 12 DFGs, the headline shape
    // must hold: >=40% instance reduction, area reduction > power
    // reduction, Div/Other nearly eliminated. (10x10 starts from the
    // full layout — paper Table IV marks it * — and needs the paper's
    // L_test=2000 budget to converge; 11x11 starts from the heatmap.)
    let dfgs = benchmarks::all();
    let mut co = Coordinator::new(ExperimentConfig { l_test_base: 150, ..tiny_cfg() });
    let r = co.run_helex(&dfgs, Grid::new(11, 11)).expect("11x11 must be feasible");
    let inst_red = helex::metrics::total_reduction_pct(&r.full_layout, &r.best_layout);
    assert!(inst_red > 40.0, "instance reduction only {inst_red}%");
    let a_red = reduction_pct(
        co.area.layout_cost(&r.full_layout),
        co.area.layout_cost(&r.best_layout),
    );
    let p_red = reduction_pct(
        co.power.layout_cost(&r.full_layout),
        co.power.layout_cost(&r.best_layout),
    );
    assert!(a_red > p_red, "area {a_red}% must exceed power {p_red}%");
    // Div is needed at most 3 times across DFGs but provisioned 64 times
    let n = r.best_layout.compute_group_instances();
    let mins = min_group_instances(&dfgs);
    assert!(
        n[OpGroup::Div.index()] <= mins[OpGroup::Div.index()] + 6,
        "Div instances {} vs min {}",
        n[OpGroup::Div.index()],
        mins[OpGroup::Div.index()]
    );
}

#[test]
fn selective_testing_is_sound() {
    // OPSG's selective testing must never admit a layout that breaks an
    // unaffected DFG: verify final layouts against the FULL set.
    let dfgs = benchmarks::dfg_set("S2");
    let mut co = Coordinator::new(tiny_cfg());
    let r = co.run_helex(&dfgs, Grid::new(9, 9)).unwrap();
    for (di, d) in dfgs.iter().enumerate() {
        let errs = r.final_mappings[di].validate(d, &r.best_layout);
        assert!(errs.is_empty(), "{}: {errs:?}", d.name);
    }
}

#[test]
fn nogsg_never_beats_full_search() {
    let dfgs = benchmarks::dfg_set("S3");
    let grid = Grid::new(10, 10);
    let mapper = Mapper::default();
    let cost = CostModel::area();
    let full_cfg = SearchConfig { l_test: 200, gsg_passes: 1, ..Default::default() };
    let nogsg_cfg = SearchConfig { run_gsg: false, ..full_cfg.clone() };
    let a = search::run(&dfgs, grid, &mapper, &cost, &full_cfg, None).unwrap();
    let b = search::run(&dfgs, grid, &mapper, &cost, &nogsg_cfg, None).unwrap();
    assert!(
        a.best_cost <= b.best_cost + 1e-9,
        "full {} must be <= noGSG {}",
        a.best_cost,
        b.best_cost
    );
}

#[test]
fn experiments_smoke_and_csv_emission() {
    let mut co = Coordinator::new(ExperimentConfig { l_test_base: 30, ..tiny_cfg() });
    // fig9 exercises the multi-size sweep path end to end
    experiments::run_experiment(&mut co, "fig9", true).unwrap();
    let csv = co.cfg.results_dir.join("fig9_size_sweep.csv");
    assert!(csv.exists(), "CSV not written: {}", csv.display());
    let body = std::fs::read_to_string(csv).unwrap();
    assert!(body.lines().count() >= 3, "CSV too short:\n{body}");
}

#[test]
fn latency_ratios_bounded() {
    // Fig 10 shape: hetero/full latency ratios stay modest (< 2x).
    let dfgs = benchmarks::dfg_set("S4");
    let mut co = Coordinator::new(tiny_cfg());
    let r = co.run_helex(&dfgs, Grid::new(9, 9)).unwrap();
    for (di, d) in dfgs.iter().enumerate() {
        let ratio = helex::metrics::latency_ratio_with_witness(
            &co.engine,
            d,
            &r.full_layout,
            &r.final_mappings[di],
        )
        .expect("full layout maps");
        assert!(ratio < 2.0, "{}: latency ratio {ratio}", d.name);
        assert!(ratio > 0.5, "{}: latency ratio {ratio}", d.name);
    }
}

#[test]
fn jam_scenarios_pin_both_routers() {
    // The canonical congestion scenario, pinned through the public API
    // for both routers: four distinct values must cross the cut between
    // columns 3 and 4 eastbound, but a 3-row Mesh4 grid has only three
    // eastbound cap-1 links per cut — routing must report congestion.
    // Doubling link capacity or adding express stride-2 links clears
    // the jam for both routers, and the cleared mappings validate.
    use helex::cgra::CellId;
    use helex::dfg::Dfg;
    use helex::fabric::{FabricSpec, Topology};
    use helex::mapper::route::{route, steiner_route, RouteOutcome, RouterArena};
    use helex::mapper::{Mapping, MapperConfig};
    use helex::ops::{GroupSet, Op};

    let jam_dfg = || {
        Dfg::new(
            "jam",
            vec![
                Op::Load,
                Op::Load,
                Op::Load,
                Op::Load,
                Op::Add,
                Op::Add,
                Op::Add,
                Op::Add,
                Op::Store,
                Op::Store,
                Op::Store,
                Op::Store,
            ],
            vec![(0, 4), (1, 5), (2, 6), (3, 7), (4, 8), (5, 9), (6, 10), (7, 11)],
        )
    };
    let jam_placement = |l: &Layout| -> Vec<CellId> {
        let g = &l.grid;
        vec![
            g.cell(0, 0),
            g.cell(0, 1),
            g.cell(0, 2),
            g.cell(0, 3),
            g.cell(1, 4),
            g.cell(1, 5),
            g.cell(1, 6),
            g.cell(1, 7),
            g.cell(2, 4),
            g.cell(2, 5),
            g.cell(2, 6),
            g.cell(2, 7),
        ]
    };
    let d = jam_dfg();
    let legacy_cfg = MapperConfig { route_iters: 3, ..Default::default() };
    let steiner_cfg =
        MapperConfig { router_steiner: true, route_iters: 3, ..Default::default() };
    let mut arena = RouterArena::new();

    // cap-1 Mesh4: both routers must diagnose the jam
    let mesh = Layout::full(Grid::new(3, 9), GroupSet::all_compute());
    let p = jam_placement(&mesh);
    assert!(
        matches!(route(&d, &mesh, &p, &legacy_cfg), RouteOutcome::Congested { .. }),
        "legacy router must report the Mesh4 jam"
    );
    assert!(
        matches!(
            steiner_route(&d, &mesh, &p, &steiner_cfg, &mut arena),
            RouteOutcome::Congested { .. }
        ),
        "steiner router must report the Mesh4 jam"
    );

    // capacity 2 or express stride-2 links clear it for both routers
    let fixes = [
        FabricSpec { link_cap: 2, ..Default::default() },
        FabricSpec { topology: Topology::Express { stride: 2 }, ..Default::default() },
    ];
    for spec in fixes {
        let l = Layout::full_on(spec.build(Grid::new(3, 9)), GroupSet::all_compute());
        let p = jam_placement(&l);
        let RouteOutcome::Routed(paths) = route(&d, &l, &p, &legacy_cfg) else {
            panic!("{} must clear the jam for the legacy router", spec.describe());
        };
        let m = Mapping { node_cell: p.clone(), edge_paths: paths, reserved: vec![] };
        assert!(m.validate(&d, &l).is_empty(), "{}", spec.describe());
        let RouteOutcome::Routed(paths) = steiner_route(&d, &l, &p, &steiner_cfg, &mut arena)
        else {
            panic!("{} must clear the jam for the steiner router", spec.describe());
        };
        let m = Mapping { node_cell: p, edge_paths: paths, reserved: vec![] };
        assert!(m.validate(&d, &l).is_empty(), "{}", spec.describe());
    }
}

#[test]
fn steiner_engine_matches_legacy_on_benchmark_corpus() {
    // end-to-end feasibility parity on the paper's Table II set: the
    // Steiner engine (with and without criticality weighting) agrees
    // with the legacy engine on every benchmark at 10x10, and its
    // mappings pass full validation.
    let dfgs = benchmarks::all();
    let full = Layout::full(Grid::new(10, 10), helex::dfg::groups_used(&dfgs));
    let legacy = MappingEngine::default();
    for crit in [false, true] {
        let engine = MappingEngine::new(helex::MapperConfig {
            router_steiner: true,
            router_criticality: crit,
            ..Default::default()
        });
        for d in &dfgs {
            let a = legacy.map(d, &full).is_mapped();
            let m = engine.map(d, &full);
            assert_eq!(
                a,
                m.is_mapped(),
                "{} (crit={crit}): routers disagree on feasibility",
                d.name
            );
            if let Some(m) = m.into_mapping() {
                assert!(m.validate(d, &full).is_empty(), "{} (crit={crit})", d.name);
            }
        }
    }
}

#[test]
fn cli_binary_basic_invocations() {
    // run the built binary for usage + show-dfg; this keeps the CLI wired
    let exe = env!("CARGO_BIN_EXE_helex");
    let out = std::process::Command::new(exe).output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));

    let out = std::process::Command::new(exe)
        .args(["show-dfg", "BIL"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("V=26"), "{s}");
    assert!(s.contains("Div"), "{s}");

    let out = std::process::Command::new(exe)
        .args(["map", "--dfg", "SOB", "--size", "6x6", "--no-xla"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("mapped"));
}
