//! Integration tests of fabric provisioning: the pinned congestion
//! case (a net set Congested on Mesh4 that routes on Express links at
//! the same grid size) and its objective-space consequence (the richer
//! fabric's synthesis surcharge shows up in the layout's ParetoPoint).

use helex::cgra::{CellId, Grid, Layout};
use helex::dfg::Dfg;
use helex::fabric::{Fabric, FabricSpec, Topology};
use helex::mapper::route::{route, RouteOutcome};
use helex::mapper::{Mapping, MapperConfig};
use helex::ops::{GroupSet, Op};
use helex::search::pareto::evaluate;

/// Four parallel LOAD→ADD→STORE streams pinned so every LOAD→ADD net
/// must cross the row-0/row-1 boundary between columns 3 and 4. Mesh4
/// gives that cut fewer directed links than there are values, so
/// PathFinder must report Congested; express skip links widen the cut.
fn jam_case(spec: FabricSpec) -> (Dfg, Layout, Vec<CellId>) {
    let d = Dfg::new(
        "jam",
        vec![
            Op::Load,
            Op::Load,
            Op::Load,
            Op::Load,
            Op::Add,
            Op::Add,
            Op::Add,
            Op::Add,
            Op::Store,
            Op::Store,
            Op::Store,
            Op::Store,
        ],
        vec![(0, 4), (1, 5), (2, 6), (3, 7), (4, 8), (5, 9), (6, 10), (7, 11)],
    );
    let l = Layout::full_on(Fabric::new(Grid::new(3, 9), spec), GroupSet::all_compute());
    let g = &l.grid;
    let p = vec![
        g.cell(0, 0),
        g.cell(0, 1),
        g.cell(0, 2),
        g.cell(0, 3),
        g.cell(1, 4),
        g.cell(1, 5),
        g.cell(1, 6),
        g.cell(1, 7),
        g.cell(2, 4),
        g.cell(2, 5),
        g.cell(2, 6),
        g.cell(2, 7),
    ];
    (d, l, p)
}

#[test]
fn pinned_jam_is_congested_on_mesh4_and_routes_on_express() {
    let cfg = MapperConfig { route_iters: 3, ..Default::default() };

    let (d, l, p) = jam_case(FabricSpec::default());
    match route(&d, &l, &p, &cfg) {
        RouteOutcome::Congested { hot_links, overuse, .. } => {
            assert!(!hot_links.is_empty(), "congestion must name the hot links");
            assert!(overuse > 0);
        }
        RouteOutcome::Routed(_) => panic!("4 values across a 3-link Mesh4 cut must congest"),
    }

    let express =
        FabricSpec { topology: Topology::Express { stride: 2 }, ..FabricSpec::default() };
    let (d, l, p) = jam_case(express);
    match route(&d, &l, &p, &cfg) {
        RouteOutcome::Routed(paths) => {
            let m = Mapping { node_cell: p, edge_paths: paths, reserved: vec![] };
            assert!(m.validate(&d, &l).is_empty(), "express witness must validate");
        }
        RouteOutcome::Congested { .. } => {
            panic!("express skip links must clear the jam at the same grid size")
        }
    }
}

#[test]
fn express_fabric_synth_surcharge_shows_in_its_pareto_point() {
    let grid = Grid::new(3, 9);
    let mesh4 =
        Layout::full_on(Fabric::new(grid, FabricSpec::default()), GroupSet::all_compute());
    let express_spec =
        FabricSpec { topology: Topology::Express { stride: 2 }, ..FabricSpec::default() };
    let express = Layout::full_on(Fabric::new(grid, express_spec), GroupSet::all_compute());

    let a = evaluate(&mesh4);
    let b = evaluate(&express);
    // same compute provisioning, so the whole delta is the fabric
    assert_eq!(a.ops, b.ops);
    assert!(
        b.area_um2 > a.area_um2,
        "express links must cost synth area: {} vs {}",
        b.area_um2,
        a.area_um2
    );
    assert!(
        b.power_uw > a.power_uw,
        "express links must cost synth power: {} vs {}",
        b.power_uw,
        a.power_uw
    );
    // the fabric participates in layout identity, so both points can
    // coexist on one front
    assert_ne!(a.fingerprint, b.fingerprint);
}
