//! Runtime integration: the rust PJRT client must load the AOT artifacts
//! (built by `make artifacts`) and produce costs identical to the native
//! cost model — the end-to-end proof that all three layers compose.
//!
//! These tests are skipped (with a loud message) when artifacts are
//! missing, so `cargo test` works pre-`make artifacts`; `make test`
//! always builds artifacts first.

use helex::cgra::{Grid, Layout};
use helex::cost::CostModel;
use helex::ops::{GroupSet, OpGroup, NUM_GROUPS};
use helex::runtime::{artifacts_dir, cross_check, Scorer};
use helex::search::BatchScorer;

fn scorer_or_skip() -> Option<Scorer> {
    match Scorer::load(&artifacts_dir(), &CostModel::area()) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIPPING runtime integration ({e})");
            None
        }
    }
}

#[test]
fn scorer_matches_native_cost_model_on_layouts() {
    let Some(mut scorer) = scorer_or_skip() else { return };
    let cost = CostModel::area();
    let grid = Grid::new(10, 10);
    let full = Layout::full(grid, GroupSet::all_compute());
    let mut variants = vec![full.clone()];
    // a few heterogeneous variants
    let cells: Vec<_> = grid.compute_cells().collect();
    for (i, &c) in cells.iter().take(8).enumerate() {
        let g = helex::ops::COMPUTE_GROUPS[i % 5];
        variants.push(variants[i].without_group(c, g));
    }
    let xla = scorer.score_layouts(&variants).unwrap();
    for (l, &x) in variants.iter().zip(&xla) {
        let native = cost.layout_cost(l);
        assert!(
            (x - native).abs() < 1e-2,
            "XLA {x} vs native {native} for layout"
        );
    }
}

#[test]
fn scorer_instance_vectors_match_native() {
    let Some(mut scorer) = scorer_or_skip() else { return };
    let cost = CostModel::area();
    let vectors: Vec<[usize; NUM_GROUPS]> = vec![
        [64, 64, 64, 0, 64, 64],
        [10, 2, 5, 0, 6, 3],
        [0, 0, 0, 0, 0, 0],
        [1, 0, 0, 0, 0, 0],
    ];
    let got = scorer.score(64, &vectors);
    for (v, &g) in vectors.iter().zip(&got) {
        let base = 64.0 * (cost.components.empty_cell + cost.components.fifos);
        let want = base + cost.instances_cost(v);
        assert!((g - want).abs() < 1e-2, "{g} vs {want} for {v:?}");
    }
}

#[test]
fn scorer_handles_oversized_batches() {
    let Some(mut scorer) = scorer_or_skip() else { return };
    // 300 > BATCH=256 forces chunking
    let vectors: Vec<[usize; NUM_GROUPS]> =
        (0..300).map(|i| [i % 60, 0, 0, 0, i % 10, 0]).collect();
    let got = scorer.score(36, &vectors);
    assert_eq!(got.len(), 300);
    let cost = CostModel::area();
    let base = 36.0 * (cost.components.empty_cell + cost.components.fifos);
    for (v, &g) in vectors.iter().zip(&got) {
        let want = base + cost.instances_cost(v);
        assert!((g - want).abs() < 1e-2);
    }
}

#[test]
fn cross_check_helper_passes() {
    let Some(mut scorer) = scorer_or_skip() else { return };
    let grid = Grid::new(12, 12);
    let full = Layout::full(grid, GroupSet::all_compute());
    let hetero = full.without_group(grid.cell(2, 3), OpGroup::Div);
    let err = cross_check(&mut scorer, &CostModel::area(), &[full, hetero]).unwrap();
    assert!(err < 1e-3, "max rel err {err}");
}

#[test]
fn heatmap_artifact_matches_native_heatmap() {
    let Some(mut scorer) = scorer_or_skip() else { return };
    if !scorer.has_heatmap_artifact() {
        eprintln!("SKIPPING heatmap artifact test");
        return;
    }
    // build usage bitmaps from real mappings of two DFGs
    let dfgs = vec![
        helex::dfg::benchmarks::benchmark("SOB"),
        helex::dfg::benchmarks::benchmark("GB"),
    ];
    let grid = Grid::new(8, 8);
    let full = Layout::full(grid, helex::dfg::groups_used(&dfgs));
    let engine = helex::MappingEngine::default();
    let mut usage = Vec::new();
    for d in &dfgs {
        let m = engine.map(d, &full).into_mapping().unwrap();
        let mut cells = vec![[0f32; NUM_GROUPS]; grid.num_cells()];
        for (n, op) in d.nodes.iter().enumerate() {
            cells[m.node_cell[n] as usize][op.group().index()] = 1.0;
        }
        usage.push(cells);
    }
    let (heat, mins) = scorer.heatmap_stats(&usage).unwrap();
    // mins must equal native min_group_instances
    let native = helex::dfg::min_group_instances(&dfgs);
    for g in helex::ops::ALL_GROUPS {
        assert_eq!(mins[g.index()] as usize, native[g.index()], "group {g}");
    }
    // union: heat cell is 1 iff some DFG used it
    for (c, row) in heat.iter().enumerate().take(grid.num_cells()) {
        for g in 0..NUM_GROUPS {
            let want = usage.iter().any(|u| u[c][g] > 0.0);
            assert_eq!(row[g] > 0.0, want, "cell {c} group {g}");
        }
    }
}

#[test]
fn end_to_end_search_with_xla_scorer_matches_native() {
    let Some(mut scorer) = scorer_or_skip() else { return };
    let dfgs = vec![helex::dfg::benchmarks::benchmark("SOB")];
    let grid = Grid::new(5, 5);
    let mapper = helex::Mapper::default();
    let cost = CostModel::area();
    let cfg = helex::search::SearchConfig { l_test: 60, gsg_passes: 1, ..Default::default() };
    let with_xla =
        helex::search::run(&dfgs, grid, &mapper, &cost, &cfg, Some(&mut scorer)).unwrap();
    let native = helex::search::run(&dfgs, grid, &mapper, &cost, &cfg, None).unwrap();
    assert!(
        (with_xla.best_cost - native.best_cost).abs() < 1e-6,
        "scorer changed the search: {} vs {}",
        with_xla.best_cost,
        native.best_cost
    );
}
