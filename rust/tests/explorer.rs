//! Integration tests for the `Explorer` session API: builder defaults
//! and validation, observer event-stream invariants, custom phase
//! pipelines, engine sharing, parity with the legacy `search::run`
//! and `.mapper(..)` compatibility surfaces, and the deterministic
//! parallel-search contract (`search_threads` can never change a
//! result).

use helex::cgra::{Grid, Layout};
use helex::cost::CostModel;
use helex::dfg::benchmarks;
use helex::search::{
    self, ExploreError, Explorer, GsgPhase, HeatmapPhase, OpsgPhase, SearchConfig, SearchCtx,
    SearchEvent, SearchPhase,
};
use helex::{Mapper, MappingEngine};

fn small_cfg() -> SearchConfig {
    SearchConfig { l_test: 120, l_fail: 2, gsg_passes: 1, ..Default::default() }
}

#[test]
fn builder_requires_dfgs() {
    assert_eq!(
        Explorer::new(Grid::new(6, 6)).run().unwrap_err(),
        ExploreError::MissingDfgs
    );
    let empty: Vec<helex::Dfg> = Vec::new();
    assert_eq!(
        Explorer::new(Grid::new(6, 6)).dfgs(&empty).run().unwrap_err(),
        ExploreError::MissingDfgs
    );
}

#[test]
fn builder_rejects_empty_pipeline() {
    let dfgs = vec![benchmarks::benchmark("SOB")];
    assert_eq!(
        Explorer::new(Grid::new(6, 6)).dfgs(&dfgs).phases(Vec::new()).run().unwrap_err(),
        ExploreError::EmptyPipeline
    );
}

#[test]
fn builder_defaults_mapper_and_cost() {
    // only grid + DFGs + a small budget: mapper, cost model and the
    // default heatmap -> OPSG -> GSG pipeline are filled in.
    let dfgs = vec![benchmarks::benchmark("SOB")];
    let r = Explorer::new(Grid::new(6, 6)).dfgs(&dfgs).config(small_cfg()).run().unwrap();
    let cost = CostModel::area(); // the documented default objective
    assert!(r.best_cost < cost.layout_cost(&r.full_layout));
    assert!((r.best_cost - cost.layout_cost(&r.best_layout)).abs() < 1e-9);
    assert_eq!(r.final_mappings.len(), dfgs.len());
}

#[test]
fn infeasible_set_is_an_error_not_a_panic() {
    let dfgs = vec![benchmarks::benchmark("SAD")]; // 63 compute ops
    let err = Explorer::new(Grid::new(5, 5)) // 9 compute cells
        .dfgs(&dfgs)
        .config(small_cfg())
        .run()
        .unwrap_err();
    assert!(matches!(err, ExploreError::Infeasible(_)), "{err:?}");
    // and the legacy wrapper maps it to None
    assert!(search::run(
        &dfgs,
        Grid::new(5, 5),
        &Mapper::default(),
        &CostModel::area(),
        &small_cfg(),
        None
    )
    .is_none());
}

#[test]
fn observer_event_stream_is_well_formed() {
    let dfgs = vec![benchmarks::benchmark("SOB"), benchmarks::benchmark("GB")];
    let mut events: Vec<SearchEvent> = Vec::new();
    let mut obs = |ev: &SearchEvent| events.push(ev.clone());
    let r = Explorer::new(Grid::new(6, 6))
        .dfgs(&dfgs)
        .config(small_cfg())
        .observer(&mut obs)
        .run()
        .unwrap();

    // every PhaseStarted has a matching PhaseFinished, in order, and
    // phases do not overlap
    let mut open: Option<String> = None;
    let mut finished: Vec<String> = Vec::new();
    for ev in &events {
        match ev {
            SearchEvent::PhaseStarted { phase, .. } => {
                assert!(open.is_none(), "phase {phase} started inside {open:?}");
                open = Some(phase.clone());
            }
            SearchEvent::PhaseFinished { phase, .. } => {
                assert_eq!(open.as_deref(), Some(phase.as_str()));
                finished.push(open.take().unwrap());
            }
            _ => assert!(open.is_some(), "event outside any phase: {ev:?}"),
        }
    }
    assert!(open.is_none(), "unfinished phase {open:?}");
    assert_eq!(finished, vec!["heatmap", "OPSG", "GSG"]);

    // Improved costs are monotonically non-increasing across the session
    let improved: Vec<f64> = events
        .iter()
        .filter_map(|ev| match ev {
            SearchEvent::Improved { best_cost, .. } => Some(*best_cost),
            _ => None,
        })
        .collect();
    assert!(!improved.is_empty());
    assert!(improved.windows(2).all(|w| w[1] <= w[0] + 1e-9), "{improved:?}");
    assert!((improved.last().unwrap() - r.best_cost).abs() < 1e-9);

    // the event stream is the trace: one LayoutTested per mapper test,
    // one Improved per trace point
    let tested_events =
        events.iter().filter(|e| matches!(e, SearchEvent::LayoutTested { .. })).count();
    assert_eq!(tested_events, r.stats.tested);
    assert_eq!(improved.len(), r.stats.trace.len());
}

#[test]
fn explorer_matches_legacy_run_wrapper() {
    // parity on two benchmark DFGs: the default pipeline must produce
    // the same SearchResult whether the engine is passed directly, built
    // from the legacy `.mapper(..)` shim, or reached via `search::run`
    // (the engine is deterministic per seed).
    let dfgs = vec![benchmarks::benchmark("SOB"), benchmarks::benchmark("GB")];
    let grid = Grid::new(7, 7);
    let engine = MappingEngine::default();
    let mapper = Mapper::default();
    let cost = CostModel::area();
    let cfg = small_cfg();

    let a = Explorer::new(grid)
        .dfgs(&dfgs)
        .engine(&engine)
        .cost(&cost)
        .config(cfg.clone())
        .run()
        .unwrap();
    let b = search::run(&dfgs, grid, &mapper, &cost, &cfg, None).unwrap();
    let c = Explorer::new(grid)
        .dfgs(&dfgs)
        .mapper(&mapper)
        .cost(&cost)
        .config(cfg.clone())
        .run()
        .unwrap();

    for other in [&b, &c] {
        assert_eq!(a.best_cost, other.best_cost);
        assert_eq!(a.best_layout, other.best_layout);
        assert_eq!(a.initial_layout, other.initial_layout);
        assert_eq!(a.min_insts, other.min_insts);
        assert_eq!(a.stats.tested, other.stats.tested);
        assert_eq!(a.stats.expanded, other.stats.expanded);
        assert_eq!(a.stats.trace.len(), other.stats.trace.len());
    }
}

#[test]
fn shared_engine_cache_persists_across_sessions() {
    // a shared engine accumulates feasibility-cache entries; a second
    // session over the same DFGs reuses them and lands on the same result
    let dfgs = vec![benchmarks::benchmark("SOB")];
    let grid = Grid::new(6, 6);
    let engine = MappingEngine::default();
    let cost = CostModel::area();
    let a = Explorer::new(grid)
        .dfgs(&dfgs)
        .engine(&engine)
        .cost(&cost)
        .config(small_cfg())
        .run()
        .unwrap();
    let filled = engine.cache_len();
    assert!(filled > 0, "a session must populate the shared cache");
    let b = Explorer::new(grid)
        .dfgs(&dfgs)
        .engine(&engine)
        .cost(&cost)
        .config(small_cfg())
        .run()
        .unwrap();
    assert_eq!(a.best_cost, b.best_cost);
    assert_eq!(a.best_layout, b.best_layout);
    assert!(engine.cache_len() >= filled);
}

/// A do-nothing phase: exercises the pluggable-pipeline seam from
/// outside the crate.
struct NullPhase;

impl SearchPhase for NullPhase {
    fn name(&self) -> &str {
        "null"
    }

    fn run(&mut self, incumbent: Layout, _ctx: &mut SearchCtx) -> Layout {
        incumbent
    }
}

#[test]
fn custom_phase_pipeline_plugs_in() {
    let dfgs = vec![benchmarks::benchmark("SOB")];
    let grid = Grid::new(6, 6);
    let cost = CostModel::area();
    // heatmap only + a custom no-op phase: the result is the initial
    // layout, untouched, and the custom phase shows up in the stats.
    let r = Explorer::new(grid)
        .dfgs(&dfgs)
        .cost(&cost)
        .config(small_cfg())
        .phases(vec![Box::new(HeatmapPhase), Box::new(NullPhase)])
        .run()
        .unwrap();
    assert_eq!(r.best_layout, r.initial_layout);
    assert_eq!(r.stats.phase_secs.len(), 2);
    assert_eq!(r.stats.insts_after_phase[1].0, "null");
    assert!(r.stats.insts_after("null").is_some());

    // the standard pipeline is reproducible via default_phases + phase()
    let full = Explorer::new(grid)
        .dfgs(&dfgs)
        .cost(&cost)
        .config(small_cfg())
        .phases(Explorer::default_phases(&small_cfg()))
        .phase(Box::new(NullPhase))
        .run()
        .unwrap();
    let names: Vec<&str> =
        full.stats.phase_secs.iter().map(|(n, _)| n.as_str()).collect();
    assert_eq!(names, vec![HeatmapPhase::NAME, OpsgPhase::NAME, GsgPhase::NAME, "null"]);
}

/// Everything result-relevant about one session, with the volatile
/// fields (wall clocks, worker tags) normalized away. Two runs of the
/// same spec must produce *equal* summaries at any thread count.
#[derive(Debug, Clone, PartialEq)]
struct RunSummary {
    outcome: Result<(), String>,
    best_cost_bits: u64,
    best_layout: Option<Layout>,
    tested: usize,
    expanded: usize,
    node_cells: Vec<Vec<helex::cgra::CellId>>,
    trace: Vec<(String, usize, u64)>,
    events: Vec<SearchEvent>,
}

fn normalize_event(ev: &SearchEvent) -> SearchEvent {
    match ev {
        SearchEvent::Improved { best_cost, tested, .. } => {
            SearchEvent::Improved { best_cost: *best_cost, tested: *tested, secs: 0.0 }
        }
        SearchEvent::PhaseFinished { phase, best_cost, .. } => {
            SearchEvent::PhaseFinished { phase: phase.clone(), secs: 0.0, best_cost: *best_cost }
        }
        SearchEvent::LayoutTested { feasible, cost, tested, .. } => SearchEvent::LayoutTested {
            feasible: *feasible,
            cost: *cost,
            tested: *tested,
            worker: 0,
        },
        other => other.clone(),
    }
}

fn run_summary(dfgs: &[helex::Dfg], grid: Grid, cfg: SearchConfig) -> RunSummary {
    let engine = MappingEngine::default();
    let cost = CostModel::area();
    let mut events: Vec<SearchEvent> = Vec::new();
    let run = {
        let events = &mut events;
        let mut obs = move |ev: &SearchEvent| events.push(normalize_event(ev));
        Explorer::new(grid)
            .dfgs(dfgs)
            .engine(&engine)
            .cost(&cost)
            .config(cfg)
            .observer(&mut obs)
            .run()
    };
    match run {
        Ok(r) => RunSummary {
            outcome: Ok(()),
            best_cost_bits: r.best_cost.to_bits(),
            best_layout: Some(r.best_layout),
            tested: r.stats.tested,
            expanded: r.stats.expanded,
            node_cells: r.final_mappings.iter().map(|m| m.node_cell.clone()).collect(),
            trace: r
                .stats
                .trace
                .iter()
                .map(|t| (t.phase.clone(), t.tested, t.best_cost.to_bits()))
                .collect(),
            events,
        },
        Err(e) => RunSummary {
            outcome: Err(e.to_string()),
            best_cost_bits: 0,
            best_layout: None,
            tested: 0,
            expanded: 0,
            node_cells: Vec::new(),
            trace: Vec::new(),
            events,
        },
    }
}

#[test]
fn search_thread_count_never_changes_results() {
    // the deterministic-reduction contract, as a property over random
    // specs: N ∈ {1,2,4} search threads produce identical layouts,
    // costs, counters, final mappings, and (normalized) event traces —
    // including identical *infeasibility*. Mirrors CI's
    // search-determinism job at unit scale.
    let pool = ["SOB", "GB", "BOX", "GAR"];
    helex::util::prop::forall("search-threads-parity", 4, 0xC0FFEE, |g| {
        let k = 1 + g.rng.below(2);
        let mut dfgs = Vec::new();
        for _ in 0..k {
            dfgs.push(benchmarks::benchmark(pool[g.rng.below(pool.len())]));
        }
        let side = 6 + (g.size % 3); // 6..=8
        let grid = Grid::new(side, side);
        let cfg = SearchConfig {
            l_test: 40 + g.rng.below(40),
            l_fail: 2,
            gsg_passes: 1,
            ..Default::default()
        };
        let baseline = run_summary(&dfgs, grid, SearchConfig { search_threads: 1, ..cfg.clone() });
        for threads in [2usize, 4] {
            let other =
                run_summary(&dfgs, grid, SearchConfig { search_threads: threads, ..cfg.clone() });
            if baseline != other {
                return Err(format!(
                    "threads=1 vs threads={threads} diverged on {:?} @ {side}x{side}: \
                     base tested={} events={} outcome={:?}; other tested={} events={} outcome={:?}",
                    dfgs.iter().map(|d| d.name.clone()).collect::<Vec<_>>(),
                    baseline.tested,
                    baseline.events.len(),
                    baseline.outcome,
                    other.tested,
                    other.events.len(),
                    other.outcome,
                ));
            }
        }
        Ok(())
    });
}
