//! End-to-end tests of the serving layer: a real `Server` on an
//! ephemeral port, driven over real sockets by the `server::client`
//! helpers — the same path `helex submit` and the CI smoke job use.

use helex::coordinator::{experiments, ExperimentConfig};
use helex::server::{client, Server, ServerConfig, ServerHandle};
use helex::service::wire;
use helex::service::{ExplorationService, JobSpec};
use helex::util::json::{self, Json};
use std::io::{Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

fn tmp_dir(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "helex-server-{tag}-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The paper's Fig 9 sweep at its smallest size (S4 @ 7×7), at a quick
/// search budget — the acceptance-criteria spec.
fn fig9_smallest_spec() -> JobSpec {
    let cfg = ExperimentConfig { l_test_base: 40, gsg_passes: 1, ..Default::default() };
    let defs = experiments::find("fig9").expect("fig9 exists");
    let specs = (defs[0].specs)(&cfg, true);
    let spec = specs.into_iter().next().expect("fig9 has specs");
    assert_eq!((spec.grid.rows, spec.grid.cols), (7, 7), "smallest fig9 size");
    spec
}

struct RunningServer {
    addr: String,
    handle: ServerHandle,
    thread: std::thread::JoinHandle<()>,
}

impl RunningServer {
    fn start(cfg: ServerConfig) -> Self {
        let server = Server::bind(cfg).expect("bind ephemeral port");
        let addr = server.local_addr().unwrap().to_string();
        let handle = server.handle().unwrap();
        let thread = std::thread::spawn(move || server.serve().expect("serve exits cleanly"));
        Self { addr, handle, thread }
    }

    fn stop(self) {
        self.handle.begin_shutdown();
        self.thread.join().expect("server thread exits after drain");
    }
}

fn test_config(store_dir: Option<PathBuf>) -> ServerConfig {
    ServerConfig {
        addr: "127.0.0.1:0".into(),
        jobs: 1,
        store_dir,
        queue_cap: 8,
        ..Default::default()
    }
}

#[test]
fn http_result_matches_direct_run_and_restart_serves_from_store() {
    let dir = tmp_dir("e2e");
    let spec = fig9_smallest_spec();

    // ground truth: the same spec through the in-process service
    let direct = ExplorationService::with_jobs(1).run_job(&spec);
    assert!(direct.outcome.is_completed(), "fig9 smallest spec must map");
    let direct_bytes = wire::strip_volatile(&wire::encode_result(&direct)).to_string();

    // cold server: compute over HTTP, persist into the store
    let server = RunningServer::start(test_config(Some(dir.clone())));
    let id = client::submit_spec(&server.addr, &spec).expect("submit");
    let over_http =
        client::wait_result(&server.addr, id, Duration::from_millis(100), 1200).expect("result");
    assert!(!over_http.from_cache, "first run computes");
    assert_eq!(over_http.id, id);
    let http_bytes = wire::strip_volatile(&wire::encode_result(&over_http)).to_string();
    assert_eq!(
        http_bytes, direct_bytes,
        "HTTP-served result must be byte-identical to a direct run_job (volatile fields aside)"
    );

    // the event stream replays the exact recorded trace as ndjson
    let (status, body) =
        client::request_raw(&server.addr, "GET", &format!("/v1/jobs/{id}/events"), b"")
            .expect("events stream");
    assert_eq!(status, 200);
    let text = String::from_utf8(body).expect("ndjson is UTF-8");
    let events: Vec<_> = text
        .lines()
        .map(|line| {
            wire::decode_event(&json::parse(line).expect("each line is one JSON event"))
                .expect("decodes as SearchEvent")
        })
        .collect();
    assert_eq!(events, over_http.events, "streamed events equal the result's trace");

    // stats reflect one computed job; graceful shutdown flushes the index
    let stats = client::get_json(&server.addr, "/v1/stats").unwrap();
    assert_eq!(stats.get("cache").unwrap().get("computed").unwrap().as_u64(), Some(1));
    server.stop();
    assert!(dir.join("index.json").exists(), "drain must flush the store index");

    // warm restart: a brand-new process-equivalent (fresh mem cache)
    // must answer from the store without recomputing
    let server = RunningServer::start(test_config(Some(dir.clone())));
    let id2 = client::submit_spec(&server.addr, &spec).expect("resubmit");
    let warm =
        client::wait_result(&server.addr, id2, Duration::from_millis(100), 1200).expect("warm");
    assert!(warm.from_cache, "restart must serve the identical spec from the store");
    let warm_bytes = wire::strip_volatile(&wire::encode_result(&warm)).to_string();
    assert_eq!(warm_bytes, direct_bytes, "store round-trip preserves every byte that matters");
    let stats = client::get_json(&server.addr, "/v1/stats").unwrap();
    let cache = stats.get("cache").unwrap();
    assert_eq!(cache.get("computed").unwrap().as_u64(), Some(0), "zero recomputes after restart");
    assert_eq!(cache.get("store_hits").unwrap().as_u64(), Some(1));
    let store = stats.get("store").unwrap();
    assert_eq!(store.get("hits").unwrap().as_u64(), Some(1));
    server.stop();

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn malformed_requests_get_4xx_and_the_server_survives() {
    let server = RunningServer::start(test_config(None));

    // JSON/spec corpus: every one must answer 400, none may kill a
    // handler (the healthz probe at the end proves liveness)
    let bad_bodies: &[&str] = &[
        "",
        "{",
        "not json at all",
        "[1,2,3]",
        "null",
        "true",
        "{\"dfgs\":0,\"grid\":{\"rows\":5,\"cols\":5}}",
        "{\"dfgs\":[],\"grid\":{\"rows\":2,\"cols\":2}}",
        "{\"dfgs\":[],\"grid\":{\"rows\":1000,\"cols\":1000}}",
        "{\"dfgs\":[{\"name\":\"x\",\"nodes\":[\"zap\"],\"edges\":[]}],\"grid\":{\"rows\":5,\"cols\":5}}",
        "{\"dfgs\":[{\"name\":\"x\",\"nodes\":[\"load\",\"store\"],\"edges\":[[0,9]]}],\"grid\":{\"rows\":5,\"cols\":5}}",
        "{\"dfgs\":[{\"name\":\"x\",\"nodes\":[\"add\",\"add\"],\"edges\":[[0,1],[1,0]]}],\"grid\":{\"rows\":5,\"cols\":5}}",
        "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"seed\":-3}",
        "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"objective\":\"speed\"}",
        "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"fabric\":7}",
        "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"fabric\":{\"topology\":\"torus\"}}",
        "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"fabric\":{\"topology\":\"express\",\"express_stride\":1}}",
        "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"fabric\":{\"link_cap\":0}}",
        "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"fabric\":{\"link_cap\":300}}",
        "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"fabric\":{\"io_mask\":\"q\"}}",
        "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"fabric\":{\"io_mask\":\"\"}}",
        "\"\\ud800\"",
        "{\"a\":1e999}",
    ];
    for body in bad_bodies {
        let (status, reply) =
            client::request_raw(&server.addr, "POST", "/v1/jobs", body.as_bytes()).unwrap();
        assert_eq!(status, 400, "body {body:?} must be a 400");
        let reply = String::from_utf8(reply).unwrap();
        assert!(reply.contains("\"error\""), "structured error body, got {reply}");
    }
    // structurally broken user graphs must answer with the *precise*
    // validation reason (the typed DfgError surface), still as a 400
    let precise: &[(&str, &str)] = &[
        (
            "{\"dfgs\":[{\"name\":\"x\",\"nodes\":[\"add\",\"add\"],\"edges\":[[0,1],[1,0]]}],\"grid\":{\"rows\":5,\"cols\":5}}",
            "cycle",
        ),
        (
            "{\"dfgs\":[{\"name\":\"x\",\"nodes\":[\"load\",\"abs\",\"store\"],\"edges\":[[0,1],[0,1],[1,2]]}],\"grid\":{\"rows\":5,\"cols\":5}}",
            "duplicate edge",
        ),
        (
            "{\"dfgs\":[{\"name\":\"x\",\"nodes\":[\"load\",\"abs\",\"store\"],\"edges\":[[0,1],[1,1],[1,2]]}],\"grid\":{\"rows\":5,\"cols\":5}}",
            "self-loop",
        ),
        (
            "{\"dfgs\":[{\"name\":\"x\",\"nodes\":[\"load\",\"zap\",\"store\"],\"edges\":[[0,1],[1,2]]}],\"grid\":{\"rows\":5,\"cols\":5}}",
            "unknown operation 'zap'",
        ),
        (
            "{\"dfgs\":[{\"name\":\"x\",\"nodes\":[\"load\",\"store\"],\"edges\":[[0,9]]}],\"grid\":{\"rows\":5,\"cols\":5}}",
            "out of range",
        ),
        // hostile dimensions surface the typed GridError reason
        (
            "{\"dfgs\":[],\"grid\":{\"rows\":2,\"cols\":2}}",
            "grid must be at least 3x3, got 2x2",
        ),
        (
            "{\"dfgs\":[],\"grid\":{\"rows\":1000,\"cols\":1000}}",
            "grid 1000x1000 too large for CellId",
        ),
        // hostile fabrics surface the typed provisioning reason
        (
            "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"fabric\":{\"topology\":\"torus\"}}",
            "unknown topology 'torus'",
        ),
        (
            "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"fabric\":{\"topology\":\"express\",\"express_stride\":1}}",
            "express stride must be at least 2",
        ),
        (
            "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"fabric\":{\"link_cap\":0}}",
            "link capacity must be at least 1",
        ),
        (
            "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"fabric\":{\"link_cap\":300}}",
            "1..=255",
        ),
        (
            "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"fabric\":{\"io_mask\":\"q\"}}",
            "unknown I/O side 'q'",
        ),
        (
            "{\"dfgs\":[],\"grid\":{\"rows\":5,\"cols\":5},\"fabric\":{\"io_mask\":\"\"}}",
            "I/O mask cannot be empty",
        ),
    ];
    for (body, needle) in precise {
        let (status, reply) =
            client::request_raw(&server.addr, "POST", "/v1/jobs", body.as_bytes()).unwrap();
        assert_eq!(status, 400, "body {body:?} must be a 400");
        let reply = String::from_utf8(reply).unwrap();
        assert!(reply.contains(needle), "expected {needle:?} in {reply}");
    }
    // a graph over the interchange node cap is refused by the cap, not
    // by an attempt to build it
    let big = format!(
        "{{\"dfgs\":[{{\"name\":\"big\",\"nodes\":[{}],\"edges\":[]}}],\"grid\":{{\"rows\":5,\"cols\":5}}}}",
        vec!["\"add\""; helex::dfg::io::MAX_NODES + 1].join(",")
    );
    let (status, reply) =
        client::request_raw(&server.addr, "POST", "/v1/jobs", big.as_bytes()).unwrap();
    assert_eq!(status, 400, "oversized graph must be a 400");
    let reply = String::from_utf8(reply).unwrap();
    assert!(reply.contains("at most"), "cap message, got {reply}");

    // deep-nesting bomb: bounded parse, not a stack overflow
    let bomb = "[".repeat(50_000);
    let (status, _) =
        client::request_raw(&server.addr, "POST", "/v1/jobs", bomb.as_bytes()).unwrap();
    assert_eq!(status, 400);

    // non-UTF-8 body
    let (status, _) =
        client::request_raw(&server.addr, "POST", "/v1/jobs", &[0xFF, 0xFE, 0x80]).unwrap();
    assert_eq!(status, 400);

    // oversize body: declare a huge Content-Length (without sending the
    // bytes — the server must refuse from the header alone)
    {
        let mut raw = std::net::TcpStream::connect(&server.addr).unwrap();
        raw.write_all(b"POST /v1/jobs HTTP/1.1\r\nHost: x\r\nContent-Length: 999999999\r\n\r\n")
            .unwrap();
        let mut reply = Vec::new();
        let _ = raw.read_to_end(&mut reply);
        let reply = String::from_utf8_lossy(&reply);
        assert!(reply.starts_with("HTTP/1.1 413"), "got: {reply}");
    }
    // chunked request bodies are refused, not misread
    {
        let mut raw = std::net::TcpStream::connect(&server.addr).unwrap();
        raw.write_all(b"POST /v1/jobs HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n")
            .unwrap();
        let mut reply = Vec::new();
        let _ = raw.read_to_end(&mut reply);
        let reply = String::from_utf8_lossy(&reply);
        assert!(reply.starts_with("HTTP/1.1 411"), "got: {reply}");
    }

    // routing errors
    let (status, _) = client::request_raw(&server.addr, "GET", "/nope", b"").unwrap();
    assert_eq!(status, 404);
    let (status, _) = client::request_raw(&server.addr, "DELETE", "/v1/jobs", b"").unwrap();
    assert_eq!(status, 405);
    let (status, _) = client::request_raw(&server.addr, "GET", "/v1/jobs/garbage!", b"").unwrap();
    assert_eq!(status, 400, "unparseable id");
    let (status, _) =
        client::request_raw(&server.addr, "GET", "/v1/jobs/job-00000000000000ff", b"").unwrap();
    assert_eq!(status, 404, "well-formed but unknown id");

    // raw-socket garbage: not even HTTP
    {
        let mut raw = std::net::TcpStream::connect(&server.addr).unwrap();
        raw.write_all(b"\x00\x01\x02 garbage\r\n\r\n").unwrap();
        let mut sink = Vec::new();
        let _ = raw.read_to_end(&mut sink); // server answers 400 or closes
    }

    // after all of that, the server still answers
    let health = client::get_json(&server.addr, "/v1/healthz").unwrap();
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
    server.stop();
}

/// The workload-ingestion acceptance path: a user-authored JSON graph
/// file (written by hand, not by our encoder) loads through
/// `dfg::io::from_path`, submits over HTTP, maps, its witness
/// validates, and the served result is byte-identical to the same spec
/// through a direct in-process `ExplorationService` run. The DOT form
/// of the same graph parses to the identical structure.
#[test]
fn user_authored_graph_file_submits_and_matches_direct_run() {
    let dir = tmp_dir("usergraph");
    std::fs::create_dir_all(&dir).unwrap();

    // hand-authored interchange text (whitespace and key order differ
    // from our canonical encoder on purpose)
    let json_path = dir.join("kernel.json");
    std::fs::write(
        &json_path,
        "{ \"name\": \"kernel\",\n  \"edges\": [[0,2],[1,2],[2,3],[2,4],[3,5],[4,5]],\n  \"nodes\": [\"load\",\"load\",\"add\",\"abs\",\"shr\",\"store\"] }\n",
    )
    .unwrap();
    let dfg = helex::dfg::io::from_path(&json_path).expect("hand-written JSON loads");
    assert!(dfg.validate().is_empty());

    // the same kernel as DOT parses to the identical structure
    let dot_path = dir.join("kernel.dot");
    std::fs::write(
        &dot_path,
        "digraph \"kernel\" { // hand-written\n  n0 [label=\"load\"]; n1 [label=\"load\"];\n  n2 [label=\"add\"]; n3 [label=\"abs\"]; n4 [label=\"shr\"]; n5 [label=\"store\"];\n  n0 -> n2; n1 -> n2; n2 -> n3; n2 -> n4; n3 -> n5; n4 -> n5;\n}\n",
    )
    .unwrap();
    let from_dot = helex::dfg::io::from_path(&dot_path).expect("hand-written DOT loads");
    assert_eq!(from_dot.nodes, dfg.nodes);
    assert_eq!(from_dot.edges, dfg.edges);

    let mut spec = JobSpec::new("user-kernel", vec![dfg], helex::Grid::new(6, 6));
    spec.search.l_test = 40;
    spec.search.gsg_passes = 1;

    // ground truth: direct in-process run; the witness must validate
    let direct = ExplorationService::with_jobs(1).run_job(&spec);
    let result = direct.outcome.search_result().expect("tiny kernel maps on 6x6");
    for (di, d) in spec.dfgs.iter().enumerate() {
        let errs = result.final_mappings[di].validate(d, &result.best_layout);
        assert!(errs.is_empty(), "witness invalid: {errs:?}");
    }
    let direct_bytes = wire::strip_volatile(&wire::encode_result(&direct)).to_string();

    // the same spec over HTTP is byte-identical, volatile fields aside
    let server = RunningServer::start(test_config(None));
    let id = client::submit_spec(&server.addr, &spec).expect("submit user graph");
    let over_http =
        client::wait_result(&server.addr, id, Duration::from_millis(100), 1200).expect("result");
    let http_bytes = wire::strip_volatile(&wire::encode_result(&over_http)).to_string();
    assert_eq!(http_bytes, direct_bytes, "served result must match the direct run byte-for-byte");
    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn submit_then_poll_surfaces_queue_states_and_infeasible_results() {
    let server = RunningServer::start(test_config(None));
    // SAD (63 compute ops) cannot fit 5x5 (9 compute cells): the job
    // completes with an infeasible *outcome*, not an HTTP error
    let spec = JobSpec {
        search: helex::search::SearchConfig { l_test: 20, ..Default::default() },
        ..JobSpec::new(
            "no-fit",
            vec![helex::dfg::benchmarks::benchmark("SAD")],
            helex::Grid::new(5, 5),
        )
    };
    let id = client::submit_spec(&server.addr, &spec).unwrap();
    let result =
        client::wait_result(&server.addr, id, Duration::from_millis(50), 1200).unwrap();
    assert!(result.outcome.infeasible_reason().is_some());
    assert!(result.best_cost().is_none());

    // poll body shape for a known job
    let body = client::get_json(&server.addr, &format!("/v1/jobs/{id}")).unwrap();
    assert_eq!(body.get("status").and_then(Json::as_str), Some("done"));
    assert_eq!(body.get("id").and_then(Json::as_str), Some(id.to_string().as_str()));
    assert!(body.get("result").is_some());
    server.stop();
}

#[test]
fn transport_retry_survives_a_flaky_listener_and_reports_exhaustion() {
    use helex::server::client::RetryPolicy;

    // a listener that kills the first two connections before answering
    // and serves a proper HTTP response on the third
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let flaky = std::thread::spawn(move || {
        for i in 0..3 {
            let (mut stream, _) = listener.accept().unwrap();
            if i < 2 {
                drop(stream); // reset before any response bytes
                continue;
            }
            let mut head = [0u8; 4096];
            let _ = stream.read(&mut head);
            let body = br#"{"ok":true}"#;
            let reply = format!(
                "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
                body.len()
            );
            stream.write_all(reply.as_bytes()).unwrap();
            stream.write_all(body).unwrap();
        }
    });

    let policy = RetryPolicy {
        attempts: 5,
        base_delay: Duration::from_millis(10),
        max_delay: Duration::from_millis(40),
        jitter_seed: 7,
    };
    let (status, body) = client::request_raw_retry(&addr, "GET", "/v1/healthz", b"", &policy)
        .expect("an attempt within the budget reaches the healthy exchange");
    assert_eq!(status, 200);
    assert_eq!(String::from_utf8(body).unwrap(), r#"{"ok":true}"#);
    flaky.join().unwrap();

    // the listener is gone: every attempt fails and the error says how
    // many were made
    let exhausted = RetryPolicy {
        attempts: 3,
        base_delay: Duration::from_millis(1),
        max_delay: Duration::from_millis(2),
        jitter_seed: 7,
    };
    let err =
        client::request_raw_retry(&addr, "GET", "/v1/healthz", b"", &exhausted).unwrap_err();
    assert!(err.to_string().contains("3 attempt(s)"), "got: {err}");
}

#[test]
fn retry_backoff_is_deterministic_exponential_and_bounded() {
    use helex::server::client::RetryPolicy;

    let policy = RetryPolicy::default();
    for attempt in 1..=6u32 {
        let delay = policy.delay_before(attempt);
        assert_eq!(delay, policy.delay_before(attempt), "same seed, same attempt, same delay");
        let shift = attempt.saturating_sub(1).min(16);
        let capped = policy.base_delay.saturating_mul(1u32 << shift).min(policy.max_delay);
        assert!(delay >= capped, "jitter only ever adds to the exponential base");
        assert!(delay <= capped.mul_f64(1.25), "jitter stays under a quarter of the delay");
    }
    // the curve saturates at max_delay (plus jitter), never past it
    assert!(policy.delay_before(30) <= policy.max_delay.mul_f64(1.25));
    // a different seed lands on a different jitter somewhere on the curve
    let other = RetryPolicy { jitter_seed: 1, ..RetryPolicy::default() };
    assert!((1..=6).any(|n| other.delay_before(n) != policy.delay_before(n)));
    // the no-retry policy is a single attempt
    assert_eq!(RetryPolicy::none().attempts, 1);
}
