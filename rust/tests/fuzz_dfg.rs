//! Property-fuzz harness over the random-DFG generator (`dfg::gen`):
//! thousands of generated graphs pushed through the interchange codecs,
//! the mapper and the search, asserting soundness everywhere — graphs
//! always validate, codecs round-trip byte-stably, mapper witnesses
//! validate, search treats infeasibility as data and its trace does not
//! depend on the thread count. The committed interchange corpus
//! (`corpus/*.json`) is also checked against paper Table II.
//!
//! Together the `forall` budgets here exceed 1000 generated graphs per
//! run (600 codec + 200 DOT + 200 mapper + 12 search).

use helex::cgra::{Grid, Layout};
use helex::cost::CostModel;
use helex::dfg::benchmarks::TABLE_II;
use helex::dfg::gen::{arb_config, generate, GenConfig};
use helex::dfg::io;
use helex::mapper::MapOutcome;
use helex::search::SearchConfig;
use helex::util::prop::forall;
use helex::{Mapper, MappingEngine};

#[test]
fn fuzz_generated_graphs_validate_and_roundtrip_json() {
    forall("fuzz_json_roundtrip", 600, 0xF0221, |g| {
        let cfg = arb_config(g.rng, g.size);
        let dfg = generate(&cfg);
        let errs = dfg.validate();
        if !errs.is_empty() {
            return Err(format!("{cfg:?}: invalid graph: {errs:?}"));
        }
        let text = io::to_json_string(&dfg);
        let back = io::from_json_str(&text).map_err(|e| format!("{cfg:?}: decode: {e}"))?;
        if back.name != dfg.name || back.nodes != dfg.nodes || back.edges != dfg.edges {
            return Err(format!("{cfg:?}: JSON round-trip changed the graph"));
        }
        // re-encode is byte-stable: the format is a canonical form
        if io::to_json_string(&back) != text {
            return Err(format!("{cfg:?}: re-encode not byte-identical"));
        }
        Ok(())
    });
}

#[test]
fn fuzz_generated_graphs_roundtrip_dot() {
    forall("fuzz_dot_roundtrip", 200, 0xF0D07, |g| {
        let cfg = arb_config(g.rng, g.size);
        let dfg = generate(&cfg);
        let text = io::to_dot(&dfg);
        let back = io::from_dot(&text).map_err(|e| format!("{cfg:?}: decode: {e}"))?;
        if back.name != dfg.name || back.nodes != dfg.nodes || back.edges != dfg.edges {
            return Err(format!("{cfg:?}: DOT round-trip changed the graph"));
        }
        Ok(())
    });
}

#[test]
fn fuzz_mapper_is_sound_on_generated_graphs() {
    forall("fuzz_mapper_sound", 200, 0xF03A9, |g| {
        let cfg = arb_config(g.rng, g.size);
        let dfg = generate(&cfg);
        let side = 5 + g.rng.below(4);
        let layout = Layout::full(Grid::new(side, side), dfg.groups_used());
        match MappingEngine::default().map(&dfg, &layout) {
            MapOutcome::Mapped { mapping, .. } => {
                let errs = mapping.validate(&dfg, &layout);
                if !errs.is_empty() {
                    return Err(format!("{}: witness invalid: {errs:?}", dfg.name));
                }
                if mapping.latency(&dfg) < dfg.critical_path_nodes() {
                    return Err(format!("{}: latency below critical path", dfg.name));
                }
            }
            MapOutcome::Failed { .. } => { /* unmappable instance: data, not a bug */ }
        }
        Ok(())
    });
}

/// Search soundness + thread invariance over generated workloads: a
/// feasible search yields a validating witness at any thread count, the
/// improvement trace (phase, tested, cost — wall time aside) and the
/// deterministic counters are byte-equal between 1 and 2 worker threads,
/// and infeasibility surfaces as an absent result, never a panic.
#[test]
fn fuzz_search_is_sound_and_thread_invariant() {
    forall("fuzz_search_sound", 12, 0xF05EA, |g| {
        let cfg = GenConfig {
            seed: g.rng.next_u64(),
            loads: 2 + g.rng.below(2),
            compute: 3 + g.rng.below(5 + g.size),
            stores: 1 + g.rng.below(2),
            binary_p: 0.5,
            ..Default::default()
        };
        let dfgs = vec![generate(&cfg)];
        let side = 6 + g.rng.below(2);
        let grid = Grid::new(side, side);
        let mapper = Mapper::default();
        let cost = CostModel::area();
        let runs: Vec<_> = [1usize, 2]
            .iter()
            .map(|&threads| {
                let scfg = SearchConfig {
                    l_test: 30,
                    gsg_passes: 1,
                    search_threads: threads,
                    ..Default::default()
                };
                helex::search::run(&dfgs, grid, &mapper, &cost, &scfg, None)
            })
            .collect();
        match (&runs[0], &runs[1]) {
            (Some(a), Some(b)) => {
                for (di, d) in dfgs.iter().enumerate() {
                    let errs = a.final_mappings[di].validate(d, &a.best_layout);
                    if !errs.is_empty() {
                        return Err(format!("{}: witness invalid: {errs:?}", d.name));
                    }
                }
                if a.best_layout != b.best_layout || a.best_cost != b.best_cost {
                    return Err(format!("{cfg:?}: result depends on search_threads"));
                }
                if a.stats.tested != b.stats.tested || a.stats.expanded != b.stats.expanded {
                    return Err(format!("{cfg:?}: counters depend on search_threads"));
                }
                let key = |r: &helex::search::SearchResult| -> Vec<(String, usize, f64)> {
                    r.stats
                        .trace
                        .iter()
                        .map(|p| (p.phase.clone(), p.tested, p.best_cost))
                        .collect()
                };
                if key(a) != key(b) {
                    return Err(format!("{cfg:?}: trace depends on search_threads"));
                }
            }
            (None, None) => { /* infeasible at both thread counts: fine */ }
            _ => return Err(format!("{cfg:?}: feasibility depends on search_threads")),
        }
        Ok(())
    });
}

/// The committed interchange corpus stays decodable, valid and faithful
/// to paper Table II. (CI's fuzz-smoke job additionally diffs the bytes
/// against a fresh `helex dfg export`.)
#[test]
fn corpus_files_decode_validate_and_match_table_ii() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("corpus");
    for (name, v, e) in TABLE_II {
        let path = dir.join(format!("{name}.json"));
        let dfg = io::from_path(&path)
            .unwrap_or_else(|err| panic!("{}: {err}", path.display()));
        assert_eq!(dfg.name, name, "{}: name mismatch", path.display());
        assert_eq!(dfg.num_nodes(), v, "{name}: V");
        assert_eq!(dfg.num_edges(), e, "{name}: E");
        let errs = dfg.validate();
        assert!(errs.is_empty(), "{name}: {errs:?}");
    }
    let files = std::fs::read_dir(&dir)
        .expect("corpus/ exists")
        .filter_map(|f| f.ok())
        .filter(|f| f.path().extension().map_or(false, |x| x == "json"))
        .count();
    assert_eq!(files, TABLE_II.len(), "corpus has exactly the 12 Table II graphs");
}
