//! End-to-end tests of the fleet layer: a real `Fleet` coordinator on
//! an ephemeral port fanning out to real `helex serve` replicas, driven
//! over real sockets by the `server::client` helpers — the same path
//! `helex submit --batch` and the CI fleet-smoke job use.

use helex::coordinator::{experiments, ExperimentConfig};
use helex::fleet::{BatchRequest, Fleet, FleetConfig, FleetHandle, DEFAULT_PRIORITY};
use helex::server::{client, Server, ServerConfig, ServerHandle};
use helex::service::wire;
use helex::service::{ExplorationService, JobSpec};
use helex::util::json::Json;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

fn tmp_dir(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "helex-fleet-{tag}-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

struct RunningServer {
    addr: String,
    handle: ServerHandle,
    thread: std::thread::JoinHandle<()>,
}

impl RunningServer {
    fn start() -> Self {
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            jobs: 1,
            queue_cap: 32,
            ..Default::default()
        };
        let server = Server::bind(cfg).expect("bind replica on an ephemeral port");
        let addr = server.local_addr().unwrap().to_string();
        let handle = server.handle().unwrap();
        let thread = std::thread::spawn(move || server.serve().expect("replica exits cleanly"));
        Self { addr, handle, thread }
    }

    fn stop(self) {
        self.handle.begin_shutdown();
        self.thread.join().expect("replica thread exits after drain");
    }
}

struct RunningFleet {
    addr: String,
    handle: FleetHandle,
    thread: std::thread::JoinHandle<()>,
}

impl RunningFleet {
    fn start(cfg: FleetConfig) -> Self {
        let fleet = Fleet::bind(cfg).expect("bind coordinator on an ephemeral port");
        let addr = fleet.local_addr().unwrap().to_string();
        let handle = fleet.handle().unwrap();
        let thread = std::thread::spawn(move || fleet.serve().expect("fleet exits cleanly"));
        Self { addr, handle, thread }
    }

    fn stop(self) {
        self.handle.begin_shutdown();
        self.thread.join().expect("fleet thread exits after drain");
    }
}

fn fleet_config(replicas: Vec<String>, store_dir: Option<PathBuf>) -> FleetConfig {
    FleetConfig {
        addr: "127.0.0.1:0".into(),
        replicas,
        store_dir,
        queue_cap: 32,
        probe_interval: Duration::from_millis(200),
        ..Default::default()
    }
}

/// A quick deterministic spec: SAD (63 compute ops) cannot fit 5×5
/// (9 compute cells), so the job resolves fast with an infeasible
/// outcome. Varying the seed varies the fingerprint.
fn quick_spec(seed: u64) -> JobSpec {
    let mut spec = JobSpec::new(
        "quick",
        vec![helex::dfg::benchmarks::benchmark("SAD")],
        helex::Grid::new(5, 5),
    );
    spec.search.l_test = 20;
    spec.seed = seed;
    spec
}

/// The acceptance-criteria E2E: the head of the fig9 sweep (plus a
/// duplicate of its first spec) as ONE batch to a 2-replica fleet must
/// yield results byte-identical (volatile fields aside) to the same
/// specs through a single in-process `ExplorationService`, with each
/// distinct fingerprint computed exactly once fleet-wide.
#[test]
fn batch_matches_direct_runs_and_computes_each_fingerprint_once() {
    let cfg = ExperimentConfig { l_test_base: 40, gsg_passes: 1, ..Default::default() };
    let defs = experiments::find("fig9").expect("fig9 exists");
    let mut specs: Vec<JobSpec> = (defs[0].specs)(&cfg, true).into_iter().take(3).collect();
    assert_eq!(specs.len(), 3, "fig9 has at least three sizes");
    specs.push(specs[0].clone()); // 4 jobs, 3 distinct fingerprints

    // ground truth: the same specs through one in-process service
    let service = ExplorationService::with_jobs(1);
    let direct: Vec<String> = specs
        .iter()
        .map(|s| wire::strip_volatile(&wire::encode_result(&service.run_job(s))).to_string())
        .collect();

    let r1 = RunningServer::start();
    let r2 = RunningServer::start();
    let dir = tmp_dir("e2e");
    let fleet = RunningFleet::start(fleet_config(
        vec![r1.addr.clone(), r2.addr.clone()],
        Some(dir.clone()),
    ));

    let batch = BatchRequest {
        label: "fig9-head".into(),
        client: "e2e".into(),
        priority: DEFAULT_PRIORITY,
        specs: specs.clone(),
    };
    let (batch_id, ids) = client::submit_batch(&fleet.addr, &batch).expect("submit batch");
    assert_eq!(ids.len(), 4);

    let body = client::wait_batch(&fleet.addr, batch_id, Duration::from_millis(100), 6000)
        .expect("batch finishes");
    assert_eq!(body.get("total").and_then(Json::as_u64), Some(4));
    assert_eq!(body.get("done").and_then(Json::as_u64), Some(4));
    assert_eq!(body.get("label").and_then(Json::as_str), Some("fig9-head"));

    for (i, id) in ids.iter().enumerate() {
        let result = client::wait_result(&fleet.addr, *id, Duration::from_millis(50), 100)
            .expect("job result");
        let bytes = wire::strip_volatile(&wire::encode_result(&result)).to_string();
        assert_eq!(
            bytes, direct[i],
            "fleet job {i} must be byte-identical to the direct run (volatile fields aside)"
        );
    }
    // the duplicate spec joined the first job's slot instead of running
    let dup = client::wait_result(&fleet.addr, ids[3], Duration::from_millis(50), 100).unwrap();
    assert!(dup.from_cache, "duplicate fingerprint must not compute again");

    let stats = client::get_json(&fleet.addr, "/v1/stats").unwrap();
    let runs = stats.get("runs").unwrap();
    assert_eq!(runs.get("distinct").and_then(Json::as_u64), Some(3));
    assert_eq!(
        runs.get("computed").and_then(Json::as_u64),
        Some(3),
        "each distinct fingerprint computed exactly once fleet-wide"
    );
    assert_eq!(runs.get("dedup_hits").and_then(Json::as_u64), Some(1));
    assert_eq!(
        stats.get("replicas").and_then(Json::as_array).map(Vec::len),
        Some(2),
        "stats report both replicas"
    );

    // the shared store holds every computed fingerprint; a fresh fleet
    // over the same store answers without recomputing
    fleet.stop();
    let fleet = RunningFleet::start(fleet_config(
        vec![r1.addr.clone(), r2.addr.clone()],
        Some(dir.clone()),
    ));
    let (warm_id, warm_ids) = client::submit_batch(&fleet.addr, &batch).expect("warm batch");
    client::wait_batch(&fleet.addr, warm_id, Duration::from_millis(50), 1200).expect("warm done");
    let warm = client::wait_result(&fleet.addr, warm_ids[0], Duration::from_millis(50), 100)
        .unwrap();
    assert!(warm.from_cache, "restarted coordinator serves from the shared store");
    let bytes = wire::strip_volatile(&wire::encode_result(&warm)).to_string();
    assert_eq!(bytes, direct[0], "store round-trip preserves every byte that matters");
    let stats = client::get_json(&fleet.addr, "/v1/stats").unwrap();
    let runs = stats.get("runs").unwrap();
    assert_eq!(runs.get("computed").and_then(Json::as_u64), Some(0));
    assert_eq!(runs.get("store_hits").and_then(Json::as_u64), Some(3));

    fleet.stop();
    r1.stop();
    r2.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Killing a replica mid-batch loses no jobs: its work is requeued onto
/// the survivor and every job still resolves.
#[test]
fn replica_departure_mid_batch_loses_no_jobs() {
    let specs: Vec<JobSpec> = (0..6).map(|i| quick_spec(1000 + i)).collect();
    let r1 = RunningServer::start();
    let r2 = RunningServer::start();
    let fleet = RunningFleet::start(fleet_config(vec![r1.addr.clone(), r2.addr.clone()], None));

    let batch = BatchRequest {
        label: "departure".into(),
        client: "e2e".into(),
        priority: DEFAULT_PRIORITY,
        specs,
    };
    let (batch_id, ids) = client::submit_batch(&fleet.addr, &batch).expect("submit batch");
    // take replica 2 down right away — whatever it had accepted or was
    // about to be handed must end up on replica 1 instead
    r2.stop();

    let body = client::wait_batch(&fleet.addr, batch_id, Duration::from_millis(100), 1200)
        .expect("batch finishes despite the departure");
    assert_eq!(body.get("done").and_then(Json::as_u64), Some(6), "zero lost jobs");
    for id in &ids {
        let result = client::wait_result(&fleet.addr, *id, Duration::from_millis(50), 100)
            .expect("every job resolves");
        assert!(result.outcome.infeasible_reason().is_some(), "SAD cannot fit 5x5");
    }
    let stats = client::get_json(&fleet.addr, "/v1/stats").unwrap();
    let runs = stats.get("runs").unwrap();
    assert_eq!(runs.get("distinct").and_then(Json::as_u64), Some(6));
    assert_eq!(runs.get("computed").and_then(Json::as_u64), Some(6));

    fleet.stop();
    r1.stop();
}

/// Admission control end to end: an over-budget batch is refused whole
/// with a 429, a within-budget one is admitted, and `POST /v1/quotas`
/// raises a client's budget at runtime.
#[test]
fn quotas_gate_admission_and_can_be_raised_at_runtime() {
    let r1 = RunningServer::start();
    let mut cfg = fleet_config(vec![r1.addr.clone()], None);
    cfg.quota_burst = 2;
    cfg.quota_rate = 0.0;
    let fleet = RunningFleet::start(cfg);

    let batch = |n: u64| BatchRequest {
        label: "quota".into(),
        client: "t3".into(),
        priority: 7,
        specs: (0..n).map(|i| quick_spec(2000 + i)).collect(),
    };
    // three jobs can never fit a burst of two: refused whole
    let err = client::submit_batch(&fleet.addr, &batch(3)).unwrap_err();
    assert!(err.to_string().contains("quota_exhausted"), "got: {err}");

    // two jobs fit exactly; the bucket is now empty and never refills
    let (batch_id, _) = client::submit_batch(&fleet.addr, &batch(2)).expect("within budget");
    let single = {
        let mut body = wire::encode_spec(&quick_spec(3000));
        if let Json::Obj(pairs) = &mut body {
            pairs.push(("client".to_string(), Json::str("t3")));
        }
        body
    };
    let (status, reply) = client::request(&fleet.addr, "POST", "/v1/jobs", Some(&single)).unwrap();
    assert_eq!(status, 429, "empty zero-rate bucket refuses, got: {reply:?}");

    // raise the budget at runtime: the rule takes effect immediately
    let rule = Json::obj(vec![
        ("client", Json::str("t3")),
        ("burst", Json::U64(8)),
        ("per_sec", Json::F64(4.0)),
    ]);
    let (status, _) = client::request(&fleet.addr, "POST", "/v1/quotas", Some(&rule)).unwrap();
    assert_eq!(status, 200);
    let (status, _) = client::request(&fleet.addr, "POST", "/v1/jobs", Some(&single)).unwrap();
    assert_eq!(status, 202, "raised quota admits the same submission");

    let quotas = client::get_json(&fleet.addr, "/v1/quotas").unwrap();
    let row = quotas
        .get("clients")
        .and_then(Json::as_array)
        .and_then(|rows| {
            rows.iter()
                .find(|r| r.get("client").and_then(Json::as_str) == Some("t3"))
                .cloned()
        })
        .expect("t3 has a listed rule");
    assert_eq!(row.get("burst").and_then(Json::as_u64), Some(8));

    client::wait_batch(&fleet.addr, batch_id, Duration::from_millis(100), 1200).unwrap();
    fleet.stop();
    r1.stop();
}

/// Inline user graphs travel through the fleet end to end: a valid
/// hand-authored graph in a batch completes byte-identical to a direct
/// run, an invalid one is refused with a 400 naming the offending job,
/// and the coordinator stays healthy throughout.
#[test]
fn inline_user_graphs_flow_through_fleet_batches() {
    let r1 = RunningServer::start();
    let fleet = RunningFleet::start(fleet_config(vec![r1.addr.clone()], None));

    // a hand-authored kernel, decoded exactly as `helex submit` would
    let dfg = helex::dfg::io::from_json_str(
        "{\"name\":\"user\",\"nodes\":[\"load\",\"load\",\"add\",\"abs\",\"store\"],\"edges\":[[0,2],[1,2],[2,3],[3,4]]}",
    )
    .expect("valid user graph");
    let mut spec = JobSpec::new("user-batch", vec![dfg], helex::Grid::new(6, 6));
    spec.search.l_test = 40;
    spec.search.gsg_passes = 1;
    let direct = ExplorationService::with_jobs(1).run_job(&spec);
    assert!(direct.outcome.is_completed(), "tiny kernel maps on 6x6");
    let direct_bytes = wire::strip_volatile(&wire::encode_result(&direct)).to_string();

    let batch = BatchRequest {
        label: "user".into(),
        client: "e2e".into(),
        priority: DEFAULT_PRIORITY,
        specs: vec![spec],
    };
    let (batch_id, ids) = client::submit_batch(&fleet.addr, &batch).expect("submit user batch");
    client::wait_batch(&fleet.addr, batch_id, Duration::from_millis(100), 1200)
        .expect("user batch finishes");
    let result =
        client::wait_result(&fleet.addr, ids[0], Duration::from_millis(50), 100).unwrap();
    let bytes = wire::strip_volatile(&wire::encode_result(&result)).to_string();
    assert_eq!(bytes, direct_bytes, "fleet-served user graph matches the direct run");

    // a structurally broken graph is refused whole, naming the job
    let bad = "{\"jobs\":[{\"dfgs\":[{\"name\":\"x\",\"nodes\":[\"add\",\"add\"],\"edges\":[[0,1],[1,0]]}],\"grid\":{\"rows\":5,\"cols\":5}}]}";
    let (status, reply) =
        client::request_raw(&fleet.addr, "POST", "/v1/batches", bad.as_bytes()).unwrap();
    assert_eq!(status, 400, "cyclic inline graph must be a 400");
    let reply = String::from_utf8(reply).unwrap();
    assert!(reply.contains("jobs[0]"), "error names the offending job, got {reply}");
    assert!(reply.contains("cycle"), "error carries the validation reason, got {reply}");

    let health = client::get_json(&fleet.addr, "/v1/healthz").unwrap();
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
    fleet.stop();
    r1.stop();
}

/// Malformed fleet submissions answer structured 4xx errors, and the
/// coordinator survives all of them (healthz at the end proves it).
#[test]
fn malformed_fleet_requests_get_4xx_and_the_coordinator_survives() {
    let r1 = RunningServer::start();
    let fleet = RunningFleet::start(fleet_config(vec![r1.addr.clone()], None));

    let bad_batches: &[&str] = &[
        "",
        "{",
        "not json at all",
        "[1,2,3]",
        "null",
        "{}",
        "{\"jobs\":[]}",
        "{\"jobs\":{}}",
        "{\"jobs\":[{}]}",
        "{\"jobs\":[{\"dfgs\":0,\"grid\":{\"rows\":5,\"cols\":5}}]}",
        "{\"client\":\"\",\"jobs\":[{}]}",
        "{\"priority\":12,\"jobs\":[{}]}",
        "{\"priority\":-1,\"jobs\":[{}]}",
    ];
    for body in bad_batches {
        let (status, reply) =
            client::request_raw(&fleet.addr, "POST", "/v1/batches", body.as_bytes()).unwrap();
        assert_eq!(status, 400, "batch body {body:?} must be a 400");
        let reply = String::from_utf8(reply).unwrap();
        assert!(reply.contains("\"error\""), "structured error body, got {reply}");
    }

    let bad_quotas: &[&str] = &[
        "null",
        "{}",
        "{\"client\":\"\"}",
        "{\"client\":\"x\",\"burst\":0}",
        "{\"client\":\"x\",\"per_sec\":-1}",
    ];
    for body in bad_quotas {
        let (status, _) =
            client::request_raw(&fleet.addr, "POST", "/v1/quotas", body.as_bytes()).unwrap();
        assert_eq!(status, 400, "quota body {body:?} must be a 400");
    }

    // a valid spec with an invalid priority / client rider is refused
    let spec = quick_spec(4000);
    let with = |key: &str, value: Json| {
        let mut body = wire::encode_spec(&spec);
        if let Json::Obj(pairs) = &mut body {
            pairs.push((key.to_string(), value));
        }
        body
    };
    let (status, _) = client::request(
        &fleet.addr,
        "POST",
        "/v1/jobs",
        Some(&with("priority", Json::U64(99))),
    )
    .unwrap();
    assert_eq!(status, 400, "priority over the cap");
    let (status, _) = client::request(
        &fleet.addr,
        "POST",
        "/v1/jobs",
        Some(&with("client", Json::U64(5))),
    )
    .unwrap();
    assert_eq!(status, 400, "non-string client");

    // routing errors
    let (status, _) = client::request_raw(&fleet.addr, "DELETE", "/v1/batches", b"").unwrap();
    assert_eq!(status, 405);
    let (status, _) =
        client::request_raw(&fleet.addr, "GET", "/v1/batches/garbage!", b"").unwrap();
    assert_eq!(status, 400, "unparseable batch id");
    let (status, _) =
        client::request_raw(&fleet.addr, "GET", "/v1/batches/batch-00ff", b"").unwrap();
    assert_eq!(status, 404, "well-formed but unknown batch id");
    let (status, _) = client::request_raw(&fleet.addr, "GET", "/v1/jobs/job-00ff", b"").unwrap();
    assert_eq!(status, 404, "well-formed but unknown job id");
    let (status, _) = client::request_raw(&fleet.addr, "GET", "/nope", b"").unwrap();
    assert_eq!(status, 404);

    // after all of that, the coordinator still answers
    let health = client::get_json(&fleet.addr, "/v1/healthz").unwrap();
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(health.get("role").and_then(Json::as_str), Some("coordinator"));
    fleet.stop();
    r1.stop();
}
