//! Property-based tests over randomly generated DFGs, layouts and
//! searches (in-tree `util::prop` driver; proptest is not vendored).
//!
//! Invariants:
//! * generated DFGs are always valid DAGs with covered producers;
//! * mapper output is always *valid* (placement respects layout and cell
//!   kinds, paths are connected/adjacent, link capacity holds);
//! * the search never returns an infeasible layout, never violates
//!   minimum instance counts, and never increases cost;
//! * heatmap layouts are subsets of full layouts;
//! * cost algebra: removal deltas compose linearly.

use helex::cgra::{Grid, Layout};
use helex::cost::CostModel;
use helex::dfg::builder::DfgSpec;
use helex::dfg::Dfg;
use helex::mapper::{MapOutcome, MapperConfig};
use helex::ops::{GroupSet, Op, OpGroup};
use helex::search::pareto::{dominates, evaluate};
use helex::search::{Explorer, ParetoFront, SearchConfig, SearchEvent, SearchObjective};
use helex::util::prop::{forall, GenCtx};
use helex::util::rng::Rng;
use helex::{Mapper, MappingEngine};

/// Generate a random-but-valid DfgSpec scaled by `size`.
fn arb_spec(g: &mut GenCtx, tag: u64) -> DfgSpec {
    // loads >= 2 so that even the first compute node can be binary
    let loads = 2 + g.rng.below(2 + g.size / 4);
    let stores = 1 + g.rng.below(2 + g.size / 6);
    let ops_pool = [
        Op::Add,
        Op::Sub,
        Op::Mul,
        Op::FAdd,
        Op::FMul,
        Op::FDiv,
        Op::Abs,
        Op::Sqrt,
        Op::Max,
        Op::Shr,
    ];
    let n_compute = 2 + g.rng.below(2 + g.size);
    let mut counts = std::collections::HashMap::new();
    for _ in 0..n_compute {
        *counts.entry(*g.rng.choose(&ops_pool)).or_insert(0usize) += 1;
    }
    let compute: Vec<(Op, usize)> = counts.into_iter().collect();
    let binary_capable: usize =
        compute.iter().filter(|(o, _)| o.arity() == 2).map(|(_, c)| c).sum();
    // choose binary so that E >= V - S (coverage bound)
    let v = loads + stores + n_compute;
    let min_edges = v - stores;
    let base_edges = stores + n_compute; // all-unary edge count
    let needed = min_edges.saturating_sub(base_edges);
    if needed > binary_capable {
        // not coverable: fall back to a known-good tiny spec
        return DfgSpec {
            name: "fallback",
            loads: 2,
            stores: 1,
            compute: vec![(Op::Add, 3)],
            binary: 2,
            seed: tag,
        };
    }
    let binary = needed + g.rng.below(binary_capable - needed + 1);
    DfgSpec { name: "prop", loads, stores, compute, binary, seed: tag }
}

#[test]
fn prop_generated_dfgs_are_valid() {
    forall("dfg_valid", 120, 0xD1, |g| {
        let tag = g.rng.next_u64();
        let spec = arb_spec(g, tag);
        let dfg = spec.build();
        let errs = dfg.validate();
        if !errs.is_empty() {
            return Err(format!("{spec:?}: {errs:?}"));
        }
        if dfg.num_nodes() != spec.num_nodes() || dfg.num_edges() != spec.num_edges() {
            return Err("count mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mapper_output_always_valid() {
    forall("mapper_valid", 40, 0xA2, |g| {
        let tag = g.rng.next_u64();
        let spec = arb_spec(g, tag);
        let dfg = spec.build();
        let side = 5 + g.rng.below(4);
        let layout = Layout::full(Grid::new(side, side), dfg.groups_used());
        if let MapOutcome::Mapped { mapping: m, .. } = MappingEngine::default().map(&dfg, &layout)
        {
            let errs = m.validate(&dfg, &layout);
            if !errs.is_empty() {
                return Err(format!("{}: {errs:?}", dfg.name));
            }
            // latency is at least the unmapped critical path
            if m.latency(&dfg) < dfg.critical_path_nodes() {
                return Err("latency below critical path".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mapper_valid_on_random_heterogeneous_layouts() {
    forall("mapper_hetero_valid", 30, 0xA3, |g| {
        let tag = g.rng.next_u64();
        let spec = arb_spec(g, tag);
        let dfg = spec.build();
        let side = 6 + g.rng.below(3);
        let grid = Grid::new(side, side);
        let mut layout = Layout::full(grid, dfg.groups_used());
        // randomly remove ~30% of group instances
        let cells: Vec<_> = grid.compute_cells().collect();
        for &c in &cells {
            for grp in layout.support(c).iter().collect::<Vec<_>>() {
                if g.rng.chance(0.3) {
                    layout.set_support(c, layout.support(c).without(grp));
                }
            }
        }
        if let MapOutcome::Mapped { mapping: m, .. } = MappingEngine::default().map(&dfg, &layout)
        {
            let errs = m.validate(&dfg, &layout);
            if !errs.is_empty() {
                return Err(format!("{errs:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_search_result_feasible_and_bounded() {
    forall("search_sound", 12, 0x5E, |g| {
        let tag = g.rng.next_u64();
        let spec = arb_spec(g, tag);
        let dfg = spec.build();
        let side = 6 + g.rng.below(3);
        let grid = Grid::new(side, side);
        let mapper = Mapper::default();
        let cost = CostModel::area();
        let cfg = SearchConfig { l_test: 40, gsg_passes: 1, ..Default::default() };
        let dfgs = vec![dfg];
        match helex::search::run(&dfgs, grid, &mapper, &cost, &cfg, None) {
            Some(r) => {
                for (di, d) in dfgs.iter().enumerate() {
                    let errs = r.final_mappings[di].validate(d, &r.best_layout);
                    if !errs.is_empty() {
                        return Err(format!("witness invalid: {errs:?}"));
                    }
                }
                if !helex::search::meets_min_instances(&r.best_layout, &r.min_insts) {
                    return Err("min instances violated".into());
                }
                let full_cost = cost.layout_cost(&r.full_layout);
                if r.best_cost > full_cost + 1e-9 {
                    return Err(format!("cost increased: {} > {full_cost}", r.best_cost));
                }
                let tmin = cost.theoretical_min_cost(&r.full_layout, &r.min_insts);
                if r.best_cost < tmin - 1e-9 {
                    return Err(format!("cost below theoretical min: {} < {tmin}", r.best_cost));
                }
                // heatmap (initial) must be a subset of full
                if !r.initial_layout.is_subset_of(&r.full_layout) {
                    return Err("initial layout not a subset of full".into());
                }
            }
            None => { /* infeasible random instance: fine */ }
        }
        Ok(())
    });
}

#[test]
fn prop_gen_workloads_are_valid_and_mapper_sound() {
    // the seeded workload generator (dfg::gen) — the loadgen/fuzz input
    // source — under the same soundness bar as the spec builder above
    forall("gen_workloads", 30, 0x6E0, |g| {
        let cfg = helex::dfg::gen::arb_config(g.rng, g.size);
        let dfg = helex::dfg::gen::generate(&cfg);
        let errs = dfg.validate();
        if !errs.is_empty() {
            return Err(format!("{cfg:?}: {errs:?}"));
        }
        let side = 6 + g.rng.below(3);
        let layout = Layout::full(Grid::new(side, side), dfg.groups_used());
        if let MapOutcome::Mapped { mapping: m, .. } = MappingEngine::default().map(&dfg, &layout)
        {
            let errs = m.validate(&dfg, &layout);
            if !errs.is_empty() {
                return Err(format!("{}: {errs:?}", dfg.name));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gen_graphs_roundtrip_the_interchange_codecs() {
    forall("gen_roundtrip", 40, 0x6E1, |g| {
        let cfg = helex::dfg::gen::arb_config(g.rng, g.size);
        let dfg = helex::dfg::gen::generate(&cfg);
        let json = helex::dfg::io::to_json_string(&dfg);
        let back = helex::dfg::io::from_json_str(&json).map_err(|e| e.to_string())?;
        if back.nodes != dfg.nodes || back.edges != dfg.edges {
            return Err("JSON round-trip changed the graph".into());
        }
        let dot = helex::dfg::io::to_dot(&dfg);
        let back = helex::dfg::io::from_dot(&dot).map_err(|e| e.to_string())?;
        if back.nodes != dfg.nodes || back.edges != dfg.edges {
            return Err("DOT round-trip changed the graph".into());
        }
        Ok(())
    });
}

#[test]
fn prop_cost_linear_in_removals() {
    forall("cost_linear", 200, 0xC0, |g| {
        let grid = Grid::new(4 + g.rng.below(6), 4 + g.rng.below(6));
        let mut layout = Layout::full(grid, GroupSet::all_compute());
        let cost = CostModel::area();
        let mut expected = cost.layout_cost(&layout);
        for _ in 0..g.size {
            let cells: Vec<_> = grid.compute_cells().collect();
            let cell = *g.rng.choose(&cells);
            let sup: Vec<OpGroup> = layout.support(cell).iter().collect();
            if sup.is_empty() {
                continue;
            }
            let grp = *g.rng.choose(&sup);
            layout.set_support(cell, layout.support(cell).without(grp));
            expected += cost.removal_delta(grp);
        }
        let actual = cost.layout_cost(&layout);
        if (actual - expected).abs() > 1e-6 {
            return Err(format!("linearity broken: {actual} vs {expected}"));
        }
        Ok(())
    });
}

#[test]
fn prop_min_group_instances_is_max_over_dfgs() {
    forall("min_insts", 80, 0x3D, |g| {
        let n = 1 + g.rng.below(4);
        let dfgs: Vec<Dfg> =
            (0..n)
                .map(|_| {
                    let tag = g.rng.next_u64();
                    arb_spec(g, tag).build()
                })
                .collect();
        let mins = helex::dfg::min_group_instances(&dfgs);
        for d in &dfgs {
            let h = d.group_histogram();
            for i in 0..helex::ops::NUM_GROUPS {
                if h[i] > mins[i] {
                    return Err(format!("{}: group {i} {} > min {}", d.name, h[i], mins[i]));
                }
            }
        }
        // and tight: some DFG achieves each min
        for i in 0..helex::ops::NUM_GROUPS {
            if mins[i] > 0
                && !dfgs.iter().any(|d| d.group_histogram()[i] == mins[i])
            {
                return Err(format!("min for group {i} not achieved"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mapping_determinism() {
    // same seed, same layout, same DFG -> identical mapping
    forall("map_deterministic", 20, 0xDE, |g| {
        let tag = g.rng.next_u64();
        let spec = arb_spec(g, tag);
        let dfg = spec.build();
        let layout = Layout::full(Grid::new(7, 7), dfg.groups_used());
        let m1 = MappingEngine::default().map(&dfg, &layout).into_mapping();
        let m2 = MappingEngine::default().map(&dfg, &layout).into_mapping();
        match (m1, m2) {
            (Some(a), Some(b)) => {
                if a.node_cell != b.node_cell || a.edge_paths != b.edge_paths {
                    return Err("nondeterministic mapping".into());
                }
            }
            (None, None) => {}
            _ => return Err("nondeterministic success".into()),
        }
        Ok(())
    });
}

#[test]
fn prop_warm_start_remap_parity() {
    // remap_from must be feasibility-equivalent to from-scratch mapping
    // across random support removals: whenever it succeeds the result
    // validates cleanly, and it succeeds at least whenever the cold path
    // does (the engine falls back internally).
    forall("warm_start_parity", 25, 0xAB, |g| {
        let tag = g.rng.next_u64();
        let spec = arb_spec(g, tag);
        let dfg = spec.build();
        let side = 6 + g.rng.below(3);
        let grid = Grid::new(side, side);
        let full = Layout::full(grid, dfg.groups_used());
        let engine = MappingEngine::default();
        let MapOutcome::Mapped { mapping: witness, .. } = engine.map(&dfg, &full) else {
            return Ok(()); // unmappable random instance: nothing to warm-start
        };
        // random support removals (some displace witness nodes)
        let mut layout = full.clone();
        for c in grid.compute_cells().collect::<Vec<_>>() {
            for grp in layout.support(c).iter().collect::<Vec<_>>() {
                if g.rng.chance(0.25) {
                    layout.set_support(c, layout.support(c).without(grp));
                }
            }
        }
        let warm = engine.remap_from(&witness, &dfg, &layout);
        let cold = MappingEngine::new(MapperConfig {
            feasibility_cache: false,
            ..Default::default()
        })
        .map(&dfg, &layout);
        match (&warm, &cold) {
            (MapOutcome::Mapped { mapping, stats }, _) => {
                let errs = mapping.validate(&dfg, &layout);
                if !errs.is_empty() {
                    return Err(format!(
                        "warm remap invalid (warm path: {}): {errs:?}",
                        stats.warm
                    ));
                }
            }
            (MapOutcome::Failed { .. }, MapOutcome::Mapped { .. }) => {
                return Err("remap_from failed where from-scratch succeeds".into());
            }
            (MapOutcome::Failed { .. }, MapOutcome::Failed { .. }) => {}
        }
        Ok(())
    });
}

#[test]
fn prop_steiner_router_matches_legacy_verdicts() {
    // the Steiner multi-fanout router must agree with the legacy
    // edge-by-edge router on feasibility (roomy full layouts, where
    // both negotiations certainly converge), and every mapping it
    // produces — with and without criticality weighting — must pass
    // the same validation bar as the legacy router's output.
    forall("steiner_vs_legacy", 25, 0x57E1, |g| {
        let tag = g.rng.next_u64();
        let spec = arb_spec(g, tag);
        let dfg = spec.build();
        let side = 8 + g.rng.below(3);
        let layout = Layout::full(Grid::new(side, side), dfg.groups_used());
        let legacy = MappingEngine::default().map(&dfg, &layout);
        for crit in [false, true] {
            let steiner = MappingEngine::new(MapperConfig {
                router_steiner: true,
                router_criticality: crit,
                ..Default::default()
            })
            .map(&dfg, &layout);
            match (&legacy, &steiner) {
                (MapOutcome::Mapped { .. }, MapOutcome::Mapped { mapping, .. }) => {
                    let errs = mapping.validate(&dfg, &layout);
                    if !errs.is_empty() {
                        return Err(format!(
                            "steiner mapping invalid (crit={crit}): {errs:?}"
                        ));
                    }
                }
                (MapOutcome::Failed { .. }, MapOutcome::Failed { .. }) => {}
                _ => {
                    return Err(format!(
                        "routers disagree on feasibility (crit={crit}): \
                         legacy mapped={} steiner mapped={}",
                        matches!(legacy, MapOutcome::Mapped { .. }),
                        matches!(steiner, MapOutcome::Mapped { .. }),
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_steiner_router_sound_on_gen_workloads() {
    // the seeded workload generator's graphs (the loadgen/fuzz input
    // source) through the Steiner router: every success validates.
    forall("steiner_gen_sound", 25, 0x57E3, |g| {
        let cfg = helex::dfg::gen::arb_config(g.rng, g.size);
        let dfg = helex::dfg::gen::generate(&cfg);
        let side = 7 + g.rng.below(3);
        let layout = Layout::full(Grid::new(side, side), dfg.groups_used());
        let engine = MappingEngine::new(MapperConfig {
            router_steiner: true,
            ..Default::default()
        });
        if let MapOutcome::Mapped { mapping: m, .. } = engine.map(&dfg, &layout) {
            let errs = m.validate(&dfg, &layout);
            if !errs.is_empty() {
                return Err(format!("{}: {errs:?}", dfg.name));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_steiner_warm_remap_parity() {
    // the Steiner engine's warm path (net-granular rip-up of dirty
    // nets, pinned routing for the rest) under random support
    // removals: whenever it succeeds the result validates, and it
    // succeeds at least whenever the cold Steiner path does.
    forall("steiner_warm_parity", 20, 0x57E2, |g| {
        let tag = g.rng.next_u64();
        let spec = arb_spec(g, tag);
        let dfg = spec.build();
        let side = 6 + g.rng.below(3);
        let grid = Grid::new(side, side);
        let full = Layout::full(grid, dfg.groups_used());
        let scfg = MapperConfig { router_steiner: true, ..Default::default() };
        let engine = MappingEngine::new(scfg.clone());
        let MapOutcome::Mapped { mapping: witness, .. } = engine.map(&dfg, &full) else {
            return Ok(()); // unmappable random instance: nothing to warm-start
        };
        let mut layout = full.clone();
        for c in grid.compute_cells().collect::<Vec<_>>() {
            for grp in layout.support(c).iter().collect::<Vec<_>>() {
                if g.rng.chance(0.25) {
                    layout.set_support(c, layout.support(c).without(grp));
                }
            }
        }
        let warm = engine.remap_from(&witness, &dfg, &layout);
        let cold = MappingEngine::new(MapperConfig {
            feasibility_cache: false,
            ..scfg
        })
        .map(&dfg, &layout);
        match (&warm, &cold) {
            (MapOutcome::Mapped { mapping, stats }, _) => {
                let errs = mapping.validate(&dfg, &layout);
                if !errs.is_empty() {
                    return Err(format!(
                        "steiner warm remap invalid (warm path: {}): {errs:?}",
                        stats.warm
                    ));
                }
            }
            (MapOutcome::Failed { .. }, MapOutcome::Mapped { .. }) => {
                return Err("steiner remap_from failed where from-scratch succeeds".into());
            }
            (MapOutcome::Failed { .. }, MapOutcome::Failed { .. }) => {}
        }
        Ok(())
    });
}

#[test]
fn prop_steiner_search_trace_is_thread_invariant() {
    // the byte-identity contract re-pinned for the Steiner router: a
    // search session's stripped wire trace, best layout and counters
    // are identical at 1/2/4 in-search threads (each forked worker
    // gets a fresh router arena, so shared scratch can never leak
    // nondeterminism into the reduction).
    use helex::service::wire;
    forall("steiner_threads_parity", 3, 0x57E4, |g| {
        let gen_cfg = helex::dfg::gen::arb_config(g.rng, g.size);
        let dfgs = vec![helex::dfg::gen::generate(&gen_cfg)];
        let side = 6 + g.rng.below(3);
        let grid = Grid::new(side, side);
        let scfg = SearchConfig {
            l_test: 40 + g.rng.below(30),
            l_fail: 2,
            gsg_passes: 1,
            ..Default::default()
        };
        let run = |threads: usize| {
            let engine = MappingEngine::new(MapperConfig {
                router_steiner: true,
                router_criticality: true,
                ..Default::default()
            });
            let cost = CostModel::area();
            let mut trace = String::new();
            let result = {
                let trace = &mut trace;
                let mut obs = move |ev: &SearchEvent| {
                    trace.push_str(&wire::strip_volatile(&wire::encode_event(ev)).to_string());
                    trace.push('\n');
                };
                Explorer::new(grid)
                    .dfgs(&dfgs)
                    .engine(&engine)
                    .cost(&cost)
                    .config(SearchConfig { search_threads: threads, ..scfg.clone() })
                    .observer(&mut obs)
                    .run()
            };
            let summary = result.ok().map(|r| {
                (
                    wire::encode_layout(&r.best_layout).to_string(),
                    r.best_cost.to_bits(),
                    r.stats.tested,
                    r.stats.expanded,
                )
            });
            (trace, summary)
        };
        let base = run(1);
        for threads in [2usize, 4] {
            let other = run(threads);
            if base != other {
                return Err(format!(
                    "steiner search diverged at {threads} threads: \
                     trace {}B vs {}B",
                    base.0.len(),
                    other.0.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pareto_front_is_nondominated_and_complete() {
    // the archive invariant under random offer sequences: no resident
    // point is dominated by another, and every offered layout is either
    // resident, dominated by a resident, or a coordinate duplicate of
    // one — nothing is silently lost. The surviving *coordinate set* is
    // offer-order independent.
    forall("pareto_front_sound", 60, 0xFA0, |g| {
        let side = 5 + g.rng.below(3);
        let grid = Grid::new(side, side);
        let full = Layout::full(grid, GroupSet::all_compute());
        let cells: Vec<_> = grid.compute_cells().collect();
        let mut offers = vec![full.clone()];
        for _ in 0..(4 + g.size) {
            let mut l = full.clone();
            for &c in &cells {
                for grp in l.support(c).iter().collect::<Vec<_>>() {
                    if g.rng.chance(0.2) {
                        l.set_support(c, l.support(c).without(grp));
                    }
                }
            }
            offers.push(l);
        }
        let mut front = ParetoFront::new();
        for l in &offers {
            front.insert(l);
        }
        let pts = front.points();
        for (i, p) in pts.iter().enumerate() {
            for (j, q) in pts.iter().enumerate() {
                if i != j && dominates(p, q) {
                    return Err(format!("front retains dominated point: {q:?} under {p:?}"));
                }
            }
        }
        for l in &offers {
            let p = evaluate(l);
            let resident = pts.iter().any(|q| q.fingerprint == p.fingerprint);
            let duplicate = pts.iter().any(|q| {
                q.ops == p.ops && q.area_um2 == p.area_um2 && q.power_uw == p.power_uw
            });
            if !(resident || duplicate || front.dominates_point(&p)) {
                return Err(format!("offer lost without cause: {p:?}"));
            }
        }
        // reversing the offer order must keep the same coordinate set
        // (fingerprints may differ when distinct layouts tie on all
        // three coordinates — the first offer wins the slot)
        let mut rev = ParetoFront::new();
        for l in offers.iter().rev() {
            rev.insert(l);
        }
        let coords = |f: &ParetoFront| -> Vec<(usize, u64, u64)> {
            f.points()
                .iter()
                .map(|p| (p.ops, p.area_um2.to_bits(), p.power_uw.to_bits()))
                .collect()
        };
        if coords(&front) != coords(&rev) {
            return Err("non-dominated coordinate set depends on offer order".into());
        }
        Ok(())
    });
}

#[test]
fn prop_subgraph_seed_adopts_or_falls_back() {
    // enabling the subgraph seed phase can steer the search but can
    // never break it: feasibility is unchanged, and the seeded session
    // still meets the full soundness bar (valid witnesses, minimum
    // instances, cost never above full).
    forall("subgraph_seed_sound", 10, 0x5B6, |g| {
        let gen_cfg = helex::dfg::gen::arb_config(g.rng, g.size);
        let dfgs = vec![helex::dfg::gen::generate(&gen_cfg)];
        let side = 6 + g.rng.below(3);
        let grid = Grid::new(side, side);
        let cost = CostModel::area();
        let base = SearchConfig { l_test: 40, gsg_passes: 1, ..Default::default() };
        let plain = Explorer::new(grid)
            .dfgs(&dfgs)
            .engine(&MappingEngine::default())
            .cost(&cost)
            .config(base.clone())
            .run();
        let seeded = Explorer::new(grid)
            .dfgs(&dfgs)
            .engine(&MappingEngine::default())
            .cost(&cost)
            .config(SearchConfig { subgraph_seed: true, ..base })
            .run();
        match (&plain, &seeded) {
            (Ok(_), Ok(s)) => {
                for (di, d) in dfgs.iter().enumerate() {
                    let errs = s.final_mappings[di].validate(d, &s.best_layout);
                    if !errs.is_empty() {
                        return Err(format!("seeded witness invalid: {errs:?}"));
                    }
                }
                if !helex::search::meets_min_instances(&s.best_layout, &s.min_insts) {
                    return Err("seeded run violates min instances".into());
                }
                let full_cost = cost.layout_cost(&s.full_layout);
                if s.best_cost > full_cost + 1e-9 {
                    return Err(format!("seeded cost increased: {} > {full_cost}", s.best_cost));
                }
            }
            (Err(_), Err(_)) => {}
            _ => {
                return Err(format!(
                    "subgraph seed flipped feasibility: plain ok={} seeded ok={}",
                    plain.is_ok(),
                    seeded.is_ok()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pareto_trace_is_thread_invariant() {
    // the multi-objective analogue of search-threads-parity: a Pareto
    // session's stripped wire trace, final front and counters are
    // byte-identical at 1/2/4 in-search threads on random generated
    // workloads (the genetic phase's RNG is thread-invariant and its
    // batches reduce in breed order).
    use helex::service::wire;
    forall("pareto_threads_parity", 3, 0x9A12, |g| {
        let gen_cfg = helex::dfg::gen::arb_config(g.rng, g.size);
        let dfgs = vec![helex::dfg::gen::generate(&gen_cfg)];
        let side = 6 + g.size % 3;
        let grid = Grid::new(side, side);
        let scfg = SearchConfig {
            l_test: 40 + g.rng.below(30),
            l_fail: 2,
            gsg_passes: 1,
            objective: SearchObjective::Pareto,
            genetic_generations: 2,
            genetic_population: 6,
            ..Default::default()
        };
        let run = |threads: usize| {
            let engine = MappingEngine::default();
            let cost = CostModel::area();
            let mut trace = String::new();
            let result = {
                let trace = &mut trace;
                let mut obs = move |ev: &SearchEvent| {
                    trace.push_str(&wire::strip_volatile(&wire::encode_event(ev)).to_string());
                    trace.push('\n');
                };
                Explorer::new(grid)
                    .dfgs(&dfgs)
                    .engine(&engine)
                    .cost(&cost)
                    .config(SearchConfig { search_threads: threads, ..scfg.clone() })
                    .observer(&mut obs)
                    .run()
            };
            let summary = result.ok().map(|r| {
                (r.front, r.best_layout, r.stats.tested, r.stats.expanded)
            });
            (trace, summary)
        };
        let base = run(1);
        for threads in [2usize, 4] {
            let other = run(threads);
            if base != other {
                return Err(format!(
                    "pareto run diverged at {threads} threads: \
                     base trace {}B front {:?}; other trace {}B front {:?}",
                    base.0.len(),
                    base.1.as_ref().map(|s| s.0.len()),
                    other.0.len(),
                    other.1.as_ref().map(|s| s.0.len()),
                ));
            }
        }
        Ok(())
    });
}

/// One observed search run: the stripped wire trace plus (on success)
/// the encoded best layout, cost bits and counters — everything the
/// byte-identity contract covers.
fn fabric_parity_run(
    dfgs: &[Dfg],
    grid: Grid,
    scfg: &SearchConfig,
    fabric: Option<helex::FabricSpec>,
    threads: usize,
) -> (String, Option<(String, u64, usize, usize)>) {
    use helex::service::wire;
    let engine = MappingEngine::default();
    let cost = CostModel::area();
    let mut trace = String::new();
    let result = {
        let trace = &mut trace;
        let mut obs = move |ev: &SearchEvent| {
            trace.push_str(&wire::strip_volatile(&wire::encode_event(ev)).to_string());
            trace.push('\n');
        };
        let mut ex = Explorer::new(grid)
            .dfgs(dfgs)
            .engine(&engine)
            .cost(&cost)
            .config(SearchConfig { search_threads: threads, ..scfg.clone() })
            .observer(&mut obs);
        if let Some(spec) = fabric {
            ex = ex.fabric(spec);
        }
        ex.run()
    };
    let summary = result.ok().map(|r| {
        (
            wire::encode_layout(&r.best_layout).to_string(),
            r.best_cost.to_bits(),
            r.stats.tested,
            r.stats.expanded,
        )
    });
    (trace, summary)
}

/// The explicit-Mesh4 `Fabric` path must be byte-identical to the
/// legacy grid path — same stripped traces, same encoded layouts, same
/// counters — at 1 and 4 in-search threads, on committed corpus graphs
/// and on generated workloads.
fn fabric_parity_check(dfgs: &[Dfg], grid: Grid, scfg: &SearchConfig) -> Result<(), String> {
    let legacy = fabric_parity_run(dfgs, grid, scfg, None, 1);
    for threads in [1usize, 4] {
        let explicit =
            fabric_parity_run(dfgs, grid, scfg, Some(helex::FabricSpec::default()), threads);
        if explicit != legacy {
            return Err(format!(
                "explicit Mesh4 fabric diverged from the legacy path at {threads} thread(s): \
                 trace {}B vs {}B",
                explicit.0.len(),
                legacy.0.len()
            ));
        }
    }
    if let Some((layout_bytes, ..)) = &legacy.1 {
        if layout_bytes.contains("\"fabric\"") {
            return Err("default-fabric layout must not carry a fabric wire key".into());
        }
    }
    Ok(())
}

#[test]
fn prop_mesh4_fabric_matches_legacy_on_corpus_graphs() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("corpus");
    let scfg = SearchConfig { l_test: 40, l_fail: 2, gsg_passes: 1, ..Default::default() };
    for name in ["SOB", "BIL"] {
        let dfg = helex::dfg::io::from_path(&dir.join(format!("{name}.json")))
            .expect("corpus graph loads");
        fabric_parity_check(&[dfg], Grid::new(7, 7), &scfg).unwrap();
    }
    // the job-level identity: a spec with an explicitly-default fabric
    // keys the same cached run as a pre-fabric spec
    let dfg = helex::dfg::io::from_path(&dir.join("SOB.json")).unwrap();
    let legacy = helex::JobSpec::new("corpus", vec![dfg], Grid::new(7, 7));
    let mut explicit = legacy.clone();
    explicit.fabric = helex::FabricSpec::default();
    assert_eq!(explicit.fingerprint(), legacy.fingerprint());
}

#[test]
fn prop_mesh4_fabric_matches_legacy_on_generated_workloads() {
    forall("mesh4_fabric_parity", 3, 0xFAB0, |g| {
        let gen_cfg = helex::dfg::gen::arb_config(g.rng, g.size);
        let dfgs = vec![helex::dfg::gen::generate(&gen_cfg)];
        let side = 6 + g.rng.below(3);
        let scfg = SearchConfig {
            l_test: 40 + g.rng.below(30),
            l_fail: 2,
            gsg_passes: 1,
            ..Default::default()
        };
        fabric_parity_check(&dfgs, Grid::new(side, side), &scfg)
    });
}

#[test]
fn prop_groupset_algebra() {
    let mut rng = Rng::seed(0x6e);
    for _ in 0..500 {
        let a = GroupSet(rng.below(64) as u8);
        let b = GroupSet(rng.below(64) as u8);
        // de morgan-ish sanity on the 6-group universe
        assert_eq!(a.union(b).len() + a.intersect(b).len(), a.len() + b.len());
        assert!(a.intersect(b).is_subset_of(a));
        assert!(a.is_subset_of(a.union(b)));
        assert_eq!(a.minus(b).intersect(b), GroupSet::EMPTY);
        assert_eq!(a.minus(b).union(a.intersect(b)), a);
    }
}
