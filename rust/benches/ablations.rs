//! Ablation benches for the design choices DESIGN.md calls out:
//! heatmap start vs full start, GSG on/off, reserve-on-demand on/off,
//! routing negotiation depth. Each reports both wall time and result
//! quality (final cost), because the trade-off is the point.
//!
//! ```sh
//! cargo bench --bench ablations
//! ```

use helex::cgra::{Grid, Layout};
use helex::cost::CostModel;
use helex::dfg::benchmarks;
use helex::mapper::{MapperConfig, MappingEngine};
use helex::search::SearchConfig;
use helex::util::bench::Harness;

fn main() {
    let mut h = Harness::from_args();
    let cost = CostModel::area();
    let dfgs = benchmarks::dfg_set("S3");
    let grid = Grid::new(10, 10);
    let engine = MappingEngine::default();
    let base = SearchConfig { l_test: 150, gsg_passes: 1, ..Default::default() };

    println!("== search ablations (S3 @ 10x10, L_test=150) ==");
    for (name, cfg) in [
        ("search::heatmap+gsg", base.clone()),
        ("search::no_heatmap", SearchConfig { use_heatmap: false, ..base.clone() }),
        ("search::no_gsg", SearchConfig { run_gsg: false, ..base.clone() }),
        (
            "search::no_heatmap_no_gsg",
            SearchConfig { use_heatmap: false, run_gsg: false, ..base.clone() },
        ),
    ] {
        let mut final_cost = 0.0;
        h.bench_once(name, || {
            let r = helex::search::Explorer::new(grid)
                .dfgs(&dfgs)
                .engine(&engine)
                .cost(&cost)
                .config(cfg.clone())
                .run()
                .unwrap();
            final_cost = r.best_cost;
        });
        println!("    -> final cost {final_cost:.1}");
    }

    println!("\n== mapper ablations (MD @ 10x10) ==");
    let d = benchmarks::benchmark("MD");
    let full = Layout::full(grid, d.groups_used());
    for (name, mcfg) in [
        ("mapper::default", bench_cfg(MapperConfig::default())),
        (
            "mapper::no_reserve",
            bench_cfg(MapperConfig { max_reserves: 0, ..MapperConfig::default() }),
        ),
        (
            "mapper::route_iters_4",
            bench_cfg(MapperConfig { route_iters: 4, ..MapperConfig::default() }),
        ),
        (
            "mapper::route_iters_24",
            bench_cfg(MapperConfig { route_iters: 24, ..MapperConfig::default() }),
        ),
        (
            "mapper::single_attempt",
            bench_cfg(MapperConfig { placement_attempts: 1, ..MapperConfig::default() }),
        ),
    ] {
        let m = MappingEngine::new(mcfg);
        let mut success = false;
        h.bench(name, || {
            let r = m.map(&d, &full);
            success = r.is_mapped();
            r.is_mapped()
        });
        println!("    -> success: {success}");
    }
}

/// Repeated identical map calls must do real work: cache off.
fn bench_cfg(cfg: MapperConfig) -> MapperConfig {
    MapperConfig { feasibility_cache: false, ..cfg }
}
