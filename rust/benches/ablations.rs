//! Ablation benches for the design choices DESIGN.md calls out:
//! heatmap start vs full start, GSG on/off, reserve-on-demand on/off,
//! routing negotiation depth. Each reports both wall time and result
//! quality (final cost), because the trade-off is the point.
//!
//! ```sh
//! cargo bench --bench ablations
//! ```

use helex::cgra::{Grid, Layout};
use helex::cost::CostModel;
use helex::dfg::benchmarks;
use helex::mapper::MapperConfig;
use helex::search::SearchConfig;
use helex::util::bench::Harness;
use helex::Mapper;

fn main() {
    let mut h = Harness::from_args();
    let cost = CostModel::area();
    let dfgs = benchmarks::dfg_set("S3");
    let grid = Grid::new(10, 10);
    let mapper = Mapper::default();
    let base = SearchConfig { l_test: 150, gsg_passes: 1, ..Default::default() };

    println!("== search ablations (S3 @ 10x10, L_test=150) ==");
    for (name, cfg) in [
        ("search::heatmap+gsg", base.clone()),
        ("search::no_heatmap", SearchConfig { use_heatmap: false, ..base.clone() }),
        ("search::no_gsg", SearchConfig { run_gsg: false, ..base.clone() }),
        (
            "search::no_heatmap_no_gsg",
            SearchConfig { use_heatmap: false, run_gsg: false, ..base.clone() },
        ),
    ] {
        let mut final_cost = 0.0;
        h.bench_once(name, || {
            let r = helex::search::Explorer::new(grid)
                .dfgs(&dfgs)
                .mapper(&mapper)
                .cost(&cost)
                .config(cfg.clone())
                .run()
                .unwrap();
            final_cost = r.best_cost;
        });
        println!("    -> final cost {final_cost:.1}");
    }

    println!("\n== mapper ablations (MD @ 10x10) ==");
    let d = benchmarks::benchmark("MD");
    let full = Layout::full(grid, d.groups_used());
    for (name, mcfg) in [
        ("mapper::default", MapperConfig::default()),
        (
            "mapper::no_reserve",
            MapperConfig { max_reserves: 0, ..MapperConfig::default() },
        ),
        (
            "mapper::route_iters_4",
            MapperConfig { route_iters: 4, ..MapperConfig::default() },
        ),
        (
            "mapper::route_iters_24",
            MapperConfig { route_iters: 24, ..MapperConfig::default() },
        ),
        (
            "mapper::single_attempt",
            MapperConfig { placement_attempts: 1, ..MapperConfig::default() },
        ),
    ] {
        let m = Mapper::new(mcfg);
        let mut success = false;
        h.bench(name, || {
            let r = m.map(&d, &full);
            success = r.is_some();
            r
        });
        println!("    -> success: {success}");
    }
}
