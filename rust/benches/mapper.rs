//! Mapper micro-benches: the search's true hot path (thousands of map
//! attempts per run). Tracked across the perf pass in EXPERIMENTS.md.
//!
//! The `remap::*` section is the MappingEngine headline: on a workload
//! of one-group-removal neighbor layouts (exactly what OPSG/GSG test),
//! the incremental warm-start path (`remap_from`) is compared against
//! from-scratch mapping of the same neighbors — warm must win.
//!
//! ```sh
//! cargo bench --bench mapper
//! ```

use helex::cgra::{Grid, Layout};
use helex::dfg::{benchmarks, heta};
use helex::mapper::{MapOutcome, MapperConfig, MappingEngine};
use helex::util::bench::Harness;

fn main() {
    let mut h = Harness::from_args();
    // micro-benches re-map identical (DFG, layout) pairs on purpose, so
    // the feasibility cache must be off to measure real work
    let engine =
        MappingEngine::new(MapperConfig { feasibility_cache: false, ..Default::default() });

    // individual DFGs, spanning sizes
    for (name, r, c) in [
        ("SOB", 5, 5),
        ("GB", 7, 7),
        ("NMS", 9, 9),
        ("FFT", 10, 10),
        ("MD", 10, 10),
        ("SAD", 12, 12),
    ] {
        let d = benchmarks::benchmark(name);
        let l = Layout::full(Grid::new(r, c), d.groups_used());
        h.bench(&format!("map::{name}_{r}x{c}"), || engine.map(&d, &l).is_mapped());
    }

    // the testLayout composite (all 12 DFGs), the unit the BB search pays
    let dfgs = benchmarks::all();
    let full = Layout::full(Grid::new(10, 10), helex::dfg::groups_used(&dfgs));
    h.bench("test_layout::12dfgs_10x10", || engine.test_layout(&dfgs, &full));

    // heterogeneous layout (harder placement): heatmap of the 12 DFGs
    if let Some(heat) = helex::search::heatmap::overlay(&dfgs, &full, &engine) {
        h.bench("test_layout::12dfgs_10x10_heatmap", || engine.test_layout(&dfgs, &heat));
    }

    // the 20x20 comparison grid
    let hdfgs = heta::all();
    let big = Layout::full(Grid::new(20, 20), helex::dfg::groups_used(&hdfgs));
    h.bench("test_layout::8heta_20x20", || engine.test_layout(&hdfgs, &big));

    // warm-start vs from-scratch on one-group-removal neighbors: for
    // each compute node of a witness mapping, remove its group under its
    // cell — the displacement-forcing neighbor workload the BB search
    // generates. Warm remaps repair the witness; cold maps start over.
    println!("\n== warm-start vs from-scratch (one-group-removal neighbors) ==");
    for (name, r, c) in [("NMS", 9, 9), ("FFT", 10, 10), ("MD", 10, 10)] {
        let d = benchmarks::benchmark(name);
        let full = Layout::full(Grid::new(r, c), d.groups_used());
        let MapOutcome::Mapped { mapping: witness, .. } = engine.map(&d, &full) else {
            println!("(skipping {name}: does not map on {r}x{c})");
            continue;
        };
        let neighbors: Vec<Layout> = d
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, op)| !op.is_memory())
            .map(|(n, op)| full.without_group(witness.node_cell[n], op.group()))
            .collect();
        let mut warm_ok = 0usize;
        let mut cold_ok = 0usize;
        h.bench(&format!("remap::{name}_{}neighbors_cold", neighbors.len()), || {
            cold_ok = neighbors.iter().filter(|l| engine.map(&d, l).is_mapped()).count();
            cold_ok
        });
        h.bench(&format!("remap::{name}_{}neighbors_warm", neighbors.len()), || {
            warm_ok = neighbors
                .iter()
                .filter(|l| engine.remap_from(&witness, &d, l).is_mapped())
                .count();
            warm_ok
        });
        println!(
            "    -> feasible neighbors: warm {warm_ok}/{n}, cold {cold_ok}/{n}",
            n = neighbors.len()
        );
    }
}
