//! Mapper micro-benches: the search's true hot path (thousands of map
//! attempts per run). Tracked across the perf pass in EXPERIMENTS.md.
//!
//! ```sh
//! cargo bench --bench mapper
//! ```

use helex::cgra::{Grid, Layout};
use helex::dfg::{benchmarks, heta};
use helex::util::bench::Harness;
use helex::Mapper;

fn main() {
    let mut h = Harness::from_args();
    let mapper = Mapper::default();

    // individual DFGs, spanning sizes
    for (name, r, c) in [
        ("SOB", 5, 5),
        ("GB", 7, 7),
        ("NMS", 9, 9),
        ("FFT", 10, 10),
        ("MD", 10, 10),
        ("SAD", 12, 12),
    ] {
        let d = benchmarks::benchmark(name);
        let l = Layout::full(Grid::new(r, c), d.groups_used());
        h.bench(&format!("map::{name}_{r}x{c}"), || mapper.map(&d, &l));
    }

    // the testLayout composite (all 12 DFGs), the unit the BB search pays
    let dfgs = benchmarks::all();
    let full = Layout::full(Grid::new(10, 10), helex::dfg::groups_used(&dfgs));
    h.bench("test_layout::12dfgs_10x10", || mapper.test_layout(&dfgs, &full));

    // heterogeneous layout (harder placement): heatmap of the 12 DFGs
    if let Some(heat) = helex::search::heatmap::overlay(&dfgs, &full, &mapper) {
        h.bench("test_layout::12dfgs_10x10_heatmap", || {
            mapper.test_layout(&dfgs, &heat)
        });
    }

    // the 20x20 comparison grid
    let hdfgs = heta::all();
    let big = Layout::full(Grid::new(20, 20), helex::dfg::groups_used(&hdfgs));
    h.bench("test_layout::8heta_20x20", || mapper.test_layout(&hdfgs, &big));
}
