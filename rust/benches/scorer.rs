//! Scorer benches: XLA/PJRT batched scoring vs the native evaluator, and
//! the end-to-end search with/without the XLA scorer. This is the
//! ablation for the runtime layer (EXPERIMENTS.md §Perf).
//!
//! ```sh
//! cargo bench --bench scorer
//! ```

use helex::cgra::{Grid, Layout};
use helex::cost::CostModel;
use helex::ops::{GroupSet, NUM_GROUPS};
use helex::runtime::{artifacts_dir, Scorer, BATCH};
use helex::search::{BatchScorer, NativeScorer};
use helex::util::bench::Harness;

fn main() {
    let mut h = Harness::from_args();
    let cost = CostModel::area();
    let grid = Grid::new(10, 10);

    // workload: one full BATCH of candidate instance vectors
    let vectors: Vec<[usize; NUM_GROUPS]> = (0..BATCH)
        .map(|i| [i % 64, i % 7, i % 13, 0, i % 11, i % 5])
        .collect();

    let mut native = NativeScorer { cost: cost.clone() };
    h.bench("native_scorer::score_256_vectors", || {
        native.score(grid.num_compute(), &vectors)
    });

    match Scorer::load(&artifacts_dir(), &cost) {
        Ok(mut s) => {
            h.bench("xla_scorer::score_256_vectors", || {
                s.score(grid.num_compute(), &vectors)
            });
            // cell-level layout scoring (the exact-representation path)
            let full = Layout::full(grid, GroupSet::all_compute());
            let layouts: Vec<Layout> = (0..64)
                .map(|i| {
                    let cell = grid.compute_cells().nth(i % grid.num_compute()).unwrap();
                    full.without_group(cell, helex::ops::COMPUTE_GROUPS[i % 5])
                })
                .collect();
            h.bench("xla_scorer::score_64_layouts", || {
                s.score_layouts(&layouts).unwrap()
            });
            println!("\n(total PJRT executions this run: {})", s.calls);
        }
        Err(e) => println!("xla scorer skipped: {e}"),
    }

    // end-to-end search ablation: native vs XLA scoring
    let dfgs = vec![helex::dfg::benchmarks::benchmark("NMS")];
    let engine = helex::MappingEngine::default();
    let cfg = helex::search::SearchConfig { l_test: 80, gsg_passes: 1, ..Default::default() };
    h.bench_once("search::nms_8x8_native_scoring", || {
        helex::search::Explorer::new(Grid::new(8, 8))
            .dfgs(&dfgs)
            .engine(&engine)
            .cost(&cost)
            .config(cfg.clone())
            .run()
    });
    if let Ok(mut s) = Scorer::load(&artifacts_dir(), &cost) {
        h.bench_once("search::nms_8x8_xla_scoring", || {
            helex::search::Explorer::new(Grid::new(8, 8))
                .dfgs(&dfgs)
                .engine(&engine)
                .cost(&cost)
                .config(cfg.clone())
                .scorer(&mut s)
                .run()
        });
    }
}
