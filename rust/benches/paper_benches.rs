//! End-to-end benches: one per paper table/figure. Each regenerates the
//! experiment at bench scale and reports its wall time (criterion is not
//! vendored in this image; `util::bench::Harness` provides the harness).
//!
//! ```sh
//! cargo bench --bench paper_benches             # all
//! cargo bench --bench paper_benches -- fig3     # filter
//! ```

use helex::coordinator::{experiments, Coordinator, ExperimentConfig};
use helex::util::bench::Harness;

fn co() -> Coordinator {
    Coordinator::new(ExperimentConfig {
        l_test_base: 120,
        gsg_passes: 1,
        verbose: false,
        ..Default::default()
    })
}

fn main() {
    let mut h = Harness::from_args();
    println!("== paper experiment benches (bench-scale budgets) ==");

    // Each experiment is measured once end-to-end: these are
    // minutes-scale workloads, not microbenchmarks.
    for exp in [
        "fig3", "fig4", "table4", "fig5", "fig6", "table5", "table6", "fig7", "table8",
        "fig9", "fig10", "fig11",
    ] {
        h.bench_once(&format!("exp::{exp}"), || {
            let mut c = co();
            // suppress experiment stdout: route results to a sink table
            experiments::run_experiment(&mut c, exp, true).expect("experiment runs");
        });
    }
    println!("\n{} experiments benchmarked", h.results.len());
}
