//! End-to-end benches: one per paper table/figure. Each regenerates the
//! experiment at bench scale and reports its wall time (criterion is not
//! vendored in this image; `util::bench::Harness` provides the harness).
//!
//! ```sh
//! cargo bench --bench paper_benches             # all
//! cargo bench --bench paper_benches -- fig3     # filter
//! ```

use helex::coordinator::{experiments, suite, Coordinator, ExperimentConfig};
use helex::search::{Explorer, SearchConfig, SearchEvent};
use helex::service::cache::CachedJob;
use helex::service::ExplorationService;
use helex::store::ResultStore;
use helex::util::bench::Harness;
use helex::util::json::{self, Json};

/// One measured search at a given in-search thread count on the fig9
/// medium spec (S4 @ 9×9, bench-scale budget). Returns
/// `(opsg+gsg tested layouts, opsg secs, gsg secs, speculative)`.
fn search_scaling_run(threads: usize) -> (usize, f64, f64, usize) {
    let dfgs = helex::dfg::benchmarks::dfg_set("S4");
    let grid = helex::Grid::new(9, 9);
    let engine = helex::MappingEngine::default();
    let cost = helex::CostModel::area();
    let cfg = SearchConfig {
        l_test: 400,
        gsg_passes: 1,
        search_threads: threads,
        ..Default::default()
    };
    let tested = std::cell::Cell::new(0usize);
    let in_search = std::cell::Cell::new(false);
    let mut obs = |ev: &SearchEvent| match ev {
        SearchEvent::PhaseStarted { phase, .. } => {
            in_search.set(phase == "OPSG" || phase == "GSG");
        }
        SearchEvent::LayoutTested { .. } => {
            if in_search.get() {
                tested.set(tested.get() + 1);
            }
        }
        _ => {}
    };
    let r = Explorer::new(grid)
        .dfgs(&dfgs)
        .engine(&engine)
        .cost(&cost)
        .config(cfg)
        .observer(&mut obs)
        .run()
        .expect("S4 maps on 9x9");
    (tested.get(), r.stats.t_opsg(), r.stats.t_gsg(), r.stats.speculative)
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn co() -> Coordinator {
    Coordinator::new(ExperimentConfig {
        l_test_base: 120,
        gsg_passes: 1,
        verbose: false,
        ..Default::default()
    })
}

fn main() {
    let mut h = Harness::from_args();
    println!("== paper experiment benches (bench-scale budgets) ==");

    // Each experiment is measured once end-to-end: these are
    // minutes-scale workloads, not microbenchmarks.
    for exp in [
        "fig3", "fig4", "table4", "fig5", "fig6", "table5", "table6", "fig7", "table8",
        "fig9", "fig10", "fig11",
    ] {
        h.bench_once(&format!("exp::{exp}"), || {
            let mut c = co();
            // suppress experiment stdout: route results to a sink table
            experiments::run_experiment(&mut c, exp, true).expect("experiment runs");
        });
    }

    // Suite throughput: jobs/sec at 1, 2 and 4 workers on the fig9
    // sweep (5 independent jobs). A fresh service per measurement keeps
    // the run cache from hiding work, so the numbers track the worker
    // pool's real speedup in the perf trajectory.
    println!("\n== suite throughput (fig9 sweep, 5 jobs) ==");
    let mut throughput: Vec<(String, f64)> = Vec::new();
    for workers in [1usize, 2, 4] {
        let name = format!("suite::fig9@{workers}w");
        let mut unique_jobs = 0usize;
        h.bench_once(&name, || {
            let cfg = ExperimentConfig {
                l_test_base: 120,
                gsg_passes: 1,
                ..Default::default()
            };
            let defs = experiments::find("fig9").expect("fig9 exists");
            let service = ExplorationService::with_jobs(workers);
            let tables = suite::run_suite(&cfg, &defs, true, &service, None);
            unique_jobs = service.cache_len();
            tables
        });
        match h.results.last() {
            Some(r) if r.name == name && unique_jobs > 0 => {
                let jobs_per_sec = unique_jobs as f64 / (r.median_ns / 1e9);
                println!("    -> {jobs_per_sec:.2} jobs/s over {unique_jobs} unique jobs");
                throughput.push((format!("{workers}w"), jobs_per_sec));
            }
            _ => {}
        }
    }

    // Search-threads scaling: wall time and tested-layouts/sec of the
    // OPSG+GSG phases at 1 vs 4 in-search workers on the fig9 medium
    // spec. The deterministic reduction makes `tested` identical at any
    // thread count, so layouts/sec isolates the real speedup. Five
    // runs per point; medians feed BENCH_search.json, which CI's
    // bench-track job gates (ratio >= 1.5 at 4t, and no >20% regression
    // of the medians vs the committed baseline).
    // (lps1, lps4, wall1, wall4, speedup); None when the section is
    // filtered out — the merged BENCH_search.json write keeps the prior
    // record's values then
    let mut threads_fields: Option<(f64, f64, f64, f64, f64)> = None;
    if h.enabled("search::threads") {
        println!("\n== search-threads scaling (fig9 medium spec: S4 @ 9x9, l_test 400) ==");
        let mut per_point: Vec<(usize, f64, f64)> = Vec::new(); // (threads, lps, wall)
        for &threads in &[1usize, 4] {
            let mut lps = Vec::new();
            let mut walls = Vec::new();
            let mut tested_total = 0usize;
            let mut spec_total = 0usize;
            // 5 samples per point: the medians gate CI on shared
            // runners, so they need headroom against noisy neighbors
            for _ in 0..5 {
                let (tested, t_opsg, t_gsg, speculative) = search_scaling_run(threads);
                let wall = (t_opsg + t_gsg).max(1e-9);
                lps.push(tested as f64 / wall);
                walls.push(wall);
                tested_total = tested;
                spec_total = speculative;
            }
            let lps_med = median(&mut lps);
            let wall_med = median(&mut walls);
            println!(
                "    search::threads@{threads}t  {lps_med:>8.1} layouts/s  \
                 (wall {wall_med:.2}s, {tested_total} tested, {spec_total} speculative)"
            );
            per_point.push((threads, lps_med, wall_med));
        }
        if let [(_, lps1, wall1), (_, lps4, wall4)] = per_point.as_slice() {
            let speedup = lps4 / lps1;
            println!("    -> {speedup:.2}x tested-layouts/sec at 4 threads vs 1");
            threads_fields = Some((*lps1, *lps4, *wall1, *wall4, speedup));
        }
    }

    // Genetic front quality: Pareto-objective searches on the same
    // fig9 medium spec, scored as 2-D (area, power) hypervolume of the
    // final front against the full layout's synth numbers, per second
    // of session wall time. Medians feed BENCH_search.json next to the
    // thread-scaling numbers.
    let mut genetic_hv_per_sec: Option<f64> = None;
    if h.enabled("search::genetic") {
        println!("\n== genetic front quality (S4 @ 9x9, pareto objective, l_test 400) ==");
        let cfg = SearchConfig {
            l_test: 400,
            gsg_passes: 1,
            objective: helex::search::SearchObjective::Pareto,
            ..Default::default()
        };
        let dfgs = helex::dfg::benchmarks::dfg_set("S4");
        let grid = helex::Grid::new(9, 9);
        let cost = helex::CostModel::area();
        let mut rates = Vec::new();
        let mut front_len = 0usize;
        let mut hv = 0.0f64;
        for _ in 0..3 {
            let engine = helex::MappingEngine::default();
            let r = Explorer::new(grid)
                .dfgs(&dfgs)
                .engine(&engine)
                .cost(&cost)
                .config(cfg.clone())
                .run()
                .expect("S4 maps on 9x9");
            let full = helex::cost::synth::synthesize(&r.full_layout);
            hv = helex::search::pareto::hypervolume_2d(
                &r.front,
                full.area_um2,
                full.power_uw,
            );
            rates.push(hv / r.stats.t_total().max(1e-9));
            front_len = r.front.len();
        }
        let rate_med = median(&mut rates);
        println!(
            "    search::genetic  {rate_med:>12.0} hv-um2uW/s  \
             ({front_len} front point(s), hv {hv:.0})"
        );
        genetic_hv_per_sec = Some(rate_med);
    }

    // Fabric routing throughput: routed-nets/sec of the PathFinder on a
    // pinned 8-stream LOAD->ADD->STORE workload at 9x9, Mesh4 vs
    // Express(stride 2). Placement and net set are identical; the delta
    // is the cost of searching the richer link set. Medians land in
    // BENCH_search.json next to the thread-scaling numbers.
    let mut fabric_route: Option<(f64, f64)> = None;
    if h.enabled("fabric::route") {
        use helex::cgra::Layout;
        use helex::fabric::{Fabric, FabricSpec, Topology};
        use helex::mapper::route::{route, RouteOutcome};
        use helex::mapper::MapperConfig;
        use helex::ops::{GroupSet, Op};

        println!("\n== fabric routing throughput (8 LOAD->ADD->STORE streams @ 9x9) ==");
        let mut ops = Vec::new();
        ops.extend(std::iter::repeat(Op::Load).take(8));
        ops.extend(std::iter::repeat(Op::Add).take(8));
        ops.extend(std::iter::repeat(Op::Store).take(8));
        let mut edges = Vec::new();
        for i in 0..8u32 {
            edges.push((i, 8 + i)); // LOAD -> ADD
            edges.push((8 + i, 16 + i)); // ADD -> STORE
        }
        let dfg = helex::dfg::Dfg::new("fabric-route-bench", ops, edges);
        let net_count = 16.0f64;

        let express =
            FabricSpec { topology: Topology::Express { stride: 2 }, ..FabricSpec::default() };
        let mut rates = Vec::new();
        for (tag, spec) in [("mesh4", FabricSpec::default()), ("express", express)] {
            let layout =
                Layout::full_on(Fabric::new(helex::Grid::new(9, 9), spec), GroupSet::all_compute());
            let g = &layout.grid;
            let placement: Vec<_> = (0..8)
                .map(|c| g.cell(0, c))
                .chain((0..8).map(|c| g.cell(4, c)))
                .chain((0..8).map(|c| g.cell(8, c)))
                .collect();
            let cfg = MapperConfig::default();
            let name = format!("fabric::route@{tag}");
            h.bench(&name, || match route(&dfg, &layout, &placement, &cfg) {
                RouteOutcome::Routed(paths) => paths.len(),
                RouteOutcome::Congested { .. } => {
                    panic!("pinned parallel streams must route on {tag}")
                }
            });
            let median_ns = h
                .results
                .iter()
                .rev()
                .find(|r| r.name == name)
                .map(|r| r.median_ns)
                .unwrap_or(0.0);
            let nets_per_sec = net_count * 1e9 / median_ns.max(1e-9);
            println!("    {name}  {nets_per_sec:>10.0} routed nets/s");
            rates.push(nets_per_sec);
        }
        if let [mesh4, express] = rates.as_slice() {
            fabric_route = Some((*mesh4, *express));
        }
    }

    // Steiner routing throughput: routed-nets/sec and rip-up rounds of
    // the legacy edge-by-edge router vs the Steiner multi-fanout router
    // on a fanout-heavy workload (8 fanout-4 nets @ 9x9), Mesh4 vs
    // Express(stride 2). Placement and net set are identical, so the
    // delta is shared-trunk construction plus the engine-owned arena.
    // Medians land in BENCH_search.json; CI's bench-track job gates the
    // Mesh4 speedup (steiner >= 1.3x legacy nets/sec).
    // (per-router rates, mesh4 speedup, legacy rounds, steiner rounds)
    let mut steiner_route_bench: Option<(Vec<(String, f64)>, f64, usize, usize)> = None;
    if h.enabled("route::steiner") {
        use helex::cgra::Layout;
        use helex::fabric::{Fabric, FabricSpec, Topology};
        use helex::mapper::route::{route_rounds, RouteOutcome};
        use helex::mapper::{MapperConfig, SteinerRouter};
        use helex::ops::{GroupSet, Op};

        println!("\n== steiner routing throughput (8 fanout-4 nets @ 9x9) ==");
        let mut ops = Vec::new();
        ops.extend(std::iter::repeat(Op::Load).take(8));
        ops.extend(std::iter::repeat(Op::Add).take(32));
        let mut edges = Vec::new();
        for i in 0..8u32 {
            for k in 0..4u32 {
                edges.push((i, 8 + 4 * i + k)); // LOAD -> 4 consumers
            }
        }
        let dfg = helex::dfg::Dfg::new("steiner-route-bench", ops, edges);
        let net_count = 8.0f64;

        let express =
            FabricSpec { topology: Topology::Express { stride: 2 }, ..FabricSpec::default() };
        let mut rates: Vec<(String, f64)> = Vec::new();
        let mut legacy_rounds = 0usize;
        let mut steiner_rounds = 0usize;
        for (tag, spec) in [("mesh4", FabricSpec::default()), ("express", express)] {
            let layout =
                Layout::full_on(Fabric::new(helex::Grid::new(9, 9), spec), GroupSet::all_compute());
            let g = &layout.grid;
            let placement: Vec<_> = (0..8)
                .map(|c| g.cell(0, c))
                .chain((0..32).map(|j| g.cell(2 + j / 7, 1 + j % 7)))
                .collect();
            let cfg = MapperConfig::default();
            let scfg = MapperConfig { router_steiner: true, ..MapperConfig::default() };
            let steiner = SteinerRouter::new();
            let nets_per_sec = |h: &Harness, name: &str| {
                let median_ns = h
                    .results
                    .iter()
                    .rev()
                    .find(|r| r.name == name)
                    .map(|r| r.median_ns)
                    .unwrap_or(0.0);
                net_count * 1e9 / median_ns.max(1e-9)
            };

            let name = format!("route::legacy@{tag}");
            h.bench(&name, || {
                let (out, rounds) = route_rounds(&dfg, &layout, &placement, &cfg);
                match out {
                    RouteOutcome::Routed(paths) => {
                        legacy_rounds = rounds;
                        paths.len()
                    }
                    RouteOutcome::Congested { .. } => {
                        panic!("fanout workload must route on {tag}")
                    }
                }
            });
            let nps = nets_per_sec(&h, &name);
            println!("    {name}  {nps:>10.0} routed nets/s  ({legacy_rounds} round(s))");
            rates.push((format!("legacy_{tag}"), nps));

            let name = format!("route::steiner@{tag}");
            h.bench(&name, || {
                let (out, rounds) = steiner.route_rounds(&dfg, &layout, &placement, &scfg);
                match out {
                    RouteOutcome::Routed(paths) => {
                        steiner_rounds = rounds;
                        paths.len()
                    }
                    RouteOutcome::Congested { .. } => {
                        panic!("fanout workload must route on {tag}")
                    }
                }
            });
            let nps = nets_per_sec(&h, &name);
            println!("    {name}  {nps:>10.0} routed nets/s  ({steiner_rounds} round(s))");
            rates.push((format!("steiner_{tag}"), nps));
        }
        let rate_of = |key: &str| {
            rates.iter().find(|(k, _)| k == key).map(|(_, v)| *v).unwrap_or(0.0)
        };
        let speedup = rate_of("steiner_mesh4") / rate_of("legacy_mesh4").max(1e-9);
        println!("    -> {speedup:.2}x steiner vs legacy routed-nets/sec on mesh4");
        steiner_route_bench = Some((rates, speedup, legacy_rounds, steiner_rounds));
    }

    // Merge-write BENCH_search.json: a filtered run refreshes only the
    // sections it measured (same pattern as BENCH_service.json below).
    if threads_fields.is_some()
        || genetic_hv_per_sec.is_some()
        || fabric_route.is_some()
        || steiner_route_bench.is_some()
    {
        let prior = std::fs::read_to_string("BENCH_search.json")
            .ok()
            .and_then(|text| json::parse(&text).ok());
        let keep = |key: &str, fallback: Json| {
            prior.as_ref().and_then(|p| p.get(key)).cloned().unwrap_or(fallback)
        };
        let (lps_field, wall_field, speedup_field) = match threads_fields {
            Some((lps1, lps4, wall1, wall4, speedup)) => (
                Json::obj(vec![("1t", Json::F64(lps1)), ("4t", Json::F64(lps4))]),
                Json::obj(vec![("1t", Json::F64(wall1)), ("4t", Json::F64(wall4))]),
                Json::F64(speedup),
            ),
            None => (
                keep("layouts_per_sec", Json::Obj(Vec::new())),
                keep("wall_secs", Json::Obj(Vec::new())),
                keep("speedup_4t", Json::F64(0.0)),
            ),
        };
        let genetic_field = match genetic_hv_per_sec {
            Some(rate) => Json::F64(rate),
            None => keep("genetic_hv_per_sec", Json::F64(0.0)),
        };
        let fabric_field = match fabric_route {
            Some((mesh4, express)) => Json::obj(vec![
                ("mesh4", Json::F64(mesh4)),
                ("express", Json::F64(express)),
            ]),
            None => keep("fabric_route_nets_per_sec", Json::Obj(Vec::new())),
        };
        let (steiner_rates_field, steiner_speedup_field, steiner_rounds_field) =
            match &steiner_route_bench {
                Some((rates, speedup, legacy_rounds, steiner_rounds)) => (
                    Json::Obj(
                        rates.iter().map(|(k, v)| (k.clone(), Json::F64(*v))).collect(),
                    ),
                    Json::F64(*speedup),
                    Json::obj(vec![
                        ("legacy", Json::F64(*legacy_rounds as f64)),
                        ("steiner", Json::F64(*steiner_rounds as f64)),
                    ]),
                ),
                None => (
                    keep("steiner_route_nets_per_sec", Json::Obj(Vec::new())),
                    keep("steiner_speedup", Json::F64(0.0)),
                    keep("steiner_ripup_rounds", Json::Obj(Vec::new())),
                ),
            };
        let record = Json::obj(vec![
            ("bench", Json::str("search")),
            ("spec", Json::str("fig9-medium:S4@9x9,l_test=400,gsg_passes=1")),
            ("layouts_per_sec", lps_field),
            ("wall_secs", wall_field),
            ("speedup_4t", speedup_field),
            ("genetic_hv_per_sec", genetic_field),
            ("fabric_route_nets_per_sec", fabric_field),
            ("steiner_route_nets_per_sec", steiner_rates_field),
            ("steiner_speedup", steiner_speedup_field),
            ("steiner_ripup_rounds", steiner_rounds_field),
        ]);
        if std::fs::write("BENCH_search.json", record.to_string()).is_ok() {
            println!("    wrote BENCH_search.json");
        }
    }

    // Workload-generator throughput: graphs/sec of `dfg::gen` at the
    // loadgen default shape, including the interchange encode — the
    // per-request cost `helex loadgen` pays before it ever touches the
    // network. Seeds advance deterministically (no wall clock).
    if h.enabled("gen::throughput") {
        println!("\n== workload generator throughput (default shape + JSON encode) ==");
        let mut seed = 0u64;
        h.bench("gen::throughput", || {
            seed = seed.wrapping_add(1);
            let cfg = helex::dfg::gen::GenConfig { seed, ..Default::default() };
            let dfg = helex::dfg::gen::generate(&cfg);
            helex::dfg::io::to_json_string(&dfg)
        });
        if let Some(r) = h.results.iter().rev().find(|r| r.name == "gen::throughput") {
            println!("    -> {:.0} graphs/s", 1e9 / r.median_ns.max(1e-9));
        }
    }

    // Result-store round-trip: encode+write+read+decode of one real
    // completed JobResult. This is the per-job overhead `helex serve`
    // pays for durability; it must stay orders of magnitude under the
    // search itself. The fixture (a full search) is skipped entirely
    // when the bench is filtered out.
    let mut store_roundtrip_ns = 0.0f64;
    if h.enabled("store::roundtrip") {
        println!("\n== result store round-trip ==");
        let store_dir =
            std::env::temp_dir().join(format!("helex-bench-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&store_dir);
        let store = ResultStore::open(&store_dir, 0).expect("open bench store");
        let service = ExplorationService::with_jobs(1);
        let spec = helex::JobSpec {
            search: helex::search::SearchConfig {
                l_test: 120,
                gsg_passes: 1,
                ..Default::default()
            },
            ..helex::JobSpec::new(
                "bench",
                helex::dfg::benchmarks::dfg_set("S4"),
                helex::Grid::new(8, 8),
            )
        };
        let result = service.run_job(&spec);
        let cached =
            CachedJob { outcome: result.outcome.clone(), events: result.events.clone() };
        let fingerprint = result.fingerprint;
        h.bench("store::roundtrip", || {
            store.put(fingerprint, &cached).expect("put");
            store.get(fingerprint).expect("hit")
        });
        store_roundtrip_ns = h
            .results
            .iter()
            .rev()
            .find(|r| r.name == "store::roundtrip")
            .map(|r| r.median_ns)
            .unwrap_or(0.0);
        let _ = std::fs::remove_dir_all(&store_dir);
    }

    // Emit the serving-layer perf record (consumed by the perf
    // trajectory like the experiment CSVs). Metrics are merged
    // per-field with any existing record, so a filtered run refreshes
    // only what it measured and never clobbers the other metric with a
    // zero.
    let ran_suite = !throughput.is_empty();
    let ran_store = store_roundtrip_ns > 0.0;
    if ran_suite || ran_store {
        let prior = std::fs::read_to_string("BENCH_service.json")
            .ok()
            .and_then(|text| json::parse(&text).ok());
        let keep = |key: &str, fallback: Json| {
            prior.as_ref().and_then(|p| p.get(key)).cloned().unwrap_or(fallback)
        };
        let suite_field = if ran_suite {
            Json::Obj(
                throughput
                    .iter()
                    .map(|(workers, jps)| (workers.clone(), Json::F64(*jps)))
                    .collect(),
            )
        } else {
            keep("suite_jobs_per_sec", Json::Obj(Vec::new()))
        };
        let store_field = if ran_store {
            Json::F64(store_roundtrip_ns)
        } else {
            keep("store_roundtrip_ns", Json::F64(0.0))
        };
        let record = Json::obj(vec![
            ("bench", Json::str("service")),
            ("suite_jobs_per_sec", suite_field),
            ("store_roundtrip_ns", store_field),
        ]);
        if std::fs::write("BENCH_service.json", record.to_string()).is_ok() {
            println!("\nwrote BENCH_service.json");
        }
    }

    println!("\n{} experiments benchmarked", h.results.len());
}
