//! End-to-end benches: one per paper table/figure. Each regenerates the
//! experiment at bench scale and reports its wall time (criterion is not
//! vendored in this image; `util::bench::Harness` provides the harness).
//!
//! ```sh
//! cargo bench --bench paper_benches             # all
//! cargo bench --bench paper_benches -- fig3     # filter
//! ```

use helex::coordinator::{experiments, suite, Coordinator, ExperimentConfig};
use helex::service::ExplorationService;
use helex::util::bench::Harness;

fn co() -> Coordinator {
    Coordinator::new(ExperimentConfig {
        l_test_base: 120,
        gsg_passes: 1,
        verbose: false,
        ..Default::default()
    })
}

fn main() {
    let mut h = Harness::from_args();
    println!("== paper experiment benches (bench-scale budgets) ==");

    // Each experiment is measured once end-to-end: these are
    // minutes-scale workloads, not microbenchmarks.
    for exp in [
        "fig3", "fig4", "table4", "fig5", "fig6", "table5", "table6", "fig7", "table8",
        "fig9", "fig10", "fig11",
    ] {
        h.bench_once(&format!("exp::{exp}"), || {
            let mut c = co();
            // suppress experiment stdout: route results to a sink table
            experiments::run_experiment(&mut c, exp, true).expect("experiment runs");
        });
    }

    // Suite throughput: jobs/sec at 1, 2 and 4 workers on the fig9
    // sweep (5 independent jobs). A fresh service per measurement keeps
    // the run cache from hiding work, so the numbers track the worker
    // pool's real speedup in the perf trajectory.
    println!("\n== suite throughput (fig9 sweep, 5 jobs) ==");
    for workers in [1usize, 2, 4] {
        let name = format!("suite::fig9@{workers}w");
        let mut unique_jobs = 0usize;
        h.bench_once(&name, || {
            let cfg = ExperimentConfig {
                l_test_base: 120,
                gsg_passes: 1,
                ..Default::default()
            };
            let defs = experiments::find("fig9").expect("fig9 exists");
            let service = ExplorationService::with_jobs(workers);
            let tables = suite::run_suite(&cfg, &defs, true, &service, None);
            unique_jobs = service.cache_len();
            tables
        });
        match h.results.last() {
            Some(r) if r.name == name && unique_jobs > 0 => {
                println!(
                    "    -> {:.2} jobs/s over {unique_jobs} unique jobs",
                    unique_jobs as f64 / (r.median_ns / 1e9)
                );
            }
            _ => {}
        }
    }
    println!("\n{} experiments benchmarked", h.results.len());
}
