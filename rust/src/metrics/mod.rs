//! Latency and reduction accounting (paper Sections IV-A/IV-I).

use crate::cgra::Layout;
use crate::dfg::Dfg;
use crate::mapper::MappingEngine;
use crate::ops::NUM_GROUPS;

/// Post-map latency ratio of a heterogeneous layout relative to the full
/// layout, per DFG (Fig 10). Returns `None` when either layout fails to
/// map (should not happen for layouts produced by the search).
pub fn latency_ratio(
    engine: &MappingEngine,
    dfg: &Dfg,
    full: &Layout,
    hetero: &Layout,
) -> Option<f64> {
    let mf = engine.map(dfg, full).into_mapping()?;
    let mh = engine.map(dfg, hetero).into_mapping()?;
    Some(mh.latency(dfg) as f64 / mf.latency(dfg) as f64)
}

/// Latency ratio using a known witness mapping for the heterogeneous
/// layout (search results carry witnesses; layouts accepted through the
/// warm-start or witness fast-path may not re-map heuristically from
/// scratch).
pub fn latency_ratio_with_witness(
    engine: &MappingEngine,
    dfg: &Dfg,
    full: &Layout,
    hetero_mapping: &crate::mapper::Mapping,
) -> Option<f64> {
    let mf = engine.map(dfg, full).into_mapping()?;
    Some(hetero_mapping.latency(dfg) as f64 / mf.latency(dfg) as f64)
}

/// Per-group instance reduction (in %) of `hetero` vs `full` over compute
/// cells, indexed by `OpGroup::index()`. Groups absent from `full` report
/// 0 (nothing to remove).
pub fn group_reduction_pct(full: &Layout, hetero: &Layout) -> [f64; NUM_GROUPS] {
    let nf = full.compute_group_instances();
    let nh = hetero.compute_group_instances();
    let mut out = [0.0; NUM_GROUPS];
    for i in 0..NUM_GROUPS {
        if nf[i] > 0 {
            out[i] = (1.0 - nh[i] as f64 / nf[i] as f64) * 100.0;
        }
    }
    out
}

/// Total instance reduction (%) over compute cells.
pub fn total_reduction_pct(full: &Layout, hetero: &Layout) -> f64 {
    let a = full.compute_instances();
    let b = hetero.compute_instances();
    if a == 0 {
        0.0
    } else {
        (1.0 - b as f64 / a as f64) * 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::dfg::benchmarks;
    use crate::ops::{GroupSet, OpGroup};

    #[test]
    fn reductions_zero_for_identical_layouts() {
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        assert_eq!(total_reduction_pct(&l, &l), 0.0);
        assert_eq!(group_reduction_pct(&l, &l), [0.0; NUM_GROUPS]);
    }

    #[test]
    fn reductions_track_removals() {
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        let cell = l.grid.compute_cells().next().unwrap();
        let h = l.without_group(cell, OpGroup::Div);
        let g = group_reduction_pct(&l, &h);
        // 1 of 16 Div instances removed
        assert!((g[OpGroup::Div.index()] - 100.0 / 16.0).abs() < 1e-9);
        assert_eq!(g[OpGroup::Arith.index()], 0.0);
        assert!(total_reduction_pct(&l, &h) > 0.0);
    }

    #[test]
    fn latency_ratio_one_for_same_layout() {
        let d = benchmarks::benchmark("SOB");
        let l = Layout::full(Grid::new(6, 6), d.groups_used());
        let m = MappingEngine::default();
        let r = latency_ratio(&m, &d, &l, &l).unwrap();
        assert!((r - 1.0).abs() < 1e-9);
    }
}
