//! DFG operations and HeLEx operation groups (paper Table I).
//!
//! HeLEx never removes *individual* operations from a cell: operations are
//! grouped by hardware implementation (Synopsys DesignWare in the paper)
//! into six groups, and the search removes one *group instance* at a time.

pub mod costs;

use std::fmt;

/// The six operation groups of Table I.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum OpGroup {
    /// Integer and logic ops (excluding DIV and MULT).
    Arith = 0,
    /// Integer and floating point DIV.
    Div = 1,
    /// Floating point ops (excluding DIV and MULT).
    FP = 2,
    /// Memory ops (LOAD, STORE) — only ever on I/O cells.
    Mem = 3,
    /// Integer and floating point MULT.
    Mult = 4,
    /// Special ops (EXP, LOG, SQRT, ...).
    Other = 5,
}

/// Number of operation groups.
pub const NUM_GROUPS: usize = 6;

/// All groups, in enum order (also the order used by the AOT artifacts).
pub const ALL_GROUPS: [OpGroup; NUM_GROUPS] = [
    OpGroup::Arith,
    OpGroup::Div,
    OpGroup::FP,
    OpGroup::Mem,
    OpGroup::Mult,
    OpGroup::Other,
];

/// The groups a *compute* cell may support (Mem lives on I/O cells and is
/// never part of the search space).
pub const COMPUTE_GROUPS: [OpGroup; 5] =
    [OpGroup::Arith, OpGroup::Div, OpGroup::FP, OpGroup::Mult, OpGroup::Other];

impl OpGroup {
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Option<Self> {
        ALL_GROUPS.get(i).copied()
    }

    pub fn name(self) -> &'static str {
        match self {
            OpGroup::Arith => "Arith",
            OpGroup::Div => "Div",
            OpGroup::FP => "FP",
            OpGroup::Mem => "Mem",
            OpGroup::Mult => "Mult",
            OpGroup::Other => "Other",
        }
    }
}

impl fmt::Display for OpGroup {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Concrete DFG operations. The set mirrors what the paper's DFGs use:
/// integer/logic arithmetic, FP arithmetic, int/FP multiply and divide,
/// loads/stores, and the "Other" specials.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    // Arith group
    Add,
    Sub,
    And,
    Or,
    Xor,
    Shl,
    Shr,
    Min,
    Max,
    Abs,
    Cmp,
    Select,
    // FP group
    FAdd,
    FSub,
    FMin,
    FMax,
    FAbs,
    FCmp,
    FToI,
    IToF,
    // Mult group
    Mul,
    FMul,
    // Div group
    Div,
    Rem,
    FDiv,
    // Other group
    Exp,
    Log,
    Sqrt,
    Sin,
    Cos,
    // Mem group
    Load,
    Store,
}

impl Op {
    /// Table I grouping.
    pub fn group(self) -> OpGroup {
        use Op::*;
        match self {
            Add | Sub | And | Or | Xor | Shl | Shr | Min | Max | Abs | Cmp | Select => {
                OpGroup::Arith
            }
            FAdd | FSub | FMin | FMax | FAbs | FCmp | FToI | IToF => OpGroup::FP,
            Mul | FMul => OpGroup::Mult,
            Div | Rem | FDiv => OpGroup::Div,
            Exp | Log | Sqrt | Sin | Cos => OpGroup::Other,
            Load | Store => OpGroup::Mem,
        }
    }

    /// Number of data inputs the operation consumes (1 or 2). Stores take
    /// one data input (address generation is implicit in the elastic I/O
    /// cell, as in T-CGRA); loads are sources.
    pub fn arity(self) -> usize {
        use Op::*;
        match self {
            Load => 0,
            Abs | FAbs | FToI | IToF | Exp | Log | Sqrt | Sin | Cos | Store => 1,
            _ => 2,
        }
    }

    pub fn is_memory(self) -> bool {
        self.group() == OpGroup::Mem
    }

    pub fn name(self) -> &'static str {
        use Op::*;
        match self {
            Add => "add",
            Sub => "sub",
            And => "and",
            Or => "or",
            Xor => "xor",
            Shl => "shl",
            Shr => "shr",
            Min => "min",
            Max => "max",
            Abs => "abs",
            Cmp => "cmp",
            Select => "select",
            FAdd => "fadd",
            FSub => "fsub",
            FMin => "fmin",
            FMax => "fmax",
            FAbs => "fabs",
            FCmp => "fcmp",
            FToI => "ftoi",
            IToF => "itof",
            Mul => "mul",
            FMul => "fmul",
            Div => "div",
            Rem => "rem",
            FDiv => "fdiv",
            Exp => "exp",
            Log => "log",
            Sqrt => "sqrt",
            Sin => "sin",
            Cos => "cos",
            Load => "load",
            Store => "store",
        }
    }
}

/// Every concrete operation, in declaration order. The wire codecs
/// ([`crate::service::wire`]) use this to resolve [`Op::from_name`], so a
/// variant added to [`Op`] must be added here — the `all_ops_is_exhaustive`
/// test pins this with an exhaustive `match` that stops compiling when a
/// variant is missing from it, forcing both lists to be revisited.
pub const ALL_OPS: [Op; 32] = [
    Op::Add,
    Op::Sub,
    Op::And,
    Op::Or,
    Op::Xor,
    Op::Shl,
    Op::Shr,
    Op::Min,
    Op::Max,
    Op::Abs,
    Op::Cmp,
    Op::Select,
    Op::FAdd,
    Op::FSub,
    Op::FMin,
    Op::FMax,
    Op::FAbs,
    Op::FCmp,
    Op::FToI,
    Op::IToF,
    Op::Mul,
    Op::FMul,
    Op::Div,
    Op::Rem,
    Op::FDiv,
    Op::Exp,
    Op::Log,
    Op::Sqrt,
    Op::Sin,
    Op::Cos,
    Op::Load,
    Op::Store,
];

impl Op {
    /// Inverse of [`Op::name`] (wire decoding); `None` for unknown names.
    pub fn from_name(name: &str) -> Option<Op> {
        ALL_OPS.iter().copied().find(|op| op.name() == name)
    }
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A set of operation groups, as a bitmask. This is the per-cell unit the
/// whole search manipulates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default, PartialOrd, Ord)]
pub struct GroupSet(pub u8);

impl GroupSet {
    pub const EMPTY: GroupSet = GroupSet(0);

    /// All compute groups (everything except Mem).
    pub fn all_compute() -> Self {
        let mut s = GroupSet::EMPTY;
        for g in COMPUTE_GROUPS {
            s.insert(g);
        }
        s
    }

    /// Only the Mem group (I/O cells).
    pub fn mem_only() -> Self {
        let mut s = GroupSet::EMPTY;
        s.insert(OpGroup::Mem);
        s
    }

    pub fn from_groups(groups: &[OpGroup]) -> Self {
        let mut s = GroupSet::EMPTY;
        for &g in groups {
            s.insert(g);
        }
        s
    }

    pub fn contains(self, g: OpGroup) -> bool {
        self.0 & (1 << g.index()) != 0
    }

    pub fn insert(&mut self, g: OpGroup) {
        self.0 |= 1 << g.index();
    }

    pub fn remove(&mut self, g: OpGroup) {
        self.0 &= !(1 << g.index());
    }

    pub fn with(mut self, g: OpGroup) -> Self {
        self.insert(g);
        self
    }

    pub fn without(mut self, g: OpGroup) -> Self {
        self.remove(g);
        self
    }

    /// Remove every group in `mask`.
    pub fn minus(self, mask: GroupSet) -> Self {
        GroupSet(self.0 & !mask.0)
    }

    pub fn union(self, other: GroupSet) -> Self {
        GroupSet(self.0 | other.0)
    }

    pub fn intersect(self, other: GroupSet) -> Self {
        GroupSet(self.0 & other.0)
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    pub fn is_subset_of(self, other: GroupSet) -> bool {
        self.0 & !other.0 == 0
    }

    pub fn len(self) -> usize {
        self.0.count_ones() as usize
    }

    pub fn iter(self) -> impl Iterator<Item = OpGroup> {
        ALL_GROUPS.into_iter().filter(move |g| self.contains(*g))
    }
}

impl fmt::Display for GroupSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_empty() {
            return f.write_str("{}");
        }
        let names: Vec<&str> = self.iter().map(|g| g.name()).collect();
        write!(f, "{{{}}}", names.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_op_has_a_group() {
        use Op::*;
        let ops = [
            Add, Sub, And, Or, Xor, Shl, Shr, Min, Max, Abs, Cmp, Select, FAdd, FSub, FMin,
            FMax, FAbs, FCmp, FToI, IToF, Mul, FMul, Div, Rem, FDiv, Exp, Log, Sqrt, Sin, Cos,
            Load, Store,
        ];
        for op in ops {
            let g = op.group();
            assert!(ALL_GROUPS.contains(&g));
            assert!(op.arity() <= 2);
        }
    }

    #[test]
    fn grouping_matches_table_1() {
        assert_eq!(Op::Add.group(), OpGroup::Arith);
        assert_eq!(Op::Shl.group(), OpGroup::Arith);
        assert_eq!(Op::Div.group(), OpGroup::Div);
        assert_eq!(Op::FDiv.group(), OpGroup::Div);
        assert_eq!(Op::FAdd.group(), OpGroup::FP);
        assert_eq!(Op::Load.group(), OpGroup::Mem);
        assert_eq!(Op::Store.group(), OpGroup::Mem);
        assert_eq!(Op::Mul.group(), OpGroup::Mult);
        assert_eq!(Op::FMul.group(), OpGroup::Mult);
        assert_eq!(Op::Exp.group(), OpGroup::Other);
        assert_eq!(Op::Sqrt.group(), OpGroup::Other);
    }

    #[test]
    fn groupset_basic_algebra() {
        let mut s = GroupSet::EMPTY;
        assert!(s.is_empty());
        s.insert(OpGroup::Arith);
        s.insert(OpGroup::Mult);
        assert_eq!(s.len(), 2);
        assert!(s.contains(OpGroup::Arith));
        assert!(!s.contains(OpGroup::Div));
        s.remove(OpGroup::Arith);
        assert!(!s.contains(OpGroup::Arith));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn all_compute_excludes_mem() {
        let s = GroupSet::all_compute();
        assert_eq!(s.len(), 5);
        assert!(!s.contains(OpGroup::Mem));
        for g in COMPUTE_GROUPS {
            assert!(s.contains(g));
        }
    }

    #[test]
    fn subset_and_minus() {
        let a = GroupSet::from_groups(&[OpGroup::Arith, OpGroup::Mult]);
        let b = GroupSet::all_compute();
        assert!(a.is_subset_of(b));
        assert!(!b.is_subset_of(a));
        let c = b.minus(a);
        assert!(!c.contains(OpGroup::Arith));
        assert!(!c.contains(OpGroup::Mult));
        assert!(c.contains(OpGroup::Div));
        assert_eq!(c.union(a), b);
    }

    #[test]
    fn groupset_iter_order_is_stable() {
        let s = GroupSet::all_compute();
        let v: Vec<OpGroup> = s.iter().collect();
        assert_eq!(
            v,
            vec![OpGroup::Arith, OpGroup::Div, OpGroup::FP, OpGroup::Mult, OpGroup::Other]
        );
    }

    #[test]
    fn display_forms() {
        assert_eq!(GroupSet::EMPTY.to_string(), "{}");
        assert_eq!(
            GroupSet::from_groups(&[OpGroup::Arith, OpGroup::Mem]).to_string(),
            "{Arith,Mem}"
        );
        assert_eq!(OpGroup::Other.to_string(), "Other");
        assert_eq!(Op::FDiv.to_string(), "fdiv");
    }

    #[test]
    fn from_index_roundtrip() {
        for g in ALL_GROUPS {
            assert_eq!(OpGroup::from_index(g.index()), Some(g));
        }
        assert_eq!(OpGroup::from_index(6), None);
    }

    #[test]
    fn op_names_roundtrip_and_are_unique() {
        let mut seen = std::collections::HashSet::new();
        for op in ALL_OPS {
            assert!(seen.insert(op.name()), "duplicate name {}", op.name());
            assert_eq!(Op::from_name(op.name()), Some(op));
        }
        assert_eq!(Op::from_name("frobnicate"), None);
        assert_eq!(Op::from_name("ADD"), None, "names are case-sensitive");
    }

    #[test]
    fn all_ops_is_exhaustive() {
        // This match is the enforcement: adding an `Op` variant makes it
        // stop compiling, and fixing it means updating the ordinal — at
        // which point the assertions below force ALL_OPS to grow too
        // (otherwise from_name would silently reject the new op's name
        // and its DFGs could never cross the wire).
        fn ordinal(op: Op) -> usize {
            use Op::*;
            match op {
                Add => 0,
                Sub => 1,
                And => 2,
                Or => 3,
                Xor => 4,
                Shl => 5,
                Shr => 6,
                Min => 7,
                Max => 8,
                Abs => 9,
                Cmp => 10,
                Select => 11,
                FAdd => 12,
                FSub => 13,
                FMin => 14,
                FMax => 15,
                FAbs => 16,
                FCmp => 17,
                FToI => 18,
                IToF => 19,
                Mul => 20,
                FMul => 21,
                Div => 22,
                Rem => 23,
                FDiv => 24,
                Exp => 25,
                Log => 26,
                Sqrt => 27,
                Sin => 28,
                Cos => 29,
                Load => 30,
                Store => 31,
            }
        }
        assert_eq!(ALL_OPS.len(), 32, "ALL_OPS must list every variant of the match above");
        for (i, op) in ALL_OPS.iter().enumerate() {
            assert_eq!(ordinal(*op), i, "ALL_OPS must stay in declaration order");
        }
    }
}
