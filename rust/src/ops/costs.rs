//! CGRA component cost model (paper Table III).
//!
//! The paper obtains these constants by synthesizing each component with
//! Synopsys DC (45nm FreePDK45 / Nangate, ~220 MHz) and normalizing to the
//! integer-arithmetic ALU. HeLEx itself only ever consumes the normalized
//! table, so baking the published constants preserves the search exactly.
//!
//! Area costs are verbatim from Table III. The paper reports a single
//! normalized "cost" column used for area; its *power* results (Figs 4, 8)
//! show a consistently smaller relative reduction (~52% vs ~70%), which
//! implies the non-removable components (FIFOs, empty-cell overhead, I/O
//! cells) carry a relatively larger share of power than of area. The
//! power table below is synthesized to reproduce that relationship and is
//! documented as a substitution in DESIGN.md §2.

use super::{GroupSet, OpGroup, NUM_GROUPS};

/// Cost of one component class, normalized to the Arith ALU (= 1.0).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComponentCosts {
    /// Per-group ALU costs, indexed by `OpGroup::index()`. The Mem entry
    /// is 0: I/O cells are accounted as whole `io_cell` units and never
    /// participate in the search.
    pub group: [f64; NUM_GROUPS],
    /// The full set of 4 input FIFOs of one cell (4x4x32 in the paper).
    pub fifos: f64,
    /// Empty cell: switches + control, no FIFOs, no FUs.
    pub empty_cell: f64,
    /// Complete I/O cell (FIFOs only, no compute).
    pub io_cell: f64,
}

impl ComponentCosts {
    /// Area costs — Table III verbatim.
    pub const fn area() -> Self {
        ComponentCosts {
            //      Arith Div   FP   Mem  Mult Other
            group: [1.0, 17.0, 4.4, 0.0, 6.2, 12.3],
            fifos: 4.9,
            empty_cell: 4.6,
            io_cell: 11.9,
        }
    }

    /// Power costs — synthesized (see module docs): same ordering as area
    /// but with a heavier fixed (FIFO/empty/I-O) share, which yields the
    /// paper's ~52%-power-vs-~70%-area reduction shape.
    pub const fn power() -> Self {
        ComponentCosts {
            //      Arith Div   FP   Mem  Mult Other
            group: [1.0, 10.5, 3.3, 0.0, 4.3, 7.6],
            fifos: 9.8,
            empty_cell: 6.9,
            io_cell: 16.6,
        }
    }

    pub fn group_cost(&self, g: OpGroup) -> f64 {
        self.group[g.index()]
    }

    /// Cost of one compute cell carrying `support`: empty-cell overhead +
    /// its FIFO set + the sum of its group ALUs. (The paper's Equation 1
    /// distributes the first two as `N_t × (empty + FIFO)`.)
    pub fn compute_cell_cost(&self, support: GroupSet) -> f64 {
        let mut c = self.empty_cell + self.fifos;
        for g in support.iter() {
            c += self.group_cost(g);
        }
        c
    }

    /// Cost of a full compute cell supporting all compute groups.
    pub fn full_compute_cell_cost(&self) -> f64 {
        self.compute_cell_cost(GroupSet::all_compute())
    }

    /// Cost of one of the 4 per-cell input FIFOs (Table VI counts FIFOs
    /// individually).
    pub fn one_fifo(&self) -> f64 {
        self.fifos / 4.0
    }
}

/// Scale factors that map normalized cost units to the absolute µm² / µW
/// figures of the paper's Table V (derived from Table V itself:
/// 5505068 µm² / 5577.6 units ≈ 987 for the 12×12 full layout).
pub const AREA_UM2_PER_UNIT: f64 = 987.0;
pub const POWER_UW_PER_UNIT: f64 = 63.0;

/// Relative cost ordering used by OPSG (most expensive group first).
pub fn groups_by_descending_cost(costs: &ComponentCosts) -> Vec<OpGroup> {
    let mut gs: Vec<OpGroup> = super::COMPUTE_GROUPS.to_vec();
    gs.sort_by(|a, b| {
        costs
            .group_cost(*b)
            .partial_cmp(&costs.group_cost(*a))
            .unwrap()
            .then(a.cmp(b)) // deterministic tie-break
    });
    gs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::COMPUTE_GROUPS;

    #[test]
    fn area_matches_table_3() {
        let c = ComponentCosts::area();
        assert_eq!(c.group_cost(OpGroup::Arith), 1.0);
        assert_eq!(c.group_cost(OpGroup::FP), 4.4);
        assert_eq!(c.group_cost(OpGroup::Mult), 6.2);
        assert_eq!(c.group_cost(OpGroup::Div), 17.0);
        assert_eq!(c.group_cost(OpGroup::Other), 12.3);
        assert_eq!(c.fifos, 4.9);
        assert_eq!(c.empty_cell, 4.6);
        assert_eq!(c.io_cell, 11.9);
    }

    #[test]
    fn full_cell_cost_matches_paper_arithmetic() {
        // Section IV-H: a cell without FUs/ALUs costs 9.5 (empty + FIFOs);
        // 7 such cells cost 66.5.
        let c = ComponentCosts::area();
        assert!((c.compute_cell_cost(GroupSet::EMPTY) - 9.5).abs() < 1e-9);
        assert!((7.0 * c.compute_cell_cost(GroupSet::EMPTY) - 66.5).abs() < 1e-9);
        // Full compute cell: 9.5 + 1 + 17 + 4.4 + 6.2 + 12.3 = 50.4
        assert!((c.full_compute_cell_cost() - 50.4).abs() < 1e-9);
    }

    #[test]
    fn opsg_order_is_most_expensive_first() {
        let order = groups_by_descending_cost(&ComponentCosts::area());
        assert_eq!(
            order,
            vec![OpGroup::Div, OpGroup::Other, OpGroup::Mult, OpGroup::FP, OpGroup::Arith]
        );
    }

    #[test]
    fn power_preserves_area_ordering_of_groups() {
        // Relative expensiveness ordering of the compute groups must match
        // area's so OPSG behaves identically under either objective.
        let a = groups_by_descending_cost(&ComponentCosts::area());
        let p = groups_by_descending_cost(&ComponentCosts::power());
        assert_eq!(a, p);
    }

    #[test]
    fn power_fixed_share_exceeds_area_fixed_share() {
        // The substitution requirement: fixed components carry a larger
        // share of a full cell's power than of its area, so removing
        // compute yields smaller % power savings (paper Figs 4/8 shape).
        let a = ComponentCosts::area();
        let p = ComponentCosts::power();
        let fixed_share =
            |c: &ComponentCosts| (c.empty_cell + c.fifos) / c.full_compute_cell_cost();
        assert!(fixed_share(&p) > fixed_share(&a));
    }

    #[test]
    fn mem_group_is_free_on_compute_cells() {
        let c = ComponentCosts::area();
        assert_eq!(c.group_cost(OpGroup::Mem), 0.0);
        for g in COMPUTE_GROUPS {
            assert!(c.group_cost(g) > 0.0);
        }
    }

    #[test]
    fn one_fifo_is_quarter_of_set() {
        let c = ComponentCosts::area();
        assert!((c.one_fifo() * 4.0 - c.fifos).abs() < 1e-12);
    }
}
