//! The fleet's dispatch core: a priority queue of fingerprint-distinct
//! tasks, fanned out to replicas by a small worker pool.
//!
//! The design transplants the `ShardedRunCache` slot discipline
//! (`service::cache`) to fleet scope. Every admitted job maps to a
//! [`RunSlot`] keyed by its content fingerprint; the *first* submission
//! of a fingerprint (the primary) enqueues a [`Task`], later ones share
//! the existing slot and wait on its condvar. One fingerprint is
//! therefore dispatched at most once fleet-wide no matter how many
//! batches carry it — the distributed analogue of the in-process
//! `get_or_compute` dedup.
//!
//! Execution order is priority-then-FIFO: a max-heap pops the highest
//! priority first, sequence numbers break ties so equal-priority work
//! keeps submission order. Before dispatching, a worker consults the
//! shared [`ResultStore`] — a fingerprint already persisted (by this
//! coordinator or a previous run) resolves without touching a replica.
//! Fresh results are written back to the store, so replicas' work
//! accumulates into the shared tier.
//!
//! Failure policy: a task is only ever *moved*, never dropped. If the
//! replica running it dies (connect refused, read timeout, poll error),
//! the worker releases the replica slot with a failure mark and retries
//! the task on whichever healthy replica `ReplicaPool::acquire` offers
//! next. Determinism (seeds derived from the fingerprint) makes the
//! re-execution byte-identical, so requeue needs no coordination beyond
//! the slot itself.

use super::replica::ReplicaPool;
use crate::server::client::{self, RetryPolicy};
use crate::service::cache::CachedJob;
use crate::service::JobSpec;
use crate::store::ResultStore;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Where a resolved run came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Origin {
    /// A replica computed it during this batch.
    Computed,
    /// The shared store already had it.
    StoreHit,
}

/// A resolved run: the cached payload plus provenance.
#[derive(Debug, Clone)]
pub struct DoneRun {
    pub job: CachedJob,
    pub origin: Origin,
    /// Seconds from dequeue to resolution at the coordinator.
    pub wall_secs: f64,
}

/// Lifecycle of one fingerprint's run.
#[derive(Debug)]
enum RunState {
    Pending,
    Dispatched,
    Done(DoneRun),
}

/// Poll-visible status of a slot.
#[derive(Debug, Clone)]
pub enum SlotStatus {
    Queued,
    Running,
    Done(DoneRun),
}

impl SlotStatus {
    pub fn name(&self) -> &'static str {
        match self {
            SlotStatus::Queued => "queued",
            SlotStatus::Running => "running",
            SlotStatus::Done(_) => "done",
        }
    }
}

/// One fingerprint's rendezvous point: every job sharing the
/// fingerprint polls or waits here; the dispatch worker fills it once.
pub struct RunSlot {
    state: Mutex<RunState>,
    done: Condvar,
}

impl RunSlot {
    fn new() -> Self {
        Self { state: Mutex::new(RunState::Pending), done: Condvar::new() }
    }

    pub fn status(&self) -> SlotStatus {
        match &*self.state.lock().unwrap() {
            RunState::Pending => SlotStatus::Queued,
            RunState::Dispatched => SlotStatus::Running,
            RunState::Done(run) => SlotStatus::Done(run.clone()),
        }
    }

    /// Block until the slot resolves or `timeout` passes.
    pub fn wait_done(&self, timeout: Duration) -> Option<DoneRun> {
        let deadline = Instant::now() + timeout;
        let mut state = self.state.lock().unwrap();
        loop {
            if let RunState::Done(run) = &*state {
                return Some(run.clone());
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.done.wait_timeout(state, deadline - now).unwrap();
            state = guard;
        }
    }

    fn mark_dispatched(&self) {
        let mut state = self.state.lock().unwrap();
        if matches!(*state, RunState::Pending) {
            *state = RunState::Dispatched;
        }
    }

    fn mark_pending(&self) {
        // requeue path: the task went back on the heap
        let mut state = self.state.lock().unwrap();
        if matches!(*state, RunState::Dispatched) {
            *state = RunState::Pending;
        }
    }

    fn fill(&self, run: DoneRun) {
        *self.state.lock().unwrap() = RunState::Done(run);
        self.done.notify_all();
    }
}

/// A queued unit of work: one fingerprint's primary submission.
struct Task {
    priority: u8,
    seq: u64,
    fp: u64,
    spec: JobSpec,
}

// JobSpec holds f64s (mapper parameters), so ordering is defined
// manually over (priority, seq) alone: max-heap pops the highest
// priority; within a priority, the earliest sequence number wins.
impl PartialEq for Task {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Task {}
impl Ord for Task {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.priority.cmp(&other.priority).then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Task {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

struct Queue {
    heap: BinaryHeap<Task>,
    /// fingerprint → slot; entries outlive resolution so late duplicates
    /// of a finished fingerprint resolve instantly.
    slots: HashMap<u64, Arc<RunSlot>>,
    next_seq: u64,
    draining: bool,
    stopping: bool,
}

/// Why an admission was refused (distinct from quota refusals, which
/// the HTTP layer handles before reaching the dispatcher).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmitError {
    QueueFull { capacity: usize },
    Draining,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::QueueFull { capacity } => {
                write!(f, "dispatch queue is full (capacity {capacity})")
            }
            AdmitError::Draining => write!(f, "coordinator is draining"),
        }
    }
}

/// One admitted job's handle: its fingerprint, the shared slot, and
/// whether this submission is the one that enqueued the work.
pub struct Admitted {
    pub fp: u64,
    pub slot: Arc<RunSlot>,
    pub primary: bool,
}

/// Counters for `/v1/stats`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DispatchStats {
    /// Distinct fingerprints ever admitted.
    pub distinct: u64,
    /// Slots resolved by replica computation.
    pub computed: u64,
    /// Slots resolved from the shared store.
    pub store_hits: u64,
    /// Admissions that joined an existing slot instead of enqueueing.
    pub dedup_hits: u64,
    /// Tasks put back after a replica failure.
    pub requeues: u64,
    /// Tasks currently waiting in the priority queue.
    pub queued: u64,
    /// Tasks currently dispatched to a replica (or store lookup).
    pub running: u64,
}

pub struct Dispatcher {
    queue: Mutex<Queue>,
    work: Condvar,
    pool: Arc<ReplicaPool>,
    store: Option<Arc<ResultStore>>,
    retry: RetryPolicy,
    queue_cap: usize,
    poll_interval: Duration,
    max_polls: usize,
    distinct: AtomicU64,
    computed: AtomicU64,
    store_hits: AtomicU64,
    dedup_hits: AtomicU64,
    requeues: AtomicU64,
    running: AtomicU64,
    admitted: AtomicU64,
    resolved: AtomicU64,
    /// Bumped on every resolution; batch aggregators and `drain` wait on
    /// it instead of polling slots.
    progress: Mutex<u64>,
    progressed: Condvar,
    workers: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl Dispatcher {
    /// Start `worker_count` dispatch workers over `pool`. `queue_cap`
    /// bounds *pending distinct* tasks (slots for finished work are
    /// retained and don't count).
    pub fn start(
        pool: Arc<ReplicaPool>,
        store: Option<Arc<ResultStore>>,
        retry: RetryPolicy,
        queue_cap: usize,
        worker_count: usize,
    ) -> Arc<Self> {
        let dispatcher = Arc::new(Self {
            queue: Mutex::new(Queue {
                heap: BinaryHeap::new(),
                slots: HashMap::new(),
                next_seq: 0,
                draining: false,
                stopping: false,
            }),
            work: Condvar::new(),
            pool,
            store,
            retry,
            queue_cap: queue_cap.max(1),
            poll_interval: Duration::from_millis(100),
            max_polls: 36_000, // 1h of polling per dispatch attempt
            distinct: AtomicU64::new(0),
            computed: AtomicU64::new(0),
            store_hits: AtomicU64::new(0),
            dedup_hits: AtomicU64::new(0),
            requeues: AtomicU64::new(0),
            running: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            resolved: AtomicU64::new(0),
            progress: Mutex::new(0),
            progressed: Condvar::new(),
            workers: Mutex::new(Vec::new()),
        });
        let mut workers = dispatcher.workers.lock().unwrap();
        for i in 0..worker_count.max(1) {
            let worker = Arc::clone(&dispatcher);
            workers.push(
                thread::Builder::new()
                    .name(format!("fleet-dispatch-{i}"))
                    .spawn(move || worker.worker_loop())
                    .expect("spawn dispatch worker"),
            );
        }
        drop(workers);
        dispatcher
    }

    /// Admit a submission (single job or whole batch) atomically:
    /// either every job gets a slot or none does. Jobs whose
    /// fingerprint is already known join the existing slot; the rest
    /// enqueue, provided the pending queue has room for *all* of them.
    pub fn admit(&self, jobs: &[(JobSpec, u8)]) -> Result<Vec<Admitted>, AdmitError> {
        let mut queue = self.queue.lock().unwrap();
        if queue.draining || queue.stopping {
            return Err(AdmitError::Draining);
        }
        let mut fresh: Vec<u64> = Vec::new();
        for (spec, _) in jobs {
            let fp = spec.fingerprint();
            if !queue.slots.contains_key(&fp) && !fresh.contains(&fp) {
                fresh.push(fp);
            }
        }
        if queue.heap.len() + fresh.len() > self.queue_cap {
            return Err(AdmitError::QueueFull { capacity: self.queue_cap });
        }
        let mut out = Vec::with_capacity(jobs.len());
        for (spec, priority) in jobs {
            let fp = spec.fingerprint();
            if let Some(slot) = queue.slots.get(&fp) {
                self.dedup_hits.fetch_add(1, Ordering::Relaxed);
                out.push(Admitted { fp, slot: Arc::clone(slot), primary: false });
                continue;
            }
            let slot = Arc::new(RunSlot::new());
            queue.slots.insert(fp, Arc::clone(&slot));
            let seq = queue.next_seq;
            queue.next_seq += 1;
            queue.heap.push(Task { priority: *priority, seq, fp, spec: spec.clone() });
            self.distinct.fetch_add(1, Ordering::Relaxed);
            self.admitted.fetch_add(1, Ordering::Relaxed);
            out.push(Admitted { fp, slot, primary: true });
        }
        drop(queue);
        self.work.notify_all();
        Ok(out)
    }

    pub fn stats(&self) -> DispatchStats {
        let queued = self.queue.lock().unwrap().heap.len() as u64;
        DispatchStats {
            distinct: self.distinct.load(Ordering::Relaxed),
            computed: self.computed.load(Ordering::Relaxed),
            store_hits: self.store_hits.load(Ordering::Relaxed),
            dedup_hits: self.dedup_hits.load(Ordering::Relaxed),
            requeues: self.requeues.load(Ordering::Relaxed),
            queued,
            running: self.running.load(Ordering::Relaxed),
        }
    }

    pub fn draining(&self) -> bool {
        self.queue.lock().unwrap().draining
    }

    /// Current progress tick; pair with
    /// [`wait_progress`](Dispatcher::wait_progress).
    pub fn progress_tick(&self) -> u64 {
        *self.progress.lock().unwrap()
    }

    /// Block until the tick advances past `last` or `timeout` passes;
    /// returns the current tick either way.
    pub fn wait_progress(&self, last: u64, timeout: Duration) -> u64 {
        let deadline = Instant::now() + timeout;
        let mut tick = self.progress.lock().unwrap();
        while *tick <= last {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = self.progressed.wait_timeout(tick, deadline - now).unwrap();
            tick = guard;
        }
        *tick
    }

    /// Stop admissions, wait for every admitted task to resolve, then
    /// stop the workers and the replica pool. Nothing queued is lost:
    /// drain *finishes* the queue rather than discarding it.
    pub fn drain(&self) {
        self.queue.lock().unwrap().draining = true;
        let mut tick = self.progress_tick();
        while self.resolved.load(Ordering::SeqCst) < self.admitted.load(Ordering::SeqCst) {
            tick = self.wait_progress(tick, Duration::from_millis(500));
        }
        self.queue.lock().unwrap().stopping = true;
        self.work.notify_all();
        let workers = std::mem::take(&mut *self.workers.lock().unwrap());
        for handle in workers {
            let _ = handle.join();
        }
        self.pool.shutdown();
    }

    fn worker_loop(&self) {
        loop {
            let task = {
                let mut queue = self.queue.lock().unwrap();
                loop {
                    if let Some(task) = queue.heap.pop() {
                        break task;
                    }
                    if queue.stopping {
                        return;
                    }
                    let (guard, _) =
                        self.work.wait_timeout(queue, Duration::from_millis(200)).unwrap();
                    queue = guard;
                }
            };
            let slot = {
                let queue = self.queue.lock().unwrap();
                Arc::clone(queue.slots.get(&task.fp).expect("queued task has a slot"))
            };
            self.running.fetch_add(1, Ordering::Relaxed);
            slot.mark_dispatched();
            let started = Instant::now();

            // shared tier first: a fingerprint anyone ever computed
            // against this store resolves without touching a replica
            if let Some(store) = &self.store {
                if let Some(job) = store.get(task.fp) {
                    self.store_hits.fetch_add(1, Ordering::Relaxed);
                    self.complete(
                        &slot,
                        DoneRun {
                            job,
                            origin: Origin::StoreHit,
                            wall_secs: started.elapsed().as_secs_f64(),
                        },
                    );
                    continue;
                }
            }

            // compute on a replica; a failed replica just moves the task
            loop {
                let Some(addr) = self.pool.acquire() else {
                    // pool shut down with the task un-run (only on abrupt
                    // teardown): put it back so a later drain can see it
                    slot.mark_pending();
                    self.running.fetch_sub(1, Ordering::Relaxed);
                    self.requeues.fetch_add(1, Ordering::Relaxed);
                    let mut queue = self.queue.lock().unwrap();
                    queue.heap.push(task);
                    return;
                };
                match self.run_on(&addr, &task.spec) {
                    Ok(job) => {
                        self.pool.release(&addr, true);
                        if let Some(store) = &self.store {
                            if let Err(e) = store.put(task.fp, &job) {
                                eprintln!(
                                    "fleet: store write for {:016x} failed: {e}",
                                    task.fp
                                );
                            }
                        }
                        self.computed.fetch_add(1, Ordering::Relaxed);
                        self.complete(
                            &slot,
                            DoneRun {
                                job,
                                origin: Origin::Computed,
                                wall_secs: started.elapsed().as_secs_f64(),
                            },
                        );
                        break;
                    }
                    Err(e) => {
                        self.pool.release(&addr, false);
                        self.requeues.fetch_add(1, Ordering::Relaxed);
                        eprintln!(
                            "fleet: job {:016x} on {addr} failed ({e}); requeueing",
                            task.fp
                        );
                        thread::sleep(Duration::from_millis(200));
                    }
                }
            }
        }
    }

    /// Run one spec on one replica end-to-end: submit (with the
    /// transport retry policy), then poll to completion.
    fn run_on(&self, addr: &str, spec: &JobSpec) -> anyhow::Result<CachedJob> {
        let id = client::submit_spec_retry(addr, spec, &self.retry)?;
        let result = client::wait_result(addr, id, self.poll_interval, self.max_polls)?;
        Ok(CachedJob { outcome: result.outcome, events: result.events })
    }

    fn complete(&self, slot: &RunSlot, run: DoneRun) {
        slot.fill(run);
        self.running.fetch_sub(1, Ordering::Relaxed);
        self.resolved.fetch_add(1, Ordering::SeqCst);
        let mut tick = self.progress.lock().unwrap();
        *tick += 1;
        self.progressed.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(priority: u8, seq: u64) -> Task {
        let spec = JobSpec::new("t", vec![], crate::cgra::Grid::new(5, 5));
        Task { priority, seq, fp: seq, spec }
    }

    #[test]
    fn heap_pops_priority_then_fifo() {
        let mut heap = BinaryHeap::new();
        heap.push(task(5, 0));
        heap.push(task(9, 1));
        heap.push(task(5, 2));
        heap.push(task(1, 3));
        heap.push(task(9, 4));
        let order: Vec<(u8, u64)> = std::iter::from_fn(|| heap.pop())
            .map(|t| (t.priority, t.seq))
            .collect();
        assert_eq!(
            order,
            vec![(9, 1), (9, 4), (5, 0), (5, 2), (1, 3)],
            "highest priority first; FIFO within a priority"
        );
    }

    #[test]
    fn run_slot_statuses_and_wait() {
        let slot = Arc::new(RunSlot::new());
        assert_eq!(slot.status().name(), "queued");
        slot.mark_dispatched();
        assert_eq!(slot.status().name(), "running");
        assert!(slot.wait_done(Duration::from_millis(20)).is_none(), "not done yet");
        let waiter = {
            let slot = Arc::clone(&slot);
            thread::spawn(move || slot.wait_done(Duration::from_secs(5)))
        };
        let job = CachedJob {
            outcome: crate::service::JobOutcome::Infeasible("test".into()),
            events: vec![],
        };
        slot.fill(DoneRun { job, origin: Origin::Computed, wall_secs: 0.5 });
        let run = waiter.join().unwrap().expect("fill wakes the waiter");
        assert_eq!(run.origin, Origin::Computed);
        assert_eq!(slot.status().name(), "done");
    }

    #[test]
    fn mark_pending_only_reverts_dispatched() {
        let slot = RunSlot::new();
        slot.mark_dispatched();
        slot.mark_pending();
        assert_eq!(slot.status().name(), "queued");
        let job = CachedJob {
            outcome: crate::service::JobOutcome::Infeasible("test".into()),
            events: vec![],
        };
        slot.fill(DoneRun { job, origin: Origin::StoreHit, wall_secs: 0.0 });
        slot.mark_pending(); // must not clobber a resolved slot
        assert_eq!(slot.status().name(), "done");
    }
}
