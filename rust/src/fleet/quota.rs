//! Per-client admission quotas: token buckets over wall-clock time.
//!
//! The single-node server's only admission control is a blanket 503 when
//! its queue fills; a fleet coordinator fronting many clients needs
//! *fairness*, not just backpressure. Each client (a free-form name the
//! submitter puts in its request body; `"anonymous"` when absent) owns a
//! token bucket: a batch of N jobs costs N tokens, tokens refill at
//! `per_sec` up to `burst`, and an insufficient balance answers
//! `429 quota_exhausted` with a retry hint instead of silently queueing
//! one client's flood ahead of everyone else's interactive work.
//!
//! Rules are runtime-mutable (`POST /v1/quotas`), so an operator can
//! widen a well-known client's budget without restarting the fleet.
//! Admission is all-or-nothing per submission: a refused batch consumes
//! zero tokens, and a submission that passes the quota but is refused
//! later (queue full) is refunded.
//!
//! The refill arithmetic is a pure function ([`refill`]) so the edge
//! cases — zero rate, saturation at `burst` — are unit-testable without
//! a clock.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// One client's quota configuration, as carried by `POST /v1/quotas`
/// (wire codec in [`crate::service::wire`]).
#[derive(Debug, Clone, PartialEq)]
pub struct QuotaRule {
    pub client: String,
    /// Bucket capacity: the largest submission admissible at once.
    pub burst: u64,
    /// Refill rate in tokens (jobs) per second; `0.0` means the bucket
    /// never refills (a hard cap).
    pub per_sec: f64,
}

/// Why a submission was refused, with enough for a useful 429 body.
#[derive(Debug, Clone, PartialEq)]
pub struct QuotaRefusal {
    pub client: String,
    /// Whole tokens available at refusal time.
    pub available: u64,
    /// Seconds until the bucket could cover the request; `None` when it
    /// never can (rate 0, or the request exceeds `burst` outright).
    pub retry_after_secs: Option<f64>,
}

impl std::fmt::Display for QuotaRefusal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.retry_after_secs {
            Some(secs) => write!(
                f,
                "quota exhausted for client '{}' ({} token(s) available; retry in {secs:.1}s)",
                self.client, self.available
            ),
            None => write!(
                f,
                "request exceeds client '{}' quota burst and can never be admitted whole",
                self.client
            ),
        }
    }
}

/// Tokens after `elapsed_secs` of refill at `per_sec`, saturating at
/// `burst`. Pure, so the zero-rate and saturation cases are testable
/// without sleeping.
pub fn refill(tokens: f64, burst: u64, per_sec: f64, elapsed_secs: f64) -> f64 {
    let grown = tokens + per_sec * elapsed_secs.max(0.0);
    grown.min(burst as f64)
}

struct Bucket {
    burst: u64,
    per_sec: f64,
    tokens: f64,
    last: Instant,
}

impl Bucket {
    fn new(burst: u64, per_sec: f64) -> Self {
        Self { burst, per_sec, tokens: burst as f64, last: Instant::now() }
    }

    fn settle(&mut self) {
        let now = Instant::now();
        self.tokens = refill(
            self.tokens,
            self.burst,
            self.per_sec,
            now.duration_since(self.last).as_secs_f64(),
        );
        self.last = now;
    }
}

/// All clients' buckets. Unknown clients get a bucket with the fleet's
/// default burst/rate on first contact.
pub struct QuotaBook {
    default_burst: u64,
    default_rate: f64,
    buckets: Mutex<HashMap<String, Bucket>>,
}

impl QuotaBook {
    pub fn new(default_burst: u64, default_rate: f64) -> Self {
        Self {
            default_burst: default_burst.max(1),
            default_rate: default_rate.max(0.0),
            buckets: Mutex::new(HashMap::new()),
        }
    }

    /// Install (or replace) a client's rule. The bucket restarts full —
    /// operators raise quotas to unblock someone *now*.
    pub fn set_rule(&self, rule: &QuotaRule) {
        self.buckets
            .lock()
            .unwrap()
            .insert(rule.client.clone(), Bucket::new(rule.burst.max(1), rule.per_sec.max(0.0)));
    }

    /// Snapshot of every bucket seen so far: `(rule, whole tokens now)`.
    pub fn rules(&self) -> Vec<(QuotaRule, u64)> {
        let mut buckets = self.buckets.lock().unwrap();
        let mut out: Vec<(QuotaRule, u64)> = buckets
            .iter_mut()
            .map(|(client, bucket)| {
                bucket.settle();
                (
                    QuotaRule {
                        client: client.clone(),
                        burst: bucket.burst,
                        per_sec: bucket.per_sec,
                    },
                    bucket.tokens as u64,
                )
            })
            .collect();
        out.sort_by(|a, b| a.0.client.cmp(&b.0.client));
        out
    }

    /// Take `n` tokens from `client`'s bucket, or refuse without taking
    /// any (all-or-nothing).
    pub fn try_take(&self, client: &str, n: u64) -> Result<(), QuotaRefusal> {
        let mut buckets = self.buckets.lock().unwrap();
        let bucket = buckets
            .entry(client.to_string())
            .or_insert_with(|| Bucket::new(self.default_burst, self.default_rate));
        bucket.settle();
        if bucket.tokens >= n as f64 {
            bucket.tokens -= n as f64;
            return Ok(());
        }
        let retry_after_secs = if n > bucket.burst {
            None // can never fit, at any refill
        } else if bucket.per_sec > 0.0 {
            Some((n as f64 - bucket.tokens) / bucket.per_sec)
        } else {
            None
        };
        Err(QuotaRefusal {
            client: client.to_string(),
            available: bucket.tokens as u64,
            retry_after_secs,
        })
    }

    /// Return tokens taken by an admission that later failed (queue
    /// full). Saturates at the bucket's burst.
    pub fn refund(&self, client: &str, n: u64) {
        let mut buckets = self.buckets.lock().unwrap();
        if let Some(bucket) = buckets.get_mut(client) {
            bucket.tokens = (bucket.tokens + n as f64).min(bucket.burst as f64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refill_is_pure_and_saturates() {
        assert_eq!(refill(0.0, 10, 2.0, 3.0), 6.0);
        assert_eq!(refill(8.0, 10, 2.0, 60.0), 10.0, "must saturate at burst");
        assert_eq!(refill(4.0, 10, 0.0, 1e9), 4.0, "zero rate never refills");
        assert_eq!(refill(4.0, 10, 2.0, -5.0), 4.0, "negative elapsed is inert");
    }

    #[test]
    fn all_or_nothing_admission_with_zero_rate() {
        let book = QuotaBook::new(2, 0.0);
        // a 3-job batch cannot ever fit a 2-token bucket
        let refusal = book.try_take("a", 3).unwrap_err();
        assert_eq!(refusal.retry_after_secs, None);
        assert_eq!(refusal.available, 2, "refusal must not consume tokens");
        // 2 jobs fit exactly once; the bucket never refills at rate 0
        book.try_take("a", 2).unwrap();
        let refusal = book.try_take("a", 1).unwrap_err();
        assert_eq!(refusal.available, 0);
        assert_eq!(refusal.retry_after_secs, None, "rate 0 has no retry horizon");
        // an unrelated client has its own bucket
        book.try_take("b", 2).unwrap();
    }

    #[test]
    fn refund_restores_tokens_up_to_burst() {
        let book = QuotaBook::new(4, 0.0);
        book.try_take("a", 3).unwrap();
        book.refund("a", 3);
        book.try_take("a", 4).unwrap();
        book.refund("a", 99); // saturates, never exceeds burst
        let refusal = book.try_take("a", 5).unwrap_err();
        assert_eq!(refusal.available, 4);
    }

    #[test]
    fn set_rule_replaces_the_bucket_full() {
        let book = QuotaBook::new(1, 0.0);
        book.try_take("a", 1).unwrap();
        assert!(book.try_take("a", 1).is_err());
        book.set_rule(&QuotaRule { client: "a".into(), burst: 10, per_sec: 5.0 });
        book.try_take("a", 10).unwrap();
        // with a refill rate, the refusal carries a retry horizon
        let refusal = book.try_take("a", 5).unwrap_err();
        let secs = refusal.retry_after_secs.expect("rate > 0 has a horizon");
        assert!(secs > 0.0 && secs <= 1.0 + 1e-6, "5 tokens at 5/s: {secs}");
    }

    #[test]
    fn rules_snapshot_is_sorted_and_settled() {
        let book = QuotaBook::new(3, 0.0);
        book.try_take("zeta", 1).unwrap();
        book.try_take("alpha", 2).unwrap();
        let rules = book.rules();
        assert_eq!(rules.len(), 2);
        assert_eq!(rules[0].0.client, "alpha");
        assert_eq!(rules[0].1, 1);
        assert_eq!(rules[1].0.client, "zeta");
        assert_eq!(rules[1].1, 2);
    }
}
