//! `helex fleet`: a multi-node coordinator over N `helex serve` replicas.
//!
//! One coordinator process speaks the same `/v1/jobs` wire format as a
//! single replica — `helex submit` and `server::client` work against
//! either, unchanged — and adds what only a fleet needs:
//!
//! | route | |
//! |---|---|
//! | `POST /v1/jobs` | one [`crate::service::JobSpec`] (+ optional `"client"`, `"priority"`); `202 {"id","fingerprint","status","url"}` |
//! | `POST /v1/batches` | a whole suite as one submission; `202` with a batch id and per-job ids |
//! | `GET /v1/batches/:id` | aggregate progress + per-job rows |
//! | `GET /v1/batches/:id/events` | ndjson: one `job_done` line per resolution, then `batch_done` |
//! | `GET /v1/jobs/:id[/events]` | per-job poll / trace replay, replica-compatible body shape |
//! | `GET`/`POST /v1/quotas` | inspect / set per-client admission quotas |
//! | `GET /v1/healthz`, `GET /v1/stats` | coordinator + per-replica health and run counters |
//!
//! **Shared result tier.** The coordinator's [`ResultStore`] is
//! consulted before any dispatch and written back after every
//! computation, and an in-flight [`dispatch::RunSlot`] per fingerprint
//! (the `ShardedRunCache` discipline, fleet-wide) dedups concurrent
//! submissions — each distinct fingerprint is computed exactly once
//! across the whole fleet, no matter how many batches or clients carry
//! it. Determinism makes this safe: replicas derive their seeds from
//! the fingerprint, so *which* replica computes is unobservable.
//!
//! **Admission control.** Instead of the single-node blanket 503:
//! per-client token quotas ([`quota::QuotaBook`], `429` when
//! exhausted), priorities ordering the dispatch queue (9 highest, FIFO
//! within a priority), and replica health probing with drain awareness
//! ([`replica::ReplicaPool`]) — a replica that answers `"draining"`
//! stops receiving work, an unreachable one has its assigned jobs
//! requeued elsewhere. Queued work survives replica departure by
//! construction: a task is only ever moved, never dropped.

pub mod dispatch;
pub mod quota;
pub mod replica;

use crate::server::client::RetryPolicy;
use crate::server::http::{self, ChunkedWriter, Request};
use crate::server::signal;
use crate::service::{wire, JobId, JobOutcome, JobResult, JobSpec};
use crate::store::ResultStore;
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use dispatch::{AdmitError, Admitted, Dispatcher, DoneRun, Origin, RunSlot, SlotStatus};
use quota::{QuotaBook, QuotaRefusal};
use replica::ReplicaPool;
use std::collections::HashMap;
use std::fmt;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Priority given to submissions that don't set one.
pub const DEFAULT_PRIORITY: u8 = 5;
/// Highest admissible priority (0 is lowest).
pub const MAX_PRIORITY: u8 = 9;
/// Hard bound on jobs per batch submission.
pub const MAX_BATCH_JOBS: usize = 4096;

/// Concurrent event-stream threads (same rationale as the single-node
/// server: streams live as long as the watched work).
const MAX_EVENT_STREAMS: usize = 64;

/// A decoded `POST /v1/batches` submission (wire codec:
/// [`crate::service::wire::decode_batch`]).
#[derive(Debug, Clone)]
pub struct BatchRequest {
    pub label: String,
    pub client: String,
    pub priority: u8,
    pub specs: Vec<JobSpec>,
}

/// Coordinator-assigned batch handle; same stable hex form as
/// [`JobId`] so ids sort and round-trip identically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BatchId(pub u64);

impl fmt::Display for BatchId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "batch-{:016x}", self.0)
    }
}

/// Failure to parse a [`BatchId`] from its textual form.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParseBatchIdError;

impl fmt::Display for ParseBatchIdError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("invalid batch id (expected 'batch-' followed by up to 16 hex digits)")
    }
}

impl std::error::Error for ParseBatchIdError {}

impl std::str::FromStr for BatchId {
    type Err = ParseBatchIdError;

    fn from_str(s: &str) -> Result<Self, ParseBatchIdError> {
        let hex = s.strip_prefix("batch-").unwrap_or(s);
        if hex.is_empty() || hex.len() > 16 || !hex.bytes().all(|b| b.is_ascii_hexdigit()) {
            return Err(ParseBatchIdError);
        }
        u64::from_str_radix(hex, 16).map(BatchId).map_err(|_| ParseBatchIdError)
    }
}

/// Coordinator tuning. `replicas` is the only field without a workable
/// default — a fleet of zero replicas cannot run anything.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Coordinator listen address (`:0` picks an ephemeral port).
    pub addr: String,
    /// `helex serve` replica addresses to fan out to.
    pub replicas: Vec<String>,
    /// Directory of the *shared* result store; `None` disables the tier.
    pub store_dir: Option<PathBuf>,
    /// Store capacity in records (0 = unbounded).
    pub store_capacity: usize,
    /// Bound on pending distinct tasks in the dispatch queue, and on
    /// the accepted-connection queue.
    pub queue_cap: usize,
    /// Concurrent jobs dispatched to each replica.
    pub slots_per_replica: usize,
    /// Replica health-probe interval.
    pub probe_interval: Duration,
    /// Connection-handler threads (HTTP plane).
    pub conn_threads: usize,
    /// Per-connection socket read timeout.
    pub read_timeout: Duration,
    /// Maximum request body size in bytes.
    pub max_body: usize,
    /// Default per-client quota: bucket capacity in jobs…
    pub quota_burst: u64,
    /// …and refill rate in jobs per second.
    pub quota_rate: f64,
    /// Transport retry policy for replica dispatch.
    pub retry: RetryPolicy,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7880".into(),
            replicas: Vec::new(),
            store_dir: None,
            store_capacity: 4096,
            queue_cap: 256,
            slots_per_replica: 2,
            probe_interval: Duration::from_secs(1),
            conn_threads: 4,
            read_timeout: Duration::from_secs(10),
            max_body: 4 * 1024 * 1024,
            quota_burst: 1024,
            quota_rate: 64.0,
            retry: RetryPolicy::default(),
        }
    }
}

/// Drain-state flags shared between the accept loop, the signal watcher
/// and test harnesses (same shape as the single-node server's).
struct Shutdown {
    requested: AtomicBool,
    drained: AtomicBool,
}

/// One admitted job as the coordinator tracks it: enough to assemble a
/// replica-compatible [`JobResult`] from the shared slot.
struct FleetJob {
    id: JobId,
    label: String,
    grid: crate::cgra::Grid,
    fingerprint: u64,
    slot: Arc<RunSlot>,
    /// Whether this submission enqueued the work (false: it joined an
    /// existing slot, so its result reports `from_cache`).
    primary: bool,
}

#[derive(Clone)]
struct BatchEntry {
    id: BatchId,
    label: String,
    client: String,
    jobs: Vec<JobId>,
}

/// Everything a connection handler needs.
struct FleetCtx {
    dispatcher: Arc<Dispatcher>,
    pool: Arc<ReplicaPool>,
    quotas: QuotaBook,
    store: Option<Arc<ResultStore>>,
    jobs: Mutex<HashMap<JobId, Arc<FleetJob>>>,
    batches: Mutex<HashMap<BatchId, BatchEntry>>,
    /// One counter feeds both job and batch ids — they live in
    /// different namespaces (`job-`/`batch-` prefixes) but never share
    /// a number, which makes logs unambiguous.
    next_id: AtomicU64,
    shutdown: Arc<Shutdown>,
    started: Instant,
    queue_cap: usize,
    read_timeout: Duration,
    max_body: usize,
    active_streams: AtomicUsize,
}

/// Handle for triggering a graceful shutdown from another thread.
#[derive(Clone)]
pub struct FleetHandle {
    addr: SocketAddr,
    shutdown: Arc<Shutdown>,
}

impl FleetHandle {
    /// Start draining: refuse new admissions, finish everything queued
    /// (requeueing across replicas as needed), then return from `serve`.
    pub fn begin_shutdown(&self) {
        self.shutdown.requested.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
    }
}

/// The coordinator: bind with [`Fleet::bind`], then block in
/// [`Fleet::serve`].
pub struct Fleet {
    cfg: FleetConfig,
    listener: TcpListener,
    ctx: Arc<FleetCtx>,
}

impl Fleet {
    /// Bind the listener, open the shared store (if configured), start
    /// the replica pool + prober and the dispatch workers.
    pub fn bind(cfg: FleetConfig) -> Result<Self> {
        if cfg.replicas.is_empty() {
            bail!("fleet needs at least one replica address");
        }
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
        let store = match &cfg.store_dir {
            Some(dir) => Some(Arc::new(
                ResultStore::open(dir, cfg.store_capacity)
                    .with_context(|| format!("opening result store {}", dir.display()))?,
            )),
            None => None,
        };
        let pool = ReplicaPool::start(&cfg.replicas, cfg.slots_per_replica, cfg.probe_interval);
        // one dispatch worker per replica slot, bounded: enough to keep
        // every slot busy, never an unbounded thread pile
        let workers = (cfg.replicas.len() * cfg.slots_per_replica.max(1)).clamp(2, 32);
        let dispatcher = Dispatcher::start(
            Arc::clone(&pool),
            store.clone(),
            cfg.retry.clone(),
            cfg.queue_cap,
            workers,
        );
        let ctx = Arc::new(FleetCtx {
            dispatcher,
            pool,
            quotas: QuotaBook::new(cfg.quota_burst, cfg.quota_rate),
            store,
            jobs: Mutex::new(HashMap::new()),
            batches: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            shutdown: Arc::new(Shutdown {
                requested: AtomicBool::new(false),
                drained: AtomicBool::new(false),
            }),
            started: Instant::now(),
            queue_cap: cfg.queue_cap,
            read_timeout: cfg.read_timeout,
            max_body: cfg.max_body,
            active_streams: AtomicUsize::new(0),
        });
        Ok(Self { cfg, listener, ctx })
    }

    /// The bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn handle(&self) -> Result<FleetHandle> {
        Ok(FleetHandle { addr: self.local_addr()?, shutdown: Arc::clone(&self.ctx.shutdown) })
    }

    /// Serve until a graceful shutdown (SIGINT or
    /// [`FleetHandle::begin_shutdown`]) completes its drain.
    pub fn serve(self) -> Result<()> {
        let addr = self.local_addr()?;
        let shutdown = Arc::clone(&self.ctx.shutdown);

        if let Some(waiter) = signal::install_sigint() {
            let shutdown = Arc::clone(&shutdown);
            std::thread::spawn(move || {
                waiter.wait();
                eprintln!(
                    "[helex fleet] SIGINT: draining (queued jobs finish, new work gets 503)"
                );
                shutdown.requested.store(true, Ordering::SeqCst);
                let _ = TcpStream::connect(addr);
            });
        }

        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(self.cfg.queue_cap);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut handlers = Vec::new();
        for _ in 0..self.cfg.conn_threads.max(1) {
            let conn_rx = Arc::clone(&conn_rx);
            let ctx = Arc::clone(&self.ctx);
            handlers.push(std::thread::spawn(move || loop {
                let next = conn_rx.lock().unwrap().recv();
                match next {
                    Ok(stream) => handle_connection(stream, &ctx),
                    Err(_) => break,
                }
            }));
        }

        let mut drainer: Option<std::thread::JoinHandle<()>> = None;
        for stream in self.listener.incoming() {
            let Ok(stream) = stream else { continue };
            if shutdown.requested.load(Ordering::SeqCst) {
                if drainer.is_none() {
                    let ctx = Arc::clone(&self.ctx);
                    let shutdown = Arc::clone(&shutdown);
                    drainer = Some(std::thread::spawn(move || {
                        ctx.dispatcher.drain();
                        if let Some(store) = &ctx.store {
                            if let Err(e) = store.flush() {
                                eprintln!("[helex fleet] warning: store flush failed: {e}");
                            }
                        }
                        shutdown.drained.store(true, Ordering::SeqCst);
                        let _ = TcpStream::connect(addr);
                    }));
                }
                if shutdown.drained.load(Ordering::SeqCst) {
                    break;
                }
                // reads keep answering during the drain; admissions get
                // 503 from the dispatcher's Draining refusal
            }
            match conn_tx.try_send(stream) {
                Ok(()) => {}
                Err(mpsc::TrySendError::Full(mut stream)) => {
                    let _ = http::write_error(
                        &mut stream,
                        503,
                        "overloaded",
                        "connection queue is full, retry later",
                    );
                }
                Err(mpsc::TrySendError::Disconnected(_)) => break,
            }
        }

        drop(conn_tx);
        for handler in handlers {
            let _ = handler.join();
        }
        if let Some(drainer) = drainer {
            let _ = drainer.join();
        } else {
            self.ctx.dispatcher.drain();
            if let Some(store) = &self.ctx.store {
                let _ = store.flush();
            }
        }
        eprintln!("[helex fleet] drained; bye");
        Ok(())
    }
}

fn handle_connection(mut stream: TcpStream, ctx: &Arc<FleetCtx>) {
    let _ = stream.set_read_timeout(Some(ctx.read_timeout));
    let _ = stream.set_write_timeout(Some(ctx.read_timeout));
    let _ = stream.set_nodelay(true);
    let request = match http::read_request(&mut stream, ctx.max_body, ctx.read_timeout) {
        Ok(request) => request,
        Err(e) => {
            let _ = http::write_error(&mut stream, e.status, "bad_request", &e.message);
            return;
        }
    };
    route(stream, &request, ctx);
}

fn route(mut stream: TcpStream, request: &Request, ctx: &Arc<FleetCtx>) {
    let path = request.path.as_str();
    let method = request.method.as_str();
    match (method, path) {
        ("POST", "/v1/jobs") => post_job(&mut stream, request, ctx),
        ("POST", "/v1/batches") => post_batch(&mut stream, request, ctx),
        ("GET", "/v1/quotas") => {
            let _ = http::write_json(&mut stream, 200, &quotas_body(ctx));
        }
        ("POST", "/v1/quotas") => post_quota(&mut stream, request, ctx),
        ("GET", "/v1/healthz") => {
            let _ = http::write_json(&mut stream, 200, &healthz_body(ctx));
        }
        ("GET", "/v1/stats") => {
            let _ = http::write_json(&mut stream, 200, &stats_body(ctx));
        }
        ("GET", _) if path.starts_with("/v1/jobs/") => get_job(stream, path, ctx),
        ("GET", _) if path.starts_with("/v1/batches/") => get_batch(stream, path, ctx),
        (_, "/v1/jobs" | "/v1/batches" | "/v1/quotas" | "/v1/healthz" | "/v1/stats") => {
            let _ = http::write_error(&mut stream, 405, "method_not_allowed", "wrong method");
        }
        (_, _) if path.starts_with("/v1/jobs/") || path.starts_with("/v1/batches/") => {
            let _ = http::write_error(&mut stream, 405, "method_not_allowed", "wrong method");
        }
        _ => {
            let _ = http::write_error(&mut stream, 404, "unknown_route", "no such route");
        }
    }
}

/// Decode a request body as JSON, answering the 400 on failure.
fn parse_body(stream: &mut TcpStream, body: &[u8]) -> Option<Json> {
    let text = match std::str::from_utf8(body) {
        Ok(text) => text,
        Err(_) => {
            let _ = http::write_error(stream, 400, "bad_encoding", "body is not UTF-8");
            return None;
        }
    };
    match json::parse(text) {
        Ok(parsed) => Some(parsed),
        Err(e) => {
            let _ = http::write_error(stream, 400, "bad_json", &e.to_string());
            None
        }
    }
}

fn write_refusal(stream: &mut TcpStream, refusal: &QuotaRefusal) {
    let _ = http::write_error(stream, 429, "quota_exhausted", &refusal.to_string());
}

fn write_admit_error(stream: &mut TcpStream, error: &AdmitError) {
    let code = match error {
        AdmitError::QueueFull { .. } => "queue_full",
        AdmitError::Draining => "draining",
    };
    let _ = http::write_error(stream, 503, code, &error.to_string());
}

/// Allocate an id and register the admitted job for polling.
fn register_job(
    ctx: &FleetCtx,
    label: String,
    grid: crate::cgra::Grid,
    admitted: Admitted,
) -> Arc<FleetJob> {
    let id = JobId(ctx.next_id.fetch_add(1, Ordering::SeqCst));
    let job = Arc::new(FleetJob {
        id,
        label,
        grid,
        fingerprint: admitted.fp,
        slot: admitted.slot,
        primary: admitted.primary,
    });
    ctx.jobs.lock().unwrap().insert(id, Arc::clone(&job));
    job
}

fn post_job(stream: &mut TcpStream, request: &Request, ctx: &Arc<FleetCtx>) {
    let Some(parsed) = parse_body(stream, &request.body) else { return };
    let spec = match wire::decode_spec(&parsed) {
        Ok(spec) => spec,
        Err(e) => {
            let _ = http::write_error(stream, 400, "bad_spec", &e.to_string());
            return;
        }
    };
    // client identity and priority ride as extra top-level keys of the
    // same body (the replica's decoder ignores them, so one payload
    // works against both a replica and the fleet)
    let client = match parsed.get("client").map(Json::as_str) {
        None => "anonymous".to_string(),
        Some(Some(name)) if !name.is_empty() => name.to_string(),
        Some(_) => {
            let _ =
                http::write_error(stream, 400, "bad_client", "client must be a non-empty string");
            return;
        }
    };
    let priority = match parsed.get("priority") {
        None => DEFAULT_PRIORITY,
        Some(value) => match value.as_u64() {
            Some(p) if p <= MAX_PRIORITY as u64 => p as u8,
            _ => {
                let _ = http::write_error(
                    stream,
                    400,
                    "bad_priority",
                    &format!("priority must be an integer in 0..={MAX_PRIORITY}"),
                );
                return;
            }
        },
    };
    if let Err(refusal) = ctx.quotas.try_take(&client, 1) {
        write_refusal(stream, &refusal);
        return;
    }
    let label = spec.label.clone();
    let grid = spec.grid;
    let jobs = [(spec, priority)];
    let admitted = match ctx.dispatcher.admit(&jobs) {
        Ok(admitted) => admitted,
        Err(e) => {
            ctx.quotas.refund(&client, 1);
            write_admit_error(stream, &e);
            return;
        }
    };
    let admitted = admitted.into_iter().next().expect("one job admitted");
    let job = register_job(ctx, label, grid, admitted);
    let body = Json::obj(vec![
        ("id", Json::str(job.id.to_string())),
        ("fingerprint", Json::str(wire::fp_hex(job.fingerprint))),
        ("status", Json::str(job.slot.status().name())),
        ("url", Json::str(format!("/v1/jobs/{}", job.id))),
    ]);
    let _ = http::write_json(stream, 202, &body);
}

fn post_batch(stream: &mut TcpStream, request: &Request, ctx: &Arc<FleetCtx>) {
    let Some(parsed) = parse_body(stream, &request.body) else { return };
    let batch = match wire::decode_batch(&parsed) {
        Ok(batch) => batch,
        Err(e) => {
            let _ = http::write_error(stream, 400, "bad_batch", &e.to_string());
            return;
        }
    };
    let BatchRequest { label, client, priority, specs } = batch;
    let count = specs.len() as u64;
    if let Err(refusal) = ctx.quotas.try_take(&client, count) {
        write_refusal(stream, &refusal);
        return;
    }
    let jobs: Vec<(JobSpec, u8)> = specs.into_iter().map(|spec| (spec, priority)).collect();
    let admitted = match ctx.dispatcher.admit(&jobs) {
        Ok(admitted) => admitted,
        Err(e) => {
            ctx.quotas.refund(&client, count);
            write_admit_error(stream, &e);
            return;
        }
    };
    let mut ids = Vec::with_capacity(jobs.len());
    let mut rows = Vec::with_capacity(jobs.len());
    for ((spec, _), adm) in jobs.into_iter().zip(admitted) {
        let job = register_job(ctx, spec.label.clone(), spec.grid, adm);
        ids.push(job.id);
        rows.push(Json::obj(vec![
            ("id", Json::str(job.id.to_string())),
            ("fingerprint", Json::str(wire::fp_hex(job.fingerprint))),
            ("url", Json::str(format!("/v1/jobs/{}", job.id))),
        ]));
    }
    let batch_id = BatchId(ctx.next_id.fetch_add(1, Ordering::SeqCst));
    ctx.batches.lock().unwrap().insert(
        batch_id,
        BatchEntry { id: batch_id, label: label.clone(), client, jobs: ids },
    );
    let body = Json::obj(vec![
        ("id", Json::str(batch_id.to_string())),
        ("label", Json::str(label)),
        ("count", Json::U64(count)),
        ("jobs", Json::Arr(rows)),
        ("url", Json::str(format!("/v1/batches/{batch_id}"))),
    ]);
    let _ = http::write_json(stream, 202, &body);
}

fn post_quota(stream: &mut TcpStream, request: &Request, ctx: &Arc<FleetCtx>) {
    let Some(parsed) = parse_body(stream, &request.body) else { return };
    let rule = match wire::decode_quota(&parsed) {
        Ok(rule) => rule,
        Err(e) => {
            let _ = http::write_error(stream, 400, "bad_quota", &e.to_string());
            return;
        }
    };
    ctx.quotas.set_rule(&rule);
    let _ = http::write_json(stream, 200, &wire::encode_quota(&rule));
}

/// Assemble a replica-compatible [`JobResult`] from a resolved slot.
/// `from_cache` is true unless this job is the primary submission of a
/// fingerprint the fleet actually computed — exactly the single-node
/// semantics, lifted to fleet scope.
fn job_result(job: &FleetJob, run: &DoneRun) -> JobResult {
    JobResult {
        id: job.id,
        label: job.label.clone(),
        grid: job.grid,
        fingerprint: job.fingerprint,
        outcome: run.job.outcome.clone(),
        events: run.job.events.clone(),
        wall_secs: run.wall_secs,
        from_cache: !(job.primary && run.origin == Origin::Computed),
    }
}

fn outcome_tag(outcome: &JobOutcome) -> &'static str {
    match outcome {
        JobOutcome::Completed(_) => "completed",
        JobOutcome::Infeasible(_) => "infeasible",
        JobOutcome::Rejected(_) => "rejected",
    }
}

/// `GET /v1/jobs/:id` and `GET /v1/jobs/:id/events`. The poll body is
/// shape-identical to the replica's, so `client::wait_result` works
/// unchanged against the coordinator.
fn get_job(mut stream: TcpStream, path: &str, ctx: &Arc<FleetCtx>) {
    let rest = &path["/v1/jobs/".len()..];
    let (id_text, events) = match rest.strip_suffix("/events") {
        Some(id_text) => (id_text, true),
        None => (rest, false),
    };
    let Ok(id) = id_text.parse::<JobId>() else {
        let _ = http::write_error(&mut stream, 400, "bad_id", "job id must be job-<hex>");
        return;
    };
    let Some(job) = ctx.jobs.lock().unwrap().get(&id).cloned() else {
        let _ = http::write_error(&mut stream, 404, "unknown_job", "no such job on this fleet");
        return;
    };
    if events {
        if !claim_stream(&mut stream, ctx) {
            return;
        }
        let ctx = Arc::clone(ctx);
        std::thread::spawn(move || {
            stream_job_events(&mut stream, &job);
            ctx.active_streams.fetch_sub(1, Ordering::SeqCst);
        });
        return;
    }
    let status = job.slot.status();
    let mut pairs = vec![
        ("id", Json::str(id.to_string())),
        ("label", Json::str(&job.label)),
        ("status", Json::str(status.name())),
        ("fingerprint", Json::str(wire::fp_hex(job.fingerprint))),
    ];
    if let SlotStatus::Done(run) = &status {
        pairs.push(("result", wire::encode_result(&job_result(&job, run))));
    }
    let _ = http::write_json(&mut stream, 200, &Json::obj(pairs));
}

/// Reserve an event-stream thread slot, answering the 503 when the cap
/// is hit. Returns false if the stream must not be started.
fn claim_stream(stream: &mut TcpStream, ctx: &FleetCtx) -> bool {
    if ctx.active_streams.fetch_add(1, Ordering::SeqCst) >= MAX_EVENT_STREAMS {
        ctx.active_streams.fetch_sub(1, Ordering::SeqCst);
        let _ =
            http::write_error(stream, 503, "overloaded", "too many concurrent event streams");
        return false;
    }
    true
}

/// Replay a job's recorded search trace as ndjson once it resolves.
/// (Live per-candidate events stay on the replica that runs the job;
/// the coordinator serves the authoritative recorded trace.)
fn stream_job_events(stream: &mut TcpStream, job: &FleetJob) {
    let Some(run) = job.slot.wait_done(Duration::from_secs(4 * 3600)) else {
        let _ = http::write_error(stream, 408, "timeout", "job did not resolve in time");
        return;
    };
    let mut writer = match ChunkedWriter::start(stream, 200, "application/x-ndjson") {
        Ok(writer) => writer,
        Err(_) => return,
    };
    for event in &run.job.events {
        let mut line = wire::encode_event(event).to_string();
        line.push('\n');
        if writer.chunk(line.as_bytes()).is_err() {
            return;
        }
    }
    let _ = writer.finish();
}

/// `GET /v1/batches/:id` and `GET /v1/batches/:id/events`.
fn get_batch(mut stream: TcpStream, path: &str, ctx: &Arc<FleetCtx>) {
    let rest = &path["/v1/batches/".len()..];
    let (id_text, events) = match rest.strip_suffix("/events") {
        Some(id_text) => (id_text, true),
        None => (rest, false),
    };
    let Ok(id) = id_text.parse::<BatchId>() else {
        let _ = http::write_error(&mut stream, 400, "bad_id", "batch id must be batch-<hex>");
        return;
    };
    let Some(batch) = ctx.batches.lock().unwrap().get(&id).cloned() else {
        let _ =
            http::write_error(&mut stream, 404, "unknown_batch", "no such batch on this fleet");
        return;
    };
    if events {
        if !claim_stream(&mut stream, ctx) {
            return;
        }
        let ctx = Arc::clone(ctx);
        std::thread::spawn(move || {
            stream_batch_events(&mut stream, &ctx, &batch);
            ctx.active_streams.fetch_sub(1, Ordering::SeqCst);
        });
        return;
    }
    let _ = http::write_json(&mut stream, 200, &batch_body(ctx, &batch));
}

/// Snapshot the batch's jobs in submission order.
fn batch_jobs(ctx: &FleetCtx, batch: &BatchEntry) -> Vec<Arc<FleetJob>> {
    let jobs = ctx.jobs.lock().unwrap();
    batch
        .jobs
        .iter()
        .map(|id| Arc::clone(jobs.get(id).expect("batch job is registered")))
        .collect()
}

/// The aggregate batch view: counts by status plus one row per job.
fn batch_body(ctx: &FleetCtx, batch: &BatchEntry) -> Json {
    let jobs = batch_jobs(ctx, batch);
    let (mut queued, mut running, mut done) = (0u64, 0u64, 0u64);
    let mut rows = Vec::with_capacity(jobs.len());
    for job in &jobs {
        let status = job.slot.status();
        let mut row = vec![
            ("id", Json::str(job.id.to_string())),
            ("label", Json::str(&job.label)),
            ("status", Json::str(status.name())),
            ("fingerprint", Json::str(wire::fp_hex(job.fingerprint))),
            ("url", Json::str(format!("/v1/jobs/{}", job.id))),
        ];
        match &status {
            SlotStatus::Queued => queued += 1,
            SlotStatus::Running => running += 1,
            SlotStatus::Done(run) => {
                done += 1;
                let result = job_result(job, run);
                row.push(("outcome", Json::str(outcome_tag(&result.outcome))));
                row.push(("best_cost", result.best_cost().map_or(Json::Null, Json::F64)));
                // Pareto jobs report how wide their final front is (0
                // for scalar jobs), so suite dashboards can tell the
                // modes apart without pulling each full result
                row.push((
                    "front_size",
                    Json::U64(
                        result.outcome.search_result().map_or(0, |r| r.front.len()) as u64
                    ),
                ));
                row.push(("from_cache", Json::Bool(result.from_cache)));
            }
        }
        rows.push(Json::obj(row));
    }
    Json::obj(vec![
        ("id", Json::str(batch.id.to_string())),
        ("label", Json::str(&batch.label)),
        ("client", Json::str(&batch.client)),
        ("total", Json::U64(jobs.len() as u64)),
        ("queued", Json::U64(queued)),
        ("running", Json::U64(running)),
        ("done", Json::U64(done)),
        ("jobs", Json::Arr(rows)),
    ])
}

/// Tail a batch as ndjson: one `job_done` line per resolution (in
/// resolution order), then a final `batch_done` line.
fn stream_batch_events(stream: &mut TcpStream, ctx: &FleetCtx, batch: &BatchEntry) {
    let jobs = batch_jobs(ctx, batch);
    let mut writer = match ChunkedWriter::start(stream, 200, "application/x-ndjson") {
        Ok(writer) => writer,
        Err(_) => return,
    };
    let mut reported = vec![false; jobs.len()];
    let mut tick = ctx.dispatcher.progress_tick();
    loop {
        for (i, job) in jobs.iter().enumerate() {
            if reported[i] {
                continue;
            }
            let SlotStatus::Done(run) = job.slot.status() else { continue };
            reported[i] = true;
            let result = job_result(job, &run);
            let mut line = Json::obj(vec![
                ("type", Json::str("job_done")),
                ("id", Json::str(job.id.to_string())),
                ("fingerprint", Json::str(wire::fp_hex(job.fingerprint))),
                ("outcome", Json::str(outcome_tag(&result.outcome))),
                ("best_cost", result.best_cost().map_or(Json::Null, Json::F64)),
                (
                    "front_size",
                    Json::U64(
                        result.outcome.search_result().map_or(0, |r| r.front.len()) as u64
                    ),
                ),
                ("from_cache", Json::Bool(result.from_cache)),
            ])
            .to_string();
            line.push('\n');
            if writer.chunk(line.as_bytes()).is_err() {
                return;
            }
        }
        if reported.iter().all(|&r| r) {
            break;
        }
        tick = ctx.dispatcher.wait_progress(tick, Duration::from_millis(500));
    }
    let mut line = Json::obj(vec![
        ("type", Json::str("batch_done")),
        ("id", Json::str(batch.id.to_string())),
        ("total", Json::U64(jobs.len() as u64)),
    ])
    .to_string();
    line.push('\n');
    let _ = writer.chunk(line.as_bytes());
    let _ = writer.finish();
}

fn quotas_body(ctx: &FleetCtx) -> Json {
    let rows = ctx
        .quotas
        .rules()
        .into_iter()
        .map(|(rule, available)| {
            Json::obj(vec![
                ("client", Json::str(rule.client)),
                ("burst", Json::U64(rule.burst)),
                ("per_sec", Json::F64(rule.per_sec)),
                ("available", Json::U64(available)),
            ])
        })
        .collect();
    Json::obj(vec![("clients", Json::Arr(rows))])
}

fn healthz_body(ctx: &FleetCtx) -> Json {
    let draining =
        ctx.shutdown.requested.load(Ordering::SeqCst) || ctx.dispatcher.draining();
    let stats = ctx.dispatcher.stats();
    let statuses = ctx.pool.statuses();
    Json::obj(vec![
        ("status", Json::str(if draining { "draining" } else { "ok" })),
        ("role", Json::str("coordinator")),
        ("draining", Json::Bool(draining)),
        ("queued", Json::U64(stats.queued)),
        ("running", Json::U64(stats.running)),
        (
            "replicas",
            Json::obj(vec![
                ("healthy", Json::U64(ctx.pool.healthy_count() as u64)),
                ("total", Json::U64(statuses.len() as u64)),
            ]),
        ),
        ("uptime_secs", Json::F64(ctx.started.elapsed().as_secs_f64())),
    ])
}

fn stats_body(ctx: &FleetCtx) -> Json {
    let draining =
        ctx.shutdown.requested.load(Ordering::SeqCst) || ctx.dispatcher.draining();
    let stats = ctx.dispatcher.stats();
    let store = match &ctx.store {
        Some(store) => {
            let s = store.stats();
            Json::obj(vec![
                ("entries", Json::U64(s.entries as u64)),
                ("hits", Json::U64(s.hits)),
                ("misses", Json::U64(s.misses)),
                ("writes", Json::U64(s.writes)),
                ("evictions", Json::U64(s.evictions)),
                ("corrupt", Json::U64(s.corrupt)),
            ])
        }
        None => Json::Null,
    };
    let replicas =
        ctx.pool.statuses().iter().map(wire::encode_replica_status).collect::<Vec<_>>();
    Json::obj(vec![
        ("role", Json::str("coordinator")),
        ("draining", Json::Bool(draining)),
        (
            "queue",
            Json::obj(vec![
                ("queued", Json::U64(stats.queued)),
                ("running", Json::U64(stats.running)),
                ("capacity", Json::U64(ctx.queue_cap as u64)),
            ]),
        ),
        (
            "runs",
            Json::obj(vec![
                ("distinct", Json::U64(stats.distinct)),
                ("computed", Json::U64(stats.computed)),
                ("store_hits", Json::U64(stats.store_hits)),
                ("dedup_hits", Json::U64(stats.dedup_hits)),
                ("requeues", Json::U64(stats.requeues)),
            ]),
        ),
        ("replicas", Json::Arr(replicas)),
        ("store", store),
        ("uptime_secs", Json::F64(ctx.started.elapsed().as_secs_f64())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_id_round_trips_and_rejects_garbage() {
        let id = BatchId(0x2a);
        assert_eq!(id.to_string(), "batch-000000000000002a");
        assert_eq!("batch-000000000000002a".parse::<BatchId>(), Ok(id));
        assert_eq!("2a".parse::<BatchId>(), Ok(id), "prefix is optional");
        assert!("".parse::<BatchId>().is_err());
        assert!("batch-".parse::<BatchId>().is_err());
        assert!("batch-xyz".parse::<BatchId>().is_err());
        assert!("batch-00000000000000000".parse::<BatchId>().is_err(), "17 digits");
        // a job id's prefix is not a batch id's
        assert_eq!("job-2a".parse::<BatchId>(), Err(ParseBatchIdError));
    }

    #[test]
    fn fleet_refuses_to_bind_without_replicas() {
        let cfg = FleetConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
        let err = Fleet::bind(cfg).unwrap_err();
        assert!(err.to_string().contains("at least one replica"), "{err}");
    }
}
