//! Replica membership, health probing, and slot accounting.
//!
//! The coordinator treats each `helex serve` process as a pool of
//! dispatch slots (`slots_per_replica` concurrent jobs). A background
//! prober hits every replica's `/v1/healthz` on an interval and folds
//! the reply into a [`ReplicaState`]:
//!
//! - `Healthy` — answering, accepting work.
//! - `Draining` — answering but shutting down (`"status": "draining"`);
//!   no new work is sent, in-flight jobs are allowed to finish.
//! - `Unreachable` — two consecutive probe or dispatch failures; the
//!   dispatcher requeues anything it had assigned there.
//!
//! A single failure only bumps a counter (a replica mid-GC or briefly
//! overloaded shouldn't get its queue confiscated); the second in a row
//! flips it. Any successful probe or dispatch resets the count, so a
//! restarted replica rejoins automatically.

use crate::server::client;
use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

/// Consecutive failures before a replica is marked [`ReplicaState::Unreachable`].
pub const UNREACHABLE_AFTER: u32 = 2;

/// A replica's standing in the fleet, as seen by the last probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    Healthy,
    Draining,
    Unreachable,
}

impl ReplicaState {
    pub fn name(self) -> &'static str {
        match self {
            ReplicaState::Healthy => "healthy",
            ReplicaState::Draining => "draining",
            ReplicaState::Unreachable => "unreachable",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "healthy" => Some(ReplicaState::Healthy),
            "draining" => Some(ReplicaState::Draining),
            "unreachable" => Some(ReplicaState::Unreachable),
            _ => None,
        }
    }
}

/// One replica's status row — what `/v1/stats` reports per node and
/// what the `ReplicaStatus` wire codec carries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicaStatus {
    pub addr: String,
    pub state: ReplicaState,
    /// Jobs this coordinator currently has dispatched to the replica.
    pub inflight: u64,
    /// The replica's own queue depth, from its last healthz reply.
    pub queued: u64,
    /// The replica's own running-job count, from its last healthz reply.
    pub running: u64,
    pub consecutive_failures: u64,
}

impl ReplicaStatus {
    fn new(addr: String) -> Self {
        Self {
            addr,
            // optimistic until the first probe lands — the prober runs
            // immediately on start, so this window is milliseconds
            state: ReplicaState::Healthy,
            inflight: 0,
            queued: 0,
            running: 0,
            consecutive_failures: 0,
        }
    }
}

/// The fleet's view of its replicas: per-node state plus slot
/// accounting, with a condvar so dispatch workers can block until a
/// slot frees or a node recovers.
pub struct ReplicaPool {
    replicas: Mutex<Vec<ReplicaStatus>>,
    freed: Condvar,
    shutdown: AtomicBool,
    slots_per_replica: u64,
    prober: Mutex<Option<thread::JoinHandle<()>>>,
}

impl ReplicaPool {
    /// Build the pool and start the health prober. The first probe runs
    /// immediately so a dead address is discovered before the first
    /// dispatch attempt, then every `probe_interval`.
    pub fn start(
        addrs: &[String],
        slots_per_replica: usize,
        probe_interval: Duration,
    ) -> Arc<Self> {
        let pool = Arc::new(Self {
            replicas: Mutex::new(addrs.iter().map(|a| ReplicaStatus::new(a.clone())).collect()),
            freed: Condvar::new(),
            shutdown: AtomicBool::new(false),
            slots_per_replica: slots_per_replica.max(1) as u64,
            prober: Mutex::new(None),
        });
        let worker = Arc::clone(&pool);
        let handle = thread::Builder::new()
            .name("fleet-prober".into())
            .spawn(move || worker.probe_loop(probe_interval))
            .expect("spawn prober thread");
        *pool.prober.lock().unwrap() = Some(handle);
        pool
    }

    /// Pool without a prober, for unit tests that drive slot accounting
    /// directly (a live prober would fail-probe dead test addresses and
    /// race the failure-count assertions).
    #[cfg(test)]
    fn without_prober(addrs: &[String], slots_per_replica: usize) -> Arc<Self> {
        Arc::new(Self {
            replicas: Mutex::new(addrs.iter().map(|a| ReplicaStatus::new(a.clone())).collect()),
            freed: Condvar::new(),
            shutdown: AtomicBool::new(false),
            slots_per_replica: slots_per_replica.max(1) as u64,
            prober: Mutex::new(None),
        })
    }

    fn probe_loop(&self, interval: Duration) {
        loop {
            let addrs: Vec<String> =
                self.replicas.lock().unwrap().iter().map(|r| r.addr.clone()).collect();
            for addr in addrs {
                if self.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                self.probe_one(&addr);
            }
            // sleep in small steps so shutdown isn't delayed a full interval
            let mut slept = Duration::ZERO;
            while slept < interval {
                if self.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let step = Duration::from_millis(50).min(interval - slept);
                thread::sleep(step);
                slept += step;
            }
        }
    }

    fn probe_one(&self, addr: &str) {
        // single attempt, no retry: the prober itself is the retry loop
        let reply = client::get_json(addr, "/v1/healthz");
        let mut replicas = self.replicas.lock().unwrap();
        let Some(replica) = replicas.iter_mut().find(|r| r.addr == addr) else {
            return;
        };
        match reply {
            Ok(body) => {
                let draining = body.get("status").and_then(Json::as_str) == Some("draining")
                    || body.get("draining").and_then(Json::as_bool) == Some(true);
                replica.state =
                    if draining { ReplicaState::Draining } else { ReplicaState::Healthy };
                replica.queued = body.get("queued").and_then(Json::as_u64).unwrap_or(0);
                replica.running = body.get("running").and_then(Json::as_u64).unwrap_or(0);
                replica.consecutive_failures = 0;
            }
            Err(_) => {
                replica.consecutive_failures += 1;
                if replica.consecutive_failures >= UNREACHABLE_AFTER as u64 {
                    replica.state = ReplicaState::Unreachable;
                }
            }
        }
        drop(replicas);
        // state changes can unblock waiters either way (a recovery frees
        // capacity; a death lets a worker give up on a doomed wait)
        self.freed.notify_all();
    }

    /// Claim a dispatch slot on the least-loaded healthy replica,
    /// blocking until one exists. Returns `None` once [`shutdown`]
    /// (`ReplicaPool::shutdown`) is called.
    pub fn acquire(&self) -> Option<String> {
        let mut replicas = self.replicas.lock().unwrap();
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            let best = replicas
                .iter_mut()
                .filter(|r| r.state == ReplicaState::Healthy && r.inflight < self.slots_per_replica)
                .min_by_key(|r| r.inflight);
            if let Some(replica) = best {
                replica.inflight += 1;
                return Some(replica.addr.clone());
            }
            // bounded wait: recheck shutdown/health even with no notify
            let (guard, _) =
                self.freed.wait_timeout(replicas, Duration::from_millis(200)).unwrap();
            replicas = guard;
        }
    }

    /// Release a slot taken by [`acquire`](ReplicaPool::acquire).
    /// `ok = false` counts a dispatch failure toward unreachability;
    /// `ok = true` clears the failure streak.
    pub fn release(&self, addr: &str, ok: bool) {
        let mut replicas = self.replicas.lock().unwrap();
        if let Some(replica) = replicas.iter_mut().find(|r| r.addr == addr) {
            replica.inflight = replica.inflight.saturating_sub(1);
            if ok {
                replica.consecutive_failures = 0;
                if replica.state == ReplicaState::Unreachable {
                    replica.state = ReplicaState::Healthy;
                }
            } else {
                replica.consecutive_failures += 1;
                if replica.consecutive_failures >= UNREACHABLE_AFTER as u64 {
                    replica.state = ReplicaState::Unreachable;
                }
            }
        }
        drop(replicas);
        self.freed.notify_all();
    }

    /// Snapshot of every replica's status, in configuration order.
    pub fn statuses(&self) -> Vec<ReplicaStatus> {
        self.replicas.lock().unwrap().clone()
    }

    /// How many replicas are currently dispatchable.
    pub fn healthy_count(&self) -> usize {
        self.replicas
            .lock()
            .unwrap()
            .iter()
            .filter(|r| r.state == ReplicaState::Healthy)
            .count()
    }

    /// Stop the prober and unblock every `acquire` waiter with `None`.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.freed.notify_all();
        let handle = self.prober.lock().unwrap().take();
        if let Some(handle) = handle {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_names_round_trip() {
        for state in
            [ReplicaState::Healthy, ReplicaState::Draining, ReplicaState::Unreachable]
        {
            assert_eq!(ReplicaState::from_name(state.name()), Some(state));
        }
        assert_eq!(ReplicaState::from_name("zombie"), None);
    }

    #[test]
    fn acquire_prefers_least_loaded_and_respects_slot_cap() {
        // no live replica needed: acquire/release only touch pool state
        let pool =
            ReplicaPool::without_prober(&["127.0.0.1:1".into(), "127.0.0.1:2".into()], 2);
        let a = pool.acquire().unwrap();
        let b = pool.acquire().unwrap();
        assert_ne!(a, b, "second acquire must take the idle replica");
        let c = pool.acquire().unwrap();
        let d = pool.acquire().unwrap();
        assert_ne!(c, d);
        // all 4 slots taken: a release must hand the slot to a blocked waiter
        let pool2 = Arc::clone(&pool);
        let waiter = thread::spawn(move || pool2.acquire());
        thread::sleep(Duration::from_millis(50));
        pool.release(&a, true);
        let e = waiter.join().unwrap().unwrap();
        assert_eq!(e, a);
        pool.shutdown();
    }

    #[test]
    fn two_failures_mark_unreachable_and_success_recovers() {
        let pool = ReplicaPool::without_prober(&["127.0.0.1:1".into()], 4);
        let addr = pool.acquire().unwrap();
        pool.release(&addr, false);
        assert_eq!(pool.statuses()[0].state, ReplicaState::Healthy, "one strike is not out");
        let addr = pool.acquire().unwrap();
        pool.release(&addr, false);
        assert_eq!(pool.statuses()[0].state, ReplicaState::Unreachable);
        assert_eq!(pool.healthy_count(), 0);
        // an unreachable replica is never handed out...
        let pool2 = Arc::clone(&pool);
        let waiter = thread::spawn(move || pool2.acquire());
        thread::sleep(Duration::from_millis(50));
        assert!(!waiter.is_finished(), "acquire must block with zero healthy replicas");
        // ...until a successful contact (here: an ok release, as after a
        // dispatch that worked) clears the streak and restores it
        pool.release("127.0.0.1:1", true);
        assert_eq!(waiter.join().unwrap().as_deref(), Some("127.0.0.1:1"));
        assert_eq!(pool.statuses()[0].state, ReplicaState::Healthy);
        pool.shutdown();
    }

    #[test]
    fn shutdown_unblocks_waiters_with_none() {
        let pool = ReplicaPool::without_prober(&["127.0.0.1:1".into()], 1);
        let _slot = pool.acquire().unwrap();
        let pool2 = Arc::clone(&pool);
        let waiter = thread::spawn(move || pool2.acquire());
        thread::sleep(Duration::from_millis(50));
        pool.shutdown();
        assert_eq!(waiter.join().unwrap(), None);
    }
}
