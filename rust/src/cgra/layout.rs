//! Functional layouts: which operation groups each cell supports.
//!
//! A layout is the unit the BB search manipulates (a "subproblem"
//! corresponds to one layout). I/O cells always support exactly Mem and
//! are never touched by the search (Section III-E); compute cells carry a
//! subset of the compute groups. Cells can additionally be marked
//! *reserved* by the mapper (reserve-on-demand: routing only, no ops).

use super::{CellId, Grid};
use crate::fabric::Fabric;
use crate::ops::{GroupSet, OpGroup, NUM_GROUPS};

/// A functional layout of a grid.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Layout {
    pub grid: Grid,
    /// The interconnect the layout is provisioned on. Defaults to the
    /// legacy-equivalent Mesh4 fabric; always consistent with `grid`
    /// (constructors guarantee it). Private so derived transforms
    /// (`clone`, `without_group`, `union`, …) can never drop it.
    fabric: Fabric,
    /// Per-cell supported groups (row-major, same indexing as `Grid`).
    support: Vec<GroupSet>,
}

impl Layout {
    /// Full homogeneous layout: every compute cell supports every compute
    /// group in `groups` (Mem is routed to I/O cells automatically).
    pub fn full(grid: Grid, groups: GroupSet) -> Self {
        Self::full_on(Fabric::mesh4(grid), groups)
    }

    /// [`Self::full`] on an explicit fabric: inert border cells (I/O
    /// sides disabled by the fabric's mask) and masked cells get empty
    /// support — they route but host no ops.
    pub fn full_on(fabric: Fabric, groups: GroupSet) -> Self {
        let grid = fabric.grid();
        let compute_support = groups.intersect(GroupSet::all_compute());
        let support = grid
            .cells()
            .map(|c| {
                if fabric.is_masked(c) {
                    GroupSet::EMPTY
                } else if grid.is_compute(c) {
                    compute_support
                } else if fabric.is_active_io(c) {
                    GroupSet::mem_only()
                } else {
                    GroupSet::EMPTY
                }
            })
            .collect();
        Self { grid, fabric, support }
    }

    /// Layout with empty compute cells (used as a base for constructing
    /// heatmap layouts).
    pub fn empty(grid: Grid) -> Self {
        Self::empty_on(Fabric::mesh4(grid))
    }

    /// [`Self::empty`] on an explicit fabric.
    pub fn empty_on(fabric: Fabric) -> Self {
        let grid = fabric.grid();
        let support = grid
            .cells()
            .map(|c| {
                if grid.is_compute(c) || fabric.is_masked(c) || !fabric.is_active_io(c) {
                    GroupSet::EMPTY
                } else {
                    GroupSet::mem_only()
                }
            })
            .collect();
        Self { grid, fabric, support }
    }

    /// An empty layout on the same grid *and fabric* as `self` (the
    /// fabric-preserving base for heatmap/seed construction).
    pub fn empty_like(&self) -> Self {
        Self::empty_on(self.fabric.clone())
    }

    /// The interconnect this layout is provisioned on.
    pub fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    pub fn support(&self, cell: CellId) -> GroupSet {
        self.support[cell as usize]
    }

    pub fn supports(&self, cell: CellId, g: OpGroup) -> bool {
        self.support[cell as usize].contains(g)
    }

    /// Set the support of a compute cell. Panics on I/O cells — the
    /// search must never touch them.
    pub fn set_support(&mut self, cell: CellId, s: GroupSet) {
        assert!(self.grid.is_compute(cell), "cannot reconfigure I/O cell {cell}");
        assert!(
            s.is_subset_of(GroupSet::all_compute()),
            "compute cells cannot host Mem"
        );
        self.support[cell as usize] = s;
    }

    /// Remove one group from a compute cell, returning the new layout.
    pub fn without_group(&self, cell: CellId, g: OpGroup) -> Layout {
        let mut l = self.clone();
        l.set_support(cell, l.support(cell).without(g));
        l
    }

    /// Remove a set of groups from a compute cell, returning the new
    /// layout.
    pub fn without_groups(&self, cell: CellId, mask: GroupSet) -> Layout {
        let mut l = self.clone();
        l.set_support(cell, l.support(cell).minus(mask));
        l
    }

    /// Number of instances of each group over *compute* cells, indexed by
    /// `OpGroup::index()` (the `N_g` of Equation 1). Mem instances count
    /// I/O cells and are reported for completeness but never searched.
    pub fn group_instances(&self) -> [usize; NUM_GROUPS] {
        let mut n = [0usize; NUM_GROUPS];
        for c in self.grid.cells() {
            for g in self.support(c).iter() {
                n[g.index()] += 1;
            }
        }
        n
    }

    /// Total group instances over compute cells only (the headline
    /// "number of operations" metric of the paper).
    pub fn compute_instances(&self) -> usize {
        self.grid
            .compute_cells()
            .map(|c| self.support(c).len())
            .sum()
    }

    /// Per-group instance counts over compute cells only.
    pub fn compute_group_instances(&self) -> [usize; NUM_GROUPS] {
        let mut n = [0usize; NUM_GROUPS];
        for c in self.grid.compute_cells() {
            for g in self.support(c).iter() {
                n[g.index()] += 1;
            }
        }
        n
    }

    /// True if every compute cell's support is a subset of `other`'s.
    pub fn is_subset_of(&self, other: &Layout) -> bool {
        self.grid == other.grid
            && self
                .grid
                .cells()
                .all(|c| self.support(c).is_subset_of(other.support(c)))
    }

    /// Union with another layout (used to overlay per-DFG usage maps into
    /// the heatmap layout).
    pub fn union(&self, other: &Layout) -> Layout {
        assert_eq!(self.grid, other.grid);
        assert_eq!(self.fabric, other.fabric);
        let support = self
            .grid
            .cells()
            .map(|c| self.support(c).union(other.support(c)))
            .collect();
        Layout { grid: self.grid, fabric: self.fabric.clone(), support }
    }

    /// Compact one-char-per-group textual rendering, for debugging and
    /// the CLI `show` command.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for r in 0..self.grid.rows {
            for c in 0..self.grid.cols {
                let id = self.grid.cell(r, c);
                let s = self.support(id);
                let glyph = if self.grid.is_io(id) {
                    "IO....".to_string()
                } else {
                    let mut t = String::new();
                    for (g, ch) in
                        [(OpGroup::Arith, 'A'), (OpGroup::Div, 'D'), (OpGroup::FP, 'F'),
                         (OpGroup::Mult, 'M'), (OpGroup::Other, 'O')]
                    {
                        t.push(if s.contains(g) { ch } else { '.' });
                    }
                    format!(".{t}")
                };
                out.push_str(&glyph);
                out.push(' ');
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> Grid {
        Grid::new(4, 5)
    }

    #[test]
    fn full_layout_supports_everything_on_compute() {
        let l = Layout::full(grid(), GroupSet::all_compute().with(OpGroup::Mem));
        for c in l.grid.compute_cells() {
            assert_eq!(l.support(c), GroupSet::all_compute());
        }
        for c in l.grid.io_cells() {
            assert_eq!(l.support(c), GroupSet::mem_only());
        }
    }

    #[test]
    fn full_layout_restricted_to_used_groups() {
        // Section IV-F: if the DFG set has no divides, the full layout has
        // no cells supporting divide.
        let used = GroupSet::from_groups(&[OpGroup::Arith, OpGroup::Mult, OpGroup::Mem]);
        let l = Layout::full(grid(), used);
        for c in l.grid.compute_cells() {
            assert!(l.supports(c, OpGroup::Arith));
            assert!(l.supports(c, OpGroup::Mult));
            assert!(!l.supports(c, OpGroup::Div));
        }
    }

    #[test]
    fn instance_counts() {
        let g = grid(); // 4x5: compute = 2*3 = 6
        let l = Layout::full(g, GroupSet::all_compute());
        let n = l.compute_group_instances();
        assert_eq!(n[OpGroup::Arith.index()], 6);
        assert_eq!(n[OpGroup::Div.index()], 6);
        assert_eq!(l.compute_instances(), 30);
        // group_instances includes Mem on the 14 I/O cells
        assert_eq!(l.group_instances()[OpGroup::Mem.index()], 14);
    }

    #[test]
    fn removal_is_functional() {
        let l = Layout::full(grid(), GroupSet::all_compute());
        let cell = l.grid.compute_cells().next().unwrap();
        let l2 = l.without_group(cell, OpGroup::Div);
        assert!(l.supports(cell, OpGroup::Div)); // original untouched
        assert!(!l2.supports(cell, OpGroup::Div));
        assert_eq!(l2.compute_instances(), l.compute_instances() - 1);
        assert!(l2.is_subset_of(&l));
        assert!(!l.is_subset_of(&l2));
    }

    #[test]
    fn without_groups_mask() {
        let l = Layout::full(grid(), GroupSet::all_compute());
        let cell = l.grid.compute_cells().next().unwrap();
        let mask = GroupSet::from_groups(&[OpGroup::Div, OpGroup::Other]);
        let l2 = l.without_groups(cell, mask);
        assert_eq!(l2.support(cell).len(), 3);
        assert!(!l2.supports(cell, OpGroup::Div));
        assert!(!l2.supports(cell, OpGroup::Other));
        assert!(l2.supports(cell, OpGroup::Arith));
    }

    #[test]
    #[should_panic(expected = "cannot reconfigure I/O cell")]
    fn touching_io_cell_panics() {
        let mut l = Layout::full(grid(), GroupSet::all_compute());
        let io = l.grid.io_cells().next().unwrap();
        l.set_support(io, GroupSet::EMPTY);
    }

    #[test]
    fn union_overlays() {
        let g = grid();
        let mut a = Layout::empty(g);
        let mut b = Layout::empty(g);
        let c1 = g.cell(1, 1);
        let c2 = g.cell(1, 2);
        a.set_support(c1, GroupSet::from_groups(&[OpGroup::Arith]));
        b.set_support(c1, GroupSet::from_groups(&[OpGroup::Mult]));
        b.set_support(c2, GroupSet::from_groups(&[OpGroup::Div]));
        let u = a.union(&b);
        assert_eq!(u.support(c1).len(), 2);
        assert_eq!(u.support(c2).len(), 1);
    }

    #[test]
    fn default_constructors_carry_the_mesh4_fabric() {
        let l = Layout::full(grid(), GroupSet::all_compute());
        assert!(l.fabric().is_default());
        assert_eq!(l.fabric().grid(), l.grid);
        assert!(Layout::empty(grid()).fabric().is_default());
    }

    #[test]
    fn fabric_survives_every_layout_transform() {
        use crate::fabric::{FabricSpec, Topology};
        let spec = FabricSpec { topology: Topology::Mesh8, ..Default::default() };
        let f = spec.build(grid());
        let l = Layout::full_on(f.clone(), GroupSet::all_compute());
        assert_eq!(l.fabric(), &f);
        let cell = l.grid.compute_cells().next().unwrap();
        assert_eq!(l.without_group(cell, OpGroup::Div).fabric(), &f);
        assert_eq!(
            l.without_groups(cell, GroupSet::from_groups(&[OpGroup::Div])).fabric(),
            &f
        );
        assert_eq!(l.clone().fabric(), &f);
        assert_eq!(l.union(&l.without_group(cell, OpGroup::Div)).fabric(), &f);
        assert_eq!(l.empty_like().fabric(), &f);
        // layouts differing only in fabric are different layouts
        let legacy = Layout::full(grid(), GroupSet::all_compute());
        assert_ne!(l, legacy);
    }

    #[test]
    fn inert_io_cells_have_no_mem_support() {
        use crate::fabric::{FabricSpec, SIDE_N, SIDE_S};
        let g = grid(); // 4x5
        let f = FabricSpec { io_mask: SIDE_N | SIDE_S, ..Default::default() }.build(g);
        let l = Layout::full_on(f.clone(), GroupSet::all_compute());
        assert_eq!(l.support(g.cell(0, 2)), GroupSet::mem_only());
        // west/east edge non-corner cells are inert: empty support
        assert_eq!(l.support(g.cell(1, 0)), GroupSet::EMPTY);
        assert_eq!(l.support(g.cell(2, 4)), GroupSet::EMPTY);
        // compute cells untouched
        assert_eq!(l.support(g.cell(1, 1)), GroupSet::all_compute());
        let e = Layout::empty_on(f);
        assert_eq!(e.support(g.cell(1, 0)), GroupSet::EMPTY);
        assert_eq!(e.support(g.cell(0, 2)), GroupSet::mem_only());
    }

    #[test]
    fn masked_cells_have_no_support() {
        let g = grid();
        let dead = g.cell(1, 2);
        let f = crate::fabric::Fabric::mesh4(g).with_masked(&[dead]);
        let l = Layout::full_on(f, GroupSet::all_compute());
        assert_eq!(l.support(dead), GroupSet::EMPTY);
        assert_eq!(l.support(g.cell(1, 1)), GroupSet::all_compute());
    }

    #[test]
    fn render_shape() {
        let l = Layout::full(grid(), GroupSet::all_compute());
        let r = l.render();
        assert_eq!(r.lines().count(), 4);
        assert!(r.contains("IO"));
        assert!(r.contains("ADFMO"));
    }
}
