//! T-CGRA architecture model (paper Section II-A, Fig 1).
//!
//! An R×C grid of cells in a 4-nearest-neighbour topology. Border cells
//! are *I/O cells* (FIFOs only; execute LOAD/STORE), interior cells are
//! *compute cells* (FU + ALU + switches + FIFOs). The machine is
//! spatially configured: each cell runs one fixed operation for the whole
//! execution, and programmable switches route values between cells,
//! possibly *through* cells (pass-through routing does not occupy the FU).

pub mod layout;

pub use layout::Layout;

/// Cell index within a grid (row-major).
pub type CellId = u16;

/// The four link directions, in neighbour order N, E, S, W.
pub const DIRS: [(i32, i32); 4] = [(-1, 0), (0, 1), (1, 0), (0, -1)];

/// Kind of a cell, determined purely by its position.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellKind {
    /// Border cell: FIFOs only, executes LOAD/STORE.
    Io,
    /// Interior cell: FU + ALU(s).
    Compute,
}

/// Why a grid could not be constructed. [`Grid::try_new`] is total:
/// untrusted dimensions (wire decoding, CLI input) turn into one of
/// these instead of a panic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridError {
    /// Fewer than 3 rows or columns: no compute cell would exist.
    TooSmall { rows: usize, cols: usize },
    /// `rows*cols` overflows the [`CellId`] index space.
    TooLarge { rows: usize, cols: usize },
}

impl std::fmt::Display for GridError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GridError::TooSmall { rows, cols } => {
                write!(f, "grid must be at least 3x3, got {rows}x{cols}")
            }
            GridError::TooLarge { rows, cols } => {
                write!(f, "grid {rows}x{cols} too large for CellId")
            }
        }
    }
}

impl std::error::Error for GridError {}

/// An R×C T-CGRA grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Grid {
    pub rows: usize,
    pub cols: usize,
}

impl Grid {
    /// Create a grid. Needs at least 3×3 so at least one compute cell
    /// exists. Panics on invalid dimensions; use [`Self::try_new`] for
    /// untrusted input.
    pub fn new(rows: usize, cols: usize) -> Self {
        match Self::try_new(rows, cols) {
            Ok(g) => g,
            Err(e @ GridError::TooSmall { .. }) => {
                panic!("{e}")
            }
            Err(GridError::TooLarge { .. }) => panic!("grid too large for CellId"),
        }
    }

    /// Total constructor: validates the dimensions instead of panicking.
    pub fn try_new(rows: usize, cols: usize) -> Result<Self, GridError> {
        if rows < 3 || cols < 3 {
            return Err(GridError::TooSmall { rows, cols });
        }
        if rows.saturating_mul(cols) > u16::MAX as usize {
            return Err(GridError::TooLarge { rows, cols });
        }
        Ok(Self { rows, cols })
    }

    pub fn num_cells(&self) -> usize {
        self.rows * self.cols
    }

    /// Number of interior (compute) cells.
    pub fn num_compute(&self) -> usize {
        (self.rows - 2) * (self.cols - 2)
    }

    /// Number of border (I/O) cells.
    pub fn num_io(&self) -> usize {
        self.num_cells() - self.num_compute()
    }

    pub fn cell(&self, r: usize, c: usize) -> CellId {
        debug_assert!(r < self.rows && c < self.cols);
        (r * self.cols + c) as CellId
    }

    pub fn coords(&self, id: CellId) -> (usize, usize) {
        let id = id as usize;
        (id / self.cols, id % self.cols)
    }

    pub fn kind(&self, id: CellId) -> CellKind {
        let (r, c) = self.coords(id);
        if r == 0 || c == 0 || r == self.rows - 1 || c == self.cols - 1 {
            CellKind::Io
        } else {
            CellKind::Compute
        }
    }

    pub fn is_compute(&self, id: CellId) -> bool {
        self.kind(id) == CellKind::Compute
    }

    pub fn is_io(&self, id: CellId) -> bool {
        self.kind(id) == CellKind::Io
    }

    /// Neighbour in direction `dir` (N/E/S/W), if inside the grid.
    pub fn neighbor(&self, id: CellId, dir: usize) -> Option<CellId> {
        let (r, c) = self.coords(id);
        let (dr, dc) = DIRS[dir];
        let (nr, nc) = (r as i32 + dr, c as i32 + dc);
        if nr < 0 || nc < 0 || nr >= self.rows as i32 || nc >= self.cols as i32 {
            None
        } else {
            Some(self.cell(nr as usize, nc as usize))
        }
    }

    /// All in-grid neighbours of a cell.
    pub fn neighbors(&self, id: CellId) -> impl Iterator<Item = CellId> + '_ {
        (0..4).filter_map(move |d| self.neighbor(id, d))
    }

    /// Manhattan distance between two cells.
    pub fn manhattan(&self, a: CellId, b: CellId) -> usize {
        let (ar, ac) = self.coords(a);
        let (br, bc) = self.coords(b);
        ar.abs_diff(br) + ac.abs_diff(bc)
    }

    /// Directed-link id for the link leaving `cell` in direction `dir`.
    /// Link ids are dense in `[0, 4 * num_cells)`; out-of-grid directions
    /// simply have no user.
    pub fn link(&self, cell: CellId, dir: usize) -> usize {
        cell as usize * 4 + dir
    }

    pub fn num_links(&self) -> usize {
        self.num_cells() * 4
    }

    /// Iterate all cell ids.
    pub fn cells(&self) -> impl Iterator<Item = CellId> {
        (0..self.num_cells() as u16).map(|i| i as CellId)
    }

    /// Iterate compute cell ids, top-left to bottom-right (the branching
    /// order Algorithms 2/3 specify).
    pub fn compute_cells(&self) -> impl Iterator<Item = CellId> + '_ {
        self.cells().filter(move |&c| self.is_compute(c))
    }

    /// Iterate I/O (border) cell ids.
    pub fn io_cells(&self) -> impl Iterator<Item = CellId> + '_ {
        self.cells().filter(move |&c| self.is_io(c))
    }
}

impl std::fmt::Display for Grid {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}", self.rows, self.cols)
    }
}

/// A set of cells over one grid, backed by a bitset. Replaces the linear
/// `Vec::contains` scans on the mapper hot path (reservation checks run
/// once per node per candidate layout).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CellSet {
    bits: Vec<u64>,
    len: usize,
}

impl CellSet {
    /// Empty set over a universe of `num_cells` cells.
    pub fn new(num_cells: usize) -> Self {
        Self { bits: vec![0; (num_cells + 63) / 64], len: 0 }
    }

    /// Build from a slice of cell ids (duplicates collapse).
    pub fn from_cells(num_cells: usize, cells: &[CellId]) -> Self {
        let mut s = Self::new(num_cells);
        for &c in cells {
            s.insert(c);
        }
        s
    }

    /// Insert a cell; returns true if it was newly added.
    pub fn insert(&mut self, c: CellId) -> bool {
        let (w, b) = (c as usize / 64, c as usize % 64);
        let fresh = self.bits[w] & (1 << b) == 0;
        self.bits[w] |= 1 << b;
        self.len += fresh as usize;
        fresh
    }

    pub fn contains(&self, c: CellId) -> bool {
        let (w, b) = (c as usize / 64, c as usize % 64);
        self.bits.get(w).map_or(false, |word| word & (1 << b) != 0)
    }

    pub fn remove(&mut self, c: CellId) {
        let (w, b) = (c as usize / 64, c as usize % 64);
        if self.bits[w] & (1 << b) != 0 {
            self.bits[w] &= !(1 << b);
            self.len -= 1;
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn clear(&mut self) {
        self.bits.fill(0);
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_10x10() {
        let g = Grid::new(10, 10);
        assert_eq!(g.num_cells(), 100);
        assert_eq!(g.num_compute(), 64);
        assert_eq!(g.num_io(), 36);
    }

    #[test]
    fn paper_20x20_has_76_io_cells() {
        // Section IV-J: 18x18 inner compute grid + 76 boundary I/O cells.
        let g = Grid::new(20, 20);
        assert_eq!(g.num_compute(), 324);
        assert_eq!(g.num_io(), 76);
    }

    #[test]
    fn kind_by_position() {
        let g = Grid::new(5, 7);
        assert_eq!(g.kind(g.cell(0, 0)), CellKind::Io);
        assert_eq!(g.kind(g.cell(0, 3)), CellKind::Io);
        assert_eq!(g.kind(g.cell(4, 6)), CellKind::Io);
        assert_eq!(g.kind(g.cell(2, 3)), CellKind::Compute);
        assert_eq!(g.kind(g.cell(1, 1)), CellKind::Compute);
    }

    #[test]
    fn neighbors_on_edges_and_interior() {
        let g = Grid::new(4, 4);
        let corner = g.cell(0, 0);
        assert_eq!(g.neighbors(corner).count(), 2);
        let interior = g.cell(1, 1);
        assert_eq!(g.neighbors(interior).count(), 4);
        let edge = g.cell(0, 2);
        assert_eq!(g.neighbors(edge).count(), 3);
    }

    #[test]
    fn neighbor_directions() {
        let g = Grid::new(4, 4);
        let c = g.cell(1, 1);
        assert_eq!(g.neighbor(c, 0), Some(g.cell(0, 1))); // N
        assert_eq!(g.neighbor(c, 1), Some(g.cell(1, 2))); // E
        assert_eq!(g.neighbor(c, 2), Some(g.cell(2, 1))); // S
        assert_eq!(g.neighbor(c, 3), Some(g.cell(1, 0))); // W
        assert_eq!(g.neighbor(g.cell(0, 0), 0), None);
        assert_eq!(g.neighbor(g.cell(0, 0), 3), None);
    }

    #[test]
    fn coords_roundtrip() {
        let g = Grid::new(6, 9);
        for id in g.cells() {
            let (r, c) = g.coords(id);
            assert_eq!(g.cell(r, c), id);
        }
    }

    #[test]
    fn manhattan_distance() {
        let g = Grid::new(8, 8);
        assert_eq!(g.manhattan(g.cell(0, 0), g.cell(3, 4)), 7);
        assert_eq!(g.manhattan(g.cell(2, 2), g.cell(2, 2)), 0);
    }

    #[test]
    fn compute_cells_iteration_order_is_row_major() {
        let g = Grid::new(4, 4);
        let cs: Vec<CellId> = g.compute_cells().collect();
        assert_eq!(cs, vec![g.cell(1, 1), g.cell(1, 2), g.cell(2, 1), g.cell(2, 2)]);
    }

    #[test]
    fn link_ids_dense_and_distinct() {
        let g = Grid::new(3, 3);
        let mut seen = std::collections::HashSet::new();
        for c in g.cells() {
            for d in 0..4 {
                assert!(seen.insert(g.link(c, d)));
                assert!(g.link(c, d) < g.num_links());
            }
        }
    }

    #[test]
    #[should_panic(expected = "at least 3x3")]
    fn too_small_grid_panics() {
        Grid::new(2, 5);
    }

    #[test]
    fn try_new_is_total() {
        assert_eq!(Grid::try_new(3, 3), Ok(Grid { rows: 3, cols: 3 }));
        assert_eq!(Grid::try_new(2, 5), Err(GridError::TooSmall { rows: 2, cols: 5 }));
        assert_eq!(Grid::try_new(5, 0), Err(GridError::TooSmall { rows: 5, cols: 0 }));
        assert_eq!(
            Grid::try_new(1000, 1000),
            Err(GridError::TooLarge { rows: 1000, cols: 1000 })
        );
        // usize overflow must not panic either
        assert!(matches!(
            Grid::try_new(usize::MAX, usize::MAX),
            Err(GridError::TooLarge { .. })
        ));
        // 255x257 = 65535 = u16::MAX fits exactly
        assert!(Grid::try_new(255, 257).is_ok());
        assert!(Grid::try_new(256, 257).is_err());
        // the error messages are what wire decoding surfaces as 400 reasons
        assert_eq!(
            Grid::try_new(2, 2).unwrap_err().to_string(),
            "grid must be at least 3x3, got 2x2"
        );
        assert_eq!(
            Grid::try_new(1000, 1000).unwrap_err().to_string(),
            "grid 1000x1000 too large for CellId"
        );
    }

    #[test]
    fn cellset_insert_contains_remove() {
        let g = Grid::new(10, 10);
        let mut s = CellSet::new(g.num_cells());
        assert!(s.is_empty());
        assert!(s.insert(7));
        assert!(!s.insert(7)); // duplicate collapses
        assert!(s.insert(99));
        assert_eq!(s.len(), 2);
        assert!(s.contains(7) && s.contains(99));
        assert!(!s.contains(8));
        s.remove(7);
        assert!(!s.contains(7));
        assert_eq!(s.len(), 1);
        s.remove(7); // double-remove is a no-op
        assert_eq!(s.len(), 1);
        s.clear();
        assert!(s.is_empty());
        assert!(!s.contains(99));
    }

    #[test]
    fn cellset_from_cells_matches_vec_contains() {
        let g = Grid::new(6, 6);
        let cells = [3u16, 17, 17, 35, 0];
        let s = CellSet::from_cells(g.num_cells(), &cells);
        assert_eq!(s.len(), 4);
        for c in g.cells() {
            assert_eq!(s.contains(c), cells.contains(&c), "cell {c}");
        }
    }
}
