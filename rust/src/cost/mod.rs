//! Layout cost model (paper Equation 1) plus the absolute-area/power
//! estimator used for Table V validation.
//!
//! ```text
//! LayoutCost = N_t × (cost(empty cells) + cost(FIFOs)) + Σ_g N_g × cost(g)
//! ```
//!
//! where `N_t` is the number of compute cells and `N_g` the instance
//! count of group `g` over compute cells. I/O cells are constant under
//! the search and excluded from the objective (the paper's reductions are
//! "with respect to the full resources of the compute cells"); Table V's
//! whole-chip validation adds them back via [`CostModel::cost_with_io`].

pub mod synth;

use crate::cgra::Layout;
use crate::ops::costs::{ComponentCosts, AREA_UM2_PER_UNIT, POWER_UW_PER_UNIT};
use crate::ops::{OpGroup, NUM_GROUPS};

/// Which objective a cost table models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    Area,
    Power,
}

/// A cost model over one component-cost table.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub components: ComponentCosts,
    pub objective: Objective,
}

impl CostModel {
    pub fn area() -> Self {
        Self { components: ComponentCosts::area(), objective: Objective::Area }
    }

    pub fn power() -> Self {
        Self { components: ComponentCosts::power(), objective: Objective::Power }
    }

    /// Equation 1: cost over compute cells.
    pub fn layout_cost(&self, layout: &Layout) -> f64 {
        let nt = layout.grid.num_compute() as f64;
        let base = nt * (self.components.empty_cell + self.components.fifos);
        let n = layout.compute_group_instances();
        base + self.instances_cost(&n)
    }

    /// Σ_g N_g × cost(g) for a per-group instance vector.
    pub fn instances_cost(&self, n: &[usize; NUM_GROUPS]) -> f64 {
        let mut c = 0.0;
        for (i, &count) in n.iter().enumerate() {
            c += count as f64 * self.components.group[i];
        }
        c
    }

    /// Whole-chip cost including I/O cells (Table V validation).
    pub fn cost_with_io(&self, layout: &Layout) -> f64 {
        self.layout_cost(layout) + layout.grid.num_io() as f64 * self.components.io_cell
    }

    /// O(1) cost delta of removing `g` from one compute cell.
    pub fn removal_delta(&self, g: OpGroup) -> f64 {
        -self.components.group_cost(g)
    }

    /// Theoretical minimum cost (Section III-D): same compute-cell count,
    /// but only the per-group minimum instance counts.
    pub fn theoretical_min_cost(&self, layout: &Layout, min_insts: &[usize; NUM_GROUPS]) -> f64 {
        let nt = layout.grid.num_compute() as f64;
        let base = nt * (self.components.empty_cell + self.components.fifos);
        // Mem instances live on I/O cells: excluded from the objective.
        let mut n = *min_insts;
        n[OpGroup::Mem.index()] = 0;
        base + self.instances_cost(&n)
    }

    /// Scale a normalized cost to the absolute unit of this objective
    /// (µm² for area, µW for power) as in Table V.
    pub fn to_absolute(&self, cost: f64) -> f64 {
        match self.objective {
            Objective::Area => cost * AREA_UM2_PER_UNIT,
            Objective::Power => cost * POWER_UW_PER_UNIT,
        }
    }
}

/// Relative reduction `1 - new/old` in percent.
pub fn reduction_pct(old: f64, new: f64) -> f64 {
    if old == 0.0 {
        0.0
    } else {
        (1.0 - new / old) * 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::ops::GroupSet;

    fn full(r: usize, c: usize) -> Layout {
        Layout::full(Grid::new(r, c), GroupSet::all_compute())
    }

    #[test]
    fn equation_1_matches_hand_computation() {
        // 4x5 grid: 6 compute cells, all 5 groups each.
        let l = full(4, 5);
        let m = CostModel::area();
        // base = 6 * 9.5 = 57; groups = 6 * (1+17+4.4+6.2+12.3) = 6*40.9
        let expect = 57.0 + 6.0 * 40.9;
        assert!((m.layout_cost(&l) - expect).abs() < 1e-9);
    }

    #[test]
    fn twelve_by_twelve_full_matches_table_5_ballpark() {
        // Paper Table V: 12x12 full ≈ 5577.6 units (with I/O).
        let l = full(12, 12);
        let m = CostModel::area();
        let with_io = m.cost_with_io(&l);
        assert!(
            (with_io - 5577.6).abs() / 5577.6 < 0.01,
            "12x12 full with IO = {with_io}, expected ≈ 5577.6"
        );
    }

    #[test]
    fn removal_reduces_cost_by_group_cost() {
        let l = full(5, 5);
        let m = CostModel::area();
        let c0 = m.layout_cost(&l);
        let cell = l.grid.compute_cells().next().unwrap();
        let l2 = l.without_group(cell, OpGroup::Div);
        let c1 = m.layout_cost(&l2);
        assert!((c0 - c1 - 17.0).abs() < 1e-9);
        assert!((m.removal_delta(OpGroup::Div) + 17.0).abs() < 1e-9);
    }

    #[test]
    fn theoretical_min_below_full() {
        let l = full(10, 10);
        let m = CostModel::area();
        let min_insts = [10, 2, 5, 17, 6, 3]; // arbitrary plausible mins
        let tm = m.theoretical_min_cost(&l, &min_insts);
        assert!(tm < m.layout_cost(&l));
        // base survives even with zero instances
        let zero = m.theoretical_min_cost(&l, &[0; NUM_GROUPS]);
        assert!((zero - 64.0 * 9.5).abs() < 1e-9);
    }

    #[test]
    fn mem_min_instances_do_not_count() {
        let l = full(10, 10);
        let m = CostModel::area();
        let a = m.theoretical_min_cost(&l, &[0, 0, 0, 0, 0, 0]);
        let b = m.theoretical_min_cost(&l, &[0, 0, 0, 99, 0, 0]);
        assert_eq!(a, b);
    }

    #[test]
    fn reduction_pct_basic() {
        assert!((reduction_pct(100.0, 30.0) - 70.0).abs() < 1e-12);
        assert_eq!(reduction_pct(0.0, 5.0), 0.0);
    }

    #[test]
    fn absolute_scaling() {
        let m = CostModel::area();
        assert!(m.to_absolute(1.0) > 900.0);
        let p = CostModel::power();
        assert!(p.to_absolute(1.0) < m.to_absolute(1.0));
    }

    #[test]
    fn power_cost_positive_and_smaller_compute_share() {
        let l = full(10, 10);
        let a = CostModel::area();
        let p = CostModel::power();
        assert!(p.layout_cost(&l) > 0.0);
        // removing everything saves a smaller *fraction* under power
        let empty = Layout::empty(l.grid);
        let ra = reduction_pct(a.layout_cost(&l), a.layout_cost(&empty));
        let rp = reduction_pct(p.layout_cost(&l), p.layout_cost(&empty));
        assert!(ra > rp, "area {ra}% should exceed power {rp}%");
    }
}
