//! Independent "synthesis" estimator for Table V validation.
//!
//! The paper validates its component-sum cost model by synthesizing the
//! complete 8×8 and 12×12 CGRAs with Synopsys DC and comparing actual
//! area/power against the model's estimates (discrepancy ≤ 1.4%). DC is
//! proprietary, so this module substitutes a *structurally independent*
//! estimator: it walks the layout as a netlist of leaf components with
//! their own absolute per-component values (µm² / µW), adds the
//! inter-cell wiring/clock-tree overheads that a real synthesis run
//! accounts for and Equation 1 does not, and reports chip totals. The
//! point of Table V is that two differently-structured estimates agree
//! to ~1%; that property is preserved.
//!
//! Provisioned fabrics (see [`crate::fabric`]) price their interconnect
//! here: extra switch degree (diagonal/express links) and per-stream
//! link capacity add per-cell surcharges, and masked cells are not
//! synthesized at all. The default Mesh4/cap-1 fabric adds *exactly*
//! zero, so Table V numbers are bit-identical to the pre-fabric model.

use crate::cgra::Layout;
use crate::cost::{CostModel, Objective};
use crate::ops::costs::{AREA_UM2_PER_UNIT, POWER_UW_PER_UNIT};

/// Absolute per-component "synthesis" results, derived independently of
/// the normalized Table III units (they are *not* exact multiples: each
/// leaf carries its own rounding, like real DC reports).
#[derive(Debug, Clone)]
pub struct SynthReport {
    pub area_um2: f64,
    pub power_uw: f64,
}

/// Leaf-level absolute values. Deliberately not exact multiples of the
/// Table III costs: each entry deviates by a fixed sub-percent amount to
/// model library-level rounding, so the validation is non-circular.
struct Leaves {
    arith: f64,
    div: f64,
    fp: f64,
    mult: f64,
    other: f64,
    fifos: f64,
    empty: f64,
    io: f64,
    /// per-cell wiring / clock overhead added by synthesis
    wiring: f64,
    /// per directed link *beyond* the baseline 4-dir switch (diagonal or
    /// express fabrics): extra crossbar ports and drivers
    link: f64,
    /// per extra value stream of link capacity (beyond 1), per directed
    /// link: wider mux trees and per-stream buffering
    stream: f64,
}

fn area_leaves() -> Leaves {
    let u = AREA_UM2_PER_UNIT;
    Leaves {
        arith: 1.004 * u,
        div: 16.93 * u,
        fp: 4.42 * u,
        mult: 6.17 * u,
        other: 12.35 * u,
        fifos: 4.88 * u,
        empty: 4.58 * u,
        io: 11.86 * u,
        wiring: 0.062 * u,
        link: 0.21 * u,
        stream: 0.13 * u,
    }
}

fn power_leaves() -> Leaves {
    let u = POWER_UW_PER_UNIT;
    Leaves {
        arith: 0.997 * u,
        div: 10.46 * u,
        fp: 3.31 * u,
        mult: 4.28 * u,
        other: 7.57 * u,
        fifos: 9.82 * u,
        empty: 6.87 * u,
        io: 16.55 * u,
        wiring: 0.055 * u,
        link: 0.19 * u,
        stream: 0.24 * u,
    }
}

fn synthesize_one(layout: &Layout, l: &Leaves) -> f64 {
    use crate::ops::OpGroup::*;
    let f = layout.fabric();
    // Fabric surcharge per cell: extra switch degree beyond the baseline
    // 4-dir mesh, plus per-stream capacity widening on every outgoing
    // link. Exactly zero for the default Mesh4/cap-1 fabric, so Table V
    // numbers are untouched.
    let extra_dirs = f.num_dirs().saturating_sub(4) as f64;
    let extra_streams = f.link_cap().saturating_sub(1) as f64 * f.num_dirs() as f64;
    let fabric_extra = extra_dirs * l.link + extra_streams * l.stream;
    let mut total = 0.0;
    for c in layout.grid.cells() {
        if f.is_masked(c) {
            continue; // masked cells are not synthesized at all
        }
        if layout.grid.is_io(c) {
            total += l.io + l.wiring + fabric_extra;
            continue;
        }
        total += l.empty + l.fifos + l.wiring + fabric_extra;
        let s = layout.support(c);
        if s.contains(Arith) {
            total += l.arith;
        }
        if s.contains(Div) {
            total += l.div;
        }
        if s.contains(FP) {
            total += l.fp;
        }
        if s.contains(Mult) {
            total += l.mult;
        }
        if s.contains(Other) {
            total += l.other;
        }
    }
    total
}

/// "Synthesize" a complete CGRA (compute + I/O cells), as the paper does
/// for Table V.
pub fn synthesize(layout: &Layout) -> SynthReport {
    SynthReport {
        area_um2: synthesize_one(layout, &area_leaves()),
        power_uw: synthesize_one(layout, &power_leaves()),
    }
}

/// HeLEx-side absolute estimates for the same chip (cost model × scale),
/// the other column of Table V.
pub fn helex_estimate(layout: &Layout) -> SynthReport {
    let a = CostModel::area();
    let p = CostModel::power();
    SynthReport {
        area_um2: a.to_absolute(a.cost_with_io(layout)),
        power_uw: p.to_absolute(p.cost_with_io(layout)),
    }
}

/// Percentage discrepancy between synthesis and estimate, per objective.
pub fn discrepancy_pct(layout: &Layout) -> (f64, f64) {
    let s = synthesize(layout);
    let e = helex_estimate(layout);
    (
        ((e.area_um2 - s.area_um2) / s.area_um2 * 100.0).abs(),
        ((e.power_uw - s.power_uw) / s.power_uw * 100.0).abs(),
    )
}

impl SynthReport {
    pub fn get(&self, obj: Objective) -> f64 {
        match obj {
            Objective::Area => self.area_um2,
            Objective::Power => self.power_uw,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::ops::GroupSet;

    fn full(r: usize, c: usize) -> Layout {
        Layout::full(Grid::new(r, c), GroupSet::all_compute())
    }

    #[test]
    fn synthesis_close_to_estimate_like_table_5() {
        // The paper reports <= 1.4% discrepancy on 8x8 and 12x12 full.
        for (r, c) in [(8, 8), (12, 12)] {
            let l = full(r, c);
            let (da, dp) = discrepancy_pct(&l);
            assert!(da < 1.5, "{r}x{c} area discrepancy {da}%");
            assert!(dp < 1.5, "{r}x{c} power discrepancy {dp}%");
        }
    }

    #[test]
    fn synthesis_not_identical_to_estimate() {
        // non-circularity: the two estimators must not agree exactly.
        let l = full(8, 8);
        let s = synthesize(&l);
        let e = helex_estimate(&l);
        assert!((s.area_um2 - e.area_um2).abs() > 1.0);
        assert!((s.power_uw - e.power_uw).abs() > 1.0);
    }

    #[test]
    fn area_magnitude_matches_paper() {
        // Table V: 8x8 full ≈ 2.12e6 µm²; ours should land within ~5%.
        let l = full(8, 8);
        let s = synthesize(&l);
        assert!(
            (s.area_um2 - 2.12e6).abs() / 2.12e6 < 0.05,
            "8x8 area {} vs 2.12e6",
            s.area_um2
        );
    }

    #[test]
    fn default_fabric_adds_exactly_nothing() {
        use crate::fabric::Fabric;
        let grid = Grid::new(8, 8);
        let legacy = Layout::full(grid, GroupSet::all_compute());
        let explicit = Layout::full_on(Fabric::mesh4(grid), GroupSet::all_compute());
        let (a, b) = (synthesize(&legacy), synthesize(&explicit));
        assert_eq!(a.area_um2.to_bits(), b.area_um2.to_bits());
        assert_eq!(a.power_uw.to_bits(), b.power_uw.to_bits());
    }

    #[test]
    fn richer_fabrics_cost_more() {
        use crate::fabric::{Fabric, FabricSpec, Topology};
        let grid = Grid::new(8, 8);
        let mesh4 = synthesize(&Layout::full(grid, GroupSet::all_compute()));
        for spec in [
            FabricSpec { topology: Topology::Mesh8, ..FabricSpec::default() },
            FabricSpec { topology: Topology::Express { stride: 2 }, ..FabricSpec::default() },
            FabricSpec { link_cap: 2, ..FabricSpec::default() },
        ] {
            let l = Layout::full_on(Fabric::new(grid, spec), GroupSet::all_compute());
            let s = synthesize(&l);
            assert!(s.area_um2 > mesh4.area_um2, "{}: area must rise", spec.describe());
            assert!(s.power_uw > mesh4.power_uw, "{}: power must rise", spec.describe());
            // the surcharge is a small overlay, not a rebasing
            assert!(s.area_um2 < mesh4.area_um2 * 1.10, "{}: surcharge too big", spec.describe());
        }
    }

    #[test]
    fn hetero_cheaper_than_full() {
        let l = full(8, 8);
        let mut hetero = l.clone();
        for c in hetero.grid.compute_cells().collect::<Vec<_>>() {
            hetero.set_support(
                c,
                GroupSet::from_groups(&[crate::ops::OpGroup::Arith, crate::ops::OpGroup::Mult]),
            );
        }
        let sf = synthesize(&l);
        let sh = synthesize(&hetero);
        assert!(sh.area_um2 < sf.area_um2);
        assert!(sh.power_uw < sf.power_uw);
        // improvement roughly consistent across both estimators (±2pp)
        let ef = helex_estimate(&l);
        let eh = helex_estimate(&hetero);
        let impr_s = 100.0 * (1.0 - sh.area_um2 / sf.area_um2);
        let impr_e = 100.0 * (1.0 - eh.area_um2 / ef.area_um2);
        assert!((impr_s - impr_e).abs() < 2.0, "{impr_s} vs {impr_e}");
    }
}
