//! Placement: assign every DFG node a cell.
//!
//! Loads are spread evenly around the border (rotation jittered per
//! attempt), compute nodes are placed in topological order on the
//! compatible free interior cell closest to their placed predecessors,
//! stores drain to the border cell nearest their producer.
//!
//! The engine drives [`place`] through its placement strategy; it is
//! equally usable standalone:
//!
//! ```
//! use helex::cgra::{Grid, Layout};
//! use helex::dfg::Dfg;
//! use helex::mapper::place::place;
//! use helex::ops::{GroupSet, Op};
//! use helex::util::rng::Rng;
//!
//! let dfg = Dfg::new("pipe", vec![Op::Load, Op::Add, Op::Store], vec![(0, 1), (1, 2)]);
//! let layout = Layout::full(Grid::new(5, 5), GroupSet::all_compute());
//! let cells = place(&dfg, &layout, &[], &mut Rng::seed(7)).expect("a 5x5 grid fits 3 nodes");
//!
//! assert_eq!(cells.len(), dfg.num_nodes());
//! // Load and Store land on I/O border cells, the Add on a compute cell.
//! assert!(layout.grid.is_io(cells[0]) && layout.grid.is_io(cells[2]));
//! assert!(layout.grid.is_compute(cells[1]));
//! ```

use crate::cgra::{CellId, Layout};
use crate::dfg::Dfg;
use crate::ops::Op;
use crate::util::rng::Rng;

/// Active I/O border cells in clockwise order starting at the top-left
/// corner. Border cells on fabric-disabled sides (I/O mask) or masked
/// out entirely are skipped — on the default fabric this is every
/// border cell, exactly as before.
pub fn border_clockwise(layout: &Layout) -> Vec<CellId> {
    let g = &layout.grid;
    let f = layout.fabric();
    let (rows, cols) = (g.rows, g.cols);
    let mut out = Vec::with_capacity(f.num_active_io());
    let mut push = |cell: CellId| {
        if f.is_active_io(cell) {
            out.push(cell);
        }
    };
    for c in 0..cols {
        push(g.cell(0, c));
    }
    for r in 1..rows {
        push(g.cell(r, cols - 1));
    }
    for c in (0..cols - 1).rev() {
        push(g.cell(rows - 1, c));
    }
    for r in (1..rows - 1).rev() {
        push(g.cell(r, 0));
    }
    debug_assert_eq!(out.len(), f.num_active_io());
    out
}

/// Place all nodes. Returns `node -> cell` or `None` if some node has no
/// compatible free cell.
pub fn place(
    dfg: &Dfg,
    layout: &Layout,
    reserved: &[CellId],
    rng: &mut Rng,
) -> Option<Vec<CellId>> {
    let g = &layout.grid;
    let f = layout.fabric();
    let n = dfg.num_nodes();
    let mut cell_of = vec![u16::MAX; n];
    let mut occupied = vec![false; g.num_cells()];
    for &r in reserved {
        occupied[r as usize] = true;
    }

    let preds = dfg.preds();
    let order = dfg.topo_order()?;

    // --- loads: spread around the border ---
    let border = border_clockwise(layout);
    let loads: Vec<usize> = (0..n).filter(|&i| dfg.nodes[i] == Op::Load).collect();
    if !loads.is_empty() {
        if border.is_empty() {
            return None; // every I/O side disabled or masked away
        }
        let rot = rng.below(border.len());
        let stride = border.len() as f64 / loads.len() as f64;
        for (k, &ld) in loads.iter().enumerate() {
            let want = (rot + (k as f64 * stride) as usize) % border.len();
            // next free border slot from the wanted position
            let mut placed = false;
            for off in 0..border.len() {
                let cand = border[(want + off) % border.len()];
                if !occupied[cand as usize] {
                    occupied[cand as usize] = true;
                    cell_of[ld] = cand;
                    placed = true;
                    break;
                }
            }
            if !placed {
                return None; // more loads than border cells
            }
        }
    }

    // --- compute nodes in topo order ---
    let center = g.cell(g.rows / 2, g.cols / 2);
    for &u in &order {
        let u = u as usize;
        let op = dfg.nodes[u];
        if op.is_memory() {
            continue;
        }
        let group = op.group();
        let mut best: Option<(f64, CellId)> = None;
        for cand in g.compute_cells() {
            if occupied[cand as usize] || !layout.supports(cand, group) {
                continue;
            }
            let mut score = 0.0;
            let mut have_pred = false;
            for &p in &preds[u] {
                let pc = cell_of[p as usize];
                if pc != u16::MAX {
                    score += f.min_hops(cand, pc) as f64;
                    have_pred = true;
                }
            }
            if !have_pred {
                // root-ish node: bias toward the border side where loads
                // sit lightly (distance to center as mild repulsion)
                score = f.min_hops(cand, center) as f64 * 0.25;
            }
            // deterministic jitter to diversify attempts
            score += rng.f64() * 0.01;
            if best.map_or(true, |(bs, _)| score < bs) {
                best = Some((score, cand));
            }
        }
        let (_, cell) = best?;
        occupied[cell as usize] = true;
        cell_of[u] = cell;
    }

    // --- stores: nearest free border cell to their producer ---
    for (u, op) in dfg.nodes.iter().enumerate() {
        if *op != Op::Store {
            continue;
        }
        let pc = preds[u].first().map(|&p| cell_of[p as usize]);
        let mut best: Option<(usize, CellId)> = None;
        for &cand in &border {
            if occupied[cand as usize] {
                continue;
            }
            let d = pc.map_or(0, |p| f.min_hops(cand, p));
            if best.map_or(true, |(bd, bc)| d < bd || (d == bd && cand < bc)) {
                best = Some((d, cand));
            }
        }
        let (_, cell) = best?;
        occupied[cell as usize] = true;
        cell_of[u] = cell;
    }

    debug_assert!(cell_of.iter().all(|&c| c != u16::MAX));
    Some(cell_of)
}

/// Warm-start re-placement: assign new cells to only the `displaced`
/// nodes, keeping every other node where `cell_of` already puts it.
/// `occupied` must mark reserved cells and the cells of all
/// non-displaced nodes (the displaced nodes' old cells are free).
///
/// Unlike cold placement, a displaced node's *successors* are fixed too,
/// so both predecessors and successors anchor the choice: each node goes
/// to the free compatible cell minimising total manhattan distance to its
/// already-placed neighbours (deterministic; ties resolve to the lowest
/// cell id). Returns `false` when some node has no compatible free cell.
pub fn replace_displaced(
    dfg: &Dfg,
    layout: &Layout,
    cell_of: &mut [CellId],
    displaced: &[usize],
    occupied: &mut [bool],
) -> bool {
    let g = &layout.grid;
    let f = layout.fabric();
    let preds = dfg.preds();
    let succs = dfg.succs();
    let mut pending = vec![false; dfg.num_nodes()];
    for &n in displaced {
        pending[n] = true;
    }
    // topological order among the displaced nodes, so re-placed
    // predecessors anchor their re-placed consumers
    let Some(order) = dfg.topo_order() else { return false };
    for u in order {
        let u = u as usize;
        if !pending[u] {
            continue;
        }
        let group = dfg.nodes[u].group();
        let old = cell_of[u];
        let mut best: Option<(f64, CellId)> = None;
        for cand in g.compute_cells() {
            if occupied[cand as usize] || !layout.supports(cand, group) {
                continue;
            }
            let mut score = 0.0;
            let mut anchors = 0usize;
            for &v in preds[u].iter().chain(succs[u].iter()) {
                if !pending[v as usize] {
                    score += f.min_hops(cand, cell_of[v as usize]) as f64;
                    anchors += 1;
                }
            }
            if anchors == 0 {
                // no fixed neighbour yet: stay close to the old spot
                score = f.min_hops(cand, old) as f64;
            }
            if best.map_or(true, |(bs, _)| score < bs) {
                best = Some((score, cand));
            }
        }
        let Some((_, cell)) = best else { return false };
        occupied[cell as usize] = true;
        cell_of[u] = cell;
        pending[u] = false;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::dfg::benchmarks;
    use crate::ops::GroupSet;

    #[test]
    fn border_clockwise_covers_all_io_once() {
        let l = Layout::full(Grid::new(5, 7), GroupSet::all_compute());
        let b = border_clockwise(&l);
        let mut set: Vec<CellId> = b.clone();
        set.sort_unstable();
        set.dedup();
        assert_eq!(set.len(), b.len());
        assert_eq!(b.len(), l.grid.num_io());
        for c in &b {
            assert!(l.grid.is_io(*c));
        }
    }

    #[test]
    fn border_clockwise_respects_the_io_mask() {
        use crate::fabric::{Fabric, FabricSpec, SIDE_N, SIDE_S};
        let spec = FabricSpec { io_mask: SIDE_N | SIDE_S, ..FabricSpec::default() };
        let l = Layout::full_on(Fabric::new(Grid::new(5, 7), spec), GroupSet::all_compute());
        let b = border_clockwise(&l);
        assert_eq!(b.len(), l.fabric().num_active_io());
        for &c in &b {
            let r = c as usize / l.grid.cols;
            assert!(r == 0 || r == l.grid.rows - 1, "cell {c} not on an enabled side");
        }
        // disabled-side cells are gone but the full-mask count is intact
        let full = border_clockwise(&Layout::full(Grid::new(5, 7), GroupSet::all_compute()));
        assert!(b.len() < full.len());
        assert_eq!(full.len(), Grid::new(5, 7).num_io());
    }

    #[test]
    fn placement_respects_kinds_and_support() {
        let d = benchmarks::benchmark("NMS");
        let l = Layout::full(Grid::new(9, 9), d.groups_used());
        let mut rng = Rng::seed(1);
        let p = place(&d, &l, &[], &mut rng).unwrap();
        let mut seen = std::collections::HashSet::new();
        for (i, op) in d.nodes.iter().enumerate() {
            assert!(seen.insert(p[i]), "cell reuse");
            if op.is_memory() {
                assert!(l.grid.is_io(p[i]));
            } else {
                assert!(l.grid.is_compute(p[i]));
                assert!(l.supports(p[i], op.group()));
            }
        }
    }

    #[test]
    fn placement_avoids_reserved_cells() {
        let d = benchmarks::benchmark("SOB");
        let l = Layout::full(Grid::new(5, 5), d.groups_used());
        let reserved: Vec<CellId> = vec![l.grid.cell(1, 1), l.grid.cell(2, 2)];
        let mut rng = Rng::seed(2);
        if let Some(p) = place(&d, &l, &reserved, &mut rng) {
            for c in p {
                assert!(!reserved.contains(&c));
            }
        }
        // 9 compute cells minus 2 reserved = 7 >= 4 compute ops, so it
        // should actually succeed:
        let mut rng = Rng::seed(2);
        assert!(place(&d, &l, &reserved, &mut rng).is_some());
    }

    #[test]
    fn placement_fails_gracefully_when_full() {
        let d = benchmarks::benchmark("SAD"); // 63 compute ops
        let l = Layout::full(Grid::new(6, 6), d.groups_used()); // 16 compute
        let mut rng = Rng::seed(3);
        assert!(place(&d, &l, &[], &mut rng).is_none());
    }

    #[test]
    fn replace_displaced_keeps_fixed_nodes_and_respects_support() {
        let d = benchmarks::benchmark("SOB");
        let l = Layout::full(Grid::new(6, 6), d.groups_used());
        let mut rng = Rng::seed(7);
        let mut cells = place(&d, &l, &[], &mut rng).unwrap();
        let before = cells.clone();
        // displace the first two compute nodes
        let displaced: Vec<usize> = d
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, op)| !op.is_memory())
            .map(|(i, _)| i)
            .take(2)
            .collect();
        let mut occupied = vec![false; l.grid.num_cells()];
        for (i, &c) in cells.iter().enumerate() {
            if !displaced.contains(&i) {
                occupied[c as usize] = true;
            }
        }
        assert!(replace_displaced(&d, &l, &mut cells, &displaced, &mut occupied));
        let mut seen = std::collections::HashSet::new();
        for (i, &c) in cells.iter().enumerate() {
            assert!(seen.insert(c), "cell reuse at node {i}");
            if displaced.contains(&i) {
                assert!(l.grid.is_compute(c));
                assert!(l.supports(c, d.nodes[i].group()));
            } else {
                assert_eq!(c, before[i], "fixed node {i} moved");
            }
        }
    }

    #[test]
    fn replace_displaced_fails_when_no_support_left() {
        let d = benchmarks::benchmark("SOB");
        let l = Layout::full(Grid::new(6, 6), d.groups_used());
        let mut rng = Rng::seed(9);
        let mut cells = place(&d, &l, &[], &mut rng).unwrap();
        let victim =
            (0..d.num_nodes()).find(|&i| !d.nodes[i].is_memory()).unwrap();
        // strip the victim's group everywhere
        let mut crippled = l.clone();
        for c in crippled.grid.compute_cells().collect::<Vec<_>>() {
            let s = crippled.support(c).without(d.nodes[victim].group());
            crippled.set_support(c, s);
        }
        let mut occupied = vec![false; l.grid.num_cells()];
        for (i, &c) in cells.iter().enumerate() {
            if i != victim {
                occupied[c as usize] = true;
            }
        }
        assert!(!replace_displaced(&d, &crippled, &mut cells, &[victim], &mut occupied));
    }

    #[test]
    fn loads_spread_on_border() {
        let d = benchmarks::benchmark("SAD"); // 16 loads
        let l = Layout::full(Grid::new(12, 12), d.groups_used());
        let mut rng = Rng::seed(4);
        let p = place(&d, &l, &[], &mut rng).unwrap();
        let load_cells: Vec<CellId> = d
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, o)| **o == Op::Load)
            .map(|(i, _)| p[i])
            .collect();
        // all distinct border cells
        let mut s = load_cells.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 16);
    }
}
