//! The `MappingEngine`: pluggable place/route strategies, structured
//! [`MapOutcome`]s, and incremental warm-start remapping.
//!
//! The engine decomposes one map attempt into a [`PlacementStrategy`]
//! and a [`RoutingStrategy`] joined by the reserve-on-demand driver loop,
//! so alternative placers/routers (simulated-annealing placement, ILP
//! routing, ...) slot in without forking the engine. Two routers ship
//! in-tree: the default [`PathFinderRouter`] (legacy edge-by-edge
//! negotiation, byte-identical traces) and the opt-in [`SteinerRouter`]
//! (shared-trunk multi-fanout trees over an engine-owned scratch arena;
//! `MapperConfig::router_steiner`). Every request resolves to a
//! [`MapOutcome`]: success carries the [`Mapping`] plus attempt
//! statistics, failure carries a structured [`MapFailure`] (which group
//! ran out of capacity, which links stayed congested, or that placement
//! was exhausted) instead of a bare `None`.
//!
//! ## Warm-start remapping
//!
//! The search tests candidate layouts that differ from an already-mapped
//! layout by a single support removal, so [`MappingEngine::remap_from`]
//! keeps the witness mapping fixed, re-places only the nodes displaced
//! by the removal ([`place::replace_displaced`]) and
//! rip-up-reroutes only their incident edges
//! ([`route::route_partial`]), falling back to from-scratch mapping when
//! the incremental path cannot close. A per-DFG feasibility cache keyed
//! by (DFG, layout) fingerprints short-circuits repeated tests of the
//! same candidate.

use super::place;
use super::route::{self, RouteOutcome};
use super::{Mapper, MapperConfig, Mapping};
use crate::cgra::{CellId, CellSet, Layout};
use crate::dfg::Dfg;
use crate::ops::{OpGroup, COMPUTE_GROUPS};
use crate::util::rng::Rng;
use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::hash::{Hash, Hasher};

/// Places every DFG node on a cell of the layout, avoiding `reserved`
/// cells. Implementations must be deterministic for a given `rng` state.
///
/// `Send` because the search's parallel candidate testing moves forked
/// engines (see [`MappingEngine::fork`]) onto worker threads.
pub trait PlacementStrategy: Send {
    fn name(&self) -> &'static str;
    fn place(
        &self,
        dfg: &Dfg,
        layout: &Layout,
        reserved: &[CellId],
        rng: &mut Rng,
    ) -> Option<Vec<CellId>>;

    /// Clone this strategy for a forked engine ([`MappingEngine::fork`]):
    /// each parallel search worker owns an engine, so strategies must be
    /// duplicable. Stateless strategies just re-box themselves.
    fn clone_box(&self) -> Box<dyn PlacementStrategy>;
}

/// Routes every DFG edge over the switch network for a fixed placement.
///
/// `Send` + [`Self::clone_box`] for the same reason as
/// [`PlacementStrategy`]: forked engines move onto search worker threads.
pub trait RoutingStrategy: Send {
    fn name(&self) -> &'static str;
    fn route(
        &self,
        dfg: &Dfg,
        layout: &Layout,
        placement: &[CellId],
        cfg: &MapperConfig,
    ) -> RouteOutcome;

    /// Clone this strategy for a forked engine ([`MappingEngine::fork`]).
    fn clone_box(&self) -> Box<dyn RoutingStrategy>;

    /// Re-route only `affected` edges, keeping the other entries of
    /// `fixed_paths` pinned. The default falls back to full routing (a
    /// strategy without incremental support still works, just slower).
    fn route_partial(
        &self,
        dfg: &Dfg,
        layout: &Layout,
        placement: &[CellId],
        fixed_paths: &[Vec<CellId>],
        affected: &[usize],
        cfg: &MapperConfig,
    ) -> Option<Vec<Vec<CellId>>> {
        let _ = (fixed_paths, affected);
        match self.route(dfg, layout, placement, cfg) {
            RouteOutcome::Routed(paths) => Some(paths),
            RouteOutcome::Congested { .. } => None,
        }
    }
}

/// The default placer: loads spread around the border, compute nodes
/// greedily placed in topological order, stores drained to the border
/// (see [`place::place`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct GreedyTopoPlacer;

impl PlacementStrategy for GreedyTopoPlacer {
    fn name(&self) -> &'static str {
        "greedy-topo"
    }

    fn place(
        &self,
        dfg: &Dfg,
        layout: &Layout,
        reserved: &[CellId],
        rng: &mut Rng,
    ) -> Option<Vec<CellId>> {
        place::place(dfg, layout, reserved, rng)
    }

    fn clone_box(&self) -> Box<dyn PlacementStrategy> {
        Box::new(*self)
    }
}

/// The default router: negotiated-congestion (PathFinder-style) A* over
/// the layout's provisioned switch network — the 4NN mesh by default
/// (see [`route::route`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct PathFinderRouter;

impl RoutingStrategy for PathFinderRouter {
    fn name(&self) -> &'static str {
        "pathfinder"
    }

    fn route(
        &self,
        dfg: &Dfg,
        layout: &Layout,
        placement: &[CellId],
        cfg: &MapperConfig,
    ) -> RouteOutcome {
        route::route(dfg, layout, placement, cfg)
    }

    fn route_partial(
        &self,
        dfg: &Dfg,
        layout: &Layout,
        placement: &[CellId],
        fixed_paths: &[Vec<CellId>],
        affected: &[usize],
        cfg: &MapperConfig,
    ) -> Option<Vec<Vec<CellId>>> {
        route::route_partial(dfg, layout, placement, fixed_paths, affected, cfg)
    }

    fn clone_box(&self) -> Box<dyn RoutingStrategy> {
        Box::new(*self)
    }
}

/// The opt-in Steiner multi-fanout router
/// (`MapperConfig::router_steiner`): edges sharing a source node form
/// one net, routed as a shared-trunk Steiner tree grown by nearest-sink
/// attachment ([`route::steiner_route`]), with optional per-net
/// criticality weighting of the congestion negotiation
/// (`MapperConfig::router_criticality`). See `docs/ROUTER.md`.
///
/// Owns a [`route::RouterArena`] — the generation-stamped A* scratch
/// and occupancy tables — reused across every route this engine
/// performs; [`Self::clone_box`] (and therefore
/// [`MappingEngine::fork`]) hands each parallel search worker a fresh
/// arena, so scratch is never shared across threads.
///
/// Its `route_partial` is *net-granular*: nets with no affected edge
/// stay pinned, nets touching one are ripped up and re-grown whole (a
/// shared trunk cannot be repaired one branch at a time).
#[derive(Default)]
pub struct SteinerRouter {
    arena: RefCell<route::RouterArena>,
}

impl SteinerRouter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Route with rip-up accounting — negotiation rounds consumed —
    /// used by the `route::steiner` bench.
    pub fn route_rounds(
        &self,
        dfg: &Dfg,
        layout: &Layout,
        placement: &[CellId],
        cfg: &MapperConfig,
    ) -> (RouteOutcome, usize) {
        route::steiner_route_rounds(dfg, layout, placement, cfg, &mut self.arena.borrow_mut())
    }
}

impl RoutingStrategy for SteinerRouter {
    fn name(&self) -> &'static str {
        "steiner"
    }

    fn route(
        &self,
        dfg: &Dfg,
        layout: &Layout,
        placement: &[CellId],
        cfg: &MapperConfig,
    ) -> RouteOutcome {
        route::steiner_route(dfg, layout, placement, cfg, &mut self.arena.borrow_mut())
    }

    fn route_partial(
        &self,
        dfg: &Dfg,
        layout: &Layout,
        placement: &[CellId],
        fixed_paths: &[Vec<CellId>],
        affected: &[usize],
        cfg: &MapperConfig,
    ) -> Option<Vec<Vec<CellId>>> {
        route::steiner_route_partial(
            dfg,
            layout,
            placement,
            fixed_paths,
            affected,
            cfg,
            &mut self.arena.borrow_mut(),
        )
    }

    fn clone_box(&self) -> Box<dyn RoutingStrategy> {
        Box::new(SteinerRouter::new())
    }
}

/// Why a map request failed. Carried by [`MapOutcome::Failed`] so that
/// consumers (search diagnostics, provisioning-aware tooling, the CLI)
/// can act on *why*, not just *that*, a mapping failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MapFailure {
    /// The layout cannot supply enough instances of `group`: the DFG
    /// demands `demand` cells supporting it but only `capacity` exist.
    /// (For [`OpGroup::Mem`] the capacity is the I/O cell count.)
    UnsupportedGroup { group: OpGroup, demand: usize, capacity: usize },
    /// Routing never converged; `hot_links` are the overused link ids of
    /// the final negotiation round (hottest first) and `overuse` the best
    /// total overuse seen.
    Congested { hot_links: Vec<usize>, overuse: usize },
    /// No placement satisfied the layout (too few compatible free cells,
    /// possibly after reservations ate the slack).
    PlacementExhausted,
}

impl fmt::Display for MapFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MapFailure::UnsupportedGroup { group, demand, capacity } => {
                write!(f, "unsupported group {group}: demand {demand} > capacity {capacity}")
            }
            MapFailure::Congested { hot_links, overuse } => {
                write!(f, "congested: {} hot links, overuse {overuse}", hot_links.len())
            }
            MapFailure::PlacementExhausted => write!(f, "placement exhausted"),
        }
    }
}

/// Effort accounting of one map request.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MapStats {
    /// Cold placement attempts consumed.
    pub attempts: usize,
    /// Reserve-on-demand reservations tried across all attempts.
    pub reserves: usize,
    /// The warm-start (incremental) path produced the result.
    pub warm: bool,
    /// The result was served from the feasibility cache.
    pub cached: bool,
}

/// Resolution of a [`MapRequest`]: the structured replacement for the
/// old `Option<Mapping>`.
#[derive(Debug, Clone)]
pub enum MapOutcome {
    Mapped { mapping: Mapping, stats: MapStats },
    Failed { failure: MapFailure, stats: MapStats },
}

impl MapOutcome {
    pub fn is_mapped(&self) -> bool {
        matches!(self, MapOutcome::Mapped { .. })
    }

    pub fn mapping(&self) -> Option<&Mapping> {
        match self {
            MapOutcome::Mapped { mapping, .. } => Some(mapping),
            MapOutcome::Failed { .. } => None,
        }
    }

    /// Consume the outcome into the legacy `Option<Mapping>` shape (used
    /// by the deprecated [`Mapper`] wrappers).
    pub fn into_mapping(self) -> Option<Mapping> {
        match self {
            MapOutcome::Mapped { mapping, .. } => Some(mapping),
            MapOutcome::Failed { .. } => None,
        }
    }

    pub fn failure(&self) -> Option<&MapFailure> {
        match self {
            MapOutcome::Mapped { .. } => None,
            MapOutcome::Failed { failure, .. } => Some(failure),
        }
    }

    pub fn stats(&self) -> &MapStats {
        match self {
            MapOutcome::Mapped { stats, .. } | MapOutcome::Failed { stats, .. } => stats,
        }
    }
}

/// One map request: a DFG, a target layout, and optionally a witness
/// mapping from a predecessor layout enabling the warm-start path.
#[derive(Clone, Copy)]
pub struct MapRequest<'a> {
    pub dfg: &'a Dfg,
    pub layout: &'a Layout,
    /// Witness from a predecessor layout (same grid); when set, the
    /// engine re-places only displaced nodes and reroutes only their
    /// incident edges before falling back to from-scratch mapping.
    pub warm_start: Option<&'a Mapping>,
}

impl<'a> MapRequest<'a> {
    pub fn new(dfg: &'a Dfg, layout: &'a Layout) -> Self {
        Self { dfg, layout, warm_start: None }
    }

    pub fn warm_start(mut self, witness: &'a Mapping) -> Self {
        self.warm_start = Some(witness);
        self
    }
}

/// A whole-set map failure: which DFG failed and why.
#[derive(Debug, Clone)]
pub struct MapSetFailure {
    pub dfg_index: usize,
    pub dfg_name: String,
    pub failure: MapFailure,
}

impl fmt::Display for MapSetFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.dfg_name, self.failure)
    }
}

/// Reserve-on-demand abandonment accounting: reservations that do not
/// reduce congestion earn strikes; [`RESERVE_STRIKE_LIMIT`] consecutive
/// non-improving observations abandon the placement attempt (perf:
/// avoids burning the whole reserve budget on hopeless placements).
#[derive(Debug, Clone)]
pub(crate) struct StrikeCounter {
    best: usize,
    strikes: usize,
    limit: usize,
}

/// A placement attempt is abandoned on the `RESERVE_STRIKE_LIMIT`-th
/// consecutive non-improving reserve observation (so `LIMIT - 1` such
/// rounds are tolerated; an improvement resets the count).
pub const RESERVE_STRIKE_LIMIT: usize = 3;

impl StrikeCounter {
    pub(crate) fn new(limit: usize) -> Self {
        Self { best: usize::MAX, strikes: 0, limit }
    }

    /// Record a congestion observation; returns true when the attempt
    /// should be abandoned. Improvements reset the strike count.
    pub(crate) fn observe(&mut self, overuse: usize) -> bool {
        if overuse < self.best {
            self.best = overuse;
            self.strikes = 0;
            false
        } else {
            self.strikes += 1;
            self.strikes >= self.limit
        }
    }
}

/// Feasibility-cache entry: a proof either way for one (DFG, layout)
/// pair under this engine's configuration.
#[derive(Debug, Clone)]
enum CacheEntry {
    Feasible(Mapping),
    /// Recorded only by the cold path: the warm path may still succeed
    /// where from-scratch mapping failed, so warm requests ignore this.
    Infeasible(MapFailure),
}

/// Hard cap on cached (DFG, layout) pairs; the cache resets when full
/// (simple and good enough: search sessions rarely exceed it).
const CACHE_CAP: usize = 1 << 16;

/// The mapping engine. See the module docs.
pub struct MappingEngine {
    pub cfg: MapperConfig,
    placer: Box<dyn PlacementStrategy>,
    router: Box<dyn RoutingStrategy>,
    cache: RefCell<HashMap<(u64, u64), CacheEntry>>,
}

impl Default for MappingEngine {
    fn default() -> Self {
        Self::new(MapperConfig::default())
    }
}

impl fmt::Debug for MappingEngine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("MappingEngine")
            .field("cfg", &self.cfg)
            .field("placer", &self.placer.name())
            .field("router", &self.router.name())
            .finish()
    }
}

impl MappingEngine {
    /// Engine with the configured strategies: [`GreedyTopoPlacer`] plus
    /// the router `cfg` selects — the legacy edge-by-edge
    /// [`PathFinderRouter`] by default, the [`SteinerRouter`] when
    /// `cfg.router_steiner` is set.
    pub fn new(cfg: MapperConfig) -> Self {
        let router: Box<dyn RoutingStrategy> = if cfg.router_steiner {
            Box::new(SteinerRouter::new())
        } else {
            Box::new(PathFinderRouter)
        };
        Self::with_strategies(cfg, Box::new(GreedyTopoPlacer), router)
    }

    /// Engine with custom strategies.
    pub fn with_strategies(
        cfg: MapperConfig,
        placer: Box<dyn PlacementStrategy>,
        router: Box<dyn RoutingStrategy>,
    ) -> Self {
        Self { cfg, placer, router, cache: RefCell::new(HashMap::new()) }
    }

    /// Engine sharing the deprecated [`Mapper`]'s configuration.
    pub fn from_mapper(mapper: &Mapper) -> Self {
        Self::new(mapper.cfg.clone())
    }

    /// Cheap clone for a parallel worker: the same configuration and
    /// strategies, but a fresh (empty) feasibility cache. The search's
    /// worker pool ([`crate::search::parallel::TestPool`]) forks one
    /// engine per thread so every cache stays thread-local and lock-free
    /// on the mapping hot path.
    pub fn fork(&self) -> MappingEngine {
        Self::with_strategies(self.cfg.clone(), self.placer.clone_box(), self.router.clone_box())
    }

    pub fn placer_name(&self) -> &'static str {
        self.placer.name()
    }

    pub fn router_name(&self) -> &'static str {
        self.router.name()
    }

    /// Entries currently held by the feasibility cache.
    pub fn cache_len(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Map one DFG onto a layout from scratch.
    pub fn map(&self, dfg: &Dfg, layout: &Layout) -> MapOutcome {
        self.run(MapRequest::new(dfg, layout))
    }

    /// Incremental warm-start remapping: keep `witness` (a valid mapping
    /// on a predecessor layout of the same grid) fixed, re-place only the
    /// nodes displaced by support removal and reroute only their incident
    /// edges. Falls back to from-scratch mapping when the incremental
    /// path cannot close, so `remap_from` succeeds whenever [`Self::map`]
    /// would.
    pub fn remap_from(&self, witness: &Mapping, dfg: &Dfg, layout: &Layout) -> MapOutcome {
        self.run(MapRequest::new(dfg, layout).warm_start(witness))
    }

    /// Resolve a [`MapRequest`].
    pub fn run(&self, req: MapRequest) -> MapOutcome {
        let key = self.cache_key(req.dfg, req.layout);
        if let Some(k) = key {
            match self.cache.borrow().get(&k) {
                Some(CacheEntry::Feasible(m)) => {
                    return MapOutcome::Mapped {
                        mapping: m.clone(),
                        stats: MapStats { cached: true, ..MapStats::default() },
                    };
                }
                // a cached cold failure only settles cold requests: a
                // warm start may still close where from-scratch failed
                Some(CacheEntry::Infeasible(fail)) if req.warm_start.is_none() => {
                    return MapOutcome::Failed {
                        failure: fail.clone(),
                        stats: MapStats { cached: true, ..MapStats::default() },
                    };
                }
                _ => {}
            }
        }

        let mut stats = MapStats::default();
        if let Some(w) = req.warm_start {
            if let Some(mapping) = self.try_warm(w, req.dfg, req.layout) {
                stats.warm = true;
                self.cache_store(key, CacheEntry::Feasible(mapping.clone()));
                return MapOutcome::Mapped { mapping, stats };
            }
            // warm path failed; reuse a cached cold verdict if one exists
            if let Some(k) = key {
                if let Some(CacheEntry::Infeasible(fail)) = self.cache.borrow().get(&k) {
                    return MapOutcome::Failed {
                        failure: fail.clone(),
                        stats: MapStats { cached: true, ..stats },
                    };
                }
            }
        }

        match self.map_cold(req.dfg, req.layout, &mut stats) {
            Ok(mapping) => {
                self.cache_store(key, CacheEntry::Feasible(mapping.clone()));
                MapOutcome::Mapped { mapping, stats }
            }
            Err(failure) => {
                self.cache_store(key, CacheEntry::Infeasible(failure.clone()));
                MapOutcome::Failed { failure, stats }
            }
        }
    }

    /// Map all DFGs, returning every mapping or the first failure.
    pub fn map_all(&self, dfgs: &[Dfg], layout: &Layout) -> Result<Vec<Mapping>, MapSetFailure> {
        let mut out = Vec::with_capacity(dfgs.len());
        for (di, d) in dfgs.iter().enumerate() {
            match self.map(d, layout) {
                MapOutcome::Mapped { mapping, .. } => out.push(mapping),
                MapOutcome::Failed { failure, .. } => {
                    return Err(MapSetFailure {
                        dfg_index: di,
                        dfg_name: d.name.clone(),
                        failure,
                    });
                }
            }
        }
        Ok(out)
    }

    /// The paper's `testLayout`: do *all* DFGs map? Short-circuits on the
    /// first failure.
    pub fn test_layout(&self, dfgs: &[Dfg], layout: &Layout) -> bool {
        dfgs.iter().all(|d| self.map(d, layout).is_mapped())
    }

    // ---- internals ----

    fn cache_key(&self, dfg: &Dfg, layout: &Layout) -> Option<(u64, u64)> {
        if !self.cfg.feasibility_cache {
            return None;
        }
        let mut h = std::collections::hash_map::DefaultHasher::new();
        dfg.name.hash(&mut h);
        dfg.nodes.hash(&mut h);
        dfg.edges.hash(&mut h);
        let dk = h.finish();
        let mut h = std::collections::hash_map::DefaultHasher::new();
        layout.hash(&mut h);
        Some((dk, h.finish()))
    }

    fn cache_store(&self, key: Option<(u64, u64)>, entry: CacheEntry) {
        if let Some(k) = key {
            let mut cache = self.cache.borrow_mut();
            if cache.len() >= CACHE_CAP {
                cache.clear();
            }
            cache.insert(k, entry);
        }
    }

    /// Necessary-condition precheck, cheap relative to placement: per
    /// group, the DFG's demand must not exceed the layout's cell count
    /// supporting it. Failing this yields the structured
    /// [`MapFailure::UnsupportedGroup`] diagnostic without touching the
    /// placer.
    fn precheck(dfg: &Dfg, layout: &Layout) -> Option<MapFailure> {
        let demand = dfg.group_histogram();
        let mem = demand[OpGroup::Mem.index()];
        let io_capacity = layout.fabric().num_active_io();
        if mem > io_capacity {
            return Some(MapFailure::UnsupportedGroup {
                group: OpGroup::Mem,
                demand: mem,
                capacity: io_capacity,
            });
        }
        for g in COMPUTE_GROUPS {
            let need = demand[g.index()];
            if need == 0 {
                continue;
            }
            let capacity =
                layout.grid.compute_cells().filter(|&c| layout.supports(c, g)).count();
            if need > capacity {
                return Some(MapFailure::UnsupportedGroup { group: g, demand: need, capacity });
            }
        }
        if dfg.compute_ops() > layout.grid.num_compute() {
            return Some(MapFailure::PlacementExhausted);
        }
        None
    }

    /// From-scratch place-and-route with the reserve-on-demand loop.
    fn map_cold(
        &self,
        dfg: &Dfg,
        layout: &Layout,
        stats: &mut MapStats,
    ) -> Result<Mapping, MapFailure> {
        if let Some(fail) = Self::precheck(dfg, layout) {
            return Err(fail);
        }
        // the least-congested routing failure across attempts, reported
        // when every attempt stays congested
        let mut best_congestion: Option<(Vec<usize>, usize)> = None;
        for attempt in 0..self.cfg.placement_attempts {
            stats.attempts += 1;
            let mut rng = Rng::seed(self.cfg.seed ^ (attempt as u64).wrapping_mul(0x9E37));
            let mut reserved: Vec<CellId> = Vec::new();
            let mut reserved_set = CellSet::new(layout.grid.num_cells());
            // placement; retried after each new reservation, abandoned
            // when reserves stop reducing congestion (StrikeCounter).
            let mut strikes = StrikeCounter::new(RESERVE_STRIKE_LIMIT);
            'reserve: for _round in 0..=self.cfg.max_reserves {
                let Some(placement) = self.placer.place(dfg, layout, &reserved, &mut rng)
                else {
                    break 'reserve; // placement impossible under reservations
                };
                match self.router.route(dfg, layout, &placement, &self.cfg) {
                    RouteOutcome::Routed(paths) => {
                        let m = Mapping {
                            node_cell: placement,
                            edge_paths: paths,
                            reserved: reserved.clone(),
                        };
                        debug_assert!(
                            m.validate(dfg, layout).is_empty(),
                            "engine produced invalid mapping: {:?}",
                            m.validate(dfg, layout)
                        );
                        return Ok(m);
                    }
                    RouteOutcome::Congested { hot_cell, hot_links, overuse } => {
                        if best_congestion.as_ref().map_or(true, |&(_, o)| overuse < o) {
                            best_congestion = Some((hot_links, overuse));
                        }
                        if strikes.observe(overuse) {
                            break 'reserve; // reserves are not helping
                        }
                        // reserve-on-demand: free the hot cell for routing
                        if reserved.len() >= self.cfg.max_reserves {
                            break 'reserve;
                        }
                        if layout.grid.is_compute(hot_cell) && !reserved_set.contains(hot_cell)
                        {
                            reserved.push(hot_cell);
                            reserved_set.insert(hot_cell);
                            stats.reserves += 1;
                        } else {
                            break 'reserve; // nothing sensible to reserve
                        }
                    }
                }
            }
        }
        Err(match best_congestion {
            Some((hot_links, overuse)) => MapFailure::Congested { hot_links, overuse },
            None => MapFailure::PlacementExhausted,
        })
    }

    /// Structural guard for the warm path: the witness must describe
    /// this DFG on this grid and fabric — lengths match, every cell is
    /// in range and of the right kind for its node, and every path
    /// connects its endpoints through fabric-adjacent hops. A witness
    /// from a different-shaped grid (or one using links this fabric
    /// does not provision) fails here and falls back to cold mapping
    /// (support and link capacity are covered elsewhere: displaced-node
    /// computation re-checks support, and adjacency-valid paths reuse
    /// the exact `(cell, dir)` link ids the witness already satisfied).
    fn witness_matches_grid(witness: &Mapping, dfg: &Dfg, layout: &Layout) -> bool {
        let g = &layout.grid;
        let f = layout.fabric();
        let num_cells = g.num_cells();
        if witness.node_cell.len() != dfg.num_nodes()
            || witness.edge_paths.len() != dfg.num_edges()
            || witness.node_cell.iter().any(|&c| c as usize >= num_cells)
            || witness.reserved.iter().any(|&c| c as usize >= num_cells)
        {
            return false;
        }
        for (n, op) in dfg.nodes.iter().enumerate() {
            let c = witness.node_cell[n];
            if op.is_memory() {
                if !f.is_active_io(c) {
                    return false;
                }
            } else if g.is_io(c) {
                return false;
            }
        }
        for (i, &(s, d)) in dfg.edges.iter().enumerate() {
            let path = &witness.edge_paths[i];
            if path.first() != Some(&witness.node_cell[s as usize])
                || path.last() != Some(&witness.node_cell[d as usize])
                || path.iter().any(|&c| c as usize >= num_cells)
                || path.windows(2).any(|w| f.direction(w[0], w[1]).is_none())
            {
                return false;
            }
        }
        true
    }

    /// The incremental path: `None` means "fall back to cold mapping".
    fn try_warm(&self, witness: &Mapping, dfg: &Dfg, layout: &Layout) -> Option<Mapping> {
        let num_cells = layout.grid.num_cells();
        if !Self::witness_matches_grid(witness, dfg, layout) {
            return None;
        }
        // nodes whose cell lost support for their group (support removal
        // never touches memory nodes or the switch fabric)
        let displaced: Vec<usize> = dfg
            .nodes
            .iter()
            .enumerate()
            .filter(|(n, op)| {
                !op.is_memory() && !layout.supports(witness.node_cell[*n], op.group())
            })
            .map(|(n, _)| n)
            .collect();
        if displaced.is_empty() {
            // the witness is still valid as-is
            return Some(witness.clone());
        }
        // when most of the DFG moved, incremental repair loses to a
        // fresh placement
        if displaced.len() * 2 > dfg.compute_ops() {
            return None;
        }
        let mut displaced_mask = vec![false; dfg.num_nodes()];
        for &n in &displaced {
            displaced_mask[n] = true;
        }
        let mut cell_of = witness.node_cell.clone();
        let mut occupied = vec![false; num_cells];
        for &c in &witness.reserved {
            occupied[c as usize] = true;
        }
        for (n, &c) in witness.node_cell.iter().enumerate() {
            if !displaced_mask[n] {
                occupied[c as usize] = true;
            }
        }
        if !place::replace_displaced(dfg, layout, &mut cell_of, &displaced, &mut occupied) {
            return None;
        }
        // rip up and reroute only the displaced nodes' incident edges
        let affected: Vec<usize> = (0..dfg.edges.len())
            .filter(|&i| {
                let (s, d) = dfg.edges[i];
                displaced_mask[s as usize] || displaced_mask[d as usize]
            })
            .collect();
        let paths = self.router.route_partial(
            dfg,
            layout,
            &cell_of,
            &witness.edge_paths,
            &affected,
            &self.cfg,
        )?;
        let m = Mapping { node_cell: cell_of, edge_paths: paths, reserved: witness.reserved.clone() };
        // guard the incremental path with full validation: an invalid
        // repair (should not happen) falls back to cold mapping instead
        // of corrupting the search
        if !m.validate(dfg, layout).is_empty() {
            debug_assert!(false, "warm-start repair invalid: {:?}", m.validate(dfg, layout));
            return None;
        }
        Some(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::dfg::benchmarks;
    use crate::ops::{GroupSet, Op};

    fn full_layout(r: usize, c: usize, d: &Dfg) -> Layout {
        Layout::full(Grid::new(r, c), d.groups_used())
    }

    #[test]
    fn engine_maps_where_mapper_did() {
        let d = benchmarks::benchmark("SOB");
        let l = full_layout(5, 5, &d);
        let engine = MappingEngine::default();
        match engine.map(&d, &l) {
            MapOutcome::Mapped { mapping, stats } => {
                assert!(mapping.validate(&d, &l).is_empty());
                assert!(stats.attempts >= 1);
                assert!(!stats.warm && !stats.cached);
            }
            MapOutcome::Failed { failure, .. } => panic!("SOB must map: {failure}"),
        }
    }

    #[test]
    fn unsupported_group_failure_carries_demand_and_capacity() {
        let d = benchmarks::benchmark("BIL"); // needs Div + Other
        let l = Layout::full(Grid::new(10, 10), GroupSet::from_groups(&[OpGroup::Arith]));
        let engine = MappingEngine::default();
        match engine.map(&d, &l) {
            MapOutcome::Failed {
                failure: MapFailure::UnsupportedGroup { group, demand, capacity },
                ..
            } => {
                assert_ne!(group, OpGroup::Arith);
                assert!(demand > 0);
                assert_eq!(capacity, 0);
            }
            other => panic!("expected UnsupportedGroup, got {other:?}"),
        }
    }

    #[test]
    fn too_small_grid_fails_with_structured_outcome() {
        let d = benchmarks::benchmark("SAD"); // 63 compute ops
        let l = full_layout(5, 5, &d); // 9 compute cells
        let engine = MappingEngine::default();
        match engine.map(&d, &l) {
            MapOutcome::Failed { failure, .. } => match failure {
                MapFailure::UnsupportedGroup { demand, capacity, .. } => {
                    assert!(demand > capacity)
                }
                MapFailure::PlacementExhausted => {}
                MapFailure::Congested { .. } => panic!("should fail before routing"),
            },
            MapOutcome::Mapped { .. } => panic!("SAD cannot fit 5x5"),
        }
    }

    #[test]
    fn engine_matches_deprecated_wrapper() {
        // the wrapper delegates here, so both must agree bit-for-bit
        let d = benchmarks::benchmark("RGB");
        let l = full_layout(8, 8, &d);
        let engine = MappingEngine::default();
        let m1 = engine.map(&d, &l).into_mapping().unwrap();
        #[allow(deprecated)]
        let m2 = Mapper::default().map(&d, &l).unwrap();
        assert_eq!(m1.node_cell, m2.node_cell);
        assert_eq!(m1.edge_paths, m2.edge_paths);
        assert_eq!(m1.reserved, m2.reserved);
    }

    #[test]
    fn feasibility_cache_serves_repeats() {
        let d = benchmarks::benchmark("GB");
        let l = full_layout(7, 7, &d);
        let engine = MappingEngine::default();
        let first = engine.map(&d, &l);
        assert!(!first.stats().cached);
        assert_eq!(engine.cache_len(), 1);
        let second = engine.map(&d, &l);
        assert!(second.stats().cached, "repeat must hit the cache");
        assert_eq!(
            first.mapping().unwrap().node_cell,
            second.mapping().unwrap().node_cell
        );
        // failures are cached too
        let sad = benchmarks::benchmark("SAD");
        let small = full_layout(5, 5, &sad);
        assert!(!engine.map(&sad, &small).is_mapped());
        assert!(engine.map(&sad, &small).stats().cached);
    }

    #[test]
    fn cache_can_be_disabled() {
        let d = benchmarks::benchmark("SOB");
        let l = full_layout(5, 5, &d);
        let engine =
            MappingEngine::new(MapperConfig { feasibility_cache: false, ..Default::default() });
        assert!(engine.map(&d, &l).is_mapped());
        assert!(!engine.map(&d, &l).stats().cached);
        assert_eq!(engine.cache_len(), 0);
    }

    #[test]
    fn warm_start_repairs_single_removal() {
        // an uncongested chain on a roomy grid: the incremental path is
        // guaranteed to close, so the warm flag and the single-node move
        // can be asserted exactly
        let d = Dfg::new(
            "chain",
            vec![Op::Load, Op::Add, Op::Mul, Op::Store],
            vec![(0, 1), (1, 2), (2, 3)],
        );
        let full = full_layout(6, 6, &d);
        let engine = MappingEngine::default();
        let witness = engine.map(&d, &full).into_mapping().expect("chain maps on 6x6");
        let neighbor = full.without_group(witness.node_cell[1], OpGroup::Arith);
        match engine.remap_from(&witness, &d, &neighbor) {
            MapOutcome::Mapped { mapping, stats } => {
                assert!(stats.warm, "one-removal neighbor must take the warm path");
                assert!(mapping.validate(&d, &neighbor).is_empty());
                // the displaced node moved, everything else stayed
                assert_ne!(mapping.node_cell[1], witness.node_cell[1]);
                let moved = mapping
                    .node_cell
                    .iter()
                    .zip(&witness.node_cell)
                    .filter(|(a, b)| a != b)
                    .count();
                assert_eq!(moved, 1, "only the displaced node may move");
            }
            MapOutcome::Failed { failure, .. } => {
                panic!("single-removal neighbor must remap: {failure}")
            }
        }
    }

    #[test]
    fn warm_start_on_real_benchmark_neighbors_stays_sound() {
        // one-group-removal neighbors of an NMS witness: every remap
        // (warm or fallen back to cold) must agree with feasibility and
        // validate cleanly
        let d = benchmarks::benchmark("NMS");
        let full = full_layout(9, 9, &d);
        let engine = MappingEngine::default();
        let witness = engine.map(&d, &full).into_mapping().expect("NMS maps on 9x9");
        for (node, op) in d.nodes.iter().enumerate().filter(|(_, op)| !op.is_memory()).take(6)
        {
            let neighbor = full.without_group(witness.node_cell[node], op.group());
            match engine.remap_from(&witness, &d, &neighbor) {
                MapOutcome::Mapped { mapping, .. } => {
                    assert!(
                        mapping.validate(&d, &neighbor).is_empty(),
                        "node {node}: invalid remap"
                    );
                }
                MapOutcome::Failed { .. } => {
                    // fallback guarantee: remap_from fails only when
                    // from-scratch mapping fails too
                    let cold = MappingEngine::new(MapperConfig {
                        feasibility_cache: false,
                        ..Default::default()
                    });
                    assert!(
                        !cold.map(&d, &neighbor).is_mapped(),
                        "node {node}: warm failed where cold succeeds"
                    );
                }
            }
        }
    }

    #[test]
    fn warm_start_with_valid_witness_is_a_noop() {
        let d = benchmarks::benchmark("SOB");
        let full = full_layout(6, 6, &d);
        let engine = MappingEngine::default();
        let witness = engine.map(&d, &full).into_mapping().unwrap();
        // remove support on a cell hosting no node of that group
        let used: Vec<CellId> = witness.node_cell.clone();
        let spare = full
            .grid
            .compute_cells()
            .find(|c| !used.contains(c))
            .expect("6x6 has spare cells");
        let neighbor = full.without_group(spare, OpGroup::Arith);
        match engine.remap_from(&witness, &d, &neighbor) {
            MapOutcome::Mapped { mapping, stats } => {
                assert!(stats.warm);
                assert_eq!(mapping.node_cell, witness.node_cell);
                assert_eq!(mapping.edge_paths, witness.edge_paths);
            }
            MapOutcome::Failed { failure, .. } => panic!("witness still valid: {failure}"),
        }
    }

    #[test]
    fn warm_start_falls_back_to_cold_when_repair_impossible() {
        let d = benchmarks::benchmark("SOB");
        let full = full_layout(6, 6, &d);
        let engine = MappingEngine::default();
        let witness = engine.map(&d, &full).into_mapping().unwrap();
        // strip Arith everywhere: warm repair and cold mapping both fail,
        // and the failure is the structured UnsupportedGroup diagnostic
        let mut crippled = full.clone();
        for c in crippled.grid.compute_cells().collect::<Vec<_>>() {
            let s = crippled.support(c).without(OpGroup::Arith);
            crippled.set_support(c, s);
        }
        match engine.remap_from(&witness, &d, &crippled) {
            MapOutcome::Failed {
                failure: MapFailure::UnsupportedGroup { group, .. },
                ..
            } => assert_eq!(group, OpGroup::Arith),
            other => panic!("expected UnsupportedGroup, got {other:?}"),
        }
    }

    #[test]
    fn witness_from_another_grid_falls_back_to_cold() {
        // same cell count, different shape: the structural guard must
        // reject the witness (no panic, no unvalidated pass-through) and
        // the request must resolve through the cold path
        let d = benchmarks::benchmark("SOB");
        let engine = MappingEngine::default();
        let narrow = Layout::full(Grid::new(4, 9), d.groups_used()); // 36 cells
        let square = Layout::full(Grid::new(6, 6), d.groups_used()); // 36 cells
        let witness = engine.map(&d, &narrow).into_mapping().expect("SOB maps on 4x9");
        match engine.remap_from(&witness, &d, &square) {
            MapOutcome::Mapped { mapping, stats } => {
                assert!(!stats.warm, "cross-grid witness must not warm-start");
                assert!(mapping.validate(&d, &square).is_empty());
            }
            MapOutcome::Failed { failure, .. } => {
                panic!("SOB must map on 6x6 via the cold fallback: {failure}")
            }
        }
    }

    #[test]
    fn strike_counter_abandons_after_limit_and_resets_on_improvement() {
        let mut s = StrikeCounter::new(RESERVE_STRIKE_LIMIT);
        assert!(!s.observe(10)); // first observation improves on MAX
        assert!(!s.observe(10)); // strike 1
        assert!(!s.observe(12)); // strike 2
        assert!(s.observe(11)); // strike 3 = RESERVE_STRIKE_LIMIT: abandon
        // an improvement resets the count
        let mut s = StrikeCounter::new(RESERVE_STRIKE_LIMIT);
        assert!(!s.observe(10));
        assert!(!s.observe(10)); // strike 1
        assert!(!s.observe(5)); // improvement: reset
        assert!(!s.observe(6)); // strike 1
        assert!(!s.observe(6)); // strike 2
        assert!(s.observe(6)); // strike 3: abandon
    }

    #[test]
    fn forked_engine_matches_parent_with_fresh_cache() {
        let d = benchmarks::benchmark("SOB");
        let l = full_layout(6, 6, &d);
        let parent = MappingEngine::default();
        assert!(parent.map(&d, &l).is_mapped());
        assert_eq!(parent.cache_len(), 1);
        let fork = parent.fork();
        // same configuration and strategies, fresh cache
        assert_eq!(fork.cfg.seed, parent.cfg.seed);
        assert_eq!(fork.placer_name(), parent.placer_name());
        assert_eq!(fork.router_name(), parent.router_name());
        assert_eq!(fork.cache_len(), 0, "forks must not share cache state");
        // deterministic: the fork reproduces the parent's mapping exactly
        let a = parent.map(&d, &l).into_mapping().unwrap();
        let b = fork.map(&d, &l).into_mapping().unwrap();
        assert_eq!(a.node_cell, b.node_cell);
        assert_eq!(a.edge_paths, b.edge_paths);
        // forked engines are Send: they move onto search worker threads
        fn assert_send<T: Send>(_: &T) {}
        assert_send(&fork);
    }

    #[test]
    fn config_selects_steiner_router() {
        let engine = MappingEngine::new(MapperConfig {
            router_steiner: true,
            ..MapperConfig::default()
        });
        assert_eq!(engine.router_name(), "steiner");
        assert_eq!(MappingEngine::default().router_name(), "pathfinder");
        // forks keep the selection (with a fresh arena)
        assert_eq!(engine.fork().router_name(), "steiner");
    }

    #[test]
    fn steiner_engine_maps_benchmarks_and_agrees_on_feasibility() {
        let engine = MappingEngine::new(MapperConfig {
            router_steiner: true,
            ..MapperConfig::default()
        });
        for name in ["SOB", "GB", "RGB", "NMS"] {
            let d = benchmarks::benchmark(name);
            let l = full_layout(10, 10, &d);
            let m = engine.map(&d, &l);
            assert!(m.is_mapped(), "{name} must map with the Steiner router");
            assert!(m.mapping().unwrap().validate(&d, &l).is_empty(), "{name}");
        }
        // infeasible stays infeasible: missing group support is decided
        // before routing, whatever the router
        let d = benchmarks::benchmark("BIL");
        let l = Layout::full(Grid::new(10, 10), GroupSet::from_groups(&[OpGroup::Arith]));
        assert!(!engine.map(&d, &l).is_mapped());
    }

    #[test]
    fn steiner_warm_start_repairs_single_removal() {
        let d = Dfg::new(
            "chain",
            vec![Op::Load, Op::Add, Op::Mul, Op::Store],
            vec![(0, 1), (1, 2), (2, 3)],
        );
        let full = full_layout(6, 6, &d);
        let engine = MappingEngine::new(MapperConfig {
            router_steiner: true,
            ..MapperConfig::default()
        });
        let witness = engine.map(&d, &full).into_mapping().expect("chain maps on 6x6");
        let neighbor = full.without_group(witness.node_cell[1], OpGroup::Arith);
        match engine.remap_from(&witness, &d, &neighbor) {
            MapOutcome::Mapped { mapping, stats } => {
                assert!(stats.warm, "one-removal neighbor must take the warm path");
                assert!(mapping.validate(&d, &neighbor).is_empty());
            }
            MapOutcome::Failed { failure, .. } => {
                panic!("single-removal neighbor must remap: {failure}")
            }
        }
    }

    #[test]
    fn map_all_reports_first_failure_with_name() {
        let sob = benchmarks::benchmark("SOB");
        let sad = benchmarks::benchmark("SAD");
        let l = Layout::full(Grid::new(6, 6), crate::dfg::groups_used(&[sob.clone(), sad.clone()]));
        let engine = MappingEngine::default();
        let err = engine.map_all(&[sob, sad], &l).unwrap_err();
        assert_eq!(err.dfg_index, 1);
        assert_eq!(err.dfg_name, "SAD");
        assert!(!engine.test_layout(
            &[benchmarks::benchmark("SOB"), benchmarks::benchmark("SAD")],
            &l
        ));
    }

    #[test]
    fn custom_strategies_plug_in() {
        // a placer that defers to the default and a router that defers to
        // the default, but with their own names: the seam the engine
        // promises to alternative strategies.
        struct NamedPlacer;
        impl PlacementStrategy for NamedPlacer {
            fn name(&self) -> &'static str {
                "custom-placer"
            }
            fn place(
                &self,
                dfg: &Dfg,
                layout: &Layout,
                reserved: &[CellId],
                rng: &mut Rng,
            ) -> Option<Vec<CellId>> {
                GreedyTopoPlacer.place(dfg, layout, reserved, rng)
            }
            fn clone_box(&self) -> Box<dyn PlacementStrategy> {
                Box::new(NamedPlacer)
            }
        }
        struct NamedRouter;
        impl RoutingStrategy for NamedRouter {
            fn name(&self) -> &'static str {
                "custom-router"
            }
            fn route(
                &self,
                dfg: &Dfg,
                layout: &Layout,
                placement: &[CellId],
                cfg: &MapperConfig,
            ) -> RouteOutcome {
                PathFinderRouter.route(dfg, layout, placement, cfg)
            }
            fn clone_box(&self) -> Box<dyn RoutingStrategy> {
                Box::new(NamedRouter)
            }
        }
        let engine = MappingEngine::with_strategies(
            MapperConfig::default(),
            Box::new(NamedPlacer),
            Box::new(NamedRouter),
        );
        assert_eq!(engine.placer_name(), "custom-placer");
        assert_eq!(engine.router_name(), "custom-router");
        let d = benchmarks::benchmark("SOB");
        let l = full_layout(5, 5, &d);
        assert!(engine.map(&d, &l).is_mapped());
        // NamedRouter relies on the default route_partial fallback: warm
        // requests still resolve correctly
        let witness = engine.map(&d, &l).into_mapping().unwrap();
        assert!(engine.remap_from(&witness, &d, &l).is_mapped());
    }
}
