//! Negotiated-congestion routing over the layout's switch network.
//!
//! PathFinder-style: every routing round rips up all paths and re-routes
//! each edge by A* search, where a link's cost is
//! `base + history + present_penalty * overuse`. The network is whatever
//! the layout's [`crate::fabric::Fabric`] provisions — the legacy 4NN
//! mesh by default, optionally with diagonal or express links and a
//! per-link capacity above one. A link carries `link_cap` distinct value
//! streams before counting as overused, and edges with the same source
//! share links for free (fan-out of the same value). History accumulates
//! on overused links between rounds, pushing later rounds around
//! persistent congestion; negotiation exits early when total overuse
//! stops improving.
//!
//! If congestion survives, the most-overused link's adjacent occupied
//! compute cell is reported as the `hot_cell` so the driver can apply
//! reserve-on-demand.
//!
//! Two routers share this negotiation skeleton (see `docs/ROUTER.md`
//! for the full internals guide):
//!
//! * the legacy **edge-by-edge** router ([`route`]) — each DFG edge is
//!   an independent A* query; fan-out sharing emerges only through the
//!   0.01 same-source reuse discount. Kept byte-identical: it is the
//!   default and its traces are pinned by CI.
//! * the **Steiner multi-fanout** router ([`steiner_route`], selected
//!   via `MapperConfig::router_steiner`) — edges sharing a source form
//!   one *net*, routed as a shared-trunk Steiner tree grown by repeated
//!   nearest-sink attachment (multi-source A* from every tree cell to
//!   the closest unconnected sink). One tree search replaces N
//!   independent queries, trunk links are counted once, and per-net
//!   criticality (longest-path slack, `router_criticality`) can scale
//!   congestion penalties so critical nets hold contested links.
//!
//! Perf notes (EXPERIMENTS.md §Perf): the A* heuristic is the fabric's
//! minimum hop count when the edge's source drives no links yet (every
//! remaining hop then costs ≥ 1), and the 0.01-reuse floor otherwise —
//! both admissible. Distance/parent arrays are reused across calls via
//! generation stamps instead of reallocation; the Steiner router keeps
//! them in an engine-owned [`RouterArena`] that survives across the
//! thousands of candidate feasibility tests one search performs.

use crate::cgra::{CellId, Layout};
use crate::fabric::Fabric;
use crate::dfg::Dfg;
use crate::mapper::MapperConfig;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Outcome of a routing attempt.
pub enum RouteOutcome {
    Routed(Vec<Vec<CellId>>),
    /// Still congested; `hot_cell` is the recommended reservation target,
    /// `hot_links` the overused link ids of the final round (hottest
    /// first, for diagnostics), and `overuse` the best (lowest) total
    /// link overuse seen — the driver uses it to detect reserves that
    /// are not helping.
    Congested { hot_cell: CellId, hot_links: Vec<usize>, overuse: usize },
}

#[derive(PartialEq)]
struct HeapEntry {
    /// cost-so-far + admissible heuristic
    priority: f64,
    cost: f64,
    cell: CellId,
}

impl Eq for HeapEntry {}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on priority, tie-break on cell id for determinism
        other
            .priority
            .partial_cmp(&self.priority)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.cell.cmp(&self.cell))
    }
}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Per-link usage bookkeeping: which source nodes currently drive a link.
#[derive(Clone, Default)]
struct LinkUse {
    srcs: Vec<u32>, // distinct DFG source nodes using this link
}

impl LinkUse {
    /// Streams beyond the link's capacity (`cap` distinct values ride
    /// for free; the legacy mesh has `cap == 1`).
    fn overuse(&self, cap: usize) -> usize {
        self.srcs.len().saturating_sub(cap)
    }
    fn has(&self, s: u32) -> bool {
        self.srcs.contains(&s)
    }
    fn add(&mut self, s: u32) {
        if !self.has(s) {
            self.srcs.push(s);
        }
    }
}

/// Reusable A* scratch buffers (generation-stamped to skip clearing).
struct AStarBuffers {
    dist: Vec<f64>,
    prev: Vec<CellId>,
    stamp: Vec<u32>,
    generation: u32,
}

impl AStarBuffers {
    fn new(n: usize) -> Self {
        Self {
            dist: vec![f64::INFINITY; n],
            prev: vec![u16::MAX; n],
            stamp: vec![0; n],
            generation: 0,
        }
    }
    /// Resize for a (possibly different) grid; cheap when already sized.
    fn ensure(&mut self, n: usize) {
        if self.dist.len() < n {
            self.dist.resize(n, f64::INFINITY);
            self.prev.resize(n, u16::MAX);
            self.stamp.resize(n, 0);
        }
    }
    fn begin(&mut self) {
        // long-lived arenas survive billions of searches: on generation
        // wrap, reset the stamps so stale entries cannot alias as current
        if self.generation == u32::MAX {
            self.stamp.fill(0);
            self.generation = 0;
        }
        self.generation += 1;
    }
    /// Frontier-size hint for the search heap: the cell count (searches
    /// can push more entries than cells, but this bounds the common case).
    fn capacity_hint(&self) -> usize {
        self.dist.len()
    }
    #[inline]
    fn get_dist(&self, c: usize) -> f64 {
        if self.stamp[c] == self.generation {
            self.dist[c]
        } else {
            f64::INFINITY
        }
    }
    #[inline]
    fn set(&mut self, c: usize, d: f64, p: CellId) {
        self.dist[c] = d;
        self.prev[c] = p;
        self.stamp[c] = self.generation;
    }
}

/// Route all edges of a placed DFG.
pub fn route(
    dfg: &Dfg,
    layout: &Layout,
    placement: &[CellId],
    cfg: &MapperConfig,
) -> RouteOutcome {
    route_rounds(dfg, layout, placement, cfg).0
}

/// Like [`route`], additionally reporting the negotiation rounds
/// consumed — the rip-up count tracked by the `route::steiner` bench.
pub fn route_rounds(
    dfg: &Dfg,
    layout: &Layout,
    placement: &[CellId],
    cfg: &MapperConfig,
) -> (RouteOutcome, usize) {
    let g = &layout.grid;
    let f = layout.fabric();
    let nlinks = f.num_links();
    let cap = f.link_cap();
    let mut history = vec![0.0f64; nlinks];

    // Route longer edges first: they have fewer detour options.
    let mut order: Vec<usize> = (0..dfg.edges.len()).collect();
    order.sort_by_key(|&i| {
        let (s, d) = dfg.edges[i];
        std::cmp::Reverse(
            f.min_hops(placement[s as usize], placement[d as usize]) as u32 * 1000 + i as u32,
        )
    });

    let mut paths: Vec<Vec<CellId>> = vec![Vec::new(); dfg.edges.len()];
    let mut last_usage: Vec<LinkUse> = vec![LinkUse::default(); nlinks];
    let mut buffers = AStarBuffers::new(g.num_cells());
    // links-per-source count this round: a source with zero links admits
    // the strong (min-hops) heuristic.
    let mut src_links: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
    // early-exit when negotiation stalls: if total overuse has not
    // improved for `stall_limit` rounds, more rounds will not help and
    // the caller should reserve a cell instead.
    let mut best_overuse = usize::MAX;
    let mut stalled = 0usize;
    let stall_limit = 3;
    let mut rounds = 0usize;

    for _round in 0..cfg.route_iters {
        rounds += 1;
        let mut usage: Vec<LinkUse> = vec![LinkUse::default(); nlinks];
        src_links.clear();
        for &ei in &order {
            let (sn, dn) = dfg.edges[ei];
            let (src, dst) = (placement[sn as usize], placement[dn as usize]);
            let strong_heuristic = src_links.get(&sn).copied().unwrap_or(0) == 0;
            let path = astar(
                f,
                src,
                dst,
                sn,
                strong_heuristic,
                &usage,
                &history,
                cfg,
                &mut buffers,
            );
            for w in path.windows(2) {
                let dir = direction(f, w[0], w[1]);
                usage[f.link(w[0], dir)].add(sn);
            }
            *src_links.entry(sn).or_insert(0) += path.len().saturating_sub(1) as u32;
            paths[ei] = path;
        }
        // converged?
        let over: Vec<usize> =
            (0..nlinks).filter(|&l| usage[l].overuse(cap) > 0).collect();
        if over.is_empty() {
            return (RouteOutcome::Routed(paths), rounds);
        }
        // accumulate history on overused links
        let mut total_overuse = 0;
        for &l in &over {
            history[l] += cfg.hist_increment * usage[l].overuse(cap) as f64;
            total_overuse += usage[l].overuse(cap);
        }
        last_usage = usage;
        if total_overuse < best_overuse {
            best_overuse = total_overuse;
            stalled = 0;
        } else {
            stalled += 1;
            if stalled >= stall_limit {
                break; // negotiation stalled; hand over to reserve-on-demand
            }
        }
    }

    // Pick the hottest link and suggest reserving an adjacent occupied
    // compute cell (RodMap's reserve-on-demand trigger).
    let mut hot_links: Vec<usize> =
        (0..nlinks).filter(|&l| last_usage[l].overuse(cap) > 0).collect();
    // hottest first; ties resolve to the highest link id (same pick as
    // the previous `max_by_key`, which kept the last maximal element)
    hot_links.sort_by_key(|&l| {
        (std::cmp::Reverse(last_usage[l].overuse(cap)), std::cmp::Reverse(l))
    });
    let hottest = hot_links.first().copied().unwrap_or(0);
    let cell = (hottest / f.num_dirs()) as CellId;
    let dir = hottest % f.num_dirs();
    let occupied: Vec<CellId> = placement.to_vec();
    let candidates = [Some(cell), f.neighbor(cell, dir)];
    let hot_cell = candidates
        .into_iter()
        .flatten()
        .chain(f.neighbors(cell))
        .find(|&c| g.is_compute(c) && occupied.contains(&c))
        .unwrap_or(cell);
    (RouteOutcome::Congested { hot_cell, hot_links, overuse: best_overuse }, rounds)
}

/// Incremental rip-up-and-reroute: re-route only the `affected` edges of
/// a placed DFG, keeping every other edge's path in `fixed_paths` pinned
/// (their link usage is seeded into every negotiation round and never
/// ripped up). Used by the warm-start remapping path, where support
/// removal displaces a few nodes and only their incident edges need new
/// routes. Returns the complete path set (fixed paths untouched) once
/// overuse reaches zero, or `None` if negotiation cannot clear the
/// congestion — the caller then falls back to from-scratch mapping.
pub fn route_partial(
    dfg: &Dfg,
    layout: &Layout,
    placement: &[CellId],
    fixed_paths: &[Vec<CellId>],
    affected: &[usize],
    cfg: &MapperConfig,
) -> Option<Vec<Vec<CellId>>> {
    let g = &layout.grid;
    let f = layout.fabric();
    let nlinks = f.num_links();
    let cap = f.link_cap();
    let mut affected_mask = vec![false; dfg.edges.len()];
    for &ei in affected {
        affected_mask[ei] = true;
    }

    // Usage contributed by the pinned paths: constant across rounds.
    let mut fixed_usage: Vec<LinkUse> = vec![LinkUse::default(); nlinks];
    let mut fixed_src_links: std::collections::HashMap<u32, u32> =
        std::collections::HashMap::new();
    for (ei, &(s, _)) in dfg.edges.iter().enumerate() {
        if affected_mask[ei] {
            continue;
        }
        for w in fixed_paths[ei].windows(2) {
            let dir = direction(f, w[0], w[1]);
            fixed_usage[f.link(w[0], dir)].add(s);
        }
        *fixed_src_links.entry(s).or_insert(0) +=
            fixed_paths[ei].len().saturating_sub(1) as u32;
    }

    // Longest affected edges first, as in the full router.
    let mut order: Vec<usize> = affected.to_vec();
    order.sort_by_key(|&i| {
        let (s, d) = dfg.edges[i];
        std::cmp::Reverse(
            f.min_hops(placement[s as usize], placement[d as usize]) as u32 * 1000 + i as u32,
        )
    });

    let mut history = vec![0.0f64; nlinks];
    let mut buffers = AStarBuffers::new(g.num_cells());
    let mut paths = fixed_paths.to_vec();
    let mut best_overuse = usize::MAX;
    let mut stalled = 0usize;
    let stall_limit = 3;

    for _round in 0..cfg.route_iters {
        let mut usage = fixed_usage.clone();
        let mut src_links = fixed_src_links.clone();
        for &ei in &order {
            let (sn, dn) = dfg.edges[ei];
            let (src, dst) = (placement[sn as usize], placement[dn as usize]);
            let strong_heuristic = src_links.get(&sn).copied().unwrap_or(0) == 0;
            let path = astar(
                f,
                src,
                dst,
                sn,
                strong_heuristic,
                &usage,
                &history,
                cfg,
                &mut buffers,
            );
            for w in path.windows(2) {
                let dir = direction(f, w[0], w[1]);
                usage[f.link(w[0], dir)].add(sn);
            }
            *src_links.entry(sn).or_insert(0) += path.len().saturating_sub(1) as u32;
            paths[ei] = path;
        }
        let mut total_overuse = 0;
        for l in 0..nlinks {
            let o = usage[l].overuse(cap);
            if o > 0 {
                history[l] += cfg.hist_increment * o as f64;
                total_overuse += o;
            }
        }
        if total_overuse == 0 {
            return Some(paths);
        }
        if total_overuse < best_overuse {
            best_overuse = total_overuse;
            stalled = 0;
        } else {
            stalled += 1;
            if stalled >= stall_limit {
                break;
            }
        }
    }
    None
}

/// Direction index such that `f.neighbor(a, dir) == b`.
fn direction(f: &Fabric, a: CellId, b: CellId) -> usize {
    f.direction(a, b).expect("cells must be adjacent")
}

/// A* from `src` to `dst` for the value produced by node `src_node`.
///
/// Heuristic: the fabric's minimum hop count when the source drives no
/// links yet this round (every remaining step costs at least the base
/// 1.0), else `0.01 * min_hops` (a route could in principle ride reused
/// links the whole way at the reuse floor). Both are admissible, so
/// paths are optimal under the current penalty landscape.
#[allow(clippy::too_many_arguments)]
fn astar(
    f: &Fabric,
    src: CellId,
    dst: CellId,
    src_node: u32,
    strong_heuristic: bool,
    usage: &[LinkUse],
    history: &[f64],
    cfg: &MapperConfig,
    buf: &mut AStarBuffers,
) -> Vec<CellId> {
    let h_scale = if strong_heuristic { 0.999 } else { 0.01 };
    let h = |c: CellId| f.min_hops(c, dst) as f64 * h_scale;
    let free_streams = f.link_cap().saturating_sub(1);
    buf.begin();
    // Size the frontier for the grid instead of a hardcoded 64: congested
    // searches visit a large fraction of the cells, and re-pushes on
    // relaxation mean the heap can exceed the cell count, so a too-small
    // capacity reallocates repeatedly in the inner loop.
    let mut heap = BinaryHeap::with_capacity(buf.capacity_hint());
    buf.set(src as usize, 0.0, src);
    heap.push(HeapEntry { priority: h(src), cost: 0.0, cell: src });
    while let Some(HeapEntry { cost, cell, .. }) = heap.pop() {
        if cell == dst {
            break;
        }
        if cost > buf.get_dist(cell as usize) {
            continue;
        }
        for d in 0..f.num_dirs() {
            let Some(next) = f.neighbor(cell, d) else { continue };
            let link = f.link(cell, d);
            let u = &usage[link];
            // same-source reuse is nearly free (fan-out broadcast);
            // below-capacity sharing pays no present penalty; otherwise
            // pay base + congestion penalties.
            let step = if u.has(src_node) {
                0.01
            } else {
                1.0 + history[link]
                    + cfg.present_penalty * u.srcs.len().saturating_sub(free_streams) as f64
            };
            let nc = cost + step;
            if nc < buf.get_dist(next as usize) {
                buf.set(next as usize, nc, cell);
                heap.push(HeapEntry { priority: nc + h(next), cost: nc, cell: next });
            }
        }
    }
    // reconstruct; the uncongested length is min_hops + 1 cells, so
    // reserve that up front (detours past it are rare)
    let mut path = Vec::with_capacity(f.min_hops(src, dst) + 1);
    path.push(dst);
    let mut cur = dst;
    while cur != src {
        cur = buf.prev[cur as usize];
        debug_assert!(cur != u16::MAX, "grid is connected; path must exist");
        path.push(cur);
    }
    path.reverse();
    path
}

// ---- Steiner multi-fanout routing ----

/// Word-parallel membership set over link ids. Unlike
/// [`crate::cgra::CellSet`] this is `usize`-indexed: `num_links` is
/// `num_cells * num_dirs` and can exceed `u16::MAX` on large
/// multi-direction fabrics.
#[derive(Clone, Default)]
struct LinkSet {
    words: Vec<u64>,
}

impl LinkSet {
    fn ensure(&mut self, nbits: usize) {
        let words = (nbits + 63) / 64;
        if self.words.len() < words {
            self.words.resize(words, 0);
        }
    }
    /// Word-parallel reset: one write per 64 links.
    fn clear(&mut self) {
        self.words.fill(0);
    }
    #[inline]
    fn contains(&self, i: usize) -> bool {
        self.words[i >> 6] & (1u64 << (i & 63)) != 0
    }
    #[inline]
    fn insert(&mut self, i: usize) {
        self.words[i >> 6] |= 1u64 << (i & 63);
    }
}

/// Engine-owned router scratch, reused across the thousands of candidate
/// feasibility tests one search performs: the generation-stamped A*
/// buffers, the per-link usage/history tables and the per-net tree
/// bookkeeping all survive between calls instead of reallocating in the
/// router inner loop.
///
/// [`crate::mapper::SteinerRouter`] owns one behind a `RefCell`; forked
/// engines ([`crate::mapper::MappingEngine::fork`]) get a fresh arena,
/// so parallel search workers never share scratch and the deterministic
/// reduction is untouched.
pub struct RouterArena {
    astar: AStarBuffers,
    /// Distinct-source (= distinct-net) count per link this round.
    usage: Vec<u32>,
    /// Congestion history per link; reset per routing call.
    history: Vec<f64>,
    /// Links of the net tree currently being grown (word-parallel).
    tree_links: LinkSet,
    /// Parent cell toward the net source, per tree cell.
    tree_parent: Vec<CellId>,
    /// Generation stamp marking tree membership (avoids clearing).
    tree_stamp: Vec<u32>,
    tree_gen: u32,
}

impl Default for RouterArena {
    fn default() -> Self {
        Self::new()
    }
}

impl RouterArena {
    pub fn new() -> Self {
        Self {
            astar: AStarBuffers::new(0),
            usage: Vec::new(),
            history: Vec::new(),
            tree_links: LinkSet::default(),
            tree_parent: Vec::new(),
            tree_stamp: Vec::new(),
            tree_gen: 0,
        }
    }

    /// Lazily size every table for a fabric; cheap when already sized.
    fn ensure(&mut self, num_cells: usize, num_links: usize) {
        self.astar.ensure(num_cells);
        if self.usage.len() < num_links {
            self.usage.resize(num_links, 0);
            self.history.resize(num_links, 0.0);
        }
        self.tree_links.ensure(num_links);
        if self.tree_parent.len() < num_cells {
            self.tree_parent.resize(num_cells, u16::MAX);
            self.tree_stamp.resize(num_cells, 0);
        }
    }

    /// Start a fresh net tree (with the same wrap guard as the A*
    /// stamps: long-lived arenas survive billions of trees).
    fn begin_tree(&mut self) {
        if self.tree_gen == u32::MAX {
            self.tree_stamp.fill(0);
            self.tree_gen = 0;
        }
        self.tree_gen += 1;
        self.tree_links.clear();
    }
}

/// One multi-fanout net: every DFG edge sharing a source node, routed
/// together as one shared-trunk Steiner tree.
struct Net {
    src_node: u32,
    src_cell: CellId,
    /// Deduped sink cells, first-encounter edge order.
    sinks: Vec<CellId>,
    /// Indices into `dfg.edges` belonging to this net.
    edges: Vec<usize>,
}

/// Group `dfg.edges` by source node, in first-encounter order.
fn build_nets(dfg: &Dfg, placement: &[CellId]) -> Vec<Net> {
    let mut by_src: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
    let mut nets: Vec<Net> = Vec::new();
    for (ei, &(s, d)) in dfg.edges.iter().enumerate() {
        let idx = *by_src.entry(s).or_insert_with(|| {
            nets.push(Net {
                src_node: s,
                src_cell: placement[s as usize],
                sinks: Vec::new(),
                edges: Vec::new(),
            });
            nets.len() - 1
        });
        let dst = placement[d as usize];
        let net = &mut nets[idx];
        if dst != net.src_cell && !net.sinks.contains(&dst) {
            net.sinks.push(dst);
        }
        net.edges.push(ei);
    }
    nets
}

/// Per-node criticality in `[0, 1]`: longest path through the node
/// (forward depth + backward depth − 1, in nodes) over the DFG's
/// critical-path length. A net inherits its source node's score;
/// computed once per routing call.
fn node_criticality(dfg: &Dfg) -> Vec<f64> {
    let n = dfg.num_nodes();
    let Some(order) = dfg.topo_order() else {
        return vec![1.0; n];
    };
    let preds = dfg.preds();
    let succs = dfg.succs();
    // longest path ending at / starting from each node, in nodes
    let mut down = vec![1u32; n];
    for &u in &order {
        for &p in &preds[u as usize] {
            down[u as usize] = down[u as usize].max(down[p as usize] + 1);
        }
    }
    let mut up = vec![1u32; n];
    for &u in order.iter().rev() {
        for &s in &succs[u as usize] {
            up[u as usize] = up[u as usize].max(up[s as usize] + 1);
        }
    }
    let total = (0..n).map(|i| down[i] + up[i] - 1).max().unwrap_or(1).max(1) as f64;
    (0..n).map(|i| (down[i] + up[i] - 1) as f64 / total).collect()
}

/// Congestion-penalty scale for a net: critical nets pay less to hold
/// contested links (they have no slack to detour), so negotiation
/// displaces slack nets first and converges in fewer rip-up rounds.
#[inline]
fn crit_factor(crit: Option<&Vec<f64>>, src_node: u32) -> f64 {
    match crit {
        Some(c) => 1.0 - 0.5 * c[src_node as usize],
        None => 1.0,
    }
}

/// Route all edges of a placed DFG as shared-trunk Steiner trees, one
/// per multi-fanout net, under the same negotiated-congestion loop as
/// [`route`]. Fabric-generic: trunk growth only uses
/// `neighbor`/`link`/`min_hops`, so Mesh4, Mesh8 and Express all
/// benefit. Selected via `MapperConfig::router_steiner`.
pub fn steiner_route(
    dfg: &Dfg,
    layout: &Layout,
    placement: &[CellId],
    cfg: &MapperConfig,
    arena: &mut RouterArena,
) -> RouteOutcome {
    steiner_route_rounds(dfg, layout, placement, cfg, arena).0
}

/// Like [`steiner_route`], additionally reporting negotiation rounds
/// consumed (the rip-up count benchmarked by `route::steiner`).
pub fn steiner_route_rounds(
    dfg: &Dfg,
    layout: &Layout,
    placement: &[CellId],
    cfg: &MapperConfig,
    arena: &mut RouterArena,
) -> (RouteOutcome, usize) {
    let g = &layout.grid;
    let f = layout.fabric();
    let nlinks = f.num_links();
    let cap = f.link_cap();
    arena.ensure(g.num_cells(), nlinks);
    arena.history[..nlinks].fill(0.0);

    let nets = build_nets(dfg, placement);
    // Route wide-span nets first: they have the fewest detour options
    // (same rationale as the legacy longest-edge-first order).
    let mut order: Vec<usize> = (0..nets.len()).collect();
    order.sort_by_key(|&i| {
        let span =
            nets[i].sinks.iter().map(|&s| f.min_hops(nets[i].src_cell, s)).max().unwrap_or(0);
        std::cmp::Reverse(span as u32 * 1000 + i as u32)
    });
    let crit = cfg.router_criticality.then(|| node_criticality(dfg));

    let mut paths: Vec<Vec<CellId>> = vec![Vec::new(); dfg.edges.len()];
    let mut best_overuse = usize::MAX;
    let mut stalled = 0usize;
    let stall_limit = 3;
    let mut rounds = 0usize;

    for _round in 0..cfg.route_iters {
        rounds += 1;
        arena.usage[..nlinks].fill(0);
        for &ni in &order {
            let factor = crit_factor(crit.as_ref(), nets[ni].src_node);
            route_net_tree(f, &nets[ni], placement, dfg, factor, cfg, arena, &mut paths);
        }
        let mut total_overuse = 0usize;
        for l in 0..nlinks {
            let o = (arena.usage[l] as usize).saturating_sub(cap);
            if o > 0 {
                arena.history[l] += cfg.hist_increment * o as f64;
                total_overuse += o;
            }
        }
        if total_overuse == 0 {
            return (RouteOutcome::Routed(paths), rounds);
        }
        if total_overuse < best_overuse {
            best_overuse = total_overuse;
            stalled = 0;
        } else {
            stalled += 1;
            if stalled >= stall_limit {
                break; // negotiation stalled; hand over to reserve-on-demand
            }
        }
    }

    // Same hot-cell diagnosis as the legacy router, read off the final
    // round's usage counters.
    let mut hot_links: Vec<usize> =
        (0..nlinks).filter(|&l| arena.usage[l] as usize > cap).collect();
    hot_links.sort_by_key(|&l| {
        (std::cmp::Reverse(arena.usage[l] as usize - cap), std::cmp::Reverse(l))
    });
    let hottest = hot_links.first().copied().unwrap_or(0);
    let cell = (hottest / f.num_dirs()) as CellId;
    let dir = hottest % f.num_dirs();
    let candidates = [Some(cell), f.neighbor(cell, dir)];
    let hot_cell = candidates
        .into_iter()
        .flatten()
        .chain(f.neighbors(cell))
        .find(|&c| g.is_compute(c) && placement.contains(&c))
        .unwrap_or(cell);
    (RouteOutcome::Congested { hot_cell, hot_links, overuse: best_overuse }, rounds)
}

/// Net-granular incremental reroute for the warm-start path: nets with
/// no affected edge keep their `fixed_paths` pinned (their link usage is
/// seeded into every round); nets touching an affected edge are ripped
/// up and re-grown whole — a tree cannot be repaired one branch at a
/// time without losing the shared trunk. Returns the complete path set
/// once overuse reaches zero, or `None` to fall back to cold mapping.
pub fn steiner_route_partial(
    dfg: &Dfg,
    layout: &Layout,
    placement: &[CellId],
    fixed_paths: &[Vec<CellId>],
    affected: &[usize],
    cfg: &MapperConfig,
    arena: &mut RouterArena,
) -> Option<Vec<Vec<CellId>>> {
    let g = &layout.grid;
    let f = layout.fabric();
    let nlinks = f.num_links();
    let cap = f.link_cap();
    arena.ensure(g.num_cells(), nlinks);
    arena.history[..nlinks].fill(0.0);

    let mut affected_mask = vec![false; dfg.edges.len()];
    for &ei in affected {
        affected_mask[ei] = true;
    }
    let nets = build_nets(dfg, placement);
    let (dirty, pinned): (Vec<usize>, Vec<usize>) =
        (0..nets.len()).partition(|&ni| nets[ni].edges.iter().any(|&ei| affected_mask[ei]));

    // Usage contributed by pinned nets: constant across rounds, trunk
    // links deduped per net (edges of one net share links for free).
    let mut fixed_usage = vec![0u32; nlinks];
    let mut seen = LinkSet::default();
    seen.ensure(nlinks);
    for &ni in &pinned {
        for &ei in &nets[ni].edges {
            for w in fixed_paths[ei].windows(2) {
                let link = f.link(w[0], direction(f, w[0], w[1]));
                if !seen.contains(link) {
                    seen.insert(link);
                    fixed_usage[link] += 1;
                }
            }
        }
        seen.clear();
    }

    let mut order: Vec<usize> = dirty;
    order.sort_by_key(|&i| {
        let span =
            nets[i].sinks.iter().map(|&s| f.min_hops(nets[i].src_cell, s)).max().unwrap_or(0);
        std::cmp::Reverse(span as u32 * 1000 + i as u32)
    });
    let crit = cfg.router_criticality.then(|| node_criticality(dfg));

    let mut paths = fixed_paths.to_vec();
    let mut best_overuse = usize::MAX;
    let mut stalled = 0usize;
    let stall_limit = 3;

    for _round in 0..cfg.route_iters {
        arena.usage[..nlinks].copy_from_slice(&fixed_usage);
        for &ni in &order {
            let factor = crit_factor(crit.as_ref(), nets[ni].src_node);
            route_net_tree(f, &nets[ni], placement, dfg, factor, cfg, arena, &mut paths);
        }
        let mut total_overuse = 0usize;
        for l in 0..nlinks {
            let o = (arena.usage[l] as usize).saturating_sub(cap);
            if o > 0 {
                arena.history[l] += cfg.hist_increment * o as f64;
                total_overuse += o;
            }
        }
        if total_overuse == 0 {
            return Some(paths);
        }
        if total_overuse < best_overuse {
            best_overuse = total_overuse;
            stalled = 0;
        } else {
            stalled += 1;
            if stalled >= stall_limit {
                break;
            }
        }
    }
    None
}

/// Grow one net's Steiner tree by repeated nearest-sink attachment and
/// write its per-edge paths into `paths`.
///
/// Each attachment is a multi-source A*: every tree cell seeds the
/// frontier at cost 0 and the search terminates at the first (=
/// cheapest) unconnected sink it pops, so the nearest sink attaches to
/// whatever trunk already exists — riding the tree is free, which is
/// exactly the fan-out sharing the legacy router only approximates with
/// its 0.01 reuse discount. The admissible heuristic is the cheapest
/// `min_hops` to any unconnected sink. Tree links are recorded
/// word-parallel in the arena's [`LinkSet`] and counted once into the
/// round's usage table, whatever the fan-out.
#[allow(clippy::too_many_arguments)]
fn route_net_tree(
    f: &Fabric,
    net: &Net,
    placement: &[CellId],
    dfg: &Dfg,
    crit_factor: f64,
    cfg: &MapperConfig,
    arena: &mut RouterArena,
    paths: &mut [Vec<CellId>],
) {
    arena.begin_tree();
    let gen = arena.tree_gen;
    arena.tree_stamp[net.src_cell as usize] = gen;
    arena.tree_parent[net.src_cell as usize] = net.src_cell;
    let mut tree_cells: Vec<CellId> = vec![net.src_cell];
    let mut remaining: Vec<CellId> = net.sinks.clone();
    let free_streams = f.link_cap().saturating_sub(1);

    while !remaining.is_empty() {
        arena.astar.begin();
        let mut heap = BinaryHeap::with_capacity(arena.astar.capacity_hint());
        let h = |c: CellId| -> f64 {
            remaining.iter().map(|&s| f.min_hops(c, s)).min().unwrap_or(0) as f64 * 0.999
        };
        for &tc in &tree_cells {
            arena.astar.set(tc as usize, 0.0, tc);
            heap.push(HeapEntry { priority: h(tc), cost: 0.0, cell: tc });
        }
        let mut found: Option<CellId> = None;
        while let Some(HeapEntry { cost, cell, .. }) = heap.pop() {
            if remaining.contains(&cell) {
                found = Some(cell);
                break;
            }
            if cost > arena.astar.get_dist(cell as usize) {
                continue;
            }
            for d in 0..f.num_dirs() {
                let Some(next) = f.neighbor(cell, d) else { continue };
                let link = f.link(cell, d);
                // other nets' streams on this link price it; this net's
                // own trunk is free by construction (tree cells seed the
                // frontier at cost 0, so trunk links are never re-paid)
                let shared = arena.usage[link] as usize;
                let step = 1.0
                    + (arena.history[link]
                        + cfg.present_penalty * shared.saturating_sub(free_streams) as f64)
                        * crit_factor;
                let nc = cost + step;
                if nc < arena.astar.get_dist(next as usize) {
                    arena.astar.set(next as usize, nc, cell);
                    heap.push(HeapEntry { priority: nc + h(next), cost: nc, cell: next });
                }
            }
        }
        let sink = found.expect("fabric is connected; every sink is reachable");
        // splice the new branch: walk the search parents back to the
        // attachment point, recording tree parents and trunk links
        let mut cur = sink;
        while arena.tree_stamp[cur as usize] != gen {
            let prev = arena.astar.prev[cur as usize];
            debug_assert!(prev != u16::MAX, "branch must reach the tree");
            arena.tree_parent[cur as usize] = prev;
            arena.tree_stamp[cur as usize] = gen;
            tree_cells.push(cur);
            let link = f.link(prev, direction(f, prev, cur));
            if !arena.tree_links.contains(link) {
                arena.tree_links.insert(link);
                arena.usage[link] += 1;
            }
            cur = prev;
        }
        remaining.retain(|&s| s != sink);
    }

    // per-edge paths: walk tree parents from each sink back to the
    // source (parallel edges to one sink share the same trunk path)
    for &ei in &net.edges {
        let (_, dn) = dfg.edges[ei];
        let dst = placement[dn as usize];
        let mut path = Vec::with_capacity(f.min_hops(net.src_cell, dst) + 1);
        path.push(dst);
        let mut cur = dst;
        while cur != net.src_cell {
            cur = arena.tree_parent[cur as usize];
            path.push(cur);
        }
        path.reverse();
        paths[ei] = path;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::ops::{GroupSet, Op};

    fn straight_line_dfg() -> (Dfg, Layout, Vec<CellId>) {
        // load(0) -> add(1) -> store(2), placed in a row
        let d = Dfg::new("line", vec![Op::Load, Op::Add, Op::Store], vec![(0, 1), (1, 2)]);
        let l = Layout::full(Grid::new(5, 5), GroupSet::all_compute());
        let g = &l.grid;
        let placement = vec![g.cell(2, 0), g.cell(2, 2), g.cell(2, 4)];
        (d, l, placement)
    }

    #[test]
    fn routes_straight_line() {
        let (d, l, p) = straight_line_dfg();
        match route(&d, &l, &p, &MapperConfig::default()) {
            RouteOutcome::Routed(paths) => {
                assert_eq!(paths[0].first(), Some(&p[0]));
                assert_eq!(paths[0].last(), Some(&p[1]));
                // shortest path length = manhattan + 1 cells
                assert_eq!(paths[0].len(), 3);
                assert_eq!(paths[1].len(), 3);
            }
            RouteOutcome::Congested { .. } => panic!("line must route"),
        }
    }

    #[test]
    fn fanout_shares_links() {
        // one load feeding two adjacent consumers: the shared prefix may
        // overlap on the same link without counting as congestion.
        let d = Dfg::new(
            "fan",
            vec![Op::Load, Op::Add, Op::Add, Op::Store, Op::Store],
            vec![(0, 1), (0, 2), (1, 3), (2, 4)],
        );
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        let g = &l.grid;
        let p = vec![g.cell(0, 2), g.cell(3, 2), g.cell(3, 3), g.cell(5, 2), g.cell(5, 3)];
        match route(&d, &l, &p, &MapperConfig::default()) {
            RouteOutcome::Routed(_) => {}
            RouteOutcome::Congested { .. } => panic!("fanout must route"),
        }
    }

    #[test]
    fn distinct_values_avoid_link_overlap() {
        // two independent chains crossing the grid: router must keep
        // their links disjoint.
        let d = Dfg::new(
            "cross",
            vec![Op::Load, Op::Load, Op::Add, Op::Add, Op::Store, Op::Store],
            vec![(0, 2), (1, 3), (2, 4), (3, 5)],
        );
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        let g = &l.grid;
        let p = vec![
            g.cell(0, 1),
            g.cell(0, 3),
            g.cell(3, 3), // crosses
            g.cell(3, 1), // crosses
            g.cell(5, 3),
            g.cell(5, 1),
        ];
        match route(&d, &l, &p, &MapperConfig::default()) {
            RouteOutcome::Routed(paths) => {
                // verify capacity invariant with the Mapping validator
                let m = crate::mapper::Mapping {
                    node_cell: p,
                    edge_paths: paths,
                    reserved: vec![],
                };
                assert!(m.validate(&d, &l).is_empty());
            }
            RouteOutcome::Congested { .. } => panic!("cross must route"),
        }
    }

    #[test]
    fn astar_finds_shortest_path_uncongested() {
        let g = Grid::new(8, 8);
        let f = Fabric::mesh4(g);
        let mut buf = AStarBuffers::new(g.num_cells());
        let usage = vec![LinkUse::default(); f.num_links()];
        let history = vec![0.0; f.num_links()];
        let cfg = MapperConfig::default();
        for (a, b) in [((1, 1), (6, 6)), ((0, 0), (7, 3)), ((4, 4), (4, 4))] {
            let src = g.cell(a.0, a.1);
            let dst = g.cell(b.0, b.1);
            let p = astar(&f, src, dst, 0, true, &usage, &history, &cfg, &mut buf);
            assert_eq!(p.len(), g.manhattan(src, dst) + 1, "{a:?}->{b:?}");
        }
    }

    #[test]
    fn buffers_reuse_across_generations() {
        let g = Grid::new(5, 5);
        let f = Fabric::mesh4(g);
        let mut buf = AStarBuffers::new(g.num_cells());
        let usage = vec![LinkUse::default(); f.num_links()];
        let history = vec![0.0; f.num_links()];
        let cfg = MapperConfig::default();
        let p1 = astar(&f, g.cell(0, 0), g.cell(4, 4), 0, true, &usage, &history, &cfg, &mut buf);
        let p2 = astar(&f, g.cell(4, 0), g.cell(0, 4), 1, true, &usage, &history, &cfg, &mut buf);
        assert_eq!(p1.len(), 9);
        assert_eq!(p2.len(), 9);
    }

    #[test]
    fn direction_helper() {
        let g = Grid::new(4, 4);
        let f = Fabric::mesh4(g);
        assert_eq!(direction(&f, g.cell(1, 1), g.cell(0, 1)), 0);
        assert_eq!(direction(&f, g.cell(1, 1), g.cell(1, 2)), 1);
    }

    #[test]
    fn route_partial_keeps_fixed_paths_pinned() {
        // route everything, then move one consumer and re-route only its
        // incident edge: the other paths must come back byte-identical.
        let d = Dfg::new(
            "pin",
            vec![Op::Load, Op::Load, Op::Add, Op::Add, Op::Store, Op::Store],
            vec![(0, 2), (1, 3), (2, 4), (3, 5)],
        );
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        let g = &l.grid;
        let mut p = vec![
            g.cell(0, 1),
            g.cell(0, 4),
            g.cell(2, 1),
            g.cell(2, 4),
            g.cell(5, 1),
            g.cell(5, 4),
        ];
        let cfg = MapperConfig::default();
        let RouteOutcome::Routed(paths) = route(&d, &l, &p, &cfg) else {
            panic!("must route");
        };
        // displace node 3 one cell left and re-route its edges (1 and 3)
        p[3] = g.cell(2, 3);
        let new = route_partial(&d, &l, &p, &paths, &[1, 3], &cfg).expect("partial");
        assert_eq!(new[0], paths[0], "unaffected edge 0 must stay pinned");
        assert_eq!(new[2], paths[2], "unaffected edge 2 must stay pinned");
        assert_eq!(new[1].first(), Some(&p[1]));
        assert_eq!(new[1].last(), Some(&p[3]));
        assert_eq!(new[3].first(), Some(&p[3]));
        assert_eq!(new[3].last(), Some(&p[5]));
        // the full mapping still satisfies every invariant
        let m = crate::mapper::Mapping { node_cell: p, edge_paths: new, reserved: vec![] };
        assert!(m.validate(&d, &l).is_empty());
    }

    #[test]
    fn route_partial_avoids_links_taken_by_fixed_paths() {
        // a straight corridor owned by a pinned path forces the re-routed
        // edge to detour rather than overlap it.
        let d = Dfg::new(
            "detour",
            vec![Op::Load, Op::Load, Op::Add, Op::Add, Op::Store, Op::Store],
            vec![(0, 2), (1, 3), (2, 4), (3, 5)],
        );
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        let g = &l.grid;
        let p = vec![
            g.cell(2, 0),
            g.cell(1, 0),
            g.cell(2, 4),
            g.cell(2, 2),
            g.cell(5, 4),
            g.cell(5, 2),
        ];
        let cfg = MapperConfig::default();
        let RouteOutcome::Routed(paths) = route(&d, &l, &p, &cfg) else {
            panic!("must route");
        };
        // re-route edge 1 (load(1,0) -> add(2,2)) while edge 0 pins the
        // row-2 corridor; the result must still be overlap-free.
        let new = route_partial(&d, &l, &p, &paths, &[1], &cfg).expect("partial");
        let m = crate::mapper::Mapping { node_cell: p, edge_paths: new, reserved: vec![] };
        assert!(m.validate(&d, &l).is_empty());
    }

    #[test]
    fn congested_outcome_reports_hot_links() {
        // Four distinct values must cross the cut between columns 3 and 4
        // eastbound, but a 3-row grid has only 3 eastbound links per cut:
        // at least one link is shared, so routing must report congestion.
        let d = Dfg::new(
            "jam",
            vec![
                Op::Load,
                Op::Load,
                Op::Load,
                Op::Load,
                Op::Add,
                Op::Add,
                Op::Add,
                Op::Add,
                Op::Store,
                Op::Store,
                Op::Store,
                Op::Store,
            ],
            vec![(0, 4), (1, 5), (2, 6), (3, 7), (4, 8), (5, 9), (6, 10), (7, 11)],
        );
        let l = Layout::full(Grid::new(3, 9), GroupSet::all_compute());
        let g = &l.grid;
        let p = vec![
            g.cell(0, 0),
            g.cell(0, 1),
            g.cell(0, 2),
            g.cell(0, 3),
            g.cell(1, 4),
            g.cell(1, 5),
            g.cell(1, 6),
            g.cell(1, 7),
            g.cell(2, 4),
            g.cell(2, 5),
            g.cell(2, 6),
            g.cell(2, 7),
        ];
        match route(&d, &l, &p, &MapperConfig { route_iters: 3, ..Default::default() }) {
            RouteOutcome::Routed(_) => panic!("4 values cannot fit a 3-link cut"),
            RouteOutcome::Congested { hot_links, overuse, .. } => {
                assert!(!hot_links.is_empty(), "congestion must name links");
                assert!(overuse > 0);
                assert!(hot_links.iter().all(|&l| l < g.num_links()));
            }
        }
    }

    /// The jam DFG and its placement on a given fabric (see
    /// `congested_outcome_reports_hot_links` for why Mesh4 congests).
    fn jam_on(fabric: crate::fabric::Fabric) -> (Dfg, Layout, Vec<CellId>) {
        let d = Dfg::new(
            "jam",
            vec![
                Op::Load,
                Op::Load,
                Op::Load,
                Op::Load,
                Op::Add,
                Op::Add,
                Op::Add,
                Op::Add,
                Op::Store,
                Op::Store,
                Op::Store,
                Op::Store,
            ],
            vec![(0, 4), (1, 5), (2, 6), (3, 7), (4, 8), (5, 9), (6, 10), (7, 11)],
        );
        let l = Layout::full_on(fabric, GroupSet::all_compute());
        let g = &l.grid;
        let p = vec![
            g.cell(0, 0),
            g.cell(0, 1),
            g.cell(0, 2),
            g.cell(0, 3),
            g.cell(1, 4),
            g.cell(1, 5),
            g.cell(1, 6),
            g.cell(1, 7),
            g.cell(2, 4),
            g.cell(2, 5),
            g.cell(2, 6),
            g.cell(2, 7),
        ];
        (d, l, p)
    }

    fn steiner_cfg() -> MapperConfig {
        MapperConfig { router_steiner: true, ..Default::default() }
    }

    #[test]
    fn steiner_routes_straight_line() {
        let (d, l, p) = straight_line_dfg();
        let mut arena = RouterArena::new();
        match steiner_route(&d, &l, &p, &steiner_cfg(), &mut arena) {
            RouteOutcome::Routed(paths) => {
                assert_eq!(paths[0].first(), Some(&p[0]));
                assert_eq!(paths[0].last(), Some(&p[1]));
                assert_eq!(paths[0].len(), 3);
                assert_eq!(paths[1].len(), 3);
            }
            RouteOutcome::Congested { .. } => panic!("line must route"),
        }
    }

    #[test]
    fn steiner_fanout_shares_one_trunk() {
        // one load feeding two consumers two rows apart: the tree must
        // route both sinks, and the trunk prefix is shared by
        // construction — each tree link is counted once, so the total
        // distinct links used stay at most the sum of both sink walks.
        let d = Dfg::new(
            "fan",
            vec![Op::Load, Op::Add, Op::Add, Op::Store, Op::Store],
            vec![(0, 1), (0, 2), (1, 3), (2, 4)],
        );
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        let g = &l.grid;
        let p = vec![g.cell(0, 2), g.cell(3, 2), g.cell(3, 3), g.cell(5, 2), g.cell(5, 3)];
        let mut arena = RouterArena::new();
        match steiner_route(&d, &l, &p, &steiner_cfg(), &mut arena) {
            RouteOutcome::Routed(paths) => {
                let m = crate::mapper::Mapping {
                    node_cell: p.clone(),
                    edge_paths: paths.clone(),
                    reserved: vec![],
                };
                assert!(m.validate(&d, &l).is_empty());
                // both fan-out paths leave the source over the SAME first
                // link: the trunk is shared, not re-derived per edge
                assert_eq!(paths[0][1], paths[1][1], "fan-out must share its trunk");
            }
            RouteOutcome::Congested { .. } => panic!("fanout must route"),
        }
    }

    #[test]
    fn steiner_deterministic_and_arena_reusable() {
        let (d, l, p) = straight_line_dfg();
        let cfg = steiner_cfg();
        let mut arena = RouterArena::new();
        let RouteOutcome::Routed(a) = steiner_route(&d, &l, &p, &cfg, &mut arena) else {
            panic!("must route");
        };
        // same arena, different grid size, then back: stamps must keep
        // reuse sound
        let d2 = Dfg::new("line2", vec![Op::Load, Op::Add, Op::Store], vec![(0, 1), (1, 2)]);
        let l2 = Layout::full(Grid::new(8, 8), GroupSet::all_compute());
        let g2 = &l2.grid;
        let p2 = vec![g2.cell(3, 0), g2.cell(3, 4), g2.cell(3, 7)];
        assert!(matches!(
            steiner_route(&d2, &l2, &p2, &cfg, &mut arena),
            RouteOutcome::Routed(_)
        ));
        let RouteOutcome::Routed(b) = steiner_route(&d, &l, &p, &cfg, &mut arena) else {
            panic!("must route");
        };
        assert_eq!(a, b, "arena reuse must not change results");
    }

    #[test]
    fn steiner_reports_jam_congestion() {
        let (d, l, p) = jam_on(Fabric::mesh4(Grid::new(3, 9)));
        let cfg = MapperConfig { route_iters: 3, ..steiner_cfg() };
        let mut arena = RouterArena::new();
        match steiner_route(&d, &l, &p, &cfg, &mut arena) {
            RouteOutcome::Routed(_) => panic!("4 values cannot fit a 3-link cut"),
            RouteOutcome::Congested { hot_links, overuse, .. } => {
                assert!(!hot_links.is_empty());
                assert!(overuse > 0);
            }
        }
    }

    #[test]
    fn steiner_clears_jam_with_capacity_and_express() {
        use crate::fabric::{FabricSpec, Topology};
        let mut arena = RouterArena::new();
        let cfg = MapperConfig { route_iters: 3, ..steiner_cfg() };
        for spec in [
            FabricSpec { link_cap: 2, ..FabricSpec::default() },
            FabricSpec { topology: Topology::Express { stride: 2 }, ..FabricSpec::default() },
        ] {
            let (d, l, p) = jam_on(Fabric::new(Grid::new(3, 9), spec));
            match steiner_route(&d, &l, &p, &cfg, &mut arena) {
                RouteOutcome::Routed(paths) => {
                    let m = crate::mapper::Mapping {
                        node_cell: p,
                        edge_paths: paths,
                        reserved: vec![],
                    };
                    assert!(m.validate(&d, &l).is_empty());
                }
                RouteOutcome::Congested { .. } => panic!("provisioned fabric must clear the jam"),
            }
        }
    }

    #[test]
    fn steiner_criticality_still_validates() {
        // a diamond with a long and a short arm: criticality weighting
        // must only re-weight costs, never produce invalid routes
        let d = Dfg::new(
            "diamond",
            vec![Op::Load, Op::Add, Op::Mul, Op::Add, Op::Add, Op::Store],
            vec![(0, 1), (0, 2), (1, 3), (3, 4), (2, 4), (4, 5)],
        );
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        let g = &l.grid;
        let p = vec![
            g.cell(0, 2),
            g.cell(1, 1),
            g.cell(1, 3),
            g.cell(2, 1),
            g.cell(3, 2),
            g.cell(5, 2),
        ];
        let cfg = MapperConfig { router_criticality: true, ..steiner_cfg() };
        let mut arena = RouterArena::new();
        match steiner_route(&d, &l, &p, &cfg, &mut arena) {
            RouteOutcome::Routed(paths) => {
                let m = crate::mapper::Mapping { node_cell: p, edge_paths: paths, reserved: vec![] };
                assert!(m.validate(&d, &l).is_empty());
            }
            RouteOutcome::Congested { .. } => panic!("diamond must route"),
        }
    }

    #[test]
    fn steiner_partial_pins_untouched_nets() {
        // same scenario as route_partial_keeps_fixed_paths_pinned, but
        // net-granular: the net of the untouched source keeps its path
        let d = Dfg::new(
            "pin",
            vec![Op::Load, Op::Load, Op::Add, Op::Add, Op::Store, Op::Store],
            vec![(0, 2), (1, 3), (2, 4), (3, 5)],
        );
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        let g = &l.grid;
        let mut p = vec![
            g.cell(0, 1),
            g.cell(0, 4),
            g.cell(2, 1),
            g.cell(2, 4),
            g.cell(5, 1),
            g.cell(5, 4),
        ];
        let cfg = steiner_cfg();
        let mut arena = RouterArena::new();
        let RouteOutcome::Routed(paths) = steiner_route(&d, &l, &p, &cfg, &mut arena) else {
            panic!("must route");
        };
        // displace node 3 and reroute its incident edges (1 and 3)
        p[3] = g.cell(2, 3);
        let new = steiner_route_partial(&d, &l, &p, &paths, &[1, 3], &cfg, &mut arena)
            .expect("partial");
        assert_eq!(new[0], paths[0], "net of node 0 untouched: edge 0 pinned");
        assert_eq!(new[2], paths[2], "net of node 2 untouched: edge 2 pinned");
        assert_eq!(new[1].first(), Some(&p[1]));
        assert_eq!(new[1].last(), Some(&p[3]));
        let m = crate::mapper::Mapping { node_cell: p, edge_paths: new, reserved: vec![] };
        assert!(m.validate(&d, &l).is_empty());
    }

    #[test]
    fn node_criticality_peaks_on_the_long_arm() {
        // 0 -> 1 -> 2 -> 4 (long arm), 0 -> 3 -> 4 (short arm)
        let d = Dfg::new(
            "crit",
            vec![Op::Load, Op::Add, Op::Mul, Op::Add, Op::Store],
            vec![(0, 1), (1, 2), (2, 4), (0, 3), (3, 4)],
        );
        let c = node_criticality(&d);
        assert_eq!(c[0], 1.0, "source sits on the critical path");
        assert_eq!(c[1], 1.0);
        assert_eq!(c[2], 1.0);
        assert_eq!(c[4], 1.0, "sink sits on the critical path");
        assert!(c[3] < 1.0, "the short arm has slack: {}", c[3]);
    }

    #[test]
    fn route_rounds_reports_ripups() {
        let (d, l, p) = straight_line_dfg();
        let cfg = MapperConfig::default();
        let (out, rounds) = route_rounds(&d, &l, &p, &cfg);
        assert!(matches!(out, RouteOutcome::Routed(_)));
        assert_eq!(rounds, 1, "an uncongested line converges in one round");
        let mut arena = RouterArena::new();
        let (out, rounds) = steiner_route_rounds(&d, &l, &p, &steiner_cfg(), &mut arena);
        assert!(matches!(out, RouteOutcome::Routed(_)));
        assert_eq!(rounds, 1);
    }

    #[test]
    fn link_capacity_two_clears_the_jam() {
        use crate::fabric::FabricSpec;
        let spec = FabricSpec { link_cap: 2, ..FabricSpec::default() };
        let (d, l, p) = jam_on(Fabric::new(Grid::new(3, 9), spec));
        let cfg = MapperConfig { route_iters: 3, ..Default::default() };
        match route(&d, &l, &p, &cfg) {
            RouteOutcome::Routed(paths) => {
                let m = crate::mapper::Mapping { node_cell: p, edge_paths: paths, reserved: vec![] };
                assert!(m.validate(&d, &l).is_empty());
            }
            RouteOutcome::Congested { .. } => panic!("a 2-capacity cut carries 6 streams"),
        }
    }

    #[test]
    fn express_links_clear_the_jam() {
        use crate::fabric::{FabricSpec, Topology};
        let spec =
            FabricSpec { topology: Topology::Express { stride: 2 }, ..FabricSpec::default() };
        let (d, l, p) = jam_on(Fabric::new(Grid::new(3, 9), spec));
        let cfg = MapperConfig { route_iters: 3, ..Default::default() };
        match route(&d, &l, &p, &cfg) {
            RouteOutcome::Routed(paths) => {
                let m = crate::mapper::Mapping { node_cell: p, edge_paths: paths, reserved: vec![] };
                assert!(m.validate(&d, &l).is_empty());
            }
            RouteOutcome::Congested { .. } => panic!("express overlay doubles the cut"),
        }
    }
}
