//! Negotiated-congestion routing over the layout's switch network.
//!
//! PathFinder-style: every routing round rips up all paths and re-routes
//! each edge by A* search, where a link's cost is
//! `base + history + present_penalty * overuse`. The network is whatever
//! the layout's [`crate::fabric::Fabric`] provisions — the legacy 4NN
//! mesh by default, optionally with diagonal or express links and a
//! per-link capacity above one. A link carries `link_cap` distinct value
//! streams before counting as overused, and edges with the same source
//! share links for free (fan-out of the same value). History accumulates
//! on overused links between rounds, pushing later rounds around
//! persistent congestion; negotiation exits early when total overuse
//! stops improving.
//!
//! If congestion survives, the most-overused link's adjacent occupied
//! compute cell is reported as the `hot_cell` so the driver can apply
//! reserve-on-demand.
//!
//! Perf notes (EXPERIMENTS.md §Perf): the A* heuristic is the fabric's
//! minimum hop count when the edge's source drives no links yet (every
//! remaining hop then costs ≥ 1), and the 0.01-reuse floor otherwise —
//! both admissible. Distance/parent arrays are reused across calls via
//! generation stamps instead of reallocation.

use crate::cgra::{CellId, Layout};
use crate::fabric::Fabric;
use crate::dfg::Dfg;
use crate::mapper::MapperConfig;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Outcome of a routing attempt.
pub enum RouteOutcome {
    Routed(Vec<Vec<CellId>>),
    /// Still congested; `hot_cell` is the recommended reservation target,
    /// `hot_links` the overused link ids of the final round (hottest
    /// first, for diagnostics), and `overuse` the best (lowest) total
    /// link overuse seen — the driver uses it to detect reserves that
    /// are not helping.
    Congested { hot_cell: CellId, hot_links: Vec<usize>, overuse: usize },
}

#[derive(PartialEq)]
struct HeapEntry {
    /// cost-so-far + admissible heuristic
    priority: f64,
    cost: f64,
    cell: CellId,
}

impl Eq for HeapEntry {}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on priority, tie-break on cell id for determinism
        other
            .priority
            .partial_cmp(&self.priority)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.cell.cmp(&self.cell))
    }
}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Per-link usage bookkeeping: which source nodes currently drive a link.
#[derive(Clone, Default)]
struct LinkUse {
    srcs: Vec<u32>, // distinct DFG source nodes using this link
}

impl LinkUse {
    /// Streams beyond the link's capacity (`cap` distinct values ride
    /// for free; the legacy mesh has `cap == 1`).
    fn overuse(&self, cap: usize) -> usize {
        self.srcs.len().saturating_sub(cap)
    }
    fn has(&self, s: u32) -> bool {
        self.srcs.contains(&s)
    }
    fn add(&mut self, s: u32) {
        if !self.has(s) {
            self.srcs.push(s);
        }
    }
}

/// Reusable A* scratch buffers (generation-stamped to skip clearing).
struct AStarBuffers {
    dist: Vec<f64>,
    prev: Vec<CellId>,
    stamp: Vec<u32>,
    generation: u32,
}

impl AStarBuffers {
    fn new(n: usize) -> Self {
        Self {
            dist: vec![f64::INFINITY; n],
            prev: vec![u16::MAX; n],
            stamp: vec![0; n],
            generation: 0,
        }
    }
    fn begin(&mut self) {
        self.generation += 1;
    }
    #[inline]
    fn get_dist(&self, c: usize) -> f64 {
        if self.stamp[c] == self.generation {
            self.dist[c]
        } else {
            f64::INFINITY
        }
    }
    #[inline]
    fn set(&mut self, c: usize, d: f64, p: CellId) {
        self.dist[c] = d;
        self.prev[c] = p;
        self.stamp[c] = self.generation;
    }
}

/// Route all edges of a placed DFG.
pub fn route(
    dfg: &Dfg,
    layout: &Layout,
    placement: &[CellId],
    cfg: &MapperConfig,
) -> RouteOutcome {
    let g = &layout.grid;
    let f = layout.fabric();
    let nlinks = f.num_links();
    let cap = f.link_cap();
    let mut history = vec![0.0f64; nlinks];

    // Route longer edges first: they have fewer detour options.
    let mut order: Vec<usize> = (0..dfg.edges.len()).collect();
    order.sort_by_key(|&i| {
        let (s, d) = dfg.edges[i];
        std::cmp::Reverse(
            f.min_hops(placement[s as usize], placement[d as usize]) as u32 * 1000 + i as u32,
        )
    });

    let mut paths: Vec<Vec<CellId>> = vec![Vec::new(); dfg.edges.len()];
    let mut last_usage: Vec<LinkUse> = vec![LinkUse::default(); nlinks];
    let mut buffers = AStarBuffers::new(g.num_cells());
    // links-per-source count this round: a source with zero links admits
    // the strong (min-hops) heuristic.
    let mut src_links: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
    // early-exit when negotiation stalls: if total overuse has not
    // improved for `stall_limit` rounds, more rounds will not help and
    // the caller should reserve a cell instead.
    let mut best_overuse = usize::MAX;
    let mut stalled = 0usize;
    let stall_limit = 3;

    for _round in 0..cfg.route_iters {
        let mut usage: Vec<LinkUse> = vec![LinkUse::default(); nlinks];
        src_links.clear();
        for &ei in &order {
            let (sn, dn) = dfg.edges[ei];
            let (src, dst) = (placement[sn as usize], placement[dn as usize]);
            let strong_heuristic = src_links.get(&sn).copied().unwrap_or(0) == 0;
            let path = astar(
                f,
                src,
                dst,
                sn,
                strong_heuristic,
                &usage,
                &history,
                cfg,
                &mut buffers,
            );
            for w in path.windows(2) {
                let dir = direction(f, w[0], w[1]);
                usage[f.link(w[0], dir)].add(sn);
            }
            *src_links.entry(sn).or_insert(0) += path.len().saturating_sub(1) as u32;
            paths[ei] = path;
        }
        // converged?
        let over: Vec<usize> =
            (0..nlinks).filter(|&l| usage[l].overuse(cap) > 0).collect();
        if over.is_empty() {
            return RouteOutcome::Routed(paths);
        }
        // accumulate history on overused links
        let mut total_overuse = 0;
        for &l in &over {
            history[l] += cfg.hist_increment * usage[l].overuse(cap) as f64;
            total_overuse += usage[l].overuse(cap);
        }
        last_usage = usage;
        if total_overuse < best_overuse {
            best_overuse = total_overuse;
            stalled = 0;
        } else {
            stalled += 1;
            if stalled >= stall_limit {
                break; // negotiation stalled; hand over to reserve-on-demand
            }
        }
    }

    // Pick the hottest link and suggest reserving an adjacent occupied
    // compute cell (RodMap's reserve-on-demand trigger).
    let mut hot_links: Vec<usize> =
        (0..nlinks).filter(|&l| last_usage[l].overuse(cap) > 0).collect();
    // hottest first; ties resolve to the highest link id (same pick as
    // the previous `max_by_key`, which kept the last maximal element)
    hot_links.sort_by_key(|&l| {
        (std::cmp::Reverse(last_usage[l].overuse(cap)), std::cmp::Reverse(l))
    });
    let hottest = hot_links.first().copied().unwrap_or(0);
    let cell = (hottest / f.num_dirs()) as CellId;
    let dir = hottest % f.num_dirs();
    let occupied: Vec<CellId> = placement.to_vec();
    let candidates = [Some(cell), f.neighbor(cell, dir)];
    let hot_cell = candidates
        .into_iter()
        .flatten()
        .chain(f.neighbors(cell))
        .find(|&c| g.is_compute(c) && occupied.contains(&c))
        .unwrap_or(cell);
    RouteOutcome::Congested { hot_cell, hot_links, overuse: best_overuse }
}

/// Incremental rip-up-and-reroute: re-route only the `affected` edges of
/// a placed DFG, keeping every other edge's path in `fixed_paths` pinned
/// (their link usage is seeded into every negotiation round and never
/// ripped up). Used by the warm-start remapping path, where support
/// removal displaces a few nodes and only their incident edges need new
/// routes. Returns the complete path set (fixed paths untouched) once
/// overuse reaches zero, or `None` if negotiation cannot clear the
/// congestion — the caller then falls back to from-scratch mapping.
pub fn route_partial(
    dfg: &Dfg,
    layout: &Layout,
    placement: &[CellId],
    fixed_paths: &[Vec<CellId>],
    affected: &[usize],
    cfg: &MapperConfig,
) -> Option<Vec<Vec<CellId>>> {
    let g = &layout.grid;
    let f = layout.fabric();
    let nlinks = f.num_links();
    let cap = f.link_cap();
    let mut affected_mask = vec![false; dfg.edges.len()];
    for &ei in affected {
        affected_mask[ei] = true;
    }

    // Usage contributed by the pinned paths: constant across rounds.
    let mut fixed_usage: Vec<LinkUse> = vec![LinkUse::default(); nlinks];
    let mut fixed_src_links: std::collections::HashMap<u32, u32> =
        std::collections::HashMap::new();
    for (ei, &(s, _)) in dfg.edges.iter().enumerate() {
        if affected_mask[ei] {
            continue;
        }
        for w in fixed_paths[ei].windows(2) {
            let dir = direction(f, w[0], w[1]);
            fixed_usage[f.link(w[0], dir)].add(s);
        }
        *fixed_src_links.entry(s).or_insert(0) +=
            fixed_paths[ei].len().saturating_sub(1) as u32;
    }

    // Longest affected edges first, as in the full router.
    let mut order: Vec<usize> = affected.to_vec();
    order.sort_by_key(|&i| {
        let (s, d) = dfg.edges[i];
        std::cmp::Reverse(
            f.min_hops(placement[s as usize], placement[d as usize]) as u32 * 1000 + i as u32,
        )
    });

    let mut history = vec![0.0f64; nlinks];
    let mut buffers = AStarBuffers::new(g.num_cells());
    let mut paths = fixed_paths.to_vec();
    let mut best_overuse = usize::MAX;
    let mut stalled = 0usize;
    let stall_limit = 3;

    for _round in 0..cfg.route_iters {
        let mut usage = fixed_usage.clone();
        let mut src_links = fixed_src_links.clone();
        for &ei in &order {
            let (sn, dn) = dfg.edges[ei];
            let (src, dst) = (placement[sn as usize], placement[dn as usize]);
            let strong_heuristic = src_links.get(&sn).copied().unwrap_or(0) == 0;
            let path = astar(
                f,
                src,
                dst,
                sn,
                strong_heuristic,
                &usage,
                &history,
                cfg,
                &mut buffers,
            );
            for w in path.windows(2) {
                let dir = direction(f, w[0], w[1]);
                usage[f.link(w[0], dir)].add(sn);
            }
            *src_links.entry(sn).or_insert(0) += path.len().saturating_sub(1) as u32;
            paths[ei] = path;
        }
        let mut total_overuse = 0;
        for l in 0..nlinks {
            let o = usage[l].overuse(cap);
            if o > 0 {
                history[l] += cfg.hist_increment * o as f64;
                total_overuse += o;
            }
        }
        if total_overuse == 0 {
            return Some(paths);
        }
        if total_overuse < best_overuse {
            best_overuse = total_overuse;
            stalled = 0;
        } else {
            stalled += 1;
            if stalled >= stall_limit {
                break;
            }
        }
    }
    None
}

/// Direction index such that `f.neighbor(a, dir) == b`.
fn direction(f: &Fabric, a: CellId, b: CellId) -> usize {
    f.direction(a, b).expect("cells must be adjacent")
}

/// A* from `src` to `dst` for the value produced by node `src_node`.
///
/// Heuristic: the fabric's minimum hop count when the source drives no
/// links yet this round (every remaining step costs at least the base
/// 1.0), else `0.01 * min_hops` (a route could in principle ride reused
/// links the whole way at the reuse floor). Both are admissible, so
/// paths are optimal under the current penalty landscape.
#[allow(clippy::too_many_arguments)]
fn astar(
    f: &Fabric,
    src: CellId,
    dst: CellId,
    src_node: u32,
    strong_heuristic: bool,
    usage: &[LinkUse],
    history: &[f64],
    cfg: &MapperConfig,
    buf: &mut AStarBuffers,
) -> Vec<CellId> {
    let h_scale = if strong_heuristic { 0.999 } else { 0.01 };
    let h = |c: CellId| f.min_hops(c, dst) as f64 * h_scale;
    let free_streams = f.link_cap().saturating_sub(1);
    buf.begin();
    let mut heap = BinaryHeap::with_capacity(64);
    buf.set(src as usize, 0.0, src);
    heap.push(HeapEntry { priority: h(src), cost: 0.0, cell: src });
    while let Some(HeapEntry { cost, cell, .. }) = heap.pop() {
        if cell == dst {
            break;
        }
        if cost > buf.get_dist(cell as usize) {
            continue;
        }
        for d in 0..f.num_dirs() {
            let Some(next) = f.neighbor(cell, d) else { continue };
            let link = f.link(cell, d);
            let u = &usage[link];
            // same-source reuse is nearly free (fan-out broadcast);
            // below-capacity sharing pays no present penalty; otherwise
            // pay base + congestion penalties.
            let step = if u.has(src_node) {
                0.01
            } else {
                1.0 + history[link]
                    + cfg.present_penalty * u.srcs.len().saturating_sub(free_streams) as f64
            };
            let nc = cost + step;
            if nc < buf.get_dist(next as usize) {
                buf.set(next as usize, nc, cell);
                heap.push(HeapEntry { priority: nc + h(next), cost: nc, cell: next });
            }
        }
    }
    // reconstruct
    let mut path = vec![dst];
    let mut cur = dst;
    while cur != src {
        cur = buf.prev[cur as usize];
        debug_assert!(cur != u16::MAX, "grid is connected; path must exist");
        path.push(cur);
    }
    path.reverse();
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::ops::{GroupSet, Op};

    fn straight_line_dfg() -> (Dfg, Layout, Vec<CellId>) {
        // load(0) -> add(1) -> store(2), placed in a row
        let d = Dfg::new("line", vec![Op::Load, Op::Add, Op::Store], vec![(0, 1), (1, 2)]);
        let l = Layout::full(Grid::new(5, 5), GroupSet::all_compute());
        let g = &l.grid;
        let placement = vec![g.cell(2, 0), g.cell(2, 2), g.cell(2, 4)];
        (d, l, placement)
    }

    #[test]
    fn routes_straight_line() {
        let (d, l, p) = straight_line_dfg();
        match route(&d, &l, &p, &MapperConfig::default()) {
            RouteOutcome::Routed(paths) => {
                assert_eq!(paths[0].first(), Some(&p[0]));
                assert_eq!(paths[0].last(), Some(&p[1]));
                // shortest path length = manhattan + 1 cells
                assert_eq!(paths[0].len(), 3);
                assert_eq!(paths[1].len(), 3);
            }
            RouteOutcome::Congested { .. } => panic!("line must route"),
        }
    }

    #[test]
    fn fanout_shares_links() {
        // one load feeding two adjacent consumers: the shared prefix may
        // overlap on the same link without counting as congestion.
        let d = Dfg::new(
            "fan",
            vec![Op::Load, Op::Add, Op::Add, Op::Store, Op::Store],
            vec![(0, 1), (0, 2), (1, 3), (2, 4)],
        );
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        let g = &l.grid;
        let p = vec![g.cell(0, 2), g.cell(3, 2), g.cell(3, 3), g.cell(5, 2), g.cell(5, 3)];
        match route(&d, &l, &p, &MapperConfig::default()) {
            RouteOutcome::Routed(_) => {}
            RouteOutcome::Congested { .. } => panic!("fanout must route"),
        }
    }

    #[test]
    fn distinct_values_avoid_link_overlap() {
        // two independent chains crossing the grid: router must keep
        // their links disjoint.
        let d = Dfg::new(
            "cross",
            vec![Op::Load, Op::Load, Op::Add, Op::Add, Op::Store, Op::Store],
            vec![(0, 2), (1, 3), (2, 4), (3, 5)],
        );
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        let g = &l.grid;
        let p = vec![
            g.cell(0, 1),
            g.cell(0, 3),
            g.cell(3, 3), // crosses
            g.cell(3, 1), // crosses
            g.cell(5, 3),
            g.cell(5, 1),
        ];
        match route(&d, &l, &p, &MapperConfig::default()) {
            RouteOutcome::Routed(paths) => {
                // verify capacity invariant with the Mapping validator
                let m = crate::mapper::Mapping {
                    node_cell: p,
                    edge_paths: paths,
                    reserved: vec![],
                };
                assert!(m.validate(&d, &l).is_empty());
            }
            RouteOutcome::Congested { .. } => panic!("cross must route"),
        }
    }

    #[test]
    fn astar_finds_shortest_path_uncongested() {
        let g = Grid::new(8, 8);
        let f = Fabric::mesh4(g);
        let mut buf = AStarBuffers::new(g.num_cells());
        let usage = vec![LinkUse::default(); f.num_links()];
        let history = vec![0.0; f.num_links()];
        let cfg = MapperConfig::default();
        for (a, b) in [((1, 1), (6, 6)), ((0, 0), (7, 3)), ((4, 4), (4, 4))] {
            let src = g.cell(a.0, a.1);
            let dst = g.cell(b.0, b.1);
            let p = astar(&f, src, dst, 0, true, &usage, &history, &cfg, &mut buf);
            assert_eq!(p.len(), g.manhattan(src, dst) + 1, "{a:?}->{b:?}");
        }
    }

    #[test]
    fn buffers_reuse_across_generations() {
        let g = Grid::new(5, 5);
        let f = Fabric::mesh4(g);
        let mut buf = AStarBuffers::new(g.num_cells());
        let usage = vec![LinkUse::default(); f.num_links()];
        let history = vec![0.0; f.num_links()];
        let cfg = MapperConfig::default();
        let p1 = astar(&f, g.cell(0, 0), g.cell(4, 4), 0, true, &usage, &history, &cfg, &mut buf);
        let p2 = astar(&f, g.cell(4, 0), g.cell(0, 4), 1, true, &usage, &history, &cfg, &mut buf);
        assert_eq!(p1.len(), 9);
        assert_eq!(p2.len(), 9);
    }

    #[test]
    fn direction_helper() {
        let g = Grid::new(4, 4);
        let f = Fabric::mesh4(g);
        assert_eq!(direction(&f, g.cell(1, 1), g.cell(0, 1)), 0);
        assert_eq!(direction(&f, g.cell(1, 1), g.cell(1, 2)), 1);
    }

    #[test]
    fn route_partial_keeps_fixed_paths_pinned() {
        // route everything, then move one consumer and re-route only its
        // incident edge: the other paths must come back byte-identical.
        let d = Dfg::new(
            "pin",
            vec![Op::Load, Op::Load, Op::Add, Op::Add, Op::Store, Op::Store],
            vec![(0, 2), (1, 3), (2, 4), (3, 5)],
        );
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        let g = &l.grid;
        let mut p = vec![
            g.cell(0, 1),
            g.cell(0, 4),
            g.cell(2, 1),
            g.cell(2, 4),
            g.cell(5, 1),
            g.cell(5, 4),
        ];
        let cfg = MapperConfig::default();
        let RouteOutcome::Routed(paths) = route(&d, &l, &p, &cfg) else {
            panic!("must route");
        };
        // displace node 3 one cell left and re-route its edges (1 and 3)
        p[3] = g.cell(2, 3);
        let new = route_partial(&d, &l, &p, &paths, &[1, 3], &cfg).expect("partial");
        assert_eq!(new[0], paths[0], "unaffected edge 0 must stay pinned");
        assert_eq!(new[2], paths[2], "unaffected edge 2 must stay pinned");
        assert_eq!(new[1].first(), Some(&p[1]));
        assert_eq!(new[1].last(), Some(&p[3]));
        assert_eq!(new[3].first(), Some(&p[3]));
        assert_eq!(new[3].last(), Some(&p[5]));
        // the full mapping still satisfies every invariant
        let m = crate::mapper::Mapping { node_cell: p, edge_paths: new, reserved: vec![] };
        assert!(m.validate(&d, &l).is_empty());
    }

    #[test]
    fn route_partial_avoids_links_taken_by_fixed_paths() {
        // a straight corridor owned by a pinned path forces the re-routed
        // edge to detour rather than overlap it.
        let d = Dfg::new(
            "detour",
            vec![Op::Load, Op::Load, Op::Add, Op::Add, Op::Store, Op::Store],
            vec![(0, 2), (1, 3), (2, 4), (3, 5)],
        );
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        let g = &l.grid;
        let p = vec![
            g.cell(2, 0),
            g.cell(1, 0),
            g.cell(2, 4),
            g.cell(2, 2),
            g.cell(5, 4),
            g.cell(5, 2),
        ];
        let cfg = MapperConfig::default();
        let RouteOutcome::Routed(paths) = route(&d, &l, &p, &cfg) else {
            panic!("must route");
        };
        // re-route edge 1 (load(1,0) -> add(2,2)) while edge 0 pins the
        // row-2 corridor; the result must still be overlap-free.
        let new = route_partial(&d, &l, &p, &paths, &[1], &cfg).expect("partial");
        let m = crate::mapper::Mapping { node_cell: p, edge_paths: new, reserved: vec![] };
        assert!(m.validate(&d, &l).is_empty());
    }

    #[test]
    fn congested_outcome_reports_hot_links() {
        // Four distinct values must cross the cut between columns 3 and 4
        // eastbound, but a 3-row grid has only 3 eastbound links per cut:
        // at least one link is shared, so routing must report congestion.
        let d = Dfg::new(
            "jam",
            vec![
                Op::Load,
                Op::Load,
                Op::Load,
                Op::Load,
                Op::Add,
                Op::Add,
                Op::Add,
                Op::Add,
                Op::Store,
                Op::Store,
                Op::Store,
                Op::Store,
            ],
            vec![(0, 4), (1, 5), (2, 6), (3, 7), (4, 8), (5, 9), (6, 10), (7, 11)],
        );
        let l = Layout::full(Grid::new(3, 9), GroupSet::all_compute());
        let g = &l.grid;
        let p = vec![
            g.cell(0, 0),
            g.cell(0, 1),
            g.cell(0, 2),
            g.cell(0, 3),
            g.cell(1, 4),
            g.cell(1, 5),
            g.cell(1, 6),
            g.cell(1, 7),
            g.cell(2, 4),
            g.cell(2, 5),
            g.cell(2, 6),
            g.cell(2, 7),
        ];
        match route(&d, &l, &p, &MapperConfig { route_iters: 3, ..Default::default() }) {
            RouteOutcome::Routed(_) => panic!("4 values cannot fit a 3-link cut"),
            RouteOutcome::Congested { hot_links, overuse, .. } => {
                assert!(!hot_links.is_empty(), "congestion must name links");
                assert!(overuse > 0);
                assert!(hot_links.iter().all(|&l| l < g.num_links()));
            }
        }
    }

    /// The jam DFG and its placement on a given fabric (see
    /// `congested_outcome_reports_hot_links` for why Mesh4 congests).
    fn jam_on(fabric: crate::fabric::Fabric) -> (Dfg, Layout, Vec<CellId>) {
        let d = Dfg::new(
            "jam",
            vec![
                Op::Load,
                Op::Load,
                Op::Load,
                Op::Load,
                Op::Add,
                Op::Add,
                Op::Add,
                Op::Add,
                Op::Store,
                Op::Store,
                Op::Store,
                Op::Store,
            ],
            vec![(0, 4), (1, 5), (2, 6), (3, 7), (4, 8), (5, 9), (6, 10), (7, 11)],
        );
        let l = Layout::full_on(fabric, GroupSet::all_compute());
        let g = &l.grid;
        let p = vec![
            g.cell(0, 0),
            g.cell(0, 1),
            g.cell(0, 2),
            g.cell(0, 3),
            g.cell(1, 4),
            g.cell(1, 5),
            g.cell(1, 6),
            g.cell(1, 7),
            g.cell(2, 4),
            g.cell(2, 5),
            g.cell(2, 6),
            g.cell(2, 7),
        ];
        (d, l, p)
    }

    #[test]
    fn link_capacity_two_clears_the_jam() {
        use crate::fabric::FabricSpec;
        let spec = FabricSpec { link_cap: 2, ..FabricSpec::default() };
        let (d, l, p) = jam_on(Fabric::new(Grid::new(3, 9), spec));
        let cfg = MapperConfig { route_iters: 3, ..Default::default() };
        match route(&d, &l, &p, &cfg) {
            RouteOutcome::Routed(paths) => {
                let m = crate::mapper::Mapping { node_cell: p, edge_paths: paths, reserved: vec![] };
                assert!(m.validate(&d, &l).is_empty());
            }
            RouteOutcome::Congested { .. } => panic!("a 2-capacity cut carries 6 streams"),
        }
    }

    #[test]
    fn express_links_clear_the_jam() {
        use crate::fabric::{FabricSpec, Topology};
        let spec =
            FabricSpec { topology: Topology::Express { stride: 2 }, ..FabricSpec::default() };
        let (d, l, p) = jam_on(Fabric::new(Grid::new(3, 9), spec));
        let cfg = MapperConfig { route_iters: 3, ..Default::default() };
        match route(&d, &l, &p, &cfg) {
            RouteOutcome::Routed(paths) => {
                let m = crate::mapper::Mapping { node_cell: p, edge_paths: paths, reserved: vec![] };
                assert!(m.validate(&d, &l).is_empty());
            }
            RouteOutcome::Congested { .. } => panic!("express overlay doubles the cut"),
        }
    }
}
