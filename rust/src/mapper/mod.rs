//! Reserve-on-demand spatial mapper (RodMap-like substrate).
//!
//! The paper uses RodMap [22] as a black box: a fast heuristic spatial
//! mapper with ~90% success that resolves link congestion by *reserving*
//! CGRA cells around congested links solely for routing. This module
//! implements the same mechanism:
//!
//! 1. **Placement** ([`place`]): loads spread around the border, compute
//!    nodes greedily placed in topological order minimising distance to
//!    placed predecessors, stores drained to the nearest border cell.
//! 2. **Routing** ([`route`]): negotiated-congestion routing (PathFinder
//!    style) over the 4NN switch network; links have capacity one value
//!    stream, but edges with the same source share links for free
//!    (fan-out broadcast).
//! 3. **Reserve-on-demand**: if congestion persists, the compute cell
//!    next to the most-overused link is evicted and reserved for routing
//!    only, its node re-placed elsewhere, and routing retried.
//!
//! The mapper is deterministic for a given seed; multiple placement
//! attempts perturb tie-breaks.

pub mod place;
pub mod route;

use crate::cgra::{CellId, Grid, Layout};
use crate::dfg::Dfg;
use crate::util::rng::Rng;

/// Mapper tuning knobs.
#[derive(Debug, Clone)]
pub struct MapperConfig {
    /// Negotiated-congestion routing rounds per placement.
    pub route_iters: usize,
    /// Independent placement attempts (different tie-break jitter).
    pub placement_attempts: usize,
    /// Maximum cells reserved for routing before giving up.
    pub max_reserves: usize,
    /// History penalty increment per overused link per round.
    pub hist_increment: f64,
    /// Present-sharing penalty factor.
    pub present_penalty: f64,
    /// Base RNG seed (attempt index is mixed in).
    pub seed: u64,
}

impl Default for MapperConfig {
    fn default() -> Self {
        Self {
            route_iters: 12,
            placement_attempts: 5,
            max_reserves: 12,
            hist_increment: 1.5,
            present_penalty: 2.0,
            seed: 0xC6A1,
        }
    }
}

/// A successful mapping of one DFG onto one layout.
#[derive(Debug, Clone)]
pub struct Mapping {
    /// Cell hosting each DFG node.
    pub node_cell: Vec<CellId>,
    /// For each DFG edge (same index as `dfg.edges`), the cell path from
    /// the source node's cell to the destination node's cell (inclusive).
    pub edge_paths: Vec<Vec<CellId>>,
    /// Cells reserved for routing only (no op placed).
    pub reserved: Vec<CellId>,
}

impl Mapping {
    /// Post-map latency: longest register-to-register path where each op
    /// costs one cycle and each link hop costs one cycle (Section IV-I).
    pub fn latency(&self, dfg: &Dfg) -> usize {
        let order = dfg.topo_order().expect("mapped DFG must be a DAG");
        let preds = dfg.preds();
        // per-edge hop count lookup
        let mut hops = std::collections::HashMap::new();
        for (i, &(s, d)) in dfg.edges.iter().enumerate() {
            let h = self.edge_paths[i].len().saturating_sub(1);
            hops.insert((s, d), h);
        }
        let mut lat = vec![1usize; dfg.num_nodes()];
        for &u in &order {
            let mut best = 0usize;
            for &p in &preds[u as usize] {
                let h = *hops.get(&(p, u)).unwrap_or(&0);
                best = best.max(lat[p as usize] + h);
            }
            lat[u as usize] = best + 1;
        }
        lat.into_iter().max().unwrap_or(0)
    }

    /// Directed input ports (cell, direction 0..4) receiving a value in
    /// this mapping — the FIFO-usage footprint for Table VI.
    pub fn input_ports_used(&self, grid: &Grid) -> std::collections::HashSet<(CellId, usize)> {
        let mut used = std::collections::HashSet::new();
        for path in &self.edge_paths {
            for w in path.windows(2) {
                let (u, v) = (w[0], w[1]);
                // direction from v's perspective: which neighbour is u?
                for d in 0..4 {
                    if grid.neighbor(v, d) == Some(u) {
                        used.insert((v, d));
                    }
                }
            }
        }
        used
    }

    /// Fast feasibility-witness check: this mapping remains valid for
    /// `layout` iff every compute node sits on a cell that still supports
    /// its group (support removal never touches the switch fabric, so
    /// routes stay valid). Used by the search to skip re-mapping.
    pub fn still_valid(&self, dfg: &Dfg, layout: &Layout) -> bool {
        dfg.nodes.iter().enumerate().all(|(n, op)| {
            op.is_memory() || layout.supports(self.node_cell[n], op.group())
        })
    }

    /// Structural validation against a DFG + layout; returns violations.
    pub fn validate(&self, dfg: &Dfg, layout: &Layout) -> Vec<String> {
        let g = &layout.grid;
        let mut errs = Vec::new();
        if self.node_cell.len() != dfg.num_nodes() {
            errs.push("node_cell length mismatch".into());
            return errs;
        }
        // 1. one node per cell
        let mut seen = std::collections::HashSet::new();
        for (n, &c) in self.node_cell.iter().enumerate() {
            if !seen.insert(c) {
                errs.push(format!("cell {c} hosts more than one node (node {n})"));
            }
        }
        // 2. compatibility + cell kinds + reservations
        for (n, op) in dfg.nodes.iter().enumerate() {
            let c = self.node_cell[n];
            if op.is_memory() {
                if !g.is_io(c) {
                    errs.push(format!("mem node {n} on non-IO cell {c}"));
                }
            } else {
                if !g.is_compute(c) {
                    errs.push(format!("compute node {n} on non-compute cell {c}"));
                }
                if !layout.supports(c, op.group()) {
                    errs.push(format!("node {n} ({op}) on cell {c} lacking {}", op.group()));
                }
                if self.reserved.contains(&c) {
                    errs.push(format!("node {n} on reserved cell {c}"));
                }
            }
        }
        // 3. paths connect and are adjacent
        for (i, &(s, d)) in dfg.edges.iter().enumerate() {
            let path = &self.edge_paths[i];
            if path.first() != Some(&self.node_cell[s as usize])
                || path.last() != Some(&self.node_cell[d as usize])
            {
                errs.push(format!("edge {i} path endpoints wrong"));
            }
            for w in path.windows(2) {
                if g.manhattan(w[0], w[1]) != 1 {
                    errs.push(format!("edge {i} has non-adjacent hop {}->{}", w[0], w[1]));
                }
            }
        }
        // 4. link capacity: distinct source nodes per directed link <= 1
        let mut link_srcs: std::collections::HashMap<usize, std::collections::HashSet<u32>> =
            std::collections::HashMap::new();
        for (i, &(s, _)) in dfg.edges.iter().enumerate() {
            for w in self.edge_paths[i].windows(2) {
                for dir in 0..4 {
                    if g.neighbor(w[0], dir) == Some(w[1]) {
                        link_srcs.entry(g.link(w[0], dir)).or_default().insert(s);
                    }
                }
            }
        }
        for (link, srcs) in link_srcs {
            if srcs.len() > 1 {
                errs.push(format!("link {link} carries {} distinct values", srcs.len()));
            }
        }
        errs
    }
}

/// The mapper.
#[derive(Debug, Clone, Default)]
pub struct Mapper {
    pub cfg: MapperConfig,
}

impl Mapper {
    pub fn new(cfg: MapperConfig) -> Self {
        Self { cfg }
    }

    /// Map one DFG onto a layout. Returns `None` on failure.
    pub fn map(&self, dfg: &Dfg, layout: &Layout) -> Option<Mapping> {
        for attempt in 0..self.cfg.placement_attempts {
            let mut rng = Rng::seed(self.cfg.seed ^ (attempt as u64).wrapping_mul(0x9E37));
            let mut reserved: Vec<CellId> = Vec::new();
            // placement; retried after each new reservation. Reserves
            // that do not reduce congestion earn strikes; two strikes
            // abandon this placement attempt (perf: avoids burning the
            // whole reserve budget on hopeless placements).
            let mut best_overuse = usize::MAX;
            let mut strikes = 0usize;
            'reserve: for _round in 0..=self.cfg.max_reserves {
                let Some(placement) =
                    place::place(dfg, layout, &reserved, &mut rng)
                else {
                    break 'reserve; // placement impossible under reservations
                };
                match route::route(dfg, layout, &placement, &self.cfg) {
                    route::RouteOutcome::Routed(paths) => {
                        let m = Mapping {
                            node_cell: placement,
                            edge_paths: paths,
                            reserved: reserved.clone(),
                        };
                        debug_assert!(
                            m.validate(dfg, layout).is_empty(),
                            "mapper produced invalid mapping: {:?}",
                            m.validate(dfg, layout)
                        );
                        return Some(m);
                    }
                    route::RouteOutcome::Congested { hot_cell, overuse } => {
                        if overuse < best_overuse {
                            best_overuse = overuse;
                            strikes = 0;
                        } else {
                            strikes += 1;
                            if strikes >= 3 {
                                break 'reserve; // reserves are not helping
                            }
                        }
                        // reserve-on-demand: free the hot cell for routing
                        if reserved.len() >= self.cfg.max_reserves {
                            break 'reserve;
                        }
                        if layout.grid.is_compute(hot_cell) && !reserved.contains(&hot_cell) {
                            reserved.push(hot_cell);
                        } else {
                            break 'reserve; // nothing sensible to reserve
                        }
                    }
                }
            }
        }
        None
    }

    /// Test whether *all* DFGs map (the paper's `testLayout`). Short-
    /// circuits on first failure.
    pub fn test_layout(&self, dfgs: &[Dfg], layout: &Layout) -> bool {
        dfgs.iter().all(|d| self.map(d, layout).is_some())
    }

    /// Map all DFGs individually, returning all mappings or None.
    pub fn map_all(&self, dfgs: &[Dfg], layout: &Layout) -> Option<Vec<Mapping>> {
        dfgs.iter().map(|d| self.map(d, layout)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::benchmarks;
    use crate::ops::GroupSet;

    fn full_layout(r: usize, c: usize, dfgs: &[Dfg]) -> Layout {
        Layout::full(Grid::new(r, c), crate::dfg::groups_used(dfgs))
    }

    #[test]
    fn maps_tiny_dfg_on_small_grid() {
        let d = benchmarks::benchmark("SOB");
        let l = full_layout(5, 5, std::slice::from_ref(&d));
        let m = Mapper::default().map(&d, &l).expect("SOB must map on 5x5");
        assert!(m.validate(&d, &l).is_empty());
    }

    #[test]
    fn maps_all_paper_benchmarks_on_10x10() {
        let dfgs = benchmarks::all();
        let l = full_layout(10, 10, &dfgs);
        let mapper = Mapper::default();
        for d in &dfgs {
            let m = mapper.map(d, &l);
            assert!(m.is_some(), "{} failed to map on 10x10 full layout", d.name);
            let m = m.unwrap();
            let errs = m.validate(d, &l);
            assert!(errs.is_empty(), "{}: {errs:?}", d.name);
        }
    }

    #[test]
    fn fails_when_support_missing() {
        let d = benchmarks::benchmark("BIL"); // needs Div + Other
        let groups = GroupSet::from_groups(&[crate::ops::OpGroup::Arith]);
        let l = Layout::full(Grid::new(10, 10), groups);
        assert!(Mapper::default().map(&d, &l).is_none());
    }

    #[test]
    fn fails_when_grid_too_small() {
        let d = benchmarks::benchmark("SAD"); // 63 compute ops
        let l = full_layout(5, 5, std::slice::from_ref(&d)); // 9 compute cells
        assert!(Mapper::default().map(&d, &l).is_none());
    }

    #[test]
    fn latency_at_least_critical_path() {
        let d = benchmarks::benchmark("BOX");
        let l = full_layout(8, 8, std::slice::from_ref(&d));
        let m = Mapper::default().map(&d, &l).unwrap();
        assert!(m.latency(&d) >= d.critical_path_nodes());
    }

    #[test]
    fn input_ports_are_plausible() {
        let d = benchmarks::benchmark("SOB");
        let l = full_layout(5, 5, std::slice::from_ref(&d));
        let m = Mapper::default().map(&d, &l).unwrap();
        let ports = m.input_ports_used(&l.grid);
        // at least one port per edge endpoint, at most 4 per cell
        assert!(!ports.is_empty());
        for &(_, dir) in &ports {
            assert!(dir < 4);
        }
    }

    #[test]
    fn test_layout_checks_all() {
        let dfgs: Vec<Dfg> =
            ["SOB", "GB"].iter().map(|n| benchmarks::benchmark(n)).collect();
        let l = full_layout(7, 7, &dfgs);
        assert!(Mapper::default().test_layout(&dfgs, &l));
        // removing Arith everywhere must break both
        let mut crippled = l.clone();
        for c in crippled.grid.compute_cells().collect::<Vec<_>>() {
            let s = crippled.support(c).without(crate::ops::OpGroup::Arith);
            crippled.set_support(c, s);
        }
        assert!(!Mapper::default().test_layout(&dfgs, &crippled));
    }

    #[test]
    fn deterministic_mapping() {
        let d = benchmarks::benchmark("RGB");
        let l = full_layout(8, 8, std::slice::from_ref(&d));
        let m1 = Mapper::default().map(&d, &l).unwrap();
        let m2 = Mapper::default().map(&d, &l).unwrap();
        assert_eq!(m1.node_cell, m2.node_cell);
        assert_eq!(m1.edge_paths, m2.edge_paths);
    }
}
