//! Spatial mapping behind the [`MappingEngine`] API.
//!
//! The paper uses RodMap [22] as a black box: a fast heuristic spatial
//! mapper with ~90% success that resolves link congestion by *reserving*
//! CGRA cells around congested links solely for routing. This module
//! implements the same mechanism as three layers:
//!
//! 1. **Strategies** — [`PlacementStrategy`] and [`RoutingStrategy`]
//!    traits with the defaults [`GreedyTopoPlacer`] ([`place`]: loads
//!    spread around the border, compute nodes greedily placed in
//!    topological order, stores drained to the border) and
//!    [`PathFinderRouter`] ([`route`]: negotiated-congestion A* over the
//!    4NN switch network; links carry one value stream, but edges with
//!    the same source share links for free). The opt-in
//!    [`SteinerRouter`] (`MapperConfig::router_steiner`) routes each
//!    multi-fanout net as one shared-trunk Steiner tree instead of
//!    edge-by-edge, optionally weighting negotiation by per-net
//!    criticality — see `docs/ROUTER.md` for the full router internals
//!    guide. Alternative placers/routers plug in via
//!    [`MappingEngine::with_strategies`].
//! 2. **The engine** ([`engine`]) — drives the strategies through the
//!    reserve-on-demand loop (evict the compute cell next to the
//!    most-overused link, re-place, re-route) and resolves every
//!    [`MapRequest`] to a structured [`MapOutcome`]: a [`Mapping`] plus
//!    stats, or a [`MapFailure`] saying *why* (unsupported group with
//!    demand/capacity, persistent congestion with the hot links, or
//!    placement exhaustion).
//! 3. **Warm-start remapping** — [`MappingEngine::remap_from`] repairs a
//!    witness mapping incrementally after support removal (re-place only
//!    displaced nodes, rip-up-reroute only their incident edges), with a
//!    feasibility cache keyed by (DFG, layout) fingerprints. This is the
//!    search's hot path: OPSG/GSG candidates are one-removal neighbors
//!    of already-witnessed layouts.
//!
//! The engine is deterministic for a given seed; multiple placement
//! attempts perturb tie-breaks. The pre-engine [`Mapper`] type survives
//! as a thin deprecated wrapper.
//!
//! ```
//! use helex::{MappingEngine, MapperConfig};
//! use helex::cgra::{Grid, Layout};
//! use helex::dfg::benchmarks;
//!
//! let dfg = benchmarks::benchmark("SOB");
//! let layout = Layout::full(Grid::new(6, 6), dfg.groups_used());
//!
//! // Default engine: legacy edge-by-edge PathFinder routing.
//! let engine = MappingEngine::default();
//! assert_eq!(engine.router_name(), "pathfinder");
//! let mapping = engine.map(&dfg, &layout).into_mapping().unwrap();
//! assert!(mapping.validate(&dfg, &layout).is_empty());
//!
//! // Opt into the Steiner multi-fanout router: same feasibility
//! // verdicts, shared-trunk routes.
//! let steiner = MappingEngine::new(MapperConfig {
//!     router_steiner: true,
//!     ..MapperConfig::default()
//! });
//! assert_eq!(steiner.router_name(), "steiner");
//! assert!(steiner.map(&dfg, &layout).is_mapped());
//! ```

pub mod engine;
pub mod place;
pub mod route;

pub use engine::{
    GreedyTopoPlacer, MapFailure, MapOutcome, MapRequest, MapSetFailure, MapStats, MappingEngine,
    PathFinderRouter, PlacementStrategy, RoutingStrategy, SteinerRouter,
};

use crate::cgra::{CellId, CellSet, Grid, Layout};
use crate::dfg::Dfg;

/// Mapper tuning knobs.
#[derive(Debug, Clone)]
pub struct MapperConfig {
    /// Negotiated-congestion routing rounds per placement.
    pub route_iters: usize,
    /// Independent placement attempts (different tie-break jitter).
    pub placement_attempts: usize,
    /// Maximum cells reserved for routing before giving up.
    pub max_reserves: usize,
    /// History penalty increment per overused link per round.
    pub hist_increment: f64,
    /// Present-sharing penalty factor.
    pub present_penalty: f64,
    /// Base RNG seed (attempt index is mixed in).
    pub seed: u64,
    /// Memoize per-(DFG, layout) feasibility results (see
    /// [`MappingEngine`]); disable for micro-benchmarks that re-map the
    /// same pair on purpose.
    pub feasibility_cache: bool,
    /// Select the Steiner multi-fanout router ([`SteinerRouter`]):
    /// edges sharing a source are routed together as one shared-trunk
    /// tree instead of independently. Off by default — the legacy
    /// edge-by-edge [`PathFinderRouter`] keeps its byte-identical
    /// traces. Config key `mapper.router.steiner`.
    pub router_steiner: bool,
    /// Weight congestion negotiation by per-net criticality (longest-
    /// path slack): critical nets pay less to hold contested links, so
    /// negotiation converges in fewer rip-up rounds. Only consulted by
    /// the Steiner router. Config key `mapper.router.criticality`.
    pub router_criticality: bool,
}

impl Default for MapperConfig {
    fn default() -> Self {
        Self {
            route_iters: 12,
            placement_attempts: 5,
            max_reserves: 12,
            hist_increment: 1.5,
            present_penalty: 2.0,
            seed: 0xC6A1,
            feasibility_cache: true,
            router_steiner: false,
            router_criticality: false,
        }
    }
}

/// Hashing keys the service's run cache and per-job seed derivation, so
/// every knob must participate (floats via `to_bits`). The exhaustive
/// destructuring makes adding a field a compile error here, forcing the
/// decision to be revisited instead of silently drifting.
impl std::hash::Hash for MapperConfig {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        use std::hash::Hash as _;
        let Self {
            route_iters,
            placement_attempts,
            max_reserves,
            hist_increment,
            present_penalty,
            seed,
            feasibility_cache,
            router_steiner,
            router_criticality,
        } = self;
        route_iters.hash(state);
        placement_attempts.hash(state);
        max_reserves.hash(state);
        hist_increment.to_bits().hash(state);
        present_penalty.to_bits().hash(state);
        seed.hash(state);
        feasibility_cache.hash(state);
        // Router-selection knobs participate only when non-default so
        // every fingerprint, derived seed and run-cache key from before
        // they existed is reproduced bit-for-bit (same gating as
        // `FabricSpec` in the wire codec).
        if *router_steiner || *router_criticality {
            router_steiner.hash(state);
            router_criticality.hash(state);
        }
    }
}

/// A successful mapping of one DFG onto one layout.
#[derive(Debug, Clone)]
pub struct Mapping {
    /// Cell hosting each DFG node.
    pub node_cell: Vec<CellId>,
    /// For each DFG edge (same index as `dfg.edges`), the cell path from
    /// the source node's cell to the destination node's cell (inclusive).
    pub edge_paths: Vec<Vec<CellId>>,
    /// Cells reserved for routing only (no op placed).
    pub reserved: Vec<CellId>,
}

impl Mapping {
    /// Post-map latency: longest register-to-register path where each op
    /// costs one cycle and each link hop costs one cycle (Section IV-I).
    pub fn latency(&self, dfg: &Dfg) -> usize {
        let order = dfg.topo_order().expect("mapped DFG must be a DAG");
        // incoming edges per node, by edge index: parallel edges between
        // the same node pair keep their distinct hop counts (a (src, dst)
        // keyed lookup would collapse them)
        let mut in_edges: Vec<Vec<usize>> = vec![Vec::new(); dfg.num_nodes()];
        for (i, &(_, d)) in dfg.edges.iter().enumerate() {
            in_edges[d as usize].push(i);
        }
        let mut lat = vec![1usize; dfg.num_nodes()];
        for &u in &order {
            let mut best = 0usize;
            for &e in &in_edges[u as usize] {
                let (p, _) = dfg.edges[e];
                let hops = self.edge_paths[e].len().saturating_sub(1);
                best = best.max(lat[p as usize] + hops);
            }
            lat[u as usize] = best + 1;
        }
        lat.into_iter().max().unwrap_or(0)
    }

    /// Directed input ports (cell, direction 0..4) receiving a value in
    /// this mapping — the FIFO-usage footprint for Table VI.
    pub fn input_ports_used(&self, grid: &Grid) -> std::collections::HashSet<(CellId, usize)> {
        let mut used = std::collections::HashSet::new();
        for path in &self.edge_paths {
            for w in path.windows(2) {
                let (u, v) = (w[0], w[1]);
                // direction from v's perspective: which neighbour is u?
                for d in 0..4 {
                    if grid.neighbor(v, d) == Some(u) {
                        used.insert((v, d));
                    }
                }
            }
        }
        used
    }

    /// Fast feasibility-witness check: this mapping remains valid for
    /// `layout` iff every compute node sits on a cell that still supports
    /// its group (support removal never touches the switch fabric, so
    /// routes stay valid). Used by the search to skip re-mapping.
    pub fn still_valid(&self, dfg: &Dfg, layout: &Layout) -> bool {
        dfg.nodes.iter().enumerate().all(|(n, op)| {
            op.is_memory() || layout.supports(self.node_cell[n], op.group())
        })
    }

    /// Structural validation against a DFG + layout; returns violations.
    /// Adjacency and link capacity follow the layout's
    /// [`crate::fabric::Fabric`] (the legacy 4NN mesh by default).
    pub fn validate(&self, dfg: &Dfg, layout: &Layout) -> Vec<String> {
        let g = &layout.grid;
        let f = layout.fabric();
        let cap = f.link_cap();
        let mut errs = Vec::new();
        if self.node_cell.len() != dfg.num_nodes() {
            errs.push("node_cell length mismatch".into());
            return errs;
        }
        // 0. every referenced cell is on this grid
        for &c in self.node_cell.iter().chain(self.reserved.iter()) {
            if c as usize >= g.num_cells() {
                errs.push(format!("cell {c} outside the {} grid", g));
                return errs;
            }
        }
        // 1. one node per cell
        let mut seen = CellSet::new(g.num_cells());
        for (n, &c) in self.node_cell.iter().enumerate() {
            if !seen.insert(c) {
                errs.push(format!("cell {c} hosts more than one node (node {n})"));
            }
        }
        // 2. compatibility + cell kinds + reservations
        let reserved = CellSet::from_cells(g.num_cells(), &self.reserved);
        for (n, op) in dfg.nodes.iter().enumerate() {
            let c = self.node_cell[n];
            if op.is_memory() {
                if !g.is_io(c) {
                    errs.push(format!("mem node {n} on non-IO cell {c}"));
                } else if !f.is_active_io(c) {
                    errs.push(format!("mem node {n} on inactive IO cell {c}"));
                }
            } else {
                if !g.is_compute(c) {
                    errs.push(format!("compute node {n} on non-compute cell {c}"));
                }
                if !layout.supports(c, op.group()) {
                    errs.push(format!("node {n} ({op}) on cell {c} lacking {}", op.group()));
                }
                if reserved.contains(c) {
                    errs.push(format!("node {n} on reserved cell {c}"));
                }
            }
        }
        // 3. paths connect and are adjacent
        for (i, &(s, d)) in dfg.edges.iter().enumerate() {
            let path = &self.edge_paths[i];
            if path.first() != Some(&self.node_cell[s as usize])
                || path.last() != Some(&self.node_cell[d as usize])
            {
                errs.push(format!("edge {i} path endpoints wrong"));
            }
            for w in path.windows(2) {
                if f.direction(w[0], w[1]).is_none() {
                    errs.push(format!("edge {i} has non-adjacent hop {}->{}", w[0], w[1]));
                }
            }
        }
        // 4. link capacity: distinct source nodes per directed link must
        // stay within the fabric's capacity (1 on the legacy mesh)
        let mut link_srcs: std::collections::HashMap<usize, std::collections::HashSet<u32>> =
            std::collections::HashMap::new();
        for (i, &(s, _)) in dfg.edges.iter().enumerate() {
            for w in self.edge_paths[i].windows(2) {
                if let Some(dir) = f.direction(w[0], w[1]) {
                    link_srcs.entry(f.link(w[0], dir)).or_default().insert(s);
                }
            }
        }
        for (link, srcs) in link_srcs {
            if srcs.len() > cap {
                errs.push(format!("link {link} carries {} distinct values", srcs.len()));
            }
        }
        errs
    }
}

/// The pre-engine mapper handle: configuration plus thin deprecated
/// wrappers over [`MappingEngine`] with the default strategies. New code
/// should construct a `MappingEngine` (it adds structured outcomes,
/// warm-start remapping and the feasibility cache); this type survives
/// so downstream callers migrate at their own pace.
#[derive(Debug, Clone, Default)]
pub struct Mapper {
    pub cfg: MapperConfig,
}

impl Mapper {
    pub fn new(cfg: MapperConfig) -> Self {
        Self { cfg }
    }

    /// Map one DFG onto a layout. Returns `None` on failure.
    #[deprecated(note = "use MappingEngine::map, which returns a structured MapOutcome")]
    pub fn map(&self, dfg: &Dfg, layout: &Layout) -> Option<Mapping> {
        MappingEngine::from_mapper(self).map(dfg, layout).into_mapping()
    }

    /// Test whether *all* DFGs map (the paper's `testLayout`). Short-
    /// circuits on first failure.
    #[deprecated(note = "use MappingEngine::test_layout")]
    pub fn test_layout(&self, dfgs: &[Dfg], layout: &Layout) -> bool {
        MappingEngine::from_mapper(self).test_layout(dfgs, layout)
    }

    /// Map all DFGs individually, returning all mappings or None.
    #[deprecated(note = "use MappingEngine::map_all, which names the failing DFG")]
    pub fn map_all(&self, dfgs: &[Dfg], layout: &Layout) -> Option<Vec<Mapping>> {
        MappingEngine::from_mapper(self).map_all(dfgs, layout).ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::benchmarks;
    use crate::ops::{GroupSet, Op};

    fn full_layout(r: usize, c: usize, dfgs: &[Dfg]) -> Layout {
        Layout::full(Grid::new(r, c), crate::dfg::groups_used(dfgs))
    }

    fn engine() -> MappingEngine {
        MappingEngine::default()
    }

    #[test]
    fn maps_tiny_dfg_on_small_grid() {
        let d = benchmarks::benchmark("SOB");
        let l = full_layout(5, 5, std::slice::from_ref(&d));
        let m = engine().map(&d, &l).into_mapping().expect("SOB must map on 5x5");
        assert!(m.validate(&d, &l).is_empty());
    }

    #[test]
    fn maps_all_paper_benchmarks_on_10x10() {
        let dfgs = benchmarks::all();
        let l = full_layout(10, 10, &dfgs);
        let engine = engine();
        for d in &dfgs {
            let m = engine.map(d, &l);
            assert!(m.is_mapped(), "{} failed to map on 10x10 full layout", d.name);
            let m = m.into_mapping().unwrap();
            let errs = m.validate(d, &l);
            assert!(errs.is_empty(), "{}: {errs:?}", d.name);
        }
    }

    #[test]
    fn fails_when_support_missing() {
        let d = benchmarks::benchmark("BIL"); // needs Div + Other
        let groups = GroupSet::from_groups(&[crate::ops::OpGroup::Arith]);
        let l = Layout::full(Grid::new(10, 10), groups);
        assert!(!engine().map(&d, &l).is_mapped());
    }

    #[test]
    fn fails_when_grid_too_small() {
        let d = benchmarks::benchmark("SAD"); // 63 compute ops
        let l = full_layout(5, 5, std::slice::from_ref(&d)); // 9 compute cells
        assert!(!engine().map(&d, &l).is_mapped());
    }

    #[test]
    fn latency_at_least_critical_path() {
        let d = benchmarks::benchmark("BOX");
        let l = full_layout(8, 8, std::slice::from_ref(&d));
        let m = engine().map(&d, &l).into_mapping().unwrap();
        assert!(m.latency(&d) >= d.critical_path_nodes());
    }

    #[test]
    fn latency_keeps_parallel_edges_distinct() {
        // two edges between the same node pair with different path
        // lengths: latency must follow the *longer* one (a (src, dst)
        // keyed hop lookup would let whichever edge came last win)
        let d = Dfg::new("par", vec![Op::Load, Op::Add, Op::Store], vec![(0, 1), (0, 1), (1, 2)]);
        let l = Layout::full(Grid::new(5, 5), GroupSet::all_compute());
        let g = &l.grid;
        let (load, add, store) = (g.cell(2, 0), g.cell(2, 2), g.cell(2, 4));
        let short = vec![load, g.cell(2, 1), add];
        let long = vec![load, g.cell(1, 0), g.cell(1, 1), g.cell(1, 2), g.cell(2, 2)];
        let out = vec![add, g.cell(2, 3), store];
        let hops_long = long.len() - 1; // 4
        let m = Mapping {
            node_cell: vec![load, add, store],
            edge_paths: vec![short.clone(), long.clone(), out.clone()],
            reserved: vec![],
        };
        // load(1) + long hops(4) + add(1) + out hops(2) + store(1) = 9
        assert_eq!(m.latency(&d), 1 + hops_long + 1 + (out.len() - 1) + 1);
        // edge order must not matter
        let m2 = Mapping {
            node_cell: vec![load, add, store],
            edge_paths: vec![long, short, out],
            reserved: vec![],
        };
        assert_eq!(m.latency(&d), m2.latency(&d));
    }

    #[test]
    fn input_ports_are_plausible() {
        let d = benchmarks::benchmark("SOB");
        let l = full_layout(5, 5, std::slice::from_ref(&d));
        let m = engine().map(&d, &l).into_mapping().unwrap();
        let ports = m.input_ports_used(&l.grid);
        // at least one port per edge endpoint, at most 4 per cell
        assert!(!ports.is_empty());
        for &(_, dir) in &ports {
            assert!(dir < 4);
        }
    }

    #[test]
    fn test_layout_checks_all() {
        let dfgs: Vec<Dfg> =
            ["SOB", "GB"].iter().map(|n| benchmarks::benchmark(n)).collect();
        let l = full_layout(7, 7, &dfgs);
        assert!(engine().test_layout(&dfgs, &l));
        // removing Arith everywhere must break both
        let mut crippled = l.clone();
        for c in crippled.grid.compute_cells().collect::<Vec<_>>() {
            let s = crippled.support(c).without(crate::ops::OpGroup::Arith);
            crippled.set_support(c, s);
        }
        assert!(!engine().test_layout(&dfgs, &crippled));
    }

    #[test]
    fn deterministic_mapping() {
        let d = benchmarks::benchmark("RGB");
        let l = full_layout(8, 8, std::slice::from_ref(&d));
        let m1 = engine().map(&d, &l).into_mapping().unwrap();
        let m2 = engine().map(&d, &l).into_mapping().unwrap();
        assert_eq!(m1.node_cell, m2.node_cell);
        assert_eq!(m1.edge_paths, m2.edge_paths);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_wrappers_still_work() {
        let d = benchmarks::benchmark("SOB");
        let l = full_layout(5, 5, std::slice::from_ref(&d));
        let mapper = Mapper::default();
        let m = mapper.map(&d, &l).expect("wrapper must still map");
        assert!(m.validate(&d, &l).is_empty());
        assert!(mapper.test_layout(std::slice::from_ref(&d), &l));
        assert_eq!(mapper.map_all(std::slice::from_ref(&d), &l).unwrap().len(), 1);
    }

    #[test]
    fn validate_flags_reserved_cell_use() {
        let d = Dfg::new("r", vec![Op::Load, Op::Add, Op::Store], vec![(0, 1), (1, 2)]);
        let l = Layout::full(Grid::new(5, 5), GroupSet::all_compute());
        let g = &l.grid;
        let add = g.cell(2, 2);
        let m = Mapping {
            node_cell: vec![g.cell(2, 0), add, g.cell(2, 4)],
            edge_paths: vec![
                vec![g.cell(2, 0), g.cell(2, 1), add],
                vec![add, g.cell(2, 3), g.cell(2, 4)],
            ],
            reserved: vec![add],
        };
        let errs = m.validate(&d, &l);
        assert!(errs.iter().any(|e| e.contains("reserved")), "{errs:?}");
    }
}
