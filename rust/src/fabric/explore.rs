//! Fabric provisioning explorer: the outer loop over candidate fabrics.
//!
//! [`FabricExplorer`] wraps the nested op-layout search
//! ([`crate::search::Explorer`]) in a provisioning sweep: for each
//! candidate [`FabricSpec`] it runs one full search session on the same
//! grid/DFG set, then merges every per-fabric outcome into a single
//! non-dominated front whose points carry the fabric descriptor they
//! were found on ([`FabricFrontPoint`]). Scalar (area/power) sessions
//! contribute their best layout's objective-space coordinates; Pareto
//! sessions contribute their whole archive. The merge is a plain
//! dominance filter over [`crate::search::pareto::dominates`] with a
//! deterministic sort, so the combined front is byte-stable at any
//! thread count, exactly like the inner search.
//!
//! Candidate order is preserved in [`FabricExploration::runs`]; an
//! infeasible candidate (e.g. a topology the DFG set congests on) stays
//! in the report with its error, it just contributes no points.

use crate::cgra::Grid;
use crate::cost::CostModel;
use crate::dfg::Dfg;
use crate::mapper::MappingEngine;
use crate::search::pareto::{self, ParetoPoint};
use crate::search::{ExploreError, Explorer, SearchConfig, SearchResult};

use super::{FabricSpec, Topology};

/// One point of the merged provisioning front: objective-space
/// coordinates plus the descriptor of the fabric that produced them.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricFrontPoint {
    pub point: ParetoPoint,
    /// [`FabricSpec::describe`] of the producing candidate.
    pub fabric: String,
}

/// One candidate's full search outcome.
#[derive(Debug)]
pub struct FabricRun {
    pub spec: FabricSpec,
    /// [`FabricSpec::describe`] — stable key for reports and traces.
    pub descriptor: String,
    pub outcome: Result<SearchResult, ExploreError>,
}

impl FabricRun {
    /// The candidate's points in objective space: the Pareto archive
    /// when the session ran multi-objective, else the best layout's
    /// coordinates. Empty for failed candidates.
    fn points(&self) -> Vec<ParetoPoint> {
        match &self.outcome {
            Ok(r) if !r.front.is_empty() => r.front.clone(),
            Ok(r) => vec![pareto::evaluate(&r.best_layout)],
            Err(_) => Vec::new(),
        }
    }
}

/// The provisioning sweep's result: every per-fabric run (candidate
/// order) and the merged descriptor-tagged non-dominated front.
#[derive(Debug)]
pub struct FabricExploration {
    pub runs: Vec<FabricRun>,
    /// Non-dominated across *all* candidates; sorted by
    /// `(ops, area, power, fingerprint, fabric)`.
    pub front: Vec<FabricFrontPoint>,
}

impl FabricExploration {
    /// The run behind the scalar-best point (lowest best_cost among
    /// feasible candidates; ties break toward earlier candidates).
    pub fn best_run(&self) -> Option<&FabricRun> {
        self.runs
            .iter()
            .filter(|r| r.outcome.is_ok())
            .min_by(|a, b| {
                let ca = a.outcome.as_ref().map(|r| r.best_cost).unwrap_or(f64::INFINITY);
                let cb = b.outcome.as_ref().map(|r| r.best_cost).unwrap_or(f64::INFINITY);
                ca.partial_cmp(&cb).unwrap_or(std::cmp::Ordering::Equal)
            })
    }
}

/// The default provisioning sweep: today's mesh, the diagonal mesh and
/// a stride-2 express overlay, all at unit link capacity with the full
/// I/O border.
pub fn default_candidates() -> Vec<FabricSpec> {
    vec![
        FabricSpec::default(),
        FabricSpec { topology: Topology::Mesh8, ..FabricSpec::default() },
        FabricSpec { topology: Topology::Express { stride: 2 }, ..FabricSpec::default() },
    ]
}

/// Builder-style provisioning sweep. Mirrors [`Explorer`]'s builder:
/// required grid (constructor) and DFG set ([`Self::dfgs`]); candidates
/// default to [`default_candidates`]; engine/cost/config default like
/// the inner search.
pub struct FabricExplorer<'a> {
    grid: Grid,
    candidates: Vec<FabricSpec>,
    dfgs: Option<&'a [Dfg]>,
    engine: Option<&'a MappingEngine>,
    cost: Option<&'a CostModel>,
    cfg: SearchConfig,
}

impl<'a> FabricExplorer<'a> {
    pub fn new(grid: Grid) -> Self {
        Self {
            grid,
            candidates: default_candidates(),
            dfgs: None,
            engine: None,
            cost: None,
            cfg: SearchConfig::default(),
        }
    }

    /// The DFG set every candidate fabric is searched against (required).
    pub fn dfgs(mut self, dfgs: &'a [Dfg]) -> Self {
        self.dfgs = Some(dfgs);
        self
    }

    /// Replace the candidate set. Invalid specs are rejected at
    /// [`Self::run`] time; an empty set is rejected too.
    pub fn candidates(mut self, candidates: Vec<FabricSpec>) -> Self {
        self.candidates = candidates;
        self
    }

    /// Share a [`MappingEngine`] across every candidate's session. Safe:
    /// the feasibility cache keys on the whole layout, fabric included.
    pub fn engine(mut self, engine: &'a MappingEngine) -> Self {
        self.engine = Some(engine);
        self
    }

    pub fn cost(mut self, cost: &'a CostModel) -> Self {
        self.cost = Some(cost);
        self
    }

    pub fn config(mut self, cfg: SearchConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Run one full search session per candidate and merge the fronts.
    pub fn run(self) -> Result<FabricExploration, ExploreError> {
        let dfgs = self.dfgs.filter(|d| !d.is_empty()).ok_or(ExploreError::MissingDfgs)?;
        if self.candidates.is_empty() {
            return Err(ExploreError::Infeasible("no candidate fabrics".into()));
        }
        for spec in &self.candidates {
            if let Err(e) = spec.validate() {
                return Err(ExploreError::Infeasible(format!(
                    "invalid candidate fabric {}: {e}",
                    spec.describe()
                )));
            }
        }
        let mut runs = Vec::with_capacity(self.candidates.len());
        for spec in self.candidates {
            let mut session = Explorer::new(self.grid)
                .fabric(spec)
                .dfgs(dfgs)
                .config(self.cfg.clone());
            if let Some(engine) = self.engine {
                session = session.engine(engine);
            }
            if let Some(cost) = self.cost {
                session = session.cost(cost);
            }
            let outcome = session.run();
            runs.push(FabricRun { spec, descriptor: spec.describe(), outcome });
        }
        let front = merge_front(&runs);
        Ok(FabricExploration { runs, front })
    }
}

/// Dominance-filter every candidate's points into one descriptor-tagged
/// front. Duplicate coordinates keep the earliest candidate's tag.
fn merge_front(runs: &[FabricRun]) -> Vec<FabricFrontPoint> {
    let mut front: Vec<FabricFrontPoint> = Vec::new();
    for run in runs {
        for point in run.points() {
            if front.iter().any(|f| {
                pareto::dominates(&f.point, &point)
                    || (f.point.ops == point.ops
                        && f.point.area_um2 == point.area_um2
                        && f.point.power_uw == point.power_uw)
            }) {
                continue;
            }
            front.retain(|f| !pareto::dominates(&point, &f.point));
            front.push(FabricFrontPoint { point, fabric: run.descriptor.clone() });
        }
    }
    front.sort_by(|a, b| {
        (a.point.ops, a.point.area_um2.to_bits(), a.point.power_uw.to_bits(), a.point.fingerprint)
            .cmp(&(
                b.point.ops,
                b.point.area_um2.to_bits(),
                b.point.power_uw.to_bits(),
                b.point.fingerprint,
            ))
            .then_with(|| a.fabric.cmp(&b.fabric))
    });
    front
}

/// Scalar-vs-scalar convenience used by reports: true when the sweep
/// found any point a plain Mesh4 run could not reach.
pub fn front_leaves_mesh4(exploration: &FabricExploration) -> bool {
    exploration.front.iter().any(|f| f.fabric != FabricSpec::default().describe())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg;
    use crate::search::SearchConfig;

    fn tiny_cfg() -> SearchConfig {
        SearchConfig { l_test: 40, l_fail: 2, gsg_passes: 1, ..SearchConfig::default() }
    }

    #[test]
    fn sweep_reports_every_candidate_and_merges_the_front() {
        let dfgs = [dfg::benchmarks::benchmark("SOB")];
        let out = FabricExplorer::new(Grid::new(6, 6))
            .dfgs(&dfgs)
            .config(tiny_cfg())
            .run()
            .unwrap();
        assert_eq!(out.runs.len(), default_candidates().len());
        assert_eq!(out.runs[0].descriptor, "mesh4");
        assert!(out.runs.iter().all(|r| r.outcome.is_ok()), "SOB maps on every default fabric");
        assert!(!out.front.is_empty());
        // Every front point's tag names a swept candidate.
        for p in &out.front {
            assert!(out.runs.iter().any(|r| r.descriptor == p.fabric), "unknown tag {}", p.fabric);
        }
        // The merged front is mutually non-dominated.
        for a in &out.front {
            for b in &out.front {
                assert!(!pareto::dominates(&a.point, &b.point) || a == b);
            }
        }
        assert!(out.best_run().is_some());
    }

    #[test]
    fn sweep_is_deterministic_across_runs() {
        let dfgs = [dfg::benchmarks::benchmark("SOB")];
        let run = || {
            FabricExplorer::new(Grid::new(6, 6))
                .dfgs(&dfgs)
                .config(tiny_cfg())
                .run()
                .unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.front, b.front);
        let costs = |e: &FabricExploration| {
            e.runs
                .iter()
                .map(|r| r.outcome.as_ref().map(|r| r.best_cost.to_bits()).ok())
                .collect::<Vec<_>>()
        };
        assert_eq!(costs(&a), costs(&b));
    }

    #[test]
    fn invalid_and_empty_candidate_sets_are_rejected() {
        let dfgs = [dfg::benchmarks::benchmark("SOB")];
        let err = FabricExplorer::new(Grid::new(6, 6))
            .dfgs(&dfgs)
            .candidates(Vec::new())
            .run()
            .unwrap_err();
        assert!(matches!(err, ExploreError::Infeasible(_)));
        let bad = FabricSpec { link_cap: 0, ..FabricSpec::default() };
        let err = FabricExplorer::new(Grid::new(6, 6))
            .dfgs(&dfgs)
            .candidates(vec![bad])
            .run()
            .unwrap_err();
        assert!(matches!(err, ExploreError::Infeasible(_)));
    }
}
