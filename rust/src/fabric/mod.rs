//! Fabric model: the provisioning-searchable generalisation of
//! [`crate::cgra::Grid`].
//!
//! A [`Fabric`] describes the *interconnect* half of the architecture
//! the layout search provisions: the cell array (rows × cols, with
//! optional masked/irregular dead cells), a [`Topology`] (the classic
//! 4-neighbour mesh, the 8-neighbour diagonal mesh, or express links
//! that jump a configurable stride), a per-link capacity, and an
//! explicit I/O *border-side mask* replacing the implicit
//! kind-by-position rule. It exposes the same `neighbors`/`link`/
//! `num_links` surface the PathFinder router, placement and `CellSet`
//! occupancy consume, so the whole mapper runs on a fabric instead of
//! the fixed mesh.
//!
//! ## Compatibility contract
//!
//! The default fabric — [`Topology::Mesh4`], link capacity 1, all four
//! I/O sides enabled, no masked cells — reproduces today's `Grid`
//! **exactly**: direction indices 0..4 are N, E, S, W in that order,
//! `link(cell, dir) = cell*4 + dir`, `num_links = num_cells*4`, and
//! `min_hops` equals the Manhattan distance. Every trace, fingerprint
//! and table stays byte-identical by default (pinned by the equivalence
//! tests below and the property test in `rust/tests/properties.rs`).
//!
//! Richer topologies append directions *after* the four mesh ones:
//!
//! * [`Topology::Mesh8`] ("diagonal"): dirs 4..8 are NE, SE, SW, NW;
//! * [`Topology::Express`]: dirs 4..8 are N, E, S, W jumps of `stride`
//!   cells (bypass wires over the mesh, Li et al.-style).
//!
//! I/O semantics under the side mask: a border cell on a *disabled*
//! side stays a border cell but becomes **inert** — its switches still
//! route, but it hosts no LOAD/STORE (placement skips it and the Mem
//! capacity precheck counts only active I/O cells). Interior masked
//! cells are *dead*: `neighbor` never enters or leaves them, so routes
//! avoid them entirely. Masked cells are a model-level facility
//! (exercised by unit tests and available to library callers); the CLI
//! exposes topology, capacity and the I/O mask.

pub mod explore;

use crate::cgra::{CellId, Grid, DIRS};
use std::sync::Arc;

/// I/O border-side mask bits (north/east/south/west edges of the
/// border ring). Corners belong to two sides and stay active while
/// either is enabled.
pub const SIDE_N: u8 = 1 << 0;
pub const SIDE_E: u8 = 1 << 1;
pub const SIDE_S: u8 = 1 << 2;
pub const SIDE_W: u8 = 1 << 3;
/// All four sides: the legacy kind-by-position behaviour.
pub const IO_ALL_SIDES: u8 = SIDE_N | SIDE_E | SIDE_S | SIDE_W;

/// Diagonal direction offsets for [`Topology::Mesh8`], dirs 4..8 in
/// order NE, SE, SW, NW (clockwise from NE, mirroring the N,E,S,W
/// clockwise order of dirs 0..4).
const DIAG: [(i32, i32); 4] = [(-1, 1), (1, 1), (1, -1), (-1, -1)];

/// Interconnect topology of a fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Topology {
    /// 4-nearest-neighbour mesh: the paper's T-CGRA interconnect and
    /// the byte-identical default.
    Mesh4,
    /// 8-neighbour mesh ("diagonal"): adds NE/SE/SW/NW links.
    Mesh8,
    /// Mesh plus express links jumping `stride` cells along each axis.
    Express { stride: usize },
}

impl Topology {
    /// Outgoing link directions per cell. Dirs 0..4 are always N,E,S,W.
    pub fn num_dirs(self) -> usize {
        match self {
            Topology::Mesh4 => 4,
            Topology::Mesh8 | Topology::Express { .. } => 8,
        }
    }

    /// (row, col) offset of direction `dir`.
    pub fn offset(self, dir: usize) -> (i32, i32) {
        if dir < 4 {
            return DIRS[dir];
        }
        match self {
            Topology::Mesh4 => panic!("Mesh4 has 4 directions, got dir {dir}"),
            Topology::Mesh8 => DIAG[dir - 4],
            Topology::Express { stride } => {
                let (dr, dc) = DIRS[dir - 4];
                (dr * stride as i32, dc * stride as i32)
            }
        }
    }

    /// Canonical CLI/wire name.
    pub fn name(self) -> &'static str {
        match self {
            Topology::Mesh4 => "mesh4",
            Topology::Mesh8 => "diagonal",
            Topology::Express { .. } => "express",
        }
    }

    /// Parse a CLI/wire/config topology name. `stride` is consumed only
    /// by `express` (the `--express-stride` flag / `fabric.express_stride`
    /// key).
    pub fn parse(name: &str, stride: usize) -> Result<Topology, String> {
        match name {
            "mesh4" | "mesh" => Ok(Topology::Mesh4),
            "diagonal" | "mesh8" => Ok(Topology::Mesh8),
            "express" => {
                if stride < 2 {
                    return Err(format!(
                        "express stride must be at least 2, got {stride}"
                    ));
                }
                Ok(Topology::Express { stride })
            }
            other => Err(format!(
                "unknown topology '{other}' (expected mesh4, diagonal or express)"
            )),
        }
    }
}

/// Parse an I/O side mask like `"nesw"`, `"ns"` or `"all"` into side
/// bits. Order-insensitive; rejects empty masks and unknown sides.
pub fn parse_io_mask(s: &str) -> Result<u8, String> {
    if s == "all" {
        return Ok(IO_ALL_SIDES);
    }
    let mut mask = 0u8;
    for ch in s.chars() {
        mask |= match ch.to_ascii_lowercase() {
            'n' => SIDE_N,
            'e' => SIDE_E,
            's' => SIDE_S,
            'w' => SIDE_W,
            other => return Err(format!("unknown I/O side '{other}' (expected n/e/s/w)")),
        };
    }
    if mask == 0 {
        return Err("I/O mask cannot be empty (no side would host LOAD/STORE)".into());
    }
    Ok(mask)
}

/// Render an I/O side mask in canonical `nesw` order.
pub fn io_mask_name(mask: u8) -> String {
    let mut s = String::new();
    for (bit, ch) in [(SIDE_N, 'n'), (SIDE_E, 'e'), (SIDE_S, 's'), (SIDE_W, 'w')] {
        if mask & bit != 0 {
            s.push(ch);
        }
    }
    s
}

/// The provisioning knobs of a fabric, without the grid: what travels
/// on [`crate::service::JobSpec`]s, config files and CLI flags.
/// `Default` is the byte-identical legacy fabric; [`Self::is_default`]
/// gates fingerprint/codec participation so pre-fabric specs keep their
/// fingerprints, store keys and wire bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FabricSpec {
    pub topology: Topology,
    /// Values one directed link carries per configuration (the paper's
    /// fabric is 1).
    pub link_cap: u8,
    /// Border sides hosting I/O cells (see [`IO_ALL_SIDES`]).
    pub io_mask: u8,
}

impl Default for FabricSpec {
    fn default() -> Self {
        Self { topology: Topology::Mesh4, link_cap: 1, io_mask: IO_ALL_SIDES }
    }
}

impl FabricSpec {
    /// True when building this spec reproduces the legacy grid exactly.
    pub fn is_default(&self) -> bool {
        *self == Self::default()
    }

    /// Validate the knobs (total: wire decoding routes through this so
    /// hostile bodies 400 instead of panicking).
    pub fn validate(&self) -> Result<(), String> {
        if self.link_cap == 0 {
            return Err("link capacity must be at least 1".into());
        }
        if self.io_mask == 0 || self.io_mask > IO_ALL_SIDES {
            return Err(format!(
                "I/O mask must be a non-empty subset of nesw, got {:#06b}",
                self.io_mask
            ));
        }
        if let Topology::Express { stride } = self.topology {
            if stride < 2 {
                return Err(format!("express stride must be at least 2, got {stride}"));
            }
        }
        Ok(())
    }

    /// Instantiate on a grid.
    pub fn build(&self, grid: Grid) -> Fabric {
        Fabric {
            grid,
            topology: self.topology,
            link_cap: self.link_cap,
            io_mask: self.io_mask,
            masked: None,
        }
    }

    /// Compact human/wire descriptor, e.g. `mesh4`, `express:3`,
    /// `diagonal+cap2`, `mesh4+io:ns`. The default renders as `mesh4`.
    pub fn describe(&self) -> String {
        let mut s = match self.topology {
            Topology::Express { stride } => format!("express:{stride}"),
            t => t.name().to_string(),
        };
        if self.link_cap != 1 {
            s.push_str(&format!("+cap{}", self.link_cap));
        }
        if self.io_mask != IO_ALL_SIDES {
            s.push_str(&format!("+io:{}", io_mask_name(self.io_mask)));
        }
        s
    }
}

/// A concrete fabric: a grid plus its interconnect provisioning. Cheap
/// to clone (masked cells are shared); content-compared and
/// content-hashed.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Fabric {
    grid: Grid,
    topology: Topology,
    link_cap: u8,
    io_mask: u8,
    /// Dead cells (sorted, deduped): `neighbor` never enters or leaves
    /// them. Model-level irregularity; `None` for regular fabrics.
    masked: Option<Arc<Vec<CellId>>>,
}

impl Fabric {
    /// The byte-identical legacy fabric over `grid`.
    pub fn mesh4(grid: Grid) -> Self {
        FabricSpec::default().build(grid)
    }

    /// Build from provisioning knobs.
    pub fn new(grid: Grid, spec: FabricSpec) -> Self {
        spec.build(grid)
    }

    /// Mark cells dead (irregular array). Sorted and deduped so equal
    /// masked sets compare and hash equal.
    pub fn with_masked(mut self, cells: &[CellId]) -> Self {
        let mut v: Vec<CellId> = cells.to_vec();
        v.sort_unstable();
        v.dedup();
        v.retain(|&c| (c as usize) < self.grid.num_cells());
        self.masked = if v.is_empty() { None } else { Some(Arc::new(v)) };
        self
    }

    pub fn grid(&self) -> Grid {
        self.grid
    }

    pub fn topology(&self) -> Topology {
        self.topology
    }

    pub fn link_cap(&self) -> usize {
        self.link_cap as usize
    }

    pub fn io_mask(&self) -> u8 {
        self.io_mask
    }

    /// The provisioning knobs, without the grid.
    pub fn spec(&self) -> FabricSpec {
        FabricSpec { topology: self.topology, link_cap: self.link_cap, io_mask: self.io_mask }
    }

    /// True for the legacy-equivalent fabric (Mesh4, cap 1, all I/O
    /// sides, no masked cells).
    pub fn is_default(&self) -> bool {
        self.spec().is_default() && self.masked.is_none()
    }

    /// Compact descriptor (see [`FabricSpec::describe`]); masked cells
    /// append their count.
    pub fn describe(&self) -> String {
        let mut s = self.spec().describe();
        if let Some(m) = &self.masked {
            s.push_str(&format!("+masked{}", m.len()));
        }
        s
    }

    /// Outgoing link directions per cell (4 or 8).
    pub fn num_dirs(&self) -> usize {
        self.topology.num_dirs()
    }

    pub fn is_masked(&self, cell: CellId) -> bool {
        self.masked.as_ref().map_or(false, |m| m.binary_search(&cell).is_ok())
    }

    /// Border cell that actually hosts LOAD/STORE: lies on at least one
    /// enabled side and is not masked. Border cells on disabled sides
    /// are *inert* — routing-only.
    pub fn is_active_io(&self, cell: CellId) -> bool {
        self.grid.is_io(cell) && !self.is_masked(cell) && self.sides(cell) & self.io_mask != 0
    }

    /// Border cell whose I/O is disabled by the side mask (or masking):
    /// still routes, hosts no ops.
    pub fn is_inert_io(&self, cell: CellId) -> bool {
        self.grid.is_io(cell) && !self.is_active_io(cell)
    }

    /// Which border sides a cell lies on (0 for interior cells).
    fn sides(&self, cell: CellId) -> u8 {
        let (r, c) = self.grid.coords(cell);
        let mut s = 0u8;
        if r == 0 {
            s |= SIDE_N;
        }
        if c == self.grid.cols - 1 {
            s |= SIDE_E;
        }
        if r == self.grid.rows - 1 {
            s |= SIDE_S;
        }
        if c == 0 {
            s |= SIDE_W;
        }
        s
    }

    /// Active I/O cells in row-major order.
    pub fn active_io_cells(&self) -> impl Iterator<Item = CellId> + '_ {
        self.grid.cells().filter(move |&c| self.is_active_io(c))
    }

    pub fn num_active_io(&self) -> usize {
        self.active_io_cells().count()
    }

    /// Neighbour of `cell` in direction `dir`, if the link exists:
    /// inside the grid and neither endpoint dead.
    pub fn neighbor(&self, cell: CellId, dir: usize) -> Option<CellId> {
        if self.is_masked(cell) {
            return None;
        }
        let (r, c) = self.grid.coords(cell);
        let (dr, dc) = self.topology.offset(dir);
        let (nr, nc) = (r as i32 + dr, c as i32 + dc);
        if nr < 0 || nc < 0 || nr >= self.grid.rows as i32 || nc >= self.grid.cols as i32 {
            return None;
        }
        let n = self.grid.cell(nr as usize, nc as usize);
        if self.is_masked(n) {
            return None;
        }
        Some(n)
    }

    /// All reachable neighbours, in direction order (mesh dirs first).
    pub fn neighbors(&self, cell: CellId) -> impl Iterator<Item = CellId> + '_ {
        (0..self.num_dirs()).filter_map(move |d| self.neighbor(cell, d))
    }

    /// Directed-link id of the link leaving `cell` in direction `dir`.
    /// Dense in `[0, num_dirs*num_cells)`; identical to
    /// [`Grid::link`] for Mesh4.
    pub fn link(&self, cell: CellId, dir: usize) -> usize {
        cell as usize * self.num_dirs() + dir
    }

    pub fn num_links(&self) -> usize {
        self.grid.num_cells() * self.num_dirs()
    }

    /// The direction whose link connects `a` to `b`, if adjacent.
    pub fn direction(&self, a: CellId, b: CellId) -> Option<usize> {
        (0..self.num_dirs()).find(|&d| self.neighbor(a, d) == Some(b))
    }

    /// Minimum hop count between two cells on an unobstructed fabric —
    /// the admissible routing heuristic and placement distance.
    /// Manhattan on Mesh4, Chebyshev on Mesh8, per-axis optimal
    /// express/unit mix on Express.
    pub fn min_hops(&self, a: CellId, b: CellId) -> usize {
        let (ar, ac) = self.grid.coords(a);
        let (br, bc) = self.grid.coords(b);
        let (dr, dc) = (ar.abs_diff(br), ac.abs_diff(bc));
        match self.topology {
            Topology::Mesh4 => dr + dc,
            Topology::Mesh8 => dr.max(dc),
            Topology::Express { stride } => axis_hops(dr, stride) + axis_hops(dc, stride),
        }
    }
}

/// Fewest hops to cover `d` cells along one axis with unit hops and
/// `stride`-jump express hops: `min_k (k + |d - k*stride|)`. The
/// optimum is at `k = d/stride` or one above.
fn axis_hops(d: usize, stride: usize) -> usize {
    let k0 = d / stride;
    let mut best = d;
    for k in [k0, k0 + 1] {
        best = best.min(k + d.abs_diff(k * stride));
    }
    best
}

impl std::fmt::Display for Fabric {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}", self.grid, self.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh4_reproduces_grid_links_and_neighbors_exactly() {
        // the byte-identity cornerstone: every link id, every neighbor,
        // every iteration order matches the legacy Grid surface
        for (r, c) in [(3, 3), (4, 7), (6, 6)] {
            let g = Grid::new(r, c);
            let f = Fabric::mesh4(g);
            assert_eq!(f.num_dirs(), 4);
            assert_eq!(f.num_links(), g.num_links());
            for cell in g.cells() {
                for d in 0..4 {
                    assert_eq!(f.link(cell, d), g.link(cell, d));
                    assert_eq!(f.neighbor(cell, d), g.neighbor(cell, d));
                }
                let fab: Vec<CellId> = f.neighbors(cell).collect();
                let leg: Vec<CellId> = g.neighbors(cell).collect();
                assert_eq!(fab, leg, "neighbor iteration order must match");
                for other in g.cells() {
                    assert_eq!(f.min_hops(cell, other), g.manhattan(cell, other));
                }
                assert_eq!(f.is_active_io(cell), g.is_io(cell));
            }
            assert_eq!(f.num_active_io(), g.num_io());
            assert!(f.is_default());
        }
    }

    #[test]
    fn mesh8_adds_diagonals_after_the_mesh_dirs() {
        let g = Grid::new(5, 5);
        let f = FabricSpec { topology: Topology::Mesh8, ..Default::default() }.build(g);
        assert_eq!(f.num_dirs(), 8);
        let c = g.cell(2, 2);
        // dirs 0..4 unchanged
        assert_eq!(f.neighbor(c, 0), Some(g.cell(1, 2)));
        assert_eq!(f.neighbor(c, 3), Some(g.cell(2, 1)));
        // dirs 4..8: NE, SE, SW, NW
        assert_eq!(f.neighbor(c, 4), Some(g.cell(1, 3)));
        assert_eq!(f.neighbor(c, 5), Some(g.cell(3, 3)));
        assert_eq!(f.neighbor(c, 6), Some(g.cell(3, 1)));
        assert_eq!(f.neighbor(c, 7), Some(g.cell(1, 1)));
        // corner has 2 mesh + 1 diagonal neighbor
        assert_eq!(f.neighbors(g.cell(0, 0)).count(), 3);
        // chebyshev distance
        assert_eq!(f.min_hops(g.cell(0, 0), g.cell(3, 4)), 4);
        assert_eq!(f.min_hops(g.cell(1, 1), g.cell(2, 2)), 1);
        assert!(!f.is_default());
    }

    #[test]
    fn express_links_jump_the_stride() {
        let g = Grid::new(7, 7);
        let f = FabricSpec { topology: Topology::Express { stride: 3 }, ..Default::default() }
            .build(g);
        let c = g.cell(3, 3);
        assert_eq!(f.neighbor(c, 4), Some(g.cell(0, 3))); // N×3
        assert_eq!(f.neighbor(c, 5), Some(g.cell(3, 6))); // E×3
        assert_eq!(f.neighbor(c, 6), Some(g.cell(6, 3))); // S×3
        assert_eq!(f.neighbor(c, 7), Some(g.cell(3, 0))); // W×3
        // near the border the jump leaves the grid
        assert_eq!(f.neighbor(g.cell(1, 1), 4), None);
        // min_hops mixes express and unit hops optimally per axis
        assert_eq!(f.min_hops(g.cell(0, 0), g.cell(0, 6)), 2); // 2 express
        assert_eq!(f.min_hops(g.cell(0, 0), g.cell(0, 4)), 2); // 3+1
        assert_eq!(f.min_hops(g.cell(0, 0), g.cell(0, 2)), 2); // 1+1 or 3-1
        assert_eq!(f.min_hops(g.cell(0, 0), g.cell(4, 5)), 5); // (3+1)+(3+1+1)
        assert_eq!(axis_hops(7, 3), 3); // 3+3+1
        assert_eq!(axis_hops(0, 3), 0);
    }

    #[test]
    fn link_ids_dense_and_distinct_on_eight_dir_fabrics() {
        let g = Grid::new(3, 3);
        let f = FabricSpec { topology: Topology::Mesh8, ..Default::default() }.build(g);
        let mut seen = std::collections::HashSet::new();
        for c in g.cells() {
            for d in 0..f.num_dirs() {
                assert!(seen.insert(f.link(c, d)));
                assert!(f.link(c, d) < f.num_links());
            }
        }
        assert_eq!(f.num_links(), 9 * 8);
    }

    #[test]
    fn io_side_mask_makes_disabled_sides_inert() {
        let g = Grid::new(5, 6);
        let f = FabricSpec { io_mask: SIDE_N | SIDE_S, ..Default::default() }.build(g);
        // top and bottom rows (incl. corners) stay active
        assert!(f.is_active_io(g.cell(0, 0)));
        assert!(f.is_active_io(g.cell(0, 3)));
        assert!(f.is_active_io(g.cell(4, 5)));
        // east/west edges (non-corner) are inert: route-only
        assert!(f.is_inert_io(g.cell(2, 0)));
        assert!(f.is_inert_io(g.cell(1, 5)));
        assert!(!f.is_active_io(g.cell(2, 0)));
        // inert cells still route: their links exist
        assert_eq!(f.neighbor(g.cell(2, 0), 1), Some(g.cell(2, 1)));
        // 2 full rows of 6
        assert_eq!(f.num_active_io(), 12);
        // compute cells are never I/O of any kind
        assert!(!f.is_active_io(g.cell(2, 2)) && !f.is_inert_io(g.cell(2, 2)));
    }

    #[test]
    fn masked_cells_are_dead() {
        let g = Grid::new(5, 5);
        let dead = g.cell(2, 2);
        let f = Fabric::mesh4(g).with_masked(&[dead, dead]); // dedup
        assert!(f.is_masked(dead));
        assert!(!f.is_default());
        // no link enters or leaves a dead cell
        for d in 0..4 {
            assert_eq!(f.neighbor(dead, d), None);
        }
        assert_eq!(f.neighbor(g.cell(1, 2), 2), None, "S into the dead cell");
        assert_eq!(f.neighbor(g.cell(2, 1), 1), None, "E into the dead cell");
        // routes can still pass around it
        assert_eq!(f.neighbor(g.cell(1, 2), 1), Some(g.cell(1, 3)));
        // a masked border cell is not active I/O
        let fb = Fabric::mesh4(g).with_masked(&[g.cell(0, 2)]);
        assert!(!fb.is_active_io(g.cell(0, 2)));
        assert!(fb.is_inert_io(g.cell(0, 2)));
        assert_eq!(fb.num_active_io(), g.num_io() - 1);
    }

    #[test]
    fn direction_finds_the_connecting_link() {
        let g = Grid::new(6, 6);
        let f = FabricSpec { topology: Topology::Express { stride: 4 }, ..Default::default() }
            .build(g);
        let c = g.cell(4, 1);
        assert_eq!(f.direction(c, g.cell(3, 1)), Some(0));
        assert_eq!(f.direction(c, g.cell(0, 1)), Some(4)); // express N
        assert_eq!(f.direction(c, g.cell(4, 5)), Some(5)); // express E
        assert_eq!(f.direction(c, g.cell(1, 2)), None);
    }

    #[test]
    fn spec_validation_and_describe() {
        assert!(FabricSpec::default().is_default());
        assert!(FabricSpec::default().validate().is_ok());
        assert_eq!(FabricSpec::default().describe(), "mesh4");

        let bad_cap = FabricSpec { link_cap: 0, ..Default::default() };
        assert!(bad_cap.validate().unwrap_err().contains("capacity"));
        let bad_mask = FabricSpec { io_mask: 0, ..Default::default() };
        assert!(bad_mask.validate().unwrap_err().contains("I/O mask"));
        let bad_stride =
            FabricSpec { topology: Topology::Express { stride: 1 }, ..Default::default() };
        assert!(bad_stride.validate().unwrap_err().contains("stride"));

        let rich = FabricSpec {
            topology: Topology::Express { stride: 3 },
            link_cap: 2,
            io_mask: SIDE_N | SIDE_S,
        };
        assert!(!rich.is_default());
        assert_eq!(rich.describe(), "express:3+cap2+io:ns");
        assert_eq!(
            FabricSpec { topology: Topology::Mesh8, ..Default::default() }.describe(),
            "diagonal"
        );
    }

    #[test]
    fn topology_and_mask_parsing() {
        assert_eq!(Topology::parse("mesh4", 0), Ok(Topology::Mesh4));
        assert_eq!(Topology::parse("diagonal", 0), Ok(Topology::Mesh8));
        assert_eq!(Topology::parse("mesh8", 0), Ok(Topology::Mesh8));
        assert_eq!(Topology::parse("express", 3), Ok(Topology::Express { stride: 3 }));
        assert!(Topology::parse("express", 1).is_err());
        assert!(Topology::parse("torus", 0).is_err());
        assert_eq!(Topology::Mesh8.name(), "diagonal");

        assert_eq!(parse_io_mask("all"), Ok(IO_ALL_SIDES));
        assert_eq!(parse_io_mask("nesw"), Ok(IO_ALL_SIDES));
        assert_eq!(parse_io_mask("sn"), Ok(SIDE_N | SIDE_S));
        assert!(parse_io_mask("x").is_err());
        assert!(parse_io_mask("").is_err());
        assert_eq!(io_mask_name(SIDE_N | SIDE_S), "ns");
        assert_eq!(io_mask_name(IO_ALL_SIDES), "nesw");
    }

    #[test]
    fn fabric_equality_and_hash_are_content_based() {
        use std::collections::HashSet;
        let g = Grid::new(5, 5);
        let a = Fabric::mesh4(g).with_masked(&[7, 12]);
        let b = Fabric::mesh4(g).with_masked(&[12, 7]); // order-insensitive
        assert_eq!(a, b);
        let mut set = HashSet::new();
        set.insert(a.clone());
        assert!(set.contains(&b));
        assert_ne!(a, Fabric::mesh4(g));
        assert_ne!(
            Fabric::mesh4(g),
            FabricSpec { link_cap: 2, ..Default::default() }.build(g)
        );
    }
}
