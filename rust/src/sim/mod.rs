//! Cycle-level elastic dataflow simulator for mapped DFGs.
//!
//! T-CGRA executes DFGs under an *elastic dynamic dataflow* model
//! (Section II-A): every cell input has a FIFO, a cell fires when all
//! its input FIFOs hold a token and all output channels have credit, and
//! links forward one token per cycle. DFG instances stream through the
//! fabric pipelined.
//!
//! The paper argues (Section IV-I) that HeLEx's heterogeneous layouts
//! increase only *fill latency* (longer routes on the critical path) and
//! leave *steady-state throughput* untouched because mappings stay
//! balanced. The static critical-path metric in `metrics` asserts the
//! first half; this simulator validates both claims executably:
//! [`simulate`] streams `n_instances` through the mapped fabric and
//! reports fill latency, steady-state initiation interval and FIFO
//! occupancy.

use crate::cgra::Layout;
use crate::dfg::{Dfg, NodeId};
use crate::mapper::Mapping;
use crate::ops::Op;

/// Per-cell input FIFO depth (the paper's cells carry 4x4x32 FIFO sets;
/// depth 4 per input).
pub const FIFO_DEPTH: usize = 4;

/// Result of a simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Cycle at which the first DFG instance fully drained (all stores
    /// fired once) — the fill latency.
    pub fill_latency: usize,
    /// Cycles between successive completed instances in steady state
    /// (averaged over the second half of the run).
    pub steady_ii: f64,
    /// Total cycles simulated.
    pub cycles: usize,
    /// Instances completed.
    pub completed: usize,
    /// Maximum FIFO occupancy observed across all edges (≤ capacity).
    pub max_fifo_occupancy: usize,
}

/// One in-flight token: which DFG instance it belongs to, and when it
/// becomes visible at the consumer (models per-hop link latency).
#[derive(Debug, Clone, Copy)]
struct Token {
    instance: u32,
    ready_at: usize,
}

/// An elastic channel for one DFG edge: a bounded FIFO whose capacity is
/// the route length plus the destination FIFO depth (tokens in flight on
/// the wire count against capacity, as in elastic pipelines).
#[derive(Debug, Clone)]
struct Channel {
    fifo: std::collections::VecDeque<Token>,
    capacity: usize,
    hops: usize,
    max_seen: usize,
}

impl Channel {
    fn new(hops: usize) -> Self {
        Self {
            fifo: std::collections::VecDeque::new(),
            capacity: hops.max(1) + FIFO_DEPTH,
            hops,
            max_seen: 0,
        }
    }
    fn has_space(&self) -> bool {
        self.fifo.len() < self.capacity
    }
    fn head_ready(&self, now: usize) -> Option<u32> {
        self.fifo.front().and_then(|t| (t.ready_at <= now).then_some(t.instance))
    }
    fn push(&mut self, instance: u32, now: usize) {
        self.fifo.push_back(Token { instance, ready_at: now + self.hops });
        self.max_seen = self.max_seen.max(self.fifo.len());
    }
}

/// Simulate `n_instances` of a mapped DFG streaming through the fabric.
///
/// `max_cycles` bounds runaway simulations (deadlock would indicate a
/// mapper bug; the simulator asserts progress instead of hanging).
pub fn simulate(
    dfg: &Dfg,
    _layout: &Layout,
    mapping: &Mapping,
    n_instances: usize,
    max_cycles: usize,
) -> SimReport {
    let n = dfg.num_nodes();
    let preds = dfg.preds();
    // channels indexed like dfg.edges; per node: in-edge ids, out-edge ids
    let mut channels: Vec<Channel> = dfg
        .edges
        .iter()
        .enumerate()
        .map(|(i, _)| Channel::new(mapping.edge_paths[i].len().saturating_sub(1)))
        .collect();
    let mut in_edges: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut out_edges: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, &(s, d)) in dfg.edges.iter().enumerate() {
        out_edges[s as usize].push(i);
        in_edges[d as usize].push(i);
    }

    // per-load: next instance to emit; per-store: instances consumed
    let mut load_next: Vec<u32> = vec![0; n];
    let mut store_done: Vec<u32> = vec![0; n];
    let stores: Vec<NodeId> = (0..n as NodeId)
        .filter(|&i| dfg.nodes[i as usize] == Op::Store)
        .collect();

    let mut completions: Vec<usize> = Vec::with_capacity(n_instances);
    let mut cycle = 0usize;
    while completions.len() < n_instances && cycle < max_cycles {
        // Two-phase synchronous update: decide firings on the current
        // state, then commit, so within a cycle order does not matter.
        let mut fires: Vec<NodeId> = Vec::new();
        for u in 0..n as NodeId {
            let ui = u as usize;
            let op = dfg.nodes[ui];
            let can_emit_inputs = match op {
                Op::Load => (load_next[ui] as usize) < n_instances,
                _ => in_edges[ui]
                    .iter()
                    .all(|&e| channels[e].head_ready(cycle).is_some()),
            };
            // elastic backpressure: every out-channel needs space
            let has_credit = out_edges[ui].iter().all(|&e| channels[e].has_space());
            if can_emit_inputs && has_credit {
                // all input tokens must belong to the same instance —
                // guaranteed by in-order elastic channels; assert it.
                if op != Op::Load && !in_edges[ui].is_empty() {
                    let insts: Vec<u32> = in_edges[ui]
                        .iter()
                        .map(|&e| channels[e].head_ready(cycle).unwrap())
                        .collect();
                    debug_assert!(
                        insts.windows(2).all(|w| w[0] == w[1]),
                        "instance skew at node {u}"
                    );
                }
                fires.push(u);
            }
        }
        // commit
        for &u in &fires {
            let ui = u as usize;
            let instance = match dfg.nodes[ui] {
                Op::Load => {
                    let i = load_next[ui];
                    load_next[ui] += 1;
                    i
                }
                _ => {
                    let mut inst = 0;
                    for &e in &in_edges[ui] {
                        inst = channels[e].fifo.pop_front().unwrap().instance;
                    }
                    inst
                }
            };
            for &e in &out_edges[ui] {
                channels[e].push(instance, cycle);
            }
            if dfg.nodes[ui] == Op::Store {
                store_done[ui] += 1;
            }
        }
        // an instance completes when every store has consumed it
        while !stores.is_empty()
            && stores
                .iter()
                .all(|&s| store_done[s as usize] as usize > completions.len())
        {
            completions.push(cycle + 1);
        }
        cycle += 1;
    }

    let fill_latency = completions.first().copied().unwrap_or(cycle);
    let steady_ii = if completions.len() >= 4 {
        let half = completions.len() / 2;
        let span = completions[completions.len() - 1] - completions[half];
        span as f64 / (completions.len() - 1 - half) as f64
    } else {
        f64::NAN
    };
    SimReport {
        fill_latency,
        steady_ii,
        cycles: cycle,
        completed: completions.len(),
        max_fifo_occupancy: channels.iter().map(|c| c.max_seen).max().unwrap_or(0),
    }
}

/// Convenience: map + simulate in one call.
pub fn map_and_simulate(
    dfg: &Dfg,
    layout: &Layout,
    engine: &crate::MappingEngine,
    n_instances: usize,
) -> Option<SimReport> {
    let m = engine.map(dfg, layout).into_mapping()?;
    Some(simulate(dfg, layout, &m, n_instances, sim_cycle_bound(dfg, n_instances)))
}

/// Default simulation cycle bound for `n_instances` of a DFG.
pub fn sim_cycle_bound(dfg: &Dfg, n_instances: usize) -> usize {
    64 * n_instances + 16 * dfg.num_nodes() + 4096
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::dfg::benchmarks;
    use crate::ops::GroupSet;
    use crate::MappingEngine;

    fn sim(name: &str, r: usize, c: usize, n: usize) -> (Dfg, SimReport) {
        let d = benchmarks::benchmark(name);
        let l = Layout::full(Grid::new(r, c), d.groups_used());
        let rep = map_and_simulate(&d, &l, &MappingEngine::default(), n).expect("must map");
        (d, rep)
    }

    #[test]
    fn completes_all_instances() {
        let (_, rep) = sim("SOB", 6, 6, 50);
        assert_eq!(rep.completed, 50);
        assert!(rep.cycles < 4000, "took {} cycles", rep.cycles);
    }

    #[test]
    fn steady_state_ii_is_bounded() {
        // Section IV-I: pipelined execution sustains a steady initiation
        // interval. Perfectly balanced mappings give II = 1; reconvergent
        // paths whose route-length skew exceeds the FIFO depth throttle
        // the pipeline, so II is bounded by a small constant rather than
        // exactly 1 (RodMap balances paths; our mapper does not, which
        // only strengthens the hetero-vs-full comparison test below).
        for name in ["SOB", "GB", "RGB"] {
            let (_, rep) = sim(name, 9, 9, 60);
            assert!(
                rep.steady_ii <= 2.5,
                "{name}: steady II {} should stay near 1",
                rep.steady_ii
            );
            assert!(rep.steady_ii >= 1.0 - 1e-9, "{name}: II {}", rep.steady_ii);
        }
    }

    #[test]
    fn fill_latency_tracks_static_critical_path() {
        let d = benchmarks::benchmark("BOX");
        let l = Layout::full(Grid::new(8, 8), d.groups_used());
        let engine = MappingEngine::default();
        let m = engine.map(&d, &l).into_mapping().unwrap();
        let rep = simulate(&d, &l, &m, 20, 10_000);
        let static_lat = m.latency(&d);
        // simulated fill is within 2x of the static estimate and at
        // least the DAG depth
        assert!(rep.fill_latency >= d.critical_path_nodes());
        assert!(
            rep.fill_latency <= 2 * static_lat + 8,
            "sim {} vs static {static_lat}",
            rep.fill_latency
        );
    }

    #[test]
    fn hetero_layout_same_throughput_higher_latency_or_equal() {
        // the paper's core latency/throughput claim, executably
        let dfgs = vec![benchmarks::benchmark("NMS")];
        let grid = Grid::new(9, 9);
        let engine = MappingEngine::default();
        let cost = crate::cost::CostModel::area();
        let cfg = crate::search::SearchConfig { l_test: 80, gsg_passes: 1, ..Default::default() };
        let r = crate::search::Explorer::new(grid)
            .dfgs(&dfgs)
            .engine(&engine)
            .cost(&cost)
            .config(cfg)
            .run()
            .unwrap();
        let full = map_and_simulate(&dfgs[0], &r.full_layout, &engine, 40).unwrap();
        // the best layout may only be warm-start reachable: simulate its
        // witness mapping instead of re-mapping from scratch
        let het = simulate(
            &dfgs[0],
            &r.best_layout,
            &r.final_mappings[0],
            40,
            sim_cycle_bound(&dfgs[0], 40),
        );
        assert_eq!(full.completed, 40);
        assert_eq!(het.completed, 40);
        // throughput preserved within noise
        assert!(
            het.steady_ii <= full.steady_ii * 1.3 + 0.2,
            "hetero II {} vs full II {}",
            het.steady_ii,
            full.steady_ii
        );
    }

    #[test]
    fn fifo_occupancy_bounded_by_capacity() {
        let (d, rep) = sim("FFT", 10, 10, 30);
        let _ = d;
        assert!(rep.max_fifo_occupancy <= 64, "occupancy {}", rep.max_fifo_occupancy);
        assert!(rep.max_fifo_occupancy >= 1);
    }

    #[test]
    fn zero_instances_is_a_noop() {
        let d = benchmarks::benchmark("SOB");
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute().with(crate::ops::OpGroup::Mem));
        let l = Layout::full(l.grid, d.groups_used());
        let m = MappingEngine::default().map(&d, &l).into_mapping().unwrap();
        let rep = simulate(&d, &l, &m, 0, 100);
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.cycles, 0);
    }
}
