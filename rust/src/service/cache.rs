//! Sharded, mutex-protected run cache with in-flight deduplication.
//!
//! The [`super::ExplorationService`] worker pool keys completed jobs by
//! the [`super::JobSpec`] content fingerprint so identical specs — within
//! one suite or across suites submitted to the same service — compute
//! once. Sharding keeps lock contention negligible (workers only touch a
//! shard for the microseconds of a lookup/insert; the search itself runs
//! outside every lock), and each entry is an [`std::sync::Arc`]'d slot
//! with a [`std::sync::Condvar`] so a duplicate submitted *while* its
//! twin is still running waits for that result instead of repeating
//! minutes of branch-and-bound.
//!
//! Results are deterministic per fingerprint (per-job engines with
//! derived seeds), so serving a hit is observationally identical to
//! recomputing — which is what makes `--jobs N` output byte-identical to
//! `--jobs 1`.

use super::JobOutcome;
use crate::search::SearchEvent;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// Shard count; keyed by the fingerprint's top bits so the low bits stay
/// fresh for the per-shard `HashMap`.
const NUM_SHARDS: usize = 16;

/// A completed job as stored in the cache: the outcome plus the full
/// event trace, so deduplicated jobs replay the original convergence
/// trace in their [`super::JobResult`].
#[derive(Debug, Clone)]
pub struct CachedJob {
    pub outcome: JobOutcome,
    pub events: Vec<SearchEvent>,
}

/// One cache entry: empty while its computing thread runs, then filled
/// once — or poisoned if that thread panicked, so waiters propagate the
/// panic instead of blocking forever.
#[derive(Default)]
enum SlotState {
    #[default]
    Empty,
    Ready(CachedJob),
    Poisoned,
}

#[derive(Default)]
struct Slot {
    state: Mutex<SlotState>,
    ready: Condvar,
}

impl Slot {
    /// Block until the computing thread fills (or poisons) the slot.
    fn wait(&self) -> CachedJob {
        let mut state = self.state.lock().unwrap();
        loop {
            match &*state {
                SlotState::Ready(job) => return job.clone(),
                SlotState::Poisoned => {
                    panic!("the thread computing this cached job panicked")
                }
                SlotState::Empty => state = self.ready.wait(state).unwrap(),
            }
        }
    }

    fn fill(&self, job: CachedJob) {
        *self.state.lock().unwrap() = SlotState::Ready(job);
        self.ready.notify_all();
    }

    fn poison(&self) {
        *self.state.lock().unwrap() = SlotState::Poisoned;
        self.ready.notify_all();
    }
}

/// Poisons the slot unless the computation filled it — turning a panic
/// in `compute` into a propagated panic for every waiter (instead of a
/// silent hang) and a sticky poisoned entry for later lookups.
struct FillGuard<'a> {
    slot: &'a Slot,
    filled: bool,
}

impl Drop for FillGuard<'_> {
    fn drop(&mut self) {
        if !self.filled {
            self.slot.poison();
        }
    }
}

/// The sharded run cache. See the module docs.
pub struct ShardedRunCache {
    shards: [Mutex<HashMap<u64, Arc<Slot>>>; NUM_SHARDS],
}

impl Default for ShardedRunCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ShardedRunCache {
    pub fn new() -> Self {
        Self { shards: std::array::from_fn(|_| Mutex::new(HashMap::new())) }
    }

    fn shard(&self, key: u64) -> &Mutex<HashMap<u64, Arc<Slot>>> {
        &self.shards[(key >> 60) as usize % NUM_SHARDS]
    }

    /// Look up `key`, computing on a miss. Returns `(job, true)` when the
    /// result came from the cache — including the case where this caller
    /// waited for an identical in-flight computation — and `(job, false)`
    /// when this caller ran `compute` itself. `compute` runs outside
    /// every lock, so concurrent *distinct* jobs never serialize here.
    pub fn get_or_compute(
        &self,
        key: u64,
        compute: impl FnOnce() -> CachedJob,
    ) -> (CachedJob, bool) {
        let slot = {
            let mut map = self.shard(key).lock().unwrap();
            if let Some(slot) = map.get(&key) {
                let slot = Arc::clone(slot);
                drop(map);
                return (slot.wait(), true);
            }
            let slot = Arc::new(Slot::default());
            map.insert(key, Arc::clone(&slot));
            slot
        };
        // compute outside every lock; the guard poisons the slot if
        // `compute` panics, so waiters panic too instead of hanging
        let mut guard = FillGuard { slot: &slot, filled: false };
        let job = compute();
        slot.fill(job.clone());
        guard.filled = true;
        (job, false)
    }

    /// Completed or in-flight entries currently held.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn probe(tag: usize) -> CachedJob {
        CachedJob {
            outcome: JobOutcome::Infeasible(format!("probe-{tag}")),
            events: Vec::new(),
        }
    }

    #[test]
    fn hit_after_miss_and_distinct_keys_separate() {
        let cache = ShardedRunCache::new();
        let (a, hit) = cache.get_or_compute(1, || probe(1));
        assert!(!hit);
        assert!(matches!(&a.outcome, JobOutcome::Infeasible(m) if m == "probe-1"));
        let (b, hit) = cache.get_or_compute(1, || probe(99));
        assert!(hit, "second lookup of the same key must be a hit");
        assert!(matches!(&b.outcome, JobOutcome::Infeasible(m) if m == "probe-1"));
        let (_, hit) = cache.get_or_compute(2, || probe(2));
        assert!(!hit, "a different key must compute");
        assert_eq!(cache.len(), 2);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn keys_spread_across_shards() {
        let cache = ShardedRunCache::new();
        for i in 0..64u64 {
            // use high bits so the shard selector actually varies
            cache.get_or_compute(i << 58, || probe(i as usize));
        }
        assert_eq!(cache.len(), 64);
        let occupied =
            cache.shards.iter().filter(|s| !s.lock().unwrap().is_empty()).count();
        assert!(occupied > 1, "64 spread keys must occupy multiple shards");
    }

    #[test]
    fn panicked_computation_poisons_the_slot() {
        let cache = ShardedRunCache::new();
        let first = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            cache.get_or_compute(9, || panic!("boom"));
        }));
        assert!(first.is_err());
        // later lookups of the poisoned key propagate instead of hanging
        let second = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            cache.get_or_compute(9, || probe(9));
        }));
        assert!(second.is_err(), "poisoned slot must propagate the panic");
        // other keys are unaffected
        let (job, hit) = cache.get_or_compute(10, || probe(10));
        assert!(!hit);
        assert!(matches!(&job.outcome, JobOutcome::Infeasible(m) if m == "probe-10"));
    }

    #[test]
    fn concurrent_duplicates_compute_once() {
        let cache = ShardedRunCache::new();
        let computed = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for _ in 0..8 {
                handles.push(s.spawn(|| {
                    cache.get_or_compute(7, || {
                        computed.fetch_add(1, Ordering::SeqCst);
                        // widen the in-flight window so siblings really wait
                        std::thread::sleep(std::time::Duration::from_millis(30));
                        probe(7)
                    })
                }));
            }
            let results: Vec<(CachedJob, bool)> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            assert_eq!(computed.load(Ordering::SeqCst), 1, "duplicates must compute once");
            assert_eq!(results.iter().filter(|(_, hit)| !hit).count(), 1);
            for (job, _) in &results {
                assert!(matches!(&job.outcome, JobOutcome::Infeasible(m) if m == "probe-7"));
            }
        });
        assert_eq!(cache.len(), 1);
    }
}
