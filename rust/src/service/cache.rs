//! Sharded, mutex-protected run cache with in-flight deduplication.
//!
//! The [`super::ExplorationService`] worker pool keys completed jobs by
//! the [`super::JobSpec`] content fingerprint so identical specs — within
//! one suite or across suites submitted to the same service — compute
//! once. Sharding keeps lock contention negligible (workers only touch a
//! shard for the microseconds of a lookup/insert; the search itself runs
//! outside every lock), and each entry is an [`std::sync::Arc`]'d slot
//! with a [`std::sync::Condvar`] so a duplicate submitted *while* its
//! twin is still running waits for that result instead of repeating
//! minutes of branch-and-bound. A computation that panics poisons its
//! slot for the waiters of that attempt (they propagate instead of
//! hanging) but the entry itself is dropped, so the key stays
//! retryable — one transient panic never permanently wedges a
//! fingerprint.
//!
//! Results are deterministic per fingerprint (per-job engines with
//! derived seeds), so serving a hit is observationally identical to
//! recomputing — which is what makes `--jobs N` output byte-identical to
//! `--jobs 1`.

use super::JobOutcome;
use crate::search::SearchEvent;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// Shard count; keyed by the fingerprint's top bits so the low bits stay
/// fresh for the per-shard `HashMap`.
const NUM_SHARDS: usize = 16;

/// A completed job as stored in the cache: the outcome plus the full
/// event trace, so deduplicated jobs replay the original convergence
/// trace in their [`super::JobResult`].
#[derive(Debug, Clone)]
pub struct CachedJob {
    pub outcome: JobOutcome,
    pub events: Vec<SearchEvent>,
}

/// One cache entry: empty while its computing thread runs, then filled
/// once — or poisoned if that thread panicked, so waiters propagate the
/// panic instead of blocking forever.
#[derive(Default)]
enum SlotState {
    #[default]
    Empty,
    Ready(CachedJob),
    Poisoned,
}

#[derive(Default)]
struct Slot {
    state: Mutex<SlotState>,
    ready: Condvar,
}

impl Slot {
    /// True once the computation resolved (filled or poisoned). Only
    /// settled slots are eviction candidates: evicting an in-flight slot
    /// would orphan the computing thread's entry and let a concurrent
    /// twin start a duplicate computation.
    fn is_settled(&self) -> bool {
        !matches!(*self.state.lock().unwrap(), SlotState::Empty)
    }

    /// Block until the computing thread fills (or poisons) the slot.
    fn wait(&self) -> CachedJob {
        let mut state = self.state.lock().unwrap();
        loop {
            match &*state {
                SlotState::Ready(job) => return job.clone(),
                SlotState::Poisoned => {
                    panic!("the thread computing this cached job panicked")
                }
                SlotState::Empty => state = self.ready.wait(state).unwrap(),
            }
        }
    }

    fn fill(&self, job: CachedJob) {
        *self.state.lock().unwrap() = SlotState::Ready(job);
        self.ready.notify_all();
    }

    fn poison(&self) {
        *self.state.lock().unwrap() = SlotState::Poisoned;
        self.ready.notify_all();
    }
}

/// Poisons the slot unless the computation filled it — turning a panic
/// in `compute` into a propagated panic for every *current* waiter
/// (instead of a silent hang) — and removes the entry from its shard,
/// so the panic is one-shot: a later submission of the same key gets a
/// fresh slot and retries instead of inheriting a permanently poisoned
/// result (a long-lived server must be able to recover from one
/// transient panic).
struct FillGuard<'a> {
    cache: &'a ShardedRunCache,
    key: u64,
    slot: &'a Arc<Slot>,
    filled: bool,
}

impl Drop for FillGuard<'_> {
    fn drop(&mut self) {
        if !self.filled {
            self.slot.poison();
            let mut shard = self.cache.shard(self.key).lock().unwrap();
            // only remove our own slot: a concurrent retry may already
            // have installed a fresh one under this key
            if let Some(entry) = shard.map.get(&self.key) {
                if Arc::ptr_eq(&entry.slot, self.slot) {
                    shard.map.remove(&self.key);
                }
            }
        }
    }
}

/// One cache entry with its LRU stamp (per-shard monotonic tick).
struct Entry {
    slot: Arc<Slot>,
    last_used: u64,
}

#[derive(Default)]
struct Shard {
    map: HashMap<u64, Entry>,
    tick: u64,
}

/// The sharded run cache. See the module docs.
///
/// Memory is bounded: each shard holds at most `per_shard_cap` entries
/// (`0` = unbounded). Inserting past the cap evicts the least recently
/// used *settled* entry — in-flight slots are never evicted (their
/// computing thread must find its entry when it fills it, and a twin
/// must keep deduplicating against it), so a shard may transiently
/// exceed the cap by the number of concurrently computing jobs.
pub struct ShardedRunCache {
    shards: [Mutex<Shard>; NUM_SHARDS],
    per_shard_cap: usize,
}

impl Default for ShardedRunCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ShardedRunCache {
    /// Unbounded cache (the embedded/suite default; long-lived servers
    /// should set a cap).
    pub fn new() -> Self {
        Self::with_capacity(0)
    }

    /// Cache holding at most `per_shard_cap` settled entries per shard
    /// (`0` = unbounded).
    pub fn with_capacity(per_shard_cap: usize) -> Self {
        Self {
            shards: std::array::from_fn(|_| Mutex::new(Shard::default())),
            per_shard_cap,
        }
    }

    fn shard(&self, key: u64) -> &Mutex<Shard> {
        &self.shards[(key >> 60) as usize % NUM_SHARDS]
    }

    /// Look up `key`, computing on a miss. Returns `(job, true)` when the
    /// result came from the cache — including the case where this caller
    /// waited for an identical in-flight computation — and `(job, false)`
    /// when this caller ran `compute` itself. `compute` runs outside
    /// every lock, so concurrent *distinct* jobs never serialize here.
    pub fn get_or_compute(
        &self,
        key: u64,
        compute: impl FnOnce() -> CachedJob,
    ) -> (CachedJob, bool) {
        let slot = {
            let mut shard = self.shard(key).lock().unwrap();
            shard.tick += 1;
            let tick = shard.tick;
            if let Some(entry) = shard.map.get_mut(&key) {
                entry.last_used = tick;
                let slot = Arc::clone(&entry.slot);
                drop(shard);
                return (slot.wait(), true);
            }
            let slot = Arc::new(Slot::default());
            shard.map.insert(key, Entry { slot: Arc::clone(&slot), last_used: tick });
            if self.per_shard_cap > 0 && shard.map.len() > self.per_shard_cap {
                // evict the LRU settled entry; the one just inserted is
                // in-flight (Empty) and therefore never a candidate
                let victim = shard
                    .map
                    .iter()
                    .filter(|(_, e)| e.slot.is_settled())
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| *k);
                if let Some(victim) = victim {
                    shard.map.remove(&victim);
                }
            }
            slot
        };
        // compute outside every lock; the guard poisons the slot if
        // `compute` panics (current waiters panic instead of hanging)
        // and drops the entry so later lookups retry
        let mut guard = FillGuard { cache: self, key, slot: &slot, filled: false };
        let job = compute();
        slot.fill(job.clone());
        guard.filled = true;
        (job, false)
    }

    /// Completed or in-flight entries currently held.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().map.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn probe(tag: usize) -> CachedJob {
        CachedJob {
            outcome: JobOutcome::Infeasible(format!("probe-{tag}")),
            events: Vec::new(),
        }
    }

    #[test]
    fn hit_after_miss_and_distinct_keys_separate() {
        let cache = ShardedRunCache::new();
        let (a, hit) = cache.get_or_compute(1, || probe(1));
        assert!(!hit);
        assert!(matches!(&a.outcome, JobOutcome::Infeasible(m) if m == "probe-1"));
        let (b, hit) = cache.get_or_compute(1, || probe(99));
        assert!(hit, "second lookup of the same key must be a hit");
        assert!(matches!(&b.outcome, JobOutcome::Infeasible(m) if m == "probe-1"));
        let (_, hit) = cache.get_or_compute(2, || probe(2));
        assert!(!hit, "a different key must compute");
        assert_eq!(cache.len(), 2);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn keys_spread_across_shards() {
        let cache = ShardedRunCache::new();
        for i in 0..64u64 {
            // use high bits so the shard selector actually varies
            cache.get_or_compute(i << 58, || probe(i as usize));
        }
        assert_eq!(cache.len(), 64);
        let occupied =
            cache.shards.iter().filter(|s| !s.lock().unwrap().map.is_empty()).count();
        assert!(occupied > 1, "64 spread keys must occupy multiple shards");
    }

    /// Keys that all land in one shard (the shard selector uses the top
    /// four bits), so per-shard capacity is exercised deterministically.
    fn same_shard_key(n: u64) -> u64 {
        (0xA << 60) | n
    }

    #[test]
    fn capacity_evicts_lru_settled_entries() {
        let cache = ShardedRunCache::with_capacity(2);
        cache.get_or_compute(same_shard_key(1), || probe(1));
        cache.get_or_compute(same_shard_key(2), || probe(2));
        // touch 1 so 2 becomes the LRU, then overflow the shard
        let (_, hit) = cache.get_or_compute(same_shard_key(1), || probe(91));
        assert!(hit);
        cache.get_or_compute(same_shard_key(3), || probe(3));
        assert_eq!(cache.len(), 2);
        let (_, hit) = cache.get_or_compute(same_shard_key(1), || probe(91));
        assert!(hit, "recently used entry must survive");
        let (job, hit) = cache.get_or_compute(same_shard_key(2), || probe(92));
        assert!(!hit, "LRU entry must have been evicted");
        assert!(matches!(&job.outcome, JobOutcome::Infeasible(m) if m == "probe-92"));
    }

    #[test]
    fn eviction_never_evicts_an_in_flight_slot() {
        let cache = ShardedRunCache::with_capacity(1);
        let (started_tx, started_rx) = std::sync::mpsc::channel();
        let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
        std::thread::scope(|s| {
            // occupy the shard's only nominal slot with an in-flight run
            // (move the receiver in: `Receiver` is Send but not Sync)
            let cache_ref = &cache;
            let worker = s.spawn(move || {
                cache_ref.get_or_compute(same_shard_key(1), || {
                    started_tx.send(()).unwrap();
                    release_rx.recv().unwrap();
                    probe(1)
                })
            });
            started_rx.recv().unwrap();
            // overflow the shard repeatedly while key 1 is in flight:
            // the settled entries churn, the in-flight slot must stay
            for n in 2..6 {
                let (_, hit) = cache.get_or_compute(same_shard_key(n), || probe(n as usize));
                assert!(!hit);
            }
            release_tx.send(()).unwrap();
            let (job, hit) = worker.join().unwrap();
            assert!(!hit);
            assert!(matches!(&job.outcome, JobOutcome::Infeasible(m) if m == "probe-1"));
        });
        // the in-flight slot was never dropped: its result is still served
        let (job, hit) = cache.get_or_compute(same_shard_key(1), || probe(99));
        assert!(hit, "slot that was in flight during eviction pressure must survive");
        assert!(matches!(&job.outcome, JobOutcome::Infeasible(m) if m == "probe-1"));
    }

    #[test]
    fn panicked_computation_is_one_shot_and_later_lookups_retry() {
        let cache = ShardedRunCache::new();
        let first = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            cache.get_or_compute(9, || panic!("boom"));
        }));
        assert!(first.is_err(), "the computing caller propagates its own panic");
        // the poisoned entry was dropped, so the key is retryable: a
        // transient panic must not permanently wedge a fingerprint
        assert_eq!(cache.len(), 0, "poisoned entry must be removed");
        let (job, hit) = cache.get_or_compute(9, || probe(9));
        assert!(!hit, "retry recomputes rather than inheriting the poison");
        assert!(matches!(&job.outcome, JobOutcome::Infeasible(m) if m == "probe-9"));
        // other keys were never affected
        let (job, hit) = cache.get_or_compute(10, || probe(10));
        assert!(!hit);
        assert!(matches!(&job.outcome, JobOutcome::Infeasible(m) if m == "probe-10"));
    }

    #[test]
    fn concurrent_duplicates_compute_once() {
        let cache = ShardedRunCache::new();
        let computed = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for _ in 0..8 {
                handles.push(s.spawn(|| {
                    cache.get_or_compute(7, || {
                        computed.fetch_add(1, Ordering::SeqCst);
                        // widen the in-flight window so siblings really wait
                        std::thread::sleep(std::time::Duration::from_millis(30));
                        probe(7)
                    })
                }));
            }
            let results: Vec<(CachedJob, bool)> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            assert_eq!(computed.load(Ordering::SeqCst), 1, "duplicates must compute once");
            assert_eq!(results.iter().filter(|(_, hit)| !hit).count(), 1);
            for (job, _) in &results {
                assert!(matches!(&job.outcome, JobOutcome::Infeasible(m) if m == "probe-7"));
            }
        });
        assert_eq!(cache.len(), 1);
    }
}
