//! The `ExplorationService`: a typed, parallel job API over the search.
//!
//! The paper's evaluation is an embarrassingly parallel sweep — DFG sets
//! × grid sizes × objectives — and this layer is what executes it at
//! scale. A job is data ([`JobSpec`]): the DFG set, target grid,
//! optimisation [`Objective`], [`SearchConfig`], [`MapperConfig`] and a
//! base seed. Submitting specs to [`ExplorationService::run_batch`]
//! assigns each a [`JobId`] and resolves it to a [`JobResult`] carrying
//! the [`SearchResult`], per-phase timings (via `SearchStats`) and the
//! full [`SearchEvent`] trace.
//!
//! Execution model:
//!
//! * a `std::thread` worker pool of `--jobs N` threads (default:
//!   available parallelism); each worker **owns the `MappingEngine` of
//!   the job it is running**, so the engine's feasibility cache stays
//!   lock-free on the mapping hot path;
//! * a sharded, mutex-protected [`cache::ShardedRunCache`] keyed by the
//!   spec's content fingerprint dedupes identical specs across
//!   experiments — duplicates submitted concurrently wait for the
//!   in-flight twin instead of recomputing;
//! * every job's mapper seed is **derived** as
//!   `splitmix64(fingerprint(spec))` ([`JobSpec::derived_seed`]), a pure
//!   function of the job's content, so results are reproducible
//!   regardless of worker count or scheduling order — `--jobs 8` emits
//!   byte-identical tables to `--jobs 1`;
//! * jobs may additionally parallelize *inside* the search
//!   (`SearchConfig::search_threads`, deterministic by construction —
//!   see [`crate::search::parallel`]); the service clamps the nested
//!   product `actively-running jobs × search_threads` to the machine's
//!   cores (a lone job on an idle pool gets every core), and
//!   `search_threads` is deliberately excluded from fingerprints so any
//!   thread count shares one cache slot and one derived seed;
//! * progress streams to the caller as [`ServiceEvent`]s (job
//!   started/improved/finished), the multi-job analogue of the
//!   `Explorer`'s per-session observer.
//!
//! Searches score natively inside jobs (the optional PJRT scorer remains
//! a single-session facility on the [`crate::coordinator::Coordinator`]
//! path). The declarative experiment suite
//! ([`crate::coordinator::suite`]) sits on top: each paper figure/table
//! is a set of specs plus a fold over the completed results.
//!
//! For serving, three more pieces live here: the [`wire`] JSON codecs
//! (specs and results over HTTP and on disk), the async [`registry`]
//! (submit/poll job states with a live per-job event log, what
//! `helex serve` executes on), and an optional
//! [`crate::store::ResultStore`] behind the run cache
//! ([`ExplorationService::with_store`]) so identical specs are answered
//! across processes and restarts without recomputation.

pub mod cache;
pub mod registry;
pub mod wire;

use crate::cgra::Grid;
use crate::cost::CostModel;
use crate::dfg::Dfg;
use crate::fabric::FabricSpec;
use crate::mapper::{MapperConfig, MappingEngine};
use crate::search::{Explorer, SearchConfig, SearchEvent, SearchResult};
use crate::store::ResultStore;
use crate::util::rng::splitmix64;
use crate::util::{StableHasher, Stopwatch};
use cache::{CachedJob, ShardedRunCache};
use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

/// Which cost model guides a job's search. (Experiment folds may still
/// evaluate the *other* model on the result, as Fig 4 does.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Objective {
    Area,
    Power,
    /// Multi-objective mode: the scalar phases still descend on the
    /// area model, but the search keeps a Pareto front over
    /// `(op count, synth area, synth power)` and runs the genetic
    /// spreading phase — see [`crate::search::SearchObjective`].
    Pareto,
}

impl Objective {
    pub fn cost_model(self) -> CostModel {
        match self {
            Objective::Area | Objective::Pareto => CostModel::area(),
            Objective::Power => CostModel::power(),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Objective::Area => "area",
            Objective::Power => "power",
            Objective::Pareto => "pareto",
        }
    }
}

/// One unit of exploration work, as data. Identical specs (by content,
/// label excluded) are interchangeable: they fingerprint equally, derive
/// the same seed, and produce the same result.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Display/grouping label (e.g. the experiment's DFG-set name). Not
    /// part of the fingerprint: two labels asking for the same
    /// computation share one run.
    pub label: String,
    pub dfgs: Vec<Dfg>,
    pub grid: Grid,
    /// Interconnect provisioning for the target grid (topology, link
    /// capacity, I/O border mask). The default Mesh4/cap-1/all-sides
    /// fabric is the legacy grid: it is excluded from the fingerprint,
    /// so every pre-fabric spec keeps its cache key and derived seed.
    pub fabric: FabricSpec,
    pub objective: Objective,
    pub search: SearchConfig,
    pub mapper: MapperConfig,
    /// Base seed mixed into [`Self::derived_seed`]; change it to get an
    /// independent replication of the same sweep.
    pub seed: u64,
}

impl JobSpec {
    /// A spec with the default objective (area), search and mapper
    /// configuration.
    pub fn new(label: impl Into<String>, dfgs: Vec<Dfg>, grid: Grid) -> Self {
        let mapper = MapperConfig::default();
        let seed = mapper.seed;
        Self {
            label: label.into(),
            dfgs,
            grid,
            fabric: FabricSpec::default(),
            objective: Objective::Area,
            search: SearchConfig::default(),
            mapper,
            seed,
        }
    }

    /// Content fingerprint: every result-relevant field (DFGs, grid,
    /// objective, search config, mapper config, base seed) — but not the
    /// label. This keys the run cache and seeds the job.
    ///
    /// The exhaustive destructuring means a field added to `JobSpec`
    /// breaks this function until someone decides whether it keys the
    /// cache; `SearchConfig`/`MapperConfig`/`Dfg` hash themselves, so
    /// their future fields participate automatically. Hashing uses the
    /// release- and platform-stable [`StableHasher`] (never
    /// `DefaultHasher`): per-job seeds derive from this value, so it is
    /// part of the reproducibility contract.
    pub fn fingerprint(&self) -> u64 {
        let Self { label: _, dfgs, grid, fabric, objective, search, mapper, seed } = self;
        let mut h = StableHasher::new();
        dfgs.hash(&mut h);
        grid.hash(&mut h);
        // the default fabric is the legacy grid: hashing it only when it
        // departs from Mesh4/cap-1/all-sides keeps every pre-fabric
        // fingerprint (and with it store keys and derived seeds) intact
        if !fabric.is_default() {
            fabric.hash(&mut h);
        }
        objective.hash(&mut h);
        search.hash(&mut h);
        mapper.hash(&mut h);
        seed.hash(&mut h);
        h.finish()
    }

    /// The mapper seed this job actually runs with:
    /// `splitmix64(fingerprint)`. A pure function of the spec's content,
    /// so a suite's results do not depend on which worker picked the job
    /// up, or in what order.
    pub fn derived_seed(&self) -> u64 {
        splitmix64(self.fingerprint())
    }

    /// `"label @ RxC"`, for progress lines.
    pub fn describe(&self) -> String {
        format!("{} @ {}x{}", self.label, self.grid.rows, self.grid.cols)
    }
}

/// Service-assigned job handle, unique within one service instance.
///
/// `Display` and `FromStr` round-trip through a *stable* zero-padded hex
/// form (`job-000000000000002a`), which is what the HTTP API puts in
/// URLs — fixed width, so ids sort lexicographically in the same order
/// as numerically and can never drift from the in-memory value (the
/// property test in `rust/tests/service.rs` pins the roundtrip).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "job-{:016x}", self.0)
    }
}

/// Failure to parse a [`JobId`] from its textual form.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParseJobIdError;

impl fmt::Display for ParseJobIdError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("invalid job id (expected 'job-' followed by up to 16 hex digits)")
    }
}

impl std::error::Error for ParseJobIdError {}

impl std::str::FromStr for JobId {
    type Err = ParseJobIdError;

    /// Accepts the canonical `job-<16 hex>` form (leading zeros and the
    /// prefix optional, so hand-typed `curl` ids work too).
    fn from_str(s: &str) -> Result<Self, ParseJobIdError> {
        let hex = s.strip_prefix("job-").unwrap_or(s);
        if hex.is_empty() || hex.len() > 16 || !hex.bytes().all(|b| b.is_ascii_hexdigit()) {
            return Err(ParseJobIdError);
        }
        u64::from_str_radix(hex, 16).map(JobId).map_err(|_| ParseJobIdError)
    }
}

/// How a job resolved.
#[derive(Debug, Clone)]
pub enum JobOutcome {
    Completed(SearchResult),
    /// The DFG set does not map on that grid — a *result*, not an error.
    Infeasible(String),
    /// The spec itself was invalid (e.g. an empty DFG set): a caller bug
    /// surfaced as data, so a worker never panics mid-batch — but kept
    /// distinct from [`Self::Infeasible`] so folds cannot present it as
    /// a scientific finding.
    Rejected(String),
}

impl JobOutcome {
    pub fn is_completed(&self) -> bool {
        matches!(self, JobOutcome::Completed(_))
    }

    pub fn search_result(&self) -> Option<&SearchResult> {
        match self {
            JobOutcome::Completed(r) => Some(r),
            JobOutcome::Infeasible(_) | JobOutcome::Rejected(_) => None,
        }
    }

    /// The infeasibility diagnostic — `None` for completed *and*
    /// rejected jobs (a rejected spec says nothing about mappability).
    pub fn infeasible_reason(&self) -> Option<&str> {
        match self {
            JobOutcome::Infeasible(why) => Some(why),
            JobOutcome::Completed(_) | JobOutcome::Rejected(_) => None,
        }
    }
}

/// The resolution of one submitted [`JobSpec`].
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: JobId,
    pub label: String,
    pub grid: Grid,
    pub fingerprint: u64,
    pub outcome: JobOutcome,
    /// The session's full [`SearchEvent`] trace (replayed from the run
    /// cache for deduplicated jobs, so every result carries one).
    pub events: Vec<SearchEvent>,
    /// Wall seconds this job occupied a worker (near zero on cache hits;
    /// per-phase search timings live in `SearchStats::phase_secs`).
    pub wall_secs: f64,
    pub from_cache: bool,
}

impl JobResult {
    pub fn best_cost(&self) -> Option<f64> {
        self.outcome.search_result().map(|r| r.best_cost)
    }
}

/// Progress stream of a batch, delivered to the `run_batch` callback on
/// the submitting thread.
#[derive(Debug, Clone)]
pub enum ServiceEvent {
    /// A worker picked the job up.
    Started { id: JobId, describe: String, worker: usize },
    /// The job's incumbent improved — forwarded from its event channel
    /// when [`ServiceConfig::live_trace`] is set.
    Improved { id: JobId, best_cost: f64, tested: usize },
    /// The job resolved (`best_cost: None` means infeasible).
    Finished {
        id: JobId,
        describe: String,
        best_cost: Option<f64>,
        secs: f64,
        from_cache: bool,
        done: usize,
        total: usize,
    },
}

/// Service tuning.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads; `0` means available parallelism.
    pub jobs: usize,
    /// Forward per-candidate `Improved` events as
    /// [`ServiceEvent::Improved`] (chatty; meant for `--verbose`).
    pub live_trace: bool,
    /// Per-shard entry cap of the in-memory run cache (16 shards, so the
    /// default bounds the cache at 16×256 completed runs); `0` =
    /// unbounded. In-flight runs never count against the cap — see
    /// [`cache::ShardedRunCache`].
    pub cache_shard_cap: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { jobs: 0, live_trace: false, cache_shard_cap: 256 }
    }
}

/// Receiver of one job's live [`SearchEvent`] stream, shared across
/// threads (the server's job registry appends to a per-job log that the
/// `/v1/jobs/:id/events` endpoint tails). For jobs served from a cache
/// or the store the full recorded trace is replayed through the sink
/// instead, so consumers always observe a complete stream.
pub trait EventSink: Send + Sync {
    fn on_event(&self, event: &SearchEvent);
}

/// Counter snapshot of one service, as served by `/v1/stats`.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub workers: usize,
    /// Completed or in-flight entries in the in-memory run cache.
    pub cache_entries: usize,
    /// Jobs actually executed by a search (the warm-restart CI check
    /// asserts this stays 0 when every answer comes from the store).
    pub computed: u64,
    /// Jobs answered by the in-memory cache (including in-flight twins).
    pub mem_hits: u64,
    /// Jobs answered by the on-disk store.
    pub store_hits: u64,
    pub store: Option<crate::store::StoreStats>,
}

/// Worker → coordinator messages (internal).
enum WorkerMsg {
    Started { index: usize, worker: usize },
    Improved { id: JobId, best_cost: f64, tested: usize },
    Finished { index: usize, result: Box<JobResult> },
}

/// The exploration service. See the module docs.
pub struct ExplorationService {
    cfg: ServiceConfig,
    cache: ShardedRunCache,
    /// Durable tier under the in-memory cache: consulted on memory
    /// misses, written through on fresh computes.
    store: Option<Arc<ResultStore>>,
    next_id: AtomicU64,
    computed: AtomicU64,
    mem_hits: AtomicU64,
    store_hits: AtomicU64,
    /// Jobs currently executing a search (not cache waits): the live
    /// divisor of the nested-parallelism budget, so a lone job on an
    /// idle pool still gets the whole machine for in-search threads.
    active_jobs: AtomicUsize,
}

impl Default for ExplorationService {
    fn default() -> Self {
        Self::new(ServiceConfig::default())
    }
}

impl ExplorationService {
    pub fn new(cfg: ServiceConfig) -> Self {
        let cache = ShardedRunCache::with_capacity(cfg.cache_shard_cap);
        Self {
            cfg,
            cache,
            store: None,
            next_id: AtomicU64::new(0),
            computed: AtomicU64::new(0),
            mem_hits: AtomicU64::new(0),
            store_hits: AtomicU64::new(0),
            active_jobs: AtomicUsize::new(0),
        }
    }

    /// Service with `jobs` workers and defaults otherwise.
    pub fn with_jobs(jobs: usize) -> Self {
        Self::new(ServiceConfig { jobs, ..Default::default() })
    }

    /// Service backed by an on-disk result store: memory misses fall
    /// through to the store, fresh computes write through to it, and
    /// identical specs are answered without recomputation across
    /// processes and restarts.
    pub fn with_store(cfg: ServiceConfig, store: Arc<ResultStore>) -> Self {
        Self { store: Some(store), ..Self::new(cfg) }
    }

    /// The backing store, if one is attached.
    pub fn store(&self) -> Option<&Arc<ResultStore>> {
        self.store.as_ref()
    }

    /// Counter snapshot for introspection (`/v1/stats`).
    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            workers: self.workers(),
            cache_entries: self.cache.len(),
            computed: self.computed.load(Ordering::Relaxed),
            mem_hits: self.mem_hits.load(Ordering::Relaxed),
            store_hits: self.store_hits.load(Ordering::Relaxed),
            store: self.store.as_ref().map(|s| s.stats()),
        }
    }

    /// Effective worker-pool width.
    pub fn workers(&self) -> usize {
        if self.cfg.jobs > 0 {
            self.cfg.jobs
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }

    /// Completed or in-flight runs held by the service's run cache.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Hand out the next job id (the async job registry assigns ids at
    /// submit time, before a worker picks the job up).
    pub fn allocate_id(&self) -> JobId {
        JobId(self.next_id.fetch_add(1, Ordering::Relaxed))
    }

    /// Run one job synchronously on the calling thread.
    pub fn run_job(&self, spec: &JobSpec) -> JobResult {
        let id = self.allocate_id();
        self.execute(id, spec, None, None)
    }

    /// Run one job synchronously, streaming its [`SearchEvent`]s into
    /// `sink` as they happen. Cache- and store-served jobs replay their
    /// recorded trace through the sink, so the stream is complete either
    /// way.
    pub fn run_job_sink(&self, spec: &JobSpec, sink: Arc<dyn EventSink>) -> JobResult {
        let id = self.allocate_id();
        self.run_assigned(id, spec, Some(sink))
    }

    /// [`Self::run_job_sink`] with a pre-allocated id (see
    /// [`Self::allocate_id`]).
    pub fn run_assigned(
        &self,
        id: JobId,
        spec: &JobSpec,
        sink: Option<Arc<dyn EventSink>>,
    ) -> JobResult {
        self.execute(id, spec, None, sink)
    }

    /// Run a batch on the worker pool; results return in submission
    /// order. `progress` (called on this thread) receives the live
    /// [`ServiceEvent`] stream.
    ///
    /// Duplicate specs inside one batch resolve to a single computation:
    /// the first claims the cache slot and the duplicate's worker waits
    /// for that result. When duplicates of *long* jobs are likely,
    /// pre-deduplicate by [`JobSpec::fingerprint`] (as the experiment
    /// suite does) so pool threads keep pulling fresh work instead of
    /// waiting on a twin.
    pub fn run_batch(
        &self,
        specs: Vec<JobSpec>,
        mut progress: Option<&mut dyn FnMut(&ServiceEvent)>,
    ) -> Vec<JobResult> {
        let total = specs.len();
        if total == 0 {
            return Vec::new();
        }
        let ids: Vec<JobId> = specs
            .iter()
            .map(|_| JobId(self.next_id.fetch_add(1, Ordering::Relaxed)))
            .collect();
        // workers() >= 1 and total >= 1 here, so the pool is never empty
        let workers = self.workers().min(total);
        let next = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<WorkerMsg>();
        let mut results: Vec<Option<JobResult>> = (0..total).map(|_| None).collect();
        std::thread::scope(|scope| {
            for worker in 0..workers {
                let tx = tx.clone();
                let (next, specs, ids) = (&next, &specs, &ids);
                scope.spawn(move || loop {
                    let index = next.fetch_add(1, Ordering::Relaxed);
                    if index >= specs.len() {
                        break;
                    }
                    let _ = tx.send(WorkerMsg::Started { index, worker });
                    let live = if self.cfg.live_trace { Some(&tx) } else { None };
                    let result = self.execute(ids[index], &specs[index], live, None);
                    let _ = tx.send(WorkerMsg::Finished { index, result: Box::new(result) });
                });
            }
            drop(tx); // the receive loop ends when the last worker exits
            let mut done = 0usize;
            for msg in rx {
                let event = match msg {
                    WorkerMsg::Started { index, worker } => ServiceEvent::Started {
                        id: ids[index],
                        describe: specs[index].describe(),
                        worker,
                    },
                    WorkerMsg::Improved { id, best_cost, tested } => {
                        ServiceEvent::Improved { id, best_cost, tested }
                    }
                    WorkerMsg::Finished { index, result } => {
                        done += 1;
                        let event = ServiceEvent::Finished {
                            id: ids[index],
                            describe: specs[index].describe(),
                            best_cost: result.best_cost(),
                            secs: result.wall_secs,
                            from_cache: result.from_cache,
                            done,
                            total,
                        };
                        results[index] = Some(*result);
                        event
                    }
                };
                if let Some(cb) = progress.as_deref_mut() {
                    cb(&event);
                }
            }
        });
        results.into_iter().map(|r| r.expect("every submitted job resolves")).collect()
    }

    /// Resolve one spec: serve it from the run cache, the on-disk store,
    /// or compute it on the calling thread (waiting on an identical
    /// in-flight run if one exists). Fresh computes write through to the
    /// store.
    fn execute(
        &self,
        id: JobId,
        spec: &JobSpec,
        live: Option<&mpsc::Sender<WorkerMsg>>,
        sink: Option<Arc<dyn EventSink>>,
    ) -> JobResult {
        let sw = Stopwatch::start();
        let fingerprint = spec.fingerprint();
        let computed_here = std::cell::Cell::new(false);
        let (cached, mem_hit) = self.cache.get_or_compute(fingerprint, || {
            if let Some(store) = &self.store {
                if let Some(job) = store.get(fingerprint) {
                    self.store_hits.fetch_add(1, Ordering::Relaxed);
                    return job;
                }
            }
            computed_here.set(true);
            self.computed.fetch_add(1, Ordering::Relaxed);
            // nested-parallelism budget divides the machine by the jobs
            // *actually running right now* (guard keeps the counter
            // accurate even if the search panics and poisons the slot)
            let running = self.active_jobs.fetch_add(1, Ordering::Relaxed) + 1;
            let _active = ActiveJobGuard(&self.active_jobs);
            let job = run_spec(id, spec, live, sink.clone(), running);
            if let Some(store) = &self.store {
                if let Err(e) = store.put(fingerprint, &job) {
                    eprintln!(
                        "[helex] warning: store write for {fingerprint:016x} failed: {e}"
                    );
                }
            }
            job
        });
        if mem_hit {
            self.mem_hits.fetch_add(1, Ordering::Relaxed);
        }
        let from_cache = !computed_here.get();
        if from_cache {
            // cache- and store-served jobs still deliver a complete
            // event stream: replay the recorded trace
            if let Some(sink) = &sink {
                for event in &cached.events {
                    sink.on_event(event);
                }
            }
        }
        JobResult {
            id,
            label: spec.label.clone(),
            grid: spec.grid,
            fingerprint,
            outcome: cached.outcome,
            events: cached.events,
            wall_secs: sw.secs(),
            from_cache,
        }
    }
}

/// Decrements the service's active-job counter when the job finishes
/// (or unwinds).
struct ActiveJobGuard<'a>(&'a AtomicUsize);

impl Drop for ActiveJobGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Per-job in-search worker budget: the spec's `search_threads` request
/// (`0` = all cores) clamped so that `concurrent_jobs × search_threads`
/// cannot oversubscribe the machine. `concurrent_jobs` is the number of
/// jobs *actively running* at launch time — not the pool width — so a
/// single submit to an idle `helex serve` still fans its search across
/// the whole machine. Purely a scheduling decision — the deterministic
/// reduction makes results identical at any thread count, which is also
/// why the clamp may depend on the local core count (and on load timing)
/// without breaking cross-machine reproducibility.
fn nested_search_threads(requested: &SearchConfig, concurrent_jobs: usize) -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let per_job = (cores / concurrent_jobs.max(1)).max(1);
    requested.search_threads_resolved().min(per_job)
}

/// Execute one spec on the calling thread: a per-job [`MappingEngine`]
/// (its feasibility cache stays thread-local and lock-free) seeded with
/// the spec's derived seed, a per-job event channel owned by the session
/// observer, and the objective's cost model. `sink`, when present,
/// receives every event as it happens (the HTTP server's live stream).
/// `concurrent_jobs` is the number of jobs running at this moment
/// (including this one); it bounds the job's own `search_threads`.
fn run_spec(
    id: JobId,
    spec: &JobSpec,
    live: Option<&mpsc::Sender<WorkerMsg>>,
    sink: Option<Arc<dyn EventSink>>,
    concurrent_jobs: usize,
) -> CachedJob {
    let engine =
        MappingEngine::new(MapperConfig { seed: spec.derived_seed(), ..spec.mapper.clone() });
    let cost = spec.objective.cost_model();
    // nested-parallelism budget: jobs × search_threads ≤ cores
    let mut search = SearchConfig {
        search_threads: nested_search_threads(&spec.search, concurrent_jobs),
        ..spec.search.clone()
    };
    // a Pareto job switches the search engine itself into front-keeping
    // mode (idempotent when the spec's SearchConfig already says so)
    if spec.objective == Objective::Pareto {
        search.objective = crate::search::SearchObjective::Pareto;
    }
    // per-job event channel: the session owns the sender half (an owned
    // observer closure), the receiver drains into the result's trace —
    // and improvements stream live to the service progress channel
    let (events_tx, events_rx) = mpsc::channel();
    let live_tx = live.cloned();
    let observer = move |event: &SearchEvent| {
        let _ = events_tx.send(event.clone());
        if let Some(s) = &sink {
            s.on_event(event);
        }
        if let (SearchEvent::Improved { best_cost, tested, .. }, Some(tx)) = (event, &live_tx)
        {
            let _ = tx.send(WorkerMsg::Improved {
                id,
                best_cost: *best_cost,
                tested: *tested,
            });
        }
    };
    let run = Explorer::new(spec.grid)
        .fabric(spec.fabric)
        .dfgs(&spec.dfgs)
        .engine(&engine)
        .cost(&cost)
        .config(search)
        .observer_owned(Box::new(observer))
        .run();
    // the observer (and with it the sender) dropped when `run` returned,
    // so this drains the complete trace
    let events: Vec<SearchEvent> = events_rx.try_iter().collect();
    let outcome = match run {
        Ok(result) => JobOutcome::Completed(result),
        // only genuine unmappability is infeasibility-as-data; builder
        // errors (empty DFG set, empty pipeline) are caller bugs
        Err(err @ crate::search::ExploreError::Infeasible(_)) => {
            JobOutcome::Infeasible(err.to_string())
        }
        Err(bad_spec) => JobOutcome::Rejected(bad_spec.to_string()),
    };
    CachedJob { outcome, events }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::benchmarks;

    fn tiny_spec(label: &str, size: (usize, usize)) -> JobSpec {
        JobSpec {
            search: SearchConfig { l_test: 40, l_fail: 2, gsg_passes: 1, ..Default::default() },
            seed: 1,
            ..JobSpec::new(label, vec![benchmarks::benchmark("SOB")], Grid::new(size.0, size.1))
        }
    }

    #[test]
    fn fingerprint_ignores_label_and_tracks_content() {
        let a = tiny_spec("x", (6, 6));
        let mut b = tiny_spec("y", (6, 6));
        assert_eq!(a.fingerprint(), b.fingerprint(), "label must not key the cache");

        b = tiny_spec("x", (6, 7));
        assert_ne!(a.fingerprint(), b.fingerprint(), "grid change must miss");

        b = tiny_spec("x", (6, 6));
        b.search.l_test = 41;
        assert_ne!(a.fingerprint(), b.fingerprint(), "l_test change must miss");

        b = tiny_spec("x", (6, 6));
        b.seed = 2;
        assert_ne!(a.fingerprint(), b.fingerprint(), "seed change must miss");

        b = tiny_spec("x", (6, 6));
        b.objective = Objective::Power;
        assert_ne!(a.fingerprint(), b.fingerprint(), "objective change must miss");

        b = tiny_spec("x", (6, 6));
        b.dfgs.push(benchmarks::benchmark("GB"));
        assert_ne!(a.fingerprint(), b.fingerprint(), "DFG-set change must miss");

        b = tiny_spec("x", (6, 6));
        b.fabric = crate::fabric::FabricSpec {
            topology: crate::fabric::Topology::Mesh4,
            link_cap: 1,
            io_mask: crate::fabric::IO_ALL_SIDES,
        };
        assert_eq!(
            a.fingerprint(),
            b.fingerprint(),
            "an explicit default fabric is the legacy grid and must share its cache slot"
        );

        b = tiny_spec("x", (6, 6));
        b.fabric.topology = crate::fabric::Topology::Express { stride: 2 };
        assert_ne!(a.fingerprint(), b.fingerprint(), "fabric change must miss");

        b = tiny_spec("x", (6, 6));
        b.search.search_threads = 8;
        assert_eq!(
            a.fingerprint(),
            b.fingerprint(),
            "search_threads is an execution knob: any thread count computes the same \
             result and must share one cache slot and one derived seed"
        );
    }

    #[test]
    fn nested_search_threads_clamp() {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let req = |n: usize| SearchConfig { search_threads: n, ..Default::default() };
        // an explicit request is honoured up to the per-job share
        assert_eq!(nested_search_threads(&req(1), 1), 1);
        assert_eq!(nested_search_threads(&req(2), 1), 2.min(cores));
        // as many concurrent jobs as cores: one in-search thread each
        assert_eq!(nested_search_threads(&req(4), cores), 1);
        assert_eq!(nested_search_threads(&req(0), cores), 1);
        // a single job may use the whole machine when asked for auto
        assert_eq!(nested_search_threads(&req(0), 1), cores);
        // the product never exceeds the machine
        for jobs in [1usize, 2, 3, 8] {
            let t = nested_search_threads(&req(0), jobs);
            assert!(t >= 1);
            assert!(t * jobs <= cores.max(jobs), "jobs={jobs} t={t} cores={cores}");
        }
    }

    #[test]
    fn derived_seed_is_content_stable() {
        let a = tiny_spec("x", (6, 6));
        assert_eq!(a.derived_seed(), tiny_spec("renamed", (6, 6)).derived_seed());
        let mut b = tiny_spec("x", (6, 6));
        b.seed = 2;
        assert_ne!(a.derived_seed(), b.derived_seed());
    }

    #[test]
    fn run_job_completes_and_caches() {
        let service = ExplorationService::with_jobs(1);
        let spec = tiny_spec("one", (6, 6));
        let r = service.run_job(&spec);
        assert!(r.outcome.is_completed(), "{:?}", r.outcome.infeasible_reason());
        assert!(!r.from_cache);
        assert!(!r.events.is_empty(), "the event trace must be captured");
        assert!(r.best_cost().unwrap() > 0.0);
        let again = service.run_job(&spec);
        assert!(again.from_cache);
        assert_eq!(again.best_cost(), r.best_cost());
        assert_eq!(again.events.len(), r.events.len(), "cached jobs replay the trace");
        assert_eq!(service.cache_len(), 1);
    }

    #[test]
    fn pareto_objective_jobs_carry_a_front() {
        let spec = JobSpec {
            objective: Objective::Pareto,
            search: SearchConfig {
                l_test: 60,
                l_fail: 2,
                gsg_passes: 1,
                genetic_generations: 2,
                genetic_population: 6,
                ..Default::default()
            },
            seed: 1,
            ..JobSpec::new("pf", vec![benchmarks::benchmark("SOB")], Grid::new(6, 6))
        };
        let r = ExplorationService::with_jobs(1).run_job(&spec);
        let res = r.outcome.search_result().expect("pareto job completes");
        assert!(!res.front.is_empty(), "pareto jobs must carry the final front");
        assert!(
            r.events.iter().any(|e| matches!(e, SearchEvent::ParetoPoint { .. })),
            "front improvements must stream through the event trace"
        );
        // the service-level objective keys the cache: same spec under
        // the scalar objective is a different computation
        let scalar = JobSpec { objective: Objective::Area, ..spec.clone() };
        assert_ne!(r.fingerprint, scalar.fingerprint());
    }

    #[test]
    fn infeasible_spec_is_a_result_not_a_panic() {
        // SAD (63 compute ops) cannot fit a 5x5 (9 compute cells)
        let spec = JobSpec {
            search: SearchConfig { l_test: 20, ..Default::default() },
            ..JobSpec::new("no", vec![benchmarks::benchmark("SAD")], Grid::new(5, 5))
        };
        let r = ExplorationService::with_jobs(1).run_job(&spec);
        assert!(!r.outcome.is_completed());
        assert!(r.outcome.infeasible_reason().is_some());
    }

    #[test]
    fn invalid_spec_is_rejected_not_infeasible() {
        // an empty DFG set is a caller bug, not an unmappability finding
        let spec = JobSpec::new("empty", Vec::new(), Grid::new(5, 5));
        let r = ExplorationService::with_jobs(1).run_job(&spec);
        assert!(matches!(r.outcome, JobOutcome::Rejected(_)), "{:?}", r.outcome);
        assert!(r.outcome.infeasible_reason().is_none());
        assert!(r.outcome.search_result().is_none());
    }

    #[test]
    fn parallel_duplicate_submissions_compute_once() {
        let service = ExplorationService::with_jobs(4);
        let specs: Vec<JobSpec> = (0..4).map(|_| tiny_spec("dup", (6, 6))).collect();
        let results = service.run_batch(specs, None);
        assert_eq!(results.len(), 4);
        let computed = results.iter().filter(|r| !r.from_cache).count();
        assert_eq!(computed, 1, "identical concurrent specs must compute once");
        let costs: Vec<_> = results.iter().map(|r| r.best_cost()).collect();
        assert!(costs.windows(2).all(|w| w[0] == w[1]));
        assert_eq!(service.cache_len(), 1);
    }

    #[test]
    fn batch_results_keep_submission_order_and_are_worker_count_invariant() {
        let specs = vec![
            tiny_spec("a", (5, 5)),
            tiny_spec("b", (6, 6)),
            tiny_spec("c", (6, 7)),
        ];
        let serial = ExplorationService::with_jobs(1).run_batch(specs.clone(), None);
        let mut finished = 0usize;
        let mut cb = |ev: &ServiceEvent| {
            if matches!(ev, ServiceEvent::Finished { .. }) {
                finished += 1;
            }
        };
        let parallel = ExplorationService::with_jobs(3).run_batch(specs, Some(&mut cb));
        assert_eq!(finished, 3);
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.label, p.label, "submission order must be preserved");
            assert_eq!(s.fingerprint, p.fingerprint);
            assert_eq!(s.best_cost(), p.best_cost(), "{}: worker count changed result", s.label);
            let (a, b) = (s.outcome.search_result(), p.outcome.search_result());
            assert_eq!(
                a.map(|r| r.best_layout.clone()),
                b.map(|r| r.best_layout.clone()),
                "{}: layouts must match across worker counts",
                s.label
            );
        }
    }
}
