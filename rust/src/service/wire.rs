//! Wire codecs: [`JobSpec`]/[`JobResult`]/[`SearchEvent`] ⇄ [`Json`].
//!
//! The HTTP API ([`crate::server`]) and the on-disk result store
//! ([`crate::store`]) share these encoders, so a result served over the
//! wire and a result persisted to disk are the same bytes. Encoding is
//! deterministic (fixed key order, compact output — see
//! [`crate::util::json`]), which is what lets tests byte-compare an
//! HTTP-served result against a direct [`super::ExplorationService`]
//! run.
//!
//! Decoding is *total and validating*: every function returns
//! [`WireError`] instead of panicking, and [`decode_spec`] re-validates
//! everything whose invariants the core types enforce with assertions
//! (grid bounds, DFG structure, layout support masks) so a malicious
//! request body can never take down a worker.
//!
//! Conventions:
//! * `u64` identifiers travel as strings — job ids via their zero-padded
//!   hex `Display` (`"job-00…2a"`), fingerprints via [`fp_hex`] (the same
//!   16-hex-digit form the store uses for filenames) — so JavaScript
//!   clients never push them through a lossy double.
//! * enum-ish values are tagged objects (`{"status":"completed",…}`) or
//!   lowercase names (`"area"`), never bare indices.

use super::{JobId, JobOutcome, JobResult, JobSpec, Objective};
use crate::cgra::{Grid, Layout};
use crate::dfg::Dfg;
use crate::fleet::quota::QuotaRule;
use crate::fleet::replica::{ReplicaState, ReplicaStatus};
use crate::fleet::{BatchRequest, DEFAULT_PRIORITY, MAX_BATCH_JOBS, MAX_PRIORITY};
use crate::mapper::{MapperConfig, Mapping};
use crate::ops::GroupSet;
use crate::search::{
    ParetoPoint, SearchConfig, SearchEvent, SearchObjective, SearchResult, SearchStats,
    TracePoint,
};
use crate::util::json::Json;
use std::fmt;

/// Version stamp embedded in persisted/served result payloads. Bump on
/// any incompatible schema change; the store treats a mismatch as a miss
/// (recompute) rather than an error.
///
/// History: `2` added the multi-objective fields — the search config's
/// `objective`/`genetic_*`/`subgraph_seed` knobs, the result's Pareto
/// `front` and best-layout `synth` estimate, and the `pareto_point`
/// event. `3` added fabric provisioning: the spec's optional `fabric`
/// object (topology / link capacity / I/O mask) and the same key on
/// encoded layouts. A record only carries `fabric` keys when the
/// provisioning departs from the legacy Mesh4 default, and decoding
/// defaults absent keys, so version-2 records decode unchanged —
/// [`decode_result`] accepts both (a warm restart over a v2 store
/// reports zero recomputes).
pub const WIRE_VERSION: u64 = 3;

/// Oldest persisted/served version this build still decodes. Every v2
/// record is a valid v3 record with the fabric keys absent (defaulted
/// Mesh4), so the store keeps serving pre-fabric results byte-for-byte.
pub const WIRE_VERSION_MIN: u64 = 2;

/// A decode failure: what was malformed, with enough context to fix the
/// request.
#[derive(Debug, Clone)]
pub struct WireError(pub String);

impl WireError {
    fn new(msg: impl Into<String>) -> Self {
        Self(msg.into())
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for WireError {}

type Result<T> = std::result::Result<T, WireError>;

/// Canonical 16-hex-digit rendering of a fingerprint — also the store's
/// filename stem, so URLs, JSON payloads and on-disk names agree.
pub fn fp_hex(fp: u64) -> String {
    format!("{fp:016x}")
}

/// Inverse of [`fp_hex`] (leading zeros optional).
pub fn parse_fp(s: &str) -> Result<u64> {
    if s.is_empty() || s.len() > 16 {
        return Err(WireError::new(format!("bad fingerprint '{s}'")));
    }
    u64::from_str_radix(s, 16).map_err(|_| WireError::new(format!("bad fingerprint '{s}'")))
}

// ---------------------------------------------------------------- helpers

fn field<'a>(obj: &'a Json, key: &str) -> Result<&'a Json> {
    obj.get(key).ok_or_else(|| WireError::new(format!("missing field '{key}'")))
}

fn get_str<'a>(obj: &'a Json, key: &str) -> Result<&'a str> {
    field(obj, key)?
        .as_str()
        .ok_or_else(|| WireError::new(format!("field '{key}' must be a string")))
}

fn get_bool(obj: &Json, key: &str) -> Result<bool> {
    field(obj, key)?
        .as_bool()
        .ok_or_else(|| WireError::new(format!("field '{key}' must be a boolean")))
}

fn get_u64(obj: &Json, key: &str) -> Result<u64> {
    field(obj, key)?
        .as_u64()
        .ok_or_else(|| WireError::new(format!("field '{key}' must be a non-negative integer")))
}

fn get_usize(obj: &Json, key: &str) -> Result<usize> {
    field(obj, key)?
        .as_usize()
        .ok_or_else(|| WireError::new(format!("field '{key}' must be a non-negative integer")))
}

fn get_f64(obj: &Json, key: &str) -> Result<f64> {
    field(obj, key)?
        .as_f64()
        .ok_or_else(|| WireError::new(format!("field '{key}' must be a number")))
}

fn get_arr<'a>(obj: &'a Json, key: &str) -> Result<&'a [Json]> {
    field(obj, key)?
        .as_array()
        .ok_or_else(|| WireError::new(format!("field '{key}' must be an array")))
}

fn insts_json(insts: &[usize; crate::ops::NUM_GROUPS]) -> Json {
    Json::Arr(insts.iter().map(|&n| Json::U64(n as u64)).collect())
}

fn decode_insts(j: &Json, what: &str) -> Result<[usize; crate::ops::NUM_GROUPS]> {
    let items = j
        .as_array()
        .ok_or_else(|| WireError::new(format!("{what} must be an array")))?;
    if items.len() != crate::ops::NUM_GROUPS {
        return Err(WireError::new(format!(
            "{what} must have {} entries, got {}",
            crate::ops::NUM_GROUPS,
            items.len()
        )));
    }
    let mut out = [0usize; crate::ops::NUM_GROUPS];
    for (i, item) in items.iter().enumerate() {
        out[i] = item
            .as_usize()
            .ok_or_else(|| WireError::new(format!("{what}[{i}] must be an integer")))?;
    }
    Ok(out)
}

fn decode_cells(j: &Json, what: &str) -> Result<Vec<crate::cgra::CellId>> {
    let items = j
        .as_array()
        .ok_or_else(|| WireError::new(format!("{what} must be an array")))?;
    items
        .iter()
        .map(|item| {
            item.as_u64()
                .and_then(|n| u16::try_from(n).ok())
                .ok_or_else(|| WireError::new(format!("{what} entries must be cell ids")))
        })
        .collect()
}

// ------------------------------------------------------------------- spec

pub fn encode_grid(grid: Grid) -> Json {
    Json::obj(vec![
        ("rows", Json::U64(grid.rows as u64)),
        ("cols", Json::U64(grid.cols as u64)),
    ])
}

pub fn decode_grid(j: &Json) -> Result<Grid> {
    let rows = get_usize(j, "rows")?;
    let cols = get_usize(j, "cols")?;
    // the total constructor owns the bounds checks, so bad input errors
    // (with its typed reason) instead of panicking a worker
    Grid::try_new(rows, cols).map_err(|e| WireError::new(e.to_string()))
}

/// Fabric provisioning codec. Only non-default knobs are emitted — the
/// default Mesh4/cap-1/all-sides fabric encodes as an *absent* key, so
/// version-2 records and minimal clients are covered by the decoder's
/// defaults.
pub fn encode_fabric(spec: &crate::fabric::FabricSpec) -> Json {
    let mut pairs = vec![("topology", Json::str(spec.topology.name()))];
    if let crate::fabric::Topology::Express { stride } = spec.topology {
        pairs.push(("express_stride", Json::U64(stride as u64)));
    }
    if spec.link_cap != 1 {
        pairs.push(("link_cap", Json::U64(spec.link_cap as u64)));
    }
    if spec.io_mask != crate::fabric::IO_ALL_SIDES {
        pairs.push(("io_mask", Json::str(crate::fabric::io_mask_name(spec.io_mask))));
    }
    Json::obj(pairs)
}

/// Decode and validate a fabric spec. Every field is optional and
/// defaults to the legacy value, so `{}` is the Mesh4 fabric.
pub fn decode_fabric(j: &Json) -> Result<crate::fabric::FabricSpec> {
    if !matches!(j, Json::Obj(_)) {
        return Err(WireError::new("field 'fabric' must be a JSON object"));
    }
    let defaults = crate::fabric::FabricSpec::default();
    let stride = match j.get("express_stride") {
        Some(_) => get_usize(j, "express_stride")?,
        None => 2,
    };
    let topology = match j.get("topology") {
        None => defaults.topology,
        Some(t) => {
            let name = t
                .as_str()
                .ok_or_else(|| WireError::new("field 'topology' must be a string"))?;
            crate::fabric::Topology::parse(name, stride).map_err(WireError::new)?
        }
    };
    let link_cap = match j.get("link_cap") {
        None => defaults.link_cap,
        Some(c) => c
            .as_u64()
            .and_then(|n| u8::try_from(n).ok())
            .ok_or_else(|| WireError::new("field 'link_cap' must be an integer in 1..=255"))?,
    };
    let io_mask = match j.get("io_mask") {
        None => defaults.io_mask,
        Some(m) => {
            let name = m
                .as_str()
                .ok_or_else(|| WireError::new("field 'io_mask' must be a string"))?;
            crate::fabric::parse_io_mask(name).map_err(WireError::new)?
        }
    };
    let spec = crate::fabric::FabricSpec { topology, link_cap, io_mask };
    spec.validate().map_err(WireError::new)?;
    Ok(spec)
}

/// DFG codec: the interchange format is owned by [`crate::dfg::io`];
/// the wire schema and the file format are the same bytes.
pub fn encode_dfg(dfg: &Dfg) -> Json {
    crate::dfg::io::dfg_to_json(dfg)
}

/// Decode and validate one DFG. The mapper and search assume
/// structurally valid DAGs (topo order, arity, no parallel edges);
/// `dfg::io` rejects anything else — including oversized payloads —
/// with the precise typed reason, which travels here as the error
/// string for HTTP 400 bodies.
pub fn decode_dfg(j: &Json) -> Result<Dfg> {
    crate::dfg::io::dfg_from_json(j).map_err(|e| WireError::new(e.to_string()))
}

fn encode_search_config(cfg: &SearchConfig) -> Json {
    Json::obj(vec![
        ("l_test", Json::U64(cfg.l_test as u64)),
        ("l_fail", Json::U64(cfg.l_fail as u64)),
        ("run_gsg", Json::Bool(cfg.run_gsg)),
        ("gsg_passes", Json::U64(cfg.gsg_passes as u64)),
        ("gsg_stale_prune_after", Json::U64(cfg.gsg_stale_prune_after as u64)),
        ("use_heatmap", Json::Bool(cfg.use_heatmap)),
        ("opsg_skip_arith", Json::Bool(cfg.opsg_skip_arith)),
        ("objective", Json::str(cfg.objective.name())),
        ("genetic_generations", Json::U64(cfg.genetic_generations as u64)),
        ("genetic_population", Json::U64(cfg.genetic_population as u64)),
        ("subgraph_seed", Json::Bool(cfg.subgraph_seed)),
        ("search_threads", Json::U64(cfg.search_threads as u64)),
    ])
}

fn decode_search_config(j: &Json) -> Result<SearchConfig> {
    let defaults = SearchConfig::default();
    Ok(SearchConfig {
        l_test: get_usize(j, "l_test")?,
        l_fail: get_usize(j, "l_fail")?,
        run_gsg: get_bool(j, "run_gsg")?,
        gsg_passes: get_usize(j, "gsg_passes")?,
        gsg_stale_prune_after: get_usize(j, "gsg_stale_prune_after")?,
        use_heatmap: get_bool(j, "use_heatmap")?,
        opsg_skip_arith: get_bool(j, "opsg_skip_arith")?,
        // the multi-objective knobs default when absent so minimal
        // clients (and pre-Pareto callers) keep working unchanged
        objective: match j.get("objective") {
            None => defaults.objective,
            Some(o) => {
                let name = o
                    .as_str()
                    .ok_or_else(|| WireError::new("field 'objective' must be a string"))?;
                SearchObjective::from_name(name).ok_or_else(|| {
                    WireError::new(format!(
                        "search objective must be \"op_count\" or \"pareto\", got '{name}'"
                    ))
                })?
            }
        },
        genetic_generations: match j.get("genetic_generations") {
            Some(_) => get_usize(j, "genetic_generations")?,
            None => defaults.genetic_generations,
        },
        genetic_population: match j.get("genetic_population") {
            Some(_) => get_usize(j, "genetic_population")?,
            None => defaults.genetic_population,
        },
        subgraph_seed: match j.get("subgraph_seed") {
            Some(_) => get_bool(j, "subgraph_seed")?,
            None => defaults.subgraph_seed,
        },
        // an execution hint, not result-relevant: absent in records
        // written before parallel search (0 = available parallelism,
        // clamped by the service's nested-parallelism budget)
        search_threads: match j.get("search_threads") {
            Some(_) => get_usize(j, "search_threads")?,
            None => 0,
        },
    })
}

fn encode_mapper_config(cfg: &MapperConfig) -> Json {
    let mut pairs = vec![
        ("route_iters", Json::U64(cfg.route_iters as u64)),
        ("placement_attempts", Json::U64(cfg.placement_attempts as u64)),
        ("max_reserves", Json::U64(cfg.max_reserves as u64)),
        ("hist_increment", Json::F64(cfg.hist_increment)),
        ("present_penalty", Json::F64(cfg.present_penalty)),
        ("seed", Json::U64(cfg.seed)),
        ("feasibility_cache", Json::Bool(cfg.feasibility_cache)),
    ];
    // router-selection knobs: emitted only when non-default, so every
    // pre-router record re-encodes to its exact bytes (same pattern as
    // the absent-when-default fabric key)
    if cfg.router_steiner {
        pairs.push(("router_steiner", Json::Bool(true)));
    }
    if cfg.router_criticality {
        pairs.push(("router_criticality", Json::Bool(true)));
    }
    Json::obj(pairs)
}

fn decode_mapper_config(j: &Json) -> Result<MapperConfig> {
    Ok(MapperConfig {
        route_iters: get_usize(j, "route_iters")?,
        placement_attempts: get_usize(j, "placement_attempts")?,
        max_reserves: get_usize(j, "max_reserves")?,
        hist_increment: get_f64(j, "hist_increment")?,
        present_penalty: get_f64(j, "present_penalty")?,
        seed: get_u64(j, "seed")?,
        feasibility_cache: get_bool(j, "feasibility_cache")?,
        router_steiner: match j.get("router_steiner") {
            Some(_) => get_bool(j, "router_steiner")?,
            None => false,
        },
        router_criticality: match j.get("router_criticality") {
            Some(_) => get_bool(j, "router_criticality")?,
            None => false,
        },
    })
}

pub fn encode_spec(spec: &JobSpec) -> Json {
    let mut pairs = vec![
        ("label", Json::str(&spec.label)),
        ("dfgs", Json::Arr(spec.dfgs.iter().map(encode_dfg).collect())),
        ("grid", encode_grid(spec.grid)),
    ];
    // default provisioning is the legacy grid: the key is absent so
    // pre-fabric specs re-encode to their exact version-2 bytes
    if !spec.fabric.is_default() {
        pairs.push(("fabric", encode_fabric(&spec.fabric)));
    }
    pairs.extend([
        ("objective", Json::str(spec.objective.name())),
        ("search", encode_search_config(&spec.search)),
        ("mapper", encode_mapper_config(&spec.mapper)),
        ("seed", Json::U64(spec.seed)),
    ]);
    Json::obj(pairs)
}

/// Decode and validate a job spec. Optional fields: `objective` (default
/// area), `search`/`mapper` (defaults), `seed` (defaults to the mapper
/// seed), `label` (defaults to `"api"`) — so a minimal client only sends
/// `dfgs` + `grid`.
pub fn decode_spec(j: &Json) -> Result<JobSpec> {
    if !matches!(j, Json::Obj(_)) {
        return Err(WireError::new("job spec must be a JSON object"));
    }
    let label = match j.get("label") {
        Some(l) => l
            .as_str()
            .ok_or_else(|| WireError::new("field 'label' must be a string"))?
            .to_string(),
        None => "api".to_string(),
    };
    let dfgs: Vec<Dfg> =
        get_arr(j, "dfgs")?.iter().map(decode_dfg).collect::<Result<_>>()?;
    let grid = decode_grid(field(j, "grid")?)?;
    let fabric = match j.get("fabric") {
        Some(f) => decode_fabric(f)?,
        None => crate::fabric::FabricSpec::default(),
    };
    let objective = match j.get("objective") {
        None => Objective::Area,
        Some(o) => match o.as_str() {
            Some("area") => Objective::Area,
            Some("power") => Objective::Power,
            Some("pareto") => Objective::Pareto,
            _ => {
                return Err(WireError::new(
                    "field 'objective' must be \"area\", \"power\" or \"pareto\"",
                ))
            }
        },
    };
    let search = match j.get("search") {
        Some(s) => decode_search_config(s)?,
        None => SearchConfig::default(),
    };
    let mapper = match j.get("mapper") {
        Some(m) => decode_mapper_config(m)?,
        None => MapperConfig::default(),
    };
    let seed = match j.get("seed") {
        Some(s) => s.as_u64().ok_or_else(|| WireError::new("field 'seed' must be a u64"))?,
        None => mapper.seed,
    };
    Ok(JobSpec { label, dfgs, grid, fabric, objective, search, mapper, seed })
}

// ----------------------------------------------------------------- result

pub fn encode_layout(layout: &Layout) -> Json {
    let grid = layout.grid;
    let mut pairs = vec![
        ("rows", Json::U64(grid.rows as u64)),
        ("cols", Json::U64(grid.cols as u64)),
    ];
    // like specs: the fabric key travels only when provisioning departs
    // from the default, so pre-fabric layout bytes are unchanged
    if !layout.fabric().is_default() {
        pairs.push(("fabric", encode_fabric(&layout.fabric().spec())));
    }
    pairs.push((
        "support",
        Json::Arr(
            grid.compute_cells()
                .map(|c| Json::U64(layout.support(c).0 as u64))
                .collect(),
        ),
    ));
    Json::obj(pairs)
}

pub fn decode_layout(j: &Json) -> Result<Layout> {
    let grid = decode_grid(j)?;
    let fabric = match j.get("fabric") {
        Some(f) => decode_fabric(f)?,
        None => crate::fabric::FabricSpec::default(),
    };
    let support = get_arr(j, "support")?;
    if support.len() != grid.num_compute() {
        return Err(WireError::new(format!(
            "layout support must have {} entries for a {grid} grid, got {}",
            grid.num_compute(),
            support.len()
        )));
    }
    let mut layout = Layout::empty_on(fabric.build(grid));
    for (cell, bits) in grid.compute_cells().zip(support) {
        let bits = bits
            .as_u64()
            .and_then(|n| u8::try_from(n).ok())
            .ok_or_else(|| WireError::new("layout support entries must be group masks"))?;
        let set = GroupSet(bits);
        // set_support asserts this; check it so decode stays total
        if !set.is_subset_of(GroupSet::all_compute()) {
            return Err(WireError::new(format!("support mask {bits:#x} is not a compute mask")));
        }
        layout.set_support(cell, set);
    }
    Ok(layout)
}

fn cells_json(cs: &[crate::cgra::CellId]) -> Json {
    Json::Arr(cs.iter().map(|&c| Json::U64(c as u64)).collect())
}

fn encode_mapping(m: &Mapping) -> Json {
    Json::obj(vec![
        ("node_cell", cells_json(&m.node_cell)),
        ("edge_paths", Json::Arr(m.edge_paths.iter().map(|p| cells_json(p)).collect())),
        ("reserved", cells_json(&m.reserved)),
    ])
}

fn decode_mapping(j: &Json) -> Result<Mapping> {
    Ok(Mapping {
        node_cell: decode_cells(field(j, "node_cell")?, "node_cell")?,
        edge_paths: get_arr(j, "edge_paths")?
            .iter()
            .map(|p| decode_cells(p, "edge_paths"))
            .collect::<Result<_>>()?,
        reserved: decode_cells(field(j, "reserved")?, "reserved")?,
    })
}

fn encode_stats(stats: &SearchStats) -> Json {
    Json::obj(vec![
        ("expanded", Json::U64(stats.expanded as u64)),
        ("tested", Json::U64(stats.tested as u64)),
        (
            "phase_secs",
            Json::Arr(
                stats
                    .phase_secs
                    .iter()
                    .map(|(phase, secs)| {
                        Json::obj(vec![("phase", Json::str(phase)), ("secs", Json::F64(*secs))])
                    })
                    .collect(),
            ),
        ),
        ("heatmap_used", Json::Bool(stats.heatmap_used)),
        ("insts_full", insts_json(&stats.insts_full)),
        (
            "insts_after_phase",
            Json::Arr(
                stats
                    .insts_after_phase
                    .iter()
                    .map(|(phase, insts)| {
                        Json::obj(vec![("phase", Json::str(phase)), ("insts", insts_json(insts))])
                    })
                    .collect(),
            ),
        ),
        (
            "trace",
            Json::Arr(
                stats
                    .trace
                    .iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("phase", Json::str(&t.phase)),
                            ("secs", Json::F64(t.secs)),
                            ("tested", Json::U64(t.tested as u64)),
                            ("best_cost", Json::F64(t.best_cost)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn decode_stats(j: &Json) -> Result<SearchStats> {
    let mut stats = SearchStats {
        expanded: get_usize(j, "expanded")?,
        tested: get_usize(j, "tested")?,
        heatmap_used: get_bool(j, "heatmap_used")?,
        insts_full: decode_insts(field(j, "insts_full")?, "insts_full")?,
        ..Default::default()
    };
    for item in get_arr(j, "phase_secs")? {
        stats.phase_secs.push((get_str(item, "phase")?.to_string(), get_f64(item, "secs")?));
    }
    for item in get_arr(j, "insts_after_phase")? {
        stats.insts_after_phase.push((
            get_str(item, "phase")?.to_string(),
            decode_insts(field(item, "insts")?, "insts")?,
        ));
    }
    for item in get_arr(j, "trace")? {
        stats.trace.push(TracePoint {
            phase: get_str(item, "phase")?.to_string(),
            secs: get_f64(item, "secs")?,
            tested: get_usize(item, "tested")?,
            best_cost: get_f64(item, "best_cost")?,
        });
    }
    Ok(stats)
}

pub fn encode_pareto_point(p: &ParetoPoint) -> Json {
    Json::obj(vec![
        ("ops", Json::U64(p.ops as u64)),
        ("area_um2", Json::F64(p.area_um2)),
        ("power_uw", Json::F64(p.power_uw)),
        ("fingerprint", Json::str(fp_hex(p.fingerprint))),
    ])
}

fn decode_pareto_point(j: &Json) -> Result<ParetoPoint> {
    Ok(ParetoPoint {
        ops: get_usize(j, "ops")?,
        area_um2: get_f64(j, "area_um2")?,
        power_uw: get_f64(j, "power_uw")?,
        fingerprint: parse_fp(get_str(j, "fingerprint")?)?,
    })
}

fn encode_search_result(r: &SearchResult) -> Json {
    // the best layout's synth estimate travels on every result (scalar
    // jobs too); derived purely from the layout, so decoders may ignore
    // it and re-encoding stays byte-stable
    let synth = crate::cost::synth::synthesize(&r.best_layout);
    Json::obj(vec![
        ("full_layout", encode_layout(&r.full_layout)),
        ("initial_layout", encode_layout(&r.initial_layout)),
        ("best_layout", encode_layout(&r.best_layout)),
        ("best_cost", Json::F64(r.best_cost)),
        (
            "synth",
            Json::obj(vec![
                ("area_um2", Json::F64(synth.area_um2)),
                ("power_uw", Json::F64(synth.power_uw)),
            ]),
        ),
        ("front", Json::Arr(r.front.iter().map(encode_pareto_point).collect())),
        ("min_insts", insts_json(&r.min_insts)),
        ("final_mappings", Json::Arr(r.final_mappings.iter().map(encode_mapping).collect())),
        ("stats", encode_stats(&r.stats)),
    ])
}

fn decode_search_result(j: &Json) -> Result<SearchResult> {
    Ok(SearchResult {
        full_layout: decode_layout(field(j, "full_layout")?)?,
        initial_layout: decode_layout(field(j, "initial_layout")?)?,
        best_layout: decode_layout(field(j, "best_layout")?)?,
        best_cost: get_f64(j, "best_cost")?,
        // "synth" is not decoded: it is a pure function of best_layout
        front: match j.get("front") {
            Some(f) => f
                .as_array()
                .ok_or_else(|| WireError::new("field 'front' must be an array"))?
                .iter()
                .map(decode_pareto_point)
                .collect::<Result<_>>()?,
            None => Vec::new(),
        },
        min_insts: decode_insts(field(j, "min_insts")?, "min_insts")?,
        final_mappings: get_arr(j, "final_mappings")?
            .iter()
            .map(decode_mapping)
            .collect::<Result<_>>()?,
        stats: decode_stats(field(j, "stats")?)?,
    })
}

pub fn encode_outcome(outcome: &JobOutcome) -> Json {
    match outcome {
        JobOutcome::Completed(r) => Json::obj(vec![
            ("status", Json::str("completed")),
            ("result", encode_search_result(r)),
        ]),
        JobOutcome::Infeasible(why) => {
            Json::obj(vec![("status", Json::str("infeasible")), ("reason", Json::str(why))])
        }
        JobOutcome::Rejected(why) => {
            Json::obj(vec![("status", Json::str("rejected")), ("reason", Json::str(why))])
        }
    }
}

pub fn decode_outcome(j: &Json) -> Result<JobOutcome> {
    match get_str(j, "status")? {
        "completed" => Ok(JobOutcome::Completed(decode_search_result(field(j, "result")?)?)),
        "infeasible" => Ok(JobOutcome::Infeasible(get_str(j, "reason")?.to_string())),
        "rejected" => Ok(JobOutcome::Rejected(get_str(j, "reason")?.to_string())),
        other => Err(WireError::new(format!("unknown outcome status '{other}'"))),
    }
}

pub fn encode_event(event: &SearchEvent) -> Json {
    match event {
        SearchEvent::PhaseStarted { phase, incumbent_cost } => Json::obj(vec![
            ("type", Json::str("phase_started")),
            ("phase", Json::str(phase)),
            ("incumbent_cost", Json::F64(*incumbent_cost)),
        ]),
        SearchEvent::LayoutTested { feasible, cost, tested, worker } => Json::obj(vec![
            ("type", Json::str("layout_tested")),
            ("feasible", Json::Bool(*feasible)),
            ("cost", Json::F64(*cost)),
            ("tested", Json::U64(*tested as u64)),
            ("worker", Json::U64(*worker as u64)),
        ]),
        SearchEvent::Improved { best_cost, tested, secs } => Json::obj(vec![
            ("type", Json::str("improved")),
            ("best_cost", Json::F64(*best_cost)),
            ("tested", Json::U64(*tested as u64)),
            ("secs", Json::F64(*secs)),
        ]),
        SearchEvent::ParetoPoint { ops, area_um2, power_uw, front_size, tested } => {
            Json::obj(vec![
                ("type", Json::str("pareto_point")),
                ("ops", Json::U64(*ops as u64)),
                ("area_um2", Json::F64(*area_um2)),
                ("power_uw", Json::F64(*power_uw)),
                ("front_size", Json::U64(*front_size as u64)),
                ("tested", Json::U64(*tested as u64)),
            ])
        }
        SearchEvent::PhaseFinished { phase, secs, best_cost } => Json::obj(vec![
            ("type", Json::str("phase_finished")),
            ("phase", Json::str(phase)),
            ("secs", Json::F64(*secs)),
            ("best_cost", Json::F64(*best_cost)),
        ]),
    }
}

pub fn decode_event(j: &Json) -> Result<SearchEvent> {
    match get_str(j, "type")? {
        "phase_started" => Ok(SearchEvent::PhaseStarted {
            phase: get_str(j, "phase")?.to_string(),
            incumbent_cost: get_f64(j, "incumbent_cost")?,
        }),
        "layout_tested" => Ok(SearchEvent::LayoutTested {
            feasible: get_bool(j, "feasible")?,
            cost: get_f64(j, "cost")?,
            tested: get_usize(j, "tested")?,
            // absent in pre-parallel records (and in stripped traces)
            worker: match j.get("worker") {
                Some(_) => get_usize(j, "worker")?,
                None => 0,
            },
        }),
        "improved" => Ok(SearchEvent::Improved {
            best_cost: get_f64(j, "best_cost")?,
            tested: get_usize(j, "tested")?,
            secs: get_f64(j, "secs")?,
        }),
        "pareto_point" => Ok(SearchEvent::ParetoPoint {
            ops: get_usize(j, "ops")?,
            area_um2: get_f64(j, "area_um2")?,
            power_uw: get_f64(j, "power_uw")?,
            front_size: get_usize(j, "front_size")?,
            tested: get_usize(j, "tested")?,
        }),
        "phase_finished" => Ok(SearchEvent::PhaseFinished {
            phase: get_str(j, "phase")?.to_string(),
            secs: get_f64(j, "secs")?,
            best_cost: get_f64(j, "best_cost")?,
        }),
        other => Err(WireError::new(format!("unknown event type '{other}'"))),
    }
}

pub fn encode_events(events: &[SearchEvent]) -> Json {
    Json::Arr(events.iter().map(encode_event).collect())
}

pub fn decode_events(j: &Json) -> Result<Vec<SearchEvent>> {
    j.as_array()
        .ok_or_else(|| WireError::new("events must be an array"))?
        .iter()
        .map(decode_event)
        .collect()
}

pub fn encode_result(result: &JobResult) -> Json {
    Json::obj(vec![
        ("version", Json::U64(WIRE_VERSION)),
        ("id", Json::str(result.id.to_string())),
        ("label", Json::str(&result.label)),
        ("grid", encode_grid(result.grid)),
        ("fingerprint", Json::str(fp_hex(result.fingerprint))),
        ("outcome", encode_outcome(&result.outcome)),
        ("events", encode_events(&result.events)),
        ("wall_secs", Json::F64(result.wall_secs)),
        ("from_cache", Json::Bool(result.from_cache)),
    ])
}

pub fn decode_result(j: &Json) -> Result<JobResult> {
    let version = get_u64(j, "version")?;
    if !(WIRE_VERSION_MIN..=WIRE_VERSION).contains(&version) {
        return Err(WireError::new(format!(
            "unsupported result version {version} (this build speaks \
             {WIRE_VERSION_MIN}..={WIRE_VERSION})"
        )));
    }
    Ok(JobResult {
        id: get_str(j, "id")?
            .parse::<JobId>()
            .map_err(|e| WireError::new(e.to_string()))?,
        label: get_str(j, "label")?.to_string(),
        grid: decode_grid(field(j, "grid")?)?,
        fingerprint: parse_fp(get_str(j, "fingerprint")?)?,
        outcome: decode_outcome(field(j, "outcome")?)?,
        events: decode_events(field(j, "events")?)?,
        wall_secs: get_f64(j, "wall_secs")?,
        from_cache: get_bool(j, "from_cache")?,
    })
}

/// Normalization for byte-comparing two encodings of "the same" job:
/// recursively drops the fields that legitimately differ between two
/// executions of one spec — ids, cache provenance, every wall-clock
/// reading (`wall_secs`, and the `secs` fields of phase timings, trace
/// points and events), and the `worker` tag on tested-layout events
/// (which worker ran a test varies with `search_threads` and timing;
/// the *order* and content of the events do not). Everything that
/// survives is part of the determinism contract.
pub fn strip_volatile(j: &Json) -> Json {
    match j {
        Json::Obj(pairs) => Json::Obj(
            pairs
                .iter()
                .filter(|(k, _)| {
                    !matches!(k.as_str(), "id" | "from_cache" | "wall_secs" | "secs" | "worker")
                })
                .map(|(k, v)| (k.clone(), strip_volatile(v)))
                .collect(),
        ),
        Json::Arr(items) => Json::Arr(items.iter().map(strip_volatile).collect()),
        other => other.clone(),
    }
}

// ------------------------------------------------------------------ fleet

pub fn encode_batch(batch: &BatchRequest) -> Json {
    Json::obj(vec![
        ("label", Json::str(&batch.label)),
        ("client", Json::str(&batch.client)),
        ("priority", Json::U64(batch.priority as u64)),
        ("jobs", Json::Arr(batch.specs.iter().map(encode_spec).collect())),
    ])
}

/// Decode a `POST /v1/batches` body. Optional fields: `label` (default
/// `"batch"`), `client` (default `"anonymous"`), `priority` (default
/// [`DEFAULT_PRIORITY`]); `jobs` is required, non-empty, and every
/// entry must decode as a full job spec (errors carry the `jobs[i]:`
/// index so a 4096-spec suite pinpoints its one bad entry).
pub fn decode_batch(j: &Json) -> Result<BatchRequest> {
    if !matches!(j, Json::Obj(_)) {
        return Err(WireError::new("batch must be a JSON object"));
    }
    let label = match j.get("label") {
        Some(l) => l
            .as_str()
            .ok_or_else(|| WireError::new("field 'label' must be a string"))?
            .to_string(),
        None => "batch".to_string(),
    };
    let client = match j.get("client") {
        Some(c) => {
            let c = c
                .as_str()
                .ok_or_else(|| WireError::new("field 'client' must be a string"))?;
            if c.is_empty() {
                return Err(WireError::new("field 'client' must be non-empty"));
            }
            c.to_string()
        }
        None => "anonymous".to_string(),
    };
    let priority = match j.get("priority") {
        Some(p) => {
            let p = p.as_u64().ok_or_else(|| {
                WireError::new("field 'priority' must be a non-negative integer")
            })?;
            if p > MAX_PRIORITY as u64 {
                return Err(WireError::new(format!("priority must be at most {MAX_PRIORITY}")));
            }
            p as u8
        }
        None => DEFAULT_PRIORITY,
    };
    let jobs = get_arr(j, "jobs")?;
    if jobs.is_empty() {
        return Err(WireError::new("batch must carry at least one job"));
    }
    if jobs.len() > MAX_BATCH_JOBS {
        return Err(WireError::new(format!(
            "batch carries {} jobs, at most {MAX_BATCH_JOBS} allowed",
            jobs.len()
        )));
    }
    let specs = jobs
        .iter()
        .enumerate()
        .map(|(i, job)| decode_spec(job).map_err(|e| WireError::new(format!("jobs[{i}]: {e}"))))
        .collect::<Result<Vec<_>>>()?;
    Ok(BatchRequest { label, client, priority, specs })
}

pub fn encode_quota(rule: &QuotaRule) -> Json {
    Json::obj(vec![
        ("client", Json::str(&rule.client)),
        ("burst", Json::U64(rule.burst)),
        ("per_sec", Json::F64(rule.per_sec)),
    ])
}

pub fn decode_quota(j: &Json) -> Result<QuotaRule> {
    if !matches!(j, Json::Obj(_)) {
        return Err(WireError::new("quota rule must be a JSON object"));
    }
    let client = get_str(j, "client")?.to_string();
    if client.is_empty() {
        return Err(WireError::new("field 'client' must be non-empty"));
    }
    let burst = get_u64(j, "burst")?;
    if burst == 0 {
        return Err(WireError::new("field 'burst' must be at least 1"));
    }
    // the parser never yields NaN/inf, but decode_quota is also fed
    // in-process values; keep it total either way
    let per_sec = get_f64(j, "per_sec")?;
    if !per_sec.is_finite() || per_sec < 0.0 {
        return Err(WireError::new("field 'per_sec' must be a finite non-negative number"));
    }
    Ok(QuotaRule { client, burst, per_sec })
}

pub fn encode_replica_status(status: &ReplicaStatus) -> Json {
    Json::obj(vec![
        ("addr", Json::str(&status.addr)),
        ("state", Json::str(status.state.name())),
        ("inflight", Json::U64(status.inflight)),
        ("queued", Json::U64(status.queued)),
        ("running", Json::U64(status.running)),
        ("consecutive_failures", Json::U64(status.consecutive_failures)),
    ])
}

pub fn decode_replica_status(j: &Json) -> Result<ReplicaStatus> {
    if !matches!(j, Json::Obj(_)) {
        return Err(WireError::new("replica status must be a JSON object"));
    }
    let addr = get_str(j, "addr")?.to_string();
    if addr.is_empty() {
        return Err(WireError::new("field 'addr' must be non-empty"));
    }
    let state_name = get_str(j, "state")?;
    let state = ReplicaState::from_name(state_name)
        .ok_or_else(|| WireError::new(format!("unknown replica state '{state_name}'")))?;
    Ok(ReplicaStatus {
        addr,
        state,
        inflight: get_u64(j, "inflight")?,
        queued: get_u64(j, "queued")?,
        running: get_u64(j, "running")?,
        consecutive_failures: get_u64(j, "consecutive_failures")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::benchmarks;
    use crate::service::ExplorationService;
    use crate::util::json;

    fn tiny_spec() -> JobSpec {
        JobSpec {
            search: SearchConfig { l_test: 40, l_fail: 2, gsg_passes: 1, ..Default::default() },
            objective: Objective::Power,
            seed: 7,
            ..JobSpec::new("wire", vec![benchmarks::benchmark("SOB")], Grid::new(6, 6))
        }
    }

    #[test]
    fn spec_roundtrip_preserves_fingerprint() {
        let spec = tiny_spec();
        let encoded = encode_spec(&spec);
        let text = encoded.to_string();
        let back = decode_spec(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.fingerprint(), spec.fingerprint(), "codec must be content-lossless");
        assert_eq!(back.label, spec.label);
        assert_eq!(encode_spec(&back).to_string(), text, "re-encoding is byte-stable");
    }

    #[test]
    fn minimal_spec_uses_defaults() {
        let j = json::parse(
            r#"{"dfgs":[{"name":"t","nodes":["load","add","load","store"],
                 "edges":[[0,1],[2,1],[1,3]]}],"grid":{"rows":5,"cols":5}}"#,
        )
        .unwrap();
        let spec = decode_spec(&j).unwrap();
        assert_eq!(spec.label, "api");
        assert_eq!(spec.objective, Objective::Area);
        assert_eq!(spec.seed, MapperConfig::default().seed);
        assert_eq!(spec.search.l_test, SearchConfig::default().l_test);
    }

    #[test]
    fn invalid_specs_are_rejected_with_reasons() {
        for (body, needle) in [
            (r#"[1,2]"#, "object"),
            (r#"{"grid":{"rows":5,"cols":5}}"#, "dfgs"),
            (r#"{"dfgs":[],"grid":{"rows":2,"cols":9}}"#, "3x3"),
            (r#"{"dfgs":[],"grid":{"rows":300,"cols":300}}"#, "too large"),
            (
                r#"{"dfgs":[{"name":"t","nodes":["frob"],"edges":[]}],"grid":{"rows":5,"cols":5}}"#,
                "unknown operation",
            ),
            (
                r#"{"dfgs":[{"name":"t","nodes":["load","store"],"edges":[[0,7]]}],"grid":{"rows":5,"cols":5}}"#,
                "out of range",
            ),
            (
                r#"{"dfgs":[{"name":"t","nodes":["add","add"],"edges":[[0,1],[1,0]]}],"grid":{"rows":5,"cols":5}}"#,
                "invalid",
            ),
            (
                r#"{"dfgs":[{"name":"t","nodes":["load","abs","store"],"edges":[[0,1],[0,1],[1,2]]}],"grid":{"rows":5,"cols":5}}"#,
                "duplicate edge",
            ),
            (
                r#"{"dfgs":[{"name":"t","nodes":["load","abs","store"],"edges":[[0,1],[1,1],[1,2]]}],"grid":{"rows":5,"cols":5}}"#,
                "self-loop",
            ),
            (
                r#"{"dfgs":[],"grid":{"rows":5,"cols":5},"objective":"speed"}"#,
                "objective",
            ),
        ] {
            let err = decode_spec(&json::parse(body).unwrap()).unwrap_err();
            assert!(
                err.0.contains(needle),
                "body {body} should fail mentioning '{needle}', got: {err}"
            );
        }
    }

    #[test]
    fn fabric_spec_roundtrip_and_default_is_absent() {
        use crate::fabric::{FabricSpec, Topology, SIDE_N, SIDE_S};
        let spec = JobSpec {
            fabric: FabricSpec {
                topology: Topology::Express { stride: 3 },
                link_cap: 2,
                io_mask: SIDE_N | SIDE_S,
            },
            ..tiny_spec()
        };
        let text = encode_spec(&spec).to_string();
        assert!(text.contains("\"fabric\""));
        let back = decode_spec(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.fabric, spec.fabric);
        assert_eq!(back.fingerprint(), spec.fingerprint(), "codec must be content-lossless");
        assert_eq!(encode_spec(&back).to_string(), text, "re-encoding is byte-stable");
        // the default fabric travels as an *absent* key: pre-fabric
        // (version 2) spec bytes are unchanged
        assert!(!encode_spec(&tiny_spec()).to_string().contains("\"fabric\""));
        // an explicit empty fabric object is the Mesh4 default too
        let j = json::parse(
            r#"{"dfgs":[{"name":"t","nodes":["load","store"],"edges":[[0,1]]}],
                 "grid":{"rows":5,"cols":5},"fabric":{}}"#,
        )
        .unwrap();
        let decoded = decode_spec(&j).unwrap();
        assert!(decoded.fabric.is_default());
        assert_eq!(decoded.fingerprint(), JobSpec { fabric: FabricSpec::default(), ..decoded.clone() }.fingerprint());
    }

    #[test]
    fn invalid_fabrics_are_rejected_with_reasons() {
        for (body, needle) in [
            (r#"{"dfgs":[],"grid":{"rows":5,"cols":5},"fabric":7}"#, "object"),
            (
                r#"{"dfgs":[],"grid":{"rows":5,"cols":5},"fabric":{"topology":"hypercube"}}"#,
                "unknown topology",
            ),
            (
                r#"{"dfgs":[],"grid":{"rows":5,"cols":5},"fabric":{"topology":"express","express_stride":1}}"#,
                "stride",
            ),
            (r#"{"dfgs":[],"grid":{"rows":5,"cols":5},"fabric":{"link_cap":0}}"#, "capacity"),
            (r#"{"dfgs":[],"grid":{"rows":5,"cols":5},"fabric":{"link_cap":300}}"#, "link_cap"),
            (r#"{"dfgs":[],"grid":{"rows":5,"cols":5},"fabric":{"io_mask":"nx"}}"#, "side"),
            (r#"{"dfgs":[],"grid":{"rows":5,"cols":5},"fabric":{"io_mask":""}}"#, "empty"),
            (r#"{"dfgs":[],"grid":{"rows":5,"cols":5},"fabric":{"topology":4}}"#, "string"),
        ] {
            let err = decode_spec(&json::parse(body).unwrap()).unwrap_err();
            assert!(
                err.0.contains(needle),
                "body {body} should fail mentioning '{needle}', got: {err}"
            );
        }
    }

    #[test]
    fn fabric_layouts_roundtrip_and_v2_records_decode() {
        use crate::fabric::{Fabric, FabricSpec, Topology};
        use crate::ops::GroupSet;
        // a non-default layout carries its fabric and round-trips
        let spec = FabricSpec { topology: Topology::Express { stride: 2 }, ..Default::default() };
        let layout =
            Layout::full_on(Fabric::new(Grid::new(6, 6), spec), GroupSet::all_compute());
        let text = encode_layout(&layout).to_string();
        assert!(text.contains("\"fabric\""));
        let back = decode_layout(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, layout, "fabric must survive the layout codec");
        assert_eq!(encode_layout(&back).to_string(), text);
        // default layouts keep their version-2 bytes (no fabric key)
        let legacy = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        assert!(!encode_layout(&legacy).to_string().contains("\"fabric\""));

        // a version-2 record (as persisted by the previous release: no
        // fabric keys, version stamp 2) still decodes — the warm-restart
        // contract that keeps a v2 store serving with zero recomputes
        let service = ExplorationService::with_jobs(1);
        let result = service.run_job(&tiny_spec());
        let mut j = encode_result(&result);
        if let Json::Obj(pairs) = &mut j {
            assert_eq!(pairs[0].0, "version");
            pairs[0].1 = Json::U64(2);
        }
        let back = decode_result(&j).unwrap();
        assert_eq!(back.best_cost(), result.best_cost());
        assert!(back
            .outcome
            .search_result()
            .unwrap()
            .best_layout
            .fabric()
            .is_default());
    }

    #[test]
    fn result_roundtrip_is_byte_stable() {
        let service = ExplorationService::with_jobs(1);
        let result = service.run_job(&tiny_spec());
        assert!(result.outcome.is_completed());
        let text = encode_result(&result).to_string();
        let back = decode_result(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(encode_result(&back).to_string(), text);
        assert_eq!(back.best_cost(), result.best_cost());
        assert_eq!(back.events.len(), result.events.len());
        let (a, b) = (back.outcome.search_result().unwrap(), result.outcome.search_result().unwrap());
        assert_eq!(a.best_layout, b.best_layout);
        assert_eq!(a.stats.tested, b.stats.tested);
        assert_eq!(a.final_mappings.len(), b.final_mappings.len());
    }

    #[test]
    fn pareto_spec_and_search_config_roundtrip() {
        let spec = JobSpec {
            objective: Objective::Pareto,
            search: SearchConfig {
                objective: SearchObjective::Pareto,
                genetic_generations: 3,
                genetic_population: 5,
                subgraph_seed: true,
                ..tiny_spec().search
            },
            ..tiny_spec()
        };
        let text = encode_spec(&spec).to_string();
        let back = decode_spec(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.fingerprint(), spec.fingerprint());
        assert_eq!(back.objective, Objective::Pareto);
        assert_eq!(back.search.objective, SearchObjective::Pareto);
        assert_eq!(back.search.genetic_generations, 3);
        assert_eq!(back.search.genetic_population, 5);
        assert!(back.search.subgraph_seed);
        // pre-Pareto records carry none of the new knobs: defaults apply
        let legacy = json::parse(
            r#"{"l_test":40,"l_fail":2,"run_gsg":true,"gsg_passes":1,
                 "gsg_stale_prune_after":3,"use_heatmap":true,"opsg_skip_arith":false}"#,
        )
        .unwrap();
        let cfg = decode_search_config(&legacy).unwrap();
        assert_eq!(cfg.objective, SearchObjective::OpCount);
        assert_eq!(cfg.genetic_generations, SearchConfig::default().genetic_generations);
        assert!(!cfg.subgraph_seed);
        let bad = json::parse(r#"{"l_test":1,"l_fail":1,"run_gsg":true,"gsg_passes":1,
                 "gsg_stale_prune_after":3,"use_heatmap":true,"opsg_skip_arith":false,
                 "objective":"speed"}"#)
        .unwrap();
        assert!(decode_search_config(&bad).unwrap_err().0.contains("op_count"));
    }

    #[test]
    fn pareto_result_front_and_events_roundtrip() {
        let spec = JobSpec {
            objective: Objective::Pareto,
            search: SearchConfig {
                genetic_generations: 2,
                genetic_population: 6,
                ..tiny_spec().search
            },
            ..tiny_spec()
        };
        let service = ExplorationService::with_jobs(1);
        let result = service.run_job(&spec);
        let r = result.outcome.search_result().expect("pareto job completes");
        assert!(!r.front.is_empty());
        let text = encode_result(&result).to_string();
        assert!(text.contains("\"synth\""), "every result carries the synth estimate");
        let back = decode_result(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(encode_result(&back).to_string(), text, "front round-trips byte-stably");
        assert_eq!(back.outcome.search_result().unwrap().front, r.front);

        let ev = SearchEvent::ParetoPoint {
            ops: 9,
            area_um2: 42.5,
            power_uw: 17.25,
            front_size: 3,
            tested: 21,
        };
        assert_eq!(decode_event(&encode_event(&ev)).unwrap(), ev);
    }

    #[test]
    fn infeasible_and_rejected_outcomes_roundtrip() {
        for outcome in [
            JobOutcome::Infeasible("no fit".into()),
            JobOutcome::Rejected("empty set".into()),
        ] {
            let back = decode_outcome(&encode_outcome(&outcome)).unwrap();
            assert_eq!(format!("{back:?}"), format!("{outcome:?}"));
        }
        assert!(decode_outcome(&Json::obj(vec![("status", Json::str("exploded"))])).is_err());
    }

    #[test]
    fn version_mismatch_is_an_error() {
        let service = ExplorationService::with_jobs(1);
        let result = service.run_job(&tiny_spec());
        let mut j = encode_result(&result);
        if let Json::Obj(pairs) = &mut j {
            pairs[0].1 = Json::U64(WIRE_VERSION + 1);
        }
        assert!(decode_result(&j).unwrap_err().0.contains("version"));
    }

    #[test]
    fn strip_volatile_removes_only_wall_clock_fields() {
        let service = ExplorationService::with_jobs(1);
        let spec = tiny_spec();
        let first = service.run_job(&spec);
        let second = service.run_job(&spec); // cache hit: same content, new clock
        assert!(second.from_cache);
        let a = strip_volatile(&encode_result(&first)).to_string();
        let b = strip_volatile(&encode_result(&second)).to_string();
        assert_eq!(a, b, "stripped encodings of one spec must be byte-identical");
        assert!(!a.contains("wall_secs"));
        assert!(!a.contains("\"worker\""), "worker tags are volatile");
        assert!(a.contains("best_cost"), "non-volatile fields survive");
    }

    #[test]
    fn layout_tested_event_roundtrips_with_worker_tag() {
        let ev = SearchEvent::LayoutTested { feasible: true, cost: 12.5, tested: 7, worker: 3 };
        let j = encode_event(&ev);
        assert_eq!(decode_event(&j).unwrap(), ev);
        // records written before parallel search carry no worker tag
        let legacy = json::parse(
            r#"{"type":"layout_tested","feasible":false,"cost":1.0,"tested":2}"#,
        )
        .unwrap();
        assert_eq!(
            decode_event(&legacy).unwrap(),
            SearchEvent::LayoutTested { feasible: false, cost: 1.0, tested: 2, worker: 0 }
        );
    }

    #[test]
    fn batch_roundtrip_and_defaults() {
        let batch = BatchRequest {
            label: "suite".into(),
            client: "ci".into(),
            priority: 8,
            specs: vec![tiny_spec(), tiny_spec()],
        };
        let text = encode_batch(&batch).to_string();
        let back = decode_batch(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.label, "suite");
        assert_eq!(back.client, "ci");
        assert_eq!(back.priority, 8);
        assert_eq!(back.specs.len(), 2);
        assert_eq!(back.specs[0].fingerprint(), batch.specs[0].fingerprint());

        // a minimal batch only sends jobs
        let minimal = json::parse(
            r#"{"jobs":[{"dfgs":[{"name":"t","nodes":["load","store"],"edges":[[0,1]]}],
                 "grid":{"rows":5,"cols":5}}]}"#,
        )
        .unwrap();
        let back = decode_batch(&minimal).unwrap();
        assert_eq!(back.label, "batch");
        assert_eq!(back.client, "anonymous");
        assert_eq!(back.priority, crate::fleet::DEFAULT_PRIORITY);
    }

    #[test]
    fn invalid_batches_are_rejected_with_reasons() {
        for (body, needle) in [
            (r#"[1,2]"#, "object"),
            (r#"{}"#, "jobs"),
            (r#"{"jobs":[]}"#, "at least one job"),
            (r#"{"jobs":0}"#, "array"),
            (r#"{"jobs":[{"grid":{"rows":5,"cols":5}}]}"#, "jobs[0]"),
            (r#"{"jobs":[{"dfgs":[],"grid":{"rows":5,"cols":5}}],"priority":12}"#, "priority"),
            (r#"{"jobs":[{"dfgs":[],"grid":{"rows":5,"cols":5}}],"priority":-1}"#, "priority"),
            (r#"{"jobs":[{"dfgs":[],"grid":{"rows":5,"cols":5}}],"client":""}"#, "client"),
            (r#"{"jobs":[{"dfgs":[],"grid":{"rows":5,"cols":5}}],"client":7}"#, "client"),
            (r#"{"jobs":[{"dfgs":[],"grid":{"rows":5,"cols":5}}],"label":9}"#, "label"),
        ] {
            let err = decode_batch(&json::parse(body).unwrap()).unwrap_err();
            assert!(
                err.0.contains(needle),
                "body {body} should fail mentioning '{needle}', got: {err}"
            );
        }
        // the second bad spec is the one named
        let j = json::parse(
            r#"{"jobs":[{"dfgs":[],"grid":{"rows":5,"cols":5}},
                 {"dfgs":[],"grid":{"rows":2,"cols":2}}]}"#,
        )
        .unwrap();
        assert!(decode_batch(&j).unwrap_err().0.contains("jobs[1]"));
    }

    #[test]
    fn quota_roundtrip_and_rejections() {
        let rule = QuotaRule { client: "ci".into(), burst: 128, per_sec: 8.5 };
        let back = decode_quota(&json::parse(&encode_quota(&rule).to_string()).unwrap()).unwrap();
        assert_eq!(back, rule);
        // integer-valued rates decode too (as_f64 accepts any numeric)
        let j = json::parse(r#"{"client":"x","burst":4,"per_sec":2}"#).unwrap();
        assert_eq!(decode_quota(&j).unwrap().per_sec, 2.0);
        for (body, needle) in [
            (r#"7"#, "object"),
            (r#"{"burst":4,"per_sec":1.0}"#, "client"),
            (r#"{"client":"","burst":4,"per_sec":1.0}"#, "non-empty"),
            (r#"{"client":"x","per_sec":1.0}"#, "burst"),
            (r#"{"client":"x","burst":0,"per_sec":1.0}"#, "at least 1"),
            (r#"{"client":"x","burst":-2,"per_sec":1.0}"#, "burst"),
            (r#"{"client":"x","burst":4}"#, "per_sec"),
            (r#"{"client":"x","burst":4,"per_sec":-1.0}"#, "per_sec"),
            (r#"{"client":"x","burst":4,"per_sec":"fast"}"#, "number"),
        ] {
            let err = decode_quota(&json::parse(body).unwrap()).unwrap_err();
            assert!(
                err.0.contains(needle),
                "body {body} should fail mentioning '{needle}', got: {err}"
            );
        }
    }

    #[test]
    fn replica_status_roundtrip_and_rejections() {
        for state in
            [ReplicaState::Healthy, ReplicaState::Draining, ReplicaState::Unreachable]
        {
            let status = ReplicaStatus {
                addr: "127.0.0.1:7878".into(),
                state,
                inflight: 2,
                queued: 5,
                running: 1,
                consecutive_failures: 0,
            };
            let text = encode_replica_status(&status).to_string();
            let back = decode_replica_status(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, status);
        }
        for (body, needle) in [
            (r#"null"#, "object"),
            (r#"{"state":"healthy"}"#, "addr"),
            (r#"{"addr":"","state":"healthy"}"#, "non-empty"),
            (
                r#"{"addr":"x","state":"zombie","inflight":0,"queued":0,"running":0,"consecutive_failures":0}"#,
                "unknown replica state",
            ),
            (r#"{"addr":"x","state":"healthy"}"#, "inflight"),
            (
                r#"{"addr":"x","state":"healthy","inflight":-1,"queued":0,"running":0,"consecutive_failures":0}"#,
                "inflight",
            ),
        ] {
            let err = decode_replica_status(&json::parse(body).unwrap()).unwrap_err();
            assert!(
                err.0.contains(needle),
                "body {body} should fail mentioning '{needle}', got: {err}"
            );
        }
    }

    #[test]
    fn fp_hex_roundtrip() {
        for fp in [0u64, 1, 0xDEAD_BEEF, u64::MAX] {
            assert_eq!(parse_fp(&fp_hex(fp)).unwrap(), fp);
            assert_eq!(fp_hex(fp).len(), 16);
        }
        assert!(parse_fp("").is_err());
        assert!(parse_fp("xyz").is_err());
        assert!(parse_fp("11112222333344445").is_err());
    }
}
