//! Async job registry: submit-now, poll-later execution over the
//! [`ExplorationService`].
//!
//! The synchronous service API (`run_job`, `run_batch`) resolves on the
//! calling thread; an HTTP server cannot hold a connection open for a
//! minutes-long search. The registry decouples the two halves:
//! [`JobRegistry::submit`] validates nothing (the spec was already
//! decoded), assigns a [`JobId`], enqueues, and returns immediately;
//! a fixed pool of worker threads drains the queue through
//! [`ExplorationService::run_assigned`]; [`JobRegistry::get`] serves the
//! current [`JobStatus`] snapshot at any time.
//!
//! Every job carries an [`EventLog`] — an append-only, condvar-signalled
//! trace of its [`SearchEvent`]s, fed live through the service's
//! [`EventSink`] hook. The `/v1/jobs/:id/events` endpoint tails it with
//! [`EventLog::wait_from`], so clients stream progress while the search
//! runs and still see the full (replayed) trace for cache-served jobs.
//!
//! Shutdown: [`JobRegistry::drain`] stops admission ([`SubmitError::Draining`]),
//! lets the workers finish everything already queued or running, and
//! joins them — no worker is ever interrupted mid-write.

use super::{EventSink, ExplorationService, JobId, JobOutcome, JobResult, JobSpec};
use crate::search::SearchEvent;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Completed entries retained for polling, beyond which the oldest are
/// evicted (queued/running jobs are never evicted). Keeps a long-lived
/// server's per-job memory bounded; evicted results remain available
/// from the store by fingerprint.
pub const DEFAULT_RETAIN_DONE: usize = 4096;

/// Where a job currently is. `Done` carries the result.
#[derive(Debug, Clone)]
pub enum JobStatus {
    Queued,
    Running,
    Done(Box<JobResult>),
}

impl JobStatus {
    pub fn name(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done(_) => "done",
        }
    }
}

/// Append-only event trace of one job, safe to tail from any number of
/// reader threads while the worker appends.
#[derive(Default)]
pub struct EventLog {
    state: Mutex<LogState>,
    grew: Condvar,
}

#[derive(Default)]
struct LogState {
    events: Vec<SearchEvent>,
    closed: bool,
}

impl EventLog {
    fn append(&self, event: &SearchEvent) {
        let mut state = self.state.lock().unwrap();
        state.events.push(event.clone());
        self.grew.notify_all();
    }

    /// Seal the log *and drop its buffer*: once the job is Done, its
    /// `JobResult.events` owns the (identical) trace, and keeping a
    /// second copy per retained job would double the registry's memory.
    /// Tailers that had not caught up complete their stream from the
    /// result (see the server's event streamer).
    fn close(&self) {
        let mut state = self.state.lock().unwrap();
        state.closed = true;
        state.events = Vec::new();
        self.grew.notify_all();
    }

    /// Everything appended so far and whether the log is complete.
    pub fn snapshot(&self) -> (Vec<SearchEvent>, bool) {
        let state = self.state.lock().unwrap();
        (state.events.clone(), state.closed)
    }

    /// Events past index `from`, blocking up to `timeout` for growth when
    /// none are available yet. Returns `(new_events, closed)`; an empty
    /// vector with `closed = false` means the timeout elapsed (poll
    /// again — streamers use this to notice dropped clients).
    pub fn wait_from(&self, from: usize, timeout: Duration) -> (Vec<SearchEvent>, bool) {
        let mut state = self.state.lock().unwrap();
        if state.events.len() <= from && !state.closed {
            let (next, _timed_out) = self.grew.wait_timeout(state, timeout).unwrap();
            state = next;
        }
        let new = state.events.get(from..).unwrap_or(&[]).to_vec();
        (new, state.closed)
    }
}

/// One submitted job: the spec, its mutable status, and the live trace.
pub struct JobEntry {
    pub id: JobId,
    pub spec: JobSpec,
    status: Mutex<JobStatus>,
    pub events: EventLog,
}

impl JobEntry {
    pub fn status(&self) -> JobStatus {
        self.status.lock().unwrap().clone()
    }

    /// The result, once the job finished.
    pub fn result(&self) -> Option<JobResult> {
        match &*self.status.lock().unwrap() {
            JobStatus::Done(result) => Some((**result).clone()),
            _ => None,
        }
    }
}

impl EventSink for JobEntry {
    fn on_event(&self, event: &SearchEvent) {
        self.events.append(event);
    }
}

/// Why a submission was refused (both map to HTTP 503).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The pending queue is at capacity; retry later.
    QueueFull,
    /// The server is shutting down and no longer admits work.
    Draining,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => f.write_str("job queue is full"),
            SubmitError::Draining => f.write_str("server is draining"),
        }
    }
}

/// Queue/worker occupancy snapshot (`/v1/stats`).
#[derive(Debug, Clone, Copy, Default)]
pub struct RegistryStats {
    pub queued: usize,
    pub running: usize,
    pub done: usize,
    pub queue_capacity: usize,
}

#[derive(Default)]
struct Pending {
    queue: VecDeque<Arc<JobEntry>>,
    running: usize,
    done: usize,
    draining: bool,
}

/// The id→entry map plus completion order for bounded retention.
#[derive(Default)]
struct JobsMap {
    by_id: HashMap<JobId, Arc<JobEntry>>,
    /// Done jobs, oldest first; the eviction queue.
    done_order: VecDeque<JobId>,
}

/// The registry. See the module docs.
pub struct JobRegistry {
    service: Arc<ExplorationService>,
    pending: Mutex<Pending>,
    /// Signalled on enqueue and on drain (workers wake to pick up work
    /// or to exit).
    work: Condvar,
    /// Signalled whenever a job finishes or the queue empties (drain
    /// waits on this).
    quiet: Condvar,
    jobs: Mutex<JobsMap>,
    queue_cap: usize,
    retain_done: usize,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl JobRegistry {
    /// Start a registry with `workers` executor threads (min 1), a
    /// pending queue bounded at `queue_cap` jobs, and at most
    /// `retain_done` completed entries kept for polling (min 1).
    pub fn start(
        service: Arc<ExplorationService>,
        workers: usize,
        queue_cap: usize,
        retain_done: usize,
    ) -> Arc<Self> {
        let registry = Arc::new(Self {
            service,
            pending: Mutex::new(Pending::default()),
            work: Condvar::new(),
            quiet: Condvar::new(),
            jobs: Mutex::new(JobsMap::default()),
            queue_cap: queue_cap.max(1),
            retain_done: retain_done.max(1),
            workers: Mutex::new(Vec::new()),
        });
        let mut handles = Vec::new();
        for _ in 0..workers.max(1) {
            let reg = Arc::clone(&registry);
            handles.push(std::thread::spawn(move || reg.worker_loop()));
        }
        *registry.workers.lock().unwrap() = handles;
        registry
    }

    fn worker_loop(&self) {
        loop {
            let entry = {
                let mut pending = self.pending.lock().unwrap();
                loop {
                    if let Some(entry) = pending.queue.pop_front() {
                        pending.running += 1;
                        break entry;
                    }
                    if pending.draining {
                        return;
                    }
                    pending = self.work.wait(pending).unwrap();
                }
            };
            *entry.status.lock().unwrap() = JobStatus::Running;
            let sink: Arc<dyn EventSink> = Arc::clone(&entry);
            // a panicking search (or a twin waiting on a poisoned cache
            // slot) must not kill the worker: the pool would silently
            // shrink, the job would stay "Running" forever, and drain()
            // would hang on the leaked running counter. Catch it and
            // resolve the job as Rejected instead.
            let computed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.service.run_assigned(entry.id, &entry.spec, Some(sink))
            }));
            let result = computed.unwrap_or_else(|panic| {
                let msg = panic
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                JobResult {
                    id: entry.id,
                    label: entry.spec.label.clone(),
                    grid: entry.spec.grid,
                    fingerprint: entry.spec.fingerprint(),
                    outcome: JobOutcome::Rejected(format!("job panicked: {msg}")),
                    events: Vec::new(),
                    wall_secs: 0.0,
                    from_cache: false,
                }
            });
            *entry.status.lock().unwrap() = JobStatus::Done(Box::new(result));
            entry.events.close();
            self.retire(entry.id);
            let mut pending = self.pending.lock().unwrap();
            pending.running -= 1;
            pending.done += 1;
            self.quiet.notify_all();
        }
    }

    /// Record a completion for retention bookkeeping, evicting the
    /// oldest done entries past the cap.
    fn retire(&self, id: JobId) {
        let mut jobs = self.jobs.lock().unwrap();
        jobs.done_order.push_back(id);
        while jobs.done_order.len() > self.retain_done {
            if let Some(oldest) = jobs.done_order.pop_front() {
                jobs.by_id.remove(&oldest);
            }
        }
    }

    /// Enqueue a spec. Returns its id immediately; the job runs when a
    /// worker frees up.
    pub fn submit(&self, spec: JobSpec) -> Result<JobId, SubmitError> {
        let id = self.service.allocate_id();
        let entry = Arc::new(JobEntry {
            id,
            spec,
            status: Mutex::new(JobStatus::Queued),
            events: EventLog::default(),
        });
        {
            let mut pending = self.pending.lock().unwrap();
            if pending.draining {
                return Err(SubmitError::Draining);
            }
            if pending.queue.len() >= self.queue_cap {
                return Err(SubmitError::QueueFull);
            }
            pending.queue.push_back(Arc::clone(&entry));
        }
        self.jobs.lock().unwrap().by_id.insert(id, entry);
        self.work.notify_one();
        Ok(id)
    }

    /// The entry for `id`, if it was submitted here and (for completed
    /// jobs) is still within the retention window.
    pub fn get(&self, id: JobId) -> Option<Arc<JobEntry>> {
        self.jobs.lock().unwrap().by_id.get(&id).cloned()
    }

    pub fn stats(&self) -> RegistryStats {
        let pending = self.pending.lock().unwrap();
        RegistryStats {
            queued: pending.queue.len(),
            running: pending.running,
            done: pending.done,
            queue_capacity: self.queue_cap,
        }
    }

    /// True once [`Self::drain`] has been called.
    pub fn draining(&self) -> bool {
        self.pending.lock().unwrap().draining
    }

    /// Graceful shutdown: refuse new submissions, wait for every queued
    /// and running job to finish, then join the workers. Idempotent.
    pub fn drain(&self) {
        {
            let mut pending = self.pending.lock().unwrap();
            pending.draining = true;
            self.work.notify_all();
            while !(pending.queue.is_empty() && pending.running == 0) {
                pending = self.quiet.wait(pending).unwrap();
            }
        }
        let handles = std::mem::take(&mut *self.workers.lock().unwrap());
        for handle in handles {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::dfg::benchmarks;
    use crate::search::SearchConfig;

    fn tiny_spec(label: &str) -> JobSpec {
        JobSpec {
            search: SearchConfig { l_test: 30, l_fail: 2, gsg_passes: 1, ..Default::default() },
            ..JobSpec::new(label, vec![benchmarks::benchmark("SOB")], Grid::new(5, 5))
        }
    }

    fn wait_done(registry: &JobRegistry, id: JobId) -> JobResult {
        let entry = registry.get(id).expect("submitted job is registered");
        for _ in 0..600 {
            if let Some(result) = entry.result() {
                return result;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        panic!("job {id} did not finish in 30s");
    }

    #[test]
    fn submit_poll_done_lifecycle() {
        let service = Arc::new(ExplorationService::with_jobs(1));
        let registry = JobRegistry::start(service, 2, 8, 64);
        let id = registry.submit(tiny_spec("lifecycle")).unwrap();
        let entry = registry.get(id).unwrap();
        assert_eq!(entry.id, id);
        let result = wait_done(&registry, id);
        assert_eq!(result.id, id, "result carries the submit-time id");
        assert!(result.outcome.is_completed());
        assert!(matches!(entry.status(), JobStatus::Done(_)));
        // the log seals and drops its buffer once Done — the result
        // owns the trace from then on (no duplicate copy per job)
        let (events, closed) = entry.events.snapshot();
        assert!(closed);
        assert!(events.is_empty(), "sealed log must not retain a second trace copy");
        assert!(!result.events.is_empty(), "the result carries the trace");
        assert!(registry.get(JobId(u64::MAX)).is_none());
        registry.drain();
        assert_eq!(registry.stats().done, 1);
    }

    #[test]
    fn event_log_tail_is_a_prefix_the_result_completes() {
        let service = Arc::new(ExplorationService::with_jobs(1));
        let registry = JobRegistry::start(service, 1, 8, 64);
        let id = registry.submit(tiny_spec("tail")).unwrap();
        let entry = registry.get(id).unwrap();
        let mut tailed = Vec::new();
        loop {
            let (new, closed) = entry.events.wait_from(tailed.len(), Duration::from_millis(100));
            let drained = new.is_empty();
            tailed.extend(new);
            if closed && drained {
                break;
            }
        }
        // the log may seal (dropping its buffer) before a tailer drains
        // it, so a tail is a *prefix* of the trace; streamers complete
        // the remainder from the result — exactly what we check here
        let result = wait_done(&registry, id);
        assert!(tailed.len() <= result.events.len());
        assert_eq!(
            tailed,
            result.events[..tailed.len()].to_vec(),
            "tailed stream must be a prefix of the recorded trace"
        );
        registry.drain();
    }

    #[test]
    fn drain_finishes_queued_work_and_refuses_new() {
        let service = Arc::new(ExplorationService::with_jobs(1));
        let registry = JobRegistry::start(service, 1, 8, 64);
        let ids: Vec<JobId> = (0..3)
            .map(|i| registry.submit(tiny_spec(&format!("drain-{i}"))).unwrap())
            .collect();
        registry.drain();
        for id in ids {
            let entry = registry.get(id).unwrap();
            assert!(
                matches!(entry.status(), JobStatus::Done(_)),
                "drain must finish queued job {id}"
            );
        }
        assert_eq!(registry.submit(tiny_spec("late")).unwrap_err(), SubmitError::Draining);
        assert!(registry.draining());
    }

    #[test]
    fn done_entries_are_evicted_past_the_retention_cap() {
        let service = Arc::new(ExplorationService::with_jobs(1));
        let registry = JobRegistry::start(service, 1, 8, 2);
        let ids: Vec<JobId> = (0..3)
            .map(|i| registry.submit(tiny_spec(&format!("retain-{i}"))).unwrap())
            .collect();
        registry.drain(); // all three complete, in submission order
        assert!(
            registry.get(ids[0]).is_none(),
            "oldest done entry must be evicted past the cap of 2"
        );
        assert!(registry.get(ids[1]).is_some());
        assert!(registry.get(ids[2]).is_some());
        assert_eq!(registry.stats().done, 3, "counters track completions, not retention");
    }

    #[test]
    fn queue_capacity_bounds_admission() {
        // a registry whose single worker is guaranteed busy: give it a
        // full queue before it can drain anything meaningful
        let service = Arc::new(ExplorationService::with_jobs(1));
        let registry = JobRegistry::start(service, 1, 2, 64);
        let mut accepted = 0;
        let mut refused = 0;
        for i in 0..40 {
            match registry.submit(tiny_spec(&format!("cap-{i}"))) {
                Ok(_) => accepted += 1,
                Err(SubmitError::QueueFull) => refused += 1,
                Err(other) => panic!("unexpected {other:?}"),
            }
        }
        assert!(refused > 0, "a 2-deep queue cannot admit 40 instant submissions");
        assert!(accepted >= 2);
        registry.drain();
    }
}
