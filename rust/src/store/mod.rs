//! Content-addressed on-disk result store.
//!
//! Persists completed jobs keyed by their [`crate::service::JobSpec`]
//! content fingerprint, so identical specs are never recomputed across
//! processes or restarts — the durable tier under the in-memory
//! [`crate::service::cache::ShardedRunCache`]. Layout on disk:
//!
//! ```text
//! <dir>/<fingerprint 16-hex>.json   one record per result (wire schema)
//! <dir>/index.json                  LRU bookkeeping {fp, last_used}
//! ```
//!
//! Design points:
//!
//! * **Atomic writes** — every file (records and the index) is written to
//!   a temp name in the same directory and `rename`d into place, so a
//!   crash mid-write can leave a stale temp file but never a torn record.
//! * **Corruption tolerance** — unreadable, unparseable or
//!   wrong-version records are treated as misses: the entry is dropped,
//!   the file best-effort deleted, a counter incremented, and the caller
//!   recomputes. A missing or corrupt index is rebuilt by scanning the
//!   directory (which also reconciles records written just before a
//!   crash), so no on-disk state can prevent the store from opening.
//! * **Versioned schema** — records embed
//!   [`crate::service::wire::WIRE_VERSION`]; a mismatch after an upgrade
//!   is a recompute, not an error.
//! * **LRU capacity eviction** — at most `capacity` records are kept
//!   (0 = unbounded); inserting past the cap evicts the least recently
//!   *used* (gets refresh recency), deleting the file.
//!
//! All methods take `&self`; an internal mutex serializes disk access
//! (record files are small — the search dominates job cost by orders of
//! magnitude, as the `store::roundtrip` bench shows).

use crate::service::cache::CachedJob;
use crate::service::wire;
use crate::util::json::{self, Json};
use std::collections::HashMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Schema version of `index.json` (records carry the wire version).
const INDEX_VERSION: u64 = 1;

/// Counters and occupancy of one store, as served by `/v1/stats`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub entries: usize,
    pub hits: u64,
    pub misses: u64,
    pub writes: u64,
    pub evictions: u64,
    /// Records dropped because they could not be read back.
    pub corrupt: u64,
}

struct Inner {
    /// fingerprint → LRU stamp (monotonic per store instance).
    index: HashMap<u64, u64>,
    tick: u64,
    /// Index mutated since the last flush.
    dirty: bool,
}

/// The store. See the module docs.
pub struct ResultStore {
    dir: PathBuf,
    capacity: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    writes: AtomicU64,
    evictions: AtomicU64,
    corrupt: AtomicU64,
}

impl ResultStore {
    /// Open (creating if needed) a store at `dir` holding at most
    /// `capacity` records (`0` = unbounded).
    pub fn open(dir: impl Into<PathBuf>, capacity: usize) -> io::Result<Self> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        let mut inner = Inner { index: HashMap::new(), tick: 0, dirty: false };
        let mut corrupt_index = false;
        match fs::read_to_string(dir.join("index.json")) {
            Ok(text) => match Self::parse_index(&text) {
                Some((tick, index)) => {
                    inner.tick = tick;
                    inner.index = index;
                }
                None => corrupt_index = true,
            },
            Err(e) if e.kind() == io::ErrorKind::NotFound => {}
            Err(_) => corrupt_index = true,
        }
        // reconcile with the records actually on disk: pick up files the
        // index missed (crash between record write and index flush) and
        // drop entries whose file is gone
        let mut on_disk: HashMap<u64, ()> = HashMap::new();
        for entry in fs::read_dir(&dir)?.flatten() {
            if let Some(fp) = record_fp(&entry.file_name().to_string_lossy()) {
                on_disk.insert(fp, ());
            }
        }
        inner.index.retain(|fp, _| on_disk.contains_key(fp));
        for fp in on_disk.keys() {
            if !inner.index.contains_key(fp) {
                inner.index.insert(*fp, 0); // oldest possible: evict first
                inner.dirty = true;
            }
        }
        if corrupt_index {
            inner.dirty = true;
        }
        let store = Self {
            dir,
            capacity,
            inner: Mutex::new(inner),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            writes: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            corrupt: AtomicU64::new(if corrupt_index { 1 } else { 0 }),
        };
        if corrupt_index {
            let _ = store.flush();
        }
        Ok(store)
    }

    fn parse_index(text: &str) -> Option<(u64, HashMap<u64, u64>)> {
        let j = json::parse(text).ok()?;
        if j.get("version")?.as_u64()? != INDEX_VERSION {
            return None;
        }
        let tick = j.get("tick")?.as_u64()?;
        let mut index = HashMap::new();
        for entry in j.get("entries")?.as_array()? {
            let fp = wire::parse_fp(entry.get("fp")?.as_str()?).ok()?;
            index.insert(fp, entry.get("last_used")?.as_u64()?);
        }
        Some((tick, index))
    }

    fn record_path(&self, fp: u64) -> PathBuf {
        self.dir.join(format!("{}.json", wire::fp_hex(fp)))
    }

    /// Atomic write: temp file in the same directory, then rename.
    fn write_atomic(&self, path: &Path, bytes: &[u8]) -> io::Result<()> {
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let tmp = self.dir.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        fs::write(&tmp, bytes)?;
        match fs::rename(&tmp, path) {
            Ok(()) => Ok(()),
            Err(e) => {
                let _ = fs::remove_file(&tmp);
                Err(e)
            }
        }
    }

    /// Look up a result by fingerprint. Corrupt records count as misses
    /// and self-heal (entry dropped, file deleted).
    pub fn get(&self, fp: u64) -> Option<CachedJob> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.index.contains_key(&fp) {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let path = self.record_path(fp);
        let decoded = fs::read_to_string(&path)
            .ok()
            .and_then(|text| json::parse(&text).ok())
            .and_then(|j| decode_record(&j, fp));
        match decoded {
            Some(job) => {
                inner.tick += 1;
                let tick = inner.tick;
                inner.index.insert(fp, tick);
                inner.dirty = true;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(job)
            }
            None => {
                inner.index.remove(&fp);
                inner.dirty = true;
                let _ = fs::remove_file(&path);
                self.corrupt.fetch_add(1, Ordering::Relaxed);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Persist a result, evicting least-recently-used records past the
    /// capacity, and flush the index.
    pub fn put(&self, fp: u64, job: &CachedJob) -> io::Result<()> {
        let record = Json::obj(vec![
            ("version", Json::U64(wire::WIRE_VERSION)),
            ("fingerprint", Json::str(wire::fp_hex(fp))),
            ("outcome", wire::encode_outcome(&job.outcome)),
            ("events", wire::encode_events(&job.events)),
        ]);
        let bytes = record.to_string();
        let mut inner = self.inner.lock().unwrap();
        self.write_atomic(&self.record_path(fp), bytes.as_bytes())?;
        inner.tick += 1;
        let tick = inner.tick;
        inner.index.insert(fp, tick);
        inner.dirty = true;
        self.writes.fetch_add(1, Ordering::Relaxed);
        while self.capacity > 0 && inner.index.len() > self.capacity {
            // the freshly inserted record has the max stamp, so it is
            // never the minimum here
            let Some((&victim, _)) =
                inner.index.iter().min_by_key(|(_, &last_used)| last_used)
            else {
                break;
            };
            inner.index.remove(&victim);
            let _ = fs::remove_file(self.record_path(victim));
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        self.flush_locked(&mut inner)
    }

    fn flush_locked(&self, inner: &mut Inner) -> io::Result<()> {
        if !inner.dirty {
            return Ok(());
        }
        let mut entries: Vec<(&u64, &u64)> = inner.index.iter().collect();
        entries.sort(); // deterministic index bytes
        let index = Json::obj(vec![
            ("version", Json::U64(INDEX_VERSION)),
            ("tick", Json::U64(inner.tick)),
            (
                "entries",
                Json::Arr(
                    entries
                        .into_iter()
                        .map(|(fp, last_used)| {
                            Json::obj(vec![
                                ("fp", Json::str(wire::fp_hex(*fp))),
                                ("last_used", Json::U64(*last_used)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        self.write_atomic(&self.dir.join("index.json"), index.to_string().as_bytes())?;
        inner.dirty = false;
        Ok(())
    }

    /// Write the index if it changed since the last flush (graceful
    /// shutdown calls this; `put` flushes on its own).
    pub fn flush(&self) -> io::Result<()> {
        self.flush_locked(&mut self.inner.lock().unwrap())
    }

    /// Records currently indexed.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn stats(&self) -> StoreStats {
        StoreStats {
            entries: self.len(),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            writes: self.writes.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            corrupt: self.corrupt.load(Ordering::Relaxed),
        }
    }
}

impl Drop for ResultStore {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

/// Fingerprint of a record filename (`<16 hex>.json`), `None` for
/// anything else (the index, temp files, strangers).
fn record_fp(name: &str) -> Option<u64> {
    let stem = name.strip_suffix(".json")?;
    if stem.len() != 16 || !stem.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    wire::parse_fp(stem).ok()
}

fn decode_record(j: &Json, fp: u64) -> Option<CachedJob> {
    if j.get("version")?.as_u64()? != wire::WIRE_VERSION {
        return None;
    }
    // a record renamed to the wrong fingerprint must not poison the cache
    if wire::parse_fp(j.get("fingerprint")?.as_str()?).ok()? != fp {
        return None;
    }
    Some(CachedJob {
        outcome: wire::decode_outcome(j.get("outcome")?).ok()?,
        events: wire::decode_events(j.get("events")?).ok()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::SearchEvent;
    use crate::service::JobOutcome;

    fn tmp_dir(tag: &str) -> PathBuf {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "helex-store-{tag}-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn probe(tag: &str) -> CachedJob {
        CachedJob {
            outcome: JobOutcome::Infeasible(format!("probe-{tag}")),
            events: vec![SearchEvent::PhaseStarted {
                phase: tag.to_string(),
                incumbent_cost: 1.5,
            }],
        }
    }

    fn reason(job: &CachedJob) -> String {
        job.outcome.infeasible_reason().unwrap().to_string()
    }

    #[test]
    fn roundtrip_within_and_across_opens() {
        let dir = tmp_dir("rt");
        {
            let store = ResultStore::open(&dir, 0).unwrap();
            assert!(store.is_empty());
            assert!(store.get(7).is_none());
            store.put(7, &probe("seven")).unwrap();
            let back = store.get(7).expect("hit after put");
            assert_eq!(reason(&back), "probe-seven");
            assert_eq!(back.events.len(), 1);
            assert_eq!(store.stats().writes, 1);
            assert_eq!(store.stats().hits, 1);
            assert_eq!(store.stats().misses, 1);
        }
        // a fresh open (new process, conceptually) serves the same bytes
        let store = ResultStore::open(&dir, 0).unwrap();
        assert_eq!(store.len(), 1);
        assert_eq!(reason(&store.get(7).expect("survives reopen")), "probe-seven");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_record_is_a_self_healing_miss() {
        let dir = tmp_dir("corrupt");
        let store = ResultStore::open(&dir, 0).unwrap();
        store.put(1, &probe("one")).unwrap();
        store.put(2, &probe("two")).unwrap();
        store.put(3, &probe("three")).unwrap();
        drop(store);
        // three corruption modes: garbage bytes, truncation, version skew
        fs::write(dir.join(format!("{}.json", wire::fp_hex(1))), b"{not json").unwrap();
        let p2 = dir.join(format!("{}.json", wire::fp_hex(2)));
        let full = fs::read(&p2).unwrap();
        fs::write(&p2, &full[..full.len() / 2]).unwrap();
        let p3 = dir.join(format!("{}.json", wire::fp_hex(3)));
        let skewed = fs::read_to_string(&p3)
            .unwrap()
            .replace("{\"version\":1", "{\"version\":999");
        fs::write(&p3, skewed).unwrap();

        let store = ResultStore::open(&dir, 0).unwrap();
        for fp in [1u64, 2, 3] {
            assert!(store.get(fp).is_none(), "corrupt record {fp} must miss, not panic");
        }
        assert_eq!(store.stats().corrupt, 3);
        assert_eq!(store.len(), 0, "corrupt entries self-heal out of the index");
        // and the store still accepts new work
        store.put(1, &probe("fresh")).unwrap();
        assert_eq!(reason(&store.get(1).unwrap()), "probe-fresh");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_or_missing_index_is_rebuilt_from_records() {
        let dir = tmp_dir("index");
        {
            let store = ResultStore::open(&dir, 0).unwrap();
            store.put(10, &probe("ten")).unwrap();
            store.put(11, &probe("eleven")).unwrap();
        }
        fs::write(dir.join("index.json"), b"]]]]").unwrap();
        let store = ResultStore::open(&dir, 0).unwrap();
        assert_eq!(store.len(), 2, "records rediscovered by directory scan");
        assert_eq!(reason(&store.get(10).unwrap()), "probe-ten");
        drop(store);
        fs::remove_file(dir.join("index.json")).unwrap();
        let store = ResultStore::open(&dir, 0).unwrap();
        assert_eq!(store.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn record_under_wrong_filename_does_not_poison() {
        let dir = tmp_dir("rename");
        let store = ResultStore::open(&dir, 0).unwrap();
        store.put(0xAAAA, &probe("a")).unwrap();
        drop(store);
        fs::rename(
            dir.join(format!("{}.json", wire::fp_hex(0xAAAA))),
            dir.join(format!("{}.json", wire::fp_hex(0xBBBB))),
        )
        .unwrap();
        let store = ResultStore::open(&dir, 0).unwrap();
        assert!(store.get(0xBBBB).is_none(), "fingerprint mismatch must miss");
        assert!(store.get(0xAAAA).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_eviction_keeps_recently_used() {
        let dir = tmp_dir("lru");
        let store = ResultStore::open(&dir, 2).unwrap();
        store.put(1, &probe("1")).unwrap();
        store.put(2, &probe("2")).unwrap();
        assert!(store.get(1).is_some(), "touch 1 so 2 is now the LRU");
        store.put(3, &probe("3")).unwrap();
        assert_eq!(store.len(), 2);
        assert!(store.get(2).is_none(), "LRU record evicted");
        assert!(store.get(1).is_some());
        assert!(store.get(3).is_some());
        assert_eq!(store.stats().evictions, 1);
        assert!(
            !dir.join(format!("{}.json", wire::fp_hex(2))).exists(),
            "eviction deletes the record file"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn temp_files_and_strangers_are_ignored_on_open() {
        let dir = tmp_dir("strangers");
        {
            let store = ResultStore::open(&dir, 0).unwrap();
            store.put(5, &probe("five")).unwrap();
        }
        fs::write(dir.join(".tmp-999-0"), b"half a record").unwrap();
        fs::write(dir.join("notes.txt"), b"hello").unwrap();
        fs::write(dir.join("zz.json"), b"{}").unwrap(); // not 16 hex digits
        let store = ResultStore::open(&dir, 0).unwrap();
        assert_eq!(store.len(), 1, "only well-named records are indexed");
        let _ = fs::remove_dir_all(&dir);
    }
}
