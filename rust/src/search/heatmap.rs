//! Heatmap initial layout (paper Section III-E, Fig 2).
//!
//! Map each DFG *individually* on the full layout; overlay the resulting
//! node→cell assignments into a heterogeneous layout where each compute
//! cell supports exactly the groups some DFG actually executed there.
//! I/O cells are untouched. If all DFGs successfully *re-map* onto the
//! heatmap layout, it becomes the initial layout; otherwise the search
//! starts from the full layout. All mapping goes through the
//! [`MappingEngine`], so infeasibility carries the structured
//! [`MapFailure`] diagnostic of the DFG that failed.

use crate::cgra::Layout;
use crate::dfg::Dfg;
use crate::mapper::{MapFailure, MapSetFailure, MappingEngine};

/// Outcome of initial-layout construction.
pub enum HeatmapOutcome {
    /// Heatmap built and all DFGs re-mapped onto it.
    Heatmap(Layout),
    /// Some DFG failed to re-map onto the heatmap; start from full.
    FullFallback,
    /// Some DFG failed to map even on the *full* layout — HeLEx
    /// terminates in failure (Algorithm 1 precondition). Carries which
    /// DFG and why.
    Infeasible { dfg: String, failure: MapFailure },
}

/// Overlay of per-DFG mappings: the heterogeneous usage layout. Fails
/// with the first DFG that does not map on `full`.
pub fn try_overlay(
    dfgs: &[Dfg],
    full: &Layout,
    engine: &MappingEngine,
) -> Result<Layout, MapSetFailure> {
    let mut heat = full.empty_like();
    for (mapping, dfg) in engine.map_all(dfgs, full)?.iter().zip(dfgs) {
        for (n, op) in dfg.nodes.iter().enumerate() {
            if op.is_memory() {
                continue; // I/O cells untouched
            }
            let cell = mapping.node_cell[n];
            let mut s = heat.support(cell);
            s.insert(op.group());
            heat.set_support(cell, s);
        }
    }
    Ok(heat)
}

/// [`try_overlay`] without the failure diagnostic.
pub fn overlay(dfgs: &[Dfg], full: &Layout, engine: &MappingEngine) -> Option<Layout> {
    try_overlay(dfgs, full, engine).ok()
}

/// Section III-E procedure.
pub fn initial_layout(dfgs: &[Dfg], full: &Layout, engine: &MappingEngine) -> HeatmapOutcome {
    let heat = match try_overlay(dfgs, full, engine) {
        Ok(heat) => heat,
        Err(fail) => {
            return HeatmapOutcome::Infeasible { dfg: fail.dfg_name, failure: fail.failure };
        }
    };
    // re-map all DFGs onto the heatmap layout
    if engine.test_layout(dfgs, &heat) {
        HeatmapOutcome::Heatmap(heat)
    } else {
        HeatmapOutcome::FullFallback
    }
}

/// Heatmap "pressure" statistics used by the REVAMP-like baseline and by
/// diagnostics: per (cell, group) count of how many DFGs placed an op of
/// that group there.
pub fn usage_counts(
    dfgs: &[Dfg],
    full: &Layout,
    engine: &MappingEngine,
) -> Option<Vec<[u16; crate::ops::NUM_GROUPS]>> {
    let mut counts = vec![[0u16; crate::ops::NUM_GROUPS]; full.grid.num_cells()];
    for (m, dfg) in engine.map_all(dfgs, full).ok()?.iter().zip(dfgs) {
        for (n, op) in dfg.nodes.iter().enumerate() {
            counts[m.node_cell[n] as usize][op.group().index()] += 1;
        }
    }
    Some(counts)
}

/// The heatmap is always a subset of the full layout and always meets the
/// per-DFG group-usage lower bound on its own mappings.
pub fn heatmap_is_subset(heat: &Layout, full: &Layout) -> bool {
    heat.grid == full.grid
        && full
            .grid
            .compute_cells()
            .all(|c| heat.support(c).is_subset_of(full.support(c)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::dfg::benchmarks;

    fn setup(names: &[&str], r: usize, c: usize) -> (Vec<Dfg>, Layout, MappingEngine) {
        let dfgs: Vec<Dfg> = names.iter().map(|n| benchmarks::benchmark(n)).collect();
        let full = Layout::full(Grid::new(r, c), crate::dfg::groups_used(&dfgs));
        (dfgs, full, MappingEngine::default())
    }

    #[test]
    fn overlay_is_subset_of_full() {
        let (dfgs, full, engine) = setup(&["SOB", "GB", "RGB"], 8, 8);
        let heat = overlay(&dfgs, &full, &engine).unwrap();
        assert!(heat.is_subset_of(&full));
        assert!(heatmap_is_subset(&heat, &full));
        // strictly smaller in practice for these tiny DFGs on 8x8
        assert!(heat.compute_instances() < full.compute_instances());
    }

    #[test]
    fn overlay_covers_each_dfg_needs() {
        let (dfgs, full, engine) = setup(&["NMS"], 9, 9);
        let heat = overlay(&dfgs, &full, &engine).unwrap();
        // total instances per group >= the DFG's op count per group
        let h = heat.compute_group_instances();
        let need = dfgs[0].group_histogram();
        for g in crate::ops::COMPUTE_GROUPS {
            assert!(
                h[g.index()] >= need[g.index()].min(full.grid.num_compute()),
                "group {g}: {} < {}",
                h[g.index()],
                need[g.index()]
            );
        }
    }

    #[test]
    fn initial_layout_feasible_or_fallback() {
        let (dfgs, full, engine) = setup(&["SOB", "GB"], 7, 7);
        match initial_layout(&dfgs, &full, &engine) {
            HeatmapOutcome::Heatmap(h) => {
                assert!(engine.test_layout(&dfgs, &h));
            }
            HeatmapOutcome::FullFallback => {} // acceptable
            HeatmapOutcome::Infeasible { dfg, failure } => {
                panic!("SOB+GB must be feasible on 7x7: {dfg}: {failure}")
            }
        }
    }

    #[test]
    fn infeasible_reported_with_diagnostic() {
        let (dfgs, full, engine) = setup(&["SAD"], 5, 5);
        match initial_layout(&dfgs, &full, &engine) {
            HeatmapOutcome::Infeasible { dfg, failure } => {
                assert_eq!(dfg, "SAD");
                // 63 compute ops cannot fit 9 compute cells: the failure
                // is structural, not congestion
                assert!(!matches!(failure, MapFailure::Congested { .. }), "{failure}");
            }
            _ => panic!("SAD on 5x5 must be infeasible"),
        }
    }

    #[test]
    fn usage_counts_sum_to_node_counts() {
        let (dfgs, full, engine) = setup(&["SOB", "GB"], 8, 8);
        let counts = usage_counts(&dfgs, &full, &engine).unwrap();
        let total: usize =
            counts.iter().map(|c| c.iter().map(|&x| x as usize).sum::<usize>()).sum();
        let expect: usize = dfgs.iter().map(|d| d.num_nodes()).sum();
        assert_eq!(total, expect);
    }
}
