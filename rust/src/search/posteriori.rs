//! Posteriori memory-resource pruning (paper Section IV-E, Table VI).
//!
//! After the search fixes the functional layout, the FIFOs (4 input
//! FIFOs per cell) that no mapping of any input DFG ever uses can be
//! removed without affecting functionality. This module computes the
//! unused-FIFO count and the resulting extra area/power savings.

use super::pareto;
use crate::cgra::Layout;
use crate::cost::CostModel;
use crate::dfg::Dfg;
use crate::mapper::MappingEngine;
use crate::ops::COMPUTE_GROUPS;
use std::collections::HashSet;

/// One objective axis of the theoretical-minimum comparison: the full
/// layout's value, the achieved value, and the floor implied by the
/// per-group minimum instance counts.
#[derive(Debug, Clone, Copy)]
pub struct Gap {
    pub full: f64,
    pub best: f64,
    pub theoretical_min: f64,
}

impl Gap {
    /// Share of the theoretically possible reduction actually achieved
    /// (the paper's Fig 6 metric). 100 when there was nothing to reduce.
    pub fn achieved_pct(&self) -> f64 {
        let possible = self.full - self.theoretical_min;
        if possible <= 0.0 {
            return 100.0;
        }
        100.0 * (self.full - self.best) / possible
    }

    pub fn remaining_pct(&self) -> f64 {
        100.0 - self.achieved_pct()
    }
}

/// Fig 6 generalized to every objective the Pareto mode tracks: op
/// count, area and power, each against its own theoretical minimum.
#[derive(Debug, Clone, Copy)]
pub struct ObjectiveGaps {
    pub ops: Gap,
    pub area: Gap,
    pub power: Gap,
}

/// Per-objective theoretical-minimum gaps of a finished search.
pub fn objective_gaps(r: &super::SearchResult) -> ObjectiveGaps {
    let gap = |m: &CostModel| Gap {
        full: m.layout_cost(&r.full_layout),
        best: m.layout_cost(&r.best_layout),
        theoretical_min: m.theoretical_min_cost(&r.full_layout, &r.min_insts),
    };
    let ops_min: usize = COMPUTE_GROUPS.iter().map(|g| r.min_insts[g.index()]).sum();
    ObjectiveGaps {
        ops: Gap {
            full: r.full_layout.compute_instances() as f64,
            best: r.best_layout.compute_instances() as f64,
            theoretical_min: ops_min as f64,
        },
        area: gap(&CostModel::area()),
        power: gap(&CostModel::power()),
    }
}

/// The op-count-minimal layout of a set, ties broken deterministically
/// by stable layout fingerprint — the selection cannot depend on the
/// order candidates were produced in (e.g. by a parallel front sweep).
pub fn select_min_layout(layouts: &[Layout]) -> Option<&Layout> {
    layouts
        .iter()
        .min_by_key(|l| (l.compute_instances(), pareto::layout_fingerprint(l)))
}

/// Result of the posteriori FIFO analysis.
#[derive(Debug, Clone)]
pub struct FifoReport {
    /// FIFOs never used by any DFG mapping.
    pub unused: usize,
    /// Total FIFOs in the CGRA (4 per cell, I/O cells included, as in
    /// Table VI: a 10×10 has 400).
    pub total: usize,
    /// Additional area improvement over the *full* layout cost, percent.
    pub area_impr_pct: f64,
    /// Additional power improvement over the full layout cost, percent.
    pub power_impr_pct: f64,
}

/// Analyze FIFO usage of `layout` under all DFG mappings.
///
/// `full` is the full homogeneous layout the improvements are reported
/// against (Table VI's %Impr baseline).
pub fn fifo_analysis(
    dfgs: &[Dfg],
    layout: &Layout,
    full: &Layout,
    engine: &MappingEngine,
) -> Option<FifoReport> {
    let mappings = engine.map_all(dfgs, layout).ok()?;
    Some(fifo_analysis_with(&mappings, layout, full))
}

/// FIFO analysis from known witness mappings (preferred: search results
/// carry witnesses, and layouts accepted through the witness fast-path
/// may not re-map heuristically from scratch).
pub fn fifo_analysis_with(
    mappings: &[crate::mapper::Mapping],
    layout: &Layout,
    full: &Layout,
) -> FifoReport {
    let g = &layout.grid;
    let mut used: HashSet<(crate::cgra::CellId, usize)> = HashSet::new();
    for m in mappings {
        used.extend(m.input_ports_used(g));
        // the input ports of cells hosting nodes with inputs are used by
        // definition (they terminate a path), already covered by paths.
    }
    let total = g.num_cells() * 4;
    // ports that exist: only count ports whose link has an in-grid
    // neighbour on the other side (border cells have fewer real ports) —
    // the paper counts 4 per cell uniformly (10x10 -> 400), so we do too.
    let unused = total - used.len();

    let a = CostModel::area();
    let p = CostModel::power();
    // savings: unused FIFO count × per-FIFO cost, relative to the full
    // layout's whole-chip cost (FIFOs span I/O cells too).
    let area_impr_pct = 100.0 * (unused as f64 * a.components.one_fifo()) / a.cost_with_io(full);
    let power_impr_pct =
        100.0 * (unused as f64 * p.components.one_fifo()) / p.cost_with_io(full);
    FifoReport { unused, total, area_impr_pct, power_impr_pct }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::dfg::benchmarks;
    use crate::ops::GroupSet;

    #[test]
    fn fifo_counts_match_grid_size() {
        let dfgs = vec![benchmarks::benchmark("SOB")];
        let l = Layout::full(Grid::new(10, 10), crate::dfg::groups_used(&dfgs));
        let r = fifo_analysis(&dfgs, &l, &l, &MappingEngine::default()).unwrap();
        assert_eq!(r.total, 400); // Table VI: 10x10 -> 400 FIFOs
        assert!(r.unused > 0 && r.unused < r.total);
    }

    #[test]
    fn small_dfg_leaves_most_fifos_unused() {
        let dfgs = vec![benchmarks::benchmark("SOB")]; // 9 nodes
        let l = Layout::full(Grid::new(10, 10), crate::dfg::groups_used(&dfgs));
        let r = fifo_analysis(&dfgs, &l, &l, &MappingEngine::default()).unwrap();
        assert!(r.unused as f64 / r.total as f64 > 0.5);
        assert!(r.area_impr_pct > 0.0);
        assert!(r.power_impr_pct > 0.0);
    }

    #[test]
    fn power_improvement_exceeds_area_improvement() {
        // Table VI shape: FIFO removal helps power more than area
        // (FIFOs carry a larger power share).
        let dfgs = vec![benchmarks::benchmark("GB"), benchmarks::benchmark("SOB")];
        let l = Layout::full(Grid::new(10, 10), crate::dfg::groups_used(&dfgs));
        let r = fifo_analysis(&dfgs, &l, &l, &MappingEngine::default()).unwrap();
        assert!(
            r.power_impr_pct > r.area_impr_pct,
            "power {} <= area {}",
            r.power_impr_pct,
            r.area_impr_pct
        );
    }

    #[test]
    fn infeasible_returns_none() {
        let dfgs = vec![benchmarks::benchmark("SAD")];
        let l = Layout::full(Grid::new(5, 5), GroupSet::all_compute());
        assert!(fifo_analysis(&dfgs, &l, &l, &MappingEngine::default()).is_none());
    }

    #[test]
    fn select_min_layout_is_order_independent() {
        let grid = Grid::new(6, 6);
        let full = Layout::full(grid, GroupSet::all_compute());
        let cells: Vec<_> = grid.compute_cells().collect();
        // two distinct layouts tying on op count, plus a bigger one
        let a = full.without_group(cells[0], crate::ops::OpGroup::Div);
        let b = full.without_group(cells[1], crate::ops::OpGroup::Mult);
        assert_eq!(a.compute_instances(), b.compute_instances());
        assert_ne!(
            crate::search::pareto::layout_fingerprint(&a),
            crate::search::pareto::layout_fingerprint(&b)
        );
        let fwd = select_min_layout(&[full.clone(), a.clone(), b.clone()]).unwrap().clone();
        let rev = select_min_layout(&[b, full.clone(), a]).unwrap().clone();
        assert_eq!(
            crate::search::pareto::layout_fingerprint(&fwd),
            crate::search::pareto::layout_fingerprint(&rev),
            "tie-break must not depend on candidate order"
        );
        assert!(fwd.compute_instances() < full.compute_instances());
        assert!(select_min_layout(&[]).is_none());
    }

    #[test]
    fn objective_gaps_cover_all_three_axes() {
        let dfgs = vec![benchmarks::benchmark("SOB"), benchmarks::benchmark("GB")];
        let engine = MappingEngine::default();
        let cost = CostModel::area();
        let cfg = crate::search::SearchConfig {
            l_test: 80,
            l_fail: 2,
            gsg_passes: 1,
            ..Default::default()
        };
        let r = crate::search::Explorer::new(Grid::new(7, 7))
            .dfgs(&dfgs)
            .engine(&engine)
            .cost(&cost)
            .config(cfg)
            .run()
            .expect("maps");
        let gaps = objective_gaps(&r);
        for (name, gap) in
            [("ops", gaps.ops), ("area", gaps.area), ("power", gaps.power)]
        {
            assert!(gap.best <= gap.full, "{name}: the search never regresses");
            assert!(
                gap.theoretical_min <= gap.best + 1e-9,
                "{name}: the floor bounds every feasible layout"
            );
            assert!(
                (0.0..=100.0).contains(&gap.achieved_pct()),
                "{name}: achieved {} out of range",
                gap.achieved_pct()
            );
            assert!((gap.achieved_pct() + gap.remaining_pct() - 100.0).abs() < 1e-9);
        }
        assert!(gaps.ops.full > gaps.ops.best, "SOB+GB on 7x7 sheds instances");
    }
}
