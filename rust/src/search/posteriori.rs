//! Posteriori memory-resource pruning (paper Section IV-E, Table VI).
//!
//! After the search fixes the functional layout, the FIFOs (4 input
//! FIFOs per cell) that no mapping of any input DFG ever uses can be
//! removed without affecting functionality. This module computes the
//! unused-FIFO count and the resulting extra area/power savings.

use crate::cgra::Layout;
use crate::cost::CostModel;
use crate::dfg::Dfg;
use crate::mapper::MappingEngine;
use std::collections::HashSet;

/// Result of the posteriori FIFO analysis.
#[derive(Debug, Clone)]
pub struct FifoReport {
    /// FIFOs never used by any DFG mapping.
    pub unused: usize,
    /// Total FIFOs in the CGRA (4 per cell, I/O cells included, as in
    /// Table VI: a 10×10 has 400).
    pub total: usize,
    /// Additional area improvement over the *full* layout cost, percent.
    pub area_impr_pct: f64,
    /// Additional power improvement over the full layout cost, percent.
    pub power_impr_pct: f64,
}

/// Analyze FIFO usage of `layout` under all DFG mappings.
///
/// `full` is the full homogeneous layout the improvements are reported
/// against (Table VI's %Impr baseline).
pub fn fifo_analysis(
    dfgs: &[Dfg],
    layout: &Layout,
    full: &Layout,
    engine: &MappingEngine,
) -> Option<FifoReport> {
    let mappings = engine.map_all(dfgs, layout).ok()?;
    Some(fifo_analysis_with(&mappings, layout, full))
}

/// FIFO analysis from known witness mappings (preferred: search results
/// carry witnesses, and layouts accepted through the witness fast-path
/// may not re-map heuristically from scratch).
pub fn fifo_analysis_with(
    mappings: &[crate::mapper::Mapping],
    layout: &Layout,
    full: &Layout,
) -> FifoReport {
    let g = &layout.grid;
    let mut used: HashSet<(crate::cgra::CellId, usize)> = HashSet::new();
    for m in mappings {
        used.extend(m.input_ports_used(g));
        // the input ports of cells hosting nodes with inputs are used by
        // definition (they terminate a path), already covered by paths.
    }
    let total = g.num_cells() * 4;
    // ports that exist: only count ports whose link has an in-grid
    // neighbour on the other side (border cells have fewer real ports) —
    // the paper counts 4 per cell uniformly (10x10 -> 400), so we do too.
    let unused = total - used.len();

    let a = CostModel::area();
    let p = CostModel::power();
    // savings: unused FIFO count × per-FIFO cost, relative to the full
    // layout's whole-chip cost (FIFOs span I/O cells too).
    let area_impr_pct = 100.0 * (unused as f64 * a.components.one_fifo()) / a.cost_with_io(full);
    let power_impr_pct =
        100.0 * (unused as f64 * p.components.one_fifo()) / p.cost_with_io(full);
    FifoReport { unused, total, area_impr_pct, power_impr_pct }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::dfg::benchmarks;
    use crate::ops::GroupSet;

    #[test]
    fn fifo_counts_match_grid_size() {
        let dfgs = vec![benchmarks::benchmark("SOB")];
        let l = Layout::full(Grid::new(10, 10), crate::dfg::groups_used(&dfgs));
        let r = fifo_analysis(&dfgs, &l, &l, &MappingEngine::default()).unwrap();
        assert_eq!(r.total, 400); // Table VI: 10x10 -> 400 FIFOs
        assert!(r.unused > 0 && r.unused < r.total);
    }

    #[test]
    fn small_dfg_leaves_most_fifos_unused() {
        let dfgs = vec![benchmarks::benchmark("SOB")]; // 9 nodes
        let l = Layout::full(Grid::new(10, 10), crate::dfg::groups_used(&dfgs));
        let r = fifo_analysis(&dfgs, &l, &l, &MappingEngine::default()).unwrap();
        assert!(r.unused as f64 / r.total as f64 > 0.5);
        assert!(r.area_impr_pct > 0.0);
        assert!(r.power_impr_pct > 0.0);
    }

    #[test]
    fn power_improvement_exceeds_area_improvement() {
        // Table VI shape: FIFO removal helps power more than area
        // (FIFOs carry a larger power share).
        let dfgs = vec![benchmarks::benchmark("GB"), benchmarks::benchmark("SOB")];
        let l = Layout::full(Grid::new(10, 10), crate::dfg::groups_used(&dfgs));
        let r = fifo_analysis(&dfgs, &l, &l, &MappingEngine::default()).unwrap();
        assert!(
            r.power_impr_pct > r.area_impr_pct,
            "power {} <= area {}",
            r.power_impr_pct,
            r.area_impr_pct
        );
    }

    #[test]
    fn infeasible_returns_none() {
        let dfgs = vec![benchmarks::benchmark("SAD")];
        let l = Layout::full(Grid::new(5, 5), GroupSet::all_compute());
        assert!(fifo_analysis(&dfgs, &l, &l, &MappingEngine::default()).is_none());
    }
}
