//! Deterministic parallel candidate testing: the scoped worker pool
//! behind OPSG's queue fills and GSG's frontier batches.
//!
//! ## The deterministic-reduction contract
//!
//! Candidates inside one branching step are *independent* mapping
//! problems, so they can be feasibility-tested concurrently — but the
//! search result must be a pure function of the inputs, never of the
//! thread count or scheduling. Three rules make that hold:
//!
//! 1. **Pure tests.** A candidate test depends only on the DFG set, the
//!    witness snapshot taken at the start of the branching step, the
//!    candidate layout, and the engine configuration. Worker engines are
//!    [forked](crate::mapper::MappingEngine::fork) with the feasibility
//!    cache *disabled*: a cache hit could replay a mapping computed from
//!    an older witness, which would make the returned witness depend on
//!    which worker (and how many) had tested which layout before. The
//!    fork also hands each worker a **fresh router arena**
//!    ([`crate::mapper::route::RouterArena`], cloned via the engine's
//!    routing strategy): router scratch is never shared across threads,
//!    so a routing call's output depends only on its arguments — pure by
//!    construction, whichever router
//!    ([legacy or Steiner](crate::mapper::route)) the config selects.
//! 2. **Speculative prefetch, authoritative reduction.** Workers test
//!    candidates speculatively ([`TestPool::prefetch`]); the reduction
//!    then walks the batch in the original *branching order* and
//!    consumes results exactly as the serial algorithm would — the
//!    winner is the first feasible candidate in branching order, and a
//!    result the reduction needs but the prefetch skipped is recomputed
//!    on the spot ([`TestPool::test_one`]; identical by rule 1).
//!    Speculative tests that lose the race are folded into
//!    `SearchStats::speculative` but can never change the result.
//! 3. **Ordered state merges.** All search-state mutation (witness
//!    updates, OPSG's `failed` set, GSG's `failChart`, pruning, events)
//!    happens on the reduction thread, in branching order — so pruning
//!    decisions and the recorded [`super::SearchEvent`] trace are
//!    byte-identical at any `SearchConfig::search_threads`.
//!
//! A single-threaded pool skips the prefetch entirely: the reduction's
//! demand path then computes exactly the tests a serial run would, in
//! the same order, through the same code.
//!
//! The contract is observable from the outside: the same exploration run
//! at different `search_threads` widths returns identical results.
//!
//! ```
//! use helex::cgra::Grid;
//! use helex::dfg::Dfg;
//! use helex::ops::Op;
//! use helex::search::{Explorer, SearchConfig};
//!
//! let dfgs = vec![Dfg::new(
//!     "pipe",
//!     vec![Op::Load, Op::Add, Op::Store],
//!     vec![(0, 1), (1, 2)],
//! )];
//! let run = |threads: usize| {
//!     let cfg = SearchConfig { l_test: 40, search_threads: threads, ..Default::default() };
//!     Explorer::new(Grid::new(6, 6)).dfgs(&dfgs).config(cfg).run().expect("maps")
//! };
//! let (serial, parallel) = (run(1), run(4));
//! assert_eq!(serial.best_cost, parallel.best_cost);
//! assert_eq!(serial.stats.tested, parallel.stats.tested);
//! ```

use crate::cgra::Layout;
use crate::dfg::Dfg;
use crate::mapper::{MapOutcome, Mapping, MappingEngine};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Shared-read snapshot of the search state one branching step tests
/// against (the read-only half of the old monolithic `SearchCtx` view;
/// the per-worker scratch is the pool's forked engines).
pub struct SharedState<'a> {
    pub dfgs: &'a [Dfg],
    /// Witness cache snapshot: fixed for the whole branching step, only
    /// merged (by the reduction, in branching order) once a winner is
    /// accepted.
    pub witness: &'a [Option<Mapping>],
    /// DFG indices each candidate must be checked against (OPSG's
    /// selective testing passes the users of the removed group; GSG
    /// passes every index).
    pub affected: &'a [usize],
}

/// The outcome of feasibility-testing one candidate layout.
pub struct CandidateTest {
    pub feasible: bool,
    /// Fresh mappings for the DFGs that needed re-mapping, in `affected`
    /// order. Consumed as new witnesses only if this candidate wins.
    pub witnesses: Vec<(usize, Mapping)>,
    /// Which worker ran the test. Diagnostic only: it rides on
    /// [`super::SearchEvent::LayoutTested`] but is stripped from wire
    /// records and byte-compared traces (it legitimately varies with
    /// thread count and timing).
    pub worker: usize,
}

/// Pure candidate test: a DFG is feasible on `layout` if its witness is
/// still valid there, or if the engine re-maps it (warm-started from the
/// witness). Short-circuits on the first failing DFG, exactly like the
/// serial loops did.
fn test_candidate(
    engine: &MappingEngine,
    shared: &SharedState<'_>,
    layout: &Layout,
    worker: usize,
) -> CandidateTest {
    let mut witnesses = Vec::new();
    for &di in shared.affected {
        let dfg = &shared.dfgs[di];
        let outcome = match &shared.witness[di] {
            Some(w) if w.still_valid(dfg, layout) => continue,
            Some(w) => engine.remap_from(w, dfg, layout),
            None => engine.map(dfg, layout),
        };
        match outcome {
            MapOutcome::Mapped { mapping, .. } => witnesses.push((di, mapping)),
            MapOutcome::Failed { .. } => {
                return CandidateTest { feasible: false, witnesses, worker };
            }
        }
    }
    CandidateTest { feasible: true, witnesses, worker }
}

/// The scoped worker pool of one search phase: `search_threads` forked
/// engines plus the prefetch/reduce drivers. See the module docs for the
/// determinism contract.
pub struct TestPool {
    engines: Vec<MappingEngine>,
}

impl TestPool {
    /// Fork `threads` worker engines off the session's shared engine.
    /// The forks disable the feasibility cache — see the module docs
    /// (rule 1) for why caching here would break reproducibility.
    pub fn for_search(engine: &MappingEngine, threads: usize) -> Self {
        let threads = threads.max(1);
        let engines = (0..threads)
            .map(|_| {
                let mut e = engine.fork();
                e.cfg.feasibility_cache = false;
                e
            })
            .collect();
        Self { engines }
    }

    pub fn threads(&self) -> usize {
        self.engines.len()
    }

    /// Authoritative test on the reduction thread (the demand path; also
    /// the only path a 1-thread pool ever takes).
    pub fn test_one(&self, shared: &SharedState<'_>, layout: &Layout) -> CandidateTest {
        test_candidate(&self.engines[0], shared, layout, 0)
    }

    /// Speculatively test `candidates` in parallel. Entries flagged
    /// `true` are skipped (the caller knows their result cannot be
    /// consumed — e.g. GSG's failChart-pruned pops). Workers pull
    /// indices in branching order and stop testing past the lowest
    /// feasible index seen so far: everything after the winner is
    /// discarded by the reduction anyway, so racing past it is pure
    /// waste. Returns one slot per candidate; `None` means "not tested
    /// here" and the reduction recomputes it on demand if it turns out
    /// to be needed.
    pub fn prefetch(
        &mut self,
        shared: &SharedState<'_>,
        candidates: &[(&Layout, bool)],
    ) -> Vec<Option<CandidateTest>> {
        let n = candidates.len();
        let mut out: Vec<Option<CandidateTest>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let testable = candidates.iter().filter(|c| !c.1).count();
        if self.engines.len() < 2 || testable < 2 {
            return out; // nothing to gain: let the demand path run serially
        }
        let next = AtomicUsize::new(0);
        let winner = AtomicUsize::new(usize::MAX);
        let (tx, rx) = mpsc::channel::<(usize, CandidateTest)>();
        std::thread::scope(|scope| {
            for (w, engine) in self.engines.iter_mut().enumerate() {
                let tx = tx.clone();
                let (next, winner) = (&next, &winner);
                scope.spawn(move || {
                    let engine: &MappingEngine = engine;
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let (layout, skip) = candidates[i];
                        if skip || i > winner.load(Ordering::Relaxed) {
                            continue;
                        }
                        let t = test_candidate(engine, shared, layout, w);
                        if t.feasible {
                            winner.fetch_min(i, Ordering::Relaxed);
                        }
                        if tx.send((i, t)).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(tx);
            for (i, t) in rx {
                out[i] = Some(t);
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::dfg::benchmarks;
    use crate::ops::OpGroup;

    fn shared_fixture() -> (Vec<Dfg>, Layout, Vec<Option<Mapping>>, Vec<usize>) {
        let dfgs = vec![benchmarks::benchmark("SOB"), benchmarks::benchmark("GB")];
        let full = Layout::full(Grid::new(7, 7), crate::dfg::groups_used(&dfgs));
        let engine = MappingEngine::default();
        let witness: Vec<Option<Mapping>> = engine
            .map_all(&dfgs, &full)
            .expect("SOB+GB map on 7x7")
            .into_iter()
            .map(Some)
            .collect();
        let affected: Vec<usize> = (0..dfgs.len()).collect();
        (dfgs, full, witness, affected)
    }

    #[test]
    fn pool_forks_cache_free_engines() {
        let engine = MappingEngine::default();
        assert!(engine.cfg.feasibility_cache);
        let pool = TestPool::for_search(&engine, 4);
        assert_eq!(pool.threads(), 4);
        let zero = TestPool::for_search(&engine, 0);
        assert_eq!(zero.threads(), 1, "a pool always has at least one engine");
    }

    #[test]
    fn prefetch_agrees_with_demand_path() {
        // every prefetched verdict (and witness placement) must equal
        // what the reduction-thread demand path computes: the purity that
        // the deterministic reduction relies on
        let (dfgs, full, witness, affected) = shared_fixture();
        let engine = MappingEngine::default();
        let shared = SharedState { dfgs: &dfgs, witness: &witness, affected: &affected };
        let candidates: Vec<Layout> = full
            .grid
            .compute_cells()
            .take(8)
            .map(|c| full.without_group(c, OpGroup::Arith))
            .collect();
        // purity: repeated demand-path tests are bit-identical
        let mut pool = TestPool::for_search(&engine, 4);
        for layout in &candidates {
            let a = pool.test_one(&shared, layout);
            let b = pool.test_one(&shared, layout);
            assert_eq!(a.feasible, b.feasible);
            assert_eq!(a.witnesses.len(), b.witnesses.len());
            for ((di_a, m_a), (di_b, m_b)) in a.witnesses.iter().zip(&b.witnesses) {
                assert_eq!(di_a, di_b);
                assert_eq!(m_a.node_cell, m_b.node_cell);
                assert_eq!(m_a.edge_paths, m_b.edge_paths);
            }
        }
        // and the parallel prefetch returns the same verdicts
        let items: Vec<(&Layout, bool)> = candidates.iter().map(|l| (l, false)).collect();
        let prefetched = pool.prefetch(&shared, &items);
        for (i, slot) in prefetched.iter().enumerate() {
            if let Some(t) = slot {
                let direct = pool.test_one(&shared, &candidates[i]);
                assert_eq!(t.feasible, direct.feasible, "candidate {i}");
                assert_eq!(t.witnesses.len(), direct.witnesses.len(), "candidate {i}");
            }
        }
    }

    #[test]
    fn prefetch_skips_flagged_candidates() {
        let (dfgs, full, witness, affected) = shared_fixture();
        let engine = MappingEngine::default();
        let shared = SharedState { dfgs: &dfgs, witness: &witness, affected: &affected };
        let candidates: Vec<Layout> = full
            .grid
            .compute_cells()
            .take(4)
            .map(|c| full.without_group(c, OpGroup::Arith))
            .collect();
        let items: Vec<(&Layout, bool)> =
            candidates.iter().enumerate().map(|(i, l)| (l, i % 2 == 0)).collect();
        let mut pool = TestPool::for_search(&engine, 2);
        let prefetched = pool.prefetch(&shared, &items);
        for (i, slot) in prefetched.iter().enumerate() {
            if i % 2 == 0 {
                assert!(slot.is_none(), "flagged candidate {i} must not be tested");
            }
        }
    }
}
