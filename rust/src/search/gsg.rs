//! General subproblem generation (paper Algorithm 3).
//!
//! Removes *any combination* of operation groups from a single cell, in
//! no particular order. Differences from OPSG: the loop does not stop at
//! the first improvement; candidates must be tested against the *entire*
//! DFG set (layouts in the queue descend from different bases, so
//! selective testing is unsound); and a `failChart` counts how often a
//! particular `(removed-combination, cell)` pair has failed, pruning
//! pairs that failed `L_fail` times. Successful improvements reset the
//! failChart and expand new subproblems from the improved layout. The
//! queue is additionally pruned of subproblems too far from the best
//! cost after prolonged non-improvement (Section III-F2 last paragraph).

use super::{SearchCtx, SearchEvent};
use crate::cgra::{CellId, Layout};
use crate::ops::{GroupSet, NUM_GROUPS};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap, HashSet};

/// A queued subproblem: a layout plus the (cell, removed-mask) metadata
/// that produced it.
struct Cand {
    cost: f64,
    layout: Layout,
    cell: CellId,
    removed: GroupSet,
}

impl PartialEq for Cand {
    fn eq(&self, other: &Self) -> bool {
        self.cost == other.cost
    }
}
impl Eq for Cand {}
impl Ord for Cand {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by cost; deterministic tie-break
        other
            .cost
            .partial_cmp(&self.cost)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.cell.cmp(&self.cell))
            .then_with(|| other.removed.0.cmp(&self.removed.0))
    }
}
impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Enumerate all non-empty removal masks of a cell's support set.
fn removal_masks(support: GroupSet) -> Vec<GroupSet> {
    let bits: Vec<u8> = support.iter().map(|g| 1u8 << g.index()).collect();
    let mut out = Vec::new();
    for m in 1u32..(1 << bits.len()) {
        let mut mask = 0u8;
        for (i, b) in bits.iter().enumerate() {
            if m & (1 << i) != 0 {
                mask |= b;
            }
        }
        out.push(GroupSet(mask));
    }
    out
}

/// Generate all valid GSG subproblems from `base` (Algorithm 3 line 3 /
/// line 17), pushing into `pq`. Batch-scores candidate costs through the
/// context's scorer when one is attached.
fn expand(
    base: &Layout,
    fail_chart: &HashMap<(u8, CellId), usize>,
    seen: &mut HashSet<u64>,
    pq: &mut BinaryHeap<Cand>,
    ctx: &mut SearchCtx,
) {
    let cost = ctx.cost;
    let min_insts = ctx.min_insts;
    let l_fail = ctx.cfg.l_fail;
    let base_insts = base.compute_group_instances();
    let base_cost = cost.layout_cost(base);
    let mut metas: Vec<(CellId, GroupSet)> = Vec::new();
    let mut vectors: Vec<[usize; NUM_GROUPS]> = Vec::new();
    for cell in base.grid.compute_cells() {
        let support = base.support(cell);
        if support.is_empty() {
            continue;
        }
        for mask in removal_masks(support) {
            // failChart pruning at generation time (cheap) — the pop-time
            // check (Algorithm 3 line 8) is retained as well.
            if *fail_chart.get(&(mask.0, cell)).unwrap_or(&0) >= l_fail {
                continue;
            }
            // min-instances validity
            let mut v = base_insts;
            let mut ok = true;
            for g in mask.iter() {
                if v[g.index()] == 0 || v[g.index()] - 1 < min_insts[g.index()] {
                    ok = false;
                    break;
                }
                v[g.index()] -= 1;
            }
            if !ok {
                continue;
            }
            metas.push((cell, mask));
            vectors.push(v);
        }
    }
    ctx.stats.expanded += metas.len();
    // candidate costs, batched through the XLA artifact when available
    let costs: Vec<f64> = if let Some(s) = ctx.scorer.as_deref_mut() {
        s.score(base.grid.num_compute(), &vectors)
    } else {
        metas
            .iter()
            .map(|(_, mask)| {
                base_cost + mask.iter().map(|g| cost.removal_delta(g)).sum::<f64>()
            })
            .collect()
    };
    for (((cell, mask), _v), c) in metas.into_iter().zip(vectors).zip(costs) {
        let layout = base.without_groups(cell, mask);
        // dedupe layouts reachable through multiple removal orders
        let h = layout_hash(&layout);
        if !seen.insert(h) {
            continue;
        }
        pq.push(Cand { cost: c, layout, cell, removed: mask });
    }
}

fn layout_hash(l: &Layout) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    l.hash(&mut h);
    h.finish()
}

/// Algorithm 3. Returns the best layout found; all shared search state
/// — stats, scorer, the witness cache shared with OPSG (a cached mapping
/// whose placements the candidate layout still supports proves
/// feasibility without re-mapping, see `Mapping::still_valid`;
/// EXPERIMENTS.md §Perf) — lives in the [`SearchCtx`]. DFGs whose
/// witness went stale are remapped through [`SearchCtx::test_dfg`],
/// which warm-starts the engine from the witness.
pub fn run(initial: &Layout, ctx: &mut SearchCtx) -> Layout {
    let dfgs = ctx.dfgs;
    let cost = ctx.cost;
    let cfg = ctx.cfg.clone();
    let mut best = initial.clone();
    let mut best_cost = cost.layout_cost(&best);
    let mut fail_chart: HashMap<(u8, CellId), usize> = HashMap::new();
    let mut seen: HashSet<u64> = HashSet::new();
    let mut pq: BinaryHeap<Cand> = BinaryHeap::new();
    expand(&best, &fail_chart, &mut seen, &mut pq, ctx);
    let mut stale = 0usize;

    while let Some(cand) = pq.pop() {
        if ctx.stats.tested >= cfg.l_test {
            break;
        }
        if cand.cost >= best_cost {
            continue;
        }
        // failChart pruning (line 8)
        let key = (cand.removed.0, cand.cell);
        if *fail_chart.get(&key).unwrap_or(&0) >= cfg.l_fail {
            continue;
        }
        // full-set testing (line 9), with witness fast-path and
        // warm-start remapping for stale witnesses
        ctx.stats.tested += 1;
        let mut succ = true;
        let mut new_witnesses: Vec<(usize, crate::mapper::Mapping)> = Vec::new();
        for (di, d) in dfgs.iter().enumerate() {
            let valid = ctx.witness[di]
                .as_ref()
                .map_or(false, |w| w.still_valid(d, &cand.layout));
            if valid {
                continue;
            }
            match ctx.test_dfg(di, &cand.layout) {
                crate::mapper::MapOutcome::Mapped { mapping, .. } => {
                    new_witnesses.push((di, mapping))
                }
                crate::mapper::MapOutcome::Failed { .. } => {
                    succ = false;
                    break;
                }
            }
        }
        ctx.emit(SearchEvent::LayoutTested {
            feasible: succ,
            cost: cand.cost,
            tested: ctx.stats.tested,
        });
        if succ {
            for (di, m) in new_witnesses {
                ctx.witness[di] = Some(m);
            }
            fail_chart.clear(); // line 12
            best = cand.layout;
            best_cost = cand.cost;
            stale = 0;
            ctx.emit_improved(best_cost);
            // line 17: expand subproblems from the improved layout
            expand(&best, &fail_chart, &mut seen, &mut pq, ctx);
        } else {
            *fail_chart.entry(key).or_insert(0) += 1; // line 15
            stale += 1;
            if stale >= cfg.gsg_stale_prune_after {
                // prune subproblems too far in cost from the best layout
                let keep: Vec<Cand> =
                    pq.drain().filter(|c| c.cost < best_cost).collect();
                pq.extend(keep);
                stale = 0;
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::cost::CostModel;
    use crate::dfg::{benchmarks, Dfg};
    use crate::mapper::MappingEngine;
    use crate::ops::OpGroup;
    use crate::search::SearchConfig;

    fn ctx<'a>(
        dfgs: &'a [Dfg],
        engine: &'a MappingEngine,
        cost: &'a CostModel,
        cfg: SearchConfig,
    ) -> SearchCtx<'a> {
        let mins = crate::dfg::min_group_instances(dfgs);
        SearchCtx::new(dfgs, engine, cost, mins, cfg)
    }

    #[test]
    fn removal_masks_enumerate_powerset() {
        let s = GroupSet::from_groups(&[OpGroup::Arith, OpGroup::Mult, OpGroup::Div]);
        let masks = removal_masks(s);
        assert_eq!(masks.len(), 7); // 2^3 - 1
        for m in &masks {
            assert!(m.is_subset_of(s));
            assert!(!m.is_empty());
        }
        // all distinct
        let mut raw: Vec<u8> = masks.iter().map(|m| m.0).collect();
        raw.sort_unstable();
        raw.dedup();
        assert_eq!(raw.len(), 7);
    }

    #[test]
    fn gsg_improves_on_arith_only_workload() {
        // Section IV-G: GSG matters most when only cheap groups remain.
        let dfgs = vec![benchmarks::benchmark("SOB"), benchmarks::benchmark("GB")];
        let full = Layout::full(Grid::new(7, 7), crate::dfg::groups_used(&dfgs));
        let engine = MappingEngine::default();
        let cost = CostModel::area();
        let cfg = SearchConfig { l_test: 200, l_fail: 2, ..Default::default() };
        let mut c = ctx(&dfgs, &engine, &cost, cfg);
        let best = run(&full, &mut c);
        assert!(cost.layout_cost(&best) < cost.layout_cost(&full));
        // feasibility is witness-proven: every accepted candidate either
        // kept a valid witness or produced a fresh mapping for it
        for (di, d) in dfgs.iter().enumerate() {
            match &c.witness[di] {
                Some(w) => assert!(w.validate(d, &best).is_empty(), "{}", d.name),
                None => assert!(c.engine.map(d, &best).is_mapped(), "{}", d.name),
            }
        }
        assert!(crate::search::meets_min_instances(&best, &c.min_insts));
    }

    #[test]
    fn gsg_respects_budget_and_failchart() {
        let dfgs = vec![benchmarks::benchmark("SOB")];
        let full = Layout::full(Grid::new(6, 6), crate::dfg::groups_used(&dfgs));
        let engine = MappingEngine::default();
        let cost = CostModel::area();
        let cfg = SearchConfig { l_test: 10, l_fail: 1, ..Default::default() };
        let mut c = ctx(&dfgs, &engine, &cost, cfg);
        let _ = run(&full, &mut c);
        assert!(c.stats.tested <= 10);
    }

    #[test]
    fn empty_support_cells_are_skipped() {
        let grid = Grid::new(5, 5);
        let l = Layout::empty(grid);
        let mut pq = BinaryHeap::new();
        let mut seen = HashSet::new();
        let dfgs: Vec<Dfg> = Vec::new();
        let engine = MappingEngine::default();
        let cost = CostModel::area();
        let mut c = SearchCtx::new(
            &dfgs,
            &engine,
            &cost,
            [0; NUM_GROUPS],
            SearchConfig { l_fail: 3, ..Default::default() },
        );
        expand(&l, &HashMap::new(), &mut seen, &mut pq, &mut c);
        assert!(pq.is_empty());
    }
}
