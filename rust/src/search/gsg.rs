//! General subproblem generation (paper Algorithm 3).
//!
//! Removes *any combination* of operation groups from a single cell, in
//! no particular order. Differences from OPSG: the loop does not stop at
//! the first improvement; candidates must be tested against the *entire*
//! DFG set (layouts in the queue descend from different bases, so
//! selective testing is unsound); and a `failChart` counts how often a
//! particular `(removed-combination, cell)` pair has failed, pruning
//! pairs that failed `L_fail` times. Successful improvements reset the
//! failChart and expand new subproblems from the improved layout. The
//! queue is additionally pruned of subproblems too far from the best
//! cost after prolonged non-improvement (Section III-F2 last paragraph).
//!
//! Frontier slices are feasibility-tested on the
//! [`super::parallel::TestPool`]: the next batch of queue pops is
//! prefetched speculatively, then consumed by the deterministic
//! reduction in pop order — failChart increments, stale-pruning and the
//! winner choice all happen in that order, and candidates after the
//! winner go back to the queue untouched. The [`Cand`] ordering is a
//! *total* order (a generation sequence number breaks every tie), so
//! re-pushed candidates pop exactly where a serial run would have
//! popped them; pruning is therefore reproducible at any thread count.

use super::parallel::{CandidateTest, SharedState, TestPool};
use super::{SearchCtx, SearchEvent};
use crate::cgra::{CellId, Layout};
use crate::ops::{GroupSet, NUM_GROUPS};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};

/// A queued subproblem: a layout plus the (cell, removed-mask) metadata
/// that produced it.
struct Cand {
    cost: f64,
    layout: Layout,
    cell: CellId,
    removed: GroupSet,
    /// Global generation sequence number, the final `Ord` tie-break:
    /// makes the ordering total, so the pop order is a property of the
    /// queue's *contents* (not of heap internals or insertion history)
    /// and candidates re-pushed after a speculative batch pop exactly
    /// where a serial run would have popped them.
    seq: u64,
}

impl PartialEq for Cand {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Cand {}
impl Ord for Cand {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by cost; fully deterministic total order
        other
            .cost
            .partial_cmp(&self.cost)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.cell.cmp(&self.cell))
            .then_with(|| other.removed.0.cmp(&self.removed.0))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Exact-dedup memory of expanded layouts, keyed by [`layout_hash`] but
/// collision-safe: layouts sharing a hash live in one bucket where an
/// exact comparison tells them apart, so a hash collision degrades to a
/// (harmless) duplicate test of nothing — a genuinely new layout is
/// *never* wrongly pruned, it is admitted and re-tested.
///
/// Behaviorally this is `HashSet<Layout>` (which also resolves
/// collisions by `Eq`); it exists as a separate type for the injectable
/// hash function, without which the collision path could never be
/// exercised by a test — `with_hash` is what lets
/// `seen_set_collision_degrades_to_retest_never_wrong_prune` force one.
struct SeenSet {
    hash: fn(&Layout) -> u64,
    buckets: HashMap<u64, Vec<Layout>>,
}

impl SeenSet {
    fn new() -> Self {
        Self::with_hash(layout_hash)
    }

    /// Seam for the collision tests: force collisions with a degenerate
    /// hash and observe that dedup still compares exactly.
    fn with_hash(hash: fn(&Layout) -> u64) -> Self {
        Self { hash, buckets: HashMap::new() }
    }

    /// True when `l` was not seen before (and is now recorded).
    fn insert(&mut self, l: &Layout) -> bool {
        let bucket = self.buckets.entry((self.hash)(l)).or_default();
        if bucket.iter().any(|seen| seen == l) {
            return false;
        }
        bucket.push(l.clone());
        true
    }
}

/// Enumerate all non-empty removal masks of a cell's support set.
fn removal_masks(support: GroupSet) -> Vec<GroupSet> {
    let bits: Vec<u8> = support.iter().map(|g| 1u8 << g.index()).collect();
    let mut out = Vec::new();
    for m in 1u32..(1 << bits.len()) {
        let mut mask = 0u8;
        for (i, b) in bits.iter().enumerate() {
            if m & (1 << i) != 0 {
                mask |= b;
            }
        }
        out.push(GroupSet(mask));
    }
    out
}

/// Generate all valid GSG subproblems from `base` (Algorithm 3 line 3 /
/// line 17), pushing into `pq`. Batch-scores candidate costs through the
/// context's scorer when one is attached.
fn expand(
    base: &Layout,
    fail_chart: &HashMap<(u8, CellId), usize>,
    seen: &mut SeenSet,
    pq: &mut BinaryHeap<Cand>,
    seq: &mut u64,
    ctx: &mut SearchCtx,
) {
    let cost = ctx.cost;
    let min_insts = ctx.min_insts;
    let l_fail = ctx.cfg.l_fail;
    let base_insts = base.compute_group_instances();
    let base_cost = cost.layout_cost(base);
    let mut metas: Vec<(CellId, GroupSet)> = Vec::new();
    let mut vectors: Vec<[usize; NUM_GROUPS]> = Vec::new();
    for cell in base.grid.compute_cells() {
        let support = base.support(cell);
        if support.is_empty() {
            continue;
        }
        for mask in removal_masks(support) {
            // failChart pruning at generation time (cheap) — the pop-time
            // check (Algorithm 3 line 8) is retained as well.
            if *fail_chart.get(&(mask.0, cell)).unwrap_or(&0) >= l_fail {
                continue;
            }
            // min-instances validity
            let mut v = base_insts;
            let mut ok = true;
            for g in mask.iter() {
                if v[g.index()] == 0 || v[g.index()] - 1 < min_insts[g.index()] {
                    ok = false;
                    break;
                }
                v[g.index()] -= 1;
            }
            if !ok {
                continue;
            }
            metas.push((cell, mask));
            vectors.push(v);
        }
    }
    ctx.stats.expanded += metas.len();
    // candidate costs, batched through the XLA artifact when available
    let costs: Vec<f64> = if let Some(s) = ctx.scorer.as_deref_mut() {
        s.score(base.grid.num_compute(), &vectors)
    } else {
        metas
            .iter()
            .map(|(_, mask)| {
                base_cost + mask.iter().map(|g| cost.removal_delta(g)).sum::<f64>()
            })
            .collect()
    };
    for (((cell, mask), _v), c) in metas.into_iter().zip(vectors).zip(costs) {
        let layout = base.without_groups(cell, mask);
        // dedupe layouts reachable through multiple removal orders
        // (exact compare under the hash, so collisions cannot prune)
        if !seen.insert(&layout) {
            continue;
        }
        *seq += 1;
        pq.push(Cand { cost: c, layout, cell, removed: mask, seq: *seq });
    }
}

fn layout_hash(l: &Layout) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    l.hash(&mut h);
    h.finish()
}

/// Algorithm 3. Returns the best layout found; all shared search state
/// — stats, scorer, the witness cache shared with OPSG (a cached mapping
/// whose placements the candidate layout still supports proves
/// feasibility without re-mapping, see `Mapping::still_valid`;
/// EXPERIMENTS.md §Perf) — lives in the [`SearchCtx`]. DFGs whose
/// witness went stale are remapped warm from the witness on the
/// [`TestPool`]'s forked engines.
///
/// The loop pops the frontier in *batches*: every pop-time skip that is
/// stable under future state (a candidate at or above the incumbent
/// cost stays unviable, because the incumbent only improves) is applied
/// while building the batch; failChart skips are merely *flagged*,
/// because the chart resets on success — their fate is decided by the
/// reduction, in pop order, against the failChart state a serial run
/// would have seen at that point. Candidates after the winner are
/// re-pushed untouched.
pub fn run(initial: &Layout, ctx: &mut SearchCtx) -> Layout {
    let dfgs = ctx.dfgs;
    let cost = ctx.cost;
    let cfg = ctx.cfg.clone();
    let mut pool = TestPool::for_search(ctx.engine, cfg.search_threads_resolved());
    // witness snapshot moves out of the ctx for the phase (merged back
    // at the end); candidate tests read it through the shared state
    let mut witness = std::mem::take(&mut ctx.witness);
    let all_dfgs: Vec<usize> = (0..dfgs.len()).collect();
    let mut best = initial.clone();
    let mut best_cost = cost.layout_cost(&best);
    let mut fail_chart: HashMap<(u8, CellId), usize> = HashMap::new();
    let mut seen = SeenSet::new();
    let mut pq: BinaryHeap<Cand> = BinaryHeap::new();
    let mut seq = 0u64;
    expand(&best, &fail_chart, &mut seen, &mut pq, &mut seq, ctx);
    let mut stale = 0usize;

    loop {
        if ctx.stats.tested >= cfg.l_test {
            break;
        }
        // ---- batch build: the next frontier slice, in pop order
        let budget = cfg.l_test - ctx.stats.tested;
        let cap = (pool.threads() * 2).max(2).min(budget);
        let mut batch: Vec<(Cand, bool)> = Vec::new();
        let mut testable = 0usize;
        while testable < cap {
            let Some(c) = pq.pop() else { break };
            if c.cost >= best_cost {
                continue; // permanent skip: best_cost only decreases
            }
            let flagged =
                *fail_chart.get(&(c.removed.0, c.cell)).unwrap_or(&0) >= cfg.l_fail;
            if !flagged {
                testable += 1;
            }
            batch.push((c, flagged));
        }
        if batch.is_empty() {
            break; // frontier exhausted
        }

        // ---- speculative prefetch + deterministic reduction
        let mut winner: Option<(usize, CandidateTest)> = None;
        {
            let shared = SharedState { dfgs, witness: &witness, affected: &all_dfgs };
            let items: Vec<(&Layout, bool)> =
                batch.iter().map(|(c, flagged)| (&c.layout, *flagged)).collect();
            let mut prefetched = pool.prefetch(&shared, &items);
            for (i, (cand, _)) in batch.iter().enumerate() {
                if winner.is_some() {
                    break; // the rest of the batch is unconsumed
                }
                // failChart pruning (line 8), against the chart state a
                // serial run would have at this pop
                let key = (cand.removed.0, cand.cell);
                if *fail_chart.get(&key).unwrap_or(&0) >= cfg.l_fail {
                    continue; // discarded, exactly like a serial pop
                }
                // full-set testing (line 9), witness fast-path inside
                let t = match prefetched[i].take() {
                    Some(t) => t,
                    None => pool.test_one(&shared, &cand.layout),
                };
                ctx.stats.tested += 1;
                ctx.emit(SearchEvent::LayoutTested {
                    feasible: t.feasible,
                    cost: cand.cost,
                    tested: ctx.stats.tested,
                    worker: t.worker,
                });
                if t.feasible {
                    winner = Some((i, t));
                } else {
                    *fail_chart.entry(key).or_insert(0) += 1; // line 15
                    stale += 1;
                    if stale >= cfg.gsg_stale_prune_after {
                        // prune subproblems too far in cost from best
                        let keep: Vec<Cand> =
                            pq.drain().filter(|c| c.cost < best_cost).collect();
                        pq.extend(keep);
                        stale = 0;
                    }
                }
            }
            ctx.stats.speculative +=
                prefetched.iter().filter(|o| o.is_some()).count();
        }

        if let Some((w, t)) = winner {
            let mut rest = batch.into_iter();
            let (win, _) = rest.nth(w).expect("winner index is in the batch");
            // candidates after the winner were never consumed: back to
            // the frontier, exactly where a serial run would have left
            // them (the total Cand order makes re-push order-invisible)
            for (cand, _) in rest {
                pq.push(cand);
            }
            for (di, m) in t.witnesses {
                witness[di] = Some(m);
            }
            fail_chart.clear(); // line 12
            best = win.layout;
            best_cost = win.cost;
            stale = 0;
            ctx.emit_improved(best_cost);
            // line 17: expand subproblems from the improved layout
            expand(&best, &fail_chart, &mut seen, &mut pq, &mut seq, ctx);
        }
    }
    ctx.witness = witness;
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::cost::CostModel;
    use crate::dfg::{benchmarks, Dfg};
    use crate::mapper::MappingEngine;
    use crate::ops::OpGroup;
    use crate::search::SearchConfig;

    fn ctx<'a>(
        dfgs: &'a [Dfg],
        engine: &'a MappingEngine,
        cost: &'a CostModel,
        cfg: SearchConfig,
    ) -> SearchCtx<'a> {
        let mins = crate::dfg::min_group_instances(dfgs);
        SearchCtx::new(dfgs, engine, cost, mins, cfg)
    }

    #[test]
    fn removal_masks_enumerate_powerset() {
        let s = GroupSet::from_groups(&[OpGroup::Arith, OpGroup::Mult, OpGroup::Div]);
        let masks = removal_masks(s);
        assert_eq!(masks.len(), 7); // 2^3 - 1
        for m in &masks {
            assert!(m.is_subset_of(s));
            assert!(!m.is_empty());
        }
        // all distinct
        let mut raw: Vec<u8> = masks.iter().map(|m| m.0).collect();
        raw.sort_unstable();
        raw.dedup();
        assert_eq!(raw.len(), 7);
    }

    #[test]
    fn gsg_improves_on_arith_only_workload() {
        // Section IV-G: GSG matters most when only cheap groups remain.
        let dfgs = vec![benchmarks::benchmark("SOB"), benchmarks::benchmark("GB")];
        let full = Layout::full(Grid::new(7, 7), crate::dfg::groups_used(&dfgs));
        let engine = MappingEngine::default();
        let cost = CostModel::area();
        let cfg = SearchConfig { l_test: 200, l_fail: 2, ..Default::default() };
        let mut c = ctx(&dfgs, &engine, &cost, cfg);
        let best = run(&full, &mut c);
        assert!(cost.layout_cost(&best) < cost.layout_cost(&full));
        // feasibility is witness-proven: every accepted candidate either
        // kept a valid witness or produced a fresh mapping for it
        for (di, d) in dfgs.iter().enumerate() {
            match &c.witness[di] {
                Some(w) => assert!(w.validate(d, &best).is_empty(), "{}", d.name),
                None => assert!(c.engine.map(d, &best).is_mapped(), "{}", d.name),
            }
        }
        assert!(crate::search::meets_min_instances(&best, &c.min_insts));
    }

    #[test]
    fn gsg_respects_budget_and_failchart() {
        let dfgs = vec![benchmarks::benchmark("SOB")];
        let full = Layout::full(Grid::new(6, 6), crate::dfg::groups_used(&dfgs));
        let engine = MappingEngine::default();
        let cost = CostModel::area();
        let cfg = SearchConfig { l_test: 10, l_fail: 1, ..Default::default() };
        let mut c = ctx(&dfgs, &engine, &cost, cfg);
        let _ = run(&full, &mut c);
        assert!(c.stats.tested <= 10);
    }

    #[test]
    fn empty_support_cells_are_skipped() {
        let grid = Grid::new(5, 5);
        let l = Layout::empty(grid);
        let mut pq = BinaryHeap::new();
        let mut seen = SeenSet::new();
        let mut seq = 0u64;
        let dfgs: Vec<Dfg> = Vec::new();
        let engine = MappingEngine::default();
        let cost = CostModel::area();
        let mut c = SearchCtx::new(
            &dfgs,
            &engine,
            &cost,
            [0; NUM_GROUPS],
            SearchConfig { l_fail: 3, ..Default::default() },
        );
        expand(&l, &HashMap::new(), &mut seen, &mut pq, &mut seq, &mut c);
        assert!(pq.is_empty());
    }

    #[test]
    fn layout_hash_separates_a_randomized_distinct_corpus() {
        // every single- and multi-group removal of a full 5x5 layout is a
        // distinct layout; the default hash must keep them apart (a
        // collision would only cost a re-test — see the SeenSet test —
        // but should not happen on corpora this small)
        let grid = Grid::new(5, 5);
        let full = Layout::full(grid, GroupSet::all_compute());
        let mut layouts: Vec<Layout> = vec![full.clone()];
        for cell in grid.compute_cells() {
            for mask in removal_masks(full.support(cell)) {
                layouts.push(full.without_groups(cell, mask));
            }
        }
        // pairwise-distinct by construction
        let n = layouts.len();
        assert!(n > 100, "corpus too small to be meaningful: {n}");
        let mut hashes: Vec<u64> = layouts.iter().map(layout_hash).collect();
        hashes.sort_unstable();
        hashes.dedup();
        assert_eq!(hashes.len(), n, "layout_hash collided on a distinct corpus");
    }

    #[test]
    fn seen_set_collision_degrades_to_retest_never_wrong_prune() {
        let grid = Grid::new(5, 5);
        let full = Layout::full(grid, GroupSet::all_compute());
        let cells: Vec<CellId> = grid.compute_cells().collect();
        let a = full.without_group(cells[0], OpGroup::Arith);
        let b = full.without_group(cells[1], OpGroup::Arith);
        assert_ne!(a, b);
        // degenerate hash: every layout collides into one bucket
        let mut forced = SeenSet::with_hash(|_| 42);
        assert!(forced.insert(&a), "first layout is new");
        assert!(
            forced.insert(&b),
            "a colliding but distinct layout must be admitted (re-tested), never pruned"
        );
        assert!(!forced.insert(&a), "an exact repeat is still deduped");
        assert!(!forced.insert(&b));
        // the real hash behaves identically, just without collisions
        let mut seen = SeenSet::new();
        assert!(seen.insert(&a));
        assert!(seen.insert(&b));
        assert!(!seen.insert(&a));
        assert!(!seen.insert(&b));
    }

    #[test]
    fn gsg_thread_count_never_changes_the_result() {
        let dfgs = vec![benchmarks::benchmark("SOB"), benchmarks::benchmark("GB")];
        let full = Layout::full(Grid::new(7, 7), crate::dfg::groups_used(&dfgs));
        let cost = CostModel::area();
        let mut outs: Vec<(Layout, usize, usize)> = Vec::new();
        for threads in [1usize, 2, 4] {
            let engine = MappingEngine::default();
            let cfg = SearchConfig {
                l_test: 150,
                l_fail: 2,
                search_threads: threads,
                ..Default::default()
            };
            let mut c = ctx(&dfgs, &engine, &cost, cfg);
            let best = run(&full, &mut c);
            outs.push((best, c.stats.tested, c.stats.expanded));
        }
        for o in &outs[1..] {
            assert_eq!(outs[0].0, o.0, "layout must not depend on search_threads");
            assert_eq!(outs[0].1, o.1, "S_tst must not depend on search_threads");
            assert_eq!(outs[0].2, o.2, "S_exp must not depend on search_threads");
        }
    }
}
