//! Multi-objective search substrate: the [`SearchObjective`] switch and
//! the [`ParetoFront`] archive.
//!
//! The paper's search minimises a scalar (op-count / Equation-1 cost),
//! yet its headline results are *area* and *power* — quantities
//! [`crate::cost::synth`] already models. `SearchObjective::Pareto`
//! turns the session into a three-objective minimisation over
//! `(ops, area_um2, power_uw)`: every proven-feasible layout the
//! pipeline produces is offered to the session's `ParetoFront`, which
//! keeps exactly the non-dominated set.
//!
//! Determinism contract: the archive's state is a pure function of the
//! *sequence of offered layouts*. Points are keyed by a
//! [`StableHasher`]-based layout fingerprint (stable across platforms
//! and toolchains), kept sorted by `(ops, area, power, fingerprint)`,
//! and duplicate fingerprints are rejected — so two runs that offer the
//! same layouts in the same order hold byte-identical fronts at any
//! `--search-threads` width (the phases guarantee the offer order is
//! thread-invariant; see [`super::parallel`]).

use crate::cgra::Layout;
use crate::cost::synth;
use crate::util::StableHasher;
use std::hash::Hasher;

/// What the search minimises.
///
/// Part of [`super::SearchConfig`] and therefore of job fingerprints:
/// switching objectives is a different job with a different derived
/// seed, exactly like changing `l_test`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SearchObjective {
    /// The paper's scalar search (Equation-1 cost over op-group
    /// instances). The session keeps no front; behavior is identical to
    /// every release before this field existed.
    #[default]
    OpCount,
    /// Three-objective minimisation of `(ops, area_um2, power_uw)`.
    /// The scalar pipeline still runs (so the paper's op-count result
    /// is always on the front), followed by a [`super::GeneticPhase`]
    /// that spreads the front; improvements stream as
    /// [`super::SearchEvent::ParetoPoint`] events.
    Pareto,
}

impl SearchObjective {
    /// Wire/CLI name (`"op_count"` / `"pareto"`).
    pub fn name(self) -> &'static str {
        match self {
            SearchObjective::OpCount => "op_count",
            SearchObjective::Pareto => "pareto",
        }
    }

    /// Inverse of [`Self::name`]; `None` for unknown names.
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "op_count" => Some(SearchObjective::OpCount),
            "pareto" => Some(SearchObjective::Pareto),
            _ => None,
        }
    }
}

/// One point of the Pareto front: a feasible layout's coordinates in
/// objective space plus the layout fingerprint that keys it.
#[derive(Debug, Clone, PartialEq)]
pub struct ParetoPoint {
    /// Total op-group instances over compute cells (the paper's scalar).
    pub ops: usize,
    /// Absolute chip area from [`synth::synthesize`] (µm²).
    pub area_um2: f64,
    /// Absolute chip power from [`synth::synthesize`] (µW).
    pub power_uw: f64,
    /// [`layout_fingerprint`] of the layout behind the point.
    pub fingerprint: u64,
}

/// Content fingerprint of a layout: grid shape plus every compute
/// cell's support mask, through the pinned FNV-1a [`StableHasher`].
/// Stable across platforms, toolchains and sessions — it keys Pareto
/// archive entries and breaks minimum-layout ties
/// ([`super::posteriori::select_min_layout`]), both reproducibility
/// contracts.
pub fn layout_fingerprint(layout: &Layout) -> u64 {
    let mut h = StableHasher::new();
    h.write_u64(layout.grid.rows as u64);
    h.write_u64(layout.grid.cols as u64);
    for cell in layout.grid.compute_cells() {
        h.write_u8(layout.support(cell).0);
    }
    // Fabric identity folds in only when provisioning departs from the
    // legacy Mesh4/cap-1/all-sides default, so every pre-fabric
    // fingerprint is preserved byte-for-byte.
    if !layout.fabric().is_default() {
        h.write(layout.fabric().describe().as_bytes());
    }
    h.finish()
}

/// Weak dominance in minimisation: `a` dominates `b` when it is no
/// worse on every objective and strictly better on at least one.
pub fn dominates(a: &ParetoPoint, b: &ParetoPoint) -> bool {
    let no_worse = a.ops <= b.ops && a.area_um2 <= b.area_um2 && a.power_uw <= b.power_uw;
    let better = a.ops < b.ops || a.area_um2 < b.area_um2 || a.power_uw < b.power_uw;
    no_worse && better
}

/// Evaluate a layout's objective-space coordinates.
pub fn evaluate(layout: &Layout) -> ParetoPoint {
    let s = synth::synthesize(layout);
    ParetoPoint {
        ops: layout.compute_instances(),
        area_um2: s.area_um2,
        power_uw: s.power_uw,
        fingerprint: layout_fingerprint(layout),
    }
}

/// The non-dominated archive. Holds the points *and* the layouts behind
/// them (consumers need the layouts: the CLI renders them, the wire
/// layer re-derives synth numbers from them).
#[derive(Debug, Clone, Default)]
pub struct ParetoFront {
    /// Sorted by `(ops, area, power, fingerprint)` at all times.
    entries: Vec<(ParetoPoint, Layout)>,
}

impl ParetoFront {
    pub fn new() -> Self {
        Self::default()
    }

    /// Offer a feasible layout to the archive. Returns the new point
    /// when it was admitted (not dominated by and not a duplicate of
    /// any resident point); admission evicts every resident point the
    /// new one dominates.
    pub fn insert(&mut self, layout: &Layout) -> Option<ParetoPoint> {
        let p = evaluate(layout);
        for (q, _) in &self.entries {
            if q.fingerprint == p.fingerprint || dominates(q, &p) {
                return None;
            }
            // a resident with identical coordinates keeps the archive
            // deterministic under re-offers of equivalent layouts: the
            // first-offered layout wins the coordinate slot
            if q.ops == p.ops && q.area_um2 == p.area_um2 && q.power_uw == p.power_uw {
                return None;
            }
        }
        self.entries.retain(|(q, _)| !dominates(&p, q));
        let at = self
            .entries
            .partition_point(|(q, _)| Self::order_key(q) < Self::order_key(&p));
        self.entries.insert(at, (p.clone(), layout.clone()));
        Some(p)
    }

    /// Total order for the archive layout: objective lexicographic,
    /// fingerprint last so distinct layouts never compare equal.
    fn order_key(p: &ParetoPoint) -> (usize, u64, u64, u64) {
        (p.ops, p.area_um2.to_bits(), p.power_uw.to_bits(), p.fingerprint)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Points in archive order.
    pub fn points(&self) -> Vec<ParetoPoint> {
        self.entries.iter().map(|(p, _)| p.clone()).collect()
    }

    /// `(point, layout)` pairs in archive order.
    pub fn entries(&self) -> &[(ParetoPoint, Layout)] {
        &self.entries
    }

    /// True when some resident point dominates `p`.
    pub fn dominates_point(&self, p: &ParetoPoint) -> bool {
        self.entries.iter().any(|(q, _)| dominates(q, p))
    }

    /// 2-D hypervolume of the front's `(area, power)` projection against
    /// a reference point (typically the full layout's synth numbers) —
    /// the quality-per-second metric of the `search::genetic` bench.
    /// Points at or beyond the reference contribute nothing.
    pub fn hypervolume(&self, ref_area: f64, ref_power: f64) -> f64 {
        hypervolume_2d(&self.points(), ref_area, ref_power)
    }
}

/// [`ParetoFront::hypervolume`] over a bare point list — what consumers
/// of a finished [`super::SearchResult`] (which carries points, not the
/// archive) use.
pub fn hypervolume_2d(points: &[ParetoPoint], ref_area: f64, ref_power: f64) -> f64 {
    // non-dominated staircase of the 2-D projection: area ascending,
    // keep only strict power improvements
    let mut pts: Vec<(f64, f64)> = points
        .iter()
        .map(|p| (p.area_um2, p.power_uw))
        .filter(|&(a, pw)| a < ref_area && pw < ref_power)
        .collect();
    pts.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.total_cmp(&y.1)));
    let mut hv = 0.0;
    let mut prev_power = ref_power;
    for (a, pw) in pts {
        if pw < prev_power {
            hv += (ref_area - a) * (prev_power - pw);
            prev_power = pw;
        }
    }
    hv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::ops::{GroupSet, OpGroup};

    fn full(r: usize, c: usize) -> Layout {
        Layout::full(Grid::new(r, c), GroupSet::all_compute())
    }

    #[test]
    fn objective_names_roundtrip() {
        for obj in [SearchObjective::OpCount, SearchObjective::Pareto] {
            assert_eq!(SearchObjective::from_name(obj.name()), Some(obj));
        }
        assert_eq!(SearchObjective::from_name("area"), None);
        assert_eq!(SearchObjective::default(), SearchObjective::OpCount);
    }

    #[test]
    fn fingerprint_tracks_support_not_identity() {
        let a = full(5, 5);
        let b = full(5, 5);
        assert_eq!(layout_fingerprint(&a), layout_fingerprint(&b));
        let cell = a.grid.compute_cells().next().unwrap();
        let c = a.without_group(cell, OpGroup::Div);
        assert_ne!(layout_fingerprint(&a), layout_fingerprint(&c));
        assert_ne!(layout_fingerprint(&full(5, 6)), layout_fingerprint(&a));
    }

    #[test]
    fn fingerprint_tracks_fabric_only_when_non_default() {
        use crate::fabric::{Fabric, FabricSpec, Topology};
        let grid = Grid::new(5, 5);
        let legacy = Layout::full(grid, GroupSet::all_compute());
        let explicit = Layout::full_on(Fabric::mesh4(grid), GroupSet::all_compute());
        // default Mesh4 preserves every pre-fabric fingerprint exactly
        assert_eq!(layout_fingerprint(&legacy), layout_fingerprint(&explicit));
        let express = Layout::full_on(
            Fabric::new(
                grid,
                FabricSpec { topology: Topology::Express { stride: 2 }, ..FabricSpec::default() },
            ),
            GroupSet::all_compute(),
        );
        assert_ne!(layout_fingerprint(&legacy), layout_fingerprint(&express));
    }

    #[test]
    fn dominance_is_strict_somewhere() {
        let p = evaluate(&full(5, 5));
        assert!(!dominates(&p, &p), "a point never dominates itself");
        let cell = full(5, 5).grid.compute_cells().next().unwrap();
        let smaller = evaluate(&full(5, 5).without_group(cell, OpGroup::Div));
        assert!(dominates(&smaller, &p));
        assert!(!dominates(&p, &smaller));
    }

    #[test]
    fn front_never_retains_a_dominated_point() {
        let l = full(6, 6);
        let cells: Vec<_> = l.grid.compute_cells().collect();
        let mut front = ParetoFront::new();
        // full first, then strictly smaller layouts that dominate it
        assert!(front.insert(&l).is_some());
        assert!(front.insert(&l.without_group(cells[0], OpGroup::Div)).is_some());
        let pts = front.points();
        assert_eq!(pts.len(), 1, "the dominated full point must be evicted: {pts:?}");
        // incomparable points coexist: two cheap groups removed trades
        // more ops for less area/power saving than one Div removal
        assert!(front
            .insert(&l.without_groups(
                cells[1],
                GroupSet::from_groups(&[OpGroup::Arith, OpGroup::Mult]),
            ))
            .is_some());
        assert_eq!(front.len(), 2);
        // re-offering a resident layout is a no-op
        assert!(front.insert(&l.without_group(cells[0], OpGroup::Div)).is_none());
        // a dominated offer is rejected outright
        assert!(front.insert(&l).is_none());
        assert_eq!(front.len(), 2);
        for (p, _) in front.entries() {
            assert!(!front.dominates_point(p));
        }
    }

    #[test]
    fn front_order_is_insertion_order_invariant() {
        let l = full(6, 6);
        let cells: Vec<_> = l.grid.compute_cells().collect();
        let variants: Vec<Layout> = vec![
            l.without_group(cells[0], OpGroup::Div),
            l.without_group(cells[1], OpGroup::Other),
            l.without_group(cells[2], OpGroup::FP),
            l.without_groups(cells[3], GroupSet::from_groups(&[OpGroup::Div, OpGroup::FP])),
        ];
        let mut a = ParetoFront::new();
        for v in &variants {
            a.insert(v);
        }
        let mut b = ParetoFront::new();
        for v in variants.iter().rev() {
            b.insert(v);
        }
        assert_eq!(a.points(), b.points(), "archive order must not depend on offer order");
    }

    #[test]
    fn hypervolume_grows_with_the_front() {
        let l = full(6, 6);
        let cells: Vec<_> = l.grid.compute_cells().collect();
        let r = evaluate(&l);
        let mut front = ParetoFront::new();
        front.insert(&l);
        assert_eq!(front.hypervolume(r.area_um2, r.power_uw), 0.0);
        front.insert(&l.without_group(cells[0], OpGroup::Div));
        let hv1 = front.hypervolume(r.area_um2, r.power_uw);
        assert!(hv1 > 0.0);
        front.insert(&l.without_groups(
            cells[1],
            GroupSet::from_groups(&[OpGroup::Div, OpGroup::Other]),
        ));
        let hv2 = front.hypervolume(r.area_um2, r.power_uw);
        assert!(hv2 > hv1);
    }
}
