//! The `Explorer` session API: a builder-configured search pipeline of
//! pluggable [`SearchPhase`]s sharing one [`SearchCtx`], with progress
//! delivered to a registered [`SearchObserver`] as [`SearchEvent`]s.
//!
//! The paper's Algorithm 1 (heatmap → OPSG → GSG) is one instantiation:
//! [`Explorer::default_phases`] builds exactly that pipeline, and the
//! legacy [`super::run`] free function is a thin wrapper over it. New
//! strategies — annealing phases, parallel branch-and-bound, the
//! subgraph-driven exploration of Melchert et al. — plug in as further
//! `SearchPhase` impls without touching any existing signature.
//!
//! ```no_run
//! use helex::dfg::benchmarks;
//! use helex::search::{Explorer, SearchConfig, SearchEvent};
//! use helex::{CostModel, Grid, Mapper};
//!
//! let dfgs = benchmarks::dfg_set("S4");
//! let mapper = Mapper::default();
//! let cost = CostModel::area();
//! let mut progress = |ev: &SearchEvent| {
//!     if let SearchEvent::Improved { best_cost, .. } = ev {
//!         println!("improved to {best_cost:.1}");
//!     }
//! };
//! let result = Explorer::new(Grid::new(9, 9))
//!     .dfgs(&dfgs)
//!     .mapper(&mapper)
//!     .cost(&cost)
//!     .config(SearchConfig::default())
//!     .observer(&mut progress)
//!     .run()
//!     .expect("S4 maps on 9x9");
//! ```

use super::{gsg, heatmap, opsg, BatchScorer, SearchConfig, SearchResult, SearchStats, TracePoint};
use crate::cgra::{Grid, Layout};
use crate::cost::CostModel;
use crate::dfg::{groups_used, min_group_instances, Dfg};
use crate::mapper::{Mapper, Mapping};
use crate::ops::NUM_GROUPS;
use crate::util::Stopwatch;
use std::fmt;

/// One progress event of a search session, delivered to the registered
/// [`SearchObserver`] as it happens. Replaces the ad-hoc trace pushes of
/// the pre-session API: the convergence trace (Fig 5), CLI progress and
/// bench instrumentation are all observers of this stream.
#[derive(Debug, Clone, PartialEq)]
pub enum SearchEvent {
    /// A phase is about to run on the incumbent best layout.
    PhaseStarted { phase: String, incumbent_cost: f64 },
    /// One candidate layout was feasibility-tested with the mapper
    /// (`tested` is the running `S_tst` counter after this test).
    LayoutTested { feasible: bool, cost: f64, tested: usize },
    /// The incumbent best layout improved. Costs are monotonically
    /// non-increasing across the whole session.
    Improved { best_cost: f64, tested: usize, secs: f64 },
    /// A phase finished; `secs` is the phase's own wall time.
    PhaseFinished { phase: String, secs: f64, best_cost: f64 },
}

/// Receiver of [`SearchEvent`]s. Any `FnMut(&SearchEvent)` closure is an
/// observer.
pub trait SearchObserver {
    fn on_event(&mut self, event: &SearchEvent);
}

impl<F: FnMut(&SearchEvent)> SearchObserver for F {
    fn on_event(&mut self, event: &SearchEvent) {
        self(event)
    }
}

/// The shared state of one search session, threaded through every phase.
///
/// Bundles what the pre-session API passed as ten loose positional
/// arguments: the DFG set, mapper, cost model, minimum-instance bounds,
/// configuration, statistics, session stopwatch, optional batch scorer
/// and the per-DFG witness cache.
pub struct SearchCtx<'a> {
    /// The DFG set the layout must keep mappable.
    pub dfgs: &'a [Dfg],
    pub mapper: &'a Mapper,
    pub cost: &'a CostModel,
    /// Theoretical minimum instances per group (Section III-D pruning).
    pub min_insts: [usize; NUM_GROUPS],
    pub cfg: SearchConfig,
    pub stats: SearchStats,
    /// Session-wide wall clock (trace timestamps span all phases).
    pub sw: Stopwatch,
    /// Optional batched candidate-cost evaluator (XLA artifact).
    pub scorer: Option<&'a mut dyn BatchScorer>,
    /// Feasibility witnesses: one cached mapping per DFG, valid for the
    /// incumbent best layout. A candidate that does not invalidate a
    /// witness is feasible for that DFG without re-mapping.
    pub witness: Vec<Option<Mapping>>,
    /// The layout the search proper starts from, recorded by
    /// initialization phases (e.g. [`HeatmapPhase`]).
    /// [`SearchResult`]`::initial_layout` falls back to the full layout
    /// when no phase records one, so custom pipelines without an
    /// initialization phase keep the correct reduction baseline.
    pub initial: Option<Layout>,
    observer: Option<&'a mut dyn SearchObserver>,
    current_phase: String,
    aborted: Option<String>,
}

impl<'a> SearchCtx<'a> {
    pub fn new(
        dfgs: &'a [Dfg],
        mapper: &'a Mapper,
        cost: &'a CostModel,
        min_insts: [usize; NUM_GROUPS],
        cfg: SearchConfig,
    ) -> Self {
        Self {
            dfgs,
            mapper,
            cost,
            min_insts,
            cfg,
            stats: SearchStats::default(),
            sw: Stopwatch::start(),
            scorer: None,
            witness: vec![None; dfgs.len()],
            initial: None,
            observer: None,
            current_phase: String::new(),
            aborted: None,
        }
    }

    pub fn set_observer(&mut self, observer: &'a mut dyn SearchObserver) {
        self.observer = Some(observer);
    }

    /// Name of the phase currently running (empty between phases).
    pub fn current_phase(&self) -> &str {
        &self.current_phase
    }

    /// Mark the session as failed; the `Explorer` turns this into
    /// [`ExploreError::Infeasible`] once the current phase returns.
    pub fn abort(&mut self, reason: impl Into<String>) {
        if self.aborted.is_none() {
            self.aborted = Some(reason.into());
        }
    }

    pub fn is_aborted(&self) -> bool {
        self.aborted.is_some()
    }

    pub(crate) fn take_abort(&mut self) -> Option<String> {
        self.aborted.take()
    }

    /// Deliver an event to the observer. `Improved` events also extend
    /// the convergence trace, so phases emit events instead of pushing
    /// `TracePoint`s by hand.
    pub fn emit(&mut self, event: SearchEvent) {
        if let SearchEvent::Improved { best_cost, tested, secs } = &event {
            self.stats.trace.push(TracePoint {
                phase: self.current_phase.clone(),
                secs: *secs,
                tested: *tested,
                best_cost: *best_cost,
            });
        }
        if let Some(obs) = self.observer.as_deref_mut() {
            obs.on_event(&event);
        }
    }

    /// Convenience wrapper for the common `Improved` emission.
    pub fn emit_improved(&mut self, best_cost: f64) {
        let tested = self.stats.tested;
        let secs = self.sw.secs();
        self.emit(SearchEvent::Improved { best_cost, tested, secs });
    }

    pub(crate) fn begin_phase(&mut self, name: &str, incumbent_cost: f64) {
        self.current_phase = name.to_string();
        self.emit(SearchEvent::PhaseStarted { phase: name.to_string(), incumbent_cost });
    }

    pub(crate) fn finish_phase(
        &mut self,
        name: &str,
        secs: f64,
        best_cost: f64,
        insts: [usize; NUM_GROUPS],
    ) {
        self.stats.phase_secs.push((name.to_string(), secs));
        self.stats.insts_after_phase.push((name.to_string(), insts));
        self.emit(SearchEvent::PhaseFinished { phase: name.to_string(), secs, best_cost });
        self.current_phase.clear();
    }
}

/// One pluggable stage of the search pipeline. A phase receives the
/// incumbent best layout and the shared session context, and returns the
/// (possibly improved) incumbent. Phases must only return layouts whose
/// feasibility is proven (by mapper tests or cached witnesses).
pub trait SearchPhase {
    fn name(&self) -> &str;
    fn run(&mut self, incumbent: Layout, ctx: &mut SearchCtx) -> Layout;
}

/// Initial-layout phase (Section III-E): overlay per-DFG mappings into a
/// heatmap layout, fall back to the full layout if the heatmap does not
/// re-map, and seed the witness cache. Aborts the session if the DFG set
/// does not map on the full layout (Algorithm 1 precondition).
pub struct HeatmapPhase;

impl HeatmapPhase {
    pub const NAME: &'static str = "heatmap";
}

impl SearchPhase for HeatmapPhase {
    fn name(&self) -> &str {
        Self::NAME
    }

    fn run(&mut self, incumbent: Layout, ctx: &mut SearchCtx) -> Layout {
        let initial = if ctx.cfg.use_heatmap {
            match heatmap::initial_layout(ctx.dfgs, &incumbent, ctx.mapper) {
                heatmap::HeatmapOutcome::Heatmap(l) => {
                    ctx.stats.heatmap_used = true;
                    l
                }
                heatmap::HeatmapOutcome::FullFallback => incumbent.clone(),
                heatmap::HeatmapOutcome::Infeasible => {
                    ctx.abort("DFG set does not map on the full layout");
                    return incumbent;
                }
            }
        } else {
            if !ctx.mapper.test_layout(ctx.dfgs, &incumbent) {
                ctx.abort("DFG set does not map on the full layout");
                return incumbent;
            }
            incumbent.clone()
        };
        // Seed witnesses with mappings on the initial layout (which just
        // passed test_layout): a DFG untouched by every later removal
        // keeps its seed witness valid to the end of the session.
        let seeded: Vec<Option<Mapping>> =
            ctx.dfgs.iter().map(|d| ctx.mapper.map(d, &initial)).collect();
        if seeded.iter().any(Option::is_none) {
            ctx.abort("initial layout no longer maps"); // should not happen
            return incumbent;
        }
        ctx.witness = seeded;
        ctx.initial = Some(initial.clone());
        let cost = ctx.cost.layout_cost(&initial);
        ctx.emit_improved(cost);
        initial
    }
}

/// Operation-based subproblem generation (Algorithm 2) as a phase.
pub struct OpsgPhase;

impl OpsgPhase {
    pub const NAME: &'static str = "OPSG";
}

impl SearchPhase for OpsgPhase {
    fn name(&self) -> &str {
        Self::NAME
    }

    fn run(&mut self, incumbent: Layout, ctx: &mut SearchCtx) -> Layout {
        opsg::run(&incumbent, ctx)
    }
}

/// General subproblem generation (Algorithm 3) as a phase; the paper
/// runs it twice, so it carries its own pass count.
pub struct GsgPhase {
    pub passes: usize,
}

impl GsgPhase {
    pub const NAME: &'static str = "GSG";
}

impl SearchPhase for GsgPhase {
    fn name(&self) -> &str {
        Self::NAME
    }

    fn run(&mut self, incumbent: Layout, ctx: &mut SearchCtx) -> Layout {
        let mut best = incumbent;
        for _pass in 0..self.passes {
            best = gsg::run(&best, ctx);
        }
        best
    }
}

/// Why an [`Explorer`] session could not produce a result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExploreError {
    /// No (or an empty) DFG set was supplied to the builder.
    MissingDfgs,
    /// An explicit empty phase pipeline was supplied.
    EmptyPipeline,
    /// The DFG set does not map (Algorithm 1 terminates in failure).
    Infeasible(String),
}

impl fmt::Display for ExploreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExploreError::MissingDfgs => write!(f, "no DFGs supplied to the Explorer builder"),
            ExploreError::EmptyPipeline => write!(f, "empty search-phase pipeline"),
            ExploreError::Infeasible(why) => write!(f, "search infeasible: {why}"),
        }
    }
}

impl std::error::Error for ExploreError {}

/// Builder-style search session. See the module docs for an example.
///
/// Required: a target grid (constructor) and a DFG set ([`Self::dfgs`]).
/// Everything else has defaults: [`Mapper::default`], the area
/// [`CostModel`], [`SearchConfig::default`] and the paper's
/// heatmap → OPSG → GSG pipeline ([`Self::default_phases`]).
pub struct Explorer<'a> {
    grid: Grid,
    dfgs: Option<&'a [Dfg]>,
    mapper: Option<&'a Mapper>,
    cost: Option<&'a CostModel>,
    cfg: SearchConfig,
    scorer: Option<&'a mut dyn BatchScorer>,
    observer: Option<&'a mut dyn SearchObserver>,
    phases: Option<Vec<Box<dyn SearchPhase>>>,
}

impl<'a> Explorer<'a> {
    pub fn new(grid: Grid) -> Self {
        Self {
            grid,
            dfgs: None,
            mapper: None,
            cost: None,
            cfg: SearchConfig::default(),
            scorer: None,
            observer: None,
            phases: None,
        }
    }

    /// The DFG set to optimise the layout for (required).
    pub fn dfgs(mut self, dfgs: &'a [Dfg]) -> Self {
        self.dfgs = Some(dfgs);
        self
    }

    pub fn mapper(mut self, mapper: &'a Mapper) -> Self {
        self.mapper = Some(mapper);
        self
    }

    pub fn cost(mut self, cost: &'a CostModel) -> Self {
        self.cost = Some(cost);
        self
    }

    pub fn config(mut self, cfg: SearchConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn scorer(mut self, scorer: &'a mut dyn BatchScorer) -> Self {
        self.scorer = Some(scorer);
        self
    }

    pub fn observer(mut self, observer: &'a mut dyn SearchObserver) -> Self {
        self.observer = Some(observer);
        self
    }

    /// Replace the whole phase pipeline. An empty vector is rejected at
    /// [`Self::run`] time.
    pub fn phases(mut self, phases: Vec<Box<dyn SearchPhase>>) -> Self {
        self.phases = Some(phases);
        self
    }

    /// Append one phase. Starts from an *empty* pipeline (not the
    /// default one) the first time it is called; use
    /// [`Self::default_phases`] to extend the standard pipeline.
    pub fn phase(mut self, phase: Box<dyn SearchPhase>) -> Self {
        self.phases.get_or_insert_with(Vec::new).push(phase);
        self
    }

    /// The paper's Algorithm 1 pipeline for a given configuration:
    /// heatmap, OPSG, and (when `cfg.run_gsg`) `cfg.gsg_passes` GSG
    /// passes.
    pub fn default_phases(cfg: &SearchConfig) -> Vec<Box<dyn SearchPhase>> {
        let mut phases: Vec<Box<dyn SearchPhase>> =
            vec![Box::new(HeatmapPhase), Box::new(OpsgPhase)];
        if cfg.run_gsg {
            phases.push(Box::new(GsgPhase { passes: cfg.gsg_passes }));
        }
        phases
    }

    /// Run the session: validate the builder, assemble the [`SearchCtx`],
    /// drive every phase and materialize the witness mappings.
    pub fn run(self) -> Result<SearchResult, ExploreError> {
        let dfgs = self.dfgs.filter(|d| !d.is_empty()).ok_or(ExploreError::MissingDfgs)?;
        let default_mapper;
        let mapper = match self.mapper {
            Some(m) => m,
            None => {
                default_mapper = Mapper::default();
                &default_mapper
            }
        };
        let default_cost;
        let cost = match self.cost {
            Some(c) => c,
            None => {
                default_cost = CostModel::area();
                &default_cost
            }
        };
        let phases = match self.phases {
            Some(p) => p,
            None => Self::default_phases(&self.cfg),
        };
        if phases.is_empty() {
            return Err(ExploreError::EmptyPipeline);
        }

        let min_insts = min_group_instances(dfgs);
        // full layout over the groups the DFG set actually uses
        // (Section IV-F)
        let full_layout = Layout::full(self.grid, groups_used(dfgs));

        let mut ctx = SearchCtx::new(dfgs, mapper, cost, min_insts, self.cfg);
        // destructure rather than assign the Option whole: the call-site
        // coercion reborrows the &mut trait object and shortens its
        // object lifetime to the ctx's (a direct Option-to-Option
        // assignment would force the ctx lifetime to equal 'a, which the
        // default_mapper/default_cost locals cannot satisfy)
        if let Some(s) = self.scorer {
            ctx.scorer = Some(s);
        }
        if let Some(obs) = self.observer {
            ctx.set_observer(obs);
        }
        ctx.stats.insts_full = full_layout.compute_group_instances();

        let mut best = full_layout.clone();
        for mut phase in phases {
            let name = phase.name().to_string();
            ctx.begin_phase(&name, cost.layout_cost(&best));
            let t = Stopwatch::start();
            best = phase.run(best, &mut ctx);
            // an aborted phase failed rather than finished: error out
            // without emitting a misleading PhaseFinished (the
            // started/finished pairing invariant holds for successful
            // sessions)
            if let Some(reason) = ctx.take_abort() {
                return Err(ExploreError::Infeasible(reason));
            }
            let insts = best.compute_group_instances();
            ctx.finish_phase(&name, t.secs(), cost.layout_cost(&best), insts);
        }
        // the reduction baseline: what the initialization phase recorded,
        // or the full layout for pipelines without one
        let initial_layout = ctx.initial.take().unwrap_or_else(|| full_layout.clone());

        // materialize final witnesses: any DFG whose cached witness is
        // missing or stale gets a fresh mapping on the final layout
        let mut final_mappings = Vec::with_capacity(dfgs.len());
        for (di, d) in dfgs.iter().enumerate() {
            let w = match ctx.witness[di].take() {
                Some(w) if w.still_valid(d, &best) => w,
                _ => mapper.map(d, &best).ok_or_else(|| {
                    ExploreError::Infeasible(format!(
                        "{}: no mapping on the final layout",
                        d.name
                    ))
                })?,
            };
            debug_assert!(w.validate(d, &best).is_empty());
            final_mappings.push(w);
        }

        let best_cost = cost.layout_cost(&best);
        Ok(SearchResult {
            full_layout,
            initial_layout,
            best_layout: best,
            best_cost,
            min_insts,
            final_mappings,
            stats: ctx.stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::benchmarks;

    #[test]
    fn default_phase_pipeline_shape() {
        let cfg = SearchConfig::default();
        let names: Vec<String> =
            Explorer::default_phases(&cfg).iter().map(|p| p.name().to_string()).collect();
        assert_eq!(names, vec!["heatmap", "OPSG", "GSG"]);
        let nogsg = SearchConfig { run_gsg: false, ..cfg };
        let names: Vec<String> =
            Explorer::default_phases(&nogsg).iter().map(|p| p.name().to_string()).collect();
        assert_eq!(names, vec!["heatmap", "OPSG"]);
    }

    #[test]
    fn ctx_abort_is_sticky_and_taken_once() {
        let dfgs = vec![benchmarks::benchmark("SOB")];
        let mapper = Mapper::default();
        let cost = CostModel::area();
        let mut ctx =
            SearchCtx::new(&dfgs, &mapper, &cost, [0; NUM_GROUPS], SearchConfig::default());
        assert!(!ctx.is_aborted());
        ctx.abort("first");
        ctx.abort("second");
        assert!(ctx.is_aborted());
        assert_eq!(ctx.take_abort().as_deref(), Some("first"));
        assert!(ctx.take_abort().is_none());
    }

    #[test]
    fn emit_improved_extends_trace_with_current_phase() {
        let dfgs = vec![benchmarks::benchmark("SOB")];
        let mapper = Mapper::default();
        let cost = CostModel::area();
        let mut ctx =
            SearchCtx::new(&dfgs, &mapper, &cost, [0; NUM_GROUPS], SearchConfig::default());
        ctx.begin_phase("custom", 10.0);
        ctx.emit_improved(5.0);
        assert_eq!(ctx.stats.trace.len(), 1);
        assert_eq!(ctx.stats.trace[0].phase, "custom");
        assert_eq!(ctx.stats.trace[0].best_cost, 5.0);
    }
}
