//! The `Explorer` session API: a builder-configured search pipeline of
//! pluggable [`SearchPhase`]s sharing one [`SearchCtx`], with progress
//! delivered to a registered [`SearchObserver`] as [`SearchEvent`]s.
//!
//! The paper's Algorithm 1 (heatmap → OPSG → GSG) is one instantiation:
//! [`Explorer::default_phases`] builds exactly that pipeline, and the
//! legacy [`super::run`] free function is a thin wrapper over it. New
//! strategies — annealing phases, parallel branch-and-bound, the
//! subgraph-driven exploration of Melchert et al. — plug in as further
//! `SearchPhase` impls without touching any existing signature.
//!
//! ```no_run
//! use helex::dfg::benchmarks;
//! use helex::search::{Explorer, SearchConfig, SearchEvent};
//! use helex::{CostModel, Grid, MappingEngine};
//!
//! let dfgs = benchmarks::dfg_set("S4");
//! let engine = MappingEngine::default();
//! let cost = CostModel::area();
//! let mut progress = |ev: &SearchEvent| {
//!     if let SearchEvent::Improved { best_cost, .. } = ev {
//!         println!("improved to {best_cost:.1}");
//!     }
//! };
//! let result = Explorer::new(Grid::new(9, 9))
//!     .dfgs(&dfgs)
//!     .engine(&engine)
//!     .cost(&cost)
//!     .config(SearchConfig::default())
//!     .observer(&mut progress)
//!     .run()
//!     .expect("S4 maps on 9x9");
//! ```

use super::genetic::GeneticPhase;
use super::pareto::{ParetoFront, SearchObjective};
use super::subgraph::SubgraphSeedPhase;
use super::{gsg, heatmap, opsg, BatchScorer, SearchConfig, SearchResult, SearchStats, TracePoint};
use crate::cgra::{Grid, Layout};
use crate::cost::CostModel;
use crate::dfg::{groups_used, min_group_instances, Dfg};
use crate::mapper::{MapOutcome, Mapper, Mapping, MappingEngine};
use crate::ops::NUM_GROUPS;
use crate::util::Stopwatch;
use std::fmt;

/// One progress event of a search session, delivered to the registered
/// [`SearchObserver`] as it happens. Replaces the ad-hoc trace pushes of
/// the pre-session API: the convergence trace (Fig 5), CLI progress and
/// bench instrumentation are all observers of this stream.
#[derive(Debug, Clone, PartialEq)]
pub enum SearchEvent {
    /// A phase is about to run on the incumbent best layout.
    PhaseStarted { phase: String, incumbent_cost: f64 },
    /// One candidate layout was feasibility-tested with the mapper
    /// (`tested` is the running `S_tst` counter after this test).
    /// `worker` is the pool worker that ran the test — diagnostic only:
    /// events are always *emitted* in deterministic reduction order, but
    /// the worker tag varies with thread count and timing, so the wire
    /// codec treats it as volatile (stripped before byte comparisons).
    LayoutTested { feasible: bool, cost: f64, tested: usize, worker: usize },
    /// The incumbent best layout improved. Costs are monotonically
    /// non-increasing across the whole session.
    Improved { best_cost: f64, tested: usize, secs: f64 },
    /// A point was admitted to the session's Pareto front
    /// ([`super::SearchObjective::Pareto`] sessions only): the anytime
    /// front streams as these events. `front_size` is the archive size
    /// after admission; like every event, emission order is
    /// deterministic at any thread count (no volatile fields).
    ParetoPoint { ops: usize, area_um2: f64, power_uw: f64, front_size: usize, tested: usize },
    /// A phase finished; `secs` is the phase's own wall time.
    PhaseFinished { phase: String, secs: f64, best_cost: f64 },
}

/// Receiver of [`SearchEvent`]s. Any `FnMut(&SearchEvent)` closure is an
/// observer.
pub trait SearchObserver {
    fn on_event(&mut self, event: &SearchEvent);
}

impl<F: FnMut(&SearchEvent)> SearchObserver for F {
    fn on_event(&mut self, event: &SearchEvent) {
        self(event)
    }
}

/// Owned, `Send` observer handle: forwards every event into an
/// [`std::sync::mpsc`] channel, so a session running on a worker thread
/// streams its trace without borrowing anything across threads. Register
/// it with [`Explorer::observer_owned`] and drain the receiver on the
/// other side; the sender drops (disconnecting the channel) when the
/// session ends. This is how the `ExplorationService` worker pool gives
/// each job its own event channel. (A disconnected receiver just means
/// nobody is listening anymore — events are then discarded.)
pub fn channel_observer(
    tx: std::sync::mpsc::Sender<SearchEvent>,
) -> impl SearchObserver + Send + 'static {
    move |event: &SearchEvent| {
        let _ = tx.send(event.clone());
    }
}

/// The shared state of one search session, threaded through every phase.
///
/// Bundles what the pre-session API passed as ten loose positional
/// arguments: the DFG set, mapping engine, cost model, minimum-instance
/// bounds, configuration, statistics, session stopwatch, optional batch
/// scorer and the per-DFG witness cache.
pub struct SearchCtx<'a> {
    /// The DFG set the layout must keep mappable.
    pub dfgs: &'a [Dfg],
    /// Feasibility oracle: phases consume [`MapOutcome`]s from it, using
    /// [`MappingEngine::remap_from`] with the cached witness so candidate
    /// tests take the incremental warm-start path.
    pub engine: &'a MappingEngine,
    pub cost: &'a CostModel,
    /// Theoretical minimum instances per group (Section III-D pruning).
    pub min_insts: [usize; NUM_GROUPS],
    pub cfg: SearchConfig,
    pub stats: SearchStats,
    /// Session-wide wall clock (trace timestamps span all phases).
    pub sw: Stopwatch,
    /// Optional batched candidate-cost evaluator (XLA artifact).
    pub scorer: Option<&'a mut dyn BatchScorer>,
    /// Feasibility witnesses: one cached mapping per DFG, valid for the
    /// incumbent best layout. A candidate that does not invalidate a
    /// witness is feasible for that DFG without re-mapping. The OPSG/GSG
    /// phases temporarily move this vector out (via `mem::take`) for the
    /// duration of their run so worker threads can read a fixed snapshot
    /// ([`super::parallel::SharedState`]) while the ctx keeps mutating
    /// stats and events; it is merged back — updated in branching order —
    /// before the phase returns.
    pub witness: Vec<Option<Mapping>>,
    /// The layout the search proper starts from, recorded by
    /// initialization phases (e.g. [`HeatmapPhase`]).
    /// [`SearchResult`]`::initial_layout` falls back to the full layout
    /// when no phase records one, so custom pipelines without an
    /// initialization phase keep the correct reduction baseline.
    pub initial: Option<Layout>,
    /// The session's Pareto archive — `Some` exactly for
    /// [`SearchObjective::Pareto`] sessions. Phases offer feasible
    /// layouts through [`Self::record_front`], which emits
    /// [`SearchEvent::ParetoPoint`] on admission.
    pub front: Option<ParetoFront>,
    observer: Option<&'a mut dyn SearchObserver>,
    current_phase: String,
    aborted: Option<String>,
}

impl<'a> SearchCtx<'a> {
    pub fn new(
        dfgs: &'a [Dfg],
        engine: &'a MappingEngine,
        cost: &'a CostModel,
        min_insts: [usize; NUM_GROUPS],
        cfg: SearchConfig,
    ) -> Self {
        Self {
            dfgs,
            engine,
            cost,
            min_insts,
            cfg,
            stats: SearchStats::default(),
            sw: Stopwatch::start(),
            scorer: None,
            witness: vec![None; dfgs.len()],
            initial: None,
            front: None,
            observer: None,
            current_phase: String::new(),
            aborted: None,
        }
    }

    pub fn set_observer(&mut self, observer: &'a mut dyn SearchObserver) {
        self.observer = Some(observer);
    }

    /// Name of the phase currently running (empty between phases).
    pub fn current_phase(&self) -> &str {
        &self.current_phase
    }

    /// Mark the session as failed; the `Explorer` turns this into
    /// [`ExploreError::Infeasible`] once the current phase returns.
    pub fn abort(&mut self, reason: impl Into<String>) {
        if self.aborted.is_none() {
            self.aborted = Some(reason.into());
        }
    }

    pub fn is_aborted(&self) -> bool {
        self.aborted.is_some()
    }

    pub(crate) fn take_abort(&mut self) -> Option<String> {
        self.aborted.take()
    }

    /// Deliver an event to the observer. `Improved` events also extend
    /// the convergence trace, so phases emit events instead of pushing
    /// `TracePoint`s by hand.
    pub fn emit(&mut self, event: SearchEvent) {
        if let SearchEvent::Improved { best_cost, tested, secs } = &event {
            self.stats.trace.push(TracePoint {
                phase: self.current_phase.clone(),
                secs: *secs,
                tested: *tested,
                best_cost: *best_cost,
            });
        }
        if let Some(obs) = self.observer.as_deref_mut() {
            obs.on_event(&event);
        }
    }

    /// Convenience wrapper for the common `Improved` emission.
    pub fn emit_improved(&mut self, best_cost: f64) {
        let tested = self.stats.tested;
        let secs = self.sw.secs();
        self.emit(SearchEvent::Improved { best_cost, tested, secs });
    }

    /// Offer a proven-feasible layout to the session's Pareto front.
    /// No-op for scalar sessions; on admission the new point streams as
    /// a [`SearchEvent::ParetoPoint`]. Must only be called while a
    /// phase is open (events nest inside phase boundaries).
    pub fn record_front(&mut self, layout: &Layout) {
        let Some(mut front) = self.front.take() else { return };
        if let Some(p) = front.insert(layout) {
            let tested = self.stats.tested;
            self.emit(SearchEvent::ParetoPoint {
                ops: p.ops,
                area_um2: p.area_um2,
                power_uw: p.power_uw,
                front_size: front.len(),
                tested,
            });
        }
        self.front = Some(front);
    }

    pub(crate) fn begin_phase(&mut self, name: &str, incumbent_cost: f64) {
        self.current_phase = name.to_string();
        self.emit(SearchEvent::PhaseStarted { phase: name.to_string(), incumbent_cost });
    }

    /// Feasibility-test one DFG against a candidate layout, consuming a
    /// [`MapOutcome`] from the engine. The DFG's cached witness (when
    /// present) is passed as a warm start, so one-removal candidates
    /// take the incremental remap path instead of a full place-and-route.
    /// Callers store the returned mapping as the new witness when the
    /// candidate is accepted.
    ///
    /// This is the *serial* helper for custom [`SearchPhase`]s: it runs
    /// on the session's shared, cache-enabled engine. The built-in
    /// OPSG/GSG phases do **not** use it — their tests go through
    /// [`super::parallel::TestPool`]'s cache-free forked engines, which
    /// is what makes their results thread-count-independent (rule 1 of
    /// the deterministic-reduction contract). A custom phase that wants
    /// that guarantee should use the pool, not this method.
    pub fn test_dfg(&self, di: usize, layout: &Layout) -> MapOutcome {
        match &self.witness[di] {
            Some(w) => self.engine.remap_from(w, &self.dfgs[di], layout),
            None => self.engine.map(&self.dfgs[di], layout),
        }
    }

    pub(crate) fn finish_phase(
        &mut self,
        name: &str,
        secs: f64,
        best_cost: f64,
        insts: [usize; NUM_GROUPS],
    ) {
        self.stats.phase_secs.push((name.to_string(), secs));
        self.stats.insts_after_phase.push((name.to_string(), insts));
        self.emit(SearchEvent::PhaseFinished { phase: name.to_string(), secs, best_cost });
        self.current_phase.clear();
    }
}

/// One pluggable stage of the search pipeline. A phase receives the
/// incumbent best layout and the shared session context, and returns the
/// (possibly improved) incumbent. Phases must only return layouts whose
/// feasibility is proven (by mapper tests or cached witnesses).
pub trait SearchPhase {
    fn name(&self) -> &str;
    fn run(&mut self, incumbent: Layout, ctx: &mut SearchCtx) -> Layout;
}

/// Initial-layout phase (Section III-E): overlay per-DFG mappings into a
/// heatmap layout, fall back to the full layout if the heatmap does not
/// re-map, and seed the witness cache. Aborts the session if the DFG set
/// does not map on the full layout (Algorithm 1 precondition).
pub struct HeatmapPhase;

impl HeatmapPhase {
    pub const NAME: &'static str = "heatmap";
}

impl SearchPhase for HeatmapPhase {
    fn name(&self) -> &str {
        Self::NAME
    }

    fn run(&mut self, incumbent: Layout, ctx: &mut SearchCtx) -> Layout {
        let initial = if ctx.cfg.use_heatmap {
            match heatmap::initial_layout(ctx.dfgs, &incumbent, ctx.engine) {
                heatmap::HeatmapOutcome::Heatmap(l) => {
                    ctx.stats.heatmap_used = true;
                    l
                }
                heatmap::HeatmapOutcome::FullFallback => incumbent.clone(),
                heatmap::HeatmapOutcome::Infeasible { dfg, failure } => {
                    ctx.abort(format!("{dfg} does not map on the full layout: {failure}"));
                    return incumbent;
                }
            }
        } else {
            match ctx.engine.map_all(ctx.dfgs, &incumbent) {
                Ok(_) => incumbent.clone(),
                Err(fail) => {
                    ctx.abort(format!("{fail} on the full layout"));
                    return incumbent;
                }
            }
        };
        // Seed witnesses with mappings on the initial layout (which just
        // passed map_all/heatmap re-mapping): a DFG untouched by every
        // later removal keeps its seed witness valid to the session end.
        match ctx.engine.map_all(ctx.dfgs, &initial) {
            Ok(mappings) => ctx.witness = mappings.into_iter().map(Some).collect(),
            Err(fail) => {
                ctx.abort(format!("initial layout no longer maps: {fail}")); // should not happen
                return incumbent;
            }
        }
        ctx.initial = Some(initial.clone());
        let cost = ctx.cost.layout_cost(&initial);
        ctx.emit_improved(cost);
        initial
    }
}

/// Operation-based subproblem generation (Algorithm 2) as a phase.
pub struct OpsgPhase;

impl OpsgPhase {
    pub const NAME: &'static str = "OPSG";
}

impl SearchPhase for OpsgPhase {
    fn name(&self) -> &str {
        Self::NAME
    }

    fn run(&mut self, incumbent: Layout, ctx: &mut SearchCtx) -> Layout {
        opsg::run(&incumbent, ctx)
    }
}

/// General subproblem generation (Algorithm 3) as a phase; the paper
/// runs it twice, so it carries its own pass count.
pub struct GsgPhase {
    pub passes: usize,
}

impl GsgPhase {
    pub const NAME: &'static str = "GSG";
}

impl SearchPhase for GsgPhase {
    fn name(&self) -> &str {
        Self::NAME
    }

    fn run(&mut self, incumbent: Layout, ctx: &mut SearchCtx) -> Layout {
        let mut best = incumbent;
        for _pass in 0..self.passes {
            best = gsg::run(&best, ctx);
        }
        best
    }
}

/// Why an [`Explorer`] session could not produce a result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExploreError {
    /// No (or an empty) DFG set was supplied to the builder.
    MissingDfgs,
    /// An explicit empty phase pipeline was supplied.
    EmptyPipeline,
    /// The DFG set does not map (Algorithm 1 terminates in failure).
    Infeasible(String),
}

impl fmt::Display for ExploreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExploreError::MissingDfgs => write!(f, "no DFGs supplied to the Explorer builder"),
            ExploreError::EmptyPipeline => write!(f, "empty search-phase pipeline"),
            ExploreError::Infeasible(why) => write!(f, "search infeasible: {why}"),
        }
    }
}

impl std::error::Error for ExploreError {}

/// Builder-style search session. See the module docs for an example.
///
/// Required: a target grid (constructor) and a DFG set ([`Self::dfgs`]).
/// Everything else has defaults: [`MappingEngine::default`], the area
/// [`CostModel`], [`SearchConfig::default`] and the paper's
/// heatmap → OPSG → GSG pipeline ([`Self::default_phases`]).
pub struct Explorer<'a> {
    grid: Grid,
    /// Interconnect provisioning for the session's layouts; defaults to
    /// the byte-identical legacy Mesh4 fabric.
    fabric: crate::fabric::FabricSpec,
    dfgs: Option<&'a [Dfg]>,
    engine: Option<&'a MappingEngine>,
    /// Engine built from a legacy [`Self::mapper`] call (owned so the
    /// borrowed-engine path stays zero-cost).
    owned_engine: Option<MappingEngine>,
    cost: Option<&'a CostModel>,
    cfg: SearchConfig,
    scorer: Option<&'a mut dyn BatchScorer>,
    observer: Option<&'a mut dyn SearchObserver>,
    owned_observer: Option<Box<dyn SearchObserver + 'a>>,
    phases: Option<Vec<Box<dyn SearchPhase>>>,
}

impl<'a> Explorer<'a> {
    pub fn new(grid: Grid) -> Self {
        Self {
            grid,
            fabric: crate::fabric::FabricSpec::default(),
            dfgs: None,
            engine: None,
            owned_engine: None,
            cost: None,
            cfg: SearchConfig::default(),
            scorer: None,
            observer: None,
            owned_observer: None,
            phases: None,
        }
    }

    /// The DFG set to optimise the layout for (required).
    pub fn dfgs(mut self, dfgs: &'a [Dfg]) -> Self {
        self.dfgs = Some(dfgs);
        self
    }

    /// Provision the session's fabric (topology, link capacity, I/O
    /// mask). The default [`crate::fabric::FabricSpec`] reproduces the
    /// legacy grid byte-for-byte.
    pub fn fabric(mut self, spec: crate::fabric::FabricSpec) -> Self {
        self.fabric = spec;
        self
    }

    /// Share a [`MappingEngine`] with the session (and with other
    /// sessions: the engine's feasibility cache persists across runs).
    pub fn engine(mut self, engine: &'a MappingEngine) -> Self {
        self.engine = Some(engine);
        self.owned_engine = None;
        self
    }

    /// Legacy entry: derive an owned engine from a [`Mapper`]'s
    /// configuration. Prefer [`Self::engine`].
    pub fn mapper(mut self, mapper: &Mapper) -> Self {
        if self.engine.is_none() {
            self.owned_engine = Some(MappingEngine::from_mapper(mapper));
        }
        self
    }

    pub fn cost(mut self, cost: &'a CostModel) -> Self {
        self.cost = Some(cost);
        self
    }

    pub fn config(mut self, cfg: SearchConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn scorer(mut self, scorer: &'a mut dyn BatchScorer) -> Self {
        self.scorer = Some(scorer);
        self
    }

    pub fn observer(mut self, observer: &'a mut dyn SearchObserver) -> Self {
        self.observer = Some(observer);
        self
    }

    /// Register an observer the session *owns* — the `Send`-compatible
    /// alternative to [`Self::observer`]'s borrow. A worker thread hands
    /// the session a handle it can move (typically a [`channel_observer`]
    /// or another boxed closure over channel senders) and events cross
    /// threads over the channel instead of through a borrow. When both
    /// are registered, the borrowed observer wins.
    pub fn observer_owned(mut self, observer: Box<dyn SearchObserver + 'a>) -> Self {
        self.owned_observer = Some(observer);
        self
    }

    /// Replace the whole phase pipeline. An empty vector is rejected at
    /// [`Self::run`] time.
    pub fn phases(mut self, phases: Vec<Box<dyn SearchPhase>>) -> Self {
        self.phases = Some(phases);
        self
    }

    /// Append one phase. Starts from an *empty* pipeline (not the
    /// default one) the first time it is called; use
    /// [`Self::default_phases`] to extend the standard pipeline.
    pub fn phase(mut self, phase: Box<dyn SearchPhase>) -> Self {
        self.phases.get_or_insert_with(Vec::new).push(phase);
        self
    }

    /// The paper's Algorithm 1 pipeline for a given configuration:
    /// heatmap, OPSG, and (when `cfg.run_gsg`) `cfg.gsg_passes` GSG
    /// passes. `cfg.subgraph_seed` inserts the [`SubgraphSeedPhase`]
    /// after the heatmap, and [`SearchObjective::Pareto`] appends the
    /// [`GeneticPhase`] — the scalar pipeline always runs first, so the
    /// paper's op-count result is always on the front.
    pub fn default_phases(cfg: &SearchConfig) -> Vec<Box<dyn SearchPhase>> {
        let mut phases: Vec<Box<dyn SearchPhase>> = vec![Box::new(HeatmapPhase)];
        if cfg.subgraph_seed {
            phases.push(Box::new(SubgraphSeedPhase));
        }
        phases.push(Box::new(OpsgPhase));
        if cfg.run_gsg {
            phases.push(Box::new(GsgPhase { passes: cfg.gsg_passes }));
        }
        if cfg.objective == SearchObjective::Pareto {
            phases.push(Box::new(GeneticPhase {
                generations: cfg.genetic_generations,
                population: cfg.genetic_population,
            }));
        }
        phases
    }

    /// Run the session: validate the builder, assemble the [`SearchCtx`],
    /// drive every phase and materialize the witness mappings.
    pub fn run(self) -> Result<SearchResult, ExploreError> {
        let dfgs = self.dfgs.filter(|d| !d.is_empty()).ok_or(ExploreError::MissingDfgs)?;
        let default_engine;
        let engine = match self.engine {
            Some(e) => e,
            None => {
                default_engine = self.owned_engine.unwrap_or_default();
                &default_engine
            }
        };
        let default_cost;
        let cost = match self.cost {
            Some(c) => c,
            None => {
                default_cost = CostModel::area();
                &default_cost
            }
        };
        let phases = match self.phases {
            Some(p) => p,
            None => Self::default_phases(&self.cfg),
        };
        if phases.is_empty() {
            return Err(ExploreError::EmptyPipeline);
        }

        let min_insts = min_group_instances(dfgs);
        // full layout over the groups the DFG set actually uses
        // (Section IV-F), on the session's provisioned fabric
        let full_layout = Layout::full_on(self.fabric.build(self.grid), groups_used(dfgs));

        // declared before ctx so the ctx's borrow of the owned observer
        // (below) outlives it, exactly like default_engine/default_cost
        let mut owned_observer = self.owned_observer;
        let mut ctx = SearchCtx::new(dfgs, engine, cost, min_insts, self.cfg);
        // destructure rather than assign the Option whole: the call-site
        // coercion reborrows the &mut trait object and shortens its
        // object lifetime to the ctx's (a direct Option-to-Option
        // assignment would force the ctx lifetime to equal 'a, which the
        // default_mapper/default_cost locals cannot satisfy)
        if let Some(s) = self.scorer {
            ctx.scorer = Some(s);
        }
        if let Some(obs) = self.observer {
            ctx.set_observer(obs);
        } else if let Some(obs) = owned_observer.as_deref_mut() {
            ctx.set_observer(obs);
        }
        ctx.stats.insts_full = full_layout.compute_group_instances();
        if ctx.cfg.objective == SearchObjective::Pareto {
            // the full layout anchors the archive: the search dominates
            // it, so the final front never retains its point (direct
            // insert, not record_front — no phase is open yet, and the
            // anchor is not an improvement worth streaming)
            let mut front = ParetoFront::new();
            front.insert(&full_layout);
            ctx.front = Some(front);
        }

        let mut best = full_layout.clone();
        for mut phase in phases {
            let name = phase.name().to_string();
            ctx.begin_phase(&name, cost.layout_cost(&best));
            let t = Stopwatch::start();
            best = phase.run(best, &mut ctx);
            // an aborted phase failed rather than finished: error out
            // without emitting a misleading PhaseFinished (the
            // started/finished pairing invariant holds for successful
            // sessions)
            if let Some(reason) = ctx.take_abort() {
                return Err(ExploreError::Infeasible(reason));
            }
            // every phase returns a proven-feasible incumbent: offer it
            // to the front (still inside the phase's event scope)
            ctx.record_front(&best);
            let insts = best.compute_group_instances();
            ctx.finish_phase(&name, t.secs(), cost.layout_cost(&best), insts);
        }
        // the reduction baseline: what the initialization phase recorded,
        // or the full layout for pipelines without one
        let initial_layout = ctx.initial.take().unwrap_or_else(|| full_layout.clone());

        // materialize final witnesses: any DFG whose cached witness is
        // stale gets a warm-start remap (falling back to from-scratch
        // inside the engine) on the final layout
        let mut final_mappings = Vec::with_capacity(dfgs.len());
        for (di, d) in dfgs.iter().enumerate() {
            let outcome = match ctx.witness[di].take() {
                Some(w) if w.still_valid(d, &best) => {
                    debug_assert!(w.validate(d, &best).is_empty());
                    final_mappings.push(w);
                    continue;
                }
                Some(w) => engine.remap_from(&w, d, &best),
                None => engine.map(d, &best),
            };
            match outcome {
                MapOutcome::Mapped { mapping, .. } => {
                    debug_assert!(mapping.validate(d, &best).is_empty());
                    final_mappings.push(mapping);
                }
                MapOutcome::Failed { failure, .. } => {
                    return Err(ExploreError::Infeasible(format!(
                        "{}: no mapping on the final layout ({failure})",
                        d.name
                    )));
                }
            }
        }

        let best_cost = cost.layout_cost(&best);
        let front = ctx.front.take().map(|f| f.points()).unwrap_or_default();
        Ok(SearchResult {
            full_layout,
            initial_layout,
            best_layout: best,
            best_cost,
            min_insts,
            final_mappings,
            front,
            stats: ctx.stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::benchmarks;

    #[test]
    fn default_phase_pipeline_shape() {
        let cfg = SearchConfig::default();
        let names: Vec<String> =
            Explorer::default_phases(&cfg).iter().map(|p| p.name().to_string()).collect();
        assert_eq!(names, vec!["heatmap", "OPSG", "GSG"]);
        let nogsg = SearchConfig { run_gsg: false, ..cfg };
        let names: Vec<String> =
            Explorer::default_phases(&nogsg).iter().map(|p| p.name().to_string()).collect();
        assert_eq!(names, vec!["heatmap", "OPSG"]);
    }

    #[test]
    fn ctx_abort_is_sticky_and_taken_once() {
        let dfgs = vec![benchmarks::benchmark("SOB")];
        let engine = MappingEngine::default();
        let cost = CostModel::area();
        let mut ctx =
            SearchCtx::new(&dfgs, &engine, &cost, [0; NUM_GROUPS], SearchConfig::default());
        assert!(!ctx.is_aborted());
        ctx.abort("first");
        ctx.abort("second");
        assert!(ctx.is_aborted());
        assert_eq!(ctx.take_abort().as_deref(), Some("first"));
        assert!(ctx.take_abort().is_none());
    }

    #[test]
    fn owned_channel_observer_streams_events_across_threads() {
        // the Send-compatible observer path: the session runs on a worker
        // thread and owns its observer; events arrive over the channel
        let (tx, rx) = std::sync::mpsc::channel();
        let worker = std::thread::spawn(move || {
            let dfgs = vec![benchmarks::benchmark("SOB")];
            let engine = MappingEngine::default();
            let cost = CostModel::area();
            Explorer::new(Grid::new(5, 5))
                .dfgs(&dfgs)
                .engine(&engine)
                .cost(&cost)
                .config(SearchConfig { l_test: 30, gsg_passes: 1, ..Default::default() })
                .observer_owned(Box::new(channel_observer(tx)))
                .run()
                .expect("SOB maps on 5x5")
        });
        // iteration ends when the sender drops, i.e. when the session ends
        let events: Vec<SearchEvent> = rx.iter().collect();
        let result = worker.join().unwrap();
        assert!(!events.is_empty());
        assert!(events
            .iter()
            .any(|e| matches!(e, SearchEvent::PhaseStarted { phase, .. } if phase == "heatmap")));
        let finishes = events
            .iter()
            .filter(|e| matches!(e, SearchEvent::PhaseFinished { .. }))
            .count();
        assert_eq!(finishes, 3, "one PhaseFinished per default-pipeline phase");
        // the channel trace agrees with the recorded stats trace
        let improvements = events
            .iter()
            .filter(|e| matches!(e, SearchEvent::Improved { .. }))
            .count();
        assert_eq!(improvements, result.stats.trace.len());
    }

    #[test]
    fn emit_improved_extends_trace_with_current_phase() {
        let dfgs = vec![benchmarks::benchmark("SOB")];
        let engine = MappingEngine::default();
        let cost = CostModel::area();
        let mut ctx =
            SearchCtx::new(&dfgs, &engine, &cost, [0; NUM_GROUPS], SearchConfig::default());
        ctx.begin_phase("custom", 10.0);
        ctx.emit_improved(5.0);
        assert_eq!(ctx.stats.trace.len(), 1);
        assert_eq!(ctx.stats.trace[0].phase, "custom");
        assert_eq!(ctx.stats.trace[0].best_cost, 5.0);
    }
}
