//! Pareto-mode genetic phase: an NSGA-II-style seeded loop over layout
//! support masks, run after the scalar pipeline in
//! [`super::SearchObjective::Pareto`] sessions.
//!
//! The scalar phases converge to one op-count-minimal layout; this
//! phase spreads the session's [`super::ParetoFront`] around it.
//! Genomes *are* layouts (per-compute-cell [`GroupSet`] support
//! vectors): crossover mixes parents per cell, mutation removes a
//! supported group or restores one from the full-support mask, and
//! feasibility is tested through the [`TestPool`]'s forked engines —
//! the same batched drivers the OPSG/GSG phases use.
//!
//! Determinism contract: the RNG is seeded from a fixed constant via
//! [`splitmix64`], offspring are generated *before* any testing, every
//! batch is consumed in full in generation order, and selection sorts
//! by `(Pareto rank, ops, area, power, fingerprint)` — so the tested
//! count, the front, the emitted event trace and the returned layout
//! are byte-identical at any `search_threads` width (pinned by the
//! property test in `rust/tests/properties.rs`).

use super::parallel::{SharedState, TestPool};
use super::pareto::{self, ParetoPoint};
use super::{meets_min_instances, SearchCtx, SearchEvent};
use crate::cgra::Layout;
use crate::dfg::groups_used;
use crate::mapper::Mapping;
use crate::ops::GroupSet;
use crate::util::rng::{splitmix64, Rng};
use std::collections::HashSet;

/// Seeded multi-objective exploration phase. Constructed by
/// [`super::Explorer::default_phases`] from
/// `SearchConfig::genetic_generations` / `genetic_population`.
pub struct GeneticPhase {
    pub generations: usize,
    pub population: usize,
}

impl GeneticPhase {
    pub const NAME: &'static str = "genetic";

    /// RNG seed domain: fixed, so the phase is a pure function of the
    /// incumbent and configuration (thread-count-invariant by
    /// construction).
    const SEED: u64 = 0x6765_6E65_7469_6331; // "genetic1"
}

/// One selection candidate: a feasible layout plus its objective point.
struct Member {
    layout: Layout,
    point: ParetoPoint,
}

/// NSGA-II-flavoured deterministic selection: non-dominated members
/// first, each tier ordered by the archive's total order, truncated to
/// `cap`.
fn select(mut members: Vec<Member>, cap: usize) -> Vec<Member> {
    let pts: Vec<ParetoPoint> = members.iter().map(|m| m.point.clone()).collect();
    let rank = |p: &ParetoPoint| -> usize {
        pts.iter().filter(|q| pareto::dominates(q, p)).count().min(1)
    };
    members.sort_by_key(|m| {
        (
            rank(&m.point),
            m.point.ops,
            m.point.area_um2.to_bits(),
            m.point.power_uw.to_bits(),
            m.point.fingerprint,
        )
    });
    members.truncate(cap.max(1));
    members
}

impl super::SearchPhase for GeneticPhase {
    fn name(&self) -> &str {
        Self::NAME
    }

    fn run(&mut self, incumbent: Layout, ctx: &mut SearchCtx) -> Layout {
        let dfgs = ctx.dfgs;
        if dfgs.is_empty() || self.generations == 0 {
            return incumbent;
        }
        let cfg = ctx.cfg.clone();
        let full_mask = groups_used(dfgs).intersect(GroupSet::all_compute());
        let compute: Vec<_> = incumbent.grid.compute_cells().collect();
        let pop_target = self.population.max(2);
        let mut rng = Rng::seed(splitmix64(Self::SEED));
        let mut pool = TestPool::for_search(ctx.engine, cfg.search_threads_resolved());
        let mut witness = std::mem::take(&mut ctx.witness);
        let all_dfgs: Vec<usize> = (0..dfgs.len()).collect();

        let mut best = incumbent.clone();
        let mut best_cost = ctx.cost.layout_cost(&best);
        ctx.record_front(&best);
        // every layout ever generated (population + offspring), so no
        // candidate is bred or tested twice
        let mut seen: HashSet<u64> = HashSet::new();
        seen.insert(pareto::layout_fingerprint(&incumbent));
        let mut members =
            vec![Member { point: pareto::evaluate(&incumbent), layout: incumbent }];

        for _gen in 0..self.generations {
            let remaining = cfg.l_test.saturating_sub(ctx.stats.tested);
            if remaining == 0 {
                break;
            }
            // ---- breed: offspring are fixed before any testing, so the
            // candidate sequence cannot depend on thread interleaving
            let mut offspring: Vec<Layout> = Vec::new();
            let mut attempts = 0usize;
            while offspring.len() < pop_target.min(remaining) && attempts < pop_target * 8 {
                attempts += 1;
                let a = &members[rng.below(members.len())].layout;
                let b = &members[rng.below(members.len())].layout;
                let mut child = a.clone();
                for &cell in &compute {
                    if rng.chance(0.5) {
                        child.set_support(cell, b.support(cell));
                    }
                }
                for _ in 0..=rng.below(2) {
                    let cell = compute[rng.below(compute.len())];
                    let support = child.support(cell);
                    let missing = full_mask.minus(support);
                    // bias toward removal: the front grows toward the
                    // cheap corner; restores keep feasibility reachable
                    if !support.is_empty() && (missing.is_empty() || rng.chance(0.7)) {
                        let gs: Vec<_> = support.iter().collect();
                        child.set_support(cell, support.without(*rng.choose(&gs)));
                    } else if !missing.is_empty() {
                        let gs: Vec<_> = missing.iter().collect();
                        child.set_support(cell, support.with(*rng.choose(&gs)));
                    }
                }
                if !meets_min_instances(&child, &ctx.min_insts) {
                    continue;
                }
                if seen.insert(pareto::layout_fingerprint(&child)) {
                    offspring.push(child);
                }
            }
            if offspring.is_empty() {
                continue;
            }

            // ---- batched feasibility testing, consumed in breed order
            let costs: Vec<f64> =
                offspring.iter().map(|l| ctx.cost.layout_cost(l)).collect();
            let mut survivors: Vec<usize> = Vec::new();
            let mut pending_witness: Option<Vec<(usize, Mapping)>> = None;
            {
                let shared = SharedState { dfgs, witness: &witness, affected: &all_dfgs };
                let items: Vec<(&Layout, bool)> =
                    offspring.iter().map(|l| (l, false)).collect();
                let mut prefetched = pool.prefetch(&shared, &items);
                for (i, child) in offspring.iter().enumerate() {
                    let t = match prefetched[i].take() {
                        Some(t) => t,
                        None => pool.test_one(&shared, child),
                    };
                    ctx.stats.tested += 1;
                    ctx.stats.expanded += 1;
                    ctx.emit(SearchEvent::LayoutTested {
                        feasible: t.feasible,
                        cost: costs[i],
                        tested: ctx.stats.tested,
                        worker: t.worker,
                    });
                    if t.feasible {
                        survivors.push(i);
                        ctx.record_front(child);
                        if costs[i] < best_cost {
                            best = child.clone();
                            best_cost = costs[i];
                            pending_witness = Some(t.witnesses);
                            ctx.emit_improved(best_cost);
                        }
                    }
                }
            }
            // witness updates outside the batch's shared snapshot, in
            // reduction order (only the last scalar improvement sticks)
            if let Some(ws) = pending_witness {
                for (di, m) in ws {
                    witness[di] = Some(m);
                }
            }

            // ---- deterministic environmental selection
            for i in survivors.into_iter().rev() {
                let layout = offspring.swap_remove(i);
                let point = pareto::evaluate(&layout);
                members.push(Member { layout, point });
            }
            members = select(members, pop_target);
        }

        ctx.witness = witness;
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::cost::CostModel;
    use crate::dfg::benchmarks;
    use crate::mapper::MappingEngine;
    use crate::search::{Explorer, SearchConfig, SearchObjective};

    fn pareto_cfg(l_test: usize) -> SearchConfig {
        SearchConfig {
            l_test,
            l_fail: 2,
            gsg_passes: 1,
            objective: SearchObjective::Pareto,
            genetic_generations: 4,
            genetic_population: 8,
            ..Default::default()
        }
    }

    #[test]
    fn pareto_session_keeps_the_scalar_result_on_the_front() {
        let dfgs = vec![benchmarks::benchmark("SOB"), benchmarks::benchmark("GB")];
        let grid = Grid::new(6, 6);
        let cost = CostModel::area();
        let scalar = {
            let engine = MappingEngine::default();
            let cfg = SearchConfig {
                objective: SearchObjective::OpCount,
                ..pareto_cfg(150)
            };
            Explorer::new(grid)
                .dfgs(&dfgs)
                .engine(&engine)
                .cost(&cost)
                .config(cfg)
                .run()
                .expect("scalar search maps")
        };
        assert!(scalar.front.is_empty(), "scalar sessions carry no front");
        let engine = MappingEngine::default();
        let r = Explorer::new(grid)
            .dfgs(&dfgs)
            .engine(&engine)
            .cost(&cost)
            .config(pareto_cfg(150))
            .run()
            .expect("pareto search maps");
        assert!(!r.front.is_empty());
        let scalar_ops = scalar.best_layout.compute_instances();
        assert!(
            r.front.iter().any(|p| p.ops <= scalar_ops),
            "the paper's scalar result must not regress: front {:?} vs {scalar_ops} ops",
            r.front
        );
        // the front never retains a dominated point, and the dominated
        // full-layout anchor is gone
        let full = pareto::evaluate(&r.full_layout);
        for p in &r.front {
            assert_ne!(p.fingerprint, full.fingerprint);
            assert!(!r.front.iter().any(|q| pareto::dominates(q, p)), "{p:?}");
        }
        // genetic ran and respected the budget
        assert!(r.stats.phase_secs.iter().any(|(n, _)| n == GeneticPhase::NAME));
        assert!(r.stats.tested <= 150);
    }

    #[test]
    fn selection_is_rank_then_objective_order() {
        let l = Layout::full(Grid::new(6, 6), GroupSet::all_compute());
        let cells: Vec<_> = l.grid.compute_cells().collect();
        let mk = |layout: Layout| Member { point: pareto::evaluate(&layout), layout };
        let dominated = mk(l.clone());
        let better = mk(l.without_group(cells[0], crate::ops::OpGroup::Div));
        let sel = select(vec![dominated, better], 2);
        assert_eq!(sel.len(), 2);
        assert!(sel[0].point.ops < sel[1].point.ops, "non-dominated tier sorts first");
        let sel = select(
            vec![mk(l.clone()), mk(l.without_group(cells[0], crate::ops::OpGroup::Div))],
            1,
        );
        assert_eq!(sel.len(), 1);
        assert!(sel[0].point.ops < l.compute_instances());
    }
}
