//! Frequent-subgraph seeding (Melchert et al.-style): mine recurring
//! connected motifs across the input DFG collection and start the
//! search from a near-minimal layout covering them, instead of the
//! full/heatmap layout.
//!
//! Enabled by `SearchConfig::subgraph_seed`; runs right after the
//! heatmap phase. The mining is a deterministic enumeration of
//! group-labelled edge motifs `(group(u), group(v))` over every DFG, in
//! input order, with a fixed size cap — no RNG, no hashing-order
//! dependence. The seed layout packs each group's theoretical-minimum
//! instance count (plus motif-weighted headroom) onto the first compute
//! cells in row-major order, co-locating frequently adjacent groups on
//! the same cells so motif instances map without long routes.
//!
//! Fallback contract: the phase *never* fails the session. The seed is
//! adopted only when every DFG maps on it **and** it beats the
//! incumbent's scalar cost; otherwise the incumbent passes through
//! untouched (one tested subproblem spent from the `L_test` budget).

use super::{meets_min_instances, SearchCtx, SearchEvent};
use crate::cgra::Layout;
use crate::mapper::MapOutcome;
use crate::ops::{OpGroup, COMPUTE_GROUPS, NUM_GROUPS};

/// Most-frequent motifs that earn headroom instances in the seed.
const MAX_MOTIFS: usize = 8;

/// The seeding phase. Stateless: everything derives from the session
/// context.
pub struct SubgraphSeedPhase;

impl SubgraphSeedPhase {
    pub const NAME: &'static str = "subgraph";
}

/// Deterministic motif mining: frequency of every compute-group edge
/// pair `(group(src), group(dst))` across the DFG set, as a dense
/// matrix (enumeration order cannot leak into the result).
fn motif_counts(dfgs: &[crate::dfg::Dfg]) -> [[usize; NUM_GROUPS]; NUM_GROUPS] {
    let mut counts = [[0usize; NUM_GROUPS]; NUM_GROUPS];
    for d in dfgs {
        for &(u, v) in &d.edges {
            let gu = d.nodes[u as usize].group();
            let gv = d.nodes[v as usize].group();
            if gu != OpGroup::Mem && gv != OpGroup::Mem {
                counts[gu.index()][gv.index()] += 1;
            }
        }
    }
    counts
}

/// The top-`MAX_MOTIFS` pairs by `(count desc, src, dst)` — a total
/// order, so the cap is deterministic.
fn top_motifs(counts: &[[usize; NUM_GROUPS]; NUM_GROUPS]) -> Vec<(OpGroup, OpGroup)> {
    let mut pairs: Vec<(usize, OpGroup, OpGroup)> = Vec::new();
    for a in COMPUTE_GROUPS {
        for b in COMPUTE_GROUPS {
            let c = counts[a.index()][b.index()];
            if c > 0 {
                pairs.push((c, a, b));
            }
        }
    }
    pairs.sort_by(|x, y| y.0.cmp(&x.0).then(x.1.cmp(&y.1)).then(x.2.cmp(&y.2)));
    pairs.truncate(MAX_MOTIFS);
    pairs.into_iter().map(|(_, a, b)| (a, b)).collect()
}

/// Build the near-minimal seed: per-group instance targets are the
/// theoretical minimum plus one instance of headroom per mined motif
/// the group participates in, packed onto the first compute cells
/// (row-major) so co-frequent groups share cells and stay adjacent.
fn seed_layout(ctx: &SearchCtx, incumbent: &Layout) -> Layout {
    let grid = incumbent.grid;
    let motifs = top_motifs(&motif_counts(ctx.dfgs));
    let num_compute = grid.num_compute();
    let mut targets = [0usize; NUM_GROUPS];
    for g in COMPUTE_GROUPS {
        targets[g.index()] = ctx.min_insts[g.index()];
    }
    for (a, b) in motifs {
        if targets[a.index()] > 0 {
            targets[a.index()] = (targets[a.index()] + 1).min(num_compute);
        }
        if targets[b.index()] > 0 {
            targets[b.index()] = (targets[b.index()] + 1).min(num_compute);
        }
    }
    let mut seed = incumbent.empty_like();
    let compute: Vec<_> = grid.compute_cells().collect();
    for g in COMPUTE_GROUPS {
        for &cell in compute.iter().take(targets[g.index()].min(num_compute)) {
            seed.set_support(cell, seed.support(cell).with(g));
        }
    }
    seed
}

impl super::SearchPhase for SubgraphSeedPhase {
    fn name(&self) -> &str {
        Self::NAME
    }

    fn run(&mut self, incumbent: Layout, ctx: &mut SearchCtx) -> Layout {
        if ctx.dfgs.is_empty() || ctx.stats.tested >= ctx.cfg.l_test {
            return incumbent;
        }
        let seed = seed_layout(ctx, &incumbent);
        let seed_cost = ctx.cost.layout_cost(&seed);
        let incumbent_cost = ctx.cost.layout_cost(&incumbent);
        // only a strict scalar improvement that still meets the bounds
        // is worth one budget unit
        if seed_cost >= incumbent_cost || !meets_min_instances(&seed, &ctx.min_insts) {
            return incumbent;
        }
        ctx.stats.expanded += 1;
        // full-set serial test (one subproblem): motifs guide the seed,
        // the mapper decides
        let mut mappings = Vec::with_capacity(ctx.dfgs.len());
        for di in 0..ctx.dfgs.len() {
            match ctx.test_dfg(di, &seed) {
                MapOutcome::Mapped { mapping, .. } => mappings.push(mapping),
                MapOutcome::Failed { .. } => break,
            }
        }
        let feasible = mappings.len() == ctx.dfgs.len();
        ctx.stats.tested += 1;
        ctx.emit(SearchEvent::LayoutTested {
            feasible,
            cost: seed_cost,
            tested: ctx.stats.tested,
            worker: 0,
        });
        if !feasible {
            return incumbent; // fallback: the session continues unharmed
        }
        ctx.witness = mappings.into_iter().map(Some).collect();
        // the seed replaces the heatmap/full start: it is the new
        // reduction baseline
        ctx.initial = Some(seed.clone());
        ctx.emit_improved(seed_cost);
        seed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::cost::CostModel;
    use crate::dfg::benchmarks;
    use crate::mapper::MappingEngine;
    use crate::search::{Explorer, SearchConfig, SearchPhase};

    #[test]
    fn motif_mining_is_deterministic_and_capped() {
        let dfgs = vec![benchmarks::benchmark("SOB"), benchmarks::benchmark("MD")];
        let a = top_motifs(&motif_counts(&dfgs));
        let b = top_motifs(&motif_counts(&dfgs));
        assert_eq!(a, b);
        assert!(a.len() <= MAX_MOTIFS);
        assert!(!a.is_empty(), "real benchmarks have compute-compute edges");
    }

    #[test]
    fn seed_meets_min_instances_and_is_near_minimal() {
        let dfgs = vec![benchmarks::benchmark("SOB"), benchmarks::benchmark("GB")];
        let engine = MappingEngine::default();
        let cost = CostModel::area();
        let mins = crate::dfg::min_group_instances(&dfgs);
        let ctx = SearchCtx::new(&dfgs, &engine, &cost, mins, SearchConfig::default());
        let grid = Grid::new(7, 7);
        let seed = seed_layout(&ctx, grid);
        assert!(meets_min_instances(&seed, &mins));
        let full = Layout::full(grid, crate::dfg::groups_used(&dfgs));
        assert!(seed.compute_instances() < full.compute_instances());
    }

    #[test]
    fn phase_adopts_or_falls_back_but_never_fails() {
        // a grid barely fitting the DFG makes the packed seed unroutable
        // often enough to exercise the fallback; either way the phase
        // must return a feasible incumbent and never abort
        for name in ["SOB", "GB", "MD"] {
            let dfgs = vec![benchmarks::benchmark(name)];
            let engine = MappingEngine::default();
            let cost = CostModel::area();
            let mins = crate::dfg::min_group_instances(&dfgs);
            let mut ctx =
                SearchCtx::new(&dfgs, &engine, &cost, mins, SearchConfig::default());
            let full = Layout::full(Grid::new(6, 6), crate::dfg::groups_used(&dfgs));
            let mappings = engine.map_all(&dfgs, &full).expect("full maps");
            ctx.witness = mappings.into_iter().map(Some).collect();
            let out = SubgraphSeedPhase.run(full.clone(), &mut ctx);
            assert!(!ctx.is_aborted(), "{name}: the seed phase must never fail");
            // whatever came back is feasible under the session witnesses
            for (di, d) in dfgs.iter().enumerate() {
                match &ctx.witness[di] {
                    Some(w) => assert!(w.validate(d, &out).is_empty(), "{name}"),
                    None => panic!("{name}: witnesses must survive the phase"),
                }
            }
        }
    }

    #[test]
    fn pipeline_with_seed_phase_completes_end_to_end() {
        let dfgs = vec![benchmarks::benchmark("SOB"), benchmarks::benchmark("GB")];
        let engine = MappingEngine::default();
        let cost = CostModel::area();
        let cfg = SearchConfig {
            l_test: 150,
            l_fail: 2,
            gsg_passes: 1,
            subgraph_seed: true,
            ..Default::default()
        };
        let r = Explorer::new(Grid::new(7, 7))
            .dfgs(&dfgs)
            .engine(&engine)
            .cost(&cost)
            .config(cfg)
            .run()
            .expect("seeded pipeline still completes");
        assert!(r.stats.phase_secs.iter().any(|(n, _)| n == SubgraphSeedPhase::NAME));
        assert!(r.best_cost < cost.layout_cost(&r.full_layout));
        for (di, d) in dfgs.iter().enumerate() {
            assert!(r.final_mappings[di].validate(d, &r.best_layout).is_empty());
        }
    }
}
