//! The HeLEx search (paper Section III), exposed as an [`Explorer`]
//! session of pluggable [`SearchPhase`]s.
//!
//! The paper's Algorithm 1 is the default pipeline:
//!
//! 1. [`HeatmapPhase`] ([`heatmap`]) — initial layout: map each DFG
//!    individually on the full layout, overlay the per-cell usage into a
//!    heterogeneous heatmap layout, and keep it if all DFGs re-map (else
//!    fall back to full).
//! 2. [`OpsgPhase`] ([`opsg`]) — BB search removing one operation group
//!    at a time, most expensive group first, with *selective testing*
//!    (only DFGs that use the removed group are re-mapped).
//! 3. [`GsgPhase`] ([`gsg`]) — BB search removing arbitrary group
//!    combinations with a `failChart` pruning memory and full-set
//!    testing.
//!
//! Two optional phases extend the pipeline: [`SubgraphSeedPhase`]
//! ([`subgraph`], `SearchConfig::subgraph_seed`) mines frequent DFG
//! motifs and tries a near-minimal seed layout after the heatmap, and
//! in [`SearchObjective::Pareto`] mode ([`pareto`]) a [`GeneticPhase`]
//! ([`genetic`]) runs last, growing a deterministic [`ParetoFront`]
//! over `(ops, area_um2, power_uw)` whose improvements stream as
//! [`SearchEvent::ParetoPoint`] events (anytime fronts).
//!
//! All phases share one [`SearchCtx`] (DFG set, mapping engine, cost
//! model, bounds, config, stats, stopwatch, scorer, witness cache) and
//! report progress as [`SearchEvent`]s to an optional [`SearchObserver`];
//! the convergence trace used by Figs 3–6 and Table IV is recorded from
//! the event stream. Feasibility testing consumes structured
//! [`crate::mapper::MapOutcome`]s from the [`crate::mapper::MappingEngine`],
//! warm-starting each candidate test from the cached witness mapping —
//! the OPSG/GSG phases route tests through the [`parallel`] worker
//! pool's forked engines (see below); [`SearchCtx::test_dfg`] remains
//! as the serial helper for custom phases that do not need the pool.
//! [`run`] is the legacy entry point, kept as a thin wrapper over
//! [`Explorer`].
//!
//! ## Parallel candidate testing (deterministic)
//!
//! Candidates within one OPSG queue fill — and sibling expansions of a
//! GSG frontier slice — are independent mapping problems, so both
//! phases feasibility-test them on a scoped worker pool of
//! [`SearchConfig::search_threads`] threads ([`parallel::TestPool`]),
//! each worker owning a [forked](crate::mapper::MappingEngine::fork)
//! engine so the mapping hot path stays lock-free. Results are merged
//! by a *deterministic reduction*: the winner is always the first
//! feasible candidate in the original branching order, speculative
//! tests that lose the race are folded into
//! [`SearchStats::speculative`] but cannot change anything, and all
//! search-state mutation (witnesses, OPSG's failed set, GSG's
//! failChart) happens in branching order on the reduction thread. The
//! consequence is a hard contract: **thread count can never change a
//! result** — layouts, result tables and the recorded
//! [`SearchEvent`] trace are byte-identical for any `search_threads`
//! (CI's `search-determinism` job and the property test in
//! `rust/tests/explorer.rs` pin this). See [`parallel`] for the three
//! rules that make the contract hold.

pub mod explorer;
pub mod genetic;
pub mod gsg;
pub mod heatmap;
pub mod opsg;
pub mod parallel;
pub mod pareto;
pub mod posteriori;
pub mod subgraph;

pub use explorer::{
    channel_observer, ExploreError, Explorer, GsgPhase, HeatmapPhase, OpsgPhase, SearchCtx,
    SearchEvent, SearchObserver, SearchPhase,
};
pub use genetic::GeneticPhase;
pub use pareto::{ParetoFront, ParetoPoint, SearchObjective};
pub use subgraph::SubgraphSeedPhase;

use crate::cgra::Layout;
use crate::cost::CostModel;
use crate::dfg::Dfg;
use crate::mapper::Mapper;
use crate::ops::NUM_GROUPS;

/// One point of the convergence trace (Fig 5): cost of the incumbent best
/// layout at a given wall time / tested-layout count. Recorded from
/// [`SearchEvent::Improved`] events; `phase` is the emitting phase's
/// name (e.g. `"heatmap"`, `"OPSG"`, `"GSG"`).
#[derive(Debug, Clone)]
pub struct TracePoint {
    pub phase: String,
    pub secs: f64,
    pub tested: usize,
    pub best_cost: f64,
}

/// Search configuration (Algorithm 1 inputs + engineering knobs).
///
/// `Hash` participates in the service's job fingerprints (run-cache key
/// + per-job seed derivation). It is implemented manually with an
/// exhaustive destructuring so any field added here forces a decision:
/// result-relevant fields hash, pure execution knobs (currently only
/// [`Self::search_threads`]) are explicitly skipped.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Mapper-invocation budget `L_test` (paper: 2000 for 10×10, grown
    /// with instance size).
    pub l_test: usize,
    /// GSG failChart threshold `L_fail`.
    pub l_fail: usize,
    /// Run the GSG phase (Section IV-G allows disabling it).
    pub run_gsg: bool,
    /// Number of GSG passes (the paper runs GSG twice).
    pub gsg_passes: usize,
    /// Prune GSG queue entries whose cost is too far from best after this
    /// many consecutive non-improving iterations.
    pub gsg_stale_prune_after: usize,
    /// Attempt the heatmap initial layout.
    pub use_heatmap: bool,
    /// Skip the Arith group in OPSG (the paper's `noGSG` variant is
    /// "HeLEx without targeting the Arith group and without running GSG",
    /// Section IV-G).
    pub opsg_skip_arith: bool,
    /// Worker threads for in-search candidate testing (OPSG queue fills,
    /// GSG frontier batches); `0` means available parallelism. A pure
    /// execution knob: the deterministic reduction ([`parallel`])
    /// guarantees byte-identical results at any value, so it is excluded
    /// from `Hash` — and therefore from job fingerprints and derived
    /// seeds — on purpose.
    pub search_threads: usize,
    /// What the search minimises: the paper's scalar op-count, or the
    /// three-objective `(ops, area, power)` Pareto mode (which appends a
    /// [`GeneticPhase`] to the pipeline and streams
    /// [`SearchEvent::ParetoPoint`] improvements).
    pub objective: SearchObjective,
    /// Generations of the Pareto-mode [`GeneticPhase`].
    pub genetic_generations: usize,
    /// Population size of the Pareto-mode [`GeneticPhase`].
    pub genetic_population: usize,
    /// Run the [`SubgraphSeedPhase`] after the heatmap: mine frequent
    /// DFG motifs and try a near-minimal seed layout instead of the
    /// heatmap start, falling back when it does not map.
    pub subgraph_seed: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            l_test: 2000,
            l_fail: 3,
            run_gsg: true,
            gsg_passes: 2,
            gsg_stale_prune_after: 64,
            use_heatmap: true,
            opsg_skip_arith: false,
            search_threads: 0,
            objective: SearchObjective::OpCount,
            genetic_generations: 8,
            genetic_population: 16,
            subgraph_seed: false,
        }
    }
}

impl std::hash::Hash for SearchConfig {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        // Exhaustive destructuring: a field added to the struct breaks
        // this impl until someone decides whether it is result-relevant.
        // `search_threads` is skipped: any thread count computes the
        // same result, so it must share one cache slot and one derived
        // seed (see the `fingerprint_ignores_label_and_tracks_content`
        // service test).
        let Self {
            l_test,
            l_fail,
            run_gsg,
            gsg_passes,
            gsg_stale_prune_after,
            use_heatmap,
            opsg_skip_arith,
            search_threads: _,
            objective,
            genetic_generations,
            genetic_population,
            subgraph_seed,
        } = self;
        l_test.hash(state);
        l_fail.hash(state);
        run_gsg.hash(state);
        gsg_passes.hash(state);
        gsg_stale_prune_after.hash(state);
        use_heatmap.hash(state);
        opsg_skip_arith.hash(state);
        objective.hash(state);
        genetic_generations.hash(state);
        genetic_population.hash(state);
        subgraph_seed.hash(state);
    }
}

/// Compute cells of the paper's 10×10 reference instance: a T-CGRA grid
/// carries a one-cell I/O border, so a 10×10 grid has an 8×8 = 64-cell
/// compute core. `L_test` budgets are quoted at this size and scaled.
const REF_COMPUTE_CELLS: usize = 8 * 8;

impl SearchConfig {
    /// Paper rule: `L_test` = 2000 at the 10×10 reference size, scaled
    /// with compute-cell count for larger instances.
    pub fn l_test_for(grid: crate::cgra::Grid) -> usize {
        Self::scale_l_test(2000, grid)
    }

    /// Scaling rule for mapper-invocation budgets: `base` is the budget
    /// at the 10×10 reference instance (64 compute cells) and grows
    /// proportionally with the target grid's compute-cell count,
    /// rounded up: `ceil(base · num_compute / 64)`.
    pub fn scale_l_test(base: usize, grid: crate::cgra::Grid) -> usize {
        (base * grid.num_compute() + REF_COMPUTE_CELLS - 1) / REF_COMPUTE_CELLS
    }

    /// Effective in-search worker count: [`Self::search_threads`], or
    /// the machine's available parallelism when it is `0`.
    pub fn search_threads_resolved(&self) -> usize {
        if self.search_threads > 0 {
            self.search_threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

/// Statistics of one HeLEx run (Table IV + Figs 3/5/6 inputs).
#[derive(Debug, Clone, Default)]
pub struct SearchStats {
    /// Subproblems expanded (`S_exp`): layouts generated into queues.
    pub expanded: usize,
    /// Subproblems tested with the mapper (`S_tst`). Counts exactly the
    /// tests a serial run would perform — identical at any thread count.
    pub tested: usize,
    /// Speculative candidate tests whose results the deterministic
    /// reduction discarded (they lost the branching-order race).
    /// Depends on thread count and timing, so it is diagnostic only:
    /// excluded from result tables, wire records and compared traces.
    pub speculative: usize,
    /// Wall seconds per executed phase, in pipeline order (one entry per
    /// phase execution; repeated phases accumulate entries).
    pub phase_secs: Vec<(String, f64)>,
    /// Whether the heatmap was usable as the initial layout.
    pub heatmap_used: bool,
    /// Per-group instances of the full layout.
    pub insts_full: [usize; NUM_GROUPS],
    /// Per-group instance counts after each executed phase, in pipeline
    /// order (for the Fig 3 breakdown).
    pub insts_after_phase: Vec<(String, [usize; NUM_GROUPS])>,
    /// Convergence trace.
    pub trace: Vec<TracePoint>,
}

impl SearchStats {
    /// Total wall seconds across every phase.
    pub fn t_total(&self) -> f64 {
        self.phase_secs.iter().map(|(_, s)| *s).sum()
    }

    /// Wall seconds spent in phases named `name` (0.0 if it never ran).
    pub fn phase_secs_for(&self, name: &str) -> f64 {
        self.phase_secs.iter().filter(|(n, _)| n.as_str() == name).map(|(_, s)| *s).sum()
    }

    pub fn t_heatmap(&self) -> f64 {
        self.phase_secs_for(HeatmapPhase::NAME)
    }

    pub fn t_opsg(&self) -> f64 {
        self.phase_secs_for(OpsgPhase::NAME)
    }

    pub fn t_gsg(&self) -> f64 {
        self.phase_secs_for(GsgPhase::NAME)
    }

    /// Instance counts after the last execution of phase `name`, if it
    /// ran.
    pub fn insts_after(&self, name: &str) -> Option<[usize; NUM_GROUPS]> {
        self.insts_after_phase
            .iter()
            .rev()
            .find(|(n, _)| n.as_str() == name)
            .map(|(_, v)| *v)
    }

    /// Instance counts after the final phase (the full layout's counts
    /// if no phase ran).
    pub fn insts_final(&self) -> [usize; NUM_GROUPS] {
        self.insts_after_phase.last().map(|(_, v)| *v).unwrap_or(self.insts_full)
    }
}

/// Result of a full HeLEx run.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub full_layout: Layout,
    pub initial_layout: Layout,
    pub best_layout: Layout,
    pub best_cost: f64,
    pub min_insts: [usize; NUM_GROUPS],
    /// Feasibility witnesses: one valid mapping per input DFG for
    /// `best_layout` (same order as the input slice). The search accepts
    /// layouts whose feasibility is proven by a cached witness even when
    /// the heuristic mapper cannot re-derive a mapping from scratch, so
    /// consumers must use these instead of re-mapping.
    pub final_mappings: Vec<crate::mapper::Mapping>,
    /// The final Pareto front ([`SearchObjective::Pareto`] sessions;
    /// empty for scalar runs). Deterministic archive order — byte-stable
    /// at any thread count.
    pub front: Vec<ParetoPoint>,
    pub stats: SearchStats,
}

/// Algorithm 1: run HeLEx on a DFG set and target grid.
///
/// Legacy entry point, kept as a thin wrapper over the [`Explorer`]
/// session API with the default phase pipeline. `scorer` optionally
/// batches candidate-cost evaluation through the AOT XLA artifact (see
/// `runtime`); pass `None` to use the native evaluator only.
pub fn run(
    dfgs: &[Dfg],
    grid: crate::cgra::Grid,
    mapper: &Mapper,
    cost: &CostModel,
    cfg: &SearchConfig,
    scorer: Option<&mut dyn BatchScorer>,
) -> Option<SearchResult> {
    let mut explorer =
        Explorer::new(grid).dfgs(dfgs).mapper(mapper).cost(cost).config(cfg.clone());
    if let Some(s) = scorer {
        explorer = explorer.scorer(s);
    }
    explorer.run().ok()
}

/// Batched candidate-cost evaluation interface, implemented by
/// `runtime::Scorer` over the AOT XLA artifact. Candidates are described
/// by their per-group instance vectors; the scorer returns Equation-1
/// costs in the same order.
pub trait BatchScorer {
    fn score(
        &mut self,
        num_compute_cells: usize,
        instance_vectors: &[[usize; NUM_GROUPS]],
    ) -> Vec<f64>;
}

/// Native (non-XLA) reference scorer; also used when artifacts are
/// unavailable.
pub struct NativeScorer {
    pub cost: CostModel,
}

impl BatchScorer for NativeScorer {
    fn score(
        &mut self,
        num_compute_cells: usize,
        instance_vectors: &[[usize; NUM_GROUPS]],
    ) -> Vec<f64> {
        let base = num_compute_cells as f64
            * (self.cost.components.empty_cell + self.cost.components.fifos);
        instance_vectors
            .iter()
            .map(|n| base + self.cost.instances_cost(n))
            .collect()
    }
}

/// Validity check shared by both branching strategies: a layout may only
/// enter a queue if it still meets the theoretical minimum instance
/// counts (Section III-D pruning).
pub fn meets_min_instances(layout: &Layout, min_insts: &[usize; NUM_GROUPS]) -> bool {
    let n = layout.compute_group_instances();
    (0..NUM_GROUPS).all(|i| {
        // Mem lives on I/O cells and is not tracked on compute cells.
        i == crate::ops::OpGroup::Mem.index() || n[i] >= min_insts[i]
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::dfg::benchmarks;
    use crate::ops::OpGroup;

    fn small_cfg() -> SearchConfig {
        SearchConfig { l_test: 120, l_fail: 2, gsg_passes: 1, ..Default::default() }
    }

    #[test]
    fn end_to_end_search_reduces_cost() {
        let dfgs = vec![benchmarks::benchmark("SOB"), benchmarks::benchmark("GB")];
        let grid = Grid::new(6, 6);
        let mapper = Mapper::default();
        let cost = CostModel::area();
        let r = run(&dfgs, grid, &mapper, &cost, &small_cfg(), None).expect("feasible");
        assert!(r.best_cost <= cost.layout_cost(&r.initial_layout));
        assert!(r.best_cost < cost.layout_cost(&r.full_layout));
        // result is feasible: every DFG has a valid witness mapping
        for (di, d) in dfgs.iter().enumerate() {
            assert!(r.final_mappings[di].validate(d, &r.best_layout).is_empty());
        }
        // and must respect the theoretical minimum
        assert!(meets_min_instances(&r.best_layout, &r.min_insts));
        // stats populated
        assert!(r.stats.tested > 0);
        assert!(r.stats.expanded >= r.stats.tested);
        assert!(!r.stats.trace.is_empty());
        // one stats entry per default-pipeline phase
        assert_eq!(r.stats.phase_secs.len(), 3);
        assert_eq!(r.stats.insts_after_phase.len(), 3);
    }

    #[test]
    fn infeasible_set_returns_none() {
        let dfgs = vec![benchmarks::benchmark("SAD")]; // 63 compute ops
        let grid = Grid::new(5, 5); // 9 compute cells
        let r = run(&dfgs, grid, &Mapper::default(), &CostModel::area(), &small_cfg(), None);
        assert!(r.is_none());
    }

    #[test]
    fn min_instances_pruning_rule() {
        let grid = Grid::new(5, 5);
        let l = Layout::full(grid, crate::ops::GroupSet::all_compute());
        let mut mins = [0usize; NUM_GROUPS];
        assert!(meets_min_instances(&l, &mins));
        mins[OpGroup::Arith.index()] = 9;
        assert!(meets_min_instances(&l, &mins)); // 9 compute cells
        mins[OpGroup::Arith.index()] = 10;
        assert!(!meets_min_instances(&l, &mins));
        // Mem mins never block
        mins[OpGroup::Arith.index()] = 0;
        mins[OpGroup::Mem.index()] = 1000;
        assert!(meets_min_instances(&l, &mins));
    }

    #[test]
    fn native_scorer_matches_cost_model() {
        let cost = CostModel::area();
        let grid = Grid::new(6, 6);
        let l = Layout::full(grid, crate::ops::GroupSet::all_compute());
        let mut s = NativeScorer { cost: cost.clone() };
        let v = s.score(grid.num_compute(), &[l.compute_group_instances()]);
        assert!((v[0] - cost.layout_cost(&l)).abs() < 1e-9);
    }

    #[test]
    fn l_test_scales_with_size() {
        assert_eq!(SearchConfig::l_test_for(Grid::new(10, 10)), 2000);
        assert!(SearchConfig::l_test_for(Grid::new(13, 15)) > 2000);
        // the documented rule: ceil(base * num_compute / 64)
        let g = Grid::new(12, 12); // 10x10 compute core = 100 cells
        assert_eq!(SearchConfig::scale_l_test(2000, g), (2000 * 100 + 63) / 64);
        assert_eq!(SearchConfig::scale_l_test(64, Grid::new(10, 10)), 64);
    }

    #[test]
    fn search_threads_is_excluded_from_the_config_hash() {
        use crate::util::StableHasher;
        use std::hash::{Hash, Hasher};
        let fp = |cfg: &SearchConfig| {
            let mut h = StableHasher::new();
            cfg.hash(&mut h);
            h.finish()
        };
        let a = SearchConfig::default();
        let b = SearchConfig { search_threads: 8, ..a.clone() };
        assert_eq!(
            fp(&a),
            fp(&b),
            "search_threads is an execution knob: it must not change job fingerprints"
        );
        let c = SearchConfig { l_test: a.l_test + 1, ..a.clone() };
        assert_ne!(fp(&a), fp(&c), "result-relevant fields must still hash");
    }

    #[test]
    fn search_threads_resolution() {
        let auto = SearchConfig::default();
        assert!(auto.search_threads_resolved() >= 1);
        let fixed = SearchConfig { search_threads: 3, ..Default::default() };
        assert_eq!(fixed.search_threads_resolved(), 3);
    }

    #[test]
    fn nogsg_skips_gsg_phase() {
        let dfgs = vec![benchmarks::benchmark("SOB")];
        let grid = Grid::new(5, 5);
        let cfg = SearchConfig { run_gsg: false, ..small_cfg() };
        let r = run(&dfgs, grid, &Mapper::default(), &CostModel::area(), &cfg, None).unwrap();
        assert!(r.stats.insts_after(GsgPhase::NAME).is_none());
        assert_eq!(r.stats.insts_final(), r.stats.insts_after(OpsgPhase::NAME).unwrap());
        assert_eq!(r.stats.t_gsg(), 0.0);
        assert!(!r.stats.trace.iter().any(|t| t.phase == GsgPhase::NAME));
    }

    #[test]
    fn stats_phase_accessors() {
        let mut s = SearchStats { insts_full: [9; NUM_GROUPS], ..Default::default() };
        assert_eq!(s.insts_final(), [9; NUM_GROUPS]);
        s.phase_secs.push(("GSG".into(), 1.0));
        s.phase_secs.push(("GSG".into(), 2.0));
        s.phase_secs.push(("OPSG".into(), 4.0));
        assert_eq!(s.t_gsg(), 3.0);
        assert_eq!(s.t_opsg(), 4.0);
        assert_eq!(s.t_heatmap(), 0.0);
        assert_eq!(s.t_total(), 7.0);
        s.insts_after_phase.push(("OPSG".into(), [5; NUM_GROUPS]));
        s.insts_after_phase.push(("GSG".into(), [3; NUM_GROUPS]));
        assert_eq!(s.insts_after("OPSG"), Some([5; NUM_GROUPS]));
        assert_eq!(s.insts_final(), [3; NUM_GROUPS]);
        assert_eq!(s.insts_after("heatmap"), None);
    }
}
