//! The HeLEx search (paper Section III).
//!
//! Three phases, mirroring Algorithm 1:
//!
//! 1. [`heatmap`] — initial layout: map each DFG individually on the full
//!    layout, overlay the per-cell usage into a heterogeneous heatmap
//!    layout, and keep it if all DFGs re-map (else fall back to full).
//! 2. [`opsg`] — BB search removing one operation group at a time, most
//!    expensive group first, with *selective testing* (only DFGs that use
//!    the removed group are re-mapped).
//! 3. [`gsg`] — BB search removing arbitrary group combinations with a
//!    `failChart` pruning memory and full-set testing.
//!
//! [`run`] drives all three and records per-phase statistics and the
//! convergence trace used by Figs 3–6 and Table IV.

pub mod gsg;
pub mod heatmap;
pub mod opsg;
pub mod posteriori;

use crate::cgra::Layout;
use crate::cost::CostModel;
use crate::dfg::{min_group_instances, Dfg};
use crate::mapper::Mapper;
use crate::ops::NUM_GROUPS;
use crate::util::Stopwatch;

/// Which phase produced an event / a removal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Heatmap,
    Opsg,
    Gsg,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Heatmap => "heatmap",
            Phase::Opsg => "OPSG",
            Phase::Gsg => "GSG",
        }
    }
}

/// One point of the convergence trace (Fig 5): cost of the incumbent best
/// layout at a given wall time / tested-layout count.
#[derive(Debug, Clone)]
pub struct TracePoint {
    pub phase: Phase,
    pub secs: f64,
    pub tested: usize,
    pub best_cost: f64,
}

/// Search configuration (Algorithm 1 inputs + engineering knobs).
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Mapper-invocation budget `L_test` (paper: 2000 for 10×10, grown
    /// with instance size).
    pub l_test: usize,
    /// GSG failChart threshold `L_fail`.
    pub l_fail: usize,
    /// Run the GSG phase (Section IV-G allows disabling it).
    pub run_gsg: bool,
    /// Number of GSG passes (the paper runs GSG twice).
    pub gsg_passes: usize,
    /// Prune GSG queue entries whose cost is too far from best after this
    /// many consecutive non-improving iterations.
    pub gsg_stale_prune_after: usize,
    /// Attempt the heatmap initial layout.
    pub use_heatmap: bool,
    /// Skip the Arith group in OPSG (the paper's `noGSG` variant is
    /// "HeLEx without targeting the Arith group and without running GSG",
    /// Section IV-G).
    pub opsg_skip_arith: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            l_test: 2000,
            l_fail: 3,
            run_gsg: true,
            gsg_passes: 2,
            gsg_stale_prune_after: 64,
            use_heatmap: true,
            opsg_skip_arith: false,
        }
    }
}

impl SearchConfig {
    /// Paper rule: `L_test` = 2000 at 10×10, scaled with compute-cell
    /// count for larger instances.
    pub fn l_test_for(grid: crate::cgra::Grid) -> usize {
        let base_cells = 8 * 8; // 10x10 compute cells
        (2000 * grid.num_compute() + base_cells - 1) / base_cells
    }
}

/// Statistics of one HeLEx run (Table IV + Figs 3/5/6 inputs).
#[derive(Debug, Clone, Default)]
pub struct SearchStats {
    /// Subproblems expanded (`S_exp`): layouts generated into queues.
    pub expanded: usize,
    /// Subproblems tested with the mapper (`S_tst`).
    pub tested: usize,
    /// Wall time per phase, seconds.
    pub t_heatmap: f64,
    pub t_opsg: f64,
    pub t_gsg: f64,
    /// Whether the heatmap was usable as the initial layout.
    pub heatmap_used: bool,
    /// Per-group instances after each phase (for the Fig 3 breakdown).
    pub insts_full: [usize; NUM_GROUPS],
    pub insts_after_heatmap: [usize; NUM_GROUPS],
    pub insts_after_opsg: [usize; NUM_GROUPS],
    pub insts_after_gsg: [usize; NUM_GROUPS],
    /// Convergence trace.
    pub trace: Vec<TracePoint>,
}

impl SearchStats {
    pub fn t_total(&self) -> f64 {
        self.t_heatmap + self.t_opsg + self.t_gsg
    }
}

/// Result of a full HeLEx run.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub full_layout: Layout,
    pub initial_layout: Layout,
    pub best_layout: Layout,
    pub best_cost: f64,
    pub min_insts: [usize; NUM_GROUPS],
    /// Feasibility witnesses: one valid mapping per input DFG for
    /// `best_layout` (same order as the input slice). The search accepts
    /// layouts whose feasibility is proven by a cached witness even when
    /// the heuristic mapper cannot re-derive a mapping from scratch, so
    /// consumers must use these instead of re-mapping.
    pub final_mappings: Vec<crate::mapper::Mapping>,
    pub stats: SearchStats,
}

/// Algorithm 1: run HeLEx on a DFG set and target grid.
///
/// `scorer` optionally batches candidate-cost evaluation through the AOT
/// XLA artifact (see `runtime`); pass `None` to use the native evaluator
/// only.
pub fn run(
    dfgs: &[Dfg],
    grid: crate::cgra::Grid,
    mapper: &Mapper,
    cost: &CostModel,
    cfg: &SearchConfig,
    mut scorer: Option<&mut dyn BatchScorer>,
) -> Option<SearchResult> {
    let mut stats = SearchStats::default();
    let sw = Stopwatch::start();

    // line 1: minimum group instances
    let min_insts = min_group_instances(dfgs);

    // full layout over the groups the DFG set actually uses (Section IV-F)
    let full_layout = Layout::full(grid, crate::dfg::groups_used(dfgs));
    stats.insts_full = full_layout.compute_group_instances();

    // lines 2-4: initial layout (heatmap if possible, else full —
    // terminate in failure if even the full layout does not map)
    let hm_sw = Stopwatch::start();
    let initial_layout = if cfg.use_heatmap {
        match heatmap::initial_layout(dfgs, &full_layout, mapper) {
            heatmap::HeatmapOutcome::Heatmap(l) => {
                stats.heatmap_used = true;
                l
            }
            heatmap::HeatmapOutcome::FullFallback => full_layout.clone(),
            heatmap::HeatmapOutcome::Infeasible => return None,
        }
    } else {
        if !mapper.test_layout(dfgs, &full_layout) {
            return None;
        }
        full_layout.clone()
    };
    stats.t_heatmap = hm_sw.secs();
    stats.insts_after_heatmap = initial_layout.compute_group_instances();
    stats.trace.push(TracePoint {
        phase: Phase::Heatmap,
        secs: sw.secs(),
        tested: stats.tested,
        best_cost: cost.layout_cost(&initial_layout),
    });

    // witnesses shared across phases, seeded with mappings on the
    // initial layout (which just passed test_layout): a DFG untouched by
    // every later removal keeps its seed witness valid to the end.
    let mut witness: Vec<Option<crate::mapper::Mapping>> =
        dfgs.iter().map(|d| mapper.map(d, &initial_layout)).collect();
    if witness.iter().any(Option::is_none) {
        return None; // initial layout no longer maps (should not happen)
    }

    // line 5: OPSG phase
    let opsg_sw = Stopwatch::start();
    let best = opsg::run(
        &initial_layout,
        dfgs,
        mapper,
        cost,
        &min_insts,
        cfg,
        &mut stats,
        &sw,
        &mut scorer,
        &mut witness,
    );
    stats.t_opsg = opsg_sw.secs();
    stats.insts_after_opsg = best.compute_group_instances();

    // line 6: GSG phase
    let gsg_sw = Stopwatch::start();
    let best = if cfg.run_gsg {
        let mut b = best;
        for _pass in 0..cfg.gsg_passes {
            b = gsg::run(
                &b,
                dfgs,
                mapper,
                cost,
                &min_insts,
                cfg,
                &mut stats,
                &sw,
                &mut scorer,
                &mut witness,
            );
        }
        b
    } else {
        best
    };
    stats.t_gsg = gsg_sw.secs();
    stats.insts_after_gsg = best.compute_group_instances();

    // materialize final witnesses: any DFG whose cached witness is
    // missing or stale gets a fresh mapping on the final layout (always
    // possible: its support was never removed from under a None witness
    // without a successful remap).
    let mut final_mappings = Vec::with_capacity(dfgs.len());
    for (di, d) in dfgs.iter().enumerate() {
        let w = match witness[di].take() {
            Some(w) if w.still_valid(d, &best) => w,
            _ => mapper
                .map(d, &best)
                .expect("accepted layout must be mappable for untouched DFGs"),
        };
        debug_assert!(w.validate(d, &best).is_empty());
        final_mappings.push(w);
    }

    let best_cost = cost.layout_cost(&best);
    Some(SearchResult {
        full_layout,
        initial_layout,
        best_layout: best,
        best_cost,
        min_insts,
        final_mappings,
        stats,
    })
}

/// Batched candidate-cost evaluation interface, implemented by
/// `runtime::Scorer` over the AOT XLA artifact. Candidates are described
/// by their per-group instance vectors; the scorer returns Equation-1
/// costs in the same order.
pub trait BatchScorer {
    fn score(
        &mut self,
        num_compute_cells: usize,
        instance_vectors: &[[usize; NUM_GROUPS]],
    ) -> Vec<f64>;
}

/// Native (non-XLA) reference scorer; also used when artifacts are
/// unavailable.
pub struct NativeScorer {
    pub cost: CostModel,
}

impl BatchScorer for NativeScorer {
    fn score(
        &mut self,
        num_compute_cells: usize,
        instance_vectors: &[[usize; NUM_GROUPS]],
    ) -> Vec<f64> {
        let base = num_compute_cells as f64
            * (self.cost.components.empty_cell + self.cost.components.fifos);
        instance_vectors
            .iter()
            .map(|n| base + self.cost.instances_cost(n))
            .collect()
    }
}

/// Validity check shared by both branching strategies: a layout may only
/// enter a queue if it still meets the theoretical minimum instance
/// counts (Section III-D pruning).
pub fn meets_min_instances(layout: &Layout, min_insts: &[usize; NUM_GROUPS]) -> bool {
    let n = layout.compute_group_instances();
    (0..NUM_GROUPS).all(|i| {
        // Mem lives on I/O cells and is not tracked on compute cells.
        i == crate::ops::OpGroup::Mem.index() || n[i] >= min_insts[i]
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::dfg::benchmarks;
    use crate::ops::OpGroup;

    fn small_cfg() -> SearchConfig {
        SearchConfig { l_test: 120, l_fail: 2, gsg_passes: 1, ..Default::default() }
    }

    #[test]
    fn end_to_end_search_reduces_cost() {
        let dfgs = vec![benchmarks::benchmark("SOB"), benchmarks::benchmark("GB")];
        let grid = Grid::new(6, 6);
        let mapper = Mapper::default();
        let cost = CostModel::area();
        let r = run(&dfgs, grid, &mapper, &cost, &small_cfg(), None).expect("feasible");
        assert!(r.best_cost <= cost.layout_cost(&r.initial_layout));
        assert!(r.best_cost < cost.layout_cost(&r.full_layout));
        // result is feasible: every DFG has a valid witness mapping
        for (di, d) in dfgs.iter().enumerate() {
            assert!(r.final_mappings[di].validate(d, &r.best_layout).is_empty());
        }
        // and must respect the theoretical minimum
        assert!(meets_min_instances(&r.best_layout, &r.min_insts));
        // stats populated
        assert!(r.stats.tested > 0);
        assert!(r.stats.expanded >= r.stats.tested);
        assert!(!r.stats.trace.is_empty());
    }

    #[test]
    fn infeasible_set_returns_none() {
        let dfgs = vec![benchmarks::benchmark("SAD")]; // 63 compute ops
        let grid = Grid::new(5, 5); // 9 compute cells
        let r = run(&dfgs, grid, &Mapper::default(), &CostModel::area(), &small_cfg(), None);
        assert!(r.is_none());
    }

    #[test]
    fn min_instances_pruning_rule() {
        let grid = Grid::new(5, 5);
        let l = Layout::full(grid, crate::ops::GroupSet::all_compute());
        let mut mins = [0usize; NUM_GROUPS];
        assert!(meets_min_instances(&l, &mins));
        mins[OpGroup::Arith.index()] = 9;
        assert!(meets_min_instances(&l, &mins)); // 9 compute cells
        mins[OpGroup::Arith.index()] = 10;
        assert!(!meets_min_instances(&l, &mins));
        // Mem mins never block
        mins[OpGroup::Arith.index()] = 0;
        mins[OpGroup::Mem.index()] = 1000;
        assert!(meets_min_instances(&l, &mins));
    }

    #[test]
    fn native_scorer_matches_cost_model() {
        let cost = CostModel::area();
        let grid = Grid::new(6, 6);
        let l = Layout::full(grid, crate::ops::GroupSet::all_compute());
        let mut s = NativeScorer { cost: cost.clone() };
        let v = s.score(grid.num_compute(), &[l.compute_group_instances()]);
        assert!((v[0] - cost.layout_cost(&l)).abs() < 1e-9);
    }

    #[test]
    fn l_test_scales_with_size() {
        assert_eq!(SearchConfig::l_test_for(Grid::new(10, 10)), 2000);
        assert!(SearchConfig::l_test_for(Grid::new(13, 15)) > 2000);
    }

    #[test]
    fn nogsg_skips_gsg_phase() {
        let dfgs = vec![benchmarks::benchmark("SOB")];
        let grid = Grid::new(5, 5);
        let cfg = SearchConfig { run_gsg: false, ..small_cfg() };
        let r = run(&dfgs, grid, &Mapper::default(), &CostModel::area(), &cfg, None).unwrap();
        assert_eq!(r.stats.insts_after_gsg, r.stats.insts_after_opsg);
        assert!(!r.stats.trace.iter().any(|t| t.phase == Phase::Gsg));
    }
}
