//! Operation-based subproblem generation (paper Algorithm 2).
//!
//! One operation group at a time, most expensive first. Every queue fill
//! removes a single instance of the current group from every compute cell
//! of the incumbent best layout (top-left to bottom-right); candidates
//! all share the same cost, so the first feasible one wins the round and
//! the queue is rebuilt from the new best. Feasibility uses *selective
//! testing*: only the DFGs containing ops of the removed group are
//! re-mapped — the others' mappings cannot be invalidated by removing a
//! group they never use (the base layout is always feasible in OPSG).

use super::{SearchCtx, SearchEvent};
use crate::cgra::{CellId, Layout};
use crate::ops::costs::groups_by_descending_cost;
use crate::ops::{GroupSet, OpGroup, NUM_GROUPS};

/// One queue fill: all valid single-removals of `op_type` from `base`.
/// Returns candidate cells in branching order; their (equal) costs come
/// from the batch scorer when provided.
fn generate_valid_layouts(
    base: &Layout,
    op_type: OpGroup,
    min_insts: &[usize; NUM_GROUPS],
    failed: &std::collections::HashSet<CellId>,
) -> Vec<CellId> {
    let mut out = Vec::new();
    // pruning: removing one instance is invalid if it would drop the
    // group's total below its minimum
    let n = base.compute_group_instances();
    if n[op_type.index()] == 0 || n[op_type.index()] <= min_insts[op_type.index()] {
        return out;
    }
    for cell in base.grid.compute_cells() {
        if base.supports(cell, op_type) && !failed.contains(&cell) {
            out.push(cell);
        }
    }
    out
}

/// Algorithm 2. Returns the best layout found; all shared search state
/// (stats, scorer, witness cache, config) lives in the [`SearchCtx`].
///
/// Perf (EXPERIMENTS.md §Perf): feasibility testing keeps a *witness
/// mapping* per DFG for the incumbent best layout. Removing group `g`
/// from cell `c` cannot invalidate a witness that does not execute a
/// `g`-op on `c` (support removal does not touch the switch fabric), so
/// such candidates are accepted without re-mapping — a sound
/// strengthening of the paper's selective testing. DFGs that *do* need
/// re-mapping go through [`SearchCtx::test_dfg`], which warm-starts the
/// engine from the witness: only the displaced nodes are re-placed and
/// only their incident edges re-routed.
pub fn run(initial: &Layout, ctx: &mut SearchCtx) -> Layout {
    let dfgs = ctx.dfgs;
    let cost = ctx.cost;
    let min_insts = ctx.min_insts;
    let cfg = ctx.cfg.clone();
    let mut best = initial.clone();
    let mut best_cost = cost.layout_cost(&best);
    let removal_order = groups_by_descending_cost(&cost.components);

    'groups: for &op_type in &removal_order {
        if cfg.opsg_skip_arith && op_type == OpGroup::Arith {
            continue;
        }
        // per-group memory of (cell) removals that failed on every base
        // so far; reset when the base layout changes.
        let mut failed: std::collections::HashSet<CellId> = std::collections::HashSet::new();
        loop {
            // line 7-8: (re)fill the queue from the incumbent best
            let cells = generate_valid_layouts(&best, op_type, &min_insts, &failed);
            ctx.stats.expanded += cells.len();
            if cells.is_empty() {
                break; // next group
            }
            // candidate costs: all equal (same removal from same base);
            // computed through the batch scorer when available, which is
            // also the cross-check that XLA and native cost agree.
            let cand_cost = if let Some(s) = ctx.scorer.as_deref_mut() {
                let mut v = best.compute_group_instances();
                v[op_type.index()] -= 1;
                s.score(best.grid.num_compute(), &[v])[0]
            } else {
                best_cost + cost.removal_delta(op_type)
            };
            if cand_cost >= best_cost {
                break; // cannot improve (never true for positive costs)
            }
            // selective testing: only DFGs using the removed group
            let mask = GroupSet::EMPTY.with(op_type);
            let affected: Vec<usize> = (0..dfgs.len())
                .filter(|&i| dfgs[i].uses_any(mask))
                .collect();

            let mut new_best_found = false;
            for cell in cells {
                if ctx.stats.tested >= cfg.l_test {
                    break 'groups;
                }
                let candidate = best.without_group(cell, op_type);
                ctx.stats.tested += 1;
                // witness reuse: a DFG only needs re-mapping if its
                // current witness executes an op of `op_type` on `cell`;
                // those that do are remapped warm from the witness.
                let mut ok = true;
                let mut new_witnesses: Vec<(usize, crate::mapper::Mapping)> = Vec::new();
                for &di in &affected {
                    let d = &dfgs[di];
                    let needs_remap = match &ctx.witness[di] {
                        Some(w) => !w.still_valid(d, &candidate),
                        None => true,
                    };
                    if !needs_remap {
                        continue;
                    }
                    match ctx.test_dfg(di, &candidate) {
                        crate::mapper::MapOutcome::Mapped { mapping, .. } => {
                            new_witnesses.push((di, mapping))
                        }
                        crate::mapper::MapOutcome::Failed { .. } => {
                            ok = false;
                            break;
                        }
                    }
                }
                ctx.emit(SearchEvent::LayoutTested {
                    feasible: ok,
                    cost: cand_cost,
                    tested: ctx.stats.tested,
                });
                if ok {
                    best = candidate;
                    best_cost = cand_cost;
                    for (di, m) in new_witnesses {
                        ctx.witness[di] = Some(m);
                    }
                    failed.clear();
                    ctx.emit_improved(best_cost);
                    new_best_found = true;
                    break; // rebuild queue from new best
                } else {
                    failed.insert(cell);
                }
            }
            if !new_best_found {
                break; // stopSearchRound: all candidates failed
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::cost::CostModel;
    use crate::dfg::{benchmarks, Dfg};
    use crate::mapper::MappingEngine;
    use crate::search::{NativeScorer, SearchConfig};

    fn setup(names: &[&str], r: usize, c: usize) -> (Vec<Dfg>, Layout, MappingEngine, CostModel) {
        let dfgs: Vec<Dfg> = names.iter().map(|n| benchmarks::benchmark(n)).collect();
        let full = Layout::full(Grid::new(r, c), crate::dfg::groups_used(&dfgs));
        (dfgs, full, MappingEngine::default(), CostModel::area())
    }

    fn ctx<'a>(
        dfgs: &'a [Dfg],
        engine: &'a MappingEngine,
        cost: &'a CostModel,
        cfg: SearchConfig,
    ) -> SearchCtx<'a> {
        let mins = crate::dfg::min_group_instances(dfgs);
        SearchCtx::new(dfgs, engine, cost, mins, cfg)
    }

    /// Feasibility check for a finished search state: the result is
    /// proven by witnesses (layouts accepted through the warm-start or
    /// witness fast-path may not re-map heuristically from scratch).
    fn witnesses_prove(c: &SearchCtx, best: &Layout) -> bool {
        c.dfgs.iter().enumerate().all(|(di, d)| match &c.witness[di] {
            Some(w) => w.validate(d, best).is_empty(),
            None => c.engine.map(d, best).is_mapped(),
        })
    }

    #[test]
    fn opsg_removes_expensive_groups_first_and_most() {
        let (dfgs, full, engine, cost) = setup(&["BIL"], 8, 8);
        let mins = crate::dfg::min_group_instances(&dfgs);
        let cfg = SearchConfig { l_test: 400, ..Default::default() };
        let mut c = ctx(&dfgs, &engine, &cost, cfg);
        let best = run(&full, &mut c);
        let nf = full.compute_group_instances();
        let nb = best.compute_group_instances();
        // BIL needs only 2 Div instances: almost all of the 36 must go
        assert!(nb[OpGroup::Div.index()] <= mins[OpGroup::Div.index()] + 2);
        assert!(nb[OpGroup::Div.index()] < nf[OpGroup::Div.index()]);
        // result still maps (witness-proven)
        assert!(witnesses_prove(&c, &best));
        assert!(c.stats.tested > 0 && c.stats.expanded >= c.stats.tested);
    }

    #[test]
    fn opsg_respects_l_test_budget() {
        let (dfgs, full, engine, cost) = setup(&["SOB", "GB"], 7, 7);
        let cfg = SearchConfig { l_test: 5, ..Default::default() };
        let mut c = ctx(&dfgs, &engine, &cost, cfg);
        let _ = run(&full, &mut c);
        assert!(c.stats.tested <= 5);
    }

    #[test]
    fn opsg_never_violates_min_instances() {
        let (dfgs, full, engine, cost) = setup(&["RGB"], 7, 7);
        let cfg = SearchConfig { l_test: 300, ..Default::default() };
        let mut c = ctx(&dfgs, &engine, &cost, cfg);
        let best = run(&full, &mut c);
        assert!(crate::search::meets_min_instances(&best, &c.min_insts));
    }

    #[test]
    fn scorer_and_native_agree() {
        let (dfgs, full, engine, cost) = setup(&["SOB"], 6, 6);
        let cfg = SearchConfig { l_test: 100, ..Default::default() };
        let mut c1 = ctx(&dfgs, &engine, &cost, cfg.clone());
        let b1 = run(&full, &mut c1);
        let mut ns = NativeScorer { cost: cost.clone() };
        let mut c2 = ctx(&dfgs, &engine, &cost, cfg);
        c2.scorer = Some(&mut ns);
        let b2 = run(&full, &mut c2);
        assert_eq!(
            cost.layout_cost(&b1),
            cost.layout_cost(&b2),
            "scorer path must not change the search"
        );
    }

    #[test]
    fn generate_skips_failed_cells() {
        let (_, full, _, _) = setup(&["SOB"], 6, 6);
        let mins = [0usize; NUM_GROUPS];
        let all = generate_valid_layouts(&full, OpGroup::Arith, &mins, &Default::default());
        let mut failed = std::collections::HashSet::new();
        failed.insert(all[0]);
        let fewer = generate_valid_layouts(&full, OpGroup::Arith, &mins, &failed);
        assert_eq!(fewer.len(), all.len() - 1);
    }
}
