//! Operation-based subproblem generation (paper Algorithm 2).
//!
//! One operation group at a time, most expensive first. Every queue fill
//! removes a single instance of the current group from every compute cell
//! of the incumbent best layout (top-left to bottom-right); candidates
//! all share the same cost, so the first feasible one wins the round and
//! the queue is rebuilt from the new best. Feasibility uses *selective
//! testing*: only the DFGs containing ops of the removed group are
//! re-mapped — the others' mappings cannot be invalidated by removing a
//! group they never use (the base layout is always feasible in OPSG).
//!
//! Candidates of one queue fill are independent, so they are tested on
//! the [`super::parallel::TestPool`] and merged by the deterministic
//! reduction: the winner is the first *feasible* candidate in the
//! original branching order regardless of which worker finished first,
//! and the `failed`-cell set is filled in that same order — so the
//! search trajectory is byte-identical at any
//! [`super::SearchConfig::search_threads`].

use super::parallel::{CandidateTest, SharedState, TestPool};
use super::{SearchCtx, SearchEvent};
use crate::cgra::{CellId, Layout};
use crate::ops::costs::groups_by_descending_cost;
use crate::ops::{GroupSet, OpGroup, NUM_GROUPS};

/// One queue fill: all valid single-removals of `op_type` from `base`.
/// Returns candidate cells in branching order; their (equal) costs come
/// from the batch scorer when provided.
fn generate_valid_layouts(
    base: &Layout,
    op_type: OpGroup,
    min_insts: &[usize; NUM_GROUPS],
    failed: &std::collections::HashSet<CellId>,
) -> Vec<CellId> {
    let mut out = Vec::new();
    // pruning: removing one instance is invalid if it would drop the
    // group's total below its minimum
    let n = base.compute_group_instances();
    if n[op_type.index()] == 0 || n[op_type.index()] <= min_insts[op_type.index()] {
        return out;
    }
    for cell in base.grid.compute_cells() {
        if base.supports(cell, op_type) && !failed.contains(&cell) {
            out.push(cell);
        }
    }
    out
}

/// Algorithm 2. Returns the best layout found; all shared search state
/// (stats, scorer, witness cache, config) lives in the [`SearchCtx`].
///
/// Perf (EXPERIMENTS.md §Perf): feasibility testing keeps a *witness
/// mapping* per DFG for the incumbent best layout. Removing group `g`
/// from cell `c` cannot invalidate a witness that does not execute a
/// `g`-op on `c` (support removal does not touch the switch fabric), so
/// such candidates are accepted without re-mapping — a sound
/// strengthening of the paper's selective testing. DFGs that *do* need
/// re-mapping are remapped warm from the witness (only the displaced
/// nodes re-placed, only their incident edges re-routed) on the
/// [`TestPool`]'s forked engines; the deterministic reduction keeps the
/// outcome independent of the worker count (see [`super::parallel`]).
pub fn run(initial: &Layout, ctx: &mut SearchCtx) -> Layout {
    let dfgs = ctx.dfgs;
    let cost = ctx.cost;
    let min_insts = ctx.min_insts;
    let cfg = ctx.cfg.clone();
    let mut pool = TestPool::for_search(ctx.engine, cfg.search_threads_resolved());
    // the witness cache moves out of the ctx for the phase: candidate
    // tests read a fixed snapshot of it through the shared state while
    // the ctx stays free for stats/event mutation on the reduction side
    let mut witness = std::mem::take(&mut ctx.witness);
    let mut best = initial.clone();
    let mut best_cost = cost.layout_cost(&best);
    let removal_order = groups_by_descending_cost(&cost.components);

    'groups: for &op_type in &removal_order {
        if cfg.opsg_skip_arith && op_type == OpGroup::Arith {
            continue;
        }
        // per-group memory of (cell) removals that failed on every base
        // so far; reset when the base layout changes. Filled in
        // branching order by the reduction, so the parallel soundness of
        // the next queue fill rests on `generate_valid_layouts`
        // excluding exactly the serial run's failed cells.
        let mut failed: std::collections::HashSet<CellId> = std::collections::HashSet::new();
        loop {
            // line 7-8: (re)fill the queue from the incumbent best
            let cells = generate_valid_layouts(&best, op_type, &min_insts, &failed);
            ctx.stats.expanded += cells.len();
            if cells.is_empty() {
                break; // next group
            }
            // candidate costs: all equal (same removal from same base);
            // computed through the batch scorer when available, which is
            // also the cross-check that XLA and native cost agree.
            let cand_cost = if let Some(s) = ctx.scorer.as_deref_mut() {
                let mut v = best.compute_group_instances();
                v[op_type.index()] -= 1;
                s.score(best.grid.num_compute(), &[v])[0]
            } else {
                best_cost + cost.removal_delta(op_type)
            };
            if cand_cost >= best_cost {
                break; // cannot improve (never true for positive costs)
            }
            // selective testing: only DFGs using the removed group
            let mask = GroupSet::EMPTY.with(op_type);
            let affected: Vec<usize> = (0..dfgs.len())
                .filter(|&i| dfgs[i].uses_any(mask))
                .collect();

            // the batch is the serial branching order capped to the
            // remaining L_test budget; a serial run would have stopped
            // at exactly that many tests
            let remaining = cfg.l_test.saturating_sub(ctx.stats.tested);
            if remaining == 0 {
                break 'groups;
            }
            let batch: Vec<(CellId, Layout)> = cells
                .iter()
                .take(remaining)
                .map(|&c| (c, best.without_group(c, op_type)))
                .collect();
            let budget_hit = cells.len() > batch.len();

            // speculative prefetch + deterministic reduction: consume
            // results in branching order, stop at the first feasible
            // candidate (the winner), recompute on demand anything the
            // prefetch skipped
            let mut winner: Option<(usize, CandidateTest)> = None;
            {
                let shared = SharedState { dfgs, witness: &witness, affected: &affected };
                let items: Vec<(&Layout, bool)> =
                    batch.iter().map(|(_, l)| (l, false)).collect();
                let mut prefetched = pool.prefetch(&shared, &items);
                for (i, (cell, layout)) in batch.iter().enumerate() {
                    let t = match prefetched[i].take() {
                        Some(t) => t,
                        None => pool.test_one(&shared, layout),
                    };
                    ctx.stats.tested += 1;
                    ctx.emit(SearchEvent::LayoutTested {
                        feasible: t.feasible,
                        cost: cand_cost,
                        tested: ctx.stats.tested,
                        worker: t.worker,
                    });
                    if t.feasible {
                        winner = Some((i, t));
                        break;
                    }
                    failed.insert(*cell);
                }
                ctx.stats.speculative +=
                    prefetched.iter().filter(|o| o.is_some()).count();
            }

            match winner {
                Some((w, t)) => {
                    best = batch
                        .into_iter()
                        .nth(w)
                        .map(|(_, l)| l)
                        .expect("winner index is in the batch");
                    best_cost = cand_cost;
                    for (di, m) in t.witnesses {
                        witness[di] = Some(m);
                    }
                    failed.clear();
                    ctx.emit_improved(best_cost);
                    // rebuild the queue from the new best
                }
                None => {
                    if budget_hit {
                        break 'groups; // L_test exhausted mid-round
                    }
                    break; // stopSearchRound: all candidates failed
                }
            }
        }
    }
    ctx.witness = witness;
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::Grid;
    use crate::cost::CostModel;
    use crate::dfg::{benchmarks, Dfg};
    use crate::mapper::MappingEngine;
    use crate::search::{NativeScorer, SearchConfig};

    fn setup(names: &[&str], r: usize, c: usize) -> (Vec<Dfg>, Layout, MappingEngine, CostModel) {
        let dfgs: Vec<Dfg> = names.iter().map(|n| benchmarks::benchmark(n)).collect();
        let full = Layout::full(Grid::new(r, c), crate::dfg::groups_used(&dfgs));
        (dfgs, full, MappingEngine::default(), CostModel::area())
    }

    fn ctx<'a>(
        dfgs: &'a [Dfg],
        engine: &'a MappingEngine,
        cost: &'a CostModel,
        cfg: SearchConfig,
    ) -> SearchCtx<'a> {
        let mins = crate::dfg::min_group_instances(dfgs);
        SearchCtx::new(dfgs, engine, cost, mins, cfg)
    }

    /// Feasibility check for a finished search state: the result is
    /// proven by witnesses (layouts accepted through the warm-start or
    /// witness fast-path may not re-map heuristically from scratch).
    fn witnesses_prove(c: &SearchCtx, best: &Layout) -> bool {
        c.dfgs.iter().enumerate().all(|(di, d)| match &c.witness[di] {
            Some(w) => w.validate(d, best).is_empty(),
            None => c.engine.map(d, best).is_mapped(),
        })
    }

    #[test]
    fn opsg_removes_expensive_groups_first_and_most() {
        let (dfgs, full, engine, cost) = setup(&["BIL"], 8, 8);
        let mins = crate::dfg::min_group_instances(&dfgs);
        let cfg = SearchConfig { l_test: 400, ..Default::default() };
        let mut c = ctx(&dfgs, &engine, &cost, cfg);
        let best = run(&full, &mut c);
        let nf = full.compute_group_instances();
        let nb = best.compute_group_instances();
        // BIL needs only 2 Div instances: almost all of the 36 must go
        assert!(nb[OpGroup::Div.index()] <= mins[OpGroup::Div.index()] + 2);
        assert!(nb[OpGroup::Div.index()] < nf[OpGroup::Div.index()]);
        // result still maps (witness-proven)
        assert!(witnesses_prove(&c, &best));
        assert!(c.stats.tested > 0 && c.stats.expanded >= c.stats.tested);
    }

    #[test]
    fn opsg_respects_l_test_budget() {
        let (dfgs, full, engine, cost) = setup(&["SOB", "GB"], 7, 7);
        let cfg = SearchConfig { l_test: 5, ..Default::default() };
        let mut c = ctx(&dfgs, &engine, &cost, cfg);
        let _ = run(&full, &mut c);
        assert!(c.stats.tested <= 5);
    }

    #[test]
    fn opsg_never_violates_min_instances() {
        let (dfgs, full, engine, cost) = setup(&["RGB"], 7, 7);
        let cfg = SearchConfig { l_test: 300, ..Default::default() };
        let mut c = ctx(&dfgs, &engine, &cost, cfg);
        let best = run(&full, &mut c);
        assert!(crate::search::meets_min_instances(&best, &c.min_insts));
    }

    #[test]
    fn scorer_and_native_agree() {
        let (dfgs, full, engine, cost) = setup(&["SOB"], 6, 6);
        let cfg = SearchConfig { l_test: 100, ..Default::default() };
        let mut c1 = ctx(&dfgs, &engine, &cost, cfg.clone());
        let b1 = run(&full, &mut c1);
        let mut ns = NativeScorer { cost: cost.clone() };
        let mut c2 = ctx(&dfgs, &engine, &cost, cfg);
        c2.scorer = Some(&mut ns);
        let b2 = run(&full, &mut c2);
        assert_eq!(
            cost.layout_cost(&b1),
            cost.layout_cost(&b2),
            "scorer path must not change the search"
        );
    }

    #[test]
    fn generate_skips_failed_cells() {
        let (_, full, _, _) = setup(&["SOB"], 6, 6);
        let mins = [0usize; NUM_GROUPS];
        let all = generate_valid_layouts(&full, OpGroup::Arith, &mins, &Default::default());
        let mut failed = std::collections::HashSet::new();
        failed.insert(all[0]);
        let fewer = generate_valid_layouts(&full, OpGroup::Arith, &mins, &failed);
        assert_eq!(fewer.len(), all.len() - 1);
        assert!(!fewer.contains(&all[0]));
        // every cell failed: the round must produce zero candidates (the
        // parallel reduction relies on this to terminate a group exactly
        // where the serial search would)
        let all_failed: std::collections::HashSet<CellId> = all.iter().copied().collect();
        assert!(generate_valid_layouts(&full, OpGroup::Arith, &mins, &all_failed).is_empty());
    }

    #[test]
    fn generate_at_exact_minimum_yields_no_candidates() {
        // the `n[g] <= min_insts[g]` pruning edge: exactly at the
        // minimum, removing one more instance is invalid, so the queue
        // fill must be empty — at minimum+1 candidates reappear
        let (_, full, _, _) = setup(&["SOB"], 6, 6);
        let g = OpGroup::Arith;
        let n = full.compute_group_instances();
        assert!(n[g.index()] > 1, "fixture needs at least two Arith instances");
        let mut mins = [0usize; NUM_GROUPS];
        mins[g.index()] = n[g.index()];
        assert!(
            generate_valid_layouts(&full, g, &mins, &Default::default()).is_empty(),
            "exactly-at-minimum must yield zero candidates"
        );
        mins[g.index()] = n[g.index()] - 1;
        assert!(
            !generate_valid_layouts(&full, g, &mins, &Default::default()).is_empty(),
            "one instance of slack must yield candidates again"
        );
        // a group with zero instances yields nothing even with mins at 0
        let empty = Layout::empty(full.grid);
        assert!(generate_valid_layouts(&empty, g, &[0; NUM_GROUPS], &Default::default())
            .is_empty());
    }

    #[test]
    fn opsg_thread_count_never_changes_the_result() {
        let (dfgs, full, engine, cost) = setup(&["SOB", "GB"], 7, 7);
        let mut outs: Vec<(Layout, usize, usize, f64)> = Vec::new();
        for threads in [1usize, 2, 4] {
            let cfg = SearchConfig {
                l_test: 150,
                search_threads: threads,
                ..Default::default()
            };
            let mut c = ctx(&dfgs, &engine, &cost, cfg);
            let best = run(&full, &mut c);
            let best_cost = cost.layout_cost(&best);
            outs.push((best, c.stats.tested, c.stats.expanded, best_cost));
        }
        for o in &outs[1..] {
            assert_eq!(outs[0].0, o.0, "layout must not depend on search_threads");
            assert_eq!(outs[0].1, o.1, "S_tst must not depend on search_threads");
            assert_eq!(outs[0].2, o.2, "S_exp must not depend on search_threads");
            assert_eq!(outs[0].3, o.3);
        }
    }
}
