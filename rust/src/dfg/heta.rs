//! The 8 DFGs used in the HETA comparison (paper Table IX, sourced from
//! HETA's evaluation / the ExPRESS benchmark suite).
//!
//! Table IX fully specifies V, E and the Add/Sub, Mult, Load/Store op
//! histograms; the builder reproduces them exactly (asserted in tests).

use super::builder::DfgSpec;
use super::Dfg;
use crate::ops::Op::*;

/// Table IX rows: (name, V, E, add_sub, mult, load_store).
pub const TABLE_IX: [(&str, usize, usize, usize, usize, usize); 8] = [
    ("arf", 46, 48, 12, 16, 18),
    ("centro-fir", 46, 60, 20, 8, 18),
    ("cosine2", 82, 91, 26, 16, 40),
    ("ewf", 43, 56, 26, 8, 9),
    ("fft", 37, 48, 12, 8, 17),
    ("fir", 44, 43, 10, 11, 23),
    ("resnet2", 64, 63, 15, 16, 33),
    ("stencil3d", 66, 68, 25, 7, 34),
];

/// (loads, stores) split of each row's load_store total, chosen so the
/// edge count is achievable (B = E - V + L must be 0..=compute ops).
const LS_SPLIT: [(usize, usize); 8] =
    [(12, 6), (12, 6), (26, 14), (6, 3), (9, 8), (15, 8), (22, 11), (24, 10)];

fn spec(idx: usize) -> DfgSpec {
    let (name, v, e, add_sub, mult, load_store) = TABLE_IX[idx];
    let (loads, stores) = LS_SPLIT[idx];
    assert_eq!(loads + stores, load_store, "{name} L/S split");
    let adds = add_sub / 2 + add_sub % 2;
    let subs = add_sub / 2;
    let compute = vec![(Add, adds), (Sub, subs), (Mul, mult)];
    let binary = e + loads - v; // from E = S + C + B and V = L + S + C
    DfgSpec { name, loads, stores, compute, binary, seed: 0x4e7a + idx as u64 }
}

/// Build one Table IX DFG by name.
pub fn heta_benchmark(name: &str) -> Dfg {
    let idx = TABLE_IX
        .iter()
        .position(|(n, ..)| *n == name)
        .unwrap_or_else(|| panic!("unknown HETA benchmark {name}"));
    spec(idx).build()
}

/// All 8 HETA DFGs in Table IX order.
pub fn all() -> Vec<Dfg> {
    (0..TABLE_IX.len()).map(|i| spec(i).build()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::OpGroup;

    #[test]
    fn counts_match_table_9() {
        for (i, (name, v, e, add_sub, mult, load_store)) in TABLE_IX.iter().enumerate() {
            let d = spec(i).build();
            assert_eq!(d.num_nodes(), *v, "{name} V");
            assert_eq!(d.num_edges(), *e, "{name} E");
            let h = d.group_histogram();
            assert_eq!(h[OpGroup::Arith.index()], *add_sub, "{name} add/sub");
            assert_eq!(h[OpGroup::Mult.index()], *mult, "{name} mult");
            assert_eq!(h[OpGroup::Mem.index()], *load_store, "{name} load/store");
            assert_eq!(h[OpGroup::Div.index()], 0, "{name}");
            assert_eq!(h[OpGroup::FP.index()], 0, "{name}");
            assert_eq!(h[OpGroup::Other.index()], 0, "{name}");
        }
    }

    #[test]
    fn all_valid() {
        for d in all() {
            let errs = d.validate();
            assert!(errs.is_empty(), "{}: {errs:?}", d.name);
        }
    }

    #[test]
    fn fits_20x20_comparison_grid() {
        // Section IV-J: 18x18 compute + 76 border I/O cells.
        for d in all() {
            assert!(d.mem_ops() <= 76, "{}", d.name);
            assert!(d.compute_ops() <= 18 * 18, "{}", d.name);
        }
    }

    #[test]
    fn lookup_by_name() {
        let d = heta_benchmark("ewf");
        assert_eq!(d.num_nodes(), 43);
    }

    #[test]
    #[should_panic(expected = "unknown HETA benchmark")]
    fn unknown_name_panics() {
        heta_benchmark("nope");
    }
}
