//! Seeded random-DFG generator: fuzzing and load-generation workloads.
//!
//! [`generate`] builds a structurally valid DAG from a [`GenConfig`] —
//! a pure function of the config, driven entirely by the deterministic
//! [`Rng`] stream, so the same seed and knobs yield a byte-identical
//! graph on any platform, at any thread count, in debug or release
//! (the contract `helex loadgen` and the fuzz harness depend on).
//!
//! Construction is layered: loads form layer 0, each compute node is
//! assigned a layer in `1..=depth`, stores come last; a node's inputs
//! are drawn only from strictly earlier layers, so the result is a DAG
//! with no self-loops or duplicate edges *by construction*, and a
//! repair pass guarantees every produced value is consumed. Infeasible
//! knob combinations (more loads than the op mix can absorb, absurd
//! counts) are clamped, never rejected: `generate` is total and always
//! returns a graph that passes [`Dfg::validate`].

use super::Dfg;
use crate::ops::{GroupSet, Op, ALL_OPS};
use crate::util::rng::Rng;

/// Shape knobs for one generated graph. The defaults make a small,
/// mixed-group kernel comparable to the paper's smaller benchmarks.
#[derive(Debug, Clone)]
pub struct GenConfig {
    /// Name prefix; the graph is named `"{name}-{seed:016x}"` so
    /// distinct seeds hash to distinct job fingerprints.
    pub name: String,
    /// RNG seed — the whole graph is a function of this plus the knobs.
    pub seed: u64,
    /// Load (source) nodes. Clamped to `1..=512`.
    pub loads: usize,
    /// Compute (non-memory) nodes. Clamped to `1..=1024`.
    pub compute: usize,
    /// Store (sink) nodes. Clamped to `1..=512`, then raised if the op
    /// mix cannot absorb every load (coverage needs sinks).
    pub stores: usize,
    /// Op-group mix: compute ops are drawn only from these groups
    /// (memory is implicit). An empty/compute-free mask falls back to
    /// all compute groups.
    pub groups: GroupSet,
    /// Probability that a binary-capable op receives two inputs —
    /// shapes the fan-in (and with it the edge count).
    pub binary_p: f64,
    /// Soft cap on consumers per producer (fan-out). 0 = unbounded.
    pub max_fanout: usize,
    /// Target number of compute layers (graph depth). 0 = auto
    /// (roughly `sqrt(compute)`).
    pub depth: usize,
}

impl Default for GenConfig {
    fn default() -> Self {
        Self {
            name: "gen".into(),
            seed: 0,
            loads: 4,
            compute: 12,
            stores: 2,
            groups: GroupSet::all_compute(),
            binary_p: 0.6,
            max_fanout: 4,
            depth: 0,
        }
    }
}

/// Draw a config scaled by the property-test size hint — the fuzz
/// harness's distribution over graph shapes.
pub fn arb_config(rng: &mut Rng, size: usize) -> GenConfig {
    let seed = rng.next_u64();
    GenConfig {
        name: "fuzz".into(),
        seed,
        loads: 1 + rng.below(2 + size / 2),
        compute: 1 + rng.below(2 + 2 * size),
        stores: 1 + rng.below(1 + size / 2),
        // a random group subset; a useless mask falls back inside
        // generate, so every draw is a legal config
        groups: GroupSet((rng.next_u64() & 0x3f) as u8),
        binary_p: rng.f64(),
        max_fanout: [0usize, 2, 3, 4, 8][rng.below(5)],
        depth: if rng.below(3) == 0 { 1 + rng.below(6) } else { 0 },
    }
}

/// One input pick: uncovered-first (keeps every producer consumed, so
/// the repair pass rarely fires), otherwise a bounded random probe that
/// respects the fan-out cap, with a deterministic fallback when the
/// probe keeps colliding.
fn pick_producer(
    rng: &mut Rng,
    visible: usize,
    outdeg: &[usize],
    picked: &[usize],
    max_fanout: usize,
) -> usize {
    if rng.chance(0.6) {
        if let Some(u) = (0..visible).find(|u| outdeg[*u] == 0 && !picked.contains(u)) {
            return u;
        }
    }
    for _ in 0..32 {
        let u = rng.below(visible);
        if picked.contains(&u) {
            continue;
        }
        if max_fanout > 0 && outdeg[u] >= max_fanout {
            continue;
        }
        return u;
    }
    // every unsaturated producer already picked: ignore the (soft)
    // fan-out cap rather than fail — the caller guarantees
    // picked.len() < visible, so a free producer exists
    (0..visible).find(|u| !picked.contains(u)).unwrap_or(0)
}

/// Build the graph described by `cfg`. Total and deterministic; the
/// result always passes [`Dfg::validate`].
pub fn generate(cfg: &GenConfig) -> Dfg {
    let loads = cfg.loads.clamp(1, 512);
    let compute = cfg.compute.clamp(1, 1024);
    let mut rng = Rng::seed(cfg.seed);

    let mut pool: Vec<Op> = ALL_OPS
        .iter()
        .copied()
        .filter(|op| !op.is_memory() && cfg.groups.contains(op.group()))
        .collect();
    if pool.is_empty() {
        pool = ALL_OPS.iter().copied().filter(|op| !op.is_memory()).collect();
    }

    let ops: Vec<Op> = (0..compute).map(|_| *rng.choose(&pool)).collect();
    let binary_capable = ops.iter().filter(|op| op.arity() == 2).count();
    // every producer needs a consumer; two-input nodes and stores are
    // the only slack, so grow the sink count when the drawn op mix
    // cannot absorb every load
    let stores = cfg.stores.clamp(1, 512).max(loads.saturating_sub(binary_capable));

    let depth = if cfg.depth > 0 {
        cfg.depth.min(compute)
    } else {
        let mut d = 1usize;
        while (d + 1) * (d + 1) <= compute {
            d += 1;
        }
        d
    };
    // one layer per compute node, each of 1..=depth guaranteed
    // nonempty; sorted so compute-node order is topological
    let mut layers: Vec<usize> = (0..compute)
        .map(|k| if k < depth { k + 1 } else { rng.range(1, depth + 1) })
        .collect();
    layers.sort_unstable();

    // fan-in per compute node, forcing enough two-input nodes that all
    // loads can be absorbed (only needed when loads > stores, in which
    // case loads >= 2 and every node sees >= 2 producers)
    let mut indeg: Vec<usize> = ops
        .iter()
        .map(|op| if op.arity() == 2 && rng.chance(cfg.binary_p) { 2 } else { 1 })
        .collect();
    let required2 = loads.saturating_sub(stores);
    let mut n2 = indeg.iter().filter(|&&d| d == 2).count();
    for i in 0..compute {
        if n2 >= required2 {
            break;
        }
        if ops[i].arity() == 2 && indeg[i] == 1 {
            indeg[i] = 2;
            n2 += 1;
        }
    }

    let total_producers = loads + compute;
    let mut outdeg = vec![0usize; total_producers];
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut indeg_actual = vec![0usize; compute];

    for i in 0..compute {
        // producers in strictly earlier layers (plus all loads)
        let visible = loads + layers.partition_point(|&l| l < layers[i]);
        let want = indeg[i].min(visible);
        let gi = (loads + i) as u32;
        let mut picked: Vec<usize> = Vec::with_capacity(want);
        for _ in 0..want {
            let choice = pick_producer(&mut rng, visible, &outdeg, &picked, cfg.max_fanout);
            picked.push(choice);
            outdeg[choice] += 1;
            edges.push((choice as u32, gi));
        }
        indeg_actual[i] = want;
    }

    for j in 0..stores {
        let gj = (total_producers + j) as u32;
        // drain the latest uncovered producer; otherwise a bounded
        // random probe under the fan-out cap
        let choice = match (0..total_producers).rev().find(|&u| outdeg[u] == 0) {
            Some(u) => u,
            None => {
                let mut c = rng.below(total_producers);
                for _ in 0..32 {
                    if cfg.max_fanout == 0 || outdeg[c] < cfg.max_fanout {
                        break;
                    }
                    c = rng.below(total_producers);
                }
                c
            }
        };
        outdeg[choice] += 1;
        edges.push((choice as u32, gj));
    }

    let mut nodes: Vec<Op> = Vec::with_capacity(total_producers + stores);
    nodes.extend(std::iter::repeat(Op::Load).take(loads));
    nodes.extend(ops.iter().copied());
    nodes.extend(std::iter::repeat(Op::Store).take(stores));

    let layer_of = |u: usize| -> usize {
        if u < loads {
            0
        } else {
            layers[u - loads]
        }
    };

    // coverage repair: every load/compute value must be consumed. Each
    // fix targets a strictly later layer (or a store), so edges keep
    // increasing in node index and the DAG property is preserved.
    for u in 0..total_producers {
        if outdeg[u] > 0 {
            continue;
        }
        let gu = u as u32;
        // (a) a later binary node with a free input slot
        let free_slot = (0..compute).find(|&c| {
            let gc = (loads + c) as u32;
            layer_of(loads + c) > layer_of(u)
                && ops[c].arity() == 2
                && indeg_actual[c] == 1
                && !edges.contains(&(gu, gc))
        });
        if let Some(c) = free_slot {
            edges.push((gu, (loads + c) as u32));
            indeg_actual[c] = 2;
            outdeg[u] += 1;
            continue;
        }
        // (b) steal a slot from an over-shared producer feeding a
        // later consumer (the donor keeps >= 1 consumer)
        let steal = (0..edges.len()).find(|&e| {
            let (p, c) = edges[e];
            let (p, c) = (p as usize, c as usize);
            outdeg[p] >= 2
                && (c >= total_producers || layer_of(c) > layer_of(u))
                && !edges.contains(&(gu, c as u32))
        });
        if let Some(e) = steal {
            let p = edges[e].0 as usize;
            edges[e] = (gu, edges[e].1);
            outdeg[p] -= 1;
            outdeg[u] += 1;
            continue;
        }
        // (c) last resort: drain through a fresh store
        let gs = nodes.len() as u32;
        nodes.push(Op::Store);
        edges.push((gu, gs));
        outdeg[u] += 1;
    }

    let dfg = Dfg { name: format!("{}-{:016x}", cfg.name, cfg.seed), nodes, edges };
    debug_assert!(dfg.validate().is_empty(), "generator bug: {:?}", dfg.validate());
    dfg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::io;
    use crate::ops::OpGroup;
    use crate::util::prop::forall;

    #[test]
    fn generated_graphs_are_always_valid() {
        forall("gen_valid", 300, 0x6e11, |g| {
            let cfg = arb_config(g.rng, g.size);
            let d = generate(&cfg);
            let errs = d.validate();
            if !errs.is_empty() {
                return Err(format!("cfg {cfg:?} produced invalid graph: {errs:?}"));
            }
            if d.topo_order().is_none() {
                return Err(format!("cfg {cfg:?} produced a cyclic graph"));
            }
            Ok(())
        });
    }

    #[test]
    fn same_seed_and_config_is_byte_identical() {
        forall("gen_deterministic", 100, 0x6e12, |g| {
            let cfg = arb_config(g.rng, g.size);
            let a = io::to_json_string(&generate(&cfg));
            let b = io::to_json_string(&generate(&cfg));
            if a != b {
                return Err(format!("cfg {cfg:?} produced different bytes"));
            }
            Ok(())
        });
    }

    #[test]
    fn shape_knobs_are_respected() {
        let cfg = GenConfig {
            loads: 5,
            compute: 20,
            stores: 3,
            depth: 4,
            ..Default::default()
        };
        let d = generate(&cfg);
        assert_eq!(d.compute_ops(), 20);
        assert!(d.nodes[..5].iter().all(|&op| op == Op::Load));
        // a path visits at most one node per compute layer
        assert!(d.critical_path_nodes() <= 4 + 2, "{}", d.critical_path_nodes());

        let mut arith_only = GroupSet::EMPTY;
        arith_only.insert(OpGroup::Arith);
        let d = generate(&GenConfig { groups: arith_only, ..Default::default() });
        for op in d.nodes.iter().filter(|op| !op.is_memory()) {
            assert_eq!(op.group(), OpGroup::Arith, "{op}");
        }
    }

    #[test]
    fn name_carries_the_seed() {
        let d = generate(&GenConfig { seed: 0xABCD, ..Default::default() });
        assert_eq!(d.name, "gen-000000000000abcd");
    }

    #[test]
    fn absurd_configs_are_clamped_within_interchange_caps() {
        let cfg = GenConfig {
            loads: 10_000,
            compute: 10_000,
            stores: 10_000,
            ..Default::default()
        };
        let d = generate(&cfg);
        assert!(d.validate().is_empty());
        assert!(d.num_nodes() <= io::MAX_NODES, "{}", d.num_nodes());
        assert!(d.num_edges() <= io::MAX_EDGES, "{}", d.num_edges());
        let back = io::from_json_str(&io::to_json_string(&d)).unwrap();
        assert_eq!(back.nodes, d.nodes);
        assert_eq!(back.edges, d.edges);
    }

    #[test]
    fn unary_only_mix_still_covers_every_load() {
        // Other = Exp/Log/Sqrt/Sin/Cos, all unary: loads can only drain
        // through stores, so the generator must grow the sink count
        let mut other_only = GroupSet::EMPTY;
        other_only.insert(OpGroup::Other);
        let cfg = GenConfig {
            loads: 8,
            stores: 1,
            groups: other_only,
            ..Default::default()
        };
        let d = generate(&cfg);
        assert!(d.validate().is_empty(), "{:?}", d.validate());
        assert!(d.nodes.iter().filter(|&&op| op == Op::Store).count() >= 8);
    }
}
