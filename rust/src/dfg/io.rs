//! DFG interchange: validated JSON and DOT import/export.
//!
//! This is the ingestion front door for externally-authored workloads:
//! compilers, graph tooling and load generators hand graphs to HeLEx in
//! one of two textual forms and get back a structurally-checked
//! [`Dfg`] or a precise [`DfgIoError`] — decoding is *total* (never
//! panics, whatever the bytes) and *validating* (everything
//! [`Dfg::validate_typed`] enforces is re-checked here, plus size caps
//! so a hostile payload cannot balloon memory).
//!
//! **JSON** is the canonical format, shared byte-for-byte with the wire
//! codec ([`crate::service::wire`] delegates here):
//!
//! ```json
//! {"name":"sob","nodes":["load","load","mul","add","store"],
//!  "edges":[[0,2],[1,2],[2,3],[1,3],[3,4]]}
//! ```
//!
//! `nodes[i]` is the op of node `i` (see [`Op::name`]); `edges` are
//! `[src,dst]` index pairs. Encoding is deterministic — fixed key
//! order, compact output — so the same graph always serializes to the
//! same bytes on any platform.
//!
//! **DOT** (a practical subset of Graphviz) is supported for interop:
//! node statements must carry a `label` attribute naming the op, edge
//! statements use `->`, and nodes must be declared before they are
//! referenced. `// …`, `# …` and `/* … */` comments are skipped;
//! unknown attributes are ignored.

use super::{Dfg, DfgError};
use crate::ops::Op;
use crate::util::json::{self, Json};
use std::fmt;
use std::path::Path;

/// Upper bound on nodes accepted from an interchange payload.
pub const MAX_NODES: usize = 4096;

/// Upper bound on edges accepted from an interchange payload.
pub const MAX_EDGES: usize = 16384;

/// Upper bound on a graph name, in bytes.
pub const MAX_NAME_LEN: usize = 256;

/// Upper bound on a DOT document, in bytes (JSON is bounded by the HTTP
/// body cap upstream; DOT can also arrive from local files).
pub const MAX_DOT_BYTES: usize = 4 * 1024 * 1024;

/// Why an interchange payload was refused.
#[derive(Debug, Clone)]
pub enum DfgIoError {
    /// Not syntactically valid JSON/DOT.
    Parse(String),
    /// Parses, but does not follow the schema: missing or mistyped
    /// fields, unknown ops, dangling endpoints, size caps.
    Schema(String),
    /// Decodes into a graph that violates DFG structure (cycles,
    /// arity, duplicate edges, …) — the typed violations say which.
    Invalid { name: String, errors: Vec<DfgError> },
}

impl fmt::Display for DfgIoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DfgIoError::Parse(msg) | DfgIoError::Schema(msg) => f.write_str(msg),
            DfgIoError::Invalid { name, errors } => {
                let joined: Vec<String> = errors.iter().map(ToString::to_string).collect();
                write!(f, "dfg '{name}' is invalid: {}", joined.join("; "))
            }
        }
    }
}

impl std::error::Error for DfgIoError {}

type Result<T> = std::result::Result<T, DfgIoError>;

fn schema(msg: impl Into<String>) -> DfgIoError {
    DfgIoError::Schema(msg.into())
}

/// Shared tail of every import path: cap-check, build, validate.
fn finish(name: String, nodes: Vec<Op>, edges: Vec<(u32, u32)>) -> Result<Dfg> {
    let dfg = Dfg { name, nodes, edges };
    let errors = dfg.validate_typed();
    if !errors.is_empty() {
        return Err(DfgIoError::Invalid { name: dfg.name, errors });
    }
    Ok(dfg)
}

// ------------------------------------------------------------------- JSON

/// Encode to the canonical JSON object (the wire schema).
pub fn dfg_to_json(dfg: &Dfg) -> Json {
    Json::obj(vec![
        ("name", Json::str(&dfg.name)),
        ("nodes", Json::Arr(dfg.nodes.iter().map(|op| Json::str(op.name())).collect())),
        (
            "edges",
            Json::Arr(
                dfg.edges
                    .iter()
                    .map(|&(s, d)| Json::Arr(vec![Json::U64(s as u64), Json::U64(d as u64)]))
                    .collect(),
            ),
        ),
    ])
}

/// Canonical file form: compact JSON plus a trailing newline.
pub fn to_json_string(dfg: &Dfg) -> String {
    let mut s = dfg_to_json(dfg).to_string();
    s.push('\n');
    s
}

/// Decode and validate a graph from a parsed JSON value.
pub fn dfg_from_json(j: &Json) -> Result<Dfg> {
    let name = j
        .get("name")
        .ok_or_else(|| schema("missing field 'name'"))?
        .as_str()
        .ok_or_else(|| schema("field 'name' must be a string"))?
        .to_string();
    if name.len() > MAX_NAME_LEN {
        return Err(schema(format!(
            "dfg name is {} bytes, at most {MAX_NAME_LEN} allowed",
            name.len()
        )));
    }
    let node_items = j
        .get("nodes")
        .ok_or_else(|| schema("missing field 'nodes'"))?
        .as_array()
        .ok_or_else(|| schema("field 'nodes' must be an array"))?;
    if node_items.len() > MAX_NODES {
        return Err(schema(format!(
            "dfg '{name}': {} nodes, at most {MAX_NODES} allowed",
            node_items.len()
        )));
    }
    let mut nodes = Vec::with_capacity(node_items.len());
    for (i, node) in node_items.iter().enumerate() {
        let op_name = node
            .as_str()
            .ok_or_else(|| schema(format!("dfg '{name}': nodes[{i}] must be a string")))?;
        let op = Op::from_name(op_name)
            .ok_or_else(|| schema(format!("dfg '{name}': unknown operation '{op_name}'")))?;
        nodes.push(op);
    }
    let edge_items = j
        .get("edges")
        .ok_or_else(|| schema("missing field 'edges'"))?
        .as_array()
        .ok_or_else(|| schema("field 'edges' must be an array"))?;
    if edge_items.len() > MAX_EDGES {
        return Err(schema(format!(
            "dfg '{name}': {} edges, at most {MAX_EDGES} allowed",
            edge_items.len()
        )));
    }
    let mut edges = Vec::with_capacity(edge_items.len());
    for (i, edge) in edge_items.iter().enumerate() {
        let pair = edge
            .as_array()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| schema(format!("dfg '{name}': edges[{i}] must be [src,dst]")))?;
        let endpoint = |k: usize| -> Result<u32> {
            pair[k]
                .as_u64()
                .and_then(|n| u32::try_from(n).ok())
                .filter(|&n| (n as usize) < nodes.len())
                .ok_or_else(|| {
                    schema(format!("dfg '{name}': edges[{i}] endpoint out of range"))
                })
        };
        edges.push((endpoint(0)?, endpoint(1)?));
    }
    finish(name, nodes, edges)
}

/// Decode and validate a graph from JSON text.
pub fn from_json_str(text: &str) -> Result<Dfg> {
    let j = json::parse(text).map_err(|e| DfgIoError::Parse(e.to_string()))?;
    dfg_from_json(&j)
}

// -------------------------------------------------------------------- DOT

fn dot_quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Render as a Graphviz digraph: one `nI [label="op"]` statement per
/// node (declaration order = node id), then one `nS -> nD` per edge.
pub fn to_dot(dfg: &Dfg) -> String {
    let mut out = String::new();
    out.push_str(&format!("digraph {} {{\n", dot_quote(&dfg.name)));
    for (i, op) in dfg.nodes.iter().enumerate() {
        out.push_str(&format!("  n{i} [label=\"{}\"];\n", op.name()));
    }
    for &(s, d) in &dfg.edges {
        out.push_str(&format!("  n{s} -> n{d};\n"));
    }
    out.push_str("}\n");
    out
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Word(String),
    Sym(char),
    Arrow,
}

/// Tokenize a DOT document: bare identifiers, quoted strings (with
/// `\"`/`\\` escapes), the symbols `{ } [ ] = ; ,` and `->`. Comments
/// (`//`, `#`, `/* */`) are skipped. Total: malformed input is a
/// `Parse` error, never a panic.
fn dot_tokens(text: &str) -> Result<Vec<Tok>> {
    let mut toks = Vec::new();
    let bytes = text.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i] as char;
        match c {
            c if c.is_ascii_whitespace() => i += 1,
            '#' => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            '/' if bytes.get(i + 1) == Some(&b'/') => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            '/' if bytes.get(i + 1) == Some(&b'*') => {
                let mut j = i + 2;
                loop {
                    if j + 1 >= bytes.len() {
                        return Err(DfgIoError::Parse("unterminated /* comment".into()));
                    }
                    if bytes[j] == b'*' && bytes[j + 1] == b'/' {
                        break;
                    }
                    j += 1;
                }
                i = j + 2;
            }
            '{' | '}' | '[' | ']' | '=' | ';' | ',' => {
                toks.push(Tok::Sym(c));
                i += 1;
            }
            '-' if bytes.get(i + 1) == Some(&b'>') => {
                toks.push(Tok::Arrow);
                i += 2;
            }
            '"' => {
                let mut raw: Vec<u8> = Vec::new();
                let mut j = i + 1;
                loop {
                    if j >= bytes.len() {
                        return Err(DfgIoError::Parse("unterminated string".into()));
                    }
                    match bytes[j] {
                        b'"' => break,
                        b'\\' => {
                            let esc = *bytes.get(j + 1).ok_or_else(|| {
                                DfgIoError::Parse("unterminated string".into())
                            })?;
                            raw.push(esc);
                            j += 2;
                        }
                        b => {
                            raw.push(b);
                            j += 1;
                        }
                    }
                }
                let s = String::from_utf8(raw)
                    .map_err(|_| DfgIoError::Parse("string is not UTF-8".into()))?;
                toks.push(Tok::Word(s));
                i = j + 1;
            }
            c if c.is_ascii_alphanumeric() || c == '_' || c == '.' => {
                let start = i;
                while i < bytes.len() {
                    let b = bytes[i] as char;
                    if b.is_ascii_alphanumeric() || b == '_' || b == '.' {
                        i += 1;
                    } else {
                        break;
                    }
                }
                toks.push(Tok::Word(text[start..i].to_string()));
            }
            other => {
                return Err(DfgIoError::Parse(format!(
                    "unexpected character '{other}' at byte {i}"
                )));
            }
        }
    }
    Ok(toks)
}

/// Cursor over the token stream with total accessors.
struct DotParser {
    toks: Vec<Tok>,
    pos: usize,
}

impl DotParser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn expect_sym(&mut self, sym: char) -> Result<()> {
        match self.next() {
            Some(Tok::Sym(c)) if c == sym => Ok(()),
            other => Err(DfgIoError::Parse(format!("expected '{sym}', got {other:?}"))),
        }
    }

    fn word(&mut self, what: &str) -> Result<String> {
        match self.next() {
            Some(Tok::Word(w)) => Ok(w),
            other => Err(DfgIoError::Parse(format!("expected {what}, got {other:?}"))),
        }
    }

    /// Consume `[k=v, …]`, returning the value of `label` if present.
    fn attr_list(&mut self) -> Result<Option<String>> {
        self.expect_sym('[')?;
        let mut label = None;
        loop {
            match self.peek() {
                Some(Tok::Sym(']')) => {
                    self.next();
                    return Ok(label);
                }
                Some(Tok::Sym(',')) | Some(Tok::Sym(';')) => {
                    self.next();
                }
                _ => {
                    let key = self.word("attribute name")?;
                    self.expect_sym('=')?;
                    let value = self.word("attribute value")?;
                    if key == "label" {
                        label = Some(value);
                    }
                }
            }
        }
    }

    fn skip_semis(&mut self) {
        while matches!(self.peek(), Some(Tok::Sym(';'))) {
            self.next();
        }
    }
}

/// Parse and validate a DOT digraph (see the module docs for the
/// accepted subset).
pub fn from_dot(text: &str) -> Result<Dfg> {
    if text.len() > MAX_DOT_BYTES {
        return Err(DfgIoError::Parse(format!(
            "dot input is {} bytes, at most {MAX_DOT_BYTES} allowed",
            text.len()
        )));
    }
    let mut p = DotParser { toks: dot_tokens(text)?, pos: 0 };
    match p.next() {
        Some(Tok::Word(w)) if w == "digraph" => {}
        other => {
            return Err(DfgIoError::Parse(format!("expected 'digraph', got {other:?}")));
        }
    }
    let name = match p.peek() {
        Some(Tok::Word(_)) => p.word("graph name")?,
        _ => "dot".to_string(),
    };
    if name.len() > MAX_NAME_LEN {
        return Err(schema(format!(
            "dfg name is {} bytes, at most {MAX_NAME_LEN} allowed",
            name.len()
        )));
    }
    p.expect_sym('{')?;

    let mut ids: Vec<String> = Vec::new();
    let mut nodes: Vec<Op> = Vec::new();
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let lookup = |ids: &[String], id: &str| -> Result<u32> {
        ids.iter()
            .position(|x| x == id)
            .map(|i| i as u32)
            .ok_or_else(|| schema(format!("edge references undeclared node '{id}'")))
    };
    loop {
        p.skip_semis();
        match p.peek() {
            Some(Tok::Sym('}')) => {
                p.next();
                break;
            }
            None => return Err(DfgIoError::Parse("unexpected end of dot input".into())),
            _ => {}
        }
        let first = p.word("node id")?;
        if matches!(first.as_str(), "graph" | "node" | "edge")
            && matches!(p.peek(), Some(Tok::Sym('[')))
        {
            // default-attribute statement: irrelevant here, skip it
            p.attr_list()?;
            continue;
        }
        match p.peek() {
            Some(Tok::Arrow) => {
                // edge chain: a -> b -> c [attrs]
                let mut prev = lookup(&ids, &first)?;
                while matches!(p.peek(), Some(Tok::Arrow)) {
                    p.next();
                    let id = p.word("edge target")?;
                    let dst = lookup(&ids, &id)?;
                    if edges.len() >= MAX_EDGES {
                        return Err(schema(format!(
                            "dfg '{name}': more than {MAX_EDGES} edges"
                        )));
                    }
                    edges.push((prev, dst));
                    prev = dst;
                }
                if matches!(p.peek(), Some(Tok::Sym('['))) {
                    p.attr_list()?;
                }
            }
            Some(Tok::Sym('[')) => {
                // node declaration: id [label="op"]
                let label = p.attr_list()?.ok_or_else(|| {
                    schema(format!("node '{first}' has no label attribute"))
                })?;
                if ids.iter().any(|x| x == &first) {
                    return Err(schema(format!("node '{first}' declared twice")));
                }
                if ids.len() >= MAX_NODES {
                    return Err(schema(format!(
                        "dfg '{name}': more than {MAX_NODES} nodes"
                    )));
                }
                let op = Op::from_name(&label).ok_or_else(|| {
                    schema(format!("dfg '{name}': unknown operation '{label}'"))
                })?;
                ids.push(first);
                nodes.push(op);
            }
            _ => {
                return Err(schema(format!("node '{first}' has no label attribute")));
            }
        }
    }
    if p.peek().is_some() {
        return Err(DfgIoError::Parse("trailing content after digraph".into()));
    }
    finish(name, nodes, edges)
}

// ------------------------------------------------------------------ files

/// Load a graph from a file, dispatching on extension: `.dot`/`.gv`
/// parse as DOT, everything else as JSON.
pub fn from_path(path: &Path) -> Result<Dfg> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| DfgIoError::Parse(format!("{}: {e}", path.display())))?;
    match path.extension().and_then(|e| e.to_str()) {
        Some("dot") | Some("gv") => from_dot(&text),
        _ => from_json_str(&text),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::benchmarks;
    use crate::ops::Op::*;

    fn structurally_equal(a: &Dfg, b: &Dfg) -> bool {
        a.name == b.name && a.nodes == b.nodes && a.edges == b.edges
    }

    #[test]
    fn every_benchmark_roundtrips_through_json() {
        for d in benchmarks::all() {
            let text = to_json_string(&d);
            assert!(text.ends_with('\n'));
            let back = from_json_str(&text).expect(&d.name);
            assert!(structurally_equal(&d, &back), "{} changed across json", d.name);
            // byte-stable: re-encoding the decoded graph is identical
            assert_eq!(to_json_string(&back), text);
        }
    }

    #[test]
    fn every_benchmark_roundtrips_through_dot() {
        for d in benchmarks::all() {
            let text = to_dot(&d);
            let back = from_dot(&text).expect(&d.name);
            assert!(structurally_equal(&d, &back), "{} changed across dot", d.name);
        }
    }

    #[test]
    fn dot_accepts_comments_attrs_and_chains() {
        let text = r#"
            // a hand-written graph
            digraph pipeline {
              graph [rankdir=LR];
              node [shape=box];
              a [label="load", color=red]; /* producer */
              b [label="abs"]
              c [label="store"]
              # chain syntax
              a -> b -> c;
            }
        "#;
        let d = from_dot(text).unwrap();
        assert_eq!(d.name, "pipeline");
        assert_eq!(d.nodes, vec![Load, Abs, Store]);
        assert_eq!(d.edges, vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn dot_rejections_carry_reasons() {
        for (text, needle) in [
            ("graph g { }", "digraph"),
            ("digraph g { a -> b; }", "undeclared node 'a'"),
            ("digraph g { a; }", "no label"),
            ("digraph g { a [shape=box]; }", "no label"),
            ("digraph g { a [label=\"frob\"]; }", "unknown operation"),
            ("digraph g { a [label=\"load\"]; a [label=\"load\"]; }", "declared twice"),
            ("digraph g { a [label=\"load\"]", "end of dot"),
            ("digraph g { } trailing", "trailing"),
            ("digraph g { a [label=\"load\" }", "expected"),
            ("digraph g { /* open", "unterminated"),
            ("digraph g { \"open", "unterminated"),
            ("digraph g { a @ b; }", "unexpected character"),
        ] {
            let err = from_dot(text).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(needle), "{text:?} should mention '{needle}', got: {msg}");
        }
    }

    #[test]
    fn dot_structural_violations_are_typed() {
        // cycle through labeled nodes
        let text = r#"digraph c {
            a [label="add"]; b [label="add"];
            a -> b; b -> a;
        }"#;
        match from_dot(text).unwrap_err() {
            DfgIoError::Invalid { name, errors } => {
                assert_eq!(name, "c");
                assert!(errors.contains(&DfgError::Cycle), "{errors:?}");
            }
            other => panic!("expected Invalid, got {other:?}"),
        }
    }

    #[test]
    fn json_rejections_carry_reasons() {
        for (text, needle) in [
            ("[", "invalid JSON"),
            ("42", "missing field 'name'"),
            (r#"{"name":7,"nodes":[],"edges":[]}"#, "must be a string"),
            (r#"{"name":"t","edges":[]}"#, "missing field 'nodes'"),
            (r#"{"name":"t","nodes":{},"edges":[]}"#, "must be an array"),
            (r#"{"name":"t","nodes":["frob"],"edges":[]}"#, "unknown operation 'frob'"),
            (r#"{"name":"t","nodes":["load"]}"#, "missing field 'edges'"),
            (r#"{"name":"t","nodes":["load","store"],"edges":[[0]]}"#, "[src,dst]"),
            (r#"{"name":"t","nodes":["load","store"],"edges":[[0,5]]}"#, "out of range"),
            (r#"{"name":"t","nodes":["load","store"],"edges":[[0,-1]]}"#, "out of range"),
            (
                r#"{"name":"t","nodes":["add","add"],"edges":[[0,1],[1,0]]}"#,
                "graph has a cycle",
            ),
            (
                r#"{"name":"t","nodes":["load","abs","store"],"edges":[[0,1],[1,1],[1,2]]}"#,
                "self-loop",
            ),
            (
                r#"{"name":"t","nodes":["load","abs","store"],"edges":[[0,1],[0,1],[1,2]]}"#,
                "duplicate edge",
            ),
        ] {
            let err = from_json_str(text).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(needle), "{text:?} should mention '{needle}', got: {msg}");
        }
    }

    #[test]
    fn size_caps_are_enforced() {
        let many_nodes = Json::obj(vec![
            ("name", Json::str("big")),
            ("nodes", Json::Arr(vec![Json::str("add"); MAX_NODES + 1])),
            ("edges", Json::Arr(vec![])),
        ]);
        let msg = dfg_from_json(&many_nodes).unwrap_err().to_string();
        assert!(msg.contains("at most"), "{msg}");

        let many_edges = Json::obj(vec![
            ("name", Json::str("big")),
            ("nodes", Json::Arr(vec![Json::str("add"); 2])),
            (
                "edges",
                Json::Arr(vec![
                    Json::Arr(vec![Json::U64(0), Json::U64(1)]);
                    MAX_EDGES + 1
                ]),
            ),
        ]);
        let msg = dfg_from_json(&many_edges).unwrap_err().to_string();
        assert!(msg.contains("at most"), "{msg}");

        let long_name = Json::obj(vec![
            ("name", Json::str("x".repeat(MAX_NAME_LEN + 1))),
            ("nodes", Json::Arr(vec![])),
            ("edges", Json::Arr(vec![])),
        ]);
        let msg = dfg_from_json(&long_name).unwrap_err().to_string();
        assert!(msg.contains("name"), "{msg}");
    }

    #[test]
    fn deeply_nested_json_is_refused_not_overflowed() {
        let bomb = format!("{}{}", "[".repeat(4000), "]".repeat(4000));
        assert!(matches!(from_json_str(&bomb).unwrap_err(), DfgIoError::Parse(_)));
    }

    #[test]
    fn from_path_dispatches_on_extension() {
        let dir = std::env::temp_dir().join(format!("helex-io-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let d = benchmarks::benchmark("SOB");
        let jpath = dir.join("g.json");
        let dpath = dir.join("g.dot");
        std::fs::write(&jpath, to_json_string(&d)).unwrap();
        std::fs::write(&dpath, to_dot(&d)).unwrap();
        assert!(structurally_equal(&d, &from_path(&jpath).unwrap()));
        assert!(structurally_equal(&d, &from_path(&dpath).unwrap()));
        assert!(from_path(&dir.join("missing.json")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
