//! The 12 benchmark DFGs of paper Table II.
//!
//! Node/edge counts match Table II exactly (asserted by tests). Op mixes
//! follow the paper's descriptions: the S3 set members (FFT, GB, RGB,
//! SOB) contain only Arith/Mult/Mem ops (Section IV-F); BIL carries the
//! chained FDIV/EXP the paper blames for its latency outlier (Section
//! IV-I); MD/NB are FP-heavy with DIV/SQRT; NMS is comparison-heavy.

use super::builder::DfgSpec;
use super::Dfg;
use crate::ops::Op::*;

/// Table II rows: (name, V, E).
pub const TABLE_II: [(&str, usize, usize); 12] = [
    ("BIL", 26, 29),
    ("BOX", 19, 18),
    ("FFT", 54, 68),
    ("GAR", 21, 24),
    ("GB", 16, 12),
    ("MD", 55, 74),
    ("NB", 30, 37),
    ("NMS", 29, 36),
    ("RGB", 27, 30),
    ("ROI", 45, 56),
    ("SAD", 80, 79),
    ("SOB", 9, 8),
];

fn spec(name: &'static str) -> DfgSpec {
    match name {
        // Bilateral filter: FP weights via EXP, normalization via FDIV.
        "BIL" => DfgSpec {
            name: "BIL",
            loads: 6,
            stores: 1,
            compute: vec![
                (FMul, 5),
                (FAdd, 4),
                (FSub, 3),
                (FDiv, 2),
                (Exp, 2),
                (FAbs, 2),
                (IToF, 1),
            ],
            binary: 9,
            seed: 0x811,
        },
        // Box filter: integer accumulate + shift-normalize.
        "BOX" => DfgSpec {
            name: "BOX",
            loads: 5,
            stores: 1,
            compute: vec![(Add, 8), (Mul, 2), (Shr, 2), (Abs, 1)],
            binary: 4,
            seed: 0x80c,
        },
        // Radix-4 FFT butterfly network: Arith + Mult only (S3 member).
        "FFT" => DfgSpec {
            name: "FFT",
            loads: 8,
            stores: 8,
            compute: vec![(Add, 10), (Sub, 10), (Mul, 14), (Shr, 4)],
            binary: 22,
            seed: 0xff7,
        },
        // Gabor filter: sinusoid × Gaussian envelope.
        "GAR" => DfgSpec {
            name: "GAR",
            loads: 4,
            stores: 1,
            compute: vec![
                (FMul, 5),
                (FAdd, 3),
                (FSub, 2),
                (Mul, 2),
                (Sin, 1),
                (Cos, 1),
                (Exp, 1),
                (IToF, 1),
            ],
            binary: 7,
            seed: 0x6a2,
        },
        // Gaussian blur: sparse constant-coefficient kernel (S3 member;
        // E < V, a forest).
        "GB" => DfgSpec {
            name: "GB",
            loads: 4,
            stores: 4,
            compute: vec![(Add, 5), (Mul, 3)],
            binary: 0,
            seed: 0x6b1,
        },
        // Molecular dynamics (Lennard-Jones force kernel).
        "MD" => DfgSpec {
            name: "MD",
            loads: 10,
            stores: 4,
            compute: vec![
                (FMul, 11),
                (FAdd, 7),
                (FSub, 8),
                (FDiv, 3),
                (Sqrt, 2),
                (FCmp, 2),
                (FMin, 2),
                (Mul, 3),
                (Add, 3),
            ],
            binary: 29,
            seed: 0x3d5,
        },
        // N-body acceleration update.
        "NB" => DfgSpec {
            name: "NB",
            loads: 6,
            stores: 3,
            compute: vec![
                (FMul, 7),
                (FAdd, 5),
                (FSub, 4),
                (FDiv, 2),
                (Sqrt, 1),
                (FAbs, 1),
                (IToF, 1),
            ],
            binary: 13,
            seed: 0x2b0,
        },
        // Non-maximal suppression: comparison/select heavy.
        "NMS" => DfgSpec {
            name: "NMS",
            loads: 6,
            stores: 2,
            compute: vec![(Cmp, 5), (Max, 5), (Select, 4), (Add, 3), (Sub, 2), (Mul, 2)],
            binary: 13,
            seed: 0x4e5,
        },
        // RGB→YIQ: 3×3 constant matrix in fixed point (S3 member).
        "RGB" => DfgSpec {
            name: "RGB",
            loads: 3,
            stores: 3,
            compute: vec![(Mul, 9), (Add, 6), (Shr, 3), (Sub, 3)],
            binary: 6,
            seed: 0x26b,
        },
        // Region-of-interest alignment: mixed int/FP address math.
        "ROI" => DfgSpec {
            name: "ROI",
            loads: 8,
            stores: 4,
            compute: vec![
                (Add, 8),
                (Sub, 4),
                (Mul, 6),
                (Cmp, 3),
                (Max, 3),
                (Min, 2),
                (FAdd, 3),
                (FMul, 2),
                (FToI, 1),
                (IToF, 1),
            ],
            binary: 19,
            seed: 0x901,
        },
        // Sum of absolute differences: |a-b| tree + adder reduction.
        "SAD" => DfgSpec {
            name: "SAD",
            loads: 16,
            stores: 1,
            compute: vec![(Abs, 24), (Sub, 24), (Add, 15)],
            binary: 15,
            seed: 0x5ad,
        },
        // Sobel: tiny gradient kernel (S3 member).
        "SOB" => DfgSpec {
            name: "SOB",
            loads: 4,
            stores: 1,
            compute: vec![(Add, 2), (Mul, 1), (Abs, 1)],
            binary: 3,
            seed: 0x50b,
        },
        other => panic!("unknown benchmark {other}"),
    }
}

/// Build one Table II benchmark by name.
pub fn benchmark(name: &str) -> Dfg {
    spec(match name {
        "BIL" | "BOX" | "FFT" | "GAR" | "GB" | "MD" | "NB" | "NMS" | "RGB" | "ROI" | "SAD"
        | "SOB" => {
            // map to 'static
            TABLE_II.iter().find(|(n, _, _)| *n == name).unwrap().0
        }
        other => panic!("unknown benchmark {other}"),
    })
    .build()
}

/// All 12 benchmarks in Table II order.
pub fn all() -> Vec<Dfg> {
    TABLE_II.iter().map(|(n, _, _)| benchmark(n)).collect()
}

/// The DFG sets of Table VII, as `(set id, member names, configurations)`.
pub const TABLE_VII: [(&str, &[&str], [(usize, usize); 2]); 6] = [
    ("S1", &["GAR", "NMS", "ROI"], [(7, 9), (9, 11)]),
    ("S2", &["BIL", "NB", "NMS", "RGB"], [(7, 7), (9, 9)]),
    ("S3", &["FFT", "GB", "RGB", "SOB"], [(10, 10), (12, 12)]),
    ("S4", &["BIL", "BOX", "GB", "GAR", "SOB"], [(7, 7), (9, 9)]),
    ("S5", &["BIL", "GB", "MD", "NB", "ROI", "SOB"], [(9, 9), (11, 11)]),
    ("S6", &["BIL", "MD", "NB", "RGB", "ROI", "SAD", "SOB"], [(10, 10), (12, 12)]),
];

/// Build a Table VII set by id ("S1".."S6").
pub fn dfg_set(id: &str) -> Vec<Dfg> {
    let (_, names, _) = TABLE_VII
        .iter()
        .find(|(s, _, _)| *s == id)
        .unwrap_or_else(|| panic!("unknown set {id}"));
    names.iter().map(|n| benchmark(n)).collect()
}

/// The 9 target CGRA sizes of Section IV.
pub const PAPER_SIZES: [(usize, usize); 9] = [
    (10, 10),
    (10, 12),
    (10, 14),
    (11, 11),
    (11, 13),
    (11, 15),
    (12, 12),
    (12, 14),
    (13, 15),
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::OpGroup;

    #[test]
    fn node_edge_counts_match_table_2() {
        for (name, v, e) in TABLE_II {
            let d = benchmark(name);
            assert_eq!(d.num_nodes(), v, "{name} V");
            assert_eq!(d.num_edges(), e, "{name} E");
        }
    }

    #[test]
    fn all_benchmarks_are_valid_dags() {
        for d in all() {
            let errs = d.validate();
            assert!(errs.is_empty(), "{}: {errs:?}", d.name);
        }
    }

    #[test]
    fn s3_members_are_arith_mult_only() {
        for name in ["FFT", "GB", "RGB", "SOB"] {
            let d = benchmark(name);
            for op in &d.nodes {
                let g = op.group();
                assert!(
                    matches!(g, OpGroup::Arith | OpGroup::Mult | OpGroup::Mem),
                    "{name} contains {op} in group {g}"
                );
            }
        }
    }

    #[test]
    fn bil_has_chained_div_and_exp() {
        let d = benchmark("BIL");
        let h = d.group_histogram();
        assert!(h[OpGroup::Div.index()] >= 2);
        assert!(h[OpGroup::Other.index()] >= 2);
    }

    #[test]
    fn md_nb_are_fp_heavy() {
        for name in ["MD", "NB"] {
            let d = benchmark(name);
            let h = d.group_histogram();
            assert!(h[OpGroup::FP.index()] > h[OpGroup::Arith.index()], "{name}");
            assert!(h[OpGroup::Div.index()] >= 1, "{name} needs DIV");
            assert!(h[OpGroup::Other.index()] >= 1, "{name} needs SQRT");
        }
    }

    #[test]
    fn sets_reference_known_benchmarks() {
        for (id, names, cfgs) in TABLE_VII {
            let set = dfg_set(id);
            assert_eq!(set.len(), names.len());
            for (r, c) in cfgs {
                assert!(r >= 3 && c >= 3);
            }
        }
    }

    #[test]
    fn builds_are_deterministic() {
        for (name, _, _) in TABLE_II {
            let a = benchmark(name);
            let b = benchmark(name);
            assert_eq!(a.edges, b.edges, "{name}");
        }
    }

    #[test]
    fn mem_ops_fit_smallest_paper_border() {
        // every DFG must have <= border I/O cells on the smallest grid it
        // is mapped to in the paper (7x7 for the sets, 10x10 for Table II)
        for d in all() {
            assert!(d.mem_ops() <= 36, "{}: {} mem ops", d.name, d.mem_ops());
        }
        for name in ["BIL", "BOX", "GB", "GAR", "SOB"] {
            // S4 runs at 7x7: border = 2*7 + 2*5 = 24
            assert!(benchmark(name).mem_ops() <= 24, "{name}");
        }
    }
}
