//! Deterministic synthetic-DFG builder.
//!
//! The paper's 12 benchmark DFGs (Table II) and HETA's 8 DFGs (Table IX)
//! are not published as files; what the search observes is their
//! *structure*: node/edge counts, per-group op histograms and DAG shape.
//! This builder generates DAGs that match those exactly (asserted in
//! tests) with kernel-like locality: consumers prefer recently-produced
//! values, loads feed the front, stores drain the back.

use super::{Dfg, NodeId};
use crate::ops::Op;
use crate::util::rng::Rng;

/// Specification for one synthetic DFG.
#[derive(Debug, Clone)]
pub struct DfgSpec {
    pub name: &'static str,
    pub loads: usize,
    pub stores: usize,
    /// Compute op multiset as `(op, count)`.
    pub compute: Vec<(Op, usize)>,
    /// How many of the arity-2-capable compute nodes actually receive two
    /// inputs (the rest receive one — an implicit-constant operand, as in
    /// the ExPRESS/HETA DFGs). Unary ops always receive one.
    pub binary: usize,
    /// RNG seed: structure is a pure function of the spec.
    pub seed: u64,
}

impl DfgSpec {
    pub fn num_nodes(&self) -> usize {
        self.loads + self.stores + self.compute.iter().map(|(_, c)| c).sum::<usize>()
    }

    pub fn num_edges(&self) -> usize {
        let n_compute: usize = self.compute.iter().map(|(_, c)| c).sum();
        let n_unary_ops: usize =
            self.compute.iter().filter(|(o, _)| o.arity() == 1).map(|(_, c)| c).sum();
        let binary_capable = n_compute - n_unary_ops;
        assert!(
            self.binary <= binary_capable,
            "{}: binary={} exceeds capable={}",
            self.name,
            self.binary,
            binary_capable
        );
        // stores contribute 1 in-edge each; compute nodes contribute their
        // assigned indegree.
        self.stores + n_compute + self.binary
    }

    /// Build the DFG. Panics (via debug assertions in tests) only on
    /// impossible specs.
    pub fn build(&self) -> Dfg {
        // Coverage bound: every non-store node needs >= 1 consumer, and
        // each edge covers at most one new producer, so E >= V - S.
        assert!(
            self.num_edges() >= self.num_nodes() - self.stores,
            "{}: E={} < V-S={} — spec cannot cover all producers",
            self.name,
            self.num_edges(),
            self.num_nodes() - self.stores
        );
        let mut rng = Rng::seed(self.seed);

        // Node layout: [loads][compute (shuffled op order)][stores].
        let mut ops: Vec<Op> = Vec::with_capacity(self.num_nodes());
        for _ in 0..self.loads {
            ops.push(Op::Load);
        }
        let mut compute_ops: Vec<Op> = Vec::new();
        for &(op, count) in &self.compute {
            for _ in 0..count {
                compute_ops.push(op);
            }
        }
        rng.shuffle(&mut compute_ops);
        let compute_start = ops.len();
        ops.extend(compute_ops.iter().copied());
        let store_start = ops.len();
        for _ in 0..self.stores {
            ops.push(Op::Store);
        }
        let _n_compute = store_start - compute_start;

        // Assign indegrees: binary-capable nodes get 2 inputs until the
        // budget is spent (later nodes first, so the "front" of the kernel
        // stays load-fed and the reduction tree sits at the back).
        let mut indeg = vec![0usize; ops.len()];
        let mut budget = self.binary;
        for i in (compute_start..store_start).rev() {
            let cap = ops[i].arity();
            indeg[i] = 1;
            // a node at position i can see only the i producers before it,
            // so indeg 2 requires i >= 2
            if cap == 2 && budget > 0 && i >= 2 {
                indeg[i] = 2;
                budget -= 1;
            }
        }
        assert_eq!(
            budget, 0,
            "{}: not enough binary-capable nodes with >=2 visible producers",
            self.name
        );
        for i in store_start..ops.len() {
            indeg[i] = 1;
        }

        // Wire edges. `uncovered` = earlier value-producing nodes that do
        // not yet feed anything; every producer must end up consumed.
        let mut edges: Vec<(NodeId, NodeId)> = Vec::with_capacity(self.num_edges());
        let mut outdeg = vec![0usize; ops.len()];
        for i in compute_start..ops.len() {
            let mut picked: Vec<usize> = Vec::with_capacity(indeg[i]);
            // producers visible to node i: all loads + compute before i
            // (stores consume compute-or-load values like everyone else).
            let visible_end = i.min(store_start);
            for _slot in 0..indeg[i] {
                // 1) earliest uncovered producer, to guarantee coverage;
                let uncovered: Vec<usize> = (0..visible_end)
                    .filter(|&p| outdeg[p] == 0 && !picked.contains(&p))
                    .collect();
                let choice = if !uncovered.is_empty() {
                    // Bias stores toward *late* uncovered producers (drain
                    // the back of the kernel), compute toward early ones.
                    if i >= store_start {
                        *uncovered.last().unwrap()
                    } else {
                        uncovered[0]
                    }
                } else {
                    // 2) otherwise a random recent producer (locality).
                    let window = 8.max(visible_end / 3);
                    let lo = visible_end.saturating_sub(window);
                    let mut tries = 0;
                    loop {
                        let p = rng.range(lo, visible_end);
                        if !picked.contains(&p) {
                            break p;
                        }
                        tries += 1;
                        if tries > 32 {
                            // fall back to any unpicked producer
                            break (0..visible_end).find(|p| !picked.contains(p)).expect(
                                "at least indeg distinct producers must exist",
                            );
                        }
                    }
                };
                picked.push(choice);
                outdeg[choice] += 1;
                edges.push((choice as NodeId, i as NodeId));
            }
        }

        // Repair pass: any producer still uncovered steals an edge slot
        // from an over-shared producer of some later consumer.
        loop {
            let Some(u) = (0..store_start).find(|&p| outdeg[p] == 0) else { break };
            let mut fixed = false;
            // find a consumer later than u whose some pred has outdeg >= 2
            for ei in 0..edges.len() {
                let (p, c) = edges[ei];
                let (p, c) = (p as usize, c as usize);
                if c > u
                    && outdeg[p] >= 2
                    && p != u
                    && !edges.iter().any(|&(a, b)| a as usize == u && b as usize == c)
                {
                    outdeg[p] -= 1;
                    outdeg[u] += 1;
                    edges[ei] = (u as NodeId, c as NodeId);
                    fixed = true;
                    break;
                }
            }
            assert!(fixed, "{}: cannot cover producer {} — spec infeasible", self.name, u);
        }

        let dfg = Dfg::new(self.name, ops, edges);
        debug_assert_eq!(dfg.num_nodes(), self.num_nodes());
        debug_assert_eq!(dfg.num_edges(), self.num_edges());
        dfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{Op::*, OpGroup};

    fn spec() -> DfgSpec {
        DfgSpec {
            name: "t",
            loads: 4,
            stores: 2,
            compute: vec![(Add, 5), (Mul, 3), (Abs, 2)],
            binary: 6,
            seed: 1,
        }
    }

    #[test]
    fn counts_match_formula() {
        let s = spec();
        assert_eq!(s.num_nodes(), 16);
        // stores(2) + compute(10) + binary(6) = 18
        assert_eq!(s.num_edges(), 18);
        let d = s.build();
        assert_eq!(d.num_nodes(), 16);
        assert_eq!(d.num_edges(), 18);
    }

    #[test]
    fn built_dfg_is_valid() {
        let d = spec().build();
        let errs = d.validate();
        assert!(errs.is_empty(), "{errs:?}");
    }

    #[test]
    fn deterministic_for_same_spec() {
        let a = spec().build();
        let b = spec().build();
        assert_eq!(a.edges, b.edges);
        assert_eq!(a.nodes, b.nodes);
    }

    #[test]
    fn different_seed_different_wiring() {
        let a = spec().build();
        let mut s = spec();
        s.seed = 99;
        let b = s.build();
        assert_ne!(a.edges, b.edges);
    }

    #[test]
    fn histogram_matches_spec() {
        let d = spec().build();
        let h = d.group_histogram();
        assert_eq!(h[OpGroup::Mem.index()], 6);
        assert_eq!(h[OpGroup::Arith.index()], 7); // 5 add + 2 abs
        assert_eq!(h[OpGroup::Mult.index()], 3);
    }

    #[test]
    fn every_producer_is_consumed() {
        let d = spec().build();
        let succs = d.succs();
        for (i, op) in d.nodes.iter().enumerate() {
            if *op != Store {
                assert!(!succs[i].is_empty(), "node {i} ({op}) unconsumed");
            }
        }
    }

    #[test]
    fn unary_heavy_spec_builds() {
        let s = DfgSpec {
            name: "u",
            loads: 3,
            stores: 2,
            compute: vec![(Abs, 6), (Add, 2)],
            binary: 1,
            seed: 5,
        };
        let d = s.build();
        assert!(d.validate().is_empty(), "{:?}", d.validate());
        assert_eq!(d.num_edges(), s.num_edges());
    }
}
