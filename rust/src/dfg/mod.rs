//! Data-flow graph IR.
//!
//! A DFG is a DAG of operations (Section II-A): nodes carry an [`Op`],
//! edges carry values. Loads are sources, stores are sinks; compute nodes
//! have 1 or 2 data inputs. Instances of the DFG execute pipelined on the
//! CGRA, so the mapper assigns every node to a distinct cell.

pub mod builder;
pub mod benchmarks;
pub mod gen;
pub mod heta;
pub mod io;

use crate::ops::{GroupSet, Op, OpGroup, NUM_GROUPS};
use std::collections::VecDeque;
use std::fmt;

/// Node id within a DFG.
pub type NodeId = u32;

/// One structural violation found by [`Dfg::validate_typed`].
///
/// `Display` reproduces the exact strings [`Dfg::validate`] has always
/// emitted, so callers matching on substrings (tests, HTTP error bodies)
/// are unaffected by the typed form. `dfg::io` and `service::wire` reuse
/// the enum so a rejected graph can be reported with the precise reason.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DfgError {
    /// An edge endpoint is `>=` the node count.
    EdgeOutOfRange { src: NodeId, dst: NodeId },
    /// An edge with `src == dst`.
    SelfLoop { node: NodeId },
    /// The same `(src, dst)` edge appears more than once.
    DuplicateEdge { src: NodeId, dst: NodeId },
    /// The graph has a directed cycle.
    Cycle,
    /// A load (source) node with data inputs.
    LoadHasInputs { node: usize, indeg: usize },
    /// A store (sink) node whose indegree is not exactly 1.
    StoreBadInputs { node: usize, indeg: usize },
    /// A compute node with indegree 0 or more inputs than its arity.
    BadIndegree { node: usize, op: Op, indeg: usize, arity: usize },
    /// A load or compute node whose value nobody consumes.
    NoConsumers { node: usize, op: Op },
    /// A node with several in-edges from the same producer.
    ParallelInEdges { node: usize },
}

impl fmt::Display for DfgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            DfgError::EdgeOutOfRange { src, dst } => {
                write!(f, "edge ({src},{dst}) out of range")
            }
            DfgError::SelfLoop { node } => write!(f, "self-loop at {node}"),
            DfgError::DuplicateEdge { src, dst } => {
                write!(f, "duplicate edge ({src},{dst})")
            }
            DfgError::Cycle => write!(f, "graph has a cycle"),
            DfgError::LoadHasInputs { node, indeg } => {
                write!(f, "load {node} has {indeg} inputs")
            }
            DfgError::StoreBadInputs { node, indeg } => {
                write!(f, "store {node} has {indeg} inputs")
            }
            DfgError::BadIndegree { node, op, indeg, arity } => {
                write!(f, "compute {node} ({op}) indeg {indeg} vs arity {arity}")
            }
            DfgError::NoConsumers { node, op } => match op {
                Op::Load => write!(f, "load {node} has no consumers"),
                _ => write!(f, "compute {node} ({op}) has no consumers"),
            },
            DfgError::ParallelInEdges { node } => {
                write!(f, "node {node} has parallel in-edges")
            }
        }
    }
}

impl std::error::Error for DfgError {}

/// A data-flow graph. `Hash` is content identity (name + nodes + edges),
/// used by the mapper's feasibility cache and the service's job
/// fingerprints.
#[derive(Debug, Clone, Hash)]
pub struct Dfg {
    pub name: String,
    /// Node id = index.
    pub nodes: Vec<Op>,
    /// Directed value edges `(src, dst)`.
    pub edges: Vec<(NodeId, NodeId)>,
}

impl Dfg {
    pub fn new(name: &str, nodes: Vec<Op>, edges: Vec<(NodeId, NodeId)>) -> Self {
        Self { name: name.to_string(), nodes, edges }
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Predecessor lists, indexed by node.
    pub fn preds(&self) -> Vec<Vec<NodeId>> {
        let mut p = vec![Vec::new(); self.nodes.len()];
        for &(s, d) in &self.edges {
            p[d as usize].push(s);
        }
        p
    }

    /// Successor lists, indexed by node.
    pub fn succs(&self) -> Vec<Vec<NodeId>> {
        let mut s = vec![Vec::new(); self.nodes.len()];
        for &(a, b) in &self.edges {
            s[a as usize].push(b);
        }
        s
    }

    /// Kahn topological order. Returns `None` if the graph has a cycle.
    pub fn topo_order(&self) -> Option<Vec<NodeId>> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        for &(_, d) in &self.edges {
            indeg[d as usize] += 1;
        }
        let succs = self.succs();
        let mut q: VecDeque<NodeId> =
            (0..n as NodeId).filter(|&i| indeg[i as usize] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(u) = q.pop_front() {
            order.push(u);
            for &v in &succs[u as usize] {
                indeg[v as usize] -= 1;
                if indeg[v as usize] == 0 {
                    q.push_back(v);
                }
            }
        }
        (order.len() == n).then_some(order)
    }

    /// Count of operations per group, indexed by `OpGroup::index()`.
    pub fn group_histogram(&self) -> [usize; NUM_GROUPS] {
        let mut h = [0usize; NUM_GROUPS];
        for op in &self.nodes {
            h[op.group().index()] += 1;
        }
        h
    }

    /// Set of groups appearing in this DFG.
    pub fn groups_used(&self) -> GroupSet {
        let mut s = GroupSet::EMPTY;
        for op in &self.nodes {
            s.insert(op.group());
        }
        s
    }

    /// Number of memory (load/store) operations.
    pub fn mem_ops(&self) -> usize {
        self.nodes.iter().filter(|o| o.is_memory()).count()
    }

    /// Number of compute (non-memory) operations.
    pub fn compute_ops(&self) -> usize {
        self.nodes.len() - self.mem_ops()
    }

    /// True if the DFG uses any group in `mask` (used by OPSG selective
    /// testing: only DFGs containing the removed group need re-mapping).
    pub fn uses_any(&self, mask: GroupSet) -> bool {
        !self.groups_used().intersect(mask).is_empty()
    }

    /// Structural validation. Returns a list of violations (empty = ok);
    /// the strings are the `Display` forms of [`Dfg::validate_typed`].
    pub fn validate(&self) -> Vec<String> {
        self.validate_typed().iter().map(ToString::to_string).collect()
    }

    /// Structural validation with typed violations (empty = ok). Total:
    /// never panics, whatever the node/edge contents.
    pub fn validate_typed(&self) -> Vec<DfgError> {
        let mut errs = Vec::new();
        let n = self.nodes.len();
        let mut seen: Vec<(NodeId, NodeId)> = Vec::with_capacity(self.edges.len());
        for &(s, d) in &self.edges {
            if s as usize >= n || d as usize >= n {
                errs.push(DfgError::EdgeOutOfRange { src: s, dst: d });
            }
            if s == d {
                errs.push(DfgError::SelfLoop { node: s });
            }
            seen.push((s, d));
        }
        seen.sort_unstable();
        let mut prev: Option<(NodeId, NodeId)> = None;
        for &e in &seen {
            if prev == Some(e) {
                let last = errs.last();
                let already = matches!(
                    last,
                    Some(DfgError::DuplicateEdge { src, dst }) if (*src, *dst) == e
                );
                if !already {
                    errs.push(DfgError::DuplicateEdge { src: e.0, dst: e.1 });
                }
            }
            prev = Some(e);
        }
        // degree and cycle analysis index adjacency by endpoint: bail
        // before them when an edge points outside the node range
        if errs.iter().any(|e| matches!(e, DfgError::EdgeOutOfRange { .. })) {
            return errs;
        }
        if self.topo_order().is_none() {
            errs.push(DfgError::Cycle);
        }
        let preds = self.preds();
        let succs = self.succs();
        for (i, &op) in self.nodes.iter().enumerate() {
            let indeg = preds[i].len();
            let outdeg = succs[i].len();
            match op {
                Op::Load => {
                    if indeg != 0 {
                        errs.push(DfgError::LoadHasInputs { node: i, indeg });
                    }
                    if outdeg == 0 {
                        errs.push(DfgError::NoConsumers { node: i, op });
                    }
                }
                Op::Store => {
                    if indeg != 1 {
                        errs.push(DfgError::StoreBadInputs { node: i, indeg });
                    }
                }
                _ => {
                    if indeg == 0 || indeg > op.arity().max(1) {
                        errs.push(DfgError::BadIndegree {
                            node: i,
                            op,
                            indeg,
                            arity: op.arity(),
                        });
                    }
                    if outdeg == 0 {
                        errs.push(DfgError::NoConsumers { node: i, op });
                    }
                }
            }
            // several in-edges from one producer
            let mut ps = preds[i].clone();
            ps.sort_unstable();
            ps.dedup();
            if ps.len() != preds[i].len() {
                errs.push(DfgError::ParallelInEdges { node: i });
            }
        }
        errs
    }

    /// Longest path length in *nodes* (unmapped critical path), used as
    /// the latency baseline denominator in Fig 10.
    pub fn critical_path_nodes(&self) -> usize {
        let order = self.topo_order().expect("DAG");
        let preds = self.preds();
        let mut depth = vec![1usize; self.nodes.len()];
        for &u in &order {
            for &p in &preds[u as usize] {
                depth[u as usize] = depth[u as usize].max(depth[p as usize] + 1);
            }
        }
        depth.into_iter().max().unwrap_or(0)
    }
}

/// Per-group maximum op count across a set of DFGs — the theoretical
/// minimum number of group instances a layout must provide (Section
/// III-D), used for pruning and for the Fig 6 bound.
pub fn min_group_instances(dfgs: &[Dfg]) -> [usize; NUM_GROUPS] {
    let mut m = [0usize; NUM_GROUPS];
    for d in dfgs {
        let h = d.group_histogram();
        for i in 0..NUM_GROUPS {
            m[i] = m[i].max(h[i]);
        }
    }
    m
}

/// Union of groups used across a set of DFGs (defines the full layout).
pub fn groups_used(dfgs: &[Dfg]) -> GroupSet {
    let mut s = GroupSet::EMPTY;
    for d in dfgs {
        s = s.union(d.groups_used());
    }
    s
}

/// Convenience: per-group op count of one DFG restricted to compute groups.
pub fn compute_group_count(d: &Dfg, g: OpGroup) -> usize {
    d.group_histogram()[g.index()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::Op::*;

    fn tiny() -> Dfg {
        // load -> add -> store ; load -> add
        Dfg::new(
            "tiny",
            vec![Load, Load, Add, Store],
            vec![(0, 2), (1, 2), (2, 3)],
        )
    }

    #[test]
    fn tiny_is_valid() {
        assert!(tiny().validate().is_empty());
    }

    #[test]
    fn topo_order_is_topological() {
        let d = tiny();
        let order = d.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; d.num_nodes()];
            for (i, &n) in order.iter().enumerate() {
                p[n as usize] = i;
            }
            p
        };
        for &(s, t) in &d.edges {
            assert!(pos[s as usize] < pos[t as usize]);
        }
    }

    #[test]
    fn cycle_detected() {
        let d = Dfg::new("cyc", vec![Add, Add], vec![(0, 1), (1, 0)]);
        assert!(d.topo_order().is_none());
        assert!(d.validate().iter().any(|e| e.contains("cycle")));
    }

    #[test]
    fn histogram_and_groups() {
        let d = tiny();
        let h = d.group_histogram();
        assert_eq!(h[OpGroup::Mem.index()], 3);
        assert_eq!(h[OpGroup::Arith.index()], 1);
        assert!(d.groups_used().contains(OpGroup::Mem));
        assert!(d.groups_used().contains(OpGroup::Arith));
        assert!(!d.groups_used().contains(OpGroup::Div));
        assert_eq!(d.mem_ops(), 3);
        assert_eq!(d.compute_ops(), 1);
    }

    #[test]
    fn min_instances_is_per_group_max() {
        let a = Dfg::new("a", vec![Load, Mul, Mul, Store], vec![(0, 1), (1, 2), (2, 3)]);
        let b = tiny();
        let m = min_group_instances(&[a, b]);
        assert_eq!(m[OpGroup::Mult.index()], 2);
        assert_eq!(m[OpGroup::Arith.index()], 1);
        assert_eq!(m[OpGroup::Mem.index()], 3);
    }

    #[test]
    fn critical_path_counts_nodes() {
        assert_eq!(tiny().critical_path_nodes(), 3); // load->add->store
    }

    #[test]
    fn invalid_arity_flagged() {
        // add with 3 inputs
        let d = Dfg::new(
            "bad",
            vec![Load, Load, Load, Add, Store],
            vec![(0, 3), (1, 3), (2, 3), (3, 4)],
        );
        assert!(d.validate().iter().any(|e| e.contains("indeg")));
    }

    #[test]
    fn duplicate_edge_reported_explicitly() {
        // the (0,2) edge appears twice: both the typed DuplicateEdge and
        // the per-node parallel-in-edges report fire
        let d = Dfg::new(
            "dup",
            vec![Load, Load, Add, Store],
            vec![(0, 2), (0, 2), (1, 2), (2, 3)],
        );
        let typed = d.validate_typed();
        assert!(typed.contains(&DfgError::DuplicateEdge { src: 0, dst: 2 }), "{typed:?}");
        assert!(typed.contains(&DfgError::ParallelInEdges { node: 2 }), "{typed:?}");
        let strs = d.validate();
        assert!(strs.iter().any(|e| e.contains("duplicate edge (0,2)")), "{strs:?}");
    }

    #[test]
    fn self_loop_reported_explicitly() {
        let d = Dfg::new("sl", vec![Load, Add, Store], vec![(0, 1), (1, 1), (1, 2)]);
        let typed = d.validate_typed();
        assert!(typed.contains(&DfgError::SelfLoop { node: 1 }), "{typed:?}");
        assert!(d.validate().iter().any(|e| e.contains("self-loop at 1")));
    }

    #[test]
    fn typed_and_string_validation_agree() {
        let cases = vec![
            tiny(),
            Dfg::new("cyc", vec![Add, Add], vec![(0, 1), (1, 0)]),
            Dfg::new("dangling", vec![Load, Add, Store], vec![(0, 1), (1, 2), (7, 1)]),
            Dfg::new("orphan", vec![Load, Add, Store], vec![(0, 1), (1, 2), (0, 1)]),
        ];
        for d in cases {
            let typed: Vec<String> =
                d.validate_typed().iter().map(ToString::to_string).collect();
            assert_eq!(typed, d.validate(), "dfg {}", d.name);
        }
    }

    #[test]
    fn error_display_matches_historic_strings() {
        assert_eq!(
            DfgError::EdgeOutOfRange { src: 3, dst: 9 }.to_string(),
            "edge (3,9) out of range"
        );
        assert_eq!(DfgError::Cycle.to_string(), "graph has a cycle");
        assert_eq!(
            DfgError::NoConsumers { node: 2, op: Op::Load }.to_string(),
            "load 2 has no consumers"
        );
        assert_eq!(
            DfgError::NoConsumers { node: 2, op: Op::Mul }.to_string(),
            "compute 2 (mul) has no consumers"
        );
        assert_eq!(
            DfgError::BadIndegree { node: 1, op: Op::Add, indeg: 3, arity: 2 }.to_string(),
            "compute 1 (add) indeg 3 vs arity 2"
        );
    }

    #[test]
    fn uses_any_matches_selective_testing_rule() {
        let d = tiny();
        let mut only_div = GroupSet::EMPTY;
        only_div.insert(OpGroup::Div);
        assert!(!d.uses_any(only_div));
        assert!(d.uses_any(only_div.with(OpGroup::Arith)));
    }
}
