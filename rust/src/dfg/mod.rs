//! Data-flow graph IR.
//!
//! A DFG is a DAG of operations (Section II-A): nodes carry an [`Op`],
//! edges carry values. Loads are sources, stores are sinks; compute nodes
//! have 1 or 2 data inputs. Instances of the DFG execute pipelined on the
//! CGRA, so the mapper assigns every node to a distinct cell.

pub mod builder;
pub mod benchmarks;
pub mod heta;

use crate::ops::{GroupSet, Op, OpGroup, NUM_GROUPS};
use std::collections::VecDeque;

/// Node id within a DFG.
pub type NodeId = u32;

/// A data-flow graph. `Hash` is content identity (name + nodes + edges),
/// used by the mapper's feasibility cache and the service's job
/// fingerprints.
#[derive(Debug, Clone, Hash)]
pub struct Dfg {
    pub name: String,
    /// Node id = index.
    pub nodes: Vec<Op>,
    /// Directed value edges `(src, dst)`.
    pub edges: Vec<(NodeId, NodeId)>,
}

impl Dfg {
    pub fn new(name: &str, nodes: Vec<Op>, edges: Vec<(NodeId, NodeId)>) -> Self {
        Self { name: name.to_string(), nodes, edges }
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Predecessor lists, indexed by node.
    pub fn preds(&self) -> Vec<Vec<NodeId>> {
        let mut p = vec![Vec::new(); self.nodes.len()];
        for &(s, d) in &self.edges {
            p[d as usize].push(s);
        }
        p
    }

    /// Successor lists, indexed by node.
    pub fn succs(&self) -> Vec<Vec<NodeId>> {
        let mut s = vec![Vec::new(); self.nodes.len()];
        for &(a, b) in &self.edges {
            s[a as usize].push(b);
        }
        s
    }

    /// Kahn topological order. Returns `None` if the graph has a cycle.
    pub fn topo_order(&self) -> Option<Vec<NodeId>> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        for &(_, d) in &self.edges {
            indeg[d as usize] += 1;
        }
        let succs = self.succs();
        let mut q: VecDeque<NodeId> =
            (0..n as NodeId).filter(|&i| indeg[i as usize] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(u) = q.pop_front() {
            order.push(u);
            for &v in &succs[u as usize] {
                indeg[v as usize] -= 1;
                if indeg[v as usize] == 0 {
                    q.push_back(v);
                }
            }
        }
        (order.len() == n).then_some(order)
    }

    /// Count of operations per group, indexed by `OpGroup::index()`.
    pub fn group_histogram(&self) -> [usize; NUM_GROUPS] {
        let mut h = [0usize; NUM_GROUPS];
        for op in &self.nodes {
            h[op.group().index()] += 1;
        }
        h
    }

    /// Set of groups appearing in this DFG.
    pub fn groups_used(&self) -> GroupSet {
        let mut s = GroupSet::EMPTY;
        for op in &self.nodes {
            s.insert(op.group());
        }
        s
    }

    /// Number of memory (load/store) operations.
    pub fn mem_ops(&self) -> usize {
        self.nodes.iter().filter(|o| o.is_memory()).count()
    }

    /// Number of compute (non-memory) operations.
    pub fn compute_ops(&self) -> usize {
        self.nodes.len() - self.mem_ops()
    }

    /// True if the DFG uses any group in `mask` (used by OPSG selective
    /// testing: only DFGs containing the removed group need re-mapping).
    pub fn uses_any(&self, mask: GroupSet) -> bool {
        !self.groups_used().intersect(mask).is_empty()
    }

    /// Structural validation. Returns a list of violations (empty = ok).
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        let n = self.nodes.len();
        for &(s, d) in &self.edges {
            if s as usize >= n || d as usize >= n {
                errs.push(format!("edge ({s},{d}) out of range"));
            }
            if s == d {
                errs.push(format!("self-loop at {s}"));
            }
        }
        if self.topo_order().is_none() {
            errs.push("graph has a cycle".into());
        }
        let preds = self.preds();
        let succs = self.succs();
        for (i, op) in self.nodes.iter().enumerate() {
            let indeg = preds[i].len();
            let outdeg = succs[i].len();
            match op {
                Op::Load => {
                    if indeg != 0 {
                        errs.push(format!("load {i} has {indeg} inputs"));
                    }
                    if outdeg == 0 {
                        errs.push(format!("load {i} has no consumers"));
                    }
                }
                Op::Store => {
                    if indeg != 1 {
                        errs.push(format!("store {i} has {indeg} inputs"));
                    }
                }
                _ => {
                    if indeg == 0 || indeg > op.arity().max(1) {
                        errs.push(format!(
                            "compute {i} ({op}) indeg {indeg} vs arity {}",
                            op.arity()
                        ));
                    }
                    if outdeg == 0 {
                        errs.push(format!("compute {i} ({op}) has no consumers"));
                    }
                }
            }
            // duplicate parallel edges
            let mut ps = preds[i].clone();
            ps.sort_unstable();
            ps.dedup();
            if ps.len() != preds[i].len() {
                errs.push(format!("node {i} has parallel in-edges"));
            }
        }
        errs
    }

    /// Longest path length in *nodes* (unmapped critical path), used as
    /// the latency baseline denominator in Fig 10.
    pub fn critical_path_nodes(&self) -> usize {
        let order = self.topo_order().expect("DAG");
        let preds = self.preds();
        let mut depth = vec![1usize; self.nodes.len()];
        for &u in &order {
            for &p in &preds[u as usize] {
                depth[u as usize] = depth[u as usize].max(depth[p as usize] + 1);
            }
        }
        depth.into_iter().max().unwrap_or(0)
    }
}

/// Per-group maximum op count across a set of DFGs — the theoretical
/// minimum number of group instances a layout must provide (Section
/// III-D), used for pruning and for the Fig 6 bound.
pub fn min_group_instances(dfgs: &[Dfg]) -> [usize; NUM_GROUPS] {
    let mut m = [0usize; NUM_GROUPS];
    for d in dfgs {
        let h = d.group_histogram();
        for i in 0..NUM_GROUPS {
            m[i] = m[i].max(h[i]);
        }
    }
    m
}

/// Union of groups used across a set of DFGs (defines the full layout).
pub fn groups_used(dfgs: &[Dfg]) -> GroupSet {
    let mut s = GroupSet::EMPTY;
    for d in dfgs {
        s = s.union(d.groups_used());
    }
    s
}

/// Convenience: per-group op count of one DFG restricted to compute groups.
pub fn compute_group_count(d: &Dfg, g: OpGroup) -> usize {
    d.group_histogram()[g.index()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::Op::*;

    fn tiny() -> Dfg {
        // load -> add -> store ; load -> add
        Dfg::new(
            "tiny",
            vec![Load, Load, Add, Store],
            vec![(0, 2), (1, 2), (2, 3)],
        )
    }

    #[test]
    fn tiny_is_valid() {
        assert!(tiny().validate().is_empty());
    }

    #[test]
    fn topo_order_is_topological() {
        let d = tiny();
        let order = d.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; d.num_nodes()];
            for (i, &n) in order.iter().enumerate() {
                p[n as usize] = i;
            }
            p
        };
        for &(s, t) in &d.edges {
            assert!(pos[s as usize] < pos[t as usize]);
        }
    }

    #[test]
    fn cycle_detected() {
        let d = Dfg::new("cyc", vec![Add, Add], vec![(0, 1), (1, 0)]);
        assert!(d.topo_order().is_none());
        assert!(d.validate().iter().any(|e| e.contains("cycle")));
    }

    #[test]
    fn histogram_and_groups() {
        let d = tiny();
        let h = d.group_histogram();
        assert_eq!(h[OpGroup::Mem.index()], 3);
        assert_eq!(h[OpGroup::Arith.index()], 1);
        assert!(d.groups_used().contains(OpGroup::Mem));
        assert!(d.groups_used().contains(OpGroup::Arith));
        assert!(!d.groups_used().contains(OpGroup::Div));
        assert_eq!(d.mem_ops(), 3);
        assert_eq!(d.compute_ops(), 1);
    }

    #[test]
    fn min_instances_is_per_group_max() {
        let a = Dfg::new("a", vec![Load, Mul, Mul, Store], vec![(0, 1), (1, 2), (2, 3)]);
        let b = tiny();
        let m = min_group_instances(&[a, b]);
        assert_eq!(m[OpGroup::Mult.index()], 2);
        assert_eq!(m[OpGroup::Arith.index()], 1);
        assert_eq!(m[OpGroup::Mem.index()], 3);
    }

    #[test]
    fn critical_path_counts_nodes() {
        assert_eq!(tiny().critical_path_nodes(), 3); // load->add->store
    }

    #[test]
    fn invalid_arity_flagged() {
        // add with 3 inputs
        let d = Dfg::new(
            "bad",
            vec![Load, Load, Load, Add, Store],
            vec![(0, 3), (1, 3), (2, 3), (3, 4)],
        );
        assert!(d.validate().iter().any(|e| e.contains("indeg")));
    }

    #[test]
    fn uses_any_matches_selective_testing_rule() {
        let d = tiny();
        let mut only_div = GroupSet::EMPTY;
        only_div.insert(OpGroup::Div);
        assert!(!d.uses_any(only_div));
        assert!(d.uses_any(only_div.with(OpGroup::Arith)));
    }
}
