//! PJRT runtime: load and execute the AOT-compiled XLA artifacts.
//!
//! `python/compile/aot.py` lowers the Layer-2 JAX functions (which call
//! the Layer-1 Pallas kernels) to HLO **text** once at build time
//! (`make artifacts`); this module loads that text with
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client
//! and executes it from the search hot path. Python never runs here.
//!
//! Artifacts (shapes fixed at AOT time, zero-padded at call time):
//!
//! * `layout_cost.hlo.txt` —
//!   `(layouts f32[B,C,G], gcosts f32[G], base f32[1]) -> (cost f32[B],)`
//!   with B=256, C=512, G=8. Equation 1 over cell-level layout bitmaps.
//! * `heatmap_stats.hlo.txt` —
//!   `(mappings f32[D,C,G]) -> (heatmap f32[C,G], min_insts f32[G])`
//!   with D=16: the per-cell union over DFGs and the per-group theoretical
//!   minimum instance counts (Sections III-D/III-E).

use crate::cgra::Layout;
use crate::cost::CostModel;
use crate::ops::{OpGroup, NUM_GROUPS};
use crate::search::BatchScorer;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// PJRT bindings. Build environments without the XLA C++ runtime get the
/// in-tree stand-in ([`stub`]-backed `xla` module): the API surface is
/// identical, but client construction fails, `Scorer::load` returns an
/// error, and every consumer falls back to [`crate::search::NativeScorer`]
/// semantics. To use real PJRT, replace this module declaration with the
/// `xla` crate dependency; no other code changes.
#[path = "stub.rs"]
mod xla;

/// AOT shape constants — must match `python/compile/aot.py`.
pub const BATCH: usize = 256;
pub const CELLS_PAD: usize = 512;
pub const GROUPS_PAD: usize = 8;
pub const DFGS_PAD: usize = 16;

/// Default artifact directory, overridable with `HELEX_ARTIFACTS`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("HELEX_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn load_exe(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("artifact path not utf-8")?,
    )
    .with_context(|| format!("loading HLO text from {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).context("PJRT compile failed")
}

/// The PJRT-backed batch scorer.
pub struct Scorer {
    client: xla::PjRtClient,
    cost_exe: xla::PjRtLoadedExecutable,
    heatmap_exe: Option<xla::PjRtLoadedExecutable>,
    /// Padded group-cost vector for the current cost model.
    gcosts: Vec<f32>,
    base_per_cell: f64,
    /// Executions performed (for perf accounting).
    pub calls: usize,
}

impl Scorer {
    /// Load artifacts from `dir` for the given cost model.
    pub fn load(dir: &Path, cost: &CostModel) -> Result<Self> {
        let cost_path = dir.join("layout_cost.hlo.txt");
        if !cost_path.exists() {
            bail!(
                "artifact {} missing — run `make artifacts` first",
                cost_path.display()
            );
        }
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let cost_exe = load_exe(&client, &cost_path)?;
        let heatmap_path = dir.join("heatmap_stats.hlo.txt");
        let heatmap_exe = if heatmap_path.exists() {
            Some(load_exe(&client, &heatmap_path)?)
        } else {
            None
        };
        let mut gcosts = vec![0f32; GROUPS_PAD];
        for g in crate::ops::ALL_GROUPS {
            gcosts[g.index()] = cost.components.group[g.index()] as f32;
        }
        Ok(Self {
            client,
            cost_exe,
            heatmap_exe,
            gcosts,
            base_per_cell: cost.components.empty_cell + cost.components.fifos,
            calls: 0,
        })
    }

    /// Convenience: load from the default artifact dir with area costs.
    pub fn load_default() -> Result<Self> {
        Self::load(&artifacts_dir(), &CostModel::area())
    }

    pub fn has_heatmap_artifact(&self) -> bool {
        self.heatmap_exe.is_some()
    }

    fn execute_cost(&mut self, layouts: Vec<f32>, base: f32) -> Result<Vec<f32>> {
        let x = xla::Literal::vec1(&layouts).reshape(&[
            BATCH as i64,
            CELLS_PAD as i64,
            GROUPS_PAD as i64,
        ])?;
        let g = xla::Literal::vec1(&self.gcosts);
        let b = xla::Literal::vec1(&[base]);
        let result = self.cost_exe.execute::<xla::Literal>(&[x, g, b])?[0][0]
            .to_literal_sync()?;
        self.calls += 1;
        let tuple = result.to_tuple1()?;
        Ok(tuple.to_vec::<f32>()?)
    }

    /// Score up to any number of cell-level layouts exactly (chunked into
    /// BATCH-sized PJRT executions).
    pub fn score_layouts(&mut self, layouts: &[Layout]) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(layouts.len());
        for chunk in layouts.chunks(BATCH) {
            let nt = chunk[0].grid.num_compute();
            let base = (nt as f64 * self.base_per_cell) as f32;
            let mut buf = vec![0f32; BATCH * CELLS_PAD * GROUPS_PAD];
            for (bi, l) in chunk.iter().enumerate() {
                assert!(l.grid.num_cells() <= CELLS_PAD, "grid exceeds CELLS_PAD");
                assert_eq!(l.grid.num_compute(), nt, "mixed grids in one chunk");
                for (ci, cell) in l.grid.compute_cells().enumerate() {
                    let s = l.support(cell);
                    for g in s.iter() {
                        buf[(bi * CELLS_PAD + ci) * GROUPS_PAD + g.index()] = 1.0;
                    }
                }
            }
            let costs = self.execute_cost(buf, base)?;
            out.extend(costs[..chunk.len()].iter().map(|&c| c as f64));
        }
        Ok(out)
    }

    /// Score per-group instance vectors. Costs are linear in instance
    /// counts, so counts are spread over pseudo-cells; results equal the
    /// cell-level scoring exactly.
    pub fn score_instance_vectors(
        &mut self,
        num_compute_cells: usize,
        vectors: &[[usize; NUM_GROUPS]],
    ) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(vectors.len());
        for chunk in vectors.chunks(BATCH) {
            let base = (num_compute_cells as f64 * self.base_per_cell) as f32;
            let mut buf = vec![0f32; BATCH * CELLS_PAD * GROUPS_PAD];
            for (bi, v) in chunk.iter().enumerate() {
                for g in crate::ops::COMPUTE_GROUPS {
                    let mut remaining = v[g.index()];
                    let mut ci = 0;
                    while remaining > 0 {
                        // pack counts as 0/1 over pseudo-cells
                        buf[(bi * CELLS_PAD + ci) * GROUPS_PAD + g.index()] = 1.0;
                        remaining -= 1;
                        ci += 1;
                        assert!(ci <= CELLS_PAD, "instance count exceeds CELLS_PAD");
                    }
                }
            }
            let costs = self.execute_cost(buf, base)?;
            out.extend(costs[..chunk.len()].iter().map(|&c| c as f64));
        }
        Ok(out)
    }

    /// Run the heatmap-stats artifact over per-DFG usage bitmaps:
    /// returns (per-cell union bitmap, per-group minimum instances).
    pub fn heatmap_stats(
        &mut self,
        usage: &[Vec<[f32; NUM_GROUPS]>], // [dfg][cell][group]
    ) -> Result<(Vec<[f32; GROUPS_PAD]>, [f64; NUM_GROUPS])> {
        let exe = self
            .heatmap_exe
            .as_ref()
            .context("heatmap_stats.hlo.txt not loaded")?;
        assert!(usage.len() <= DFGS_PAD, "too many DFGs for DFGS_PAD");
        let ncells = usage.first().map_or(0, |u| u.len());
        assert!(ncells <= CELLS_PAD);
        let mut buf = vec![0f32; DFGS_PAD * CELLS_PAD * GROUPS_PAD];
        for (d, cells) in usage.iter().enumerate() {
            for (c, groups) in cells.iter().enumerate() {
                for (g, &v) in groups.iter().enumerate() {
                    buf[(d * CELLS_PAD + c) * GROUPS_PAD + g] = v;
                }
            }
        }
        let x = xla::Literal::vec1(&buf).reshape(&[
            DFGS_PAD as i64,
            CELLS_PAD as i64,
            GROUPS_PAD as i64,
        ])?;
        let result = exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        self.calls += 1;
        let (heat_lit, mins_lit) = result.to_tuple2()?;
        let heat_flat = heat_lit.to_vec::<f32>()?;
        let mins_flat = mins_lit.to_vec::<f32>()?;
        let mut heat = vec![[0f32; GROUPS_PAD]; CELLS_PAD];
        for c in 0..CELLS_PAD {
            for g in 0..GROUPS_PAD {
                heat[c][g] = heat_flat[c * GROUPS_PAD + g];
            }
        }
        let mut mins = [0f64; NUM_GROUPS];
        for g in 0..NUM_GROUPS {
            mins[g] = mins_flat[g] as f64;
        }
        Ok((heat, mins))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

impl BatchScorer for Scorer {
    fn score(
        &mut self,
        num_compute_cells: usize,
        instance_vectors: &[[usize; NUM_GROUPS]],
    ) -> Vec<f64> {
        self.score_instance_vectors(num_compute_cells, instance_vectors)
            .expect("PJRT execution failed")
    }
}

/// Sanity cross-check used by the coordinator on startup: XLA and native
/// scorers must agree on a sample of layouts.
pub fn cross_check(scorer: &mut Scorer, cost: &CostModel, layouts: &[Layout]) -> Result<f64> {
    let xla_costs = scorer.score_layouts(layouts)?;
    let mut max_rel = 0.0f64;
    for (l, &xc) in layouts.iter().zip(&xla_costs) {
        let nc = cost.layout_cost(l);
        let rel = ((xc - nc) / nc).abs();
        max_rel = max_rel.max(rel);
    }
    if max_rel > 1e-3 {
        bail!("XLA/native scorer disagreement: max rel err {max_rel}");
    }
    Ok(max_rel)
}

/// Mem index helper re-exported for artifact-layout documentation.
pub fn mem_group_index() -> usize {
    OpGroup::Mem.index()
}

#[cfg(test)]
mod tests {
    // Runtime tests that need artifacts live in rust/tests/
    // runtime_integration.rs (they require `make artifacts` first).
    use super::*;

    #[test]
    fn artifact_dir_env_override() {
        std::env::set_var("HELEX_ARTIFACTS", "/tmp/helex_artifacts_test");
        assert_eq!(artifacts_dir(), PathBuf::from("/tmp/helex_artifacts_test"));
        std::env::remove_var("HELEX_ARTIFACTS");
        assert_eq!(artifacts_dir(), PathBuf::from("artifacts"));
    }

    #[test]
    fn missing_artifacts_error_is_friendly() {
        let err = Scorer::load(Path::new("/nonexistent"), &CostModel::area())
            .err()
            .unwrap()
            .to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn shape_constants_cover_paper_grids() {
        // biggest grid in the paper: 20x20 comparison = 400 cells
        assert!(20 * 20 <= CELLS_PAD);
        assert!(crate::ops::NUM_GROUPS <= GROUPS_PAD);
        assert!(12 <= DFGS_PAD); // 12 Table II DFGs
    }
}
