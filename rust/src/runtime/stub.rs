//! Offline stand-in for the `xla` PJRT bindings.
//!
//! This build does not ship the XLA C++ runtime, so this module mirrors
//! the slice of the `xla` crate's API that [`super`] uses and fails at
//! client-construction time. `Scorer::load` therefore returns a friendly
//! error and every consumer falls back to native scoring. Swapping the
//! real bindings back in means deleting this module (and its `mod xla`
//! declaration in `runtime/mod.rs`) and adding the `xla` crate to
//! `Cargo.toml`; no other code changes.

use std::fmt;

/// Error produced by every stub entry point.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: XLA/PJRT runtime not available in this build (native scoring is used instead)"
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal), XlaError> {
        Err(unavailable("Literal::to_tuple2"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable("Literal::to_vec"))
    }
}
